"""Fused *activation-quantized* branched matmul: int8 x int8 per branch.

Activation-quantized variant of :mod:`repro.kernels.branched_matmul_q`
(same ``(M/bm, S/bn, N)`` branch-innermost grid, same f32 branch-sum
accumulator): the activation rows quantize once per row-block into an
int8 VMEM scratch (per-token absmax scales, see
:func:`repro.kernels.lowrank_matmul_qa.quantize_rows`), and every
branch's three-stage chain runs int8 x int8 with int32 accumulation —
each rank intermediate is dequantized by its row x channel scale
product and immediately requantized per-row, so no activation tile at
f32 width ever hits the MXU.

Scale folding order per branch: ``x_scale * u_scale`` after stage 1,
``h1_scale * xc_scale`` after stage 2, ``h2_scale * v_scale`` after
stage 3; the f32 branch contributions then sum in the scratch
accumulator exactly like the weight-only kernel.

Padding discipline: per-token scales are row-local, so bucket-padded
all-zero rows quantize to zero rows with scale 0 and contribute exactly
zero to every branch — real rows never see padding in their scales.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.lowrank_matmul import CompilerParams
from repro.kernels.lowrank_matmul_qa import quantize_rows

DEFAULT_BM = 256
DEFAULT_BN = 256


def _kernel(x_ref, uq_ref, us_ref, xcq_ref, xcs_ref, vq_ref, vs_ref,
            o_ref, acc_ref, xq_ref, xs_ref):
    """x (bm,C); u_q (1,C,r1) + u_scale (1,1,r1); xc_q (1,r1,r2) +
    xc_scale (1,1,r2); v_q (1,r2,bn) + v_scale (1,1,bn); o (bm,bn);
    scratch: acc (bm,bn) f32, xq (bm,C) int8, xs (bm,1) f32."""
    j = pl.program_id(1)
    n = pl.program_id(2)
    n_total = pl.num_programs(2)

    @pl.when((j == 0) & (n == 0))
    def _quantize_x():
        xq_ref[...], xs_ref[...] = quantize_rows(x_ref[...])

    h1 = (jnp.dot(xq_ref[...], uq_ref[0],
                  preferred_element_type=jnp.int32).astype(jnp.float32)
          * xs_ref[...] * us_ref[0])
    h1q, h1s = quantize_rows(h1)
    h2 = (jnp.dot(h1q, xcq_ref[0],
                  preferred_element_type=jnp.int32).astype(jnp.float32)
          * h1s * xcs_ref[0])
    h2q, h2s = quantize_rows(h2)
    contrib = (jnp.dot(h2q, vq_ref[0],
                       preferred_element_type=jnp.int32).astype(jnp.float32)
               * h2s * vs_ref[0])

    @pl.when(n == 0)
    def _init():
        acc_ref[...] = contrib

    @pl.when(n > 0)
    def _accum():
        acc_ref[...] += contrib

    @pl.when(n == n_total - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def branched_matmul_qa(x: jax.Array, u_q: jax.Array, u_scale: jax.Array,
                       xc_q: jax.Array, xc_scale: jax.Array,
                       v_q: jax.Array, v_scale: jax.Array, *,
                       bm: int = DEFAULT_BM, bn: int = DEFAULT_BN,
                       interpret: bool = False) -> jax.Array:
    """x (M,C); u_q (N,C,r1); xc_q (N,r1,r2); v_q (N,r2,S) + per-branch
    per-output-channel scales -> (M,S), all dots int8 x int8.  Requires
    M % bm == 0 and S % bn == 0 (ops.py pads)."""
    m, c = x.shape
    n, c2, r1 = u_q.shape
    _, _, r2 = xc_q.shape
    _, _, s = v_q.shape
    assert c == c2, (x.shape, u_q.shape)
    assert u_scale.shape == (n, 1, r1) and xc_scale.shape == (n, 1, r2) \
        and v_scale.shape == (n, 1, s), \
        (u_scale.shape, xc_scale.shape, v_scale.shape)
    assert m % bm == 0 and s % bn == 0, (m, s, bm, bn)

    grid = (m // bm, s // bn, n)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, c), lambda i, j, k: (i, 0)),
            pl.BlockSpec((1, c, r1), lambda i, j, k: (k, 0, 0)),
            pl.BlockSpec((1, 1, r1), lambda i, j, k: (k, 0, 0)),
            pl.BlockSpec((1, r1, r2), lambda i, j, k: (k, 0, 0)),
            pl.BlockSpec((1, 1, r2), lambda i, j, k: (k, 0, 0)),
            pl.BlockSpec((1, r2, bn), lambda i, j, k: (k, 0, j)),
            pl.BlockSpec((1, 1, bn), lambda i, j, k: (k, 0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, s), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32),
                        pltpu.VMEM((bm, c), jnp.int8),
                        pltpu.VMEM((bm, 1), jnp.float32)],
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(x, u_q, u_scale, xc_q, xc_scale, v_q, v_scale)


def vmem_bytes(m_block: int, c: int, r1: int, r2: int, s_block: int,
               act_bytes: int = 2, q_bytes: int = 1) -> int:
    """VMEM footprint of one grid step (fit check used by ops.py).

    Counts the f32 activation block, the int8 activation scratch + row
    scales, the quantized branch tiles + their channel scales, the
    transient int8/f32 rank intermediates, and the f32 branch
    accumulator + out block.
    """
    return (m_block * c * act_bytes                    # x block
            + m_block * c + m_block * 4                # int8 x scratch + scales
            + (c * r1 + r1 * r2 + r2 * s_block) * q_bytes
            + (r1 + r2 + s_block) * 4                  # channel scales
            + m_block * (r1 + r2) * (1 + 4)            # int8+f32 intermediates
            + 2 * m_block * 4                          # h1/h2 row scales
            + m_block * s_block * (act_bytes + 2 * 4))  # out + acc + contrib

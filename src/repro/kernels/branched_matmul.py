"""Branched (block-diagonal) low-rank matmul Pallas kernel — paper Fig. 4.

Computes ``y = sum_n ((x @ u_n) @ xc_n) @ v_n`` — the grouped-matmul
realization of branched Tucker/SVD on the MXU.  Each branch's chain runs
entirely in VMEM (two rank-bottleneck intermediates never touch HBM) and
the branch sum accumulates into a VMEM f32 accumulator.

Grid: ``(M/bm, S/bn, N)`` with the branch dim innermost (the output block
is revisited across consecutive branch steps — the Pallas reduction
pattern).  Per-branch weights ``u_n (C, r1)``, ``xc_n (r1, r2)``,
``v_n (r2, bn)`` stream through VMEM one branch at a time, which is how
the paper's "N x smaller core" translates into N x smaller *working set*
on TPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.lowrank_matmul import CompilerParams

DEFAULT_BM = 256
DEFAULT_BN = 256


def _kernel(x_ref, u_ref, xc_ref, v_ref, o_ref, acc_ref):
    """x (bm,C); u (1,C,r1); xc (1,r1,r2); v (1,r2,bn); o (bm,bn);
    acc (bm,bn) f32 scratch."""
    n = pl.program_id(2)
    n_total = pl.num_programs(2)

    h1 = jnp.dot(x_ref[...], u_ref[0],
                 preferred_element_type=jnp.float32).astype(x_ref.dtype)
    h2 = jnp.dot(h1, xc_ref[0],
                 preferred_element_type=jnp.float32).astype(x_ref.dtype)
    contrib = jnp.dot(h2, v_ref[0], preferred_element_type=jnp.float32)

    @pl.when(n == 0)
    def _init():
        acc_ref[...] = contrib

    @pl.when(n > 0)
    def _accum():
        acc_ref[...] += contrib

    @pl.when(n == n_total - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def branched_matmul(x: jax.Array, u: jax.Array, xc: jax.Array,
                    v: jax.Array, *, bm: int = DEFAULT_BM,
                    bn: int = DEFAULT_BN, interpret: bool = False
                    ) -> jax.Array:
    """x (M,C); u (N,C,r1); xc (N,r1,r2); v (N,r2,S) -> (M,S)."""
    m, c = x.shape
    n, c2, r1 = u.shape
    _, _, r2 = xc.shape
    _, _, s = v.shape
    assert c == c2, (x.shape, u.shape)
    assert m % bm == 0 and s % bn == 0, (m, s, bm, bn)

    grid = (m // bm, s // bn, n)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, c), lambda i, j, k: (i, 0)),
            pl.BlockSpec((1, c, r1), lambda i, j, k: (k, 0, 0)),
            pl.BlockSpec((1, r1, r2), lambda i, j, k: (k, 0, 0)),
            pl.BlockSpec((1, r2, bn), lambda i, j, k: (k, 0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, s), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(x, u, xc, v)


def vmem_bytes(m_block: int, c: int, r1: int, r2: int, s_block: int,
               dtype_bytes: int = 2) -> int:
    return (m_block * c * dtype_bytes
            + c * r1 * dtype_bytes + r1 * r2 * dtype_bytes
            + r2 * s_block * dtype_bytes
            + 2 * m_block * s_block * (dtype_bytes + 4))
"""Fused low-rank matmul Pallas kernel: y = (x @ w0) @ w1.

The whole point of the kernel (DESIGN.md §3): the rank-bottleneck
intermediate ``h = x @ w0`` ( M x R ) stays in a VMEM scratch accumulator
and **never round-trips to HBM**.  XLA on its own materializes ``h``
between the two dots; at training token counts (M ~ 1e6, R ~ 512, bf16)
that is ~1 GB of avoidable HBM traffic per decomposed layer per step —
the TPU analogue of the paper's "more layers = more latency" complaint.

Grid: ``(M/bm, S/bn)`` with j innermost.  At ``j == 0`` the kernel
computes ``h_i = x_i @ w0`` (full C and R resident in VMEM) into scratch;
every j-step then computes ``y_ij = h_i @ w1_j`` on the MXU.  Both
matmuls accumulate in f32.

Block shapes are MXU-aligned (multiples of 128 lanes / 8 sublanes) —
which is exactly why the paper's §2.1 rank alignment matters: an
unaligned R pads w0/w1 tiles with zeros and burns MXU cycles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams; support both.
CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams


DEFAULT_BM = 256
DEFAULT_BN = 256


def _kernel(x_ref, w0_ref, w1_ref, o_ref, h_ref):
    """x (bm, C); w0 (C, R); w1 (R, bn); o (bm, bn); scratch h (bm, R) f32."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _compute_h():
        h_ref[...] = jnp.dot(x_ref[...], w0_ref[...],
                             preferred_element_type=jnp.float32)

    h = h_ref[...].astype(x_ref.dtype)
    o_ref[...] = jnp.dot(h, w1_ref[...],
                         preferred_element_type=jnp.float32
                         ).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("bm", "bn", "interpret"))
def lowrank_matmul(x: jax.Array, w0: jax.Array, w1: jax.Array, *,
                   bm: int = DEFAULT_BM, bn: int = DEFAULT_BN,
                   interpret: bool = False) -> jax.Array:
    """y = (x @ w0) @ w1, fused. x (M,C); w0 (C,R); w1 (R,S) -> (M,S).

    Requires M % bm == 0 and S % bn == 0 (ops.py pads & dispatches).
    """
    m, c = x.shape
    c2, r = w0.shape
    r2, s = w1.shape
    assert c == c2 and r == r2, (x.shape, w0.shape, w1.shape)
    assert m % bm == 0 and s % bn == 0, (m, s, bm, bn)

    grid = (m // bm, s // bn)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, c), lambda i, j: (i, 0)),
            pl.BlockSpec((c, r), lambda i, j: (0, 0)),
            pl.BlockSpec((r, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, s), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, r), jnp.float32)],
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
    )(x, w0, w1)


def vmem_bytes(m_block: int, c: int, r: int, s_block: int,
               dtype_bytes: int = 2) -> int:
    """VMEM footprint of one grid step (fit check used by ops.py)."""
    return (m_block * c * dtype_bytes          # x block
            + c * r * dtype_bytes              # w0 (resident)
            + r * s_block * dtype_bytes        # w1 block
            + m_block * s_block * dtype_bytes  # out block
            + m_block * r * 4)                 # f32 scratch h

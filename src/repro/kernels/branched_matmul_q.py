"""Fused *quantized* branched matmul: y = sum_n ((x @ dq(u_n)) @ dq(xc_n)) @ dq(v_n).

Weight-only quantized variant of :mod:`repro.kernels.branched_matmul`
(same grid, same branch-sum scratch accumulator): each branch's factor
tiles arrive in VMEM as int8 (or fp8) values plus f32 per-output-channel
scales, are dequantized *in VMEM* right before the MXU dots, and both
rank-bottleneck intermediates plus the branch-sum accumulator never
touch HBM.  Before this kernel, quantized branched/Tucker layers
dequantized *outside* the kernel (a full-size bf16 weight materialized
in HBM per step), forfeiting exactly the bandwidth the quantization was
bought for.

Grid: ``(M/bm, S/bn, N)`` with the branch dim innermost — the output
block is revisited across consecutive branch steps (the Pallas reduction
pattern), so per-branch weights stream through VMEM one branch at a
time at int8 width: the paper's "N x smaller core" (Eq. 17) compounds
with the 2x narrower storage into a 2N x smaller working set vs the
dense bf16 layer.

Scales follow :mod:`repro.quant.quantize` (absmax over the input axis,
one f32 scale per output channel, per branch): ``u_scale (N, 1, r1)``,
``xc_scale (N, 1, r2)``, ``v_scale (N, 1, S)``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.lowrank_matmul import CompilerParams

DEFAULT_BM = 256
DEFAULT_BN = 256


def _kernel(x_ref, uq_ref, us_ref, xcq_ref, xcs_ref, vq_ref, vs_ref,
            o_ref, acc_ref):
    """x (bm,C); u_q (1,C,r1) + u_scale (1,1,r1); xc_q (1,r1,r2) +
    xc_scale (1,1,r2); v_q (1,r2,bn) + v_scale (1,1,bn); o (bm,bn);
    acc (bm,bn) f32 scratch."""
    n = pl.program_id(2)
    n_total = pl.num_programs(2)

    u = (uq_ref[0].astype(jnp.float32) * us_ref[0]).astype(x_ref.dtype)
    xc = (xcq_ref[0].astype(jnp.float32) * xcs_ref[0]).astype(x_ref.dtype)
    v = (vq_ref[0].astype(jnp.float32) * vs_ref[0]).astype(x_ref.dtype)

    h1 = jnp.dot(x_ref[...], u,
                 preferred_element_type=jnp.float32).astype(x_ref.dtype)
    h2 = jnp.dot(h1, xc,
                 preferred_element_type=jnp.float32).astype(x_ref.dtype)
    contrib = jnp.dot(h2, v, preferred_element_type=jnp.float32)

    @pl.when(n == 0)
    def _init():
        acc_ref[...] = contrib

    @pl.when(n > 0)
    def _accum():
        acc_ref[...] += contrib

    @pl.when(n == n_total - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def branched_matmul_q(x: jax.Array, u_q: jax.Array, u_scale: jax.Array,
                      xc_q: jax.Array, xc_scale: jax.Array,
                      v_q: jax.Array, v_scale: jax.Array, *,
                      bm: int = DEFAULT_BM, bn: int = DEFAULT_BN,
                      interpret: bool = False) -> jax.Array:
    """x (M,C); u_q (N,C,r1); xc_q (N,r1,r2); v_q (N,r2,S) + per-branch
    per-output-channel scales -> (M,S).  Requires M % bm == 0 and
    S % bn == 0 (ops.py pads)."""
    m, c = x.shape
    n, c2, r1 = u_q.shape
    _, _, r2 = xc_q.shape
    _, _, s = v_q.shape
    assert c == c2, (x.shape, u_q.shape)
    assert u_scale.shape == (n, 1, r1) and xc_scale.shape == (n, 1, r2) \
        and v_scale.shape == (n, 1, s), \
        (u_scale.shape, xc_scale.shape, v_scale.shape)
    assert m % bm == 0 and s % bn == 0, (m, s, bm, bn)

    grid = (m // bm, s // bn, n)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, c), lambda i, j, k: (i, 0)),
            pl.BlockSpec((1, c, r1), lambda i, j, k: (k, 0, 0)),
            pl.BlockSpec((1, 1, r1), lambda i, j, k: (k, 0, 0)),
            pl.BlockSpec((1, r1, r2), lambda i, j, k: (k, 0, 0)),
            pl.BlockSpec((1, 1, r2), lambda i, j, k: (k, 0, 0)),
            pl.BlockSpec((1, r2, bn), lambda i, j, k: (k, 0, j)),
            pl.BlockSpec((1, 1, bn), lambda i, j, k: (k, 0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, s), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(x, u_q, u_scale, xc_q, xc_scale, v_q, v_scale)


def vmem_bytes(m_block: int, c: int, r1: int, r2: int, s_block: int,
               act_bytes: int = 2, q_bytes: int = 1) -> int:
    """VMEM footprint of one grid step (fit check used by ops.py).

    Counts the quantized branch tiles + scales, their dequantized
    activation-width copies, and the f32 branch accumulator + out block.
    """
    deq = (c * r1 + r1 * r2 + r2 * s_block) * act_bytes
    return (m_block * c * act_bytes
            + (c * r1 + r1 * r2 + r2 * s_block) * q_bytes
            + (r1 + r2 + s_block) * 4
            + deq
            + 2 * m_block * s_block * (act_bytes + 4))

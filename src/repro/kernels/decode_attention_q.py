"""Fused int8-KV decode attention: one query row vs a quantized cache.

The decode step is the roofline's memory corner: each new token streams
the entire KV pool ``(slots, S_max, KV_heads, head_dim)`` through the
core just to attend one query.  Quantizing the pool
(:mod:`repro.quant.kv`) shrinks those bytes 4x vs f32 — but only if the
attention read consumes int8 *directly*.  A dequantize-then-attend
fallback materializes a full-precision pool copy in HBM every step and
hands the win straight back.  This kernel keeps the narrow bytes all
the way into VMEM:

* int8 K/V tiles stream in per ``(slot, kv_head)`` program;
* per-(slot, head, channel) scales (:mod:`repro.quant.kv` layout) fold
  into the *query* row for K (``(q * k_scale) @ k_q^T == q @ dq(k)^T``)
  and into the final output for V (``(p @ v_q) * v_scale``) — O(D)
  multiplies replace O(S*D) dequantization work;
* online softmax over sequence blocks: f32 running max / sum /
  accumulator live in VMEM scratch across the arbitrary grid dim, so
  logits for the full S_max never materialize;
* per-slot validity is masked from ``cache_pos`` (position ``p`` is
  live iff ``p <= cache_pos[slot]`` — the slot's freshly written token
  included), which also neutralizes the S padding ``ops.py`` adds.

Grid: ``(B, KV_heads, S/bs)`` with the sequence dim innermost
(arbitrary); slots and heads are parallel.  The GQA group of G = H/KH
query heads rides along as rows of the q/out tiles, so one pass over a
K/V tile serves the whole group.

``decode_attention_latent_q`` is the MLA twin: the absorbed decode form
attends latent-space queries against an int8 *latent* pool
(``ckv_q (B, S, r)`` + ``krope_q (B, S, rope)``, per-(slot, channel)
f32 scales — no head axis, every head shares the one latent stream).
The ckv/krope scales fold into the two latent query rows for the
logits, and the ckv scales into the context output (the "V" of latent
attention is the ckv stream again), so the int8 latents are consumed
directly — same online-softmax scratch discipline, grid ``(B, S/bs)``
with all H heads riding as tile rows.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.lowrank_matmul import CompilerParams

DEFAULT_BS = 128
_NEG_INF = -1e30
_MINOR = 128        # f32 scratch lane width for the (G, 1) running stats


def _kernel(q_ref, kq_ref, ks_ref, vq_ref, vs_ref, cp_ref, o_ref,
            acc_ref, m_ref, l_ref, *, scale, softcap):
    """q (1,1,G,D); k_q/v_q (1,bs,1,D) int8; k/v_scale (1,1,D) f32;
    cache_pos (1,1) i32 SMEM; o (1,1,G,D); scratch acc (G,D),
    m/l (G,128) f32 (col 0 live, broadcast across lanes)."""
    si = pl.program_id(2)
    ns = pl.num_programs(2)
    bs = kq_ref.shape[1]

    @pl.when(si == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)                     # (G, D)
    ks = ks_ref[0, 0].astype(jnp.float32)                   # (D,)
    kq = kq_ref[0, :, 0, :].astype(jnp.float32)             # (bs, D)
    # K scales + 1/sqrt(D) fold into the single query row.
    s = jnp.dot(q * (ks * scale)[None, :], kq.T,
                preferred_element_type=jnp.float32)         # (G, bs)
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    pos = si * bs + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)
    s = jnp.where(pos <= cp_ref[0, 0], s, _NEG_INF)

    m_prev = m_ref[:, :1]                                   # (G, 1)
    l_prev = l_ref[:, :1]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)                                  # (G, bs)
    vq = vq_ref[0, :, 0, :].astype(jnp.float32)             # (bs, D)
    acc = acc_ref[...] * alpha + jnp.dot(
        p, vq, preferred_element_type=jnp.float32)
    l_new = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc
    m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(si == ns - 1)
    def _flush():
        vs = vs_ref[0, 0].astype(jnp.float32)               # (D,)
        o = acc / l_new * vs[None, :]   # V scales fold into the output
        o_ref[0, 0] = o.astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("bs", "softcap", "interpret"))
def decode_attention_q(q: jax.Array, k_q: jax.Array, k_scale: jax.Array,
                       v_q: jax.Array, v_scale: jax.Array,
                       cache_pos: jax.Array, *, bs: int = DEFAULT_BS,
                       softcap: float = 0.0,
                       interpret: bool = False) -> jax.Array:
    """Fused decode attention over an int8 KV pool.

    q (B, KH, G, D); k_q/v_q (B, S, KH, D) int8; k/v_scale (B, KH, D)
    f32; cache_pos (B, 1) int32 -> (B, KH, G, D) in q.dtype.
    Requires S % bs == 0 (ops.py pads; padded positions mask out).
    """
    b, kh, g, d = q.shape
    _, s, kh2, d2 = k_q.shape
    assert (kh, d) == (kh2, d2), (q.shape, k_q.shape)
    assert k_q.shape == v_q.shape
    assert k_scale.shape == v_scale.shape == (b, kh, d), \
        (k_scale.shape, v_scale.shape)
    assert cache_pos.shape == (b, 1), cache_pos.shape
    assert s % bs == 0, (s, bs)

    grid = (b, kh, s // bs)
    kernel = functools.partial(_kernel, scale=1.0 / (d ** 0.5),
                               softcap=softcap)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, g, d), lambda i, j, k: (i, j, 0, 0)),
            pl.BlockSpec((1, bs, 1, d), lambda i, j, k: (i, k, j, 0)),
            pl.BlockSpec((1, 1, d), lambda i, j, k: (i, j, 0)),
            pl.BlockSpec((1, bs, 1, d), lambda i, j, k: (i, k, j, 0)),
            pl.BlockSpec((1, 1, d), lambda i, j, k: (i, j, 0)),
            pl.BlockSpec((1, 1), lambda i, j, k: (i, 0),
                         memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d), lambda i, j, k: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kh, g, d), q.dtype),
        scratch_shapes=[pltpu.VMEM((g, d), jnp.float32),
                        pltpu.VMEM((g, _MINOR), jnp.float32),
                        pltpu.VMEM((g, _MINOR), jnp.float32)],
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(q, k_q, k_scale, v_q, v_scale, cache_pos)


def vmem_bytes(g: int, d: int, s_block: int, act_bytes: int = 4,
               q_bytes: int = 1) -> int:
    """VMEM footprint of one grid step (fit check used by ops.py)."""
    return (g * d * act_bytes                 # q tile
            + 2 * s_block * d * q_bytes       # k_q + v_q tiles
            + 2 * d * 4                       # k/v scale rows
            + g * d * act_bytes               # out tile
            + g * d * 4                       # f32 accumulator
            + 2 * g * _MINOR * 4)             # running max / sum


# ---------------------------------------------------------------------------
# MLA latent variant: absorbed decode over an int8 latent pool
# ---------------------------------------------------------------------------

def _latent_kernel(ql_ref, qr_ref, cq_ref, cs_ref, rq_ref, rs_ref, cp_ref,
                   o_ref, acc_ref, m_ref, l_ref, *, scale):
    """q_lat (1,H,L); q_rope (1,H,R); ckv_q (1,bs,L) / krope_q (1,bs,R)
    int8; ckv/krope_scale (1,L)/(1,R) f32; cache_pos (1,1) i32 SMEM;
    o (1,H,L); scratch acc (H,L), m/l (H,128) f32 (col 0 live)."""
    si = pl.program_id(1)
    ns = pl.num_programs(1)
    bs = cq_ref.shape[1]

    @pl.when(si == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    ql = ql_ref[0].astype(jnp.float32)                      # (H, L)
    qr = qr_ref[0].astype(jnp.float32)                      # (H, R)
    cs = cs_ref[0].astype(jnp.float32)                      # (L,)
    rs = rs_ref[0].astype(jnp.float32)                      # (R,)
    cq = cq_ref[0].astype(jnp.float32)                      # (bs, L)
    rq = rq_ref[0].astype(jnp.float32)                      # (bs, R)
    # Latent + rope scales (and the 1/sqrt(nope+rope) logit scale) fold
    # into the two query rows: (ql * cs) @ cq^T == ql @ dq(ckv)^T.
    s = (jnp.dot(ql * (cs * scale)[None, :], cq.T,
                 preferred_element_type=jnp.float32)
         + jnp.dot(qr * (rs * scale)[None, :], rq.T,
                   preferred_element_type=jnp.float32))     # (H, bs)
    pos = si * bs + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)
    s = jnp.where(pos <= cp_ref[0, 0], s, _NEG_INF)

    m_prev = m_ref[:, :1]                                   # (H, 1)
    l_prev = l_ref[:, :1]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)                                  # (H, bs)
    acc = acc_ref[...] * alpha + jnp.dot(
        p, cq, preferred_element_type=jnp.float32)          # ctx over ckv
    l_new = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc
    m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(si == ns - 1)
    def _flush():
        o = acc / l_new * cs[None, :]   # ckv scales fold into the context
        o_ref[0] = o.astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("scale", "bs", "interpret"))
def decode_attention_latent_q(q_lat: jax.Array, q_rope: jax.Array,
                              ckv_q: jax.Array, ckv_scale: jax.Array,
                              krope_q: jax.Array, krope_scale: jax.Array,
                              cache_pos: jax.Array, *, scale: float,
                              bs: int = DEFAULT_BS,
                              interpret: bool = False) -> jax.Array:
    """Fused absorbed-form MLA decode over an int8 latent pool.

    q_lat (B, H, L); q_rope (B, H, R); ckv_q (B, S, L) / krope_q
    (B, S, R) int8; ckv/krope_scale (B, L)/(B, R) f32; cache_pos (B, 1)
    int32 -> context latents (B, H, L) in q_lat.dtype.  ``scale`` is
    the logit scale 1/sqrt(qk_nope + qk_rope).  Requires S % bs == 0
    (ops.py pads; padded positions mask out).
    """
    b, h, lora = q_lat.shape
    rope = q_rope.shape[-1]
    assert q_rope.shape == (b, h, rope), q_rope.shape
    s = ckv_q.shape[1]
    assert ckv_q.shape == (b, s, lora), (ckv_q.shape, q_lat.shape)
    assert krope_q.shape == (b, s, rope), krope_q.shape
    assert ckv_scale.shape == (b, lora), ckv_scale.shape
    assert krope_scale.shape == (b, rope), krope_scale.shape
    assert cache_pos.shape == (b, 1), cache_pos.shape
    assert s % bs == 0, (s, bs)

    grid = (b, s // bs)
    kernel = functools.partial(_latent_kernel, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, h, lora), lambda i, k: (i, 0, 0)),
            pl.BlockSpec((1, h, rope), lambda i, k: (i, 0, 0)),
            pl.BlockSpec((1, bs, lora), lambda i, k: (i, k, 0)),
            pl.BlockSpec((1, lora), lambda i, k: (i, 0)),
            pl.BlockSpec((1, bs, rope), lambda i, k: (i, k, 0)),
            pl.BlockSpec((1, rope), lambda i, k: (i, 0)),
            pl.BlockSpec((1, 1), lambda i, k: (i, 0),
                         memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((1, h, lora), lambda i, k: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, lora), q_lat.dtype),
        scratch_shapes=[pltpu.VMEM((h, lora), jnp.float32),
                        pltpu.VMEM((h, _MINOR), jnp.float32),
                        pltpu.VMEM((h, _MINOR), jnp.float32)],
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
    )(q_lat, q_rope, ckv_q, ckv_scale, krope_q, krope_scale, cache_pos)


def vmem_bytes_latent(h: int, lora: int, rope: int, s_block: int,
                      act_bytes: int = 4, q_bytes: int = 1) -> int:
    """VMEM footprint of one latent grid step (fit check for ops.py)."""
    return (h * (lora + rope) * act_bytes     # q_lat + q_rope tiles
            + s_block * (lora + rope) * q_bytes   # ckv_q + krope_q tiles
            + (lora + rope) * 4               # scale rows
            + h * lora * act_bytes            # out tile
            + h * lora * 4                    # f32 accumulator
            + 2 * h * _MINOR * 4)             # running max / sum

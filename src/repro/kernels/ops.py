"""Jit'd public wrappers for the Pallas kernels.

Handles:
* leading-batch flattening (``(..., C) -> (M, C)``),
* padding M/S up to tile multiples (and slicing back),
* interpret-mode on CPU (the container target) vs compiled on TPU,
* VMEM-fit dispatch — oversize geometries fall back to the jnp reference
  (which XLA fuses reasonably); the kernel covers the production-common
  block sizes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import branched_matmul as bk
from repro.kernels import lowrank_matmul as lk
from repro.kernels import lowrank_matmul_q as qk
from repro.kernels import ref

# v5e practical per-core VMEM working-set budget (conservative).
VMEM_BUDGET = 64 * 1024 * 1024


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x: jax.Array, axis: int, mult: int) -> tuple[jax.Array, int]:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x, 0
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), pad


def lowrank_matmul(x: jax.Array, w0: jax.Array, w1: jax.Array, *,
                   bm: int = lk.DEFAULT_BM, bn: int = lk.DEFAULT_BN,
                   force_kernel: bool = False) -> jax.Array:
    """y = (x @ w0) @ w1 with the fused kernel when it fits VMEM."""
    lead = x.shape[:-1]
    c = x.shape[-1]
    r, s = w1.shape
    x2 = x.reshape(-1, c)
    m = x2.shape[0]
    bm_eff = min(bm, max(8, m))
    fits = lk.vmem_bytes(bm_eff, c, r, min(bn, s)) <= VMEM_BUDGET
    if not (fits or force_kernel):
        return ref.lowrank_matmul_ref(x, w0, w1)
    x2, pad_m = _pad_to(x2, 0, bm_eff)
    w1p, pad_s = _pad_to(w1, 1, bn)
    y = lk.lowrank_matmul(x2, w0, w1p, bm=bm_eff, bn=min(bn, w1p.shape[1]),
                          interpret=not _on_tpu())
    if pad_m:
        y = y[:m]
    if pad_s:
        y = y[:, :s]
    return y.reshape(*lead, s)


def lowrank_matmul_q(x: jax.Array, w0_q: jax.Array, w0_scale: jax.Array,
                     w1_q: jax.Array, w1_scale: jax.Array, *,
                     bm: int = qk.DEFAULT_BM, bn: int = qk.DEFAULT_BN,
                     force_kernel: bool = False) -> jax.Array:
    """y = (x @ dq(w0)) @ dq(w1) with the fused quantized kernel."""
    lead = x.shape[:-1]
    c = x.shape[-1]
    r, s = w1_q.shape
    x2 = x.reshape(-1, c)
    m = x2.shape[0]
    bm_eff = min(bm, max(8, m))
    q_bytes = jnp.dtype(w0_q.dtype).itemsize
    fits = qk.vmem_bytes(bm_eff, c, r, min(bn, s),
                         q_bytes=q_bytes) <= VMEM_BUDGET
    if not (fits or force_kernel):
        return ref.lowrank_matmul_q_ref(x, w0_q, w0_scale, w1_q, w1_scale)
    x2, pad_m = _pad_to(x2, 0, bm_eff)
    w1p, pad_s = _pad_to(w1_q, 1, bn)
    w1sp, _ = _pad_to(w1_scale, 1, bn)     # zero scales -> zero columns
    y = qk.lowrank_matmul_q(x2, w0_q, w0_scale, w1p, w1sp,
                            bm=bm_eff, bn=min(bn, w1p.shape[1]),
                            interpret=not _on_tpu())
    if pad_m:
        y = y[:m]
    if pad_s:
        y = y[:, :s]
    return y.reshape(*lead, s)


def branched_matmul(x: jax.Array, u: jax.Array, xc: jax.Array,
                    v: jax.Array, *, bm: int = bk.DEFAULT_BM,
                    bn: int = bk.DEFAULT_BN,
                    force_kernel: bool = False) -> jax.Array:
    """y = sum_n ((x @ u_n) @ xc_n) @ v_n with the grouped kernel."""
    lead = x.shape[:-1]
    c = x.shape[-1]
    n, _, r1 = u.shape
    _, _, r2 = xc.shape
    s = v.shape[-1]
    x2 = x.reshape(-1, c)
    m = x2.shape[0]
    bm_eff = min(bm, max(8, m))
    fits = bk.vmem_bytes(bm_eff, c, r1, r2, min(bn, s)) <= VMEM_BUDGET
    if not (fits or force_kernel):
        return ref.branched_matmul_ref(x, u, xc, v)
    x2, pad_m = _pad_to(x2, 0, bm_eff)
    vp, pad_s = _pad_to(v, 2, bn)
    y = bk.branched_matmul(x2, u, xc, vp, bm=bm_eff,
                           bn=min(bn, vp.shape[2]),
                           interpret=not _on_tpu())
    if pad_m:
        y = y[:m]
    if pad_s:
        y = y[:, :s]
    return y.reshape(*lead, s)

"""Jit'd public wrappers for the Pallas kernels.

Handles:
* leading-batch flattening (``(..., C) -> (M, C)``),
* padding M/S up to tile multiples (and slicing back),
* interpret-mode on CPU (the container target) vs compiled on TPU,
* VMEM-fit dispatch — :func:`kernel_fits` is the single fit predicate;
  :class:`repro.layers.plan.LinearPlan` consults it for its kernel
  eligibility decision and the wrappers use it as the fallback check for
  direct callers.  Oversize geometries fall back to the jnp reference
  (which XLA fuses reasonably); the kernels cover the production-common
  block sizes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import branched_matmul as bk
from repro.kernels import branched_matmul_q as bqk
from repro.kernels import branched_matmul_qa as bak
from repro.kernels import branched_matmul_sq as bsk
from repro.kernels import decode_attention_paged as dap
from repro.kernels import decode_attention_q as dak
from repro.kernels import lowrank_matmul as lk
from repro.kernels import lowrank_matmul_q as qk
from repro.kernels import lowrank_matmul_qa as aqk
from repro.kernels import lowrank_matmul_sq as sk
from repro.kernels import ref

# v5e practical per-core VMEM working-set budget (conservative).
VMEM_BUDGET = 64 * 1024 * 1024

#: serve-tier fault injection hook (``kernel_gate`` point): when set,
#: :func:`kernel_fits` consults it and a fire forces the jnp reference
#: fallback — exercised at trace/plan time, so the chaos suite proves
#: a kernel rejection degrades throughput, never correctness (the
#: references are bit-exact oracles).  ``None`` when inert.
_FAULT_INJECTOR = None


def set_fault_injector(inj) -> None:
    """Install (or with ``None`` clear) the serve tier's
    :class:`repro.serve.faults.FaultInjector` for the ``kernel_gate``
    injection point."""
    global _FAULT_INJECTOR
    _FAULT_INJECTOR = inj


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x: jax.Array, axis: int, mult: int) -> tuple[jax.Array, int]:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x, 0
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), pad


def _bm_eff(bm: int, m: int) -> int:
    return min(bm, max(8, m))


def kernel_fits(kernel: str, m: int, *, c: int, s: int, r: int = 0,
                r1: int = 0, r2: int = 0, q_bytes: int = 1,
                bm: int | None = None, bn: int | None = None) -> bool:
    """Does one grid step of ``kernel`` at this geometry fit the VMEM
    budget?  The one fit predicate behind plan eligibility and the
    wrappers' fallback dispatch.  ``bm``/``bn`` default to the kernel's
    own tile sizes; wrappers pass the caller's so the fit check matches
    the launch.  The S-block is the full ``bn`` — the wrappers pad S up
    to a ``bn`` multiple, so the launched block is never narrower."""
    del s  # padded up to a bn multiple at launch
    if _FAULT_INJECTOR is not None and _FAULT_INJECTOR.fire("kernel_gate"):
        return False               # injected rejection -> jnp fallback
    if kernel == "lowrank":
        return lk.vmem_bytes(_bm_eff(bm or lk.DEFAULT_BM, m), c, r,
                             bn or lk.DEFAULT_BN) <= VMEM_BUDGET
    if kernel == "lowrank_q":
        return qk.vmem_bytes(_bm_eff(bm or qk.DEFAULT_BM, m), c, r,
                             bn or qk.DEFAULT_BN,
                             q_bytes=q_bytes) <= VMEM_BUDGET
    if kernel == "lowrank_qa":
        return aqk.vmem_bytes(_bm_eff(bm or aqk.DEFAULT_BM, m), c, r,
                              bn or aqk.DEFAULT_BN,
                              q_bytes=q_bytes) <= VMEM_BUDGET
    if kernel == "lowrank_sq":
        return sk.vmem_bytes(_bm_eff(bm or sk.DEFAULT_BM, m), c, r,
                             bn or sk.DEFAULT_BN,
                             q_bytes=q_bytes) <= VMEM_BUDGET
    if kernel == "branched":
        return bk.vmem_bytes(_bm_eff(bm or bk.DEFAULT_BM, m), c, r1, r2,
                             bn or bk.DEFAULT_BN) <= VMEM_BUDGET
    if kernel == "branched_q":
        return bqk.vmem_bytes(_bm_eff(bm or bqk.DEFAULT_BM, m), c, r1, r2,
                              bn or bqk.DEFAULT_BN,
                              q_bytes=q_bytes) <= VMEM_BUDGET
    if kernel == "branched_qa":
        return bak.vmem_bytes(_bm_eff(bm or bak.DEFAULT_BM, m), c, r1, r2,
                              bn or bak.DEFAULT_BN,
                              q_bytes=q_bytes) <= VMEM_BUDGET
    if kernel == "branched_sq":
        return bsk.vmem_bytes(_bm_eff(bm or bsk.DEFAULT_BM, m), c, r1, r2,
                              bn or bsk.DEFAULT_BN,
                              q_bytes=q_bytes) <= VMEM_BUDGET
    if kernel == "decode_attn_q":
        # Per-(slot, kv-head) program: c = head_dim, r = GQA group size,
        # bn = the sequence block; m (the slot count) is grid-parallel.
        return dak.vmem_bytes(max(1, r), c, bn or dak.DEFAULT_BS,
                              q_bytes=q_bytes) <= VMEM_BUDGET
    if kernel == "decode_attn_paged":
        # Per-(slot, kv-head) program over one physical block: c =
        # head_dim, r = GQA group size, bn = the pool's block size.
        # Same tile inventory as the slot kernel (the f32 variant skips
        # the scale rows, a rounding error in the bound).
        return dap.vmem_bytes(max(1, r), c, bn or 16,
                              q_bytes=q_bytes) <= VMEM_BUDGET
    if kernel == "decode_latent_q":
        # Per-slot program: c = kv_lora_rank, r = head count, r1 = the
        # rope dim; all H heads ride as tile rows of one program.
        return dak.vmem_bytes_latent(max(1, r), c, r1,
                                     bn or dak.DEFAULT_BS,
                                     q_bytes=q_bytes) <= VMEM_BUDGET
    raise ValueError(f"unknown kernel {kernel!r}")


def lowrank_matmul(x: jax.Array, w0: jax.Array, w1: jax.Array, *,
                   bm: int = lk.DEFAULT_BM, bn: int = lk.DEFAULT_BN,
                   force_kernel: bool = False) -> jax.Array:
    """y = (x @ w0) @ w1 with the fused kernel when it fits VMEM."""
    lead = x.shape[:-1]
    c = x.shape[-1]
    r, s = w1.shape
    x2 = x.reshape(-1, c)
    m = x2.shape[0]
    bm_eff = _bm_eff(bm, m)
    if not (force_kernel or kernel_fits("lowrank", m, c=c, r=r, s=s,
                                        bm=bm, bn=bn)):
        return ref.lowrank_matmul_ref(x, w0, w1)
    x2, pad_m = _pad_to(x2, 0, bm_eff)
    w1p, pad_s = _pad_to(w1, 1, bn)
    y = lk.lowrank_matmul(x2, w0, w1p, bm=bm_eff, bn=min(bn, w1p.shape[1]),
                          interpret=not _on_tpu())
    if pad_m:
        y = y[:m]
    if pad_s:
        y = y[:, :s]
    return y.reshape(*lead, s)


def lowrank_matmul_q(x: jax.Array, w0_q: jax.Array, w0_scale: jax.Array,
                     w1_q: jax.Array, w1_scale: jax.Array, *,
                     bm: int = qk.DEFAULT_BM, bn: int = qk.DEFAULT_BN,
                     force_kernel: bool = False) -> jax.Array:
    """y = (x @ dq(w0)) @ dq(w1) with the fused quantized kernel."""
    lead = x.shape[:-1]
    c = x.shape[-1]
    r, s = w1_q.shape
    x2 = x.reshape(-1, c)
    m = x2.shape[0]
    bm_eff = _bm_eff(bm, m)
    q_bytes = jnp.dtype(w0_q.dtype).itemsize
    if not (force_kernel or kernel_fits("lowrank_q", m, c=c, r=r, s=s,
                                        q_bytes=q_bytes, bm=bm,
                                        bn=bn)):
        return ref.lowrank_matmul_q_ref(x, w0_q, w0_scale, w1_q, w1_scale)
    x2, pad_m = _pad_to(x2, 0, bm_eff)
    w1p, pad_s = _pad_to(w1_q, 1, bn)
    w1sp, _ = _pad_to(w1_scale, 1, bn)     # zero scales -> zero columns
    y = qk.lowrank_matmul_q(x2, w0_q, w0_scale, w1p, w1sp,
                            bm=bm_eff, bn=min(bn, w1p.shape[1]),
                            interpret=not _on_tpu())
    if pad_m:
        y = y[:m]
    if pad_s:
        y = y[:, :s]
    return y.reshape(*lead, s)


def lowrank_matmul_qa(x: jax.Array, w0_q: jax.Array, w0_scale: jax.Array,
                      w1_q: jax.Array, w1_scale: jax.Array, *,
                      bm: int = aqk.DEFAULT_BM, bn: int = aqk.DEFAULT_BN,
                      force_kernel: bool = False) -> jax.Array:
    """y = dq(q(x) @ w0_q) -> requant -> dq(h_q @ w1_q) with the fused
    activation-quantized kernel — both dots int8 x int8 on the MXU,
    per-token act scales folded with the per-channel weight scales."""
    lead = x.shape[:-1]
    c = x.shape[-1]
    r, s = w1_q.shape
    x2 = x.reshape(-1, c)
    m = x2.shape[0]
    bm_eff = _bm_eff(bm, m)
    q_bytes = jnp.dtype(w0_q.dtype).itemsize
    if not (force_kernel or kernel_fits("lowrank_qa", m, c=c, r=r, s=s,
                                        q_bytes=q_bytes, bm=bm,
                                        bn=bn)):
        return ref.lowrank_matmul_qa_ref(x2, w0_q, w0_scale, w1_q,
                                         w1_scale).reshape(*lead, s)
    x2, pad_m = _pad_to(x2, 0, bm_eff)     # zero rows -> zero act scales
    w1p, pad_s = _pad_to(w1_q, 1, bn)
    w1sp, _ = _pad_to(w1_scale, 1, bn)     # zero scales -> zero columns
    y = aqk.lowrank_matmul_qa(x2, w0_q, w0_scale, w1p, w1sp,
                              bm=bm_eff, bn=min(bn, w1p.shape[1]),
                              interpret=not _on_tpu())
    if pad_m:
        y = y[:m]
    if pad_s:
        y = y[:, :s]
    return y.reshape(*lead, s)


def lowrank_matmul_sq(x: jax.Array, w0_sp: jax.Array, w0_idx: jax.Array,
                      w0_scale: jax.Array, w1_sp: jax.Array,
                      w1_idx: jax.Array, w1_scale: jax.Array, *,
                      bm: int = sk.DEFAULT_BM, bn: int = sk.DEFAULT_BN,
                      force_kernel: bool = False) -> jax.Array:
    """y = (x @ ds(w0)) @ ds(w1) with the fused sparse-int8 kernel —
    2:4-packed factors expanded + dequantized in VMEM."""
    lead = x.shape[:-1]
    c = x.shape[-1]
    r = w0_sp.shape[-1]
    s = w1_sp.shape[-1]
    x2 = x.reshape(-1, c)
    m = x2.shape[0]
    bm_eff = _bm_eff(bm, m)
    q_bytes = jnp.dtype(w0_sp.dtype).itemsize
    if not (force_kernel or kernel_fits("lowrank_sq", m, c=c, r=r, s=s,
                                        q_bytes=q_bytes, bm=bm, bn=bn)):
        return ref.lowrank_matmul_sq_ref(x, w0_sp, w0_idx, w0_scale,
                                         w1_sp, w1_idx, w1_scale)
    x2, pad_m = _pad_to(x2, 0, bm_eff)
    w1p, pad_s = _pad_to(w1_sp, 2, bn)
    w1sp, _ = _pad_to(w1_scale, 1, bn)     # zero scales -> zero columns
    y = sk.lowrank_matmul_sq(x2, w0_sp, w0_idx, w0_scale,
                             w1p, w1_idx, w1sp,
                             bm=bm_eff, bn=min(bn, w1p.shape[2]),
                             interpret=not _on_tpu())
    if pad_m:
        y = y[:m]
    if pad_s:
        y = y[:, :s]
    return y.reshape(*lead, s)


def branched_matmul(x: jax.Array, u: jax.Array, xc: jax.Array,
                    v: jax.Array, *, bm: int = bk.DEFAULT_BM,
                    bn: int = bk.DEFAULT_BN,
                    force_kernel: bool = False) -> jax.Array:
    """y = sum_n ((x @ u_n) @ xc_n) @ v_n with the grouped kernel."""
    lead = x.shape[:-1]
    c = x.shape[-1]
    n, _, r1 = u.shape
    _, _, r2 = xc.shape
    s = v.shape[-1]
    x2 = x.reshape(-1, c)
    m = x2.shape[0]
    bm_eff = _bm_eff(bm, m)
    if not (force_kernel or kernel_fits("branched", m, c=c, r1=r1, r2=r2,
                                        s=s, bm=bm, bn=bn)):
        return ref.branched_matmul_ref(x2, u, xc, v).reshape(*lead, s)
    x2, pad_m = _pad_to(x2, 0, bm_eff)
    vp, pad_s = _pad_to(v, 2, bn)
    y = bk.branched_matmul(x2, u, xc, vp, bm=bm_eff,
                           bn=min(bn, vp.shape[2]),
                           interpret=not _on_tpu())
    if pad_m:
        y = y[:m]
    if pad_s:
        y = y[:, :s]
    return y.reshape(*lead, s)


def branched_matmul_q(x: jax.Array, u_q: jax.Array, u_scale: jax.Array,
                      xc_q: jax.Array, xc_scale: jax.Array,
                      v_q: jax.Array, v_scale: jax.Array, *,
                      bm: int = bqk.DEFAULT_BM, bn: int = bqk.DEFAULT_BN,
                      force_kernel: bool = False) -> jax.Array:
    """y = sum_n ((x @ dq(u_n)) @ dq(xc_n)) @ dq(v_n) with the fused
    quantized branched kernel — int8 branch tiles dequantized in VMEM,
    branch sum in the scratch accumulator."""
    lead = x.shape[:-1]
    c = x.shape[-1]
    n, _, r1 = u_q.shape
    _, _, r2 = xc_q.shape
    s = v_q.shape[-1]
    x2 = x.reshape(-1, c)
    m = x2.shape[0]
    bm_eff = _bm_eff(bm, m)
    q_bytes = jnp.dtype(u_q.dtype).itemsize
    if not (force_kernel or kernel_fits("branched_q", m, c=c, r1=r1, r2=r2,
                                        s=s, q_bytes=q_bytes, bm=bm,
                                        bn=bn)):
        return ref.branched_matmul_q_ref(x2, u_q, u_scale, xc_q, xc_scale,
                                         v_q, v_scale).reshape(*lead, s)
    x2, pad_m = _pad_to(x2, 0, bm_eff)
    vp, pad_s = _pad_to(v_q, 2, bn)
    vsp, _ = _pad_to(v_scale, 2, bn)       # zero scales -> zero columns
    y = bqk.branched_matmul_q(x2, u_q, u_scale, xc_q, xc_scale, vp, vsp,
                              bm=bm_eff, bn=min(bn, vp.shape[2]),
                              interpret=not _on_tpu())
    if pad_m:
        y = y[:m]
    if pad_s:
        y = y[:, :s]
    return y.reshape(*lead, s)


def branched_matmul_qa(x: jax.Array, u_q: jax.Array, u_scale: jax.Array,
                       xc_q: jax.Array, xc_scale: jax.Array,
                       v_q: jax.Array, v_scale: jax.Array, *,
                       bm: int = bak.DEFAULT_BM, bn: int = bak.DEFAULT_BN,
                       force_kernel: bool = False) -> jax.Array:
    """y = sum_n of the all-int8 branch chains with the fused
    activation-quantized branched kernel — activations quantize once
    per row block, every branch dot runs int8 x int8, branch sum in the
    f32 scratch accumulator."""
    lead = x.shape[:-1]
    c = x.shape[-1]
    n, _, r1 = u_q.shape
    _, _, r2 = xc_q.shape
    s = v_q.shape[-1]
    x2 = x.reshape(-1, c)
    m = x2.shape[0]
    bm_eff = _bm_eff(bm, m)
    q_bytes = jnp.dtype(u_q.dtype).itemsize
    if not (force_kernel or kernel_fits("branched_qa", m, c=c, r1=r1,
                                        r2=r2, s=s, q_bytes=q_bytes,
                                        bm=bm, bn=bn)):
        return ref.branched_matmul_qa_ref(x2, u_q, u_scale, xc_q, xc_scale,
                                          v_q, v_scale).reshape(*lead, s)
    x2, pad_m = _pad_to(x2, 0, bm_eff)     # zero rows -> zero act scales
    vp, pad_s = _pad_to(v_q, 2, bn)
    vsp, _ = _pad_to(v_scale, 2, bn)       # zero scales -> zero columns
    y = bak.branched_matmul_qa(x2, u_q, u_scale, xc_q, xc_scale, vp, vsp,
                               bm=bm_eff, bn=min(bn, vp.shape[2]),
                               interpret=not _on_tpu())
    if pad_m:
        y = y[:m]
    if pad_s:
        y = y[:, :s]
    return y.reshape(*lead, s)


def branched_matmul_sq(x: jax.Array, u_sp: jax.Array, u_idx: jax.Array,
                       u_scale: jax.Array, xc_q: jax.Array,
                       xc_scale: jax.Array, v_sp: jax.Array,
                       v_idx: jax.Array, v_scale: jax.Array, *,
                       bm: int = bsk.DEFAULT_BM, bn: int = bsk.DEFAULT_BN,
                       force_kernel: bool = False) -> jax.Array:
    """y = sum_n ((x @ ds(u_n)) @ dq(xc_n)) @ ds(v_n) with the fused
    sparse-int8 branched kernel — 2:4-packed u/v tiles expanded +
    dequantized in VMEM, int8 core, branch sum in scratch."""
    lead = x.shape[:-1]
    c = x.shape[-1]
    r1 = u_sp.shape[-1]
    r2 = xc_q.shape[-1]
    s = v_sp.shape[-1]
    x2 = x.reshape(-1, c)
    m = x2.shape[0]
    bm_eff = _bm_eff(bm, m)
    q_bytes = jnp.dtype(u_sp.dtype).itemsize
    if not (force_kernel or kernel_fits("branched_sq", m, c=c, r1=r1,
                                        r2=r2, s=s, q_bytes=q_bytes,
                                        bm=bm, bn=bn)):
        return ref.branched_matmul_sq_ref(
            x2, u_sp, u_idx, u_scale, xc_q, xc_scale, v_sp, v_idx,
            v_scale).reshape(*lead, s)
    x2, pad_m = _pad_to(x2, 0, bm_eff)
    vp, pad_s = _pad_to(v_sp, 3, bn)
    vsp, _ = _pad_to(v_scale, 2, bn)       # zero scales -> zero columns
    y = bsk.branched_matmul_sq(x2, u_sp, u_idx, u_scale, xc_q, xc_scale,
                               vp, v_idx, vsp, bm=bm_eff,
                               bn=min(bn, vp.shape[3]),
                               interpret=not _on_tpu())
    if pad_m:
        y = y[:m]
    if pad_s:
        y = y[:, :s]
    return y.reshape(*lead, s)


def decode_attention_q(q: jax.Array, k_q: jax.Array, k_scale: jax.Array,
                       v_q: jax.Array, v_scale: jax.Array,
                       cache_pos: jax.Array, *, softcap: float = 0.0,
                       bs: int = dak.DEFAULT_BS,
                       force_kernel: bool = False) -> jax.Array:
    """One decode step of attention over an int8 KV pool, fused.

    q (B, 1, H, D); k_q/v_q (B, S, KH, D) int8; k/v_scale (B, KH, D)
    f32 per-(slot, head, channel); cache_pos (B,) -> (B, 1, H, D).
    Positions beyond each slot's ``cache_pos`` are masked in-kernel, so
    the S padding added here never leaks into the softmax.
    """
    b, sq, h, d = q.shape
    assert sq == 1, q.shape
    s, kh = k_q.shape[1], k_q.shape[2]
    g = h // kh
    q_bytes = jnp.dtype(k_q.dtype).itemsize
    if not (force_kernel or kernel_fits("decode_attn_q", b, c=d, s=s, r=g,
                                        q_bytes=q_bytes, bn=bs)):
        return ref.decode_attention_q_ref(q, k_q, k_scale, v_q, v_scale,
                                          cache_pos, softcap=softcap)
    # Head layout matches the jnp decode path: H rows group as (KH, G).
    qg = q[:, 0].reshape(b, kh, g, d)
    kq_p, _ = _pad_to(k_q, 1, bs)
    vq_p, _ = _pad_to(v_q, 1, bs)
    o = dak.decode_attention_q(
        qg, kq_p, k_scale, vq_p, v_scale,
        cache_pos.astype(jnp.int32).reshape(b, 1),
        bs=min(bs, kq_p.shape[1]), softcap=softcap,
        interpret=not _on_tpu())
    return o.reshape(b, 1, h, d)


def decode_attention_paged(q: jax.Array, k: jax.Array, v: jax.Array,
                           block_tables: jax.Array, cache_pos: jax.Array,
                           *, softcap: float = 0.0,
                           force_kernel: bool = False) -> jax.Array:
    """One decode step of attention over a full-width paged KV pool.

    q (B, 1, H, D); k/v (NB+1, bs, KH, D) — batch axis = physical
    block; block_tables (B, nblk) int32; cache_pos (B,) ->
    (B, 1, H, D).  The kernel's sequence block IS the pool block (no S
    padding); table entries beyond a stream's allocation alias the
    dummy block and mask out by position.
    """
    b, sq, h, d = q.shape
    assert sq == 1, q.shape
    bs, kh = k.shape[1], k.shape[2]
    g = h // kh
    q_bytes = jnp.dtype(k.dtype).itemsize
    if not (force_kernel or kernel_fits("decode_attn_paged", b, c=d, s=bs,
                                        r=g, q_bytes=q_bytes, bn=bs)):
        return ref.decode_attention_paged_ref(q, k, v, block_tables,
                                              cache_pos, softcap=softcap)
    qg = q[:, 0].reshape(b, kh, g, d)
    o = dap.decode_attention_paged(
        qg, k, v, block_tables.astype(jnp.int32),
        cache_pos.astype(jnp.int32).reshape(b, 1),
        softcap=softcap, interpret=not _on_tpu())
    return o.reshape(b, 1, h, d)


def decode_attention_paged_q(q: jax.Array, k_q: jax.Array,
                             k_scale: jax.Array, v_q: jax.Array,
                             v_scale: jax.Array, block_tables: jax.Array,
                             cache_pos: jax.Array, *, softcap: float = 0.0,
                             force_kernel: bool = False) -> jax.Array:
    """One decode step of attention over an int8 paged KV pool, fused.

    q (B, 1, H, D); k_q/v_q (NB+1, bs, KH, D) int8; PER-BLOCK k/v_scale
    (NB+1, KH, D) f32; block_tables (B, nblk) int32; cache_pos (B,) ->
    (B, 1, H, D).  K scales fold into the query row per block, V scales
    into each block's context contribution.
    """
    b, sq, h, d = q.shape
    assert sq == 1, q.shape
    bs, kh = k_q.shape[1], k_q.shape[2]
    g = h // kh
    q_bytes = jnp.dtype(k_q.dtype).itemsize
    if not (force_kernel or kernel_fits("decode_attn_paged", b, c=d, s=bs,
                                        r=g, q_bytes=q_bytes, bn=bs)):
        return ref.decode_attention_paged_q_ref(
            q, k_q, k_scale, v_q, v_scale, block_tables, cache_pos,
            softcap=softcap)
    qg = q[:, 0].reshape(b, kh, g, d)
    o = dap.decode_attention_paged_q(
        qg, k_q, k_scale, v_q, v_scale, block_tables.astype(jnp.int32),
        cache_pos.astype(jnp.int32).reshape(b, 1),
        softcap=softcap, interpret=not _on_tpu())
    return o.reshape(b, 1, h, d)


def decode_attention_latent_q(q_lat: jax.Array, q_rope: jax.Array,
                              ckv_q: jax.Array, ckv_scale: jax.Array,
                              krope_q: jax.Array, krope_scale: jax.Array,
                              cache_pos: jax.Array, *, scale: float,
                              bs: int = dak.DEFAULT_BS,
                              force_kernel: bool = False) -> jax.Array:
    """One absorbed-form MLA decode step over an int8 latent pool, fused.

    q_lat (B, 1, H, L); q_rope (B, 1, H, R); ckv_q (B, S, L) / krope_q
    (B, S, R) int8; ckv/krope_scale (B, L)/(B, R) f32 per-(slot,
    channel); cache_pos (B,) -> context latents (B, 1, H, L).
    ``scale`` is the logit scale 1/sqrt(qk_nope + qk_rope).  Positions
    beyond each slot's ``cache_pos`` are masked in-kernel, so the S
    padding added here never leaks into the softmax.
    """
    b, sq, h, lora = q_lat.shape
    assert sq == 1, q_lat.shape
    s = ckv_q.shape[1]
    rope = q_rope.shape[-1]
    q_bytes = jnp.dtype(ckv_q.dtype).itemsize
    if not (force_kernel or kernel_fits("decode_latent_q", b, c=lora, s=s,
                                        r=h, r1=rope, q_bytes=q_bytes,
                                        bn=bs)):
        return ref.decode_attention_latent_q_ref(
            q_lat, q_rope, ckv_q, ckv_scale, krope_q, krope_scale,
            cache_pos, scale=scale)
    cq_p, _ = _pad_to(ckv_q, 1, bs)
    rq_p, _ = _pad_to(krope_q, 1, bs)
    o = dak.decode_attention_latent_q(
        q_lat[:, 0], q_rope[:, 0], cq_p, ckv_scale, rq_p, krope_scale,
        cache_pos.astype(jnp.int32).reshape(b, 1), scale=scale,
        bs=min(bs, cq_p.shape[1]), interpret=not _on_tpu())
    return o[:, None]

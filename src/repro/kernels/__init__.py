"""Pallas TPU kernels for the paper's compute hot-spots.

* :mod:`repro.kernels.lowrank_matmul` — fused ``(x@W0)@W1`` (the SVD pair
  of paper Eq. 3) keeping the rank-bottleneck intermediate in VMEM.
* :mod:`repro.kernels.branched_matmul` — block-diagonal grouped matmul
  (the paper's branched Tucker, Fig. 4, adapted to the MXU).
* :mod:`repro.kernels.lowrank_matmul_q` — weight-only quantized variant:
  int8/fp8 factor tiles dequantized in VMEM (see repro/quant/).
* :mod:`repro.kernels.ops` — jit'd wrappers with padding + dispatch.
* :mod:`repro.kernels.ref` — pure-jnp oracles for the allclose tests.

Validated with ``interpret=True`` on CPU; compiled path targets TPU.
"""

"""Fused *sparse-quantized* low-rank matmul: y = (x @ ds(w0)) @ ds(w1).

Compound-compression variant of :mod:`repro.kernels.lowrank_matmul_q`
(same grid, same f32 rank scratch): each factor arrives in VMEM as
2:4-packed int8 values (slot-major ``(2, C/4, R)``) plus int8 row-index
metadata ``(2, C/4, 1)`` and f32 per-output-channel scales, is
**expanded and dequantized in VMEM** right before the MXU dot, and the
rank intermediate ``h = x @ ds(w0)`` lives in f32 scratch — neither a
dense nor a dequantized weight ever touches HBM.

Why it compounds: decode is memory-bound on weight streaming, and the
2:4 packing halves the *int8* bytes again — ``0.5·C·R`` values +
``C/2`` index bytes + ``4R`` scale bytes vs ``C·R + 4R`` for int8-only
(~1.9-2x fewer at production sizes, ~4x vs bf16, ~8x vs f32), on top of
the rank reduction itself.

The in-VMEM expand is pure VPU work, no gathers: the slot-major packing
makes ``sp_ref[i]`` a contiguous ``(C/4, N)`` tile; each of the two
kept slots is broadcast 4x along the sublane axis (``jnp.repeat``) and
masked against a ``row % 4`` iota compared with the (also repeated)
index column — two multiply-adds reconstruct the dense ``(C, N)`` tile
with pruned rows as exact zeros.  An expansion-*matmul* formulation
(``E^T @ sp``) was rejected: it costs ``C²R/2`` MXU FLOPs per tile,
catastrophic at decode block sizes.

Layout follows :mod:`repro.quant.sparse`: ``w0_sp (2, C/4, R)``,
``w0_idx (2, C/4, 1)``, ``w0_scale (1, R)``; ``w1_sp (2, R/4, S)``,
``w1_idx (2, R/4, 1)``, ``w1_scale (1, S)``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.lowrank_matmul import CompilerParams

DEFAULT_BM = 256
DEFAULT_BN = 256


def expand_tile(sp, idx, scale, out_dtype):
    """Dense ``(4G, N)`` tile from a slot-major 2:4 pack, in VMEM.

    ``sp (2, G, N)`` packed values; ``idx (2, G, 1)`` int8 within-group
    row positions; ``scale (1, N)`` f32 (pass 1.0 for unquantized).
    Row ``4g + j`` gets slot ``i``'s value iff ``idx[i, g] == j`` —
    pruned rows stay exactly zero.
    """
    g, n = sp.shape[1], sp.shape[2]
    pos = jax.lax.broadcasted_iota(jnp.int32, (4 * g, 1), 0) % 4
    dense = jnp.zeros((4 * g, n), jnp.float32)
    for i in range(sp.shape[0]):
        vals = jnp.repeat(sp[i].astype(jnp.float32), 4, axis=0)
        sel = jnp.repeat(idx[i].astype(jnp.int32), 4, axis=0)
        dense = dense + jnp.where(sel == pos, vals, 0.0)
    return (dense * scale).astype(out_dtype)


def _kernel(x_ref, w0sp_ref, w0i_ref, w0s_ref, w1sp_ref, w1i_ref, w1s_ref,
            o_ref, h_ref):
    """x (bm, C); w0 pack (2, C/4, R)+(2, C/4, 1)+(1, R); w1 pack
    (2, R/4, bn)+(2, R/4, 1)+(1, bn); o (bm, bn); scratch h (bm, R)."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _compute_h():
        w0 = expand_tile(w0sp_ref[...], w0i_ref[...], w0s_ref[...],
                         x_ref.dtype)
        h_ref[...] = jnp.dot(x_ref[...], w0,
                             preferred_element_type=jnp.float32)

    w1 = expand_tile(w1sp_ref[...], w1i_ref[...], w1s_ref[...], x_ref.dtype)
    h = h_ref[...].astype(x_ref.dtype)
    o_ref[...] = jnp.dot(h, w1,
                         preferred_element_type=jnp.float32
                         ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def lowrank_matmul_sq(x: jax.Array, w0_sp: jax.Array, w0_idx: jax.Array,
                      w0_scale: jax.Array, w1_sp: jax.Array,
                      w1_idx: jax.Array, w1_scale: jax.Array, *,
                      bm: int = DEFAULT_BM, bn: int = DEFAULT_BN,
                      interpret: bool = False) -> jax.Array:
    """y = (x @ ds(w0)) @ ds(w1), fused sparse-int8 chain.

    x (M, C); w0_sp (2, C/4, R) + w0_idx (2, C/4, 1) + w0_scale (1, R);
    w1_sp (2, R/4, S) + w1_idx (2, R/4, 1) + w1_scale (1, S) -> (M, S).
    Requires M % bm == 0 and S % bn == 0 (ops.py pads), C % 4 == 0 and
    R % 4 == 0 (the packing's group size).
    """
    m, c = x.shape
    two, c4, r = w0_sp.shape
    _, r4, s = w1_sp.shape
    assert two == 2 and c == 4 * c4 and r == 4 * r4, \
        (x.shape, w0_sp.shape, w1_sp.shape)
    assert w0_idx.shape == (2, c4, 1) and w1_idx.shape == (2, r4, 1), \
        (w0_idx.shape, w1_idx.shape)
    assert w0_scale.shape == (1, r) and w1_scale.shape == (1, s), \
        (w0_scale.shape, w1_scale.shape)
    assert m % bm == 0 and s % bn == 0, (m, s, bm, bn)

    grid = (m // bm, s // bn)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, c), lambda i, j: (i, 0)),
            pl.BlockSpec((2, c4, r), lambda i, j: (0, 0, 0)),
            pl.BlockSpec((2, c4, 1), lambda i, j: (0, 0, 0)),
            pl.BlockSpec((1, r), lambda i, j: (0, 0)),
            pl.BlockSpec((2, r4, bn), lambda i, j: (0, 0, j)),
            pl.BlockSpec((2, r4, 1), lambda i, j: (0, 0, 0)),
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, s), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, r), jnp.float32)],
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
    )(x, w0_sp, w0_idx, w0_scale, w1_sp, w1_idx, w1_scale)


def vmem_bytes(m_block: int, c: int, r: int, s_block: int,
               act_bytes: int = 2, q_bytes: int = 1) -> int:
    """VMEM footprint of one grid step (fit check used by ops.py).

    Counts the packed tiles + index/scale metadata, the expanded f32
    and activation-width dense copies, and the f32 rank scratch.
    """
    packed = (c // 2) * r + (r // 2) * s_block       # kept values
    meta = (c // 2) + (r // 2)                       # int8 indices
    expanded = (c * r + r * s_block) * (4 + act_bytes)
    return (m_block * c * act_bytes                  # x block
            + packed * q_bytes + meta
            + (r + s_block) * 4                      # f32 scales
            + expanded
            + m_block * s_block * act_bytes          # out block
            + m_block * r * 4)                       # f32 scratch h

"""Fused block-table decode attention over a paged KV pool.

The paged serve pool (:mod:`repro.serve.paging`) stores K/V as
fixed-size physical blocks ``(num_blocks + 1, block_size, KH, D)`` and
addresses them per stream through an int32 block table — so decode
cannot stream a contiguous ``(slots, S_max, ...)`` region; each
stream's logical sequence is scattered across the pool.  A
gather-then-attend fallback materializes every stream's contiguous
copy in HBM each step, handing back exactly the bytes paging saved.
This kernel keeps the indirection in the *index maps*:

* the block table and ``cache_pos`` ride in as **scalar-prefetch**
  operands (:class:`pltpu.PrefetchScalarGridSpec`) — the k/v BlockSpec
  index maps read ``bt[slot, blk]`` to aim each grid step's DMA at the
  right physical block, so K/V tiles stream straight from their paged
  homes into VMEM, one block per sequence step;
* idle table entries alias the reserved dummy block (physical id
  ``num_blocks``); the ``pos <= cache_pos`` validity mask kills their
  logits, so the garbage they hold never reaches the softmax;
* online softmax over the logical block sequence: f32 running max /
  sum / accumulator in VMEM scratch across the arbitrary grid dim,
  identical discipline to :mod:`repro.kernels.decode_attention_q`.

``decode_attention_paged_q`` is the int8 twin.  Scales are PER BLOCK
(``(num_blocks + 1, KH, D)`` f32 — blocked together with their values,
so a copy-on-write shared prefix block travels with its own scales).
Per-block K scales fold into the query row exactly as the slot kernel
folds per-slot scales; per-block V scales can no longer fold into the
final output (they change block to block), so each block's context
contribution is scaled before accumulation — O(G*D) multiplies per
block in place of O(bs*D) dequantization.

Grid: ``(B_slots, KV_heads, blocks_per_slot)`` with the block dim
innermost (arbitrary); slots and heads are parallel.  The GQA group of
G = H/KH query heads rides as rows of the q/out tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.lowrank_matmul import CompilerParams

_NEG_INF = -1e30
_MINOR = 128        # f32 scratch lane width for the (G, 1) running stats


def _kernel(bt_ref, cp_ref, q_ref, k_ref, v_ref, o_ref,
            acc_ref, m_ref, l_ref, *, scale, softcap):
    """q (1,1,G,D); k/v (1,bs,1,D) — the physical block the index map
    aimed at; bt (B,nblk) / cache_pos (B,1) i32 SMEM (scalar prefetch);
    o (1,1,G,D); scratch acc (G,D), m/l (G,128) f32 (col 0 live)."""
    b = pl.program_id(0)
    si = pl.program_id(2)
    ns = pl.num_programs(2)
    bs = k_ref.shape[1]

    @pl.when(si == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)                     # (G, D)
    k = k_ref[0, :, 0, :].astype(jnp.float32)               # (bs, D)
    s = jnp.dot(q * scale, k.T,
                preferred_element_type=jnp.float32)         # (G, bs)
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    pos = si * bs + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)
    s = jnp.where(pos <= cp_ref[b, 0], s, _NEG_INF)

    m_prev = m_ref[:, :1]                                   # (G, 1)
    l_prev = l_ref[:, :1]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)                                  # (G, bs)
    v = v_ref[0, :, 0, :].astype(jnp.float32)               # (bs, D)
    acc = acc_ref[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    l_new = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc
    m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(si == ns - 1)
    def _flush():
        o_ref[0, 0] = (acc / l_new).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("softcap", "interpret"))
def decode_attention_paged(q: jax.Array, k: jax.Array, v: jax.Array,
                           block_tables: jax.Array, cache_pos: jax.Array,
                           *, softcap: float = 0.0,
                           interpret: bool = False) -> jax.Array:
    """Fused decode attention over a full-width paged KV pool.

    q (B, KH, G, D); k/v (NB+1, bs, KH, D) — batch axis = physical
    block, id NB reserved dummy; block_tables (B, nblk) int32;
    cache_pos (B, 1) int32 -> (B, KH, G, D) in q.dtype.  The sequence
    block size IS the pool's block size (no padding: nblk covers
    exactly blocks_per_slot logical blocks).
    """
    b, kh, g, d = q.shape
    nb1, bs, kh2, d2 = k.shape
    assert (kh, d) == (kh2, d2), (q.shape, k.shape)
    assert k.shape == v.shape
    nblk = block_tables.shape[1]
    assert block_tables.shape == (b, nblk), block_tables.shape
    assert cache_pos.shape == (b, 1), cache_pos.shape

    grid = (b, kh, nblk)
    kernel = functools.partial(_kernel, scale=1.0 / (d ** 0.5),
                               softcap=softcap)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, g, d), lambda i, j, s, bt, cp: (i, j, 0, 0)),
            pl.BlockSpec((1, bs, 1, d),
                         lambda i, j, s, bt, cp: (bt[i, s], 0, j, 0)),
            pl.BlockSpec((1, bs, 1, d),
                         lambda i, j, s, bt, cp: (bt[i, s], 0, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d),
                               lambda i, j, s, bt, cp: (i, j, 0, 0)),
        scratch_shapes=[pltpu.VMEM((g, d), jnp.float32),
                        pltpu.VMEM((g, _MINOR), jnp.float32),
                        pltpu.VMEM((g, _MINOR), jnp.float32)],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kh, g, d), q.dtype),
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(block_tables, cache_pos, q, k, v)


def _kernel_q(bt_ref, cp_ref, q_ref, kq_ref, ks_ref, vq_ref, vs_ref, o_ref,
              acc_ref, m_ref, l_ref, *, scale, softcap):
    """Int8 twin: k_q/v_q (1,bs,1,D) int8 + PER-BLOCK k/v_scale (1,1,D)
    f32 tiles follow the same block-table index maps.  K scales fold
    into the query row per block; V scales multiply each block's
    context contribution before accumulation."""
    b = pl.program_id(0)
    si = pl.program_id(2)
    ns = pl.num_programs(2)
    bs = kq_ref.shape[1]

    @pl.when(si == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)                     # (G, D)
    ks = ks_ref[0, 0].astype(jnp.float32)                   # (D,)
    kq = kq_ref[0, :, 0, :].astype(jnp.float32)             # (bs, D)
    s = jnp.dot(q * (ks * scale)[None, :], kq.T,
                preferred_element_type=jnp.float32)         # (G, bs)
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    pos = si * bs + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)
    s = jnp.where(pos <= cp_ref[b, 0], s, _NEG_INF)

    m_prev = m_ref[:, :1]                                   # (G, 1)
    l_prev = l_ref[:, :1]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)                                  # (G, bs)
    vq = vq_ref[0, :, 0, :].astype(jnp.float32)             # (bs, D)
    vs = vs_ref[0, 0].astype(jnp.float32)                   # (D,)
    acc = acc_ref[...] * alpha + jnp.dot(
        p, vq, preferred_element_type=jnp.float32) * vs[None, :]
    l_new = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc
    m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(si == ns - 1)
    def _flush():
        o_ref[0, 0] = (acc / l_new).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("softcap", "interpret"))
def decode_attention_paged_q(q: jax.Array, k_q: jax.Array,
                             k_scale: jax.Array, v_q: jax.Array,
                             v_scale: jax.Array, block_tables: jax.Array,
                             cache_pos: jax.Array, *, softcap: float = 0.0,
                             interpret: bool = False) -> jax.Array:
    """Fused decode attention over an int8 paged KV pool.

    q (B, KH, G, D); k_q/v_q (NB+1, bs, KH, D) int8; per-block
    k/v_scale (NB+1, KH, D) f32; block_tables (B, nblk) int32;
    cache_pos (B, 1) int32 -> (B, KH, G, D) in q.dtype.
    """
    b, kh, g, d = q.shape
    nb1, bs, kh2, d2 = k_q.shape
    assert (kh, d) == (kh2, d2), (q.shape, k_q.shape)
    assert k_q.shape == v_q.shape
    assert k_scale.shape == v_scale.shape == (nb1, kh, d), \
        (k_scale.shape, v_scale.shape)
    nblk = block_tables.shape[1]
    assert block_tables.shape == (b, nblk), block_tables.shape
    assert cache_pos.shape == (b, 1), cache_pos.shape

    grid = (b, kh, nblk)
    kernel = functools.partial(_kernel_q, scale=1.0 / (d ** 0.5),
                               softcap=softcap)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, g, d), lambda i, j, s, bt, cp: (i, j, 0, 0)),
            pl.BlockSpec((1, bs, 1, d),
                         lambda i, j, s, bt, cp: (bt[i, s], 0, j, 0)),
            pl.BlockSpec((1, 1, d),
                         lambda i, j, s, bt, cp: (bt[i, s], j, 0)),
            pl.BlockSpec((1, bs, 1, d),
                         lambda i, j, s, bt, cp: (bt[i, s], 0, j, 0)),
            pl.BlockSpec((1, 1, d),
                         lambda i, j, s, bt, cp: (bt[i, s], j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d),
                               lambda i, j, s, bt, cp: (i, j, 0, 0)),
        scratch_shapes=[pltpu.VMEM((g, d), jnp.float32),
                        pltpu.VMEM((g, _MINOR), jnp.float32),
                        pltpu.VMEM((g, _MINOR), jnp.float32)],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kh, g, d), q.dtype),
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(block_tables, cache_pos, q, k_q, k_scale, v_q, v_scale)


def vmem_bytes(g: int, d: int, block_size: int, act_bytes: int = 4,
               q_bytes: int = 1) -> int:
    """VMEM footprint of one grid step (fit check used by ops.py) —
    same tile inventory as the slot kernel; the scale rows are absent
    from the f32 variant but cost nothing to keep in the bound."""
    return (g * d * act_bytes                 # q tile
            + 2 * block_size * d * q_bytes    # k + v block tiles
            + 2 * d * 4                       # per-block k/v scale rows
            + g * d * act_bytes               # out tile
            + g * d * 4                       # f32 accumulator
            + 2 * g * _MINOR * 4)             # running max / sum

"""Fused *quantized* low-rank matmul: y = (x @ dq(w0)) @ dq(w1).

Weight-only quantized variant of :mod:`repro.kernels.lowrank_matmul`
(same grid, same scratch-accumulator design): the factor tiles arrive in
VMEM as int8 (or fp8) values plus f32 per-channel scales, are
dequantized *in VMEM* right before the MXU dot, and the rank-bottleneck
intermediate ``h = x @ dq(w0)`` lives in the f32 scratch accumulator —
it never round-trips to HBM, and neither does any dequantized weight.

Why it's a serving win on top of the bf16 fused kernel: decode is
memory-bound on weight streaming, and int8 factors move **half the
bytes** per step (1 byte/elem vs 2, + a negligible ``R + S`` f32 scale
row).  Combined with the rank reduction itself the weight bytes per
token drop by ``2 * alpha`` vs the dense bf16 layer.

Scales follow :mod:`repro.quant.quantize`: ``w0_scale (1, R)``,
``w1_scale (1, S)`` — one f32 scale per output channel, broadcast over
the tile's input axis at dequant.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.lowrank_matmul import CompilerParams

DEFAULT_BM = 256
DEFAULT_BN = 256


def _kernel(x_ref, w0q_ref, w0s_ref, w1q_ref, w1s_ref, o_ref, h_ref):
    """x (bm, C); w0_q (C, R) + w0_scale (1, R); w1_q (R, bn) +
    w1_scale (1, bn); o (bm, bn); scratch h (bm, R) f32."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _compute_h():
        w0 = (w0q_ref[...].astype(jnp.float32) * w0s_ref[...]
              ).astype(x_ref.dtype)
        h_ref[...] = jnp.dot(x_ref[...], w0,
                             preferred_element_type=jnp.float32)

    w1 = (w1q_ref[...].astype(jnp.float32) * w1s_ref[...]
          ).astype(x_ref.dtype)
    h = h_ref[...].astype(x_ref.dtype)
    o_ref[...] = jnp.dot(h, w1,
                         preferred_element_type=jnp.float32
                         ).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("bm", "bn", "interpret"))
def lowrank_matmul_q(x: jax.Array, w0_q: jax.Array, w0_scale: jax.Array,
                     w1_q: jax.Array, w1_scale: jax.Array, *,
                     bm: int = DEFAULT_BM, bn: int = DEFAULT_BN,
                     interpret: bool = False) -> jax.Array:
    """y = (x @ (w0_q*w0_scale)) @ (w1_q*w1_scale), fused.

    x (M,C); w0_q (C,R); w0_scale (1,R); w1_q (R,S); w1_scale (1,S)
    -> (M,S).  Requires M % bm == 0 and S % bn == 0 (ops.py pads).
    """
    m, c = x.shape
    c2, r = w0_q.shape
    r2, s = w1_q.shape
    assert c == c2 and r == r2, (x.shape, w0_q.shape, w1_q.shape)
    assert w0_scale.shape == (1, r) and w1_scale.shape == (1, s), \
        (w0_scale.shape, w1_scale.shape)
    assert m % bm == 0 and s % bn == 0, (m, s, bm, bn)

    grid = (m // bm, s // bn)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, c), lambda i, j: (i, 0)),
            pl.BlockSpec((c, r), lambda i, j: (0, 0)),
            pl.BlockSpec((1, r), lambda i, j: (0, 0)),
            pl.BlockSpec((r, bn), lambda i, j: (0, j)),
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, s), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, r), jnp.float32)],
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
    )(x, w0_q, w0_scale, w1_q, w1_scale)


def vmem_bytes(m_block: int, c: int, r: int, s_block: int,
               act_bytes: int = 2, q_bytes: int = 1) -> int:
    """VMEM footprint of one grid step (fit check used by ops.py)."""
    return (m_block * c * act_bytes           # x block
            + c * r * q_bytes                 # w0_q (resident)
            + r * 4                           # w0_scale
            + r * s_block * q_bytes           # w1_q block
            + s_block * 4                     # w1_scale block
            + m_block * s_block * act_bytes   # out block
            + m_block * r * 4)                # f32 scratch h

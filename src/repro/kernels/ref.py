"""Pure-jnp oracles for the Pallas kernels (the allclose reference)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def lowrank_matmul_ref(x: jax.Array, w0: jax.Array, w1: jax.Array,
                       accum_dtype=jnp.float32) -> jax.Array:
    """y = (x @ w0) @ w1 through the rank bottleneck. x (M,C) -> (M,S)."""
    h = jnp.matmul(x, w0, preferred_element_type=accum_dtype)
    y = jnp.matmul(h.astype(x.dtype), w1, preferred_element_type=accum_dtype)
    return y.astype(x.dtype)


def lowrank_matmul_q_ref(x: jax.Array, w0_q: jax.Array, w0_scale: jax.Array,
                         w1_q: jax.Array, w1_scale: jax.Array,
                         accum_dtype=jnp.float32) -> jax.Array:
    """Dequantize-then-matmul oracle for the fused quantized kernel.

    Dequantizes each factor to ``x.dtype`` first (matching the kernel's
    in-VMEM dequant) and reuses the bf16 reference chain.
    """
    w0 = (w0_q.astype(accum_dtype) * w0_scale).astype(x.dtype)
    w1 = (w1_q.astype(accum_dtype) * w1_scale).astype(x.dtype)
    return lowrank_matmul_ref(x, w0, w1, accum_dtype)


def lowrank_matmul_qa_ref(x: jax.Array, w0_q: jax.Array,
                          w0_scale: jax.Array, w1_q: jax.Array,
                          w1_scale: jax.Array) -> jax.Array:
    """Exact-math oracle for the activation-quantized fused kernel.

    Replicates the kernel's arithmetic step by step — per-token absmax
    quantization of the activation rows, int8 x int8 dots with int32
    accumulation, scale folding after each dot, and the per-row int8
    requantization of the rank intermediate — rather than dequantizing
    and reusing the float chain, so kernel parity is tight (interpret
    mode matches to float rounding, not to quantization error).
    """
    from repro.kernels.lowrank_matmul_qa import quantize_rows
    xq, xs = quantize_rows(x)
    h = (jnp.matmul(xq, w0_q, preferred_element_type=jnp.int32)
         .astype(jnp.float32) * xs * w0_scale)
    hq, hs = quantize_rows(h)
    y = (jnp.matmul(hq, w1_q, preferred_element_type=jnp.int32)
         .astype(jnp.float32) * hs * w1_scale)
    return y.astype(x.dtype)


def branched_matmul_qa_ref(x: jax.Array, u_q: jax.Array,
                           u_scale: jax.Array, xc_q: jax.Array,
                           xc_scale: jax.Array, v_q: jax.Array,
                           v_scale: jax.Array) -> jax.Array:
    """Exact-math oracle for the activation-quantized branched kernel.

    Same discipline as :func:`lowrank_matmul_qa_ref`, per branch: the
    activation rows quantize once, each branch's three int8 x int8 dots
    fold their row x channel scale products, both rank intermediates
    requantize per-row, and the f32 branch contributions sum at the end.
    """
    from repro.kernels.lowrank_matmul_qa import quantize_rows
    xq, xs = quantize_rows(x)
    n = u_q.shape[0]
    y = jnp.zeros((x.shape[0], v_q.shape[-1]), jnp.float32)
    for i in range(n):
        h1 = (jnp.matmul(xq, u_q[i], preferred_element_type=jnp.int32)
              .astype(jnp.float32) * xs * u_scale[i])
        h1q, h1s = quantize_rows(h1)
        h2 = (jnp.matmul(h1q, xc_q[i], preferred_element_type=jnp.int32)
              .astype(jnp.float32) * h1s * xc_scale[i])
        h2q, h2s = quantize_rows(h2)
        y = y + (jnp.matmul(h2q, v_q[i], preferred_element_type=jnp.int32)
                 .astype(jnp.float32) * h2s * v_scale[i])
    return y.astype(x.dtype)


def lowrank_matmul_sq_ref(x: jax.Array, w0_sp: jax.Array, w0_idx: jax.Array,
                          w0_scale: jax.Array, w1_sp: jax.Array,
                          w1_idx: jax.Array, w1_scale: jax.Array,
                          accum_dtype=jnp.float32) -> jax.Array:
    """Expand-dequantize-then-matmul oracle for the fused sparse-int8
    kernel: scatters each factor's 2:4-packed rows back to dense in
    ``x.dtype`` (matching the kernel's in-VMEM expand + dequant) and
    reuses the plain reference chain."""
    from repro.quant.sparse import expand_sparse
    w0 = expand_sparse(w0_sp, w0_idx, w0_scale, x.dtype)
    w1 = expand_sparse(w1_sp, w1_idx, w1_scale, x.dtype)
    return lowrank_matmul_ref(x, w0, w1, accum_dtype)


def branched_matmul_ref(x: jax.Array, u: jax.Array, xc: jax.Array,
                        v: jax.Array, accum_dtype=jnp.float32) -> jax.Array:
    """y = sum_n ((x @ u_n) @ xc_n) @ v_n  (paper Eq. 17).

    x (M,C); u (N,C,r1); xc (N,r1,r2); v (N,r2,S) -> (M,S).
    """
    h = jnp.einsum("mc,ncr->nmr", x, u, preferred_element_type=accum_dtype)
    h = h.astype(x.dtype)
    h = jnp.einsum("nmr,nrs->nms", h, xc, preferred_element_type=accum_dtype)
    h = h.astype(x.dtype)
    y = jnp.einsum("nms,nso->mo", h, v, preferred_element_type=accum_dtype)
    return y.astype(x.dtype)


def branched_matmul_q_ref(x: jax.Array, u_q: jax.Array, u_scale: jax.Array,
                          xc_q: jax.Array, xc_scale: jax.Array,
                          v_q: jax.Array, v_scale: jax.Array,
                          accum_dtype=jnp.float32) -> jax.Array:
    """Dequantize-then-matmul oracle for the fused quantized branched
    kernel — dequantizes each factor to ``x.dtype`` (matching the
    kernel's in-VMEM dequant) and reuses the branched reference."""
    u = (u_q.astype(accum_dtype) * u_scale).astype(x.dtype)
    xc = (xc_q.astype(accum_dtype) * xc_scale).astype(x.dtype)
    v = (v_q.astype(accum_dtype) * v_scale).astype(x.dtype)
    return branched_matmul_ref(x, u, xc, v, accum_dtype)


def branched_matmul_sq_ref(x: jax.Array, u_sp: jax.Array, u_idx: jax.Array,
                           u_scale: jax.Array, xc_q: jax.Array,
                           xc_scale: jax.Array, v_sp: jax.Array,
                           v_idx: jax.Array, v_scale: jax.Array,
                           accum_dtype=jnp.float32) -> jax.Array:
    """Oracle for the fused sparse-int8 branched kernel: the outer
    ``u``/``v`` factors expand from their 2:4 packing per branch, the
    core ``xc`` dequantizes as a plain int8 tile, then the branched
    reference chain runs in ``x.dtype``."""
    from repro.quant.sparse import expand_sparse
    u = expand_sparse(u_sp, u_idx, u_scale, x.dtype)
    xc = (xc_q.astype(accum_dtype) * xc_scale).astype(x.dtype)
    v = expand_sparse(v_sp, v_idx, v_scale, x.dtype)
    return branched_matmul_ref(x, u, xc, v, accum_dtype)


def decode_attention_q_ref(q: jax.Array, k_q: jax.Array, k_scale: jax.Array,
                           v_q: jax.Array, v_scale: jax.Array,
                           cache_pos: jax.Array, *,
                           softcap: float = 0.0) -> jax.Array:
    """Dequantize-then-attend oracle for the fused int8 decode kernel.

    q (B, 1, H, D); k_q/v_q (B, S, KH, D) int8; k/v_scale (B, KH, D);
    cache_pos (B,) -> (B, 1, H, D) in q.dtype.  Full f32 softmax over
    the (validity-masked) sequence — the allclose target for the
    online-softmax kernel.
    """
    b, sq, h, d = q.shape
    skv, kh = k_q.shape[1], k_q.shape[2]
    k = k_q.astype(jnp.float32) * k_scale[:, None]
    v = v_q.astype(jnp.float32) * v_scale[:, None]
    qg = q.astype(jnp.float32).reshape(b, sq, kh, h // kh, d)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qg, k,
                   preferred_element_type=jnp.float32) / math.sqrt(d)
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    valid = jnp.arange(skv)[None, :] <= cache_pos[:, None]
    s = jnp.where(valid[:, None, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskh->bqkgh", p, v)
    return o.reshape(b, sq, h, d).astype(q.dtype)


def decode_attention_paged_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                               block_tables: jax.Array,
                               cache_pos: jax.Array, *,
                               softcap: float = 0.0) -> jax.Array:
    """Gather-then-attend oracle for the paged decode kernel.

    q (B, 1, H, D); k/v (NB+1, bs, KH, D) — batch axis = physical
    block, id NB is the reserved dummy; block_tables (B, nblk) int32;
    cache_pos (B,) -> (B, 1, H, D).  Gathers each stream's blocks into
    its logical (S, KH, D) view, then runs the exact slot-pool math
    (same op order as ``layers.cache.gqa_decode_attention``, so the f32
    paged path is bit-identical to the slot path).
    """
    b, sq, h, d = q.shape
    kh = k.shape[2]
    nblk, bs = block_tables.shape[1], k.shape[1]
    skv = nblk * bs
    kk = k[block_tables].reshape(b, skv, kh, d)      # (B, S, KH, D)
    vv = v[block_tables].reshape(b, skv, kh, d)
    qg = q.reshape(b, sq, kh, h // kh, d)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qg, kk,
                   preferred_element_type=jnp.float32) * (1.0 / math.sqrt(d))
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    valid = jnp.arange(skv)[None, :] <= cache_pos[:, None]
    s = jnp.where(valid[:, None, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1).astype(vv.dtype)
    o = jnp.einsum("bkgqs,bskh->bqkgh", p, vv)
    return o.reshape(b, sq, h, d)


def decode_attention_paged_q_ref(q: jax.Array, k_q: jax.Array,
                                 k_scale: jax.Array, v_q: jax.Array,
                                 v_scale: jax.Array,
                                 block_tables: jax.Array,
                                 cache_pos: jax.Array, *,
                                 softcap: float = 0.0) -> jax.Array:
    """Dequantize-gather-attend oracle for the paged int8 decode kernel.

    k_q/v_q (NB+1, bs, KH, D) int8 with PER-BLOCK scale rows
    k/v_scale (NB+1, KH, D) — a shared prefix block carries its own
    scales, so adopting it never requantizes.  Dequantizes per block,
    gathers through the tables, then full f32 softmax like
    :func:`decode_attention_q_ref`.
    """
    b, sq, h, d = q.shape
    kh = k_q.shape[2]
    nblk, bs = block_tables.shape[1], k_q.shape[1]
    skv = nblk * bs
    k = k_q.astype(jnp.float32) * k_scale[:, None]   # (NB+1, bs, KH, D)
    v = v_q.astype(jnp.float32) * v_scale[:, None]
    kk = k[block_tables].reshape(b, skv, kh, d)
    vv = v[block_tables].reshape(b, skv, kh, d)
    qg = q.astype(jnp.float32).reshape(b, sq, kh, h // kh, d)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qg, kk,
                   preferred_element_type=jnp.float32) / math.sqrt(d)
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    valid = jnp.arange(skv)[None, :] <= cache_pos[:, None]
    s = jnp.where(valid[:, None, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskh->bqkgh", p, vv)
    return o.reshape(b, sq, h, d).astype(q.dtype)


def decode_attention_latent_q_ref(q_lat: jax.Array, q_rope: jax.Array,
                                  ckv_q: jax.Array, ckv_scale: jax.Array,
                                  krope_q: jax.Array, krope_scale: jax.Array,
                                  cache_pos: jax.Array, *,
                                  scale: float) -> jax.Array:
    """Dequantize-then-attend oracle for the fused int8 MLA latent
    decode kernel (absorbed form).

    q_lat (B, 1, H, L); q_rope (B, 1, H, R); ckv_q (B, S, L) / krope_q
    (B, S, R) int8; ckv/krope_scale (B, L)/(B, R); cache_pos (B,) ->
    context latents (B, 1, H, L) in q_lat.dtype.  Full f32 softmax over
    the validity-masked latent pool — the allclose target for the
    online-softmax latent kernel.
    """
    cc = ckv_q.astype(jnp.float32) * ckv_scale[:, None]
    cr = krope_q.astype(jnp.float32) * krope_scale[:, None]
    s = (jnp.einsum("bqhl,bsl->bhqs", q_lat.astype(jnp.float32), cc,
                    preferred_element_type=jnp.float32)
         + jnp.einsum("bqhr,bsr->bhqs", q_rope.astype(jnp.float32), cr,
                      preferred_element_type=jnp.float32)) * scale
    valid = jnp.arange(cc.shape[1])[None, :] <= cache_pos[:, None]
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    ctx = jnp.einsum("bhqs,bsl->bqhl", p, cc)
    return ctx.astype(q_lat.dtype)

"""Pure-jnp oracles for the Pallas kernels (the allclose reference)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def lowrank_matmul_ref(x: jax.Array, w0: jax.Array, w1: jax.Array,
                       accum_dtype=jnp.float32) -> jax.Array:
    """y = (x @ w0) @ w1 through the rank bottleneck. x (M,C) -> (M,S)."""
    h = jnp.matmul(x, w0, preferred_element_type=accum_dtype)
    y = jnp.matmul(h.astype(x.dtype), w1, preferred_element_type=accum_dtype)
    return y.astype(x.dtype)


def branched_matmul_ref(x: jax.Array, u: jax.Array, xc: jax.Array,
                        v: jax.Array, accum_dtype=jnp.float32) -> jax.Array:
    """y = sum_n ((x @ u_n) @ xc_n) @ v_n  (paper Eq. 17).

    x (M,C); u (N,C,r1); xc (N,r1,r2); v (N,r2,S) -> (M,S).
    """
    h = jnp.einsum("mc,ncr->nmr", x, u, preferred_element_type=accum_dtype)
    h = h.astype(x.dtype)
    h = jnp.einsum("nmr,nrs->nms", h, xc, preferred_element_type=accum_dtype)
    h = h.astype(x.dtype)
    y = jnp.einsum("nms,nso->mo", h, v, preferred_element_type=accum_dtype)
    return y.astype(x.dtype)

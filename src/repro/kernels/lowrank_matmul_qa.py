"""Fused *activation-quantized* low-rank matmul: int8 x int8 on the MXU.

Activation-quantized variant of :mod:`repro.kernels.lowrank_matmul_q`
(same grid, same once-per-row-block rank intermediate): instead of
dequantizing the int8 factors up to activation width and multiplying in
f32, the activation rows are quantized *on the fly* — per-token (row)
absmax over the contraction axis — so both MXU dots run int8 x int8
with int32 accumulation.  Scales fold into the output exactly once per
dot: ``x_scale (bm,1) * w0_scale (1,R)`` after stage 1, and
``h_scale (bm,1) * w1_scale (1,bn)`` after stage 2.  The rank
intermediate ``h`` is requantized per-row to int8 in VMEM scratch
(int8 values + f32 row scales) so stage 2 also runs at int8 operand
width — no f32 activation tile is ever re-read.

Why prefill cares: prefill is the M-large MXU-bound segment, and the
MXU runs int8 x int8 at ~2x the f32 rate while the activation stream
between the decomposed stages halves.  Decode (M = batch) stays on the
weight-only kernels — its dots are too skinny for the throughput term
to matter and per-row scales over a handful of rows buy nothing.

Padding discipline: per-token scales are **row-local** (absmax over the
row's own C entries), so bucket-padded all-zero rows get scale 0,
quantize to all-zero int8 rows, and contribute exactly zero — real
rows' scales never see padding (the KV pad-masking discipline from the
serve tier, applied to activations).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.lowrank_matmul import CompilerParams

DEFAULT_BM = 256
DEFAULT_BN = 256

INT8_QMAX = 127.0


def quantize_rows(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-row (per-token) symmetric absmax int8 quantization.

    x (M, K) any float -> (int8 (M, K), f32 scales (M, 1)).  All-zero
    rows get scale 0 with a safe divisor (the convention of
    :func:`repro.quant.quantize.quantize_array`, per-row instead of
    per-channel), so padded rows stay exactly zero.
    """
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1, keepdims=True)
    scale = amax / INT8_QMAX
    safe = jnp.where(scale > 0, scale, 1.0)
    q = jnp.clip(jnp.round(xf / safe), -INT8_QMAX, INT8_QMAX)
    return q.astype(jnp.int8), scale


def _kernel(x_ref, w0q_ref, w0s_ref, w1q_ref, w1s_ref, o_ref,
            hq_ref, hs_ref):
    """x (bm, C); w0_q (C, R) + w0_scale (1, R); w1_q (R, bn) +
    w1_scale (1, bn); o (bm, bn); scratch hq (bm, R) int8 +
    h_scale (bm, 1) f32."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _compute_h():
        xq, xs = quantize_rows(x_ref[...])
        acc = jnp.dot(xq, w0q_ref[...],
                      preferred_element_type=jnp.int32)
        h = acc.astype(jnp.float32) * xs * w0s_ref[...]
        hq_ref[...], hs_ref[...] = quantize_rows(h)

    acc = jnp.dot(hq_ref[...], w1q_ref[...],
                  preferred_element_type=jnp.int32)
    o_ref[...] = (acc.astype(jnp.float32) * hs_ref[...] * w1s_ref[...]
                  ).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("bm", "bn", "interpret"))
def lowrank_matmul_qa(x: jax.Array, w0_q: jax.Array, w0_scale: jax.Array,
                      w1_q: jax.Array, w1_scale: jax.Array, *,
                      bm: int = DEFAULT_BM, bn: int = DEFAULT_BN,
                      interpret: bool = False) -> jax.Array:
    """y = dq(q(x) @ w0_q) -> requant -> dq(h_q @ w1_q), all-int8 dots.

    x (M,C); w0_q (C,R); w0_scale (1,R); w1_q (R,S); w1_scale (1,S)
    -> (M,S).  Requires M % bm == 0 and S % bn == 0 (ops.py pads).
    """
    m, c = x.shape
    c2, r = w0_q.shape
    r2, s = w1_q.shape
    assert c == c2 and r == r2, (x.shape, w0_q.shape, w1_q.shape)
    assert w0_scale.shape == (1, r) and w1_scale.shape == (1, s), \
        (w0_scale.shape, w1_scale.shape)
    assert m % bm == 0 and s % bn == 0, (m, s, bm, bn)

    grid = (m // bm, s // bn)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, c), lambda i, j: (i, 0)),
            pl.BlockSpec((c, r), lambda i, j: (0, 0)),
            pl.BlockSpec((1, r), lambda i, j: (0, 0)),
            pl.BlockSpec((r, bn), lambda i, j: (0, j)),
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, s), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, r), jnp.int8),
                        pltpu.VMEM((bm, 1), jnp.float32)],
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
    )(x, w0_q, w0_scale, w1_q, w1_scale)


def vmem_bytes(m_block: int, c: int, r: int, s_block: int,
               act_bytes: int = 2, q_bytes: int = 1) -> int:
    """VMEM footprint of one grid step (fit check used by ops.py).

    Counts the f32 pre-quant activation block plus its transient int8
    copy and row scales, the int8 factor tiles + scale rows, the int8
    rank scratch (+ f32 transient h at requant), and the out block.
    """
    return (m_block * c * act_bytes           # x block
            + m_block * c                     # int8 x (transient)
            + m_block * 4                     # x row scales
            + c * r * q_bytes                 # w0_q (resident)
            + r * 4                           # w0_scale
            + r * s_block * q_bytes           # w1_q block
            + s_block * 4                     # w1_scale block
            + m_block * s_block * act_bytes   # out block
            + m_block * r                     # int8 scratch h
            + m_block * r * 4                 # f32 h at requant (transient)
            + m_block * 4)                    # h row scales

"""Fused *sparse-quantized* branched matmul (paper Eq. 17 chain).

    y = sum_n ((x @ ds(u_n)) @ dq(xc_n)) @ ds(v_n)

Compound-compression variant of :mod:`repro.kernels.branched_matmul_q`
(same ``(M/bm, S/bn, N)`` branch-innermost grid, same branch-sum f32
scratch accumulator): the outer ``u``/``v`` factors arrive per branch
as 2:4-packed int8 values + int8 row-index metadata + f32 scales and
are **expanded and dequantized in VMEM**
(:func:`repro.kernels.lowrank_matmul_sq.expand_tile`); the small
trainable core ``xc`` stays a plain int8 tile (it is excluded from the
default sparse targets — pruning the already-tiny core buys little and
costs accuracy).  Neither a dense nor a dequantized weight ever
round-trips to HBM.

Layout follows :mod:`repro.quant.sparse` with the branch axis leading:
``u_sp (N, 2, C/4, r1)``, ``u_idx (N, 2, C/4, 1)``,
``u_scale (N, 1, r1)``; ``xc_q (N, r1, r2)``, ``xc_scale (N, 1, r2)``;
``v_sp (N, 2, r2/4, S)``, ``v_idx (N, 2, r2/4, 1)``,
``v_scale (N, 1, S)``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.lowrank_matmul import CompilerParams
from repro.kernels.lowrank_matmul_sq import expand_tile

DEFAULT_BM = 256
DEFAULT_BN = 256


def _kernel(x_ref, usp_ref, ui_ref, us_ref, xcq_ref, xcs_ref,
            vsp_ref, vi_ref, vs_ref, o_ref, acc_ref):
    """x (bm, C); u pack (1, 2, C/4, r1)+(1, 2, C/4, 1)+(1, 1, r1);
    xc (1, r1, r2)+(1, 1, r2); v pack (1, 2, r2/4, bn)+(1, 2, r2/4, 1)
    +(1, 1, bn); o (bm, bn); acc (bm, bn) f32 scratch."""
    n = pl.program_id(2)
    n_total = pl.num_programs(2)

    u = expand_tile(usp_ref[0], ui_ref[0], us_ref[0], x_ref.dtype)
    xc = (xcq_ref[0].astype(jnp.float32) * xcs_ref[0]).astype(x_ref.dtype)
    v = expand_tile(vsp_ref[0], vi_ref[0], vs_ref[0], x_ref.dtype)

    h1 = jnp.dot(x_ref[...], u,
                 preferred_element_type=jnp.float32).astype(x_ref.dtype)
    h2 = jnp.dot(h1, xc,
                 preferred_element_type=jnp.float32).astype(x_ref.dtype)
    contrib = jnp.dot(h2, v, preferred_element_type=jnp.float32)

    @pl.when(n == 0)
    def _init():
        acc_ref[...] = contrib

    @pl.when(n > 0)
    def _accum():
        acc_ref[...] += contrib

    @pl.when(n == n_total - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def branched_matmul_sq(x: jax.Array, u_sp: jax.Array, u_idx: jax.Array,
                       u_scale: jax.Array, xc_q: jax.Array,
                       xc_scale: jax.Array, v_sp: jax.Array,
                       v_idx: jax.Array, v_scale: jax.Array, *,
                       bm: int = DEFAULT_BM, bn: int = DEFAULT_BN,
                       interpret: bool = False) -> jax.Array:
    """x (M, C); u_sp (N, 2, C/4, r1); xc_q (N, r1, r2); v_sp
    (N, 2, r2/4, S) + index metadata + per-branch per-output-channel
    scales -> (M, S).  Requires M % bm == 0 and S % bn == 0 (ops.py
    pads), C % 4 == 0 and r2 % 4 == 0 (the packing's group size)."""
    m, c = x.shape
    nb, two, c4, r1 = u_sp.shape
    _, _, r2 = xc_q.shape
    _, _, r24, s = v_sp.shape
    assert two == 2 and c == 4 * c4 and r2 == 4 * r24, \
        (x.shape, u_sp.shape, xc_q.shape, v_sp.shape)
    assert u_idx.shape == (nb, 2, c4, 1) and v_idx.shape == (nb, 2, r24, 1), \
        (u_idx.shape, v_idx.shape)
    assert u_scale.shape == (nb, 1, r1) and xc_scale.shape == (nb, 1, r2) \
        and v_scale.shape == (nb, 1, s), \
        (u_scale.shape, xc_scale.shape, v_scale.shape)
    assert m % bm == 0 and s % bn == 0, (m, s, bm, bn)

    grid = (m // bm, s // bn, nb)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, c), lambda i, j, k: (i, 0)),
            pl.BlockSpec((1, 2, c4, r1), lambda i, j, k: (k, 0, 0, 0)),
            pl.BlockSpec((1, 2, c4, 1), lambda i, j, k: (k, 0, 0, 0)),
            pl.BlockSpec((1, 1, r1), lambda i, j, k: (k, 0, 0)),
            pl.BlockSpec((1, r1, r2), lambda i, j, k: (k, 0, 0)),
            pl.BlockSpec((1, 1, r2), lambda i, j, k: (k, 0, 0)),
            pl.BlockSpec((1, 2, r24, bn), lambda i, j, k: (k, 0, 0, j)),
            pl.BlockSpec((1, 2, r24, 1), lambda i, j, k: (k, 0, 0, 0)),
            pl.BlockSpec((1, 1, bn), lambda i, j, k: (k, 0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, s), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(x, u_sp, u_idx, u_scale, xc_q, xc_scale, v_sp, v_idx, v_scale)


def vmem_bytes(m_block: int, c: int, r1: int, r2: int, s_block: int,
               act_bytes: int = 2, q_bytes: int = 1) -> int:
    """VMEM footprint of one grid step (fit check used by ops.py).

    Counts one branch's packed u/v tiles + the int8 core + index/scale
    metadata, their expanded f32 and activation-width copies, and the
    f32 branch accumulator + out block.
    """
    packed = (c // 2) * r1 + (r2 // 2) * s_block     # kept u/v values
    meta = (c // 2) + (r2 // 2)                      # int8 indices
    expanded = (c * r1 + r1 * r2 + r2 * s_block) * (4 + act_bytes)
    return (m_block * c * act_bytes
            + packed * q_bytes + r1 * r2 * q_bytes + meta
            + (r1 + r2 + s_block) * 4
            + expanded
            + 2 * m_block * s_block * (act_bytes + 4))

"""Roofline analysis from compiled HLO (the dry-run's perf report).

XLA's ``compiled.cost_analysis()`` reports *one iteration* of every
``while`` body (verified experimentally) and per-device numbers.  This
module therefore parses ``compiled.as_text()`` (optimized, post-SPMD HLO)
itself:

* **FLOPs** — every ``dot`` (2 * |out| * |contracted|) and ``convolution``
  (2 * |out| * k_h * k_w * C_in / groups), with ops inside ``while`` bodies
  scaled by the loop trip count (detected from the loop-bound constant in
  the condition computation; recursive for nested scans).
* **HBM traffic** — fusion-boundary accounting: for every materialized op
  (fusion, dot, conv, copy, collective, reduce, scatter/gather, ...) count
  written output bytes + read operand bytes (operands resolved through the
  name->shape table).  This is the standard no-reuse roofline convention.
* **Collective bytes** — per collective op, payload bytes x the ring
  factor for its group size N (all-reduce 2(N-1)/N, all-gather /
  reduce-scatter / all-to-all (N-1)/N, collective-permute 1), scaled by
  trip counts like everything else.

The three roofline terms then follow from the hardware constants in
:mod:`repro.analysis.hw_specs`:

    compute    = FLOPs_per_device / peak_FLOP/s
    memory     = HBM_bytes_per_device / HBM_bw
    collective = collective_bytes_per_device / link_bw
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any

from repro.analysis.hw_specs import DEFAULT, HardwareSpec

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_MATERIALIZED = _COLLECTIVES + (
    "fusion", "dot", "convolution", "copy", "reduce", "sort", "scatter",
    "gather", "dynamic-slice", "dynamic-update-slice", "transpose",
    "broadcast", "iota", "concatenate", "slice", "reverse", "pad",
    "select-and-scatter", "reduce-window", "cholesky", "triangular-solve",
    "rng", "convert", "custom-call",
)

_FREE = ("get-tuple-element", "tuple", "bitcast", "parameter", "constant",
         "after-all", "partition-id", "replica-id", "bitcast-convert",
         "reshape")


@dataclasses.dataclass
class Op:
    name: str
    kind: str
    shape_str: str
    out_bytes: float
    out_elems: float
    operands: list[str]
    attrs: str


@dataclasses.dataclass
class Computation:
    name: str
    ops: dict[str, Op]
    order: list[str]


_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\]"
    r"(?:\{[^}]*\})?)\s*([\w\-]+)\((.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*->.*\{\s*$")


def _shape_bytes(shape_str: str) -> tuple[float, float]:
    """Total (bytes, elems) of a shape string (sums tuple components)."""
    total_b = total_e = 0.0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        elems = 1.0
        if dims:
            for d in dims.split(","):
                elems *= int(d)
        total_e += elems
        total_b += elems * _DTYPE_BYTES[dtype]
    return total_b, total_e


def _operand_names(argstr: str) -> list[str]:
    # operands are the leading %names before the first "),"-style attr
    names = []
    depth = 0
    for tok in re.finditer(r"%([\w.\-]+)|[()]", argstr):
        t = tok.group(0)
        if t == "(":
            depth += 1
        elif t == ")":
            if depth == 0:
                break
            depth -= 1
        else:
            names.append(tok.group(1))
    return names


def parse_hlo(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_RE.match(line)
            if m:
                cur = Computation(m.group(1), {}, [])
            continue
        if line.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        name, shape_str, kind, rest = m.groups()
        out_b, out_e = _shape_bytes(shape_str)
        cur.ops[name] = Op(name, kind, shape_str, out_b, out_e,
                           _operand_names(rest), rest)
        cur.order.append(name)
    return comps


# ---------------------------------------------------------------------------
# Per-op costs
# ---------------------------------------------------------------------------

def _dot_flops(op: Op, comp: Computation) -> float:
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.attrs)
    if not m or not op.operands:
        return 0.0
    lhs = comp.ops.get(op.operands[0])
    if lhs is None:
        return 0.0
    dims_m = _SHAPE_RE.search(lhs.shape_str)
    if not dims_m or not dims_m.group(2):
        return 0.0
    lhs_dims = [int(d) for d in dims_m.group(2).split(",")]
    contracted = 1.0
    if m.group(1):
        for d in m.group(1).split(","):
            contracted *= lhs_dims[int(d)]
    return 2.0 * op.out_elems * contracted


def _conv_flops(op: Op, comp: Computation) -> float:
    if len(op.operands) < 2:
        return 0.0
    rhs = comp.ops.get(op.operands[1])
    if rhs is None:
        return 0.0
    dims_m = _SHAPE_RE.search(rhs.shape_str)
    if not dims_m or not dims_m.group(2):
        return 0.0
    rhs_dims = [int(d) for d in dims_m.group(2).split(",")]
    # rhs dims: spatial... + input features + output features (per dim_labels)
    lab = re.search(r"dim_labels=\w+_(\w+)->", op.attrs)
    groups = 1
    gm = re.search(r"feature_group_count=(\d+)", op.attrs)
    if gm:
        groups = int(gm.group(1))
    if lab:
        rl = lab.group(1)       # e.g. "01io"
        per_out = 1.0
        for ch, d in zip(rl, rhs_dims):
            if ch != "o":
                per_out *= d
    else:
        per_out = 1.0
        for d in rhs_dims[:-1]:
            per_out *= d
    return 2.0 * op.out_elems * per_out  # rhs 'i' is already per-group


def _group_size(op: Op, total_devices: int) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", op.attrs)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([0-9,]+)\}", op.attrs)
    if m:
        return len(m.group(1).split(","))
    return total_devices


def _collective_bytes(op: Op, comp: Computation, n_devices: int,
                      pure: set | None = None) -> float:
    """On-wire bytes per device (ring algorithms).

    When the collective's operand resolves (through CPU-inserted dtype
    converts) to a bf16 value, the payload is counted at bf16 size: on
    TPU the collective runs on the bf16 tensor directly.
    """
    n = max(2, _group_size(op, n_devices))
    ring = (n - 1) / n
    kind = op.kind.replace("-start", "")
    payload = op.out_bytes
    if pure and op.operands:
        src = _resolve_through_converts(op.operands[0], comp, pure)
        if src is not None and src.shape_str.startswith("bf16[") \
                and op.shape_str.startswith("f32["):
            payload = payload / 2.0
    if kind == "all-reduce":
        return 2.0 * ring * payload
    if kind == "all-gather":
        return ring * payload
    if kind == "reduce-scatter":
        in_bytes = sum(comp.ops[o].out_bytes for o in op.operands
                       if o in comp.ops)
        return ring * (in_bytes or payload * n)
    if kind == "all-to-all":
        return ring * payload
    if kind == "collective-permute":
        return payload
    return 0.0


# ---------------------------------------------------------------------------
# While-loop trip counts
# ---------------------------------------------------------------------------

def _trip_count(op: Op, comps: dict[str, Computation],
                default: int = 1) -> int:
    # XLA annotates analyzed loops directly — trust it first.
    m = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', op.attrs)
    if m:
        return max(1, int(m.group(1)))
    cm = re.search(r"condition=%?([\w.\-]+)", op.attrs)
    if not cm or cm.group(1) not in comps:
        return default
    cond = comps[cm.group(1)]
    # the loop bound = the largest scalar integer constant in the condition
    bounds = []
    for o in cond.ops.values():
        if o.kind != "constant" or not o.shape_str.startswith(("s32[]",
                                                               "u32[]",
                                                               "s64[]")):
            continue
        cm2 = re.match(r"\s*(\d+)\)?", o.attrs)
        if cm2:
            bounds.append(int(cm2.group(1)))
    if bounds:
        return max(1, max(bounds))
    return default


@dataclasses.dataclass
class HloCosts:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: float = 0.0
    collective_detail: dict = dataclasses.field(default_factory=dict)
    while_trips: dict = dataclasses.field(default_factory=dict)

    def add(self, other: "HloCosts", scale: float = 1.0) -> None:
        self.flops += scale * other.flops
        self.hbm_bytes += scale * other.hbm_bytes
        self.collective_bytes += scale * other.collective_bytes
        for k, v in other.collective_detail.items():
            self.collective_detail[k] = \
                self.collective_detail.get(k, 0.0) + scale * v


def _pure_convert_names(comps: dict[str, Computation]) -> dict[str, set]:
    """Per computation: ops that are dtype converts (or fusions wrapping
    only a convert).  The XLA *CPU* backend materializes f32 copies of
    bf16 values around every dot; on TPU the MXU consumes bf16 and the
    f32->bf16 output cast fuses — so converts are *free* for the TPU
    roofline and traffic is accounted at the underlying value's size."""
    out: dict[str, set] = {}
    for comp in comps.values():
        pure: set[str] = set()
        for op in comp.ops.values():
            if op.kind == "convert":
                pure.add(op.name)
            elif op.kind == "fusion":
                m = re.search(r"calls=%?([\w.\-]+)", op.attrs)
                body = comps.get(m.group(1)) if m else None
                if body is not None and {o.kind for o in body.ops.values()} \
                        <= {"parameter", "convert", "bitcast", "copy",
                            "reshape"}:
                    pure.add(op.name)
        out[comp.name] = pure
    return out


def _resolve_through_converts(name: str, comp: Computation,
                              pure: set) -> Op | None:
    """Follow convert chains to the underlying (TPU-real) value."""
    seen = 0
    op = comp.ops.get(name)
    while op is not None and op.name in pure and op.operands and seen < 8:
        op = comp.ops.get(op.operands[0])
        seen += 1
    return op


def _fusion_sliced_params(op: Op, comps: dict[str, Computation]
                          ) -> dict[int, float]:
    """For a fusion op: operand indices consumed *only* via dynamic-slice
    (or gather) inside the body -> bytes actually read per execution."""
    m = re.search(r"calls=%?([\w.\-]+)", op.attrs)
    body = comps.get(m.group(1)) if m else None
    if body is None:
        return {}
    # parameter name -> operand index
    pidx: dict[str, int] = {}
    for o in body.ops.values():
        if o.kind == "parameter":
            im = re.match(r"(\d+)\)?", o.attrs)
            if im:
                pidx[o.name] = int(im.group(1))
    read: dict[int, float] = {}
    bad: set[int] = set()
    for o in body.ops.values():
        if o.kind == "parameter":
            continue
        for j, operand in enumerate(o.operands):
            if operand not in pidx:
                continue
            i = pidx[operand]
            if o.kind in ("dynamic-slice", "gather", "slice") and j == 0:
                read[i] = read.get(i, 0.0) + o.out_bytes
            elif o.kind in ("convert", "bitcast", "reshape", "copy"):
                # pass-through: conservatively treat as full read
                bad.add(i)
            else:
                bad.add(i)
    return {i: b for i, b in read.items() if i not in bad}


def _fusion_dus_root(op: Op, comps: dict[str, Computation]
                     ) -> tuple[float, int] | None:
    """If a fusion's root is a dynamic-update-slice of parameter K, return
    (update_bytes, K): the fusion writes only the slice in place."""
    m = re.search(r"calls=%?([\w.\-]+)", op.attrs)
    body = comps.get(m.group(1)) if m else None
    if body is None or not body.order:
        return None
    root = body.ops[body.order[-1]]
    if root.kind != "dynamic-update-slice" or len(root.operands) < 2:
        return None
    tgt = body.ops.get(root.operands[0])
    upd = body.ops.get(root.operands[1])
    if tgt is None or upd is None or tgt.kind != "parameter":
        return None
    im = re.match(r"(\d+)\)?", tgt.attrs)
    if not im:
        return None
    return upd.out_bytes, int(im.group(1))


def _comp_costs(comp: Computation, comps: dict[str, Computation],
                n_devices: int, visited_fusions: dict,
                memo: dict) -> HloCosts:
    if comp.name in memo:
        return memo[comp.name]
    if "__pure__" not in visited_fusions:
        visited_fusions["__pure__"] = _pure_convert_names(comps)
    pure_all = visited_fusions["__pure__"]
    pure = pure_all.get(comp.name, set())
    costs = HloCosts()
    for name in comp.order:
        op = comp.ops[name]
        kind = op.kind.replace("-start", "") if op.kind.endswith("-start") \
            else op.kind
        if op.kind.endswith("-done"):
            continue
        if kind == "while":
            bm = re.search(r"body=%?([\w.\-]+)", op.attrs)
            trips = _trip_count(op, comps)
            if bm and bm.group(1) in comps:
                body = _comp_costs(comps[bm.group(1)], comps, n_devices,
                                   visited_fusions, memo)
                costs.add(body, scale=trips)
                costs.while_trips[name] = trips
            continue
        if kind in ("call", "conditional"):
            for cname in re.findall(r"(?:to_apply|calls)=%?([\w.\-]+)",
                                    op.attrs):
                if cname in comps:
                    costs.add(_comp_costs(comps[cname], comps, n_devices,
                                          visited_fusions, memo))
            continue
        if kind == "dot":
            costs.flops += _dot_flops(op, comp)
        elif kind == "convolution":
            costs.flops += _conv_flops(op, comp)
        elif kind == "fusion":
            # dots/convs inside fusions still carry their own cost
            fm = re.search(r"calls=%?([\w.\-]+)", op.attrs)
            if fm and fm.group(1) in comps:
                sub = comps[fm.group(1)]
                for o in sub.ops.values():
                    if o.kind == "dot":
                        costs.flops += _dot_flops(o, sub)
                    elif o.kind == "convolution":
                        costs.flops += _conv_flops(o, sub)
        if kind in _COLLECTIVES:
            b = _collective_bytes(op, comp, n_devices, pure)
            costs.collective_bytes += b
            costs.collective_detail[kind] = \
                costs.collective_detail.get(kind, 0.0) + b
        # HBM traffic: materialized outputs + materialized operand reads.
        # Pure dtype-converts are CPU-backend artifacts (TPU fuses the
        # cast): skip their output and account reads/writes at the
        # underlying value's size.  A fusion that only *dynamic-slices*
        # an operand (the scan-over-stacked-layers pattern) is charged
        # the slice, not the full stack — otherwise every layer-scan
        # iteration would be billed the whole weight stack.
        if kind in _MATERIALIZED and name not in pure:
            out_charge = op.out_bytes
            skip_read: set[int] = set()
            if kind == "dynamic-update-slice":
                # in-place update: traffic = the updated slice (r+w), not
                # the whole buffer (XLA aliases the target).
                upd = comp.ops.get(op.operands[1]) if len(op.operands) > 1 \
                    else None
                if upd is not None:
                    out_charge = upd.out_bytes
                skip_read.add(0)
            sliced = {}
            if kind == "fusion":
                sliced = _fusion_sliced_params(op, comps)
                dus = _fusion_dus_root(op, comps)
                if dus is not None:
                    out_charge = min(out_charge, dus[0])
                    skip_read.add(dus[1])
            costs.hbm_bytes += out_charge
            for i, o in enumerate(op.operands):
                if i in skip_read:
                    continue
                src = _resolve_through_converts(o, comp, pure)
                if src is None or src.out_bytes <= 128:
                    continue
                costs.hbm_bytes += min(src.out_bytes,
                                       sliced.get(i, src.out_bytes))
    memo[comp.name] = costs
    return costs


def cpu_bf16_upcast_bytes(text: str) -> float:
    """Bytes of f32 copies of bf16 parameters/caches created by the XLA
    *CPU* backend (it has no native bf16 dot/scatter, so it materializes
    f32 upcasts of loop-invariant weights and cache buffers).  These
    buffers do not exist on TPU — the dry-run's corrected peak subtracts
    them.  Counted: top-level ``convert``/``copy``-to-f32 ops (and f32
    dynamic-update-slice chains) whose operand is a parameter /
    get-tuple-element of matching element count.
    """
    comps = parse_hlo(text)
    # fusion bodies are not buffer boundaries — their "parameters" are
    # producer outputs, not real buffers; only scan entry + control-flow
    # computations (while bodies / conds / entry).
    fusion_bodies: set[str] = set()
    for comp in comps.values():
        for op in comp.ops.values():
            if op.kind == "fusion":
                m = re.search(r"calls=%?([\w.\-]+)", op.attrs)
                if m:
                    fusion_bodies.add(m.group(1))
            for sub in re.findall(r"(?:to_apply)=%?([\w.\-]+)", op.attrs):
                fusion_bodies.add(sub)
    def is_pure_convert(op: Op) -> bool:
        if op.kind == "convert":
            return True
        if op.kind != "fusion":
            return False
        m = re.search(r"calls=%?([\w.\-]+)", op.attrs)
        body = comps.get(m.group(1)) if m else None
        if body is None:
            return False
        kinds = {o.kind for o in body.ops.values()}
        return kinds <= {"parameter", "convert", "bitcast", "copy"}

    total = 0.0
    for comp in comps.values():
        if comp.name in fusion_bodies:
            continue
        for op in comp.ops.values():
            if not op.shape_str.startswith("f32[") or op.out_bytes < 64e6:
                continue
            if not is_pure_convert(op):
                continue
            src = comp.ops.get(op.operands[0]) if op.operands else None
            if src is None:
                continue
            if src.kind in ("parameter", "get-tuple-element", "copy") \
                    and src.shape_str.startswith("bf16[") \
                    and abs(src.out_bytes * 2 - op.out_bytes) < 1:
                total += op.out_bytes
    return total


def analyze_hlo(text: str, n_devices: int) -> HloCosts:
    comps = parse_hlo(text)
    entry = None
    for line in text.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_RE.match(line)
            if m:
                entry = m.group(1)
            break
    if entry is None or entry not in comps:
        # fall back: the computation with the most ops
        entry = max(comps, key=lambda c: len(comps[c].ops))
    return _comp_costs(comps[entry], comps, n_devices, {}, {})


# ---------------------------------------------------------------------------
# Roofline report
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    flops: float
    hbm_bytes: float
    collective_bytes: float
    model_flops: float
    bottleneck: str
    useful_ratio: float
    detail: dict

    @property
    def step_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """model-useful compute time / bound step time (the score)."""
        if self.step_s <= 0:
            return 0.0
        return min(1.0, (self.model_flops and
                         self.model_flops / self.flops or 0.0)
                   * self.compute_s / self.step_s)


def roofline(costs: HloCosts, *, n_devices: int, model_flops_global: float,
             spec: HardwareSpec = DEFAULT) -> Roofline:
    """``costs`` are per-device (post-SPMD HLO); model_flops are global."""
    compute = costs.flops / spec.peak_flops_bf16
    memory = costs.hbm_bytes / spec.hbm_bandwidth
    coll = costs.collective_bytes / spec.ici_link_bandwidth
    model_per_dev = model_flops_global / n_devices
    terms = {"compute": compute, "memory": memory, "collective": coll}
    bottleneck = max(terms, key=terms.get)
    useful = model_per_dev / costs.flops if costs.flops else 0.0
    return Roofline(compute, memory, coll, costs.flops, costs.hbm_bytes,
                    costs.collective_bytes, model_per_dev, bottleneck,
                    useful, dict(costs.collective_detail))

"""Aggregate dry-run cell JSONs into the EXPERIMENTS.md tables.

    PYTHONPATH=src python -m repro.analysis.report results/dryrun [--mesh sp]
"""
from __future__ import annotations

import glob
import json
import os
import sys


def load_cells(out_dir: str) -> list[dict]:
    cells = []
    for f in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(f) as fh:
            cells.append(json.load(fh))
    return cells


def _fmt_s(x: float) -> str:
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.1f}ms"
    return f"{x * 1e6:.0f}us"


def roofline_table(cells: list[dict], mesh: str = "single_pod") -> str:
    rows = ["| arch | shape | compute | memory | collective | bound | "
            "MODEL/HLO flops | roofline-frac | fits HBM |",
            "|---|---|---|---|---|---|---|---|---|"]
    for c in cells:
        if c["mesh"] != mesh:
            continue
        if c["status"] == "skip":
            rows.append(f"| {c['arch']} | {c['shape']} | — | — | — | "
                        f"skip: {c['reason']} | — | — | — |")
            continue
        if c["status"] != "ok":
            rows.append(f"| {c['arch']} | {c['shape']} | ERROR |||||||")
            continue
        r = c["roofline"]
        m = c["memory"]
        rows.append(
            f"| {c['arch']} | {c['shape']} | {_fmt_s(r['compute_s'])} | "
            f"{_fmt_s(r['memory_s'])} | {_fmt_s(r['collective_s'])} | "
            f"**{r['bottleneck']}** | {r['useful_flops_ratio']:.2f} | "
            f"{r['roofline_fraction']:.4f} | "
            f"{'yes' if m['fits_hbm'] else 'NO'} |")
    return "\n".join(rows)


def dryrun_table(cells: list[dict], mesh: str) -> str:
    rows = ["| arch | shape | compile | bytes/dev (arg+tmp, TPU-corr.) | "
            "HLO flops/dev | coll. bytes/dev | collectives |",
            "|---|---|---|---|---|---|---|"]
    for c in cells:
        if c["mesh"] != mesh or c["status"] != "ok":
            continue
        m, co = c["memory"], c["costs"]
        det = ", ".join(f"{k.replace('all-','a')}:{v / 1e9:.1f}G"
                        for k, v in sorted(
                            co["collective_detail"].items()))
        rows.append(
            f"| {c['arch']} | {c['shape']} | {c['compile_s']}s | "
            f"{m['peak_bytes_tpu_corrected'] / 1e9:.1f} GB | "
            f"{co['flops_per_device'] / 1e12:.2f} T | "
            f"{co['collective_bytes_per_device'] / 1e9:.2f} GB | {det} |")
    return "\n".join(rows)


def summarize(out_dir: str) -> dict:
    cells = load_cells(out_dir)
    ok = [c for c in cells if c["status"] == "ok"]
    skip = [c for c in cells if c["status"] == "skip"]
    err = [c for c in cells if c["status"] == "error"]
    worst = sorted((c for c in ok if c["mesh"] == "single_pod"),
                   key=lambda c: c["roofline"]["roofline_fraction"])
    coll = sorted((c for c in ok if c["mesh"] == "single_pod"),
                  key=lambda c: -c["roofline"]["collective_s"])
    return {"ok": len(ok), "skip": len(skip), "error": len(err),
            "worst_fraction": [(c["arch"], c["shape"],
                                c["roofline"]["roofline_fraction"])
                               for c in worst[:5]],
            "most_collective_bound": [(c["arch"], c["shape"],
                                       c["roofline"]["collective_s"])
                                      for c in coll[:5]]}


def main():
    out_dir = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun"
    cells = load_cells(out_dir)
    print("## Roofline (single-pod 16x16)\n")
    print(roofline_table(cells, "single_pod"))
    print("\n## Dry-run detail (single-pod)\n")
    print(dryrun_table(cells, "single_pod"))
    print("\n## Dry-run detail (multi-pod 2x16x16)\n")
    print(dryrun_table(cells, "multi_pod"))
    print("\n## Summary\n")
    print(json.dumps(summarize(out_dir), indent=2))


if __name__ == "__main__":
    main()

from repro.analysis import hw_specs  # noqa: F401

"""TPU v5e hardware constants used by the cost model and roofline analysis.

Single source of truth: the rank-selection cost model (repro.core.cost_model)
and the roofline report (repro.analysis.roofline) both read these, so the
paper's Algorithm-1 adaptation and the perf analysis agree on the hardware.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class HardwareSpec:
    name: str
    peak_flops_bf16: float      # FLOP/s per chip
    hbm_bandwidth: float        # B/s per chip
    hbm_bytes: float            # HBM capacity per chip
    ici_link_bandwidth: float   # B/s per ICI link
    mxu_dim: int                # systolic array tile (lanes)
    sublanes: int               # VREG sublane granularity
    vmem_bytes: float           # per-core VMEM
    int8_mxu_mult: float = 2.0  # int8 x int8 issue rate vs bf16/f32

    def peak_flops(self, operand_bytes: int = 2) -> float:
        """MXU FLOP/s at the *widest* operand width feeding the dot.

        int8 x int8 (both operands 1 byte) issues at ``int8_mxu_mult``
        times the bf16 rate; anything wider — including int8 weights
        dequantized in VMEM against full-width activations — runs at
        the base rate.
        """
        if operand_bytes <= 1:
            return self.peak_flops_bf16 * self.int8_mxu_mult
        return self.peak_flops_bf16


# Per the assignment prompt: 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link.
TPU_V5E = HardwareSpec(
    name="tpu_v5e",
    peak_flops_bf16=197e12,
    hbm_bandwidth=819e9,
    hbm_bytes=16 * 1024**3,
    ici_link_bandwidth=50e9,
    mxu_dim=128,
    sublanes=8,
    vmem_bytes=128 * 1024**2,
)

DEFAULT = TPU_V5E


def mxu_padded(dim: int, spec: HardwareSpec = DEFAULT) -> int:
    """Dim as the MXU sees it: zero-padded up to a multiple of 128 lanes."""
    t = spec.mxu_dim
    return ((dim + t - 1) // t) * t


def sublane_padded(dim: int, spec: HardwareSpec = DEFAULT) -> int:
    t = spec.sublanes
    return ((dim + t - 1) // t) * t

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST stay the first statements in this module — jax
locks the device count at first initialization, and the production meshes
need 512 placeholder host devices.  Do not import this module from tests.

Worker mode (one cell)::

    python -m repro.launch.dryrun --arch llama3.2-1b --shape train_4k \
        [--multi-pod] [--rank-mode ratio|aligned|none] [--branches N] \
        [--freeze] [--shard-rank] [--out cell.json] [--save-hlo cell.hlo.gz]

Sweep mode (all cells, subprocess per cell, resumable)::

    python -m repro.launch.dryrun --sweep --out-dir results/dryrun \
        [--multi-pod] [--jobs 4]

Each cell records: lower/compile wall time, ``memory_analysis()`` (bytes
per device — proves it fits), ``cost_analysis()``, and the parsed roofline
terms (compute / memory / collective seconds + bottleneck) from
:mod:`repro.analysis.roofline`.
"""
import argparse
import dataclasses
import gzip
import json
import subprocess
import sys
import time
import traceback

import jax

from repro.analysis import roofline as rl
from repro.analysis.hw_specs import DEFAULT as HW
from repro.configs import registry
from repro.configs.base import (LRDConfig, RunConfig, SHAPES, ShapeConfig,
                                applicable_shapes, skip_reason)
from repro.launch.mesh import make_production_mesh
from repro.models.api import get_model, input_specs
from repro.parallel import sharding as shd
from repro.train import steps as steps_mod
from repro.train.optim import OptimConfig


def build_lrd(args) -> LRDConfig:
    if args.rank_mode == "none":
        return LRDConfig(enabled=False)
    return LRDConfig(enabled=True, compression=args.compression,
                     rank_mode=args.rank_mode, branches=args.branches,
                     freeze=args.freeze, rank_align=args.rank_align)


def _shape_tree(model, init_fn):
    """eval_shape for params while capturing the (static) axes tree."""
    box = {}

    def only_params(key):
        p, a = init_fn(key)
        box["axes"] = a
        return p

    sds = jax.eval_shape(only_params, jax.random.PRNGKey(0))
    return sds, box["axes"]


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             lrd: LRDConfig, shard_rank: bool = False,
             seq_shard: bool | None = None,
             remat: str | None = None,
             moe_groups: int | None = None,
             fsdp: bool | None = None,
             grad_accum: int | None = None,
             save_hlo: str | None = None) -> dict:
    t_start = time.time()
    entry = registry.get(arch)
    cfg = entry.full
    if moe_groups is not None:
        cfg = dataclasses.replace(cfg, moe_dispatch_groups=moe_groups)
    shape = SHAPES[shape_name]
    reason = skip_reason(cfg, shape)
    if reason:
        return {"arch": arch, "shape": shape_name,
                "mesh": "multi_pod" if multi_pod else "single_pod",
                "status": "skip", "reason": reason}

    parallel = entry.parallel(shape.kind)
    parallel = dataclasses.replace(
        parallel, multi_pod=multi_pod, shard_rank=shard_rank,
        **({"seq_shard": seq_shard} if seq_shard is not None else {}),
        **({"fsdp": fsdp} if fsdp is not None else {}),
        **({"grad_accum": grad_accum} if grad_accum is not None else {}),
        **({"remat": remat} if remat is not None else {}))
    run = RunConfig(model=cfg, lrd=lrd, parallel=parallel)
    model = get_model(cfg)
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_devices = mesh.size
    notes: list[str] = []

    def init_fn(key):
        p, a = model.init(key)
        if lrd.enabled:
            from repro.core.surgery import decompose_model
            p, a, report = decompose_model(p, a, lrd,
                                           m_tokens=shape.seq_len)
            init_fn.report = report          # type: ignore[attr-defined]
        return p, a

    with mesh:
        shd.install_activation_rules(mesh, parallel)
        try:
            params_sds, axes = _shape_tree(model, init_fn)
            surgery = getattr(init_fn, "report", None)
            p_shardings = shd.make_param_shardings(mesh, params_sds, axes,
                                                   parallel, notes)
            specs = input_specs(cfg, shape)
            in_shd = shd.input_shardings(mesh, specs, parallel)

            if shape.kind == "train":
                opt_sds = jax.eval_shape(
                    lambda p: steps_mod.init_opt_state(
                        model, run, p, OptimConfig()), params_sds)
                o_shardings = _opt_shardings(mesh, opt_sds, p_shardings)
                step = steps_mod.make_train_step(model, run, OptimConfig(),
                                                 mesh)
                jit_step = jax.jit(
                    step,
                    in_shardings=(p_shardings, o_shardings,
                                  {k: in_shd[k] for k in specs}),
                    donate_argnums=(0, 1))
                args_sds = (params_sds, opt_sds, specs)
            elif shape.kind == "prefill":
                cache_sds = (model.cache_spec(shape.global_batch,
                                              shape.seq_len)
                             if cfg.has_decode else None)
                if cache_sds is None:   # encoder: forward pass, no cache
                    step = steps_mod.make_forward_step(model, run)
                    jit_step = jax.jit(step, in_shardings=(p_shardings,
                                                           in_shd))
                    args_sds = (params_sds, dict(specs))
                else:
                    c_shd = shd.cache_shardings(mesh, cache_sds, parallel,
                                                shape.global_batch,
                                                shape.seq_len)
                    step = steps_mod.make_prefill_step(model, run)
                    jit_step = jax.jit(
                        step, in_shardings=(p_shardings, in_shd, c_shd),
                        donate_argnums=(2,))
                    args_sds = (params_sds, specs, cache_sds)
            else:  # decode
                cache_sds = model.cache_spec(shape.global_batch,
                                             shape.seq_len)
                c_shd = shd.cache_shardings(mesh, cache_sds, parallel,
                                            shape.global_batch,
                                            shape.seq_len)
                step = steps_mod.make_decode_step(model, run)
                jit_step = jax.jit(
                    step,
                    in_shardings=(p_shardings, in_shd["tokens"],
                                  in_shd["positions"], c_shd),
                    donate_argnums=(3,))
                args_sds = (params_sds, specs["tokens"],
                            specs["positions"], cache_sds)

            t0 = time.time()
            lowered = jit_step.lower(*args_sds)
            t_lower = time.time() - t0
            t0 = time.time()
            compiled = lowered.compile()
            t_compile = time.time() - t0

            mem = compiled.memory_analysis()
            try:
                ca = compiled.cost_analysis() or {}
            except Exception:
                ca = {}
            hlo = compiled.as_text()
            costs = rl.analyze_hlo(hlo, n_devices)
            upcast = rl.cpu_bf16_upcast_bytes(hlo)
            if shape.kind == "train":
                model_flops = cfg.flops_per_token() * shape.global_batch \
                    * shape.seq_len
            elif shape.kind == "prefill":
                model_flops = cfg.flops_per_token() / 3.0 \
                    * shape.global_batch * shape.seq_len
            else:
                model_flops = cfg.flops_per_token() / 3.0 \
                    * shape.global_batch
            roof = rl.roofline(costs, n_devices=n_devices,
                               model_flops_global=model_flops, spec=HW)
            if save_hlo:
                with gzip.open(save_hlo, "wt") as f:
                    f.write(hlo)
            result = {
                "arch": arch, "shape": shape_name,
                "mesh": "multi_pod" if multi_pod else "single_pod",
                "status": "ok",
                "n_devices": n_devices,
                "lower_s": round(t_lower, 2),
                "compile_s": round(t_compile, 2),
                "memory": {
                    "argument_bytes": mem.argument_size_in_bytes,
                    "output_bytes": mem.output_size_in_bytes,
                    "temp_bytes": mem.temp_size_in_bytes,
                    "peak_bytes": mem.argument_size_in_bytes
                    + mem.temp_size_in_bytes,
                    # XLA *CPU* materializes f32 copies of bf16 weights /
                    # caches (no native bf16 dot); TPU doesn't. Corrected
                    # peak subtracts those buffers (see roofline.py).
                    "cpu_bf16_upcast_bytes": upcast,
                    "peak_bytes_tpu_corrected": max(
                        0, mem.argument_size_in_bytes
                        + mem.temp_size_in_bytes - upcast),
                    "fits_hbm": (mem.argument_size_in_bytes
                                 + mem.temp_size_in_bytes - upcast)
                    < HW.hbm_bytes,
                },
                "xla_cost_analysis": {k: ca.get(k) for k in
                                      ("flops", "bytes accessed")},
                "costs": {
                    "flops_per_device": costs.flops,
                    "hbm_bytes_per_device": costs.hbm_bytes,
                    "collective_bytes_per_device": costs.collective_bytes,
                    "collective_detail": costs.collective_detail,
                    "while_trips": costs.while_trips,
                },
                "roofline": {
                    "compute_s": roof.compute_s,
                    "memory_s": roof.memory_s,
                    "collective_s": roof.collective_s,
                    "step_s": roof.step_s,
                    "bottleneck": roof.bottleneck,
                    "model_flops_per_device": roof.model_flops,
                    "useful_flops_ratio": roof.useful_ratio,
                    "roofline_fraction": roof.roofline_fraction,
                },
                "surgery": surgery.summary() if surgery else None,
                "sharding_notes": notes[:20],
                "total_s": round(time.time() - t_start, 2),
            }
            return result
        finally:
            shd.clear_activation_rules()


def _opt_shardings(mesh, opt_sds, p_shardings):
    """Adam m/v follow the param shardings; scalars/zero-size replicate."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    rep = NamedSharding(mesh, P())
    out = {}
    for k, v in opt_sds.items():
        if k == "adam":
            out[k] = {
                "step": rep,
                "m": jax.tree.map(
                    lambda s, p: p if s.ndim and s.shape != (0,) else rep,
                    v["m"], p_shardings),
                "v": jax.tree.map(
                    lambda s, p: p if s.ndim and s.shape != (0,) else rep,
                    v["v"], p_shardings),
            }
        elif k == "ef":
            out[k] = jax.tree.map(lambda _: rep, v)
        else:
            out[k] = jax.tree.map(lambda _: rep, v)
    return out


# ---------------------------------------------------------------------------
# Sweep driver
# ---------------------------------------------------------------------------

def all_cells() -> list[tuple[str, str]]:
    cells = []
    for arch in registry.assigned_names():
        cfg = registry.get(arch).full
        for shape in applicable_shapes(cfg):
            cells.append((arch, shape.name))
        for shape in SHAPES.values():
            if skip_reason(cfg, shape):
                cells.append((arch, shape.name))   # recorded as skip
    return cells


def sweep(args) -> int:
    import os as _os
    _os.makedirs(args.out_dir, exist_ok=True)
    cells = all_cells()
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    jobs: list[tuple[str, str, bool, str]] = []
    for arch, shape in cells:
        for mp in meshes:
            tag = f"{arch}__{shape}__{'mp' if mp else 'sp'}"
            if args.tag_suffix:
                tag += f"__{args.tag_suffix}"
            out = _os.path.join(args.out_dir, tag + ".json")
            if _os.path.exists(out) and not args.force:
                continue
            jobs.append((arch, shape, mp, out))
    print(f"[sweep] {len(jobs)} cells to run "
          f"({len(cells) * len(meshes)} total)")
    running: list[tuple[subprocess.Popen, str]] = []
    failed = 0

    def drain(block: bool):
        nonlocal failed
        done = []
        for proc, out in running:
            if proc.poll() is None and not block:
                continue
            proc.wait()
            done.append((proc, out))
            ok = proc.returncode == 0 and _os.path.exists(out)
            status = "?"
            if ok:
                with open(out) as f:
                    status = json.load(f).get("status")
            else:
                failed += 1
            print(f"[sweep] {out}: rc={proc.returncode} status={status}",
                  flush=True)
        for d in done:
            running.remove(d)

    for arch, shape, mp, out in jobs:
        while len(running) >= args.jobs:
            drain(block=False)
            time.sleep(1)
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", arch, "--shape", shape, "--out", out,
               "--rank-mode", args.rank_mode,
               "--compression", str(args.compression),
               "--branches", str(args.branches)]
        if mp:
            cmd.append("--multi-pod")
        if args.freeze:
            cmd.append("--freeze")
        if args.shard_rank:
            cmd.append("--shard-rank")
        env = dict(_os.environ)
        env["PYTHONPATH"] = env.get("PYTHONPATH", "src")
        proc = subprocess.Popen(cmd, env=env,
                                stdout=subprocess.DEVNULL,
                                stderr=subprocess.PIPE)
        running.append((proc, out))
        print(f"[sweep] launched {out}", flush=True)
    while running:
        drain(block=True)
    return failed


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--rank-mode", default="ratio",
                    choices=["none", "ratio", "aligned", "search"])
    ap.add_argument("--compression", type=float, default=2.0)
    ap.add_argument("--rank-align", type=int, default=128)
    ap.add_argument("--branches", type=int, default=1)
    ap.add_argument("--freeze", action="store_true")
    ap.add_argument("--shard-rank", action="store_true")
    ap.add_argument("--seq-shard", type=int, default=-1,
                    help="-1 keep arch default; 0/1 override")
    ap.add_argument("--moe-groups", type=int, default=-1,
                    help="-1 keep config; N = hierarchical dispatch groups")
    ap.add_argument("--fsdp", type=int, default=-1,
                    help="-1 keep config; 0/1 override")
    ap.add_argument("--grad-accum", type=int, default=-1)
    ap.add_argument("--remat", default=None)
    ap.add_argument("--out", default=None)
    ap.add_argument("--save-hlo", default=None)
    ap.add_argument("--sweep", action="store_true")
    ap.add_argument("--out-dir", default="results/dryrun")
    ap.add_argument("--tag-suffix", default="")
    ap.add_argument("--jobs", type=int, default=4)
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    if args.sweep:
        sys.exit(1 if sweep(args) else 0)

    try:
        result = run_cell(
            args.arch, args.shape, multi_pod=args.multi_pod,
            lrd=build_lrd(args), shard_rank=args.shard_rank,
            seq_shard=None if args.seq_shard < 0 else bool(args.seq_shard),
            remat=args.remat,
            moe_groups=None if args.moe_groups < 0 else args.moe_groups,
            fsdp=None if args.fsdp < 0 else bool(args.fsdp),
            grad_accum=None if args.grad_accum < 0 else args.grad_accum,
            save_hlo=args.save_hlo)
    except Exception:
        result = {"arch": args.arch, "shape": args.shape,
                  "mesh": "multi_pod" if args.multi_pod else "single_pod",
                  "status": "error", "error": traceback.format_exc()}
    out = json.dumps(result, indent=2, default=float)
    if args.out:
        with open(args.out, "w") as f:
            f.write(out)
    print(out if len(out) < 8000 else out[:8000])
    if result["status"] == "error":
        sys.exit(1)


if __name__ == "__main__":
    main()

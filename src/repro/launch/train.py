"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b \
        [--smoke] [--steps N] [--lrd ratio|aligned|search|none] \
        [--compression 2.0] [--freeze] [--branches N] \
        [--ckpt-dir DIR] [--batch B] [--seq S]

On this CPU container only ``--smoke`` configs are trainable; on a real
slice the same entry launches the full config onto the production mesh
(the mesh is chosen by device count at startup).
"""
from __future__ import annotations

import argparse
import dataclasses

import jax

from repro.configs import registry
from repro.configs.base import LRDConfig, RunConfig, ShapeConfig
from repro.train.data import ByteTextLM, SyntheticImages, SyntheticLM
from repro.train.fault_tolerance import PreemptionHandler, run_with_restart
from repro.train.loop import train
from repro.train.optim import OptimConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=registry.names())
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config (CPU-trainable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lrd", default="aligned",
                    choices=["none", "ratio", "aligned", "search"])
    ap.add_argument("--compression", type=float, default=2.0)
    ap.add_argument("--freeze", action="store_true")
    ap.add_argument("--branches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--corpus", default=None)
    ap.add_argument("--max-restarts", type=int, default=2)
    args = ap.parse_args()

    entry = registry.get(args.arch)
    cfg = entry.smoke if args.smoke else entry.full
    lrd = (LRDConfig() if args.lrd == "none" else
           LRDConfig(enabled=True, rank_mode=args.lrd,
                     compression=args.compression, freeze=args.freeze,
                     branches=args.branches,
                     min_dim=32 if args.smoke else 256))
    parallel = entry.parallel("train")
    if args.smoke:
        parallel = dataclasses.replace(parallel, fsdp=False,
                                       seq_shard=False, remat="none")
    run = RunConfig(model=cfg, lrd=lrd, parallel=parallel)

    if cfg.family == "resnet":
        data = SyntheticImages(cfg, batch=args.batch)
    elif cfg.family == "encoder":
        data = SyntheticLM(cfg, ShapeConfig("t", args.seq, args.batch,
                                            "train"))
    else:
        data = ByteTextLM(cfg, batch=args.batch, seq_len=args.seq,
                          path=args.corpus)
    ocfg = OptimConfig(peak_lr=args.lr, warmup_steps=max(1, args.steps // 10),
                       total_steps=args.steps)

    def attempt(i: int):
        with PreemptionHandler() as p:
            r = train(run, data, num_steps=args.steps, optim_cfg=ocfg,
                      ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                      preemption=p, log_every=10)
        return {"result": r}

    out = run_with_restart(attempt, max_restarts=args.max_restarts)
    r = out["result"]
    print(f"[done] step={r.step} loss={r.losses[-1]:.4f} "
          f"restarts={out['restarts']} "
          f"stragglers={r.straggler_report['stragglers']}")


if __name__ == "__main__":
    main()

"""Production mesh construction.

A *function*, not a module-level constant: importing this module never
touches jax device state (the dry-run must set XLA_FLAGS before the first
jax call; tests must keep seeing 1 CPU device).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """(16, 16) = 256-chip pod; (2, 16, 16) = 2 pods = 512 chips.

    ``pod`` is pure data-parallel (the slow inter-pod link is crossed once
    per step by the gradient all-reduce); ``data`` carries DP + FSDP;
    ``model`` carries TP / EP / SP.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh() -> jax.sharding.Mesh:
    """Whatever devices exist right now (tests / examples on 1 CPU)."""
    n = len(jax.devices())
    return jax.make_mesh((1, n), ("data", "model"))

"""Serving launcher: load (or init+decompose) a model and serve a batch of
synthetic requests through the continuous-batching engine.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --smoke \
        [--ckpt-dir DIR] [--requests 8] [--max-new 32]
"""
from __future__ import annotations

import argparse
import dataclasses

import jax

from repro.configs import registry
from repro.configs.base import LRDConfig, RunConfig
from repro.core.surgery import decompose_model
from repro.models.api import get_model
from repro.serve.engine import Request, ServeEngine
from repro.train import checkpoint as ckpt


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=registry.names())
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--lrd", default="aligned",
                    choices=["none", "ratio", "aligned", "search"])
    ap.add_argument("--ckpt-dir", default=None,
                    help="restore params from a training checkpoint")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=256)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    entry = registry.get(args.arch)
    cfg = entry.smoke if args.smoke else entry.full
    if not cfg.has_decode:
        raise SystemExit(f"{args.arch} is encoder-only: nothing to serve")
    model = get_model(cfg)
    params, axes = model.init(jax.random.PRNGKey(0))
    lrd = LRDConfig()
    if args.lrd != "none":
        lrd = LRDConfig(enabled=True, rank_mode=args.lrd,
                        min_dim=32 if args.smoke else 256)
        params, _, rep = decompose_model(params, axes, lrd)
        print(f"[lrd] {rep.summary()}")
    if args.ckpt_dir:
        got = ckpt.restore_latest(args.ckpt_dir, {"params": params})
        if got:
            params = got[0]["params"]
            print(f"[restore] step {got[1]['step']}")

    run = RunConfig(model=cfg, lrd=lrd, parallel=entry.parallel("decode"))
    eng = ServeEngine(run, params, slots=args.slots, max_seq=args.max_seq)
    key = jax.random.PRNGKey(7)
    for i in range(args.requests):
        key, sub = jax.random.split(key)
        n = 3 + int(jax.random.randint(sub, (), 0, 6))
        prompt = jax.random.randint(sub, (n,), 0, cfg.vocab_size).tolist()
        eng.add_request(Request(uid=i, prompt=prompt,
                                max_new_tokens=args.max_new,
                                temperature=args.temperature))
    eng.run_until_done()
    print(f"[throughput] {eng.throughput()}")


if __name__ == "__main__":
    main()

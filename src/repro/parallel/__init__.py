from repro.parallel.sharding import (  # noqa: F401
    make_param_shardings, activation_resolver, install_activation_rules,
    batch_sharding, input_shardings,
)

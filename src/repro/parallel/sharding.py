"""Logical-axis -> NamedSharding resolution (DP / FSDP / TP / EP / SP).

Every parameter carries a tuple of *logical* axis names (built by
``ParamBuilder``); this module resolves them onto the production mesh
``(pod, data, model)`` per a rule table derived from ``ParallelConfig``:

    vocab    -> model         (TP on embed/unembed; replicate if indivisible)
    ffn/qkv  -> model         (megatron col/row pattern falls out of the
                               in/out logical names on each weight)
    inner    -> model         (SSM d_inner TP)
    experts  -> model         (EP: expert banks shard over chips)
    embed    -> data if FSDP  (param + optimizer-state sharding)
    rank     -> None          (default: factors inherit the dense layer's
                               sharding; the partial-sum all-reduce then
                               moves M x R bytes instead of M x d — the
                               low-rank collective win, see EXPERIMENTS.md)
             -> model if ``shard_rank`` (the hillclimb variant: W0
                               col-sharded, GSPMD inserts an M x R
                               all-gather before W1)
    batch    -> (pod, data)   pure DP across pods, DP+FSDP within
    seq      -> model if SP   (activation sequence sharding)

A mesh axis is used at most once per tensor; conflicts resolve by a fixed
priority (EP > vocab > ffn/qkv/inner > rank).  Dims not divisible by their
mesh-axis size fall back to replication (recorded, surfaced by the
dry-run).
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ParallelConfig
from repro.layers import param as lp

PyTree = Any

# Priority for claiming the `model` axis when several logical axes on one
# tensor map to it (a mesh axis may appear only once per PartitionSpec).
_MODEL_PRIORITY = {
    lp.EXPERTS: 0, lp.VOCAB: 1, lp.FFN: 2, lp.QKV: 2, lp.INNER: 2,
    lp.HEADS: 3, lp.KV_HEADS: 3, lp.RANK: 4,
}


def _rules(parallel: ParallelConfig) -> dict[str, Any]:
    """logical axis -> mesh axis (or None) for *parameters*."""
    rules: dict[str, Any] = {
        lp.VOCAB: "model",
        lp.FFN: "model",
        lp.QKV: "model",
        lp.INNER: "model",
        lp.EXPERTS: "model",
        lp.EMBED: "data" if parallel.fsdp else None,
        # RANK: the factor that lost its EMBED/FFN dim must still FSDP-shard
        # (else its f32 optimizer moments replicate over `data` — observed
        # +125 GB/device on deepseek-v2).  Priority rules keep one axis use.
        lp.RANK: "model" if parallel.shard_rank
        else ("data" if parallel.fsdp else None),
        lp.HEADS: "model",
        lp.KV_HEADS: "model",
        lp.LAYERS: None,
        lp.BRANCH: None,
        lp.CONV: None,
        lp.STATE: None,
        lp.HEAD_DIM: None,
        lp.BATCH: None,
        lp.SEQ: None,
        None: None,
    }
    if not parallel.shard_vocab:
        rules[lp.VOCAB] = None
    return rules


def _spec_for(axes: tuple, shape: tuple[int, ...], rules: dict,
              mesh: Mesh, notes: list[str] | None = None,
              path: str = "") -> P:
    assert len(axes) == len(shape), (axes, shape)
    used: set[str] = set()
    entries = []
    # resolve high-priority dims first, then fill in order
    order = sorted(range(len(axes)),
                   key=lambda i: _MODEL_PRIORITY.get(axes[i], 9))
    resolved: dict[int, Any] = {}
    for i in order:
        ax = rules.get(axes[i], None)
        if ax is None:
            resolved[i] = None
            continue
        ax_names = ax if isinstance(ax, tuple) else (ax,)
        if any(a in used for a in ax_names):
            resolved[i] = None
            continue
        size = int(np.prod([mesh.shape[a] for a in ax_names]))
        if shape[i] % size != 0:
            if notes is not None:
                notes.append(f"{path}: dim {i} ({axes[i]}={shape[i]}) "
                             f"not divisible by {ax}={size}; replicated")
            resolved[i] = None
            continue
        used.update(ax_names)
        resolved[i] = ax
    for i in range(len(axes)):
        entries.append(resolved[i])
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


def make_param_shardings(mesh: Mesh, params: PyTree, axes: PyTree,
                         parallel: ParallelConfig,
                         notes: list[str] | None = None) -> PyTree:
    """NamedSharding tree matching ``params`` (leaves may be arrays or
    ShapeDtypeStructs).

    Quant- and sparse-aware: a tree rewritten by
    ``repro.quant.quantize_tree`` or ``repro.quant.sparsify_tree`` after
    the axes were built still resolves — ``k_q`` leaves inherit ``k``'s
    logical axes, ``k_scale`` leaves shard on the out-dim axis (or
    replicate), and 2:4-packed ``k_sp`` / ``k_idx`` leaves keep ``k``'s
    out-dim sharding with the packed slot/group axes replicated — all
    via ``repro.quant.align_quantized_axes`` per dict node.
    """
    from repro.quant.quantize import align_quantized_axes
    rules = _rules(parallel)

    def walk(p: Any, a: Any, path: tuple[str, ...]) -> Any:
        if isinstance(p, dict):
            a2 = align_quantized_axes(p, a) if isinstance(a, dict) else a
            return {k: walk(p[k], a2[k], (*path, k)) for k in p}
        spec = _spec_for(tuple(a), tuple(p.shape), rules, mesh,
                         notes, "/".join(path))
        return NamedSharding(mesh, spec)

    return walk(params, axes, ())


# ---------------------------------------------------------------------------
# Activations
# ---------------------------------------------------------------------------

def _data_axes(mesh: Mesh) -> Any:
    return ("pod", "data") if "pod" in mesh.axis_names else "data"


def activation_resolver(mesh: Mesh, parallel: ParallelConfig
                        ) -> Callable:
    """Returns fn(logical_axes, shape) -> NamedSharding|None for shard_act."""
    data = _data_axes(mesh)
    data_size = int(np.prod([mesh.shape[a] for a in
                             (data if isinstance(data, tuple) else (data,))]))
    model_size = mesh.shape["model"]

    def rule(axes: tuple, shape: tuple[int, ...]):
        entries = []
        used = set()
        for ax, dim in zip(axes, shape):
            tgt = None
            if ax == lp.BATCH and "d" not in used and dim % data_size == 0:
                tgt = data
                used.add("d")
            elif ax == lp.SEQ and parallel.seq_shard and "m" not in used \
                    and dim % model_size == 0:
                tgt = "model"
                used.add("m")
            elif ax in (lp.FFN, lp.QKV, lp.HEADS, lp.KV_HEADS, lp.EXPERTS,
                        lp.VOCAB, lp.INNER) \
                    and "m" not in used and dim % model_size == 0:
                tgt = "model"
                used.add("m")
            entries.append(tgt)
        while entries and entries[-1] is None:
            entries.pop()
        if not entries:
            return None
        return NamedSharding(mesh, P(*entries))

    return rule


def install_activation_rules(mesh: Mesh, parallel: ParallelConfig) -> None:
    lp.set_activation_resolver(activation_resolver(mesh, parallel))


def clear_activation_rules() -> None:
    lp.set_activation_resolver(None)


# ---------------------------------------------------------------------------
# Inputs / caches
# ---------------------------------------------------------------------------

def batch_sharding(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P(_data_axes(mesh)))


def input_shardings(mesh: Mesh, specs: dict,
                    parallel: ParallelConfig) -> dict:
    """Shard every step input along its leading (batch) dim where divisible."""
    data = _data_axes(mesh)
    data_size = int(np.prod([mesh.shape[a] for a in
                             (data if isinstance(data, tuple) else (data,))]))
    out = {}
    for name, spec in specs.items():
        if spec.shape and spec.shape[0] % data_size == 0:
            out[name] = NamedSharding(mesh, P(data))
        else:
            out[name] = NamedSharding(mesh, P())
    return out


def cache_shardings(mesh: Mesh, cache_spec: PyTree,
                    parallel: ParallelConfig, batch: int,
                    seq_len: int | None = None) -> PyTree:
    """KV caches / SSM states.

    Attention caches shard **batch over data, cache-seq over model**
    (sequence-sharded KV: each chip holds S/model slots; the softmax
    reduction over the sharded axis is a cheap scalar-sized all-reduce).
    Sharding kv-heads or head_dim instead forces GSPMD into full cache
    rematerialization per step (observed: involuntary-remat warnings +
    8.5 GB/step all-gathers on the decode cells).

    When batch is unshardable (B=1 long-context decode) and
    ``decode_seq_shard`` is set, the seq dim takes BOTH axes.

    SSM states have no seq dim: heads shard over model (matching the
    d_inner TP of the SSD einsums), batch over data.
    """
    data = _data_axes(mesh)
    data_size = int(np.prod([mesh.shape[a] for a in
                             (data if isinstance(data, tuple) else (data,))]))
    model_size = mesh.shape["model"]

    def leaf(spec):
        # Layouts (leading stack dims first):
        #   KV cache      (L.., B, S, KH, hd)
        #   MLA cache     (L.., B, S, lora)
        #   SSM state     (L,  B, nh, hd, N)
        #   conv tail     (L,  B, w-1, d_inner or 2N)
        shape = spec.shape
        entries: list[Any] = [None] * len(shape)
        try:
            bi = next(i for i, d in enumerate(shape) if d == batch)
        except StopIteration:
            return NamedSharding(mesh, P())
        has_seq = (seq_len is not None and len(shape) > bi + 1
                   and shape[bi + 1] == seq_len)
        batch_shardable = batch % data_size == 0
        if batch_shardable:
            entries[bi] = data
        if has_seq:
            si = bi + 1
            if not batch_shardable and parallel.decode_seq_shard:
                both = ((*data, "model") if isinstance(data, tuple)
                        else (data, "model"))
                if shape[si] % (data_size * model_size) == 0:
                    entries[si] = both
                elif shape[si] % model_size == 0:
                    entries[si] = "model"
            elif shape[si] % model_size == 0:
                entries[si] = "model"
        else:
            # SSM state: shard the heads dim (first dim after batch
            # divisible by model) to match d_inner TP
            for i in range(bi + 1, len(shape)):
                if shape[i] % model_size == 0 and shape[i] >= model_size:
                    entries[i] = "model"
                    break
        while entries and entries[-1] is None:
            entries.pop()
        return NamedSharding(mesh, P(*entries))

    return jax.tree.map(leaf, cache_spec)

"""Checkpointing: atomic commit, async writer, auto-resume, elastic restore.

Layout::

    <dir>/step_00001234/arrays.npz      flattened path->array archive
    <dir>/step_00001234/MANIFEST.json   step, checksum, tree paths, meta
    <dir>/LATEST                        name of the newest *committed* step

Writes go to ``<dir>/.tmp-<step>`` first and are ``os.rename``d into place
(rename is atomic on POSIX), the manifest is written last, and LATEST is
swapped by tmp-file rename — a crash at any point leaves either the old or
the new checkpoint fully intact, never a torn one.  ``restore_latest``
validates the checksum and walks backwards past corrupt/partial steps
(fault-injection tested).

Checkpoints are *gathered* (host arrays), so a restore can re-shard onto
any topology — the elastic-restore path: ``restore(..., shardings=...)``
``device_put``s each leaf with its target ``NamedSharding``.  An async
mode hands the (already host-copied) tree to a writer thread so the train
loop never blocks on disk.
"""
from __future__ import annotations

import hashlib
import io
import json
import os
import queue
import shutil
import threading
from typing import Any

import jax
import numpy as np

PyTree = Any
_SEP = "\x1d"          # path separator inside npz keys


def _flatten(tree: PyTree) -> dict[str, np.ndarray]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = _SEP.join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        arr = np.asarray(jax.device_get(leaf))
        if arr.dtype.name == "bfloat16":     # npz-portable, lossless
            arr = arr.astype(np.float32)
        out[key] = arr
    return out


def _tree_like(template: PyTree, arrays: dict[str, np.ndarray]) -> PyTree:
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, tmpl in flat:
        key = _SEP.join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        if key not in arrays:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = arrays[key]
        leaves.append(arr)
    return jax.tree.unflatten(treedef, leaves)


def _checksum(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


def save(ckpt_dir: str, step: int, tree: PyTree,
         meta: dict | None = None) -> str:
    """Synchronous atomic save. Returns the committed directory."""
    os.makedirs(ckpt_dir, exist_ok=True)
    name = f"step_{step:08d}"
    tmp = os.path.join(ckpt_dir, f".tmp-{name}")
    final = os.path.join(ckpt_dir, name)
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    arrays = _flatten(tree)
    npz_path = os.path.join(tmp, "arrays.npz")
    with open(npz_path, "wb") as f:
        np.savez(f, **arrays)
        f.flush()
        os.fsync(f.fileno())
    manifest = {
        "step": step,
        "checksum": _checksum(npz_path),
        "n_leaves": len(arrays),
        "meta": meta or {},
    }
    mpath = os.path.join(tmp, "MANIFEST.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    # swap LATEST atomically
    latest_tmp = os.path.join(ckpt_dir, ".LATEST.tmp")
    with open(latest_tmp, "w") as f:
        f.write(name)
        f.flush()
        os.fsync(f.fileno())
    os.rename(latest_tmp, os.path.join(ckpt_dir, "LATEST"))
    return final


def _valid(ckpt_dir: str, name: str) -> bool:
    d = os.path.join(ckpt_dir, name)
    mpath = os.path.join(d, "MANIFEST.json")
    npz = os.path.join(d, "arrays.npz")
    if not (os.path.isfile(mpath) and os.path.isfile(npz)):
        return False
    try:
        with open(mpath) as f:
            manifest = json.load(f)
        return manifest["checksum"] == _checksum(npz)
    except Exception:
        return False


def list_steps(ckpt_dir: str) -> list[str]:
    if not os.path.isdir(ckpt_dir):
        return []
    return sorted(n for n in os.listdir(ckpt_dir) if n.startswith("step_"))


def latest_valid(ckpt_dir: str) -> str | None:
    """Newest committed+checksummed step (walks past corrupt ones)."""
    names = list_steps(ckpt_dir)
    for name in reversed(names):
        if _valid(ckpt_dir, name):
            return name
    return None


def restore(ckpt_dir: str, name: str, template: PyTree,
            shardings: PyTree | None = None) -> tuple[PyTree, dict]:
    """Load a step into ``template``'s structure.  With ``shardings``
    (a matching NamedSharding tree) each leaf is device_put onto the
    *current* mesh — the elastic-restore path (the gathered arrays are
    topology-independent)."""
    d = os.path.join(ckpt_dir, name)
    with np.load(os.path.join(d, "arrays.npz")) as z:
        arrays = {k: z[k] for k in z.files}
    with open(os.path.join(d, "MANIFEST.json")) as f:
        manifest = json.load(f)
    tree = _tree_like(template, arrays)
    # cast via jnp (numpy has no bf16 cast path)
    if shardings is not None:
        tree = jax.tree.map(
            lambda a, s, t: jax.device_put(
                jax.numpy.asarray(a, dtype=t.dtype), s),
            tree, shardings, template)
    else:
        tree = jax.tree.map(
            lambda a, t: jax.numpy.asarray(a, dtype=t.dtype),
            tree, template)
    return tree, manifest


def restore_latest(ckpt_dir: str, template: PyTree,
                   shardings: PyTree | None = None
                   ) -> tuple[PyTree, dict] | None:
    name = latest_valid(ckpt_dir)
    if name is None:
        return None
    return restore(ckpt_dir, name, template, shardings)


class AsyncCheckpointer:
    """Background writer thread: ``save`` returns immediately after the
    host copy; ``wait`` drains the queue (call before exit)."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._q: queue.Queue = queue.Queue()
        self._err: Exception | None = None
        self._t = threading.Thread(target=self._worker, daemon=True)
        self._t.start()

    def _worker(self):
        while True:
            item = self._q.get()
            if item is None:
                return
            step, host_tree, meta = item
            try:
                save(self.ckpt_dir, step, host_tree, meta)
                self._gc()
            except Exception as e:      # surfaced on next save()/wait()
                self._err = e
            finally:
                self._q.task_done()

    def _gc(self):
        names = [n for n in list_steps(self.ckpt_dir)
                 if _valid(self.ckpt_dir, n)]
        for n in names[:-self.keep]:
            shutil.rmtree(os.path.join(self.ckpt_dir, n), ignore_errors=True)

    def save(self, step: int, tree: PyTree, meta: dict | None = None):
        if self._err:
            raise self._err
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                 tree)
        self._q.put((step, host_tree, meta))

    def wait(self):
        self._q.join()
        if self._err:
            raise self._err

    def close(self):
        self.wait()
        self._q.put(None)
        self._t.join()

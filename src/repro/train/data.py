"""Data pipeline: synthetic + byte-level text, deterministic resume.

Both sources are *stateless functions of (seed, step)* or carry an explicit
cursor state that is saved in every checkpoint — restoring a checkpoint
replays the exact stream (no data repeated or skipped), which the fault-
tolerance tests assert.
"""
from __future__ import annotations

import dataclasses
import hashlib
import os
from typing import Iterator

import jax
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig

_DEFAULT_CORPUS = (
    "low rank decomposition replaces a weight matrix with two smaller "
    "factors computed from its singular value decomposition. the ranks "
    "are chosen for a target compression ratio, then aligned to hardware "
    "tiles so the matrix units stay full. freezing the teacher derived "
    "factors accelerates fine tuning, merging factors into neighbouring "
    "layers restores the original depth, and branching splits the core "
    "into parallel groups that run as one grouped matmul. " * 50
)


@dataclasses.dataclass
class DataState:
    step: int = 0

    def to_dict(self) -> dict:
        return {"step": np.asarray(self.step)}

    @staticmethod
    def from_dict(d: dict) -> "DataState":
        return DataState(step=int(np.asarray(d["step"])))


class SyntheticLM:
    """Counter-based PRNG batches: batch(i) is a pure function of (seed, i)."""

    def __init__(self, cfg: ModelConfig, shape: ShapeConfig, seed: int = 0):
        self.cfg = cfg
        self.shape = shape
        self.seed = seed

    def batch(self, step: int) -> dict:
        from repro.models.api import synth_inputs
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        return synth_inputs(self.cfg, self.shape, key)

    def stream(self, state: DataState) -> Iterator[tuple[dict, DataState]]:
        step = state.step
        while True:
            yield self.batch(step), DataState(step + 1)
            step += 1


class ByteTextLM:
    """Byte-level LM batches from a text file (or a built-in corpus).

    Deterministic shuffle per epoch via a seed-derived permutation; the
    (step) cursor alone reconstructs the position, so resume is exact.
    """

    def __init__(self, cfg: ModelConfig, batch: int, seq_len: int,
                 path: str | None = None, seed: int = 0):
        if path and os.path.isfile(path):
            with open(path, "rb") as f:
                raw = f.read()
        else:
            raw = _DEFAULT_CORPUS.encode()
        data = np.frombuffer(raw, dtype=np.uint8).astype(np.int32)
        data = data % cfg.vocab_size
        self.tokens = data
        self.batch_size = batch
        self.seq_len = seq_len
        self.seed = seed
        n = (len(data) - 1) // seq_len
        assert n >= 1, "corpus shorter than one sequence"
        self.n_seqs = n

    def _perm(self, epoch: int) -> np.ndarray:
        rng = np.random.default_rng(
            int.from_bytes(hashlib.sha256(
                f"{self.seed}:{epoch}".encode()).digest()[:8], "little"))
        return rng.permutation(self.n_seqs)

    def batch(self, step: int) -> dict:
        per_epoch = max(1, self.n_seqs // self.batch_size)
        epoch, idx = divmod(step, per_epoch)
        perm = self._perm(epoch)
        rows = []
        for b in range(self.batch_size):
            sid = perm[(idx * self.batch_size + b) % self.n_seqs]
            lo = sid * self.seq_len
            rows.append(self.tokens[lo:lo + self.seq_len])
        return {"tokens": jax.numpy.asarray(np.stack(rows))}

    def stream(self, state: DataState) -> Iterator[tuple[dict, DataState]]:
        step = state.step
        while True:
            yield self.batch(step), DataState(step + 1)
            step += 1


class SyntheticImages:
    def __init__(self, cfg: ModelConfig, batch: int, seed: int = 0):
        self.cfg = cfg
        self.batch_size = batch
        self.seed = seed

    def batch(self, step: int) -> dict:
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        k1, k2 = jax.random.split(key)
        cfg = self.cfg
        return {
            "images": jax.random.normal(
                k1, (self.batch_size, cfg.img_size, cfg.img_size, 3),
                jax.numpy.float32) * 0.3,
            "labels": jax.random.randint(
                k2, (self.batch_size,), 0, cfg.num_classes),
        }

    def stream(self, state: DataState) -> Iterator[tuple[dict, DataState]]:
        step = state.step
        while True:
            yield self.batch(step), DataState(step + 1)
            step += 1

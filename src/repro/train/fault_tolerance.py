"""Fault tolerance: straggler detection, preemption, supervised restarts.

* :class:`StragglerDetector` — per-step EWMA wall-time; steps slower than
  ``threshold x`` the EWMA are flagged (on a real fleet this feeds the
  scheduler's replace-node decision; here it feeds logs + tests).
* :class:`PreemptionHandler` — converts SIGTERM/SIGINT into a polite
  "checkpoint now and exit" flag the train loop checks every step.
* :func:`run_with_restart` — a supervisor that restarts a crashing train
  function from the latest valid checkpoint, up to ``max_restarts``; this
  is the single-process stand-in for a cluster controller rescheduling a
  failed worker, and the fault-injection tests drive it with deliberately
  crashing steps.
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import Callable


@dataclasses.dataclass
class StragglerEvent:
    step: int
    seconds: float
    ewma: float


class StragglerDetector:
    def __init__(self, threshold: float = 3.0, alpha: float = 0.2,
                 warmup: int = 3):
        self.threshold = threshold
        self.alpha = alpha
        self.warmup = warmup
        self.ewma: float | None = None
        self._n = 0
        self.events: list[StragglerEvent] = []
        self._t0: float | None = None

    def start(self) -> None:
        self._t0 = time.perf_counter()

    def stop(self, step: int) -> StragglerEvent | None:
        assert self._t0 is not None, "stop() without start()"
        dt = time.perf_counter() - self._t0
        self._t0 = None
        self._n += 1
        if self.ewma is None:
            self.ewma = dt
            return None
        flagged = None
        if self._n > self.warmup and dt > self.threshold * self.ewma:
            flagged = StragglerEvent(step, dt, self.ewma)
            self.events.append(flagged)
            # don't poison the EWMA with the straggler sample
        else:
            self.ewma = (1 - self.alpha) * self.ewma + self.alpha * dt
        return flagged

    def report(self) -> dict:
        return {"steps": self._n, "ewma_s": self.ewma,
                "stragglers": [(e.step, round(e.seconds, 4))
                               for e in self.events]}


class PreemptionHandler:
    """SIGTERM -> checkpoint-and-exit flag (cloud TPU preemption pattern)."""

    def __init__(self, signals=(signal.SIGTERM,)):
        self._requested = False
        self._signals = signals
        self._old = {}

    def __enter__(self):
        for s in self._signals:
            self._old[s] = signal.signal(s, self._handle)
        return self

    def __exit__(self, *exc):
        for s, h in self._old.items():
            signal.signal(s, h)
        return False

    def _handle(self, signum, frame):
        self._requested = True

    @property
    def preempted(self) -> bool:
        return self._requested

    def request(self) -> None:        # test hook
        self._requested = True


def run_with_restart(train_fn: Callable[[int], dict], *,
                     max_restarts: int = 3,
                     on_restart: Callable[[int, Exception], None]
                     | None = None) -> dict:
    """Supervise ``train_fn(attempt)``; restart on exceptions.

    ``train_fn`` must itself resume from the latest checkpoint (the loop
    does).  Returns the final result dict with a ``restarts`` count.
    """
    attempt = 0
    while True:
        try:
            result = train_fn(attempt)
            result["restarts"] = attempt
            return result
        except Exception as e:          # noqa: BLE001 — supervisor boundary
            attempt += 1
            if attempt > max_restarts:
                raise
            if on_restart is not None:
                on_restart(attempt, e)

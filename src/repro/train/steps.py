"""Step builders: the jit-able train / prefill / decode step functions.

These are the functions the dry-run lowers and the training loop runs:

* ``make_train_step`` — loss -> grad (with grad-accumulation scan and
  remat policy) -> masked AdamW update.  With ``grad_compression_rank``
  and a multi-pod mesh, the pod-axis gradient sync goes through
  EF-PowerSGD inside a partially-manual ``shard_map`` (manual over
  ``pod``, GSPMD auto over ``data``/``model``) — the all-reduce then
  moves ``r*(C+S)`` instead of ``C*S`` bytes per tensor across the slow
  inter-pod link.
* ``make_prefill_step`` / ``make_decode_step`` — the serving pair.
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import RunConfig
from repro.core.freezing import trainable_mask
from repro.models.blocks import BlockOpts
from repro.train import compression as comp
from repro.train import optim

PyTree = Any


def block_opts(run: RunConfig) -> BlockOpts:
    return BlockOpts(freeze_factors=run.lrd.freeze and run.lrd.enabled,
                     use_pallas=run.lrd.use_pallas)


def make_loss_fn(model, run: RunConfig) -> Callable:
    opts = block_opts(run)
    remat = run.parallel.remat

    def loss_fn(params, batch):
        return model.loss(params, batch, opts=opts, remat=remat)
    return loss_fn


def _microbatch(batch: PyTree, n: int) -> PyTree:
    return jax.tree.map(
        lambda x: x.reshape(n, x.shape[0] // n, *x.shape[1:]), batch)


def make_train_step(model, run: RunConfig, opt_cfg: optim.OptimConfig,
                    mesh=None) -> Callable:
    """(params, opt_state, batch) -> (params, opt_state, metrics).

    ``opt_state`` carries {"adam": ..., "ef": ...} when compression is on.
    """
    loss_fn = make_loss_fn(model, run)
    accum = max(1, run.parallel.grad_accum)
    use_comp = (run.parallel.grad_compression_rank > 0)
    comp_cfg = comp.CompressionConfig(rank=run.parallel.grad_compression_rank)
    multi_pod = mesh is not None and "pod" in getattr(mesh, "axis_names", ())

    def grads_of(params, batch):
        if accum == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
            return loss, metrics, grads
        micro = _microbatch(batch, accum)

        def body(carry, mb):
            gsum, lsum = carry
            (loss, _), g = jax.value_and_grad(loss_fn, has_aux=True)(
                params, mb)
            gsum = jax.tree.map(
                lambda a, b: a + b.astype(jnp.float32), gsum, g)
            return (gsum, lsum + loss), None

        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (gsum, lsum), _ = jax.lax.scan(body, (zeros, jnp.zeros(())), micro)
        grads = jax.tree.map(lambda g: (g / accum), gsum)
        return lsum / accum, {}, grads

    def apply_update(params, opt_state, grads, loss, metrics):
        mask = trainable_mask(params, enabled=run.lrd.freeze
                              and run.lrd.enabled)
        new_params, new_adam, om = optim.adamw_update(
            grads, opt_state["adam"], params, opt_cfg, mask)
        metrics = dict(metrics, loss=loss, **om)
        return new_params, dict(opt_state, adam=new_adam), metrics

    if not use_comp:
        def train_step(params, opt_state, batch):
            loss, metrics, grads = grads_of(params, batch)
            return apply_update(params, opt_state, grads, loss, metrics)
        return train_step

    # --- EF-PowerSGD gradient sync -------------------------------------
    if multi_pod:
        npods = mesh.shape["pod"]

        def synced_grads(params, opt_state, batch):
            def local(params, ef, batch):
                loss, metrics, grads = grads_of(params, batch)
                reduce_fn = lambda t: jax.lax.pmean(t, "pod")
                g2, ef2, _ = comp.compress_decompress(
                    grads, ef, comp_cfg, reduce_fn)
                loss = jax.lax.pmean(loss, "pod")
                return loss, metrics, g2, ef2
            # manual over `pod` only; GSPMD keeps handling data/model
            return jax.shard_map(
                local, mesh=mesh, axis_names={"pod"},
                in_specs=(P(), P(), P("pod")), out_specs=P(),
                check_vma=False)(params, opt_state["ef"], batch)
    else:
        def synced_grads(params, opt_state, batch):
            loss, metrics, grads = grads_of(params, batch)
            g2, ef2, _ = comp.compress_decompress(
                grads, opt_state["ef"], comp_cfg, lambda t: t)
            return loss, metrics, g2, ef2

    def train_step(params, opt_state, batch):
        loss, metrics, grads, ef = synced_grads(params, opt_state, batch)
        new_params, opt_state2, metrics = apply_update(
            params, opt_state, grads, loss, metrics)
        return new_params, dict(opt_state2, ef=ef), metrics

    return train_step


def init_opt_state(model, run: RunConfig, params: PyTree,
                   opt_cfg: optim.OptimConfig, key=None) -> dict:
    mask = trainable_mask(params, enabled=run.lrd.freeze and run.lrd.enabled)
    state = {"adam": optim.adamw_init(params, mask)}
    if run.parallel.grad_compression_rank > 0:
        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                             params)
        state["ef"] = comp.init_state(
            zeros, comp.CompressionConfig(
                rank=run.parallel.grad_compression_rank),
            key if key is not None else jax.random.PRNGKey(17))
    return state


# ---------------------------------------------------------------------------
# Serving steps
# ---------------------------------------------------------------------------

def make_prefill_step(model, run: RunConfig) -> Callable:
    opts = block_opts(run)

    def prefill_step(params, batch, cache):
        return model.prefill(params, batch, cache, opts=opts)
    return prefill_step


def make_decode_step(model, run: RunConfig) -> Callable:
    opts = block_opts(run)

    def decode_step(params, tokens, positions, cache):
        return model.decode_step(params, tokens, positions, cache, opts=opts)
    return decode_step


def make_forward_step(model, run: RunConfig) -> Callable:
    """Encoder-style full forward returning per-position logits."""
    opts = block_opts(run)

    def forward_step(params, batch):
        x, _ = model.forward(params, batch, opts=opts)
        return model.logits(params, x, opts)
    return forward_step

"""The training loop: jit + shardings + checkpoints + fault tolerance.

``train()`` is the single entry used by examples and tests.  It:

1. builds (or restores) params/opt-state with their NamedShardings,
2. jits the train step with donated state,
3. steps the data pipeline with an explicit cursor,
4. checkpoints asynchronously every ``ckpt_every`` (atomic commits),
5. auto-resumes from the latest valid checkpoint (``resume=True``),
6. honours preemption (checkpoint now, exit), tracks stragglers,
7. optionally crashes on cue (``fault_hook``) for the restart tests.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import numpy as np

from repro.configs.base import RunConfig
from repro.models.api import get_model
from repro.parallel.sharding import (install_activation_rules,
                                     make_param_shardings)
from repro.train import checkpoint as ckpt
from repro.train import steps as steps_mod
from repro.train.data import DataState
from repro.train.fault_tolerance import PreemptionHandler, StragglerDetector
from repro.train.optim import OptimConfig

PyTree = Any


@dataclasses.dataclass
class TrainResult:
    step: int
    metrics: dict
    losses: list[float]
    straggler_report: dict
    resumed_from: int | None = None


def train(run: RunConfig, data, *, num_steps: int,
          optim_cfg: OptimConfig | None = None,
          mesh: jax.sharding.Mesh | None = None,
          ckpt_dir: str | None = None, ckpt_every: int = 0,
          resume: bool = True, log_every: int = 10,
          decompose: bool = True,
          fault_hook: Callable[[int], None] | None = None,
          preemption: PreemptionHandler | None = None,
          log_fn: Callable[[str], None] = print) -> TrainResult:
    model = get_model(run.model)
    optim_cfg = optim_cfg or OptimConfig(total_steps=num_steps)

    # ---- init params (+ LRD surgery) -----------------------------------
    params, axes = model.init(jax.random.PRNGKey(run.seed))
    if decompose and run.lrd.enabled:
        from repro.core.surgery import decompose_model
        params, axes, report = decompose_model(params, axes, run.lrd)
        log_fn(f"[lrd] {report.summary()}")
    opt_state = steps_mod.init_opt_state(model, run, params, optim_cfg)
    data_state = DataState()

    # ---- shardings ------------------------------------------------------
    if mesh is not None:
        install_activation_rules(mesh, run.parallel)
        p_shard = make_param_shardings(mesh, params, axes, run.parallel)
        params = jax.tree.map(jax.device_put, params, p_shard)

    # ---- resume ----------------------------------------------------------
    resumed_from = None
    if ckpt_dir and resume:
        template = {"params": params, "opt": opt_state,
                    "data": data_state.to_dict()}
        got = ckpt.restore_latest(ckpt_dir, template)
        if got is not None:
            tree, manifest = got
            params, opt_state = tree["params"], tree["opt"]
            data_state = DataState.from_dict(tree["data"])
            resumed_from = manifest["step"]
            log_fn(f"[resume] step {resumed_from}")

    train_step = steps_mod.make_train_step(model, run, optim_cfg, mesh)
    jit_step = jax.jit(train_step, donate_argnums=(0, 1))

    writer = ckpt.AsyncCheckpointer(ckpt_dir) if (ckpt_dir and ckpt_every) \
        else None
    detector = StragglerDetector()
    losses: list[float] = []
    metrics: dict = {}
    stream = data.stream(data_state)

    start = int(np.asarray(opt_state["adam"]["step"]))
    step = start
    try:
        for step in range(start, num_steps):
            if fault_hook is not None:
                fault_hook(step)
            batch, data_state = next(stream)
            detector.start()
            params, opt_state, metrics = jit_step(params, opt_state, batch)
            jax.block_until_ready(metrics["loss"])
            detector.stop(step)
            loss = float(np.asarray(metrics["loss"]))
            losses.append(loss)
            if log_every and (step % log_every == 0 or step == num_steps - 1):
                log_fn(f"[train] step={step + 1} loss={loss:.4f} "
                       f"lr={float(np.asarray(metrics['lr'])):.2e}")
            done = step + 1
            want_ckpt = writer and (done % ckpt_every == 0
                                    or done == num_steps)
            if preemption is not None and preemption.preempted:
                log_fn(f"[preempt] checkpointing at step {done} and exiting")
                want_ckpt = writer is not None
            if want_ckpt:
                writer.save(done, {"params": params, "opt": opt_state,
                                   "data": data_state.to_dict()},
                            meta={"loss": loss})
            if preemption is not None and preemption.preempted:
                break
    finally:
        if writer:
            writer.close()

    return TrainResult(step=step + 1, metrics=metrics, losses=losses,
                       straggler_report=detector.report(),
                       resumed_from=resumed_from)

"""AdamW (from scratch) with parameter masks — the paper's §2.2 freezing.

Frozen leaves (mask=False) get *zero-size* moment buffers, so freezing is
visible in optimizer-state memory (``memory_analysis`` in the dry-run) as
well as in backward FLOPs (via ``stop_gradient`` at the apply seam).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class OptimConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    grad_clip: float = 1.0


def lr_schedule(cfg: OptimConfig, step: jax.Array) -> jax.Array:
    """Linear warmup -> cosine decay to min_lr_frac."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, step / max(1, cfg.warmup_steps))
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(math.pi * prog))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.peak_lr * warm * frac


def _moment_like(p, trainable):
    if trainable:
        return jnp.zeros(p.shape, jnp.float32)
    return jnp.zeros((0,), jnp.float32)       # frozen: no moment state


def adamw_init(params: PyTree, mask: PyTree | None = None) -> dict:
    if mask is None:
        mask = jax.tree.map(lambda _: True, params)
    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(_moment_like, params, mask),
        "v": jax.tree.map(_moment_like, params, mask),
    }


def global_norm(tree: PyTree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def adamw_update(grads: PyTree, state: dict, params: PyTree,
                 cfg: OptimConfig, mask: PyTree | None = None
                 ) -> tuple[PyTree, dict, dict]:
    """Returns (new_params, new_state, metrics)."""
    if mask is None:
        mask = jax.tree.map(lambda _: True, params)
    step = state["step"] + 1
    lr = lr_schedule(cfg, step)

    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-12)) \
        if cfg.grad_clip > 0 else 1.0

    b1, b2 = cfg.b1, cfg.b2
    c1 = 1 - b1 ** step.astype(jnp.float32)
    c2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v, trainable):
        if not trainable:
            return p, m, v
        g = g.astype(jnp.float32) * scale
        m2 = b1 * m + (1 - b1) * g
        v2 = b2 * v + (1 - b2) * jnp.square(g)
        mh = m2 / c1
        vh = v2 / c2
        delta = mh / (jnp.sqrt(vh) + cfg.eps) \
            + cfg.weight_decay * p.astype(jnp.float32)
        p2 = p.astype(jnp.float32) - lr * delta
        return p2.astype(p.dtype), m2, v2

    out = jax.tree.map(upd, params, grads, state["m"], state["v"], mask)
    # out is a tree of 3-tuples aligned with params' structure
    is_leaf = lambda x: isinstance(x, tuple) and len(x) == 3 \
        and not isinstance(x[0], tuple)
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=is_leaf)
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=is_leaf)
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=is_leaf)
    new_state = {"step": step, "m": new_m, "v": new_v}
    return new_params, new_state, {"lr": lr, "grad_norm": gnorm}


def optimizer_state_bytes(state: dict) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(state))

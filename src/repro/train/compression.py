"""PowerSGD-style low-rank gradient compression with error feedback.

The paper's idea — low-rank structure exploited for efficiency — applied
to the *optimizer communication* (DESIGN.md §5): a gradient matrix
``G (C, S)`` is factorized per sync as ``P (C, r) @ Q(S, r)^T`` with one
power iteration warm-started from the previous Q; only P and Q cross the
slow link.  The compression residual is fed back into the next step's
gradient (error feedback), which is what keeps SGD/Adam convergence.

Comm bytes per tensor: ``r*(C+S)`` instead of ``C*S`` — the same Eq.-3
accounting as the paper's layer compression, now for the pod-level
all-reduce.  Integration point: :func:`repro.train.steps.sync_grads_pod`
wraps this around an explicit ``lax.psum`` over the ``pod`` mesh axis
inside ``shard_map`` (GSPMD stays in charge of data/model axes).

Tensors that are not 2D+ (norm scales, biases) or too small to win are
synced uncompressed.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    rank: int = 4
    min_dim: int = 64          # don't compress tensors smaller than this
    power_iters: int = 1


def _compressible(shape: tuple[int, ...], cfg: CompressionConfig) -> bool:
    if len(shape) < 2:
        return False
    c = int(jnp.prod(jnp.array(shape[:-1])))
    s = shape[-1]
    if min(c, s) < cfg.min_dim:
        return False
    return cfg.rank * (c + s) < c * s       # compression actually wins


def init_state(grads: PyTree, cfg: CompressionConfig, key: jax.Array) -> dict:
    """Per-leaf: error-feedback buffer + warm-start Q."""
    leaves, treedef = jax.tree.flatten(grads)
    keys = jax.random.split(key, len(leaves))

    def leaf(g, k):
        if not _compressible(g.shape, cfg):
            return {"err": jnp.zeros((0,), jnp.float32)}
        s = g.shape[-1]
        return {
            "err": jnp.zeros(g.shape, jnp.float32),
            "q": jax.random.normal(k, (s, cfg.rank), jnp.float32),
        }
    states = [leaf(g, k) for g, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, states)


def compress_decompress(grads: PyTree, state: PyTree, cfg: CompressionConfig,
                        reduce_fn: Callable[[jax.Array], jax.Array]
                        ) -> tuple[PyTree, PyTree, dict]:
    """EF-PowerSGD round: returns (synced_grads, new_state, stats).

    ``reduce_fn`` is the mean-reduction across the sync group (injected:
    identity for single-process tests, ``lax.pmean`` over `pod` in the
    sharded train step).  It is applied to P/Q for compressed tensors and
    to the raw gradient for uncompressed ones.
    """
    g_leaves, treedef = jax.tree.flatten(grads)
    s_leaves = treedef.flatten_up_to(state)
    bytes_raw = bytes_sent = 0
    out_g, out_s = [], []

    for g, st in zip(g_leaves, s_leaves):
        bytes_raw += g.size * 4
        if "q" not in st:
            bytes_sent += g.size * 4
            out_g.append(reduce_fn(g))
            out_s.append(st)
            continue
        gf = g.astype(jnp.float32).reshape(-1, g.shape[-1])   # (C, S)
        gf = gf + st["err"].reshape(gf.shape)                  # error feedback
        q = st["q"]
        # one (or more) power iterations, reduce P then Q (PowerSGD alg. 1)
        for _ in range(cfg.power_iters):
            p = reduce_fn(gf @ q)                              # (C, r)
            p, _ = jnp.linalg.qr(p)                            # orthonormal
            q = reduce_fn(gf.T @ p)                            # (S, r)
        ghat = p @ q.T
        err = gf - ghat                                        # local residual
        bytes_sent += (p.size + q.size) * 4
        out_g.append(ghat.reshape(g.shape).astype(g.dtype))
        out_s.append({"err": err.reshape(g.shape), "q": q})

    stats = {"bytes_raw": bytes_raw, "bytes_sent": bytes_sent}
    return (jax.tree.unflatten(treedef, out_g),
            jax.tree.unflatten(treedef, out_s), stats)

"""Quantized low-rank factors: the second compression axis.

* :mod:`repro.quant.quantize` — per-channel symmetric int8 / fp8-emulated
  quantization of decomposed factors, plus the ``quantize_tree`` /
  ``dequantize_tree`` pytree transforms that mirror the surgery's
  key-rewriting conventions.
* The matching serving hot path lives in
  :mod:`repro.kernels.lowrank_matmul_q` (fused kernel that dequantizes
  int8 factor tiles in VMEM) behind ``repro.kernels.ops.lowrank_matmul_q``.
* :mod:`repro.quant.kv` — *runtime* quantization: the serve-time int8
  KV cache pool (per-(slot, head, channel) scales, incremental decode
  writes), consumed directly by the fused
  :mod:`repro.kernels.decode_attention_q` kernel.
* :mod:`repro.quant.sparse` — 2:4 semi-structured sparsity of the
  factors (``k_sp``/``k_idx`` packed-value + index-metadata pairs),
  composable with the int8 axis; the fused sparse hot path lives in
  :mod:`repro.kernels.lowrank_matmul_sq` / ``branched_matmul_sq``.

See ``src/repro/quant/README.md`` for the design and config knobs.
"""
from repro.quant.quantize import (  # noqa: F401
    FACTOR_KEYS, IDX_SUFFIX, MODES, QUANT_SUFFIX, SCALE_SUFFIX, SP_SUFFIX,
    align_quantized_axes, dequantize_array, dequantize_subtree,
    dequantize_tree, is_quantized, quantize_array, quantize_tree,
    relative_error, scale_axes, sparse_index_axes, sparse_value_axes,
    tree_bytes,
)
from repro.quant.sparse import (  # noqa: F401
    PATTERN_24, SPARSE_KEYS, desparsify_subtree, desparsify_tree,
    expand_sparse, is_sparse, relative_error_sparse, sparsify_array,
    sparsify_tree,
)

"""Runtime KV-cache quantization: per-(slot, head, channel) int8 K/V.

Weight quantization (:mod:`repro.quant.quantize`) shrinks the *static*
stream; at serve time the decode step is bound by the *runtime* stream —
every token reads the entire KV pool ``(slots, S_max, KV_heads,
head_dim)`` to attend to one query.  This module stores that pool as
int8 values plus f32 scales so the decode-attention read moves ~4x
fewer bytes than an f32 pool (~2x vs bf16), and the fused kernel
(:mod:`repro.kernels.decode_attention_q`) dequantizes tiles in VMEM so
no full-precision copy ever materializes in HBM.

Layout (mirrors the ``k_q``/``k_scale`` pair convention of the weight
subsystem):

    {"k":  (B, S, KH, D) f32}
      -> {"k_q": int8 (B, S, KH, D), "k_scale": f32 (B, KH, D)}

Scales are **per (slot, head, channel)** — one f32 scale per head_dim
channel of each slot's K (or V) stream, i.e. the absmax reduction runs
over the *sequence* axis.  Two reasons over per-token scales:

* the kernel folds K scales into the single query row and V scales into
  the final output (O(D) multiplies instead of O(S*D) dequant work);
* scale storage is O(KH*D) per slot instead of O(S*KH), so the byte
  overhead vanishes as contexts grow.

The cost is that the sequence-reduced scale must cover tokens that have
not arrived yet.  :func:`kv_write_token` handles this *incrementally*:
the scale is a running per-channel max, and when a new token enlarges
it, the slot's int8 history is rescaled in place (``round(q * old/new)``
— at most half an LSB of extra rounding at the new, larger scale; the
O(S) rescale pass is skipped via ``lax.cond`` when no channel grew, so
the steady-state write is a one-row scatter).
Symmetric, no zero point: ``x ~= q * scale`` with ``q in [-127, 127]``.

Prefill quantizes on insert: the whole prompt's K/V is reduced over its
sequence axis in one shot, so the cache pool and the engine's
``_insert_slot`` scatter stay int8 throughout — no f32 staging copy.

The write/quantize primitives are *rank-polymorphic over the tail*: the
same running-max math that handles GQA pools ``(B, S, KH, D)`` with
scales ``(B, KH, D)`` handles the MLA latent cache ``(B, S, r)`` with
per-(slot, channel) scales ``(B, r)`` — the ``mla_latent_int8`` family
of :mod:`repro.layers.cache` reuses ``quantize_kv_prefill`` /
``kv_write_token`` / ``kv_write_chunk`` verbatim on its ``ckv`` /
``krope`` leaves.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.quant.quantize import INT8_QMAX

PyTree = Any

#: runtime KV quantization modes (weight-side fp8 has no KV variant:
#: the decode kernel's dequant-free scale folding needs the int8 grid).
KV_MODES = ("int8",)

#: overflow ceiling for the running-max scales.  A single NaN/Inf
#: activation must corrupt only its own cache row — NOT the
#: per-(slot, head, channel) scale, which the requant pass multiplies
#: into the slot's entire int8 history (``ratio = old/new`` goes to ~0
#: under an overflowed scale, silently zeroing every past token, and a
#: NaN propagates through ``maximum`` forever).
KV_SCALE_MAX = 1e30


def _finite_scale(candidate: jax.Array) -> jax.Array:
    """Overflow-guard a running-max scale candidate: a non-finite
    absmax contributes **nothing** (the running max keeps its old
    value, so the slot's int8 history survives bit-exact — the
    poisoned row itself is sanitized to 0 by :func:`quantize_kv`, and
    the numerical watchdog quarantines the stream off its own NaN
    logits the same step); finite candidates are capped at
    :data:`KV_SCALE_MAX`."""
    return jnp.minimum(jnp.where(jnp.isfinite(candidate), candidate, 0.0),
                       KV_SCALE_MAX)


def _check_mode(mode: str) -> None:
    if mode not in KV_MODES:
        raise ValueError(
            f"unknown kv quant mode {mode!r} (want one of {KV_MODES})")


# ---------------------------------------------------------------------------
# Cache construction
# ---------------------------------------------------------------------------

def kv_cache_spec_q(batch: int, seq_len: int, num_kv_heads: int,
                    head_dim: int, mode: str = "int8") -> dict:
    """ShapeDtypeStruct tree of an int8 KV cache (the quantized twin of
    :func:`repro.layers.attention.kv_cache_spec`)."""
    _check_mode(mode)
    vshape = (batch, seq_len, num_kv_heads, head_dim)
    sshape = (batch, num_kv_heads, head_dim)
    return {"k_q": jax.ShapeDtypeStruct(vshape, jnp.int8),
            "k_scale": jax.ShapeDtypeStruct(sshape, jnp.float32),
            "v_q": jax.ShapeDtypeStruct(vshape, jnp.int8),
            "v_scale": jax.ShapeDtypeStruct(sshape, jnp.float32)}


def init_kv_cache_q(batch: int, seq_len: int, num_kv_heads: int,
                    head_dim: int, mode: str = "int8") -> dict:
    """Zero-initialized int8 KV cache (zero scales dequantize to zeros)."""
    spec = kv_cache_spec_q(batch, seq_len, num_kv_heads, head_dim, mode)
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), spec)


def is_quantized_kv(cache: Any) -> bool:
    """Does this per-layer cache dict hold int8 K/V (or int8 MLA
    latents)?"""
    return isinstance(cache, dict) and ("k_q" in cache or "ckv_q" in cache)


# ---------------------------------------------------------------------------
# Quantize / dequantize
# ---------------------------------------------------------------------------

def quantize_kv(x: jax.Array, scale: jax.Array) -> jax.Array:
    """Quantize ``x`` with a given (broadcastable) scale -> int8.

    Non-finite inputs land as 0 (``int8`` cast of NaN is undefined;
    a poisoned activation must corrupt only its own row,
    deterministically)."""
    safe = jnp.where(scale > 0, scale, 1.0)
    q = jnp.round(x.astype(jnp.float32) / safe)
    q = jnp.where(jnp.isfinite(q), jnp.clip(q, -INT8_QMAX, INT8_QMAX), 0.0)
    return q.astype(jnp.int8)


def kv_scales(x: jax.Array, axis: int = 1) -> jax.Array:
    """Per-(slot, head, channel) scales: absmax over the seq ``axis``,
    clamped to :data:`KV_SCALE_MAX` (overflow guard)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=axis)
    return _finite_scale(amax / INT8_QMAX)


def quantize_kv_prefill(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """One-shot prompt quantization.

    ``x (B, S, KH, D)`` -> ``(q int8 (B, S, KH, D), scale f32 (B, KH, D))``
    with the absmax reduced over the prompt's sequence axis.
    """
    scale = kv_scales(x, axis=1)
    return quantize_kv(x, scale[:, None]), scale


def dequantize_kv(q: jax.Array, scale: jax.Array,
                  dtype=jnp.float32) -> jax.Array:
    """``q (B, S, KH, D) * scale (B, KH, D)`` -> ``(B, S, KH, D)``."""
    return (q.astype(jnp.float32) * scale[:, None]).astype(dtype)


def kv_write_chunk(cache_q: jax.Array, scale: jax.Array, new: jax.Array,
                   start: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Insert a prefill chunk's K (or V) into an int8 cache pool.

    ``cache_q (B, S, KH, D)`` int8; ``scale (B, KH, D)`` f32;
    ``new (B, C, KH, D)``; ``start`` scalar — the chunk's sequence
    offset.  The chunked twin of :func:`kv_write_token`: ONE vectorized
    per-channel absmax over the whole chunk updates the running-max
    scale (instead of C sequential per-token passes, each with its own
    potential O(S) history requant), the slot history is requantized at
    most once per chunk, and the chunk lands as a single
    ``dynamic_update_slice``.  The final scale equals the per-token
    loop's (max is associative); requantized history values can differ
    by 1 LSB from the sequential path (one rounding instead of several).
    """
    newf = new.astype(jnp.float32)
    scale_new = jnp.maximum(
        scale, _finite_scale(jnp.max(jnp.abs(newf), axis=1) / INT8_QMAX))

    def _requant(c):
        safe = jnp.where(scale_new > 0, scale_new, 1.0)
        ratio = jnp.where(scale_new > 0, scale / safe, 1.0)
        return jnp.clip(jnp.round(c.astype(jnp.float32) * ratio[:, None]),
                        -INT8_QMAX, INT8_QMAX).astype(jnp.int8)

    cache_q = jax.lax.cond(jnp.any(scale_new > scale), _requant,
                           lambda c: c, cache_q)
    q_new = quantize_kv(newf, scale_new[:, None])
    return jax.lax.dynamic_update_slice_in_dim(cache_q, q_new, start, 1), \
        scale_new


def quantize_kv_tree(cache: PyTree, prompt_len: jax.Array | None = None
                     ) -> PyTree:
    """Quantize a full-precision stream cache into the int8 pool layout.

    Walks the cache pytree and replaces every GQA KV dict ``{"k","v"}``
    (leaves ``(..., S, KH, D)``, sequence axis -3) and every MLA latent
    dict ``{"ckv","krope"}`` (leaves ``(..., S, r)``, sequence axis -2)
    with the quantized ``*_q``/``*_scale`` layout — works on both
    per-layer and stacked ``(L, B, S, ...)`` caches; non-KV state
    passes through untouched.  ``prompt_len`` masks positions
    ``>= prompt_len`` (the right-padded prefill tail) out of both the
    values and the absmax scale reduction, so the result is
    bit-identical to the quantize-on-insert whole-prefill path.

    The chunked-prefill scheduler stages an in-flight prompt at full
    precision (chunk attention over the exact K/V prefix, so chunked
    greedy == whole-prefill greedy) and calls this once at slot insert
    — the stacked-cache one-shot twin of :func:`quantize_kv_prefill`.
    """
    def one(x, seq_axis):
        xf = x.astype(jnp.float32)
        if prompt_len is not None:
            s = x.shape[seq_axis]
            mask = (jnp.arange(s) < prompt_len).reshape(
                (s,) + (1,) * (-seq_axis - 1))
            xf = jnp.where(mask, xf, 0.0)
        scale = _finite_scale(jnp.max(jnp.abs(xf), axis=seq_axis)
                              / INT8_QMAX)
        sc = jnp.expand_dims(scale, seq_axis)
        safe = jnp.where(sc > 0, sc, 1.0)
        q = jnp.round(xf / safe)
        q = jnp.where(jnp.isfinite(q),
                      jnp.clip(q, -INT8_QMAX, INT8_QMAX), 0.0)
        return q.astype(jnp.int8), scale

    def pair(t, names, seq_axis):
        out = {}
        for name in names:
            q, scale = one(t[name], seq_axis)
            out[name + "_q"] = q
            out[name + "_scale"] = scale
        return out

    def rec(t):
        if isinstance(t, dict):
            if set(t) == {"k", "v"}:
                return pair(t, ("k", "v"), -3)
            if set(t) == {"ckv", "krope"}:
                return pair(t, ("ckv", "krope"), -2)
            return {key: rec(v) for key, v in t.items()}
        return t

    return rec(cache)


def kv_write_token(cache_q: jax.Array, scale: jax.Array, new: jax.Array,
                   pos: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Insert one decoded token's K (or V) into an int8 cache pool.

    ``cache_q (B, S, KH, D)`` int8; ``scale (B, KH, D)`` f32;
    ``new (B, KH, D)``; ``pos (B,)`` per-slot write positions.
    Returns ``(cache_q', scale')``.

    The scale is a per-channel running max: ``scale' = max(scale,
    |new| / 127)``.  Where it grew, the slot's history is requantized at
    the larger scale (``round(q * scale/scale')``); where it did not,
    the ratio is exactly 1 and the rescale is a bit-exact no-op — so the
    whole O(S) history pass runs under a ``lax.cond`` and is skipped
    entirely unless some channel's max actually grew (rare once a slot
    is warm).  The steady-state write stays O(1) like the f32 scatter:
    one token row, not a full pool read-modify-write per step.
    """
    newf = new.astype(jnp.float32)
    scale_new = jnp.maximum(scale, _finite_scale(jnp.abs(newf) / INT8_QMAX))

    def _requant(c):
        safe = jnp.where(scale_new > 0, scale_new, 1.0)
        ratio = jnp.where(scale_new > 0, scale / safe, 1.0)
        return jnp.clip(jnp.round(c.astype(jnp.float32) * ratio[:, None]),
                        -INT8_QMAX, INT8_QMAX).astype(jnp.int8)

    cache_q = jax.lax.cond(jnp.any(scale_new > scale), _requant,
                           lambda c: c, cache_q)
    q_new = quantize_kv(newf, scale_new)
    bidx = jnp.arange(cache_q.shape[0])
    return cache_q.at[bidx, pos].set(q_new), scale_new


# ---------------------------------------------------------------------------
# Accounting (cost model / benchmarks)
# ---------------------------------------------------------------------------

def kv_bytes_per_step(slots: int, seq_len: int, num_kv_heads: int,
                      head_dim: int, *, quantize: str | None = None,
                      dtype_bytes: int = 4) -> int:
    """HBM bytes one layer's K+V pool streams per decode step.

    Decode attention reads every slot's full cache (invalid positions
    are masked, not skipped), so the per-step read is the whole pool:
    values at 1 byte/elt for int8 (plus the f32 scale rows) vs
    ``dtype_bytes`` for the unquantized pool.

    Analytic GQA convenience only — the serve pool and roofline derive
    their numbers from :meth:`repro.layers.cache.CachePlan.
    bytes_per_step` (which covers the MLA latent families too); the
    plan-contract tests cross-check the two.
    """
    n = slots * seq_len * num_kv_heads * head_dim
    if quantize in (None, "none"):
        return 2 * n * dtype_bytes
    _check_mode(quantize)
    return 2 * n + 2 * slots * num_kv_heads * head_dim * 4

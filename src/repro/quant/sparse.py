"""2:4 semi-structured sparsity of decomposed factor matrices.

The third compression axis: low-rank surgery shrinks the *rank*
(:mod:`repro.core.surgery`), per-channel quantization shrinks the
*width* (:mod:`repro.quant.quantize`), and this module shrinks the
*density* — a magnitude-based N:M (2:4) prune of the factor matrices
that composes multiplicatively with both, halving the weight bytes
streamed per decode token again on top of the int8 halving.

Conventions mirror :mod:`repro.quant.quantize`: params stay plain
nested dicts, and a sparsified factor ``k (..., C, S)`` is rewritten in
place as the key triple

    k_sp  — packed kept values, slot-major ``(..., 2, C/4, S)``
            (int8 when composed with quantization, else ``k``'s dtype)
    k_idx — int8 within-group row positions ``(..., 2, C/4, 1)``,
            values in ``{0..3}``, ascending per group
    k_scale — f32 per-output-channel scales ``(..., 1, S)`` (only when
            quantized; same convention as ``quantize_tree``)

**The 2:4 mask is shared across the output axis**: for every group of 4
input rows, the 2 rows with the largest aggregate magnitude (L1 norm
across output channels) are kept for *all* columns.  A per-column mask
would need 2 bits of metadata per kept value (``0.25 byte/value`` — on
int8 values that caps the byte gain at 1.6x, below the 2x the sparsity
nominally buys); the shared mask needs one int8 position per kept *row*
(``C/2`` bytes per factor, amortized over all S columns), so the byte
gain stays ~2x.  The trade is coarser pruning — acceptable on low-rank
factors, whose rows are energy-sorted by construction (the SVD already
concentrated magnitude), and measured end-to-end by
``benchmarks/bench_frontier.py``'s ``token_match`` column.

Slot-major packing (keep-slot as the leading axis, not interleaved)
lets the fused kernels slice ``sp_ref[i]`` as a contiguous 2D tile —
no strided sublane access — and expand it in VMEM with two
repeat/iota-compare passes (:mod:`repro.kernels.lowrank_matmul_sq`).
"""
from __future__ import annotations

from typing import Any, Iterable

import jax
import jax.numpy as jnp

from repro.quant.quantize import (IDX_SUFFIX, MODE_INT8, SCALE_SUFFIX,
                                  SP_SUFFIX, is_quantized, quantize_array,
                                  scale_axes, sparse_index_axes,
                                  sparse_value_axes)

PyTree = Any

PATTERN_24 = "2:4"
PATTERNS = (PATTERN_24,)

#: factor keys the 2:4 pass targets by default: the teacher-derived
#: outer factors (SVD pair w0/w1, branched u/v).  The trainable core
#: (xc) and the spatial Tucker factors are excluded — they are small,
#: and the branched kernel keeps xc as a plain int8 tile.
SPARSE_KEYS = ("w0", "w1", "u", "v")


def pattern_nm(pattern: str) -> tuple[int, int]:
    """``"2:4" -> (2, 4)`` — kept rows per group, group size."""
    if pattern not in PATTERNS:
        raise ValueError(
            f"unknown sparsity pattern {pattern!r} (want one of {PATTERNS})")
    keep, group = (int(t) for t in pattern.split(":"))
    return keep, group


def sparsify_array(w: jax.Array, pattern: str = PATTERN_24,
                   mode: str = MODE_INT8
                   ) -> tuple[jax.Array, jax.Array, jax.Array | None]:
    """Magnitude-prune ``w (..., C, S)`` to 2:4 along the input axis.

    Returns ``(sp, idx, scale)``: packed values ``(..., 2, C/4, S)``,
    int8 within-group positions ``(..., 2, C/4, 1)`` (ascending), and
    per-output-channel f32 scales ``(..., 1, S)`` when ``mode`` is a
    quant mode (``sp`` is then int8/fp8); ``mode="none"`` keeps ``sp``
    in ``w``'s dtype and returns ``scale=None``.

    Magnitude is the row's L1 norm across output channels — the mask is
    shared over S (see module docstring for the byte math).  Requires
    ``C % 4 == 0``.
    """
    keep, group = pattern_nm(pattern)
    *lead, c, s = w.shape
    if c % group:
        raise ValueError(f"input dim {c} not divisible by {group} "
                         f"for {pattern} sparsity: {w.shape}")
    g = c // group
    wf = w.astype(jnp.float32)
    wg = wf.reshape(*lead, g, group, s)
    score = jnp.sum(jnp.abs(wg), axis=-1)                # (..., G, 4)
    # Top-`keep` rows per group; ascending positions for a stable layout
    # (argsort of -score is stable, so ties keep the lower row).
    top = jnp.argsort(-score, axis=-1)[..., :keep]
    idx = jnp.sort(top, axis=-1)                         # (..., G, 2)
    sp = jnp.take_along_axis(wg, idx[..., None], axis=-2)  # (..., G, 2, S)
    # Slot-major: (..., 2, G, S) / (..., 2, G, 1).
    sp = jnp.moveaxis(sp, -2, -3)
    idx = jnp.swapaxes(idx, -1, -2)[..., None].astype(jnp.int8)
    if mode == "none":
        return sp.astype(w.dtype), idx, None
    # Reuse the per-output-channel quantizer by flattening the packed
    # axes: absmax over all kept rows, one f32 scale per column.
    flat = sp.reshape(*lead, keep * g, s)
    q, scale = quantize_array(flat, mode)
    return q.reshape(*lead, keep, g, s), idx, scale


def expand_sparse(sp: jax.Array, idx: jax.Array,
                  scale: jax.Array | None = None,
                  dtype=None) -> jax.Array:
    """Inverse scatter: ``(..., 2, C/4, S) -> (..., C, S)`` dense.

    Pruned rows come back as zeros; with ``scale`` the values are also
    dequantized (matching the fused kernels' in-VMEM expand+dequant).
    Default output dtype: bf16 when dequantizing, else ``sp``'s dtype.
    """
    *lead, keep, g, s = sp.shape
    group = 4 * idx.shape[-1]        # idx (..., keep, G, 1); 2:4 -> 4
    oh = (idx.astype(jnp.int32)
          == jnp.arange(group, dtype=jnp.int32))          # (..., 2, G, 4)
    dense = jnp.einsum("...igj,...igs->...gjs", oh.astype(jnp.float32),
                       sp.astype(jnp.float32))            # (..., G, 4, S)
    dense = dense.reshape(*lead, g * group, s)
    if scale is not None:
        dense = dense * scale
        return dense.astype(dtype or jnp.bfloat16)
    return dense.astype(dtype or sp.dtype)


def is_sparse(node: dict) -> bool:
    """Does this (linear) subtree hold 2:4-packed factors?"""
    return isinstance(node, dict) and any(
        k.endswith(SP_SUFFIX) for k in node)


def desparsify_subtree(node: dict, dtype=jnp.bfloat16) -> dict:
    """Restore one subtree's ``k_sp``/``k_idx``(/``k_scale``) triples to
    plain dense ``k`` (pruned rows as zeros)."""
    out = {}
    for k, v in node.items():
        if k.endswith(SP_SUFFIX):
            base = k[: -len(SP_SUFFIX)]
            out[base] = expand_sparse(v, node[base + IDX_SUFFIX],
                                      node.get(base + SCALE_SUFFIX), dtype)
        elif k.endswith(IDX_SUFFIX):
            continue
        elif (k.endswith(SCALE_SUFFIX)
              and k[: -len(SCALE_SUFFIX)] + SP_SUFFIX in node):
            continue
        else:
            out[k] = v
    return out


def sparsify_tree(params: PyTree, pattern: str = PATTERN_24,
                  mode: str = MODE_INT8, *,
                  targets: Iterable[str] = SPARSE_KEYS,
                  axes: PyTree | None = None) -> PyTree:
    """Sparsify (and optionally quantize) every targeted factor leaf.

    Walks the nested-dict tree the way ``quantize_tree`` does; only 2D+
    array leaves whose key is in ``targets`` *and* whose input dim is
    divisible by the group size are rewritten — other factors pass
    through untouched (a later ``quantize_tree`` still picks them up,
    and mixed subtrees take the reference execution path).  Subtrees
    already sparse or already quantized are left alone, so the
    transform is idempotent and runs *before* ``quantize_tree`` in the
    serve-engine load pipeline.

    ``mode`` is a quant mode (``"int8"``/``"fp8"`` — one pass does
    prune + quantize, emitting ``k_sp``+``k_idx``+``k_scale``) or
    ``"none"`` (prune only, ``k_sp`` keeps the source dtype — the
    sparse-only point of the compression frontier).

    With ``axes`` (the matching logical-axes tree) the rewrite is
    applied to both trees and ``(sparams, saxes)`` is returned, same
    contract as ``quantize_tree``.
    """
    _, group = pattern_nm(pattern)
    targets = set(targets)

    def walk(node: Any, ax: Any) -> tuple[Any, Any]:
        if not isinstance(node, dict):
            return node, ax
        if is_sparse(node) or is_quantized(node):
            return dict(node), (dict(ax) if isinstance(ax, dict) else ax)
        out, a_out = {}, {}
        for k, v in node.items():
            a_k = ax[k] if isinstance(ax, dict) else None
            if (k in targets and hasattr(v, "ndim") and v.ndim >= 2
                    and v.shape[-2] % group == 0 and v.shape[-2] >= group):
                sp, idx, scale = sparsify_array(v, pattern, mode)
                out[k + SP_SUFFIX] = sp
                out[k + IDX_SUFFIX] = idx
                if scale is not None:
                    out[k + SCALE_SUFFIX] = scale
                if isinstance(ax, dict):
                    a_out[k + SP_SUFFIX] = sparse_value_axes(a_k)
                    a_out[k + IDX_SUFFIX] = sparse_index_axes(a_k)
                    if scale is not None:
                        a_out[k + SCALE_SUFFIX] = scale_axes(a_k)
            else:
                out[k], a_out[k] = walk(v, a_k)
        return out, a_out

    sparams, saxes = walk(params, axes)
    if axes is None:
        return sparams
    return sparams, saxes


def desparsify_tree(params: PyTree, dtype=jnp.bfloat16) -> PyTree:
    """Inverse tree transform: restore plain (zero-padded) factor keys."""

    def walk(node: Any) -> Any:
        if not isinstance(node, dict):
            return node
        if is_sparse(node):
            return desparsify_subtree(node, dtype)
        return {k: walk(v) for k, v in node.items()}

    return walk(params)


def relative_error_sparse(w: jax.Array, pattern: str = PATTERN_24,
                          mode: str = MODE_INT8) -> float:
    """||w - expand(sparsify(w))|| / ||w|| — prune + quant round trip."""
    sp, idx, scale = sparsify_array(w, pattern, mode)
    wd = expand_sparse(sp, idx, scale, jnp.float32)
    num = float(jnp.linalg.norm((w.astype(jnp.float32) - wd).reshape(-1)))
    den = float(jnp.linalg.norm(w.astype(jnp.float32).reshape(-1)))
    return num / max(den, 1e-30)

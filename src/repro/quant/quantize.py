"""Per-channel symmetric quantization of decomposed factor matrices.

The paper gets compression *and* speed from the low-rank structure; this
module compounds both by quantizing the factor matrices themselves —
int8 (4x smaller than f32, 2x smaller than bf16) or fp8-emulated — which
halves the HBM weight traffic on the serving hot path on top of the
rank reduction.

Conventions mirror :mod:`repro.core.surgery`: params stay plain nested
dicts, and a quantized factor ``k`` is rewritten *in place* as the key
pair ``k_q`` (narrow values) + ``k_scale`` (f32 per-channel scales), e.g.

    {"w0": (C, R), "w1": (R, S)}
      -> {"w0_q": int8 (C, R), "w0_scale": f32 (1, R),
          "w1_q": int8 (R, S), "w1_scale": f32 (1, S)}

so :func:`repro.layers.param.apply_linear` / ``apply_conv`` dispatch on
the keys present and model code never changes — the same seam the LRD
surgery uses.

Scales are *per output channel*: the absmax reduction runs over the
input (second-to-last) axis only, keeping one scale per column (and per
leading batch/branch index for stacked or branched factors).  Symmetric
(no zero-point): ``w ≈ q * scale`` with ``q in [-127, 127]`` for int8.
"""
from __future__ import annotations

from typing import Any, Iterable

import jax
import jax.numpy as jnp

PyTree = Any

MODE_INT8 = "int8"
MODE_FP8 = "fp8"
MODES = (MODE_INT8, MODE_FP8)

#: keys the LRD surgery can produce (SVD pair, branched, Tucker-2).
FACTOR_KEYS = ("w0", "w1", "u", "xc", "v", "tucker_u", "core", "tucker_v")

QUANT_SUFFIX = "_q"
SCALE_SUFFIX = "_scale"
# 2:4 structured-sparsity pair (repro.quant.sparse): ``k_sp`` packed
# values (slot-major ``(..., 2, C/4, S)``) + ``k_idx`` int8 within-group
# row positions ``(..., 2, C/4, 1)``.  Defined here so the axes
# alignment below covers sparse trees without a circular import.
SP_SUFFIX = "_sp"
IDX_SUFFIX = "_idx"

INT8_QMAX = 127.0          # symmetric narrow range [-127, 127]
FP8_MAX = 448.0            # e4m3 max finite

# fp8 storage dtype; gated because very old jax lacks it (mode="fp8"
# then raises rather than silently misreporting e4m3 numerics).
_FP8_DTYPE = getattr(jnp, "float8_e4m3fn", None)


def quantize_array(w: jax.Array, mode: str = MODE_INT8, *,
                   axis: int = -2) -> tuple[jax.Array, jax.Array]:
    """Quantize ``w`` per-channel along ``axis`` -> ``(q, scale)``.

    ``scale`` keeps ``w``'s shape with ``axis`` collapsed to 1, so
    ``q.astype(f32) * scale`` broadcasts back to ``w``.  All-zero
    channels get scale 0 (dequantizes to exact zeros).
    """
    if mode not in MODES:
        raise ValueError(f"unknown quant mode {mode!r} (want one of {MODES})")
    wf = w.astype(jnp.float32)
    amax = jnp.max(jnp.abs(wf), axis=axis, keepdims=True)
    qmax = INT8_QMAX if mode == MODE_INT8 else FP8_MAX
    scale = amax / qmax
    safe = jnp.where(scale > 0, scale, 1.0)
    scaled = wf / safe
    if mode == MODE_INT8:
        q = jnp.clip(jnp.round(scaled), -INT8_QMAX, INT8_QMAX
                     ).astype(jnp.int8)
    else:
        if _FP8_DTYPE is None:
            raise NotImplementedError(
                "fp8 quantization needs jnp.float8_e4m3fn (jax too old); "
                "use mode='int8'")
        q = scaled.astype(_FP8_DTYPE)
    return q, scale


def dequantize_array(q: jax.Array, scale: jax.Array,
                     dtype=jnp.bfloat16) -> jax.Array:
    """Inverse of :func:`quantize_array` (up to rounding error)."""
    return (q.astype(jnp.float32) * scale).astype(dtype)


def is_quantized(node: dict) -> bool:
    """Does this (linear/conv) subtree hold quantized factors?"""
    return isinstance(node, dict) and any(
        k.endswith(QUANT_SUFFIX) for k in node)


def dequantize_subtree(node: dict, dtype=jnp.bfloat16) -> dict:
    """Restore one subtree's ``k_q``/``k_scale`` pairs to plain ``k``."""
    out = {}
    for k, v in node.items():
        if k.endswith(QUANT_SUFFIX):
            base = k[: -len(QUANT_SUFFIX)]
            out[base] = dequantize_array(v, node[base + SCALE_SUFFIX], dtype)
        elif k.endswith(SCALE_SUFFIX):
            continue
        else:
            out[k] = v
    return out


def scale_axes(axes: tuple) -> tuple:
    """Logical axes of a ``k_scale`` leaf given factor ``k``'s axes.

    The absmax reduction collapses the input (second-to-last) axis to 1,
    so the scale keeps ``k``'s axes with that position unsharded (None)
    and the out-dim axis intact — which is how quantized trees shard:
    ``k_scale`` follows ``k``'s output dim or replicates.
    """
    if len(axes) < 2:
        raise ValueError(f"factor axes must be 2D+: {axes}")
    return (*axes[:-2], None, axes[-1])


def sparse_value_axes(axes: tuple) -> tuple:
    """Logical axes of a ``k_sp`` leaf given factor ``k``'s axes.

    The slot-major packing ``(..., 2, C/4, S)`` inserts an unsharded
    keep-slot axis before the (grouped) input axis; the input and output
    axes keep their logical names, so a sparse tree shards like its
    dense twin (the grouped input dim is C/4 — still divisible for any
    mesh that divided C, since C % 4 == 0).
    """
    if len(axes) < 2:
        raise ValueError(f"factor axes must be 2D+: {axes}")
    return (*axes[:-2], None, axes[-2], axes[-1])


def sparse_index_axes(axes: tuple) -> tuple:
    """Logical axes of a ``k_idx`` leaf ``(..., 2, C/4, 1)``: keep-slot
    and the collapsed output dim unsharded, input axis as the value."""
    if len(axes) < 2:
        raise ValueError(f"factor axes must be 2D+: {axes}")
    return (*axes[:-2], None, axes[-2], None)


def align_quantized_axes(params_node: dict, axes_node: dict) -> dict:
    """Axes dict aligned with a (possibly quantized/sparse) params dict.

    For every ``k_q``/``k_scale`` (and sparse ``k_sp``/``k_idx``) key
    whose axes entry is missing, derives it from factor ``k``'s logical
    axes: ``k_q`` inherits them verbatim, ``k_scale`` gets
    :func:`scale_axes`, ``k_sp``/``k_idx`` get
    :func:`sparse_value_axes`/:func:`sparse_index_axes`.  This is the
    one place the rewrite conventions meet the axes trees —
    ``parallel.sharding.make_param_shardings`` calls it per dict node,
    so trees quantized or sparsified *after* the axes were built still
    resolve.
    """
    out = {}
    for k in params_node:
        if k in axes_node:
            out[k] = axes_node[k]
            continue
        if k.endswith(QUANT_SUFFIX):
            base = k[: -len(QUANT_SUFFIX)]
            if base in axes_node:
                out[k] = axes_node[base]
                continue
        elif k.endswith(SCALE_SUFFIX):
            base = k[: -len(SCALE_SUFFIX)]
            if base in axes_node:
                out[k] = scale_axes(axes_node[base])
                continue
        elif k.endswith(SP_SUFFIX):
            base = k[: -len(SP_SUFFIX)]
            if base in axes_node:
                out[k] = sparse_value_axes(axes_node[base])
                continue
        elif k.endswith(IDX_SUFFIX):
            base = k[: -len(IDX_SUFFIX)]
            if base in axes_node:
                out[k] = sparse_index_axes(axes_node[base])
                continue
        raise KeyError(
            f"cannot resolve logical axes for param key {k!r} "
            f"(axes node has {sorted(axes_node)})")
    return out


def quantize_tree(params: PyTree, mode: str = MODE_INT8, *,
                  targets: Iterable[str] = FACTOR_KEYS,
                  axes: PyTree | None = None) -> PyTree:
    """Quantize every targeted factor leaf in a param tree.

    Walks the nested-dict tree the way the surgery does; only 2D+ array
    leaves whose key is in ``targets`` are rewritten (norms, embeddings,
    dense ``w`` layers the surgery kept as ORG, and biases pass through
    untouched).  Already-quantized subtrees are left alone, so the
    transform is idempotent.

    When ``axes`` (the matching logical-axes tree) is given, the rewrite
    is applied to *both* trees and ``(qparams, qaxes)`` is returned:
    ``k_q`` inherits ``k``'s axes, ``k_scale`` gets :func:`scale_axes` —
    so quantized trees keep sharding through
    ``parallel.sharding.make_param_shardings``.
    """
    targets = set(targets)

    def walk(node: Any, ax: Any) -> tuple[Any, Any]:
        if not isinstance(node, dict):
            return node, ax
        if is_quantized(node):
            out = dict(node)
            a_out = (align_quantized_axes(node, ax)
                     if isinstance(ax, dict) else ax)
            return out, a_out
        out, a_out = {}, {}
        for k, v in node.items():
            if isinstance(ax, dict):
                if k not in ax:
                    raise KeyError(
                        f"axes tree missing entry for param key {k!r} "
                        f"(axes node has {sorted(ax)})")
                a_k = ax[k]
            else:
                a_k = None
            if (k in targets and hasattr(v, "ndim") and v.ndim >= 2):
                q, scale = quantize_array(v, mode)
                out[k + QUANT_SUFFIX] = q
                out[k + SCALE_SUFFIX] = scale
                if isinstance(ax, dict):
                    a_out[k + QUANT_SUFFIX] = a_k
                    a_out[k + SCALE_SUFFIX] = scale_axes(a_k)
            else:
                out[k], a_out[k] = walk(v, a_k)
        return out, a_out

    qparams, qaxes = walk(params, axes)
    if axes is None:
        return qparams
    return qparams, qaxes


def dequantize_tree(params: PyTree, dtype=jnp.bfloat16) -> PyTree:
    """Inverse tree transform: restore plain factor keys everywhere."""

    def walk(node: Any) -> Any:
        if not isinstance(node, dict):
            return node
        if is_quantized(node):
            return dequantize_subtree(node, dtype)
        return {k: walk(v) for k, v in node.items()}

    return walk(params)


# ---------------------------------------------------------------------------
# Accounting helpers (benchmarks / reports)
# ---------------------------------------------------------------------------

def tree_bytes(params: PyTree) -> int:
    """Total parameter bytes (what HBM must hold / stream per full pass)."""
    total = 0
    for leaf in jax.tree.leaves(params):
        itemsize = getattr(leaf.dtype, "itemsize", None)
        if itemsize is None:                      # fp8 dtypes on old numpy
            itemsize = jnp.dtype(leaf.dtype).itemsize
        total += int(leaf.size) * int(itemsize)
    return total


def relative_error(w: jax.Array, mode: str = MODE_INT8, *,
                   axis: int = -2) -> float:
    """||w - dq(q(w))|| / ||w|| — the round-trip quantization error."""
    q, scale = quantize_array(w, mode, axis=axis)
    wd = dequantize_array(q, scale, jnp.float32)
    num = float(jnp.linalg.norm((w.astype(jnp.float32) - wd).reshape(-1)))
    den = float(jnp.linalg.norm(w.astype(jnp.float32).reshape(-1)))
    return num / max(den, 1e-30)

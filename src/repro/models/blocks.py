"""Per-family transformer/SSM blocks built on the LRD-transparent layers.

Every block has ``init_*(pb, cfg)`` building a *single layer's* params
(stacked by the model via ``jax.vmap``) and ``apply_*`` operating on one
layer's params.  Cache pytrees are per-layer dicts stacked by the model.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.layers import attention as attn
from repro.layers import cache as cache_mod
from repro.layers import ssm as ssm_mod
from repro.layers.mlp import apply_mlp, init_mlp
from repro.layers.moe import MoEOpts, apply_moe, init_moe
from repro.layers.norm import (init_layer_norm, init_rms_norm, layer_norm,
                               rms_norm)
from repro.layers.param import ParamBuilder, shard_act, BATCH, SEQ, EMBED


class BlockOpts(NamedTuple):
    freeze_factors: bool = False
    use_pallas: bool = False
    act_quantize: bool = False

    def attn(self, softcap: float = 0.0) -> attn.AttnOpts:
        return attn.AttnOpts(self.freeze_factors, self.use_pallas, softcap,
                             self.act_quantize)

    def moe(self) -> MoEOpts:
        return MoEOpts(self.freeze_factors, self.use_pallas,
                       self.act_quantize)

    def ssm(self) -> ssm_mod.SSMOpts:
        return ssm_mod.SSMOpts(self.freeze_factors, self.use_pallas,
                               self.act_quantize)

    def kw(self) -> dict:
        return dict(freeze_factors=self.freeze_factors,
                    use_pallas=self.use_pallas,
                    act_quantize=self.act_quantize)


def _norm_fns(cfg):
    if cfg.family == "encoder":
        return init_layer_norm, layer_norm
    return init_rms_norm, rms_norm


# ---------------------------------------------------------------------------
# Decoder / encoder block (dense FFN or MoE; GQA or MLA or merged attention)
# ---------------------------------------------------------------------------

def init_block(pb: ParamBuilder, cfg, *, moe: bool) -> None:
    init_norm, _ = _norm_fns(cfg)
    init_norm(pb, "attn_norm", cfg.d_model)
    if cfg.mla:
        attn.init_mla(pb, "mla", cfg)
    else:
        attn.init_attention(pb, "attn", cfg.d_model, cfg.num_heads,
                            cfg.num_kv_heads, cfg.resolved_head_dim)
    init_norm(pb, "mlp_norm", cfg.d_model)
    if moe:
        init_moe(pb, "moe", cfg.d_model, cfg.resolved_moe_d_ff,
                 cfg.moe_num_experts, cfg.moe_num_shared, cfg.act)
    else:
        init_mlp(pb, "mlp", cfg.d_model, cfg.d_ff, cfg.act)


def apply_block(p: dict, x: jax.Array, cfg, *, positions, cache=None,
                cache_pos=None, prompt_len=None, start_pos=None,
                cache_plan=None, opts: BlockOpts = BlockOpts()
                ) -> tuple[jax.Array, Any, jax.Array]:
    """Pre-norm block.  Returns (x', new_cache, aux_loss).

    ``start_pos`` (scalar) marks a chunked prefill: x covers prompt
    positions ``[start_pos, start_pos + S)`` and K/V land at the offset
    in the existing cache slot (see ``attention.apply_attention``).
    ``cache_plan`` is the layer's :class:`repro.layers.cache.CachePlan`
    (classified from the cache keys when None).
    """
    _, norm = _norm_fns(cfg)
    causal = not cfg.is_encoder
    h = norm(p["attn_norm"], x, cfg.norm_eps)
    if "mla" in p:
        a, new_cache = attn.apply_mla(
            p["mla"], h, cfg, positions=positions, causal=causal,
            cache=cache, cache_pos=cache_pos, prompt_len=prompt_len,
            start_pos=start_pos, plan=cache_plan,
            opts=opts.attn(cfg.attn_logit_softcap))
    elif "merged" in p:
        a = attn.apply_merged_attention(
            p["merged"], h, positions=positions, causal=causal,
            opts=opts.attn(cfg.attn_logit_softcap))
        new_cache = None
    else:
        a, new_cache = attn.apply_attention(
            p["attn"], h, num_heads=cfg.num_heads,
            num_kv_heads=cfg.num_kv_heads, head_dim=cfg.resolved_head_dim,
            rope_theta=cfg.rope_theta, positions=positions, causal=causal,
            cache=cache, cache_pos=cache_pos, prompt_len=prompt_len,
            start_pos=start_pos, plan=cache_plan,
            opts=opts.attn(cfg.attn_logit_softcap))
    x = x + a
    h = norm(p["mlp_norm"], x, cfg.norm_eps)
    aux = jnp.zeros((), jnp.float32)
    if "moe" in p:
        f, aux = apply_moe(p["moe"], h, top_k=cfg.moe_top_k,
                           capacity_factor=cfg.moe_capacity_factor,
                           act=cfg.act, opts=opts.moe(),
                           dispatch_groups=cfg.moe_dispatch_groups)
    else:
        f = apply_mlp(p["mlp"], h, cfg.act, **opts.kw())
    x = x + f
    x = shard_act(x, BATCH, SEQ, EMBED)
    return x, new_cache, aux


def block_cache_spec(cfg, batch: int, seq_len: int, dtype,
                     kv_quantize: str | None = None, paged=None) -> dict:
    # One declarative seam for every family: gqa_f32 | gqa_int8 |
    # mla_latent | mla_latent_int8 | gqa_paged_* (the MLA latent —
    # itself the paper's rank-compressed K/V factor — quantizes like
    # any other pool now; a PagedGeometry selects the paged layout,
    # where batch/seq_len mean (num_blocks + 1, block_size)).
    return cache_mod.build_cache_plan(cfg, dtype, kv_quantize,
                                      paged).spec(batch, seq_len)


def init_block_cache(cfg, batch: int, seq_len: int, dtype,
                     kv_quantize: str | None = None, paged=None) -> dict:
    return cache_mod.build_cache_plan(cfg, dtype, kv_quantize,
                                      paged).init(batch, seq_len)


# ---------------------------------------------------------------------------
# Cross-attention block (VLM): self-style block + gated cross attention
# ---------------------------------------------------------------------------

def init_cross_block(pb: ParamBuilder, cfg) -> None:
    init_norm, _ = _norm_fns(cfg)
    init_norm(pb, "norm", cfg.d_model)
    kv_dim = cfg.vision_d_model or cfg.d_model
    attn.init_cross_attention(pb, "cross_attn", cfg.d_model, cfg.num_heads,
                              cfg.num_kv_heads, cfg.resolved_head_dim, kv_dim)
    init_norm(pb, "mlp_norm", cfg.d_model)
    init_mlp(pb, "mlp", cfg.d_model, cfg.d_ff, cfg.act)


def cross_block_kv(p: dict, image_feats: jax.Array, cfg, *,
                   opts: BlockOpts = BlockOpts()) -> dict:
    return attn.cross_attn_kv(p["cross_attn"], image_feats,
                              num_kv_heads=cfg.num_kv_heads,
                              head_dim=cfg.resolved_head_dim,
                              opts=opts.attn())


def cross_kv_all(cross_stacked: dict, image_feats: jax.Array, cfg, *,
                 opts: BlockOpts = BlockOpts()) -> dict:
    """K/V for every stacked cross block: {"k","v"} (n_super, B, T, KH, hd)."""
    def body(_, p_l):
        return None, cross_block_kv(p_l, image_feats, cfg, opts=opts)
    _, kvs = jax.lax.scan(body, None, cross_stacked)
    return kvs


def apply_cross_block(p: dict, x: jax.Array, cfg, *,
                      image_feats: jax.Array | None = None,
                      kv: dict | None = None,
                      opts: BlockOpts = BlockOpts()) -> jax.Array:
    _, norm = _norm_fns(cfg)
    h = norm(p["norm"], x, cfg.norm_eps)
    a = attn.apply_cross_attention(
        p["cross_attn"], h, image_feats, num_heads=cfg.num_heads,
        num_kv_heads=cfg.num_kv_heads, head_dim=cfg.resolved_head_dim,
        kv=kv, opts=opts.attn())
    x = x + a
    h = norm(p["mlp_norm"], x, cfg.norm_eps)
    x = x + apply_mlp(p["mlp"], h, cfg.act, **opts.kw())
    return x


# ---------------------------------------------------------------------------
# SSM (mamba2) block
# ---------------------------------------------------------------------------

def init_ssm_block(pb: ParamBuilder, cfg) -> None:
    init_rms_norm(pb, "norm", cfg.d_model)
    ssm_mod.init_ssm(pb, "ssm", ssm_mod.dims_from_config(cfg))


def apply_ssm_block(p: dict, x: jax.Array, cfg, *, state=None,
                    decode: bool = False, opts: BlockOpts = BlockOpts()
                    ) -> tuple[jax.Array, Any]:
    dims = ssm_mod.dims_from_config(cfg)
    h = rms_norm(p["norm"], x, cfg.norm_eps)
    if decode:
        y, new_state = ssm_mod.apply_ssm_decode(
            p["ssm"], h, dims, state, opts=opts.ssm(), norm_eps=cfg.norm_eps)
    else:
        y, new_state = ssm_mod.apply_ssm(
            p["ssm"], h, dims, state=state, opts=opts.ssm(),
            norm_eps=cfg.norm_eps)
    x = x + y
    x = shard_act(x, BATCH, SEQ, EMBED)
    return x, new_state

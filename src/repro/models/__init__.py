from repro.models.api import get_model  # noqa: F401

"""LM trunk assembly for all assigned architectures.

One :class:`LMModel` covers the six LM families by composing the blocks in
:mod:`repro.models.blocks` into *stacked segments* scanned with
``lax.scan`` (compile time stays flat in depth — mandatory at 48-100
layers):

* dense / encoder:   one stack of L blocks.
* moe:               optional unstacked first dense block (deepseek), then
                     a stack of MoE blocks.
* vlm:               self-attn stack reshaped ``(n_super, every-1, ...)``
                     interleaved with a cross-attn stack ``(n_super, ...)``
                     — scan over super-blocks, inner scan over self layers.
* ssm:               one stack of mamba2 blocks.
* hybrid (zamba2):   mamba2 stack with a *shared* attention block applied
                     every ``hybrid_attn_every`` layers (scan over
                     super-groups; the shared block's params are reused,
                     each application has its own KV cache slot).

Caches are pytrees stacked along each segment's scan axis, so prefill and
decode run under the same scans.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.layers import cache as cache_mod
from repro.layers import ssm as ssm_mod
from repro.layers.norm import init_layer_norm, init_rms_norm, layer_norm, rms_norm
from repro.layers.param import (
    ParamBuilder, apply_linear, init_linear, shard_act,
    BATCH, SEQ, EMBED, VOCAB, LAYERS,
)
from repro.models import blocks as B

PyTree = Any
CE_CHUNK_SEQ = 512      # logits computed per seq-chunk to bound activation


def _axes_tuple_leaf(x):
    return isinstance(x, tuple)


def _stack_axes(axes: PyTree) -> PyTree:
    return jax.tree.map(lambda a: (LAYERS, *a), axes,
                        is_leaf=_axes_tuple_leaf)


class LMModel:
    """init / apply / loss / cache management for one architecture."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.dtype = jnp.dtype(cfg.dtype)
        self.padded_vocab = (-cfg.vocab_size) % 128 + cfg.vocab_size \
            if cfg.pad_vocab else cfg.vocab_size
        f = cfg.family
        if f == "vlm":
            assert cfg.cross_attn_every > 1
            assert cfg.num_layers % cfg.cross_attn_every == 0, cfg.num_layers
            self.n_super = cfg.num_layers // cfg.cross_attn_every
            self.n_self_per = cfg.cross_attn_every - 1
        if f == "hybrid":
            self.n_groups = cfg.num_layers // cfg.hybrid_attn_every
            self.n_trailing = cfg.num_layers % cfg.hybrid_attn_every

    # -- init ---------------------------------------------------------------

    def _build_one(self, build_fn):
        def fn(key):
            pb = ParamBuilder(key, self.dtype)
            build_fn(pb)
            return pb.params
        return fn

    def _init_stack(self, key, n, build_fn):
        fn = self._build_one(build_fn)
        params = jax.vmap(fn)(jax.random.split(key, n))
        axes = ParamBuilder(jax.random.PRNGKey(0), self.dtype)
        build_fn(axes)
        return params, _stack_axes(axes.axes)

    def init(self, key: jax.Array) -> tuple[PyTree, PyTree]:
        cfg = self.cfg
        pb = ParamBuilder(key, self.dtype)
        keys = jax.random.split(jax.random.fold_in(key, 1), 8)

        if cfg.family != "encoder":
            pb.child("embed").param(
                "w", (self.padded_vocab, cfg.d_model), (VOCAB, EMBED),
                init="embed", scale=0.02)
        elif cfg.frontend_dim and cfg.frontend_dim != cfg.d_model:
            init_linear(pb, "frontend_proj", cfg.frontend_dim, cfg.d_model,
                        EMBED, EMBED)

        f = cfg.family
        if f in ("dense", "encoder"):
            p, a = self._init_stack(
                keys[0], cfg.num_layers,
                lambda b: B.init_block(b, cfg, moe=False))
            pb.attach("blocks", p, a)
        elif f == "moe":
            n_first = cfg.moe_first_dense
            if n_first:
                first = ParamBuilder(keys[1], self.dtype)
                B.init_block(first, cfg, moe=False)
                pb.attach("first", first.params, first.axes)
            p, a = self._init_stack(
                keys[0], cfg.num_layers - n_first,
                lambda b: B.init_block(b, cfg, moe=True))
            pb.attach("blocks", p, a)
        elif f == "vlm":
            p, a = self._init_stack(
                keys[0], self.n_super * self.n_self_per,
                lambda b: B.init_block(b, cfg, moe=False))
            pb.attach("blocks", p, a)
            p, a = self._init_stack(
                keys[1], self.n_super, lambda b: B.init_cross_block(b, cfg))
            pb.attach("cross", p, a)
        elif f == "ssm":
            p, a = self._init_stack(keys[0], cfg.num_layers,
                                    lambda b: B.init_ssm_block(b, cfg))
            pb.attach("blocks", p, a)
        elif f == "hybrid":
            p, a = self._init_stack(keys[0], cfg.num_layers,
                                    lambda b: B.init_ssm_block(b, cfg))
            pb.attach("blocks", p, a)
            shared = ParamBuilder(keys[2], self.dtype)
            B.init_block(shared, cfg, moe=False)
            pb.attach("shared_attn", shared.params, shared.axes)
        else:
            raise ValueError(f"LMModel does not handle family {f!r}")

        if cfg.family == "encoder":
            init_layer_norm(pb, "final_norm", cfg.d_model)
        else:
            init_rms_norm(pb, "final_norm", cfg.d_model)
        if not cfg.tie_embeddings:
            init_linear(pb, "unembed", cfg.d_model, self.padded_vocab,
                        EMBED, VOCAB)
        return pb.params, pb.axes

    # -- embedding / head -----------------------------------------------------

    def embed(self, params: PyTree, batch: dict) -> jax.Array:
        cfg = self.cfg
        if cfg.family == "encoder":
            x = batch["frames"].astype(self.dtype)
            if "frontend_proj" in params:
                x = apply_linear(params["frontend_proj"], x)
            return x
        tok = batch["tokens"]
        emb = params["embed"]["w"]
        x = emb[tok].astype(self.dtype)
        return shard_act(x, BATCH, SEQ, EMBED)

    def logits(self, params: PyTree, x: jax.Array,
               opts: B.BlockOpts = B.BlockOpts()) -> jax.Array:
        cfg = self.cfg
        norm = layer_norm if cfg.family == "encoder" else rms_norm
        h = norm(params["final_norm"], x, cfg.norm_eps)
        if cfg.tie_embeddings:
            w = params["embed"]["w"]
            out = jnp.einsum("bsd,vd->bsv", h, w,
                             preferred_element_type=jnp.float32)
        else:
            out = apply_linear(params["unembed"], h, **opts.kw(),
                               accum_dtype=jnp.float32)
        out = out.astype(jnp.float32)
        if self.padded_vocab != cfg.vocab_size:
            # mask padded vocab columns (they hold real weights but are
            # not tokens): large-negative so softmax/argmax ignore them
            mask = jnp.arange(self.padded_vocab) < cfg.vocab_size
            out = jnp.where(mask[None, None, :], out, -1e30)
        return out

    # -- trunk ---------------------------------------------------------------

    def trunk(self, params: PyTree, x: jax.Array, *, positions, cache=None,
              cache_pos=None, batch=None, opts=B.BlockOpts(),
              remat: str = "none", prompt_len=None, start_pos=None,
              cache_plan=None) -> tuple[jax.Array, PyTree, jax.Array]:
        """Run all blocks. Returns (x, new_cache, aux_loss_sum).

        ``prompt_len`` (scalar, prefill only) marks how many leading
        positions are real tokens when the prompt is right-padded — the
        quantized-KV prefill masks pad positions out of its scale
        reduction (see ``apply_attention``).

        ``start_pos`` (scalar) switches prefill into *chunk* mode: x
        covers prompt positions ``[start_pos, start_pos + S)`` and each
        block's K/V lands at the offset in the existing cache slot.
        Attention-cached families only (the serve scheduler gates
        chunked admission accordingly).

        ``cache_plan`` is the per-layer :class:`repro.layers.cache.
        CachePlan` the serve runner threads down; classified from the
        cache keys when None (direct callers)."""
        cfg = self.cfg
        f = cfg.family
        decode = cache_pos is not None

        def wrap(fn):
            if remat == "none" or decode:
                return fn
            policy = (jax.checkpoint_policies.nothing_saveable
                      if remat == "full"
                      else jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
            return jax.checkpoint(fn, policy=policy)

        aux_total = jnp.zeros((), jnp.float32)
        new_cache: dict | None = {} if cache is not None else None

        def scan_attn_stack(x, stack_p, stack_cache):
            def body(carry, xs):
                h, aux = carry
                p_l, c_l = xs
                h, nc, a = B.apply_block(p_l, h, cfg, positions=positions,
                                         cache=c_l, cache_pos=cache_pos,
                                         prompt_len=prompt_len,
                                         start_pos=start_pos,
                                         cache_plan=cache_plan, opts=opts)
                return (h, aux + a), nc
            (x, aux), ncs = lax.scan(wrap(body), (x, aux_total * 0),
                                     (stack_p, stack_cache))
            return x, ncs, aux

        if f in ("dense", "encoder", "moe"):
            if f == "moe" and "first" in params:
                c0 = None if cache is None else cache["first"]
                x, nc0, a0 = B.apply_block(
                    params["first"], x, cfg, positions=positions, cache=c0,
                    cache_pos=cache_pos, prompt_len=prompt_len,
                    start_pos=start_pos, cache_plan=cache_plan, opts=opts)
                aux_total = aux_total + a0
                if new_cache is not None:
                    new_cache["first"] = nc0
            bc = None if cache is None else cache["blocks"]
            x, ncs, aux = scan_attn_stack(x, params["blocks"], bc)
            aux_total = aux_total + aux
            if new_cache is not None:
                new_cache["blocks"] = ncs

        elif f == "vlm":
            ns, npr = self.n_super, self.n_self_per
            self_p = jax.tree.map(
                lambda t: t.reshape(ns, npr, *t.shape[1:]), params["blocks"])
            if cache is None:        # train: no caches
                img = batch["image_embeds"].astype(self.dtype)

                def super_train(carry, xs):
                    h, aux = carry
                    sp, cp = xs
                    def inner(c2, p_l):
                        hh, aa = c2
                        hh, _, a = B.apply_block(p_l, hh, cfg,
                                                 positions=positions,
                                                 opts=opts)
                        return (hh, aa + a), None
                    (h, aux), _ = lax.scan(wrap(inner), (h, aux), sp)
                    h = B.apply_cross_block(cp, h, cfg, image_feats=img,
                                            opts=opts)
                    return (h, aux), None

                (x, aux_total), _ = lax.scan(wrap(super_train),
                                             (x, aux_total),
                                             (self_p, params["cross"]))
            else:
                if decode:
                    img_kv = cache["cross_kv"]
                else:
                    img = batch["image_embeds"].astype(self.dtype)
                    img_kv = B.cross_kv_all(params["cross"], img, cfg,
                                            opts=opts)

                def super_body(carry, xs):
                    h, aux = carry
                    sp, cp, sc, kv_l = xs
                    def inner(c2, xs2):
                        hh, aa = c2
                        p_l, c_l = xs2
                        hh, nc, a = B.apply_block(
                            p_l, hh, cfg, positions=positions, cache=c_l,
                            cache_pos=cache_pos, prompt_len=prompt_len,
                            start_pos=start_pos, cache_plan=cache_plan,
                            opts=opts)
                        return (hh, aa + a), nc
                    (h, aux), ncs = lax.scan(wrap(inner), (h, aux), (sp, sc))
                    h = B.apply_cross_block(cp, h, cfg, kv=kv_l, opts=opts)
                    return (h, aux), ncs

                (x, aux_total), ncs = lax.scan(
                    wrap(super_body), (x, aux_total),
                    (self_p, params["cross"], cache["self"], img_kv))
                new_cache["self"] = ncs
                new_cache["cross_kv"] = img_kv

        elif f == "ssm":
            bc = None if cache is None else cache["blocks"]
            def body(carry, xs):
                h = carry
                p_l, s_l = xs
                h, ns = B.apply_ssm_block(p_l, h, cfg, state=s_l,
                                          decode=decode, opts=opts)
                return h, ns
            if cache is None:
                def body_nc(h, p_l):
                    h, _ = B.apply_ssm_block(p_l, h, cfg, opts=opts)
                    return h, None
                x, _ = lax.scan(wrap(body_nc), x, params["blocks"])
            else:
                x, ncs = lax.scan(wrap(body), x, (params["blocks"], bc))
                new_cache["blocks"] = ncs

        elif f == "hybrid":
            x, new_cache, aux_total = self._hybrid_trunk(
                params, x, positions=positions, cache=cache,
                cache_pos=cache_pos, cache_plan=cache_plan, opts=opts,
                wrap=wrap)
        else:
            raise ValueError(f)
        return x, new_cache, aux_total

    def _hybrid_trunk(self, params, x, *, positions, cache, cache_pos, opts,
                      wrap, cache_plan=None):
        cfg = self.cfg
        every = cfg.hybrid_attn_every
        ng, nt = self.n_groups, self.n_trailing
        decode = cache_pos is not None
        shared_p = params["shared_attn"]
        new_cache = {} if cache is not None else None
        aux = jnp.zeros((), jnp.float32)

        grouped = jax.tree.map(
            lambda t: t[:ng * every].reshape(ng, every, *t.shape[1:]),
            params["blocks"])
        trailing = jax.tree.map(lambda t: t[ng * every:], params["blocks"])

        def group_body(carry, xs):
            h, a = carry
            if cache is None:
                gp, = xs
                def inner(hh, p_l):
                    hh, _ = B.apply_ssm_block(p_l, hh, cfg, opts=opts)
                    return hh, None
                h, _ = lax.scan(wrap(inner), h, gp)
                h, _, a2 = B.apply_block(shared_p, h, cfg,
                                         positions=positions, opts=opts)
                return (h, a + a2), None
            gp, gs, sc = xs
            def inner(hh, xs2):
                p_l, s_l = xs2
                hh, ns = B.apply_ssm_block(p_l, hh, cfg, state=s_l,
                                           decode=decode, opts=opts)
                return hh, ns
            h, nss = lax.scan(wrap(inner), h, (gp, gs))
            h, nc, a2 = B.apply_block(shared_p, h, cfg, positions=positions,
                                      cache=sc, cache_pos=cache_pos,
                                      cache_plan=cache_plan, opts=opts)
            return (h, a + a2), (nss, nc)

        if cache is None:
            (x, aux), _ = lax.scan(wrap(group_body), (x, aux), (grouped,))
            if nt:
                def tail(hh, p_l):
                    hh, _ = B.apply_ssm_block(p_l, hh, cfg, opts=opts)
                    return hh, None
                x, _ = lax.scan(wrap(tail), x, trailing)
            return x, None, aux

        gs = jax.tree.map(
            lambda t: t[:ng * every].reshape(ng, every, *t.shape[1:]),
            cache["blocks"])
        ts = jax.tree.map(lambda t: t[ng * every:], cache["blocks"])
        (x, aux), (nss, ncs) = lax.scan(
            wrap(group_body), (x, aux), (grouped, gs, cache["shared"]))
        new_states = jax.tree.map(
            lambda t: t.reshape(ng * every, *t.shape[2:]), nss)
        if nt:
            def tail(hh, xs2):
                p_l, s_l = xs2
                hh, ns = B.apply_ssm_block(p_l, hh, cfg, state=s_l,
                                           decode=decode, opts=opts)
                return hh, ns
            x, tns = lax.scan(wrap(tail), x, (trailing, ts))
            new_states = jax.tree.map(
                lambda a, b: jnp.concatenate([a, b], 0), new_states, tns)
        new_cache["blocks"] = new_states
        new_cache["shared"] = ncs
        return x, new_cache, aux

    # -- top-level steps ------------------------------------------------------

    def forward(self, params: PyTree, batch: dict, *,
                opts: B.BlockOpts = B.BlockOpts(), remat: str = "none"
                ) -> tuple[jax.Array, jax.Array]:
        """Full-sequence forward (training). Returns (logits_fn input x, aux).

        Note: returns the *pre-head* activations; loss() applies the head in
        chunks to bound the logits materialization.
        """
        x = self.embed(params, batch)
        bsz, s = x.shape[:2]
        positions = jnp.broadcast_to(jnp.arange(s)[None, :], (bsz, s))
        x, _, aux = self.trunk(params, x, positions=positions, batch=batch,
                               opts=opts, remat=remat)
        return x, aux

    def loss(self, params: PyTree, batch: dict, *,
             opts: B.BlockOpts = B.BlockOpts(), remat: str = "none"
             ) -> tuple[jax.Array, dict]:
        cfg = self.cfg
        x, aux = self.forward(params, batch, opts=opts, remat=remat)
        if cfg.family == "encoder":
            labels = batch["labels"]
            valid = jnp.ones_like(labels, dtype=bool)
        else:
            tok = batch["tokens"]
            labels = jnp.concatenate(
                [tok[:, 1:], jnp.zeros_like(tok[:, :1])], axis=1)
            valid = jnp.concatenate(
                [jnp.ones_like(tok[:, 1:], bool),
                 jnp.zeros_like(tok[:, :1], bool)], axis=1)
        ce, n_tok = self._chunked_ce(params, x, labels, valid, opts)
        loss = ce / jnp.maximum(n_tok, 1.0)
        total = loss + 0.01 * aux
        return total, {"ce": loss, "aux": aux, "tokens": n_tok}

    def _chunked_ce(self, params, x, labels, valid, opts):
        """Cross-entropy with seq-chunked logits (never materializes B,S,V)."""
        bsz, s, d = x.shape
        chunk = min(CE_CHUNK_SEQ, s)
        n = s // chunk if s % chunk == 0 else 1
        chunk = s // n
        xs = jnp.moveaxis(x.reshape(bsz, n, chunk, d), 1, 0)
        ls = jnp.moveaxis(labels.reshape(bsz, n, chunk), 1, 0)
        vs = jnp.moveaxis(valid.reshape(bsz, n, chunk), 1, 0)

        def body(carry, inp):
            ce_sum, tok_sum = carry
            xc, lc, vc = inp
            logits = self.logits(params, xc, opts)          # (B,chunk,V) f32
            logz = jax.nn.logsumexp(logits, axis=-1)
            gold = jnp.take_along_axis(logits, lc[..., None],
                                       axis=-1)[..., 0]
            ce = jnp.where(vc, logz - gold, 0.0)
            return (ce_sum + ce.sum(), tok_sum + vc.sum()), None

        # checkpoint: logits recompute in backward — never stored as
        # per-chunk scan residuals (B,chunk,V f32 would dominate memory)
        (ce, n_tok), _ = lax.scan(
            jax.checkpoint(body,
                           policy=jax.checkpoint_policies.nothing_saveable),
            (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
            (xs, ls, vs))
        return ce, n_tok

    # -- caches ----------------------------------------------------------------

    def _cache_tree(self, batch: int, seq_len: int, make_leaf,
                    kv_quantize: str | None = None,
                    paged=None) -> PyTree:
        cfg = self.cfg
        f = cfg.family
        dt = self.dtype
        def kv(n=None, inner=None):
            spec = B.block_cache_spec(cfg, batch, seq_len, dt, kv_quantize,
                                      paged)
            lead = tuple(d for d in (n, inner) if d is not None)
            tree = jax.tree.map(
                lambda s: make_leaf((*lead, *s.shape), s.dtype), spec)
            if paged is not None and "block_tables" in tree:
                bt = tree["block_tables"]
                if not isinstance(bt, jax.ShapeDtypeStruct):
                    # unallocated table rows must alias the dummy block,
                    # never physical block 0 (zeros would)
                    tree["block_tables"] = jnp.full(
                        bt.shape, paged.dummy_block, bt.dtype)
            return tree
        if f in ("dense", "moe"):
            out = {"blocks": kv(cfg.num_layers - cfg.moe_first_dense)}
            if f == "moe" and cfg.moe_first_dense:
                out["first"] = kv()
            return out
        if f == "vlm":
            t_img = cfg.num_image_tokens
            hd = cfg.resolved_head_dim
            kvshape = (self.n_super, batch, t_img, cfg.num_kv_heads, hd)
            return {
                "self": kv(self.n_super, self.n_self_per),
                "cross_kv": {"k": make_leaf(kvshape, dt),
                             "v": make_leaf(kvshape, dt)},
            }
        dims = ssm_mod.dims_from_config(cfg)
        sspec = ssm_mod.ssm_state_spec(batch, dims, dt)
        states = jax.tree.map(
            lambda s: make_leaf((cfg.num_layers, *s.shape), s.dtype), sspec)
        if f == "ssm":
            return {"blocks": states}
        if f == "hybrid":
            return {"blocks": states,
                    "shared": jax.tree.map(
                        lambda s: make_leaf((self.n_groups, *s.shape),
                                            s.dtype),
                        B.block_cache_spec(cfg, batch, seq_len, dt,
                                           kv_quantize))}
        raise ValueError(f)

    def cache_spec(self, batch: int, seq_len: int,
                   kv_quantize: str | None = None, paged=None) -> PyTree:
        return self._cache_tree(batch, seq_len, jax.ShapeDtypeStruct,
                                kv_quantize, paged)

    def init_cache(self, batch: int, seq_len: int,
                   kv_quantize: str | None = None, paged=None) -> PyTree:
        """For paged pools ``batch``/``seq_len`` are the leaf geometry
        ``(num_blocks + 1, block_size)``; block-table leaves take their
        ``(slots, blocks_per_slot)`` shape from the geometry and
        initialize to the dummy block."""
        return self._cache_tree(batch, seq_len,
                                lambda s, d: jnp.zeros(s, d), kv_quantize,
                                paged)

    def cache_plan(self, kv_quantize: str | None = None, paged=None
                   ) -> cache_mod.CachePlan:
        """The per-attention-layer :class:`repro.layers.cache.CachePlan`
        (one geometry for all of this model's attention layers)."""
        return cache_mod.build_cache_plan(self.cfg, self.dtype, kv_quantize,
                                          paged)

    def cache_plans(self, kv_quantize: str | None = None, paged=None
                    ) -> list[cache_mod.CachePlan]:
        """One plan per cached attention layer — the declarative source
        the serve pool and roofline derive ALL byte accounting from
        (recurrent SSM state is not a per-token KV stream: no plans)."""
        cfg = self.cfg
        f = cfg.family
        if f in ("dense", "moe"):
            n = cfg.num_layers
        elif f == "vlm":
            n = self.n_super * self.n_self_per
        elif f == "hybrid":
            n = self.n_groups
        else:                     # ssm / encoder: no attention KV pools
            return []
        return [self.cache_plan(kv_quantize, paged)] * n

    # -- prefill / decode -------------------------------------------------------

    def prefill(self, params: PyTree, batch: dict, cache: PyTree, *,
                last_pos: jax.Array | None = None, cache_plan=None,
                opts: B.BlockOpts = B.BlockOpts()
                ) -> tuple[jax.Array, PyTree]:
        """Fill the cache with a full prompt; returns (last-pos logits, cache).

        ``last_pos`` (scalar) is the index of the prompt's final *real*
        token — pass it when the prompt is right-padded (e.g. the serve
        engine's power-of-2 length buckets) so the returned logits are
        the real last token's, not the pad tail's.  Causal attention
        already keeps padded positions from influencing real ones
        (recurrent/MoE-capacity families must prefill unpadded — the
        engine does not bucket them), and the trunk masks pad positions
        out of the quantized-KV scale reduction.
        """
        x = self.embed(params, batch)
        bsz, s = x.shape[:2]
        positions = jnp.broadcast_to(jnp.arange(s)[None, :], (bsz, s))
        prompt_len = None if last_pos is None else last_pos + 1
        x, new_cache, _ = self.trunk(params, x, positions=positions,
                                     cache=cache, batch=batch, opts=opts,
                                     prompt_len=prompt_len,
                                     cache_plan=cache_plan)
        if last_pos is None:
            xl = x[:, -1:, :]
        else:
            xl = lax.dynamic_slice_in_dim(x, last_pos, 1, axis=1)
        logits = self.logits(params, xl, opts)
        return logits, new_cache

    def prefill_chunk(self, params: PyTree, batch: dict, cache: PyTree, *,
                      start_pos: jax.Array, prompt_len: jax.Array,
                      cache_plan=None, opts: B.BlockOpts = B.BlockOpts()
                      ) -> tuple[jax.Array, PyTree]:
        """Continue a prefill one chunk at a time (continuous batching).

        ``batch["tokens"]`` (1, C) holds prompt positions
        ``[start_pos, start_pos + C)`` of a prompt whose
        ``[0, start_pos)`` K/V prefix is already written into ``cache``;
        the chunk's K/V lands at the offset and attention covers the
        whole causal prefix, so running a prompt chunk-by-chunk writes
        a cache (and produces last-token logits) identical to one-shot
        :meth:`prefill`.  The chunk may be right-padded (length
        bucketing): pass ``prompt_len`` as the chunk's real *end*
        position — ``min(prompt length, start_pos + real chunk len)`` —
        and pad rows beyond it are zeroed at the K/V write, so they can
        never corrupt mid-prompt positions or int8 scales, and
        causality hides them from every real query.

        Returns ``(logits, cache)`` with logits (1, 1, V) taken at the
        prompt's last *real* position when it falls inside this chunk
        (the final chunk; callers ignore the value for earlier chunks,
        where it is clamped to the chunk's last row).

        Attention-cached families only — recurrent state (SSM/hybrid)
        advances through pad tokens and MoE capacity routing is not
        pad-inert, so the serve scheduler prefills those families whole.
        """
        x = self.embed(params, batch)
        bsz, c = x.shape[:2]
        positions = jnp.broadcast_to(
            start_pos + jnp.arange(c)[None, :], (bsz, c))
        x, new_cache, _ = self.trunk(params, x, positions=positions,
                                     cache=cache, batch=batch, opts=opts,
                                     prompt_len=prompt_len,
                                     start_pos=start_pos,
                                     cache_plan=cache_plan)
        lp = jnp.clip(prompt_len - 1 - start_pos, 0, c - 1)
        xl = lax.dynamic_slice_in_dim(x, lp, 1, axis=1)
        logits = self.logits(params, xl, opts)
        return logits, new_cache

    def decode_step(self, params: PyTree, tokens: jax.Array,
                    positions: jax.Array, cache: PyTree, *,
                    cache_plan=None, opts: B.BlockOpts = B.BlockOpts()
                    ) -> tuple[jax.Array, PyTree]:
        """One token per sequence. tokens (B,1); positions (B,) absolute."""
        cfg = self.cfg
        if cfg.family == "encoder":
            raise ValueError("encoder-only model has no decode step")
        batch = {"tokens": tokens}
        x = self.embed(params, batch)
        pos2d = positions[:, None]
        x, new_cache, _ = self.trunk(params, x, positions=pos2d,
                                     cache=cache, cache_pos=positions,
                                     batch=batch, opts=opts,
                                     cache_plan=cache_plan)
        logits = self.logits(params, x, opts)
        return logits, new_cache

"""Bottleneck ResNet-50/101/152 — the paper's own benchmark architectures.

Built on the same ``(params, axes)`` trees and the conv/linear dispatch
seams, so the LRD surgery (SVD on 1x1 convs + fc, Tucker-2 on 3x3 convs)
applies unchanged — this is the model Tables 1 and 3-6 of the paper are
measured on.

Norms are per-channel scale/bias ("frozen-stats batch norm"): the paper
fine-tunes from a pre-trained model, where folding BN running stats into
scale/bias is standard; it also keeps :func:`merge_bottleneck` exact.

``merge_bottleneck`` implements the paper's Fig. 3 layer merging: after
Tucker-decomposing the 3x3 conv, its U factor is absorbed into the
preceding 1x1 conv and its V factor into the following 1x1 conv, restoring
the original layer count.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.layers.conv import apply_conv, init_conv
from repro.layers.param import ParamBuilder, apply_linear, init_linear, EMBED, VOCAB
from repro.core import merging

PyTree = Any


def _stage_widths(cfg: ModelConfig) -> list[tuple[int, int, int]]:
    w = cfg.resnet_width
    return [(w * 2**i, w * 2**i * 4, 1 if i == 0 else 2)
            for i in range(len(cfg.resnet_stage_blocks))]


def analytic_param_count(cfg: ModelConfig) -> int:
    total = 3 * cfg.resnet_width * 49 + cfg.resnet_width * 2   # stem + norm
    c_in = cfg.resnet_width
    for (mid, out, _), n in zip(_stage_widths(cfg), cfg.resnet_stage_blocks):
        for b in range(n):
            total += c_in * mid + mid * mid * 9 + mid * out
            total += 2 * (mid + mid + out)
            if b == 0:
                total += c_in * out + 2 * out
            c_in = out
    total += c_in * cfg.num_classes + cfg.num_classes
    return total


class ResNetModel:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.dtype = jnp.dtype(cfg.dtype)

    # -- init -----------------------------------------------------------------

    def init(self, key: jax.Array) -> tuple[PyTree, PyTree]:
        cfg = self.cfg
        pb = ParamBuilder(key, self.dtype)
        init_conv(pb, "stem", 3, cfg.resnet_width, 7)
        self._init_norm(pb, "stem_norm", cfg.resnet_width)
        c_in = cfg.resnet_width
        for si, ((mid, out, _), n) in enumerate(
                zip(_stage_widths(cfg), cfg.resnet_stage_blocks)):
            stage = pb.child(f"stage{si}")
            for bi in range(n):
                blk = stage.child(f"block{bi}")
                init_conv(blk, "conv1", c_in, mid, 1)
                self._init_norm(blk, "norm1", mid)
                init_conv(blk, "conv2", mid, mid, 3)
                self._init_norm(blk, "norm2", mid)
                init_conv(blk, "conv3", mid, out, 1)
                self._init_norm(blk, "norm3", out)
                if bi == 0:
                    init_conv(blk, "downsample", c_in, out, 1)
                    self._init_norm(blk, "ds_norm", out)
                c_in = out
        init_linear(pb, "fc", c_in, cfg.num_classes, EMBED, VOCAB)
        pb.param("fc_bias", (cfg.num_classes,), (VOCAB,), init="zeros")
        return pb.params, pb.axes

    @staticmethod
    def _init_norm(pb: ParamBuilder, name: str, dim: int) -> None:
        sub = pb.child(name)
        sub.param("scale", (dim,), (EMBED,), init="ones")
        sub.param("bias", (dim,), (EMBED,), init="zeros")

    @staticmethod
    def _norm(p: dict, x: jax.Array) -> jax.Array:
        return x * p["scale"][None, None, None, :] \
            + p["bias"][None, None, None, :]

    # -- forward ----------------------------------------------------------------

    def forward(self, params: PyTree, images: jax.Array, *,
                freeze_factors: bool = False) -> jax.Array:
        cfg = self.cfg
        kw = dict(freeze_factors=freeze_factors)
        x = apply_conv(params["stem"], images, stride=2, **kw)
        x = jax.nn.relu(self._norm(params["stem_norm"], x))
        x = jax.lax.reduce_window(
            x, -jnp.inf, jax.lax.max, (1, 3, 3, 1), (1, 2, 2, 1),
            "SAME")
        for si, ((mid, out, stride), n) in enumerate(
                zip(_stage_widths(cfg), cfg.resnet_stage_blocks)):
            stage = params[f"stage{si}"]
            for bi in range(n):
                blk = stage[f"block{bi}"]
                s = stride if bi == 0 else 1
                h = apply_conv(blk["conv1"], x, stride=1, **kw)
                h = jax.nn.relu(self._norm(blk["norm1"], h))
                h = apply_conv(blk["conv2"], h, stride=s, **kw)
                h = jax.nn.relu(self._norm(blk["norm2"], h))
                h = apply_conv(blk["conv3"], h, stride=1, **kw)
                h = self._norm(blk["norm3"], h)
                if "downsample" in blk:
                    x = apply_conv(blk["downsample"], x, stride=s, **kw)
                    x = self._norm(blk["ds_norm"], x)
                x = jax.nn.relu(x + h)
        x = jnp.mean(x, axis=(1, 2))
        logits = apply_linear(params["fc"], x, freeze_factors=freeze_factors,
                              accum_dtype=jnp.float32)
        return logits.astype(jnp.float32) + params["fc_bias"]

    def loss(self, params: PyTree, batch: dict, **kw) -> tuple[jax.Array, dict]:
        logits = self.forward(params, batch["images"],
                              freeze_factors=kw.get("freeze_factors", False))
        labels = batch["labels"]
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
        loss = jnp.mean(logz - gold)
        acc = jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
        return loss, {"ce": loss, "acc": acc}

    def layer_count(self, params: PyTree) -> int:
        """Weighted-layer count as the paper counts it (convs + fc)."""
        count = 0
        def visit(p):
            nonlocal count
            if isinstance(p, dict):
                keys = set(p)
                if keys & {"w"} and p["w"].ndim >= 2:
                    count += 1
                elif "tucker_u" in keys:
                    count += 3
                elif "core" in keys and "u" in keys:
                    count += 3
                elif "w0" in keys:
                    count += 2
                elif "u" in keys and "xc" in keys:
                    count += 3
                else:
                    for v in p.values():
                        visit(v)
        visit(params)
        return count


def merge_bottleneck(params: PyTree) -> PyTree:
    """Paper §2.3 / Fig. 3: absorb Tucker 1x1 factors into the neighbouring
    1x1 convs of every bottleneck, restoring the original layer count.

    Expects conv2 subtrees decomposed as {"tucker_u","core","tucker_v"};
    conv1/conv3 must still be dense.  Returns a rewritten tree where

        conv1' = conv1 @ U,  conv2' = core,  conv3' = V @ conv3.
    """
    import copy
    out = copy.deepcopy(jax.tree.map(lambda x: x, params))
    for sk, stage in out.items():
        if not (isinstance(stage, dict) and sk.startswith("stage")):
            continue
        for bk, blk in stage.items():
            if not (isinstance(blk, dict) and "conv2" in blk):
                continue
            c2 = blk["conv2"]
            if "tucker_u" not in c2:
                continue
            assert "w" in blk["conv1"] and "w" in blk["conv3"], \
                "merging needs dense 1x1 neighbours"
            blk["conv1"] = {"w": merging.merge_conv1x1_into_u(
                blk["conv1"]["w"], c2["tucker_u"])}
            blk["conv3"] = {"w": merging.merge_v_into_conv1x1(
                c2["tucker_v"], blk["conv3"]["w"])}
            # norm1 now lives in the R1 basis: reset to identity scale of R1
            r1 = c2["core"].shape[-2]
            r2 = c2["core"].shape[-1]
            dt = c2["core"].dtype
            blk["norm1"] = {"scale": jnp.ones((r1,), dt),
                            "bias": jnp.zeros((r1,), dt)}
            blk["norm2"] = {"scale": jnp.ones((r2,), dt),
                            "bias": jnp.zeros((r2,), dt)}
            blk["conv2"] = {"w": c2["core"]}
    return out

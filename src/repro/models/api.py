"""Model construction + input specs (the ShapeDtypeStruct seam).

``input_specs(cfg, shape)`` returns stand-ins for every model input of an
(architecture x shape) cell: weak-type-correct, shardable, no device
allocation — the dry-run lowers ``train_step`` / ``serve_step`` against
these.  Modality frontends are STUBS per the assignment: ``[audio]``
provides precomputed frame embeddings, ``[vlm]`` precomputed patch
embeddings.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig


def get_model(cfg: ModelConfig):
    if cfg.family == "resnet":
        from repro.models.resnet import ResNetModel
        return ResNetModel(cfg)
    from repro.models.lm import LMModel
    return LMModel(cfg)


def input_specs(cfg: ModelConfig, shape: ShapeConfig,
                *, with_labels: bool = True) -> dict[str, Any]:
    """ShapeDtypeStructs for one (arch x shape) cell's step inputs."""
    sds = jax.ShapeDtypeStruct
    b, s = shape.global_batch, shape.seq_len
    dt = jnp.dtype(cfg.dtype)

    if cfg.family == "resnet":
        out = {"images": sds((b, cfg.img_size, cfg.img_size, 3), dt)}
        if with_labels:
            out["labels"] = sds((b,), jnp.int32)
        return out

    if shape.kind == "decode":
        return {"tokens": sds((b, 1), jnp.int32),
                "positions": sds((b,), jnp.int32)}

    if cfg.family == "encoder":
        out = {"frames": sds((b, s, cfg.frontend_dim or cfg.d_model), dt)}
        if with_labels:
            out["labels"] = sds((b, s), jnp.int32)
        return out

    out = {"tokens": sds((b, s), jnp.int32)}
    if cfg.family == "vlm":
        out["image_embeds"] = sds(
            (b, cfg.num_image_tokens, cfg.vision_d_model or cfg.d_model), dt)
    return out


def synth_inputs(cfg: ModelConfig, shape: ShapeConfig, key: jax.Array,
                 *, with_labels: bool = True) -> dict[str, jax.Array]:
    """Random concrete inputs matching :func:`input_specs` (smoke tests)."""
    specs = input_specs(cfg, shape, with_labels=with_labels)
    out = {}
    for name, spec in specs.items():
        key, sub = jax.random.split(key)
        if jnp.issubdtype(spec.dtype, jnp.integer):
            hi = cfg.num_classes if cfg.family == "resnet" else cfg.vocab_size
            if name == "positions":
                hi = shape.seq_len - 1
            out[name] = jax.random.randint(sub, spec.shape, 0, hi,
                                           dtype=spec.dtype)
        else:
            out[name] = jax.random.normal(sub, spec.shape,
                                          jnp.float32).astype(spec.dtype) * 0.2
    return out

"""KVPoolManager: slot + KV-byte accounting over the serve cache pool.

The pool is the model's stacked cache pytree laid out
``(..., B_slots, S_max, ...)`` — one batch slot per in-flight stream,
any :class:`repro.layers.cache.CachePlan` family (full-width or int8
GQA K/V, full-width or int8 MLA latents).  This manager owns the state
side of the serve stack:

* the cache pytree itself plus the per-slot write positions,
* slot allocation with admission *tickets* (monotone age — KV-pressure
  preemption evicts the youngest stream first),
* byte accounting, derived from the model's cache plans —
  ``CachePlan.bytes_per_token`` / ``bytes_per_step`` are the single
  source of truth, so new cache families (the int8 MLA latent pool,
  and whatever comes next) are costed automatically instead of being
  silently undercounted by hand-maintained key lists.
  ``used_bytes()`` weights per-token bytes by each occupied slot's
  logical occupancy, an optional ``byte_budget`` gates admission
  (:meth:`can_admit`) and drives preemption (:meth:`pressure_victims`),
  and ``kv_bytes_per_step`` is the roofline's full-pool decode read,
* the slot scatter (:meth:`insert`): a batch=1 stream cache lands in
  its slot in one jitted donate-argnums call, masking the right-padded
  prompt tail — and quantizing a full-precision chunked-prefill staging
  cache into an int8 pool on the fly (``from_full_precision=True``).

Compute never lives here (that is :class:`repro.serve.runner.
ModelRunner`); policy never lives here (that is
:class:`repro.serve.scheduler.Scheduler`).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.layers import cache as cache_mod
from repro.quant import kv as kvq
from repro.serve import paging
from repro.serve.faults import NULL_INJECTOR
from repro.serve.paging import PoolExhausted

PyTree = Any


class IntegrityError(AssertionError):
    """A pool invariant does not hold (refcounts vs block tables, free
    list disjointness, byte accounting).  Raised by
    ``check_integrity()`` — the oracle every lifecycle/chaos test runs
    after each mutation, and the engine runs per step under
    ``debug=True``."""


def _corrupt_scale_leaf(cache: PyTree, index: int) -> PyTree:
    """Fault-injection helper (``block_scale``): set one stream's /
    block's row of the FIRST ``*_scale`` leaf to ``+inf`` — the
    signature of a corrupted quantized block.  Dequantized KV goes
    non-finite, the next step's logits go NaN, and the numerical
    watchdog must quarantine exactly that stream.  ``index`` is a slot
    (slot pool: scales ``(..., B, KH, D)`` / ``(..., B, r)``) or a
    physical block id (paged pool — same tail ranks)."""
    state = {"done": False}

    def leaf(path, x):
        key = str(getattr(path[-1], "key", path[-1]))
        if state["done"] or not key.endswith("_scale"):
            return x
        state["done"] = True
        ax = x.ndim - 3 if key in ("k_scale", "v_scale") else x.ndim - 2
        ix = (slice(None),) * ax + (index,)
        return x.at[ix].set(jnp.inf)

    return jax.tree_util.tree_map_with_path(leaf, cache)


class KVPoolManager:
    """Slot/byte owner for one engine's KV pool."""

    # Sequence-axis position of per-position cache leaves, by key —
    # shared with the plans (layers/cache.py owns the map).  Leaves
    # without an entry (scales, SSM states, cross-attn image KV) have
    # no prompt-length axis to mask.
    _SEQ_AXIS = cache_mod.SEQ_AXIS

    def __init__(self, model, slots: int, max_seq: int, *,
                 kv_quantize: str | None = None,
                 byte_budget: int | None = None):
        self.model = model
        self.slots = slots
        self.max_seq = max_seq
        self.kv_quantize = kv_quantize
        self.byte_budget = byte_budget
        self.cache = model.init_cache(slots, max_seq,
                                      kv_quantize=kv_quantize)
        self.positions = np.zeros((slots,), np.int32)   # next write pos
        self.lengths = np.zeros((slots,), np.int64)     # logical KV tokens
        self.tickets = np.full((slots,), -1, np.int64)  # admission age; -1 free
        self._next_ticket = 0
        #: fault source (inert by default; the engine threads its
        #: injector in)
        self.faults = NULL_INJECTOR

        #: one CachePlan per cached attention layer — the declarative
        #: source of ALL byte accounting (empty for recurrent models).
        self.plans = model.cache_plans(kv_quantize)
        #: per-position KV bytes of ONE stream across all layers
        self.bytes_per_token = sum(p.bytes_per_token for p in self.plans)
        #: HBM bytes the whole pool streams per decode step (masked,
        #: not skipped — every slot's full S_max is read).  VLM
        #: cross-attn image KV is a per-image constant stream outside
        #: the per-token plans; it is read every step too.
        self.kv_bytes_per_step = sum(
            p.bytes_per_step(slots, max_seq) for p in self.plans)
        for path, leaf in jax.tree_util.tree_flatten_with_path(
                self.cache)[0]:
            if any(str(getattr(p, "key", p)) == "cross_kv" for p in path):
                self.kv_bytes_per_step += leaf.size * leaf.dtype.itemsize

        self._jit_insert = jax.jit(self._insert_slot, donate_argnums=(0,))
        self._jit_insert_q = jax.jit(self._insert_slot_quantizing,
                                     donate_argnums=(0,))

    # -- slot bookkeeping ---------------------------------------------------

    def free_slots(self) -> list[int]:
        return [i for i in range(self.slots) if self.tickets[i] < 0]

    def occupied_slots(self) -> list[int]:
        return [i for i in range(self.slots) if self.tickets[i] >= 0]

    def allocate(self, slot: int, length: int,
                 tokens: list[int] | None = None) -> int:
        """Reserve ``slot`` for a stream of ``length`` prompt tokens.
        The full prompt's bytes are reserved up front, so admission
        cannot overshoot the budget mid-prefill.  ``tokens`` is the
        prompt itself — unused here; the paged pool prefix-matches it.
        Returns the number of leading prompt tokens whose KV is already
        pooled (always 0 for the slot layout)."""
        del tokens
        assert self.tickets[slot] < 0, slot
        if self.faults.fire("pool_alloc"):
            raise PoolExhausted("injected: pool_alloc (slot pool)")
        self.tickets[slot] = self._next_ticket
        self._next_ticket += 1
        self.lengths[slot] = length
        self.positions[slot] = 0
        return 0

    def grow(self, slot: int, n: int = 1,
             token: int | None = None) -> None:
        """Account ``n`` decoded tokens of KV growth for ``slot``
        (``token`` — the id whose KV just landed — matters only to the
        paged pool's prefix registry)."""
        del token
        self.positions[slot] += n
        self.lengths[slot] += n

    def release(self, slot: int, publish: bool = True) -> None:
        """Free ``slot``.  ``publish`` exists for surface parity with
        the paged pool (which registers released blocks in its radix);
        the slot layout shares nothing, so it is a no-op here."""
        del publish
        self.tickets[slot] = -1
        self.lengths[slot] = 0
        self.positions[slot] = 0

    # -- invariants ---------------------------------------------------------

    def check_integrity(self) -> bool:
        """Cross-validate the slot pool's invariants; raises
        :class:`IntegrityError` on the first violation, returns True
        when everything holds.  The oracle behind the engine's
        ``debug=`` flag and the lifecycle/chaos tests."""
        errs: list[str] = []
        occ = self.occupied_slots()
        for s in range(self.slots):
            if self.tickets[s] < 0:
                if self.lengths[s] or self.positions[s]:
                    errs.append(
                        f"free slot {s} holds state "
                        f"(len={self.lengths[s]}, pos={self.positions[s]})")
            else:
                if not 0 <= self.positions[s] <= self.max_seq:
                    errs.append(
                        f"slot {s} position {self.positions[s]} out of "
                        f"[0, {self.max_seq}]")
                if not 0 <= self.lengths[s] <= self.max_seq:
                    errs.append(
                        f"slot {s} length {self.lengths[s]} out of "
                        f"[0, {self.max_seq}]")
        tickets = [int(self.tickets[s]) for s in occ]
        if len(set(tickets)) != len(tickets):
            errs.append(f"duplicate admission tickets: {tickets}")
        recomputed = int(sum(int(self.lengths[s]) for s in occ)
                         * self.bytes_per_token)
        if recomputed != self.used_bytes():
            errs.append(
                f"used_bytes {self.used_bytes()} != occupied-slot "
                f"recomputation {recomputed}")
        if errs:
            raise IntegrityError("; ".join(errs))
        return True

    # -- byte budget --------------------------------------------------------

    def used_bytes(self) -> int:
        return int(self.lengths.sum() * self.bytes_per_token)

    def capacity_bytes(self) -> int:
        """Bytes this pool can hold: the byte budget when one is set,
        else the physical pool (every slot full).  0 for plans with no
        per-position bytes (recurrent state) — callers fall back to
        slot-count occupancy."""
        physical = self.slots * self.max_seq * self.bytes_per_token
        if self.byte_budget is not None:
            return min(self.byte_budget, physical) if physical else \
                self.byte_budget
        return physical

    def prefix_affinity(self, tokens: list[int] | None) -> int:
        """Leading prompt tokens whose KV this pool already holds.
        Always 0 for the slot layout (no cross-request reuse) — the
        router's routing signal, overridden by the paged pool."""
        del tokens
        return 0

    def can_admit(self, prompt_len: int,
                  tokens: list[int] | None = None) -> bool:
        """Admission gate: does a ``prompt_len``-token stream fit the
        byte budget?  An empty pool always admits (otherwise a single
        over-budget prompt could deadlock the queue)."""
        del tokens
        if self.byte_budget is None or self.bytes_per_token == 0:
            return True
        if not self.occupied_slots():
            return True
        projected = self.used_bytes() + prompt_len * self.bytes_per_token
        return projected <= self.byte_budget

    def pressure_victims(self) -> list[int]:
        """Slots to preempt, youngest ticket first, until the pool is
        back under its byte budget.  At least one stream always
        survives — pressure sheds load, it never empties the pool."""
        if self.byte_budget is None or self.bytes_per_token == 0:
            return []
        occ = sorted(self.occupied_slots(), key=lambda s: self.tickets[s])
        victims: list[int] = []
        used = self.used_bytes()
        while used > self.byte_budget and len(occ) > 1:
            s = occ.pop()                      # youngest admission
            victims.append(s)
            used -= int(self.lengths[s] * self.bytes_per_token)
        return victims

    # -- slot scatter -------------------------------------------------------

    @classmethod
    def _insert_slot(cls, cache: PyTree, cache1: PyTree, slot: jax.Array,
                     length: jax.Array) -> PyTree:
        """Scatter a batch=1 cache into slot ``slot`` of the pool.

        Batch dim = the dim where pool and single differ (single == 1).
        ``length`` is the prompt's real token count: bucketed prefill
        right-pads the prompt, so positions ``>= length`` of the
        per-position leaves are zeroed before the scatter (int8 pools
        then dequantize the tail to exact zero; decode overwrites each
        position before it ever becomes attendable either way).
        """
        def leaf(path, pool, one):
            keys = [str(getattr(p, "key", p)) for p in path]
            ax = None if "cross_kv" in keys else cls._SEQ_AXIS.get(keys[-1])
            if ax is not None:
                idx = jnp.arange(one.shape[ax])
                mask = (idx < length).reshape(idx.shape + (1,) * (-ax - 1))
                one = jnp.where(mask, one, jnp.zeros_like(one))
            diff = [i for i, (a, b) in
                    enumerate(zip(pool.shape, one.shape)) if a != b]
            if not diff:                 # slots == 1: whole-pool replace
                return one.astype(pool.dtype)
            start = [0] * pool.ndim
            start[diff[0]] = slot
            return jax.lax.dynamic_update_slice(
                pool, one.astype(pool.dtype), tuple(start))
        return jax.tree_util.tree_map_with_path(leaf, cache, cache1)

    @classmethod
    def _insert_slot_quantizing(cls, cache: PyTree, cache1: PyTree,
                                slot: jax.Array, length: jax.Array) -> PyTree:
        """Insert a *full-precision* staging cache into an int8 pool:
        quantize (one-shot scales over the real prompt, pad masked) and
        scatter in the same compiled call — the pool never sees a
        full-width copy in between."""
        return cls._insert_slot(cache, kvq.quantize_kv_tree(cache1, length),
                                slot, length)

    def insert(self, cache1: PyTree, slot: int, length: int, *,
               from_full_precision: bool = False) -> None:
        """Land a finished stream cache in its pool slot (one jitted
        call; the old pool buffer is donated)."""
        fn = (self._jit_insert_q
              if (self.kv_quantize and from_full_precision)
              else self._jit_insert)
        self.cache = fn(self.cache, cache1, jnp.asarray(slot, jnp.int32),
                        jnp.asarray(length, jnp.int32))
        self.positions[slot] = length
        self.lengths[slot] = length
        if self.kv_quantize and self.faults.fire("block_scale"):
            self.cache = _corrupt_scale_leaf(self.cache, slot)


class PagedKVPoolManager:
    """Block-granular pool: the engine-facing :class:`KVPoolManager`
    surface backed by a :class:`repro.serve.paging.BlockPool`, per-slot
    block tables, and a radix prefix cache.

    Device state is the paged cache pytree — K/V leaves
    ``(num_blocks + 1, block_size, ...)`` plus per-layer
    ``(slots, blocks_per_slot)`` int32 block tables (physical id
    ``num_blocks`` is the reserved dummy; idle table entries alias it).
    Host state is the refcounted :class:`BlockPool`, each slot's block
    table and token list (exactly the tokens whose KV is pooled —
    prompt at insert, +1 per decode step), and the usual
    positions/lengths/tickets arrays.

    Lifecycle vs the slot pool:

    * :meth:`allocate` radix-matches the prompt (capped at
      ``length - 1`` so at least one token always re-prefills — the
      engine needs its logits), retains matched blocks read-only, and
      allocates fresh blocks past the divergence point;
    * the engine gathers the matched prefix into the stream's staging
      cache (:meth:`gather_prefix`) and chunk-prefills only the
      suffix;
    * :meth:`insert` re-matches against the radix first (adoption
      dedup: a concurrent identical prompt may have registered the
      same blocks since admission — ours are released, theirs
      retained), registers the stream's remaining full prompt blocks
      first-writer-wins, then scatters the staged KV into the blocks
      the stream still owns (int8 pools quantize per block on the
      way in — one scale row per block, blocked with its values);
    * :meth:`release` publishes the stream's *generated* full blocks
      to the radix too (a preempted stream resumes by re-matching its
      own blocks — near-zero recompute, deterministic under greedy)
      and drops all references: unreferenced registered blocks go
      cold (LRU-recyclable), unregistered ones free.

    ``used_bytes`` counts referenced (ref > 0) physical blocks — the
    block-granular byte accounting the ISSUE's preemption policy runs
    on: shared prefix bytes are counted once, not per stream.
    """

    _SEQ_AXIS = cache_mod.SEQ_AXIS

    def __init__(self, model, slots: int, max_seq: int, *,
                 kv_quantize: str | None = None,
                 byte_budget: int | None = None,
                 block_size: int = paging.DEFAULT_BLOCK_SIZE,
                 num_blocks: int | None = None):
        if max_seq % block_size:
            raise ValueError(
                f"max_seq {max_seq} must be a multiple of the KV block "
                f"size {block_size}")
        bpslot = max_seq // block_size
        if num_blocks is None:
            num_blocks = slots * bpslot
        if num_blocks < bpslot:
            raise ValueError(
                f"num_blocks {num_blocks} cannot cover one full stream "
                f"({bpslot} blocks)")
        self.model = model
        self.slots = slots
        self.max_seq = max_seq
        self.kv_quantize = kv_quantize
        self.byte_budget = byte_budget
        self.block_size = block_size
        self.num_blocks = num_blocks
        self.blocks_per_slot = bpslot
        self.geometry = cache_mod.PagedGeometry(block_size, num_blocks,
                                                slots, bpslot)

        self.cache = model.init_cache(num_blocks + 1, block_size,
                                      kv_quantize=kv_quantize,
                                      paged=self.geometry)
        self.positions = np.zeros((slots,), np.int32)   # next write pos
        self.lengths = np.zeros((slots,), np.int64)     # logical KV tokens
        self.tickets = np.full((slots,), -1, np.int64)  # admission age
        self._next_ticket = 0

        self.faults = NULL_INJECTOR
        self.blocks = paging.BlockPool(num_blocks, block_size)
        self.tables: list[list[int]] = [[] for _ in range(slots)]
        self.tokens: list[list[int]] = [[] for _ in range(slots)]
        #: leading radix-adopted blocks per slot (read-only shares)
        self._shared: list[int] = [0] * slots
        #: fresh blocks replaced by a concurrent twin's at insert
        self.adoptions = 0

        self.plans = model.cache_plans(kv_quantize, paged=self.geometry)
        self.bytes_per_token = sum(p.bytes_per_token for p in self.plans)
        #: KV bytes of one physical block across all layers
        self.bytes_per_block = sum(p.bytes_per_block for p in self.plans)
        self.kv_bytes_per_step = sum(
            p.bytes_per_step(slots, max_seq) for p in self.plans)

        self._jit_table = jax.jit(self._table_update, donate_argnums=(0,))
        self._jit_gather = jax.jit(self._gather_prefix, donate_argnums=(0,),
                                   static_argnames=("block_size",))
        self._jit_insert = jax.jit(
            functools.partial(self._insert_blocks, quantize=False),
            donate_argnums=(0,), static_argnames=("block_size",))
        self._jit_insert_q = jax.jit(
            functools.partial(self._insert_blocks, quantize=True),
            donate_argnums=(0,), static_argnames=("block_size",))

    # -- slot bookkeeping ---------------------------------------------------

    def free_slots(self) -> list[int]:
        return [i for i in range(self.slots) if self.tickets[i] < 0]

    def occupied_slots(self) -> list[int]:
        return [i for i in range(self.slots) if self.tickets[i] >= 0]

    def allocate(self, slot: int, length: int,
                 tokens: list[int] | None = None) -> int:
        """Reserve ``slot`` for a ``length``-token prompt: attach to
        the radix-cached prefix (capped one block short of the whole
        prompt — the final token must re-prefill for its logits) and
        allocate fresh blocks covering positions ``[0, length]`` (the
        +1 is the first decode write).  Returns the matched token
        count — the engine skips prefilling that prefix.

        Exception-safe: if the fresh-block loop exhausts the pool
        (``can_admit`` is optimistic — a concurrent admission can win
        the race for the last cold block), every block retained or
        allocated so far is released before the
        :class:`~repro.serve.paging.PoolExhausted` propagates — no
        refcount leaks, no half-reserved slot."""
        assert self.tickets[slot] < 0, slot
        if self.faults.fire("pool_alloc"):
            raise PoolExhausted("injected: pool_alloc (paged admission)")
        toks = [int(t) for t in tokens] if tokens is not None else []
        if toks and self.faults.fire("radix_match"):
            toks_match = []        # injected: prefix reuse blind spot
        else:
            toks_match = toks
        matched = self.blocks.match_retain(toks_match,
                                           max_tokens=length - 1) \
            if toks_match else []
        table = list(matched)
        need = min(length // self.block_size + 1, self.blocks_per_slot)
        try:
            while len(table) < need:
                table.append(self.blocks.alloc())
        except PoolExhausted:
            for bid in table:      # matched retains AND fresh allocs
                self.blocks.release(bid)
            raise
        self.tickets[slot] = self._next_ticket
        self._next_ticket += 1
        self.lengths[slot] = length
        self.positions[slot] = 0
        self.tables[slot] = table
        self.tokens[slot] = toks[:length]
        self._shared[slot] = len(matched)
        # the device table row stays at the dummy until :meth:`insert`
        # activates the stream: decode steps scatter a garbage row for
        # every non-live slot at its position (0 here), and a published
        # table would route that write into a radix-shared block
        return len(matched) * self.block_size

    def grow(self, slot: int, n: int = 1,
             token: int | None = None) -> None:
        """Account ``n`` decoded tokens for ``slot`` (``token`` is the
        id whose KV the decode step just wrote — it extends the slot's
        token list so release can publish generated blocks).  Allocates
        the next block when the write position crosses into it.

        Atomic: fresh blocks are secured *before* any accounting
        mutates, so a :class:`~repro.serve.paging.PoolExhausted` (real
        or injected) leaves the slot exactly as it was — the engine
        preempts the stream and it resumes cleanly later."""
        need = min((int(self.positions[slot]) + n) // self.block_size + 1,
                   self.blocks_per_slot)
        fresh: list[int] = []
        try:
            if (len(self.tables[slot]) < need
                    and self.faults.fire("pool_alloc")):
                raise PoolExhausted("injected: pool_alloc (decode grow)")
            while len(self.tables[slot]) + len(fresh) < need:
                fresh.append(self.blocks.alloc())
        except PoolExhausted:
            for bid in fresh:
                self.blocks.release(bid)
            raise
        if token is not None:
            self.tokens[slot].append(int(token))
        self.positions[slot] += n
        self.lengths[slot] += n
        if fresh:
            self.tables[slot].extend(fresh)
            self._push_table(slot)

    def release(self, slot: int, publish: bool = True) -> None:
        """Free ``slot``: publish its full token blocks to the radix
        (prompt AND generated — a preempted request readmits onto its
        own blocks), drop every block reference, and point the device
        table row back at the dummy block.  ``publish=False`` skips the
        radix registration — quarantined streams must never donate a
        (possibly poisoned) cache to future prompts."""
        if publish and self.positions[slot] > 0:  # KV actually landed
            n_full = int(self.positions[slot]) // self.block_size
            n_full = min(n_full, len(self.tables[slot]))
            if n_full:
                self.blocks.register(
                    self.tokens[slot][:n_full * self.block_size],
                    self.tables[slot][:n_full])
        for bid in self.tables[slot]:
            self.blocks.release(bid)
        self.tables[slot] = []
        self.tokens[slot] = []
        self._shared[slot] = 0
        self.tickets[slot] = -1
        self.lengths[slot] = 0
        self.positions[slot] = 0
        self._push_table(slot)

    # -- invariants ---------------------------------------------------------

    def check_integrity(self) -> bool:
        """Cross-validate every paged-pool invariant; raises
        :class:`IntegrityError` on violation, returns True when all
        hold.

        * refcounts: ``blocks.ref[b]`` equals the number of block-table
          entries referencing ``b`` (every table entry holds exactly
          one reference — matched-retained or freshly allocated);
        * state partition: free, cold, and referenced block sets are
          disjoint and cover the pool; free blocks are unreferenced and
          not radix-registered; cold blocks are unreferenced AND
          registered;
        * byte accounting: ``used_bytes()`` equals the recomputed
          referenced-block count times ``bytes_per_block``;
        * slot state: free slots hold no table/tokens/length; occupied
          slots' shared-prefix count and token lists are in bounds.
        """
        errs: list[str] = []
        table_refs = [0] * self.num_blocks
        for s in range(self.slots):
            if self.tickets[s] < 0:
                if (self.tables[s] or self.tokens[s] or self.lengths[s]
                        or self.positions[s] or self._shared[s]):
                    errs.append(f"free slot {s} holds state")
                continue
            if self._shared[s] > len(self.tables[s]):
                errs.append(
                    f"slot {s} shared count {self._shared[s]} exceeds "
                    f"table length {len(self.tables[s])}")
            if len(self.tokens[s]) > self.max_seq:
                errs.append(f"slot {s} token list overflows max_seq")
            for bid in self.tables[s]:
                if not 0 <= bid < self.num_blocks:
                    errs.append(f"slot {s} references bad block {bid}")
                else:
                    table_refs[bid] += 1
        if table_refs != self.blocks.ref:
            diff = [b for b in range(self.num_blocks)
                    if table_refs[b] != self.blocks.ref[b]]
            errs.append(
                f"refcount mismatch on blocks {diff[:8]}: tables say "
                f"{[table_refs[b] for b in diff[:8]]}, pool says "
                f"{[self.blocks.ref[b] for b in diff[:8]]}")
        free = set(self.blocks.free)
        cold = set(self.blocks.cold)
        referenced = {b for b in range(self.num_blocks)
                      if self.blocks.ref[b] > 0}
        if len(free) != len(self.blocks.free):
            errs.append("duplicate entries on the free list")
        if free & cold:
            errs.append(f"free/cold overlap: {sorted(free & cold)[:8]}")
        if (free | cold | referenced) != set(range(self.num_blocks)) \
                or (free & referenced) or (cold & referenced):
            errs.append("free/cold/referenced do not partition the pool")
        for b in free:
            if b in self.blocks.radix:
                errs.append(f"free block {b} still radix-registered")
        for b in cold:
            if b not in self.blocks.radix:
                errs.append(f"cold block {b} not radix-registered")
        recomputed = len(referenced) * self.bytes_per_block
        if recomputed != self.used_bytes():
            errs.append(
                f"used_bytes {self.used_bytes()} != referenced-block "
                f"recomputation {recomputed}")
        if errs:
            raise IntegrityError("; ".join(errs))
        return True

    # -- byte budget --------------------------------------------------------

    def used_bytes(self) -> int:
        """Bytes of referenced (ref > 0) physical blocks — a shared
        prefix counts once, however many streams attach to it."""
        return int(self.blocks.used_blocks() * self.bytes_per_block)

    def capacity_bytes(self) -> int:
        """Bytes this pool can hold: the byte budget when one is set,
        else the whole physical block pool."""
        physical = self.num_blocks * self.bytes_per_block
        if self.byte_budget is not None:
            return min(self.byte_budget, physical) if physical else \
                self.byte_budget
        return physical

    def prefix_affinity(self, tokens: list[int] | None) -> int:
        """Leading prompt tokens whose KV this pool already holds —
        the radix prefix peek (no refcounts taken), in tokens.  The
        router routes shared-prompt traffic to the replica where its
        blocks already are."""
        if not tokens:
            return 0
        matched = self.blocks.match_peek([int(t) for t in tokens],
                                         max_tokens=len(tokens) - 1)
        return len(matched) * self.block_size

    def can_admit(self, prompt_len: int,
                  tokens: list[int] | None = None) -> bool:
        """Admission gate in blocks: fresh blocks the prompt needs
        (radix hits subtract — shared blocks are already paid for)
        must fit both the physical pool and the byte budget.  Matched
        blocks that are currently *cold* count against both: they sit
        in ``free_capacity`` now but :meth:`allocate` warms them
        (removing them from the recyclable set, and into the ref > 0
        bytes ``used_bytes`` counts).  An empty pool always admits
        budget-wise (a single over-budget prompt must not deadlock the
        queue)."""
        need = min(prompt_len // self.block_size + 1, self.blocks_per_slot)
        matched_cold = 0
        if tokens is not None:
            matched = self.blocks.match_peek(
                [int(t) for t in tokens], max_tokens=prompt_len - 1)
            need -= len(matched)
            matched_cold = sum(
                1 for b in matched if self.blocks.ref[b] == 0)
        if need + matched_cold > self.blocks.free_capacity():
            return False                   # physically impossible right now
        if self.byte_budget is None or self.bytes_per_block == 0:
            return True
        if not self.occupied_slots():
            return True
        projected = self.used_bytes() + \
            (need + matched_cold) * self.bytes_per_block
        return projected <= self.byte_budget

    def pressure_victims(self) -> list[int]:
        """Slots to preempt, youngest ticket first: first until the
        referenced-block bytes are back under the byte budget, then
        until the pool can physically cover every surviving stream's
        imminent block allocation (recycling cold blocks counts).  At
        least one stream always survives."""
        occ = sorted(self.occupied_slots(), key=lambda s: self.tickets[s])
        victims: list[int] = []
        # simulated refcounts across the whole victim set: a block two
        # victims share (ref == 2) frees once BOTH are popped — a
        # static ref == 1 snapshot would never count it and preempt
        # more streams than the budget requires
        ref = list(self.blocks.ref)

        def pop_frees(s):     # blocks that reach ref 0 when s releases
            n = 0
            for b in self.tables[s]:
                ref[b] -= 1
                if ref[b] == 0:
                    n += 1
            return n

        freed = 0
        if self.byte_budget is not None and self.bytes_per_block:
            used = self.used_bytes()
            while used > self.byte_budget and len(occ) > 1:
                s = occ.pop()                  # youngest admission
                n = pop_frees(s)
                freed += n
                used -= n * self.bytes_per_block
                victims.append(s)

        def needs_block(s):   # next grow crosses into an unallocated block
            nxt = int(self.positions[s]) + 1
            need = min(nxt // self.block_size + 1, self.blocks_per_slot)
            return self.positions[s] > 0 and need > len(self.tables[s])

        # byte-budget victims' blocks land on the free/cold lists too
        cap = self.blocks.free_capacity() + freed
        while len(occ) > 1 and cap < sum(map(needs_block, occ)):
            s = occ.pop()
            cap += pop_frees(s)
            victims.append(s)
        return victims

    # -- device gather / scatter --------------------------------------------

    def _ids_row(self, table: list[int]) -> np.ndarray:
        row = np.full((self.blocks_per_slot,), self.geometry.dummy_block,
                      np.int32)
        row[:len(table)] = table
        return row

    def _push_table(self, slot: int) -> None:
        self.cache = self._jit_table(self.cache,
                                     jnp.asarray(slot, jnp.int32),
                                     jnp.asarray(self._ids_row(
                                         self.tables[slot])))

    @staticmethod
    def _table_update(cache: PyTree, slot: jax.Array,
                      row: jax.Array) -> PyTree:
        """Write one slot's block-table row on every layer's table."""
        def leaf(path, x):
            if str(getattr(path[-1], "key", path[-1])) != "block_tables":
                return x
            ix = (slice(None),) * (x.ndim - 2) + (slot,)
            return x.at[ix].set(row)
        return jax.tree_util.tree_map_with_path(leaf, cache)

    @staticmethod
    def _gather_prefix(staging: PyTree, cache: PyTree, ids: jax.Array,
                       upto: jax.Array, *, block_size: int) -> PyTree:
        """Copy the pooled KV of blocks ``ids`` into a contiguous
        batch=1 staging cache, dequantizing int8 blocks, masking
        positions ``>= upto`` to the staging zeros."""
        def layer(pld, sgd):
            out = {}
            for name in ("k", "v"):
                if name in pld:
                    g = jnp.take(pld[name], ids, axis=pld[name].ndim - 4)
                else:
                    qv = jnp.take(pld[name + "_q"], ids,
                                  axis=pld[name + "_q"].ndim - 4)
                    sc = jnp.take(pld[name + "_scale"], ids,
                                  axis=pld[name + "_scale"].ndim - 3)
                    g = qv.astype(jnp.float32) * sc[..., :, None, :, :]
                # (..., nblk, bs, KH, D) -> (..., 1, S, KH, D)
                lead = g.shape[:-4]
                seq = g.shape[-4] * g.shape[-3]
                g = g.reshape(*lead, 1, seq, *g.shape[-2:])
                mask = (jnp.arange(seq) < upto).reshape(
                    (1,) * (len(lead) + 1) + (seq, 1, 1))
                out[name] = jnp.where(mask, g.astype(sgd[name].dtype),
                                      sgd[name])
            return out

        def rec(pld, sgd):
            if isinstance(pld, dict) and "block_tables" in pld:
                return layer(pld, sgd)
            if isinstance(pld, dict):
                return {k: rec(pld[k], sgd[k]) for k in pld}
            return sgd
        return rec(cache, staging)

    @staticmethod
    def _insert_blocks(cache: PyTree, cache1: PyTree, sc_ids: jax.Array,
                       length: jax.Array, *, block_size: int,
                       quantize: bool) -> PyTree:
        """Scatter a staged batch=1 stream cache into physical blocks.

        ``sc_ids (blocks_per_slot,)`` — destination physical block per
        logical block; blocks the stream does NOT own (radix-adopted,
        or past the prompt's coverage) are pre-pointed at the dummy, so
        a shared prefix block is never written (the copy-on-write
        invariant lives here).  Rows ``>= length`` are zero-masked.
        Int8 pools quantize per block: one absmax scale row per
        physical block, blocked together with its values.
        """
        def layer(pld, sgd):
            out = dict(pld)
            for name in ("k", "v"):
                x = sgd[name]                     # (..., 1, S, KH, D)
                seq = x.shape[-3]
                xb = x.reshape(*x.shape[:-4], seq // block_size,
                               block_size, *x.shape[-2:])
                pos = jnp.arange(seq).reshape(seq // block_size,
                                              block_size)
                xb = jnp.where((pos < length)[..., None, None], xb, 0.0)
                if not quantize and name in pld:
                    ax = pld[name].ndim - 4
                    ix = (slice(None),) * ax + (sc_ids,)
                    out[name] = pld[name].at[ix].set(
                        xb.astype(pld[name].dtype))
                    continue
                scale = kvq.kv_scales(xb, axis=-3)     # (..., nblk, KH, D)
                qv = kvq.quantize_kv(xb, jnp.expand_dims(scale, -3))
                axv = pld[name + "_q"].ndim - 4
                out[name + "_q"] = pld[name + "_q"].at[
                    (slice(None),) * axv + (sc_ids,)].set(qv)
                axs = pld[name + "_scale"].ndim - 3
                out[name + "_scale"] = pld[name + "_scale"].at[
                    (slice(None),) * axs + (sc_ids,)].set(scale)
            return out

        def rec(pld, sgd):
            if isinstance(pld, dict) and "block_tables" in pld:
                return layer(pld, sgd)
            if isinstance(pld, dict):
                return {k: rec(pld[k], sgd[k]) if k in sgd else pld[k]
                        for k in pld}
            return pld
        return rec(cache, cache1)

    def gather_prefix(self, staging: PyTree, slot: int,
                      upto: int) -> PyTree:
        """Fill a fresh staging cache with ``slot``'s first ``upto``
        pooled positions (the radix-matched prefix)."""
        return self._jit_gather(staging, self.cache,
                                jnp.asarray(self._ids_row(
                                    self.tables[slot])),
                                jnp.asarray(upto, jnp.int32),
                                block_size=self.block_size)

    def insert(self, cache1: PyTree, slot: int, length: int, *,
               from_full_precision: bool = False) -> None:
        """Land a finished stream cache in its blocks (one jitted
        scatter; the old pool buffer is donated).

        Host-side adoption first: if another stream registered blocks
        for our full prompt blocks since admission, adopt theirs
        (retain the published block, release our redundant fresh one)
        — N concurrent identical prompts still store the prefix
        exactly once.  Then register our remaining full blocks
        first-writer-wins and scatter only into blocks we own.
        """
        del from_full_precision   # staging is always full-precision here
        toks = self.tokens[slot]
        table = self.tables[slot]
        n_full = min(length // self.block_size, len(table))
        path = self.blocks.match_peek(toks[:n_full * self.block_size])
        for i in range(len(path)):
            if table[i] != path[i]:
                self.blocks.retain(path[i])
                self.blocks.release(table[i])   # fresh, never written
                table[i] = path[i]
                self.adoptions += 1
        self._shared[slot] = max(self._shared[slot], len(path))
        if n_full > len(path):
            self.blocks.register(toks[:n_full * self.block_size],
                                 table[:n_full])
        # scatter staged KV into owned blocks only; adopted entries
        # aim at the dummy (their content is already pooled)
        ids = self._ids_row(table)
        ids[:self._shared[slot]] = self.geometry.dummy_block
        fn = self._jit_insert_q if self.kv_quantize else self._jit_insert
        self.cache = fn(self.cache, cache1, jnp.asarray(ids),
                        jnp.asarray(length, jnp.int32),
                        block_size=self.block_size)
        self.positions[slot] = length
        self.lengths[slot] = length
        self._push_table(slot)
        if self.kv_quantize and table and self.faults.fire("block_scale"):
            # corrupt the first block this stream *owns* (not a
            # radix-adopted share) — the watchdog must quarantine this
            # stream, with minimal collateral on its prefix twins
            own = min(self._shared[slot], len(table) - 1)
            self.cache = _corrupt_scale_leaf(self.cache, table[own])

    # -- stats (bench / tests) ----------------------------------------------

    def physical_blocks_in_use(self) -> int:
        return self.blocks.used_blocks()

    def prefix_stats(self) -> dict:
        st = self.blocks.stats
        return {"prefix_queries": st.prefix_queries,
                "prefix_block_hits": st.prefix_block_hits,
                "adopted_blocks": self.adoptions,
                "evictions": st.evictions}

"""KVPoolManager: slot + KV-byte accounting over the serve cache pool.

The pool is the model's stacked cache pytree laid out
``(..., B_slots, S_max, ...)`` — one batch slot per in-flight stream,
any :class:`repro.layers.cache.CachePlan` family (full-width or int8
GQA K/V, full-width or int8 MLA latents).  This manager owns the state
side of the serve stack:

* the cache pytree itself plus the per-slot write positions,
* slot allocation with admission *tickets* (monotone age — KV-pressure
  preemption evicts the youngest stream first),
* byte accounting, derived from the model's cache plans —
  ``CachePlan.bytes_per_token`` / ``bytes_per_step`` are the single
  source of truth, so new cache families (the int8 MLA latent pool,
  and whatever comes next) are costed automatically instead of being
  silently undercounted by hand-maintained key lists.
  ``used_bytes()`` weights per-token bytes by each occupied slot's
  logical occupancy, an optional ``byte_budget`` gates admission
  (:meth:`can_admit`) and drives preemption (:meth:`pressure_victims`),
  and ``kv_bytes_per_step`` is the roofline's full-pool decode read,
* the slot scatter (:meth:`insert`): a batch=1 stream cache lands in
  its slot in one jitted donate-argnums call, masking the right-padded
  prompt tail — and quantizing a full-precision chunked-prefill staging
  cache into an int8 pool on the fly (``from_full_precision=True``).

Compute never lives here (that is :class:`repro.serve.runner.
ModelRunner`); policy never lives here (that is
:class:`repro.serve.scheduler.Scheduler`).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.layers import cache as cache_mod
from repro.quant import kv as kvq

PyTree = Any


class KVPoolManager:
    """Slot/byte owner for one engine's KV pool."""

    # Sequence-axis position of per-position cache leaves, by key —
    # shared with the plans (layers/cache.py owns the map).  Leaves
    # without an entry (scales, SSM states, cross-attn image KV) have
    # no prompt-length axis to mask.
    _SEQ_AXIS = cache_mod.SEQ_AXIS

    def __init__(self, model, slots: int, max_seq: int, *,
                 kv_quantize: str | None = None,
                 byte_budget: int | None = None):
        self.model = model
        self.slots = slots
        self.max_seq = max_seq
        self.kv_quantize = kv_quantize
        self.byte_budget = byte_budget
        self.cache = model.init_cache(slots, max_seq,
                                      kv_quantize=kv_quantize)
        self.positions = np.zeros((slots,), np.int32)   # next write pos
        self.lengths = np.zeros((slots,), np.int64)     # logical KV tokens
        self.tickets = np.full((slots,), -1, np.int64)  # admission age; -1 free
        self._next_ticket = 0

        #: one CachePlan per cached attention layer — the declarative
        #: source of ALL byte accounting (empty for recurrent models).
        self.plans = model.cache_plans(kv_quantize)
        #: per-position KV bytes of ONE stream across all layers
        self.bytes_per_token = sum(p.bytes_per_token for p in self.plans)
        #: HBM bytes the whole pool streams per decode step (masked,
        #: not skipped — every slot's full S_max is read).  VLM
        #: cross-attn image KV is a per-image constant stream outside
        #: the per-token plans; it is read every step too.
        self.kv_bytes_per_step = sum(
            p.bytes_per_step(slots, max_seq) for p in self.plans)
        for path, leaf in jax.tree_util.tree_flatten_with_path(
                self.cache)[0]:
            if any(str(getattr(p, "key", p)) == "cross_kv" for p in path):
                self.kv_bytes_per_step += leaf.size * leaf.dtype.itemsize

        self._jit_insert = jax.jit(self._insert_slot, donate_argnums=(0,))
        self._jit_insert_q = jax.jit(self._insert_slot_quantizing,
                                     donate_argnums=(0,))

    # -- slot bookkeeping ---------------------------------------------------

    def free_slots(self) -> list[int]:
        return [i for i in range(self.slots) if self.tickets[i] < 0]

    def occupied_slots(self) -> list[int]:
        return [i for i in range(self.slots) if self.tickets[i] >= 0]

    def allocate(self, slot: int, length: int) -> None:
        """Reserve ``slot`` for a stream of ``length`` prompt tokens.
        The full prompt's bytes are reserved up front, so admission
        cannot overshoot the budget mid-prefill."""
        assert self.tickets[slot] < 0, slot
        self.tickets[slot] = self._next_ticket
        self._next_ticket += 1
        self.lengths[slot] = length
        self.positions[slot] = 0

    def grow(self, slot: int, n: int = 1) -> None:
        """Account ``n`` decoded tokens of KV growth for ``slot``."""
        self.positions[slot] += n
        self.lengths[slot] += n

    def release(self, slot: int) -> None:
        self.tickets[slot] = -1
        self.lengths[slot] = 0
        self.positions[slot] = 0

    # -- byte budget --------------------------------------------------------

    def used_bytes(self) -> int:
        return int(self.lengths.sum() * self.bytes_per_token)

    def can_admit(self, prompt_len: int) -> bool:
        """Admission gate: does a ``prompt_len``-token stream fit the
        byte budget?  An empty pool always admits (otherwise a single
        over-budget prompt could deadlock the queue)."""
        if self.byte_budget is None or self.bytes_per_token == 0:
            return True
        if not self.occupied_slots():
            return True
        projected = self.used_bytes() + prompt_len * self.bytes_per_token
        return projected <= self.byte_budget

    def pressure_victims(self) -> list[int]:
        """Slots to preempt, youngest ticket first, until the pool is
        back under its byte budget.  At least one stream always
        survives — pressure sheds load, it never empties the pool."""
        if self.byte_budget is None or self.bytes_per_token == 0:
            return []
        occ = sorted(self.occupied_slots(), key=lambda s: self.tickets[s])
        victims: list[int] = []
        used = self.used_bytes()
        while used > self.byte_budget and len(occ) > 1:
            s = occ.pop()                      # youngest admission
            victims.append(s)
            used -= int(self.lengths[s] * self.bytes_per_token)
        return victims

    # -- slot scatter -------------------------------------------------------

    @classmethod
    def _insert_slot(cls, cache: PyTree, cache1: PyTree, slot: jax.Array,
                     length: jax.Array) -> PyTree:
        """Scatter a batch=1 cache into slot ``slot`` of the pool.

        Batch dim = the dim where pool and single differ (single == 1).
        ``length`` is the prompt's real token count: bucketed prefill
        right-pads the prompt, so positions ``>= length`` of the
        per-position leaves are zeroed before the scatter (int8 pools
        then dequantize the tail to exact zero; decode overwrites each
        position before it ever becomes attendable either way).
        """
        def leaf(path, pool, one):
            keys = [str(getattr(p, "key", p)) for p in path]
            ax = None if "cross_kv" in keys else cls._SEQ_AXIS.get(keys[-1])
            if ax is not None:
                idx = jnp.arange(one.shape[ax])
                mask = (idx < length).reshape(idx.shape + (1,) * (-ax - 1))
                one = jnp.where(mask, one, jnp.zeros_like(one))
            diff = [i for i, (a, b) in
                    enumerate(zip(pool.shape, one.shape)) if a != b]
            if not diff:                 # slots == 1: whole-pool replace
                return one.astype(pool.dtype)
            start = [0] * pool.ndim
            start[diff[0]] = slot
            return jax.lax.dynamic_update_slice(
                pool, one.astype(pool.dtype), tuple(start))
        return jax.tree_util.tree_map_with_path(leaf, cache, cache1)

    @classmethod
    def _insert_slot_quantizing(cls, cache: PyTree, cache1: PyTree,
                                slot: jax.Array, length: jax.Array) -> PyTree:
        """Insert a *full-precision* staging cache into an int8 pool:
        quantize (one-shot scales over the real prompt, pad masked) and
        scatter in the same compiled call — the pool never sees a
        full-width copy in between."""
        return cls._insert_slot(cache, kvq.quantize_kv_tree(cache1, length),
                                slot, length)

    def insert(self, cache1: PyTree, slot: int, length: int, *,
               from_full_precision: bool = False) -> None:
        """Land a finished stream cache in its pool slot (one jitted
        call; the old pool buffer is donated)."""
        fn = (self._jit_insert_q
              if (self.kv_quantize and from_full_precision)
              else self._jit_insert)
        self.cache = fn(self.cache, cache1, jnp.asarray(slot, jnp.int32),
                        jnp.asarray(length, jnp.int32))
        self.positions[slot] = length
        self.lengths[slot] = length

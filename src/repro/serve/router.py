"""ServeRouter: a data-parallel serve tier over N replica engines.

Each replica is a full :class:`repro.serve.engine.ServeEngine` stack
(Scheduler + KVPoolManager/PagedKVPoolManager + ModelRunner) pinned to
its own :class:`jax.Device` — params and KV pool committed there, every
step dispatched there — so replicas never contend for one device's
compute queue.  The router owns three service-level decisions the
single engine cannot make:

**Placement** (:meth:`ServeRouter.add_request`).  New requests route by
*least KV pressure*: each replica's score is its pool's live
``used_bytes`` plus the projected bytes of everything queued or
mid-prefill, over ``capacity_bytes`` (stream-count occupancy when the
plan has no per-position bytes).  Paged layouts add *radix prefix
affinity* first: a prompt whose leading blocks already sit in some
replica's radix cache routes there (ties broken by pressure), so
shared-prompt traffic lands where its KV blocks already are instead of
re-prefilling on a cold replica.  With ``priority_aware=False`` the
router degrades to round-robin FIFO — the priority-blind baseline the
bench compares against.

**SLO-aware admission** (:class:`SLOTracker`).  Per replica, the router
watches the live interactive p99 inter-token latency (the engine's
bounded per-class sample ring) against ``slo_itl_ms``.  Batch requests
are only admitted to a replica whose interactive tail has headroom
(``p99 <= headroom * slo`` with enough samples, hysteresis via
:meth:`SLOTracker.observe`); otherwise they queue in the router's held
deque and drain when a replica's interactive load clears.  A replica
whose tail breaches the target also gets ``engine.slo_pressure`` set,
tripping the engine's :class:`~repro.serve.scheduler.LoadShedder` one
step early — batch load degrades before interactive tails do.  Held
requests still honor ``deadline_s`` / ``max_queue_s`` (terminal
``deadline_exceeded`` from the held queue).

**Failure containment** (:class:`repro.serve.guard.ReplicaGuard`).  A
replica whose ``step`` raises, or that keeps producing numerical-
watchdog casualties, is pulled from rotation: its in-flight streams are
preempted (requeued with their generated prefix, bit-exact under
greedy) and its waiting queue is re-routed to healthy replicas.  At
least one replica always stays routable.  Fault injection composes
per-replica via :meth:`repro.serve.faults.FaultInjector.split`: one
chaos spec, independent deterministic streams per replica.

Wall-clock accounting: :meth:`step` drives every replica once (one
*round*) and records ``max`` per-replica step seconds as the round's
wall time — replicas are data-parallel on their own devices, so the
service-level clock is the slowest replica, not the sum.  On a
single-device test host the replicas time-share the device but the
modeled ``round_seconds`` still reflects the parallel deployment; the
per-replica engine stats keep the measured per-device seconds.

Determinism: routing only picks *which* engine serves a request.
Greedy sampling is argmax over logits of the same params on the same
prompt, and chunked == whole prefill is bit-exact — so per-request
token streams are identical across replica counts and routing orders
(``tests/test_serve_router.py`` pins this).
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Sequence

from repro.serve.engine import ServeEngine
from repro.serve.faults import FaultInjector
from repro.serve.guard import ReplicaGuard, ReplicaGuardPolicy
from repro.serve.metrics import latency_summary, percentiles
from repro.serve.scheduler import PRIORITIES, Request

__all__ = ["ServeRouter", "Replica", "SLOPolicy", "SLOTracker"]


@dataclasses.dataclass
class SLOPolicy:
    """Knobs of the interactive-tail admission gate.

    ``slo_itl_ms`` is the target p99 inter-token latency for
    interactive streams.  Batch traffic is admitted to a replica only
    while that replica's interactive tail has headroom: at least
    ``min_samples`` gap samples and ``p99 <= headroom * slo_itl_ms``
    (an idle replica — no interactive in flight — always admits).
    ``headroom < 1`` is the dead band that keeps admission from
    flapping right at the target.  The p99 is computed over the last
    ``window`` samples, not the whole ring — a single jittery step must
    not latch the verdict for the rest of the replica's life."""
    slo_itl_ms: float
    headroom: float = 0.6
    min_samples: int = 8
    window: int = 128


class SLOTracker:
    """Hysteresis switch over one replica's interactive p99 ITL.

    :meth:`observe` engages at ``p99 >= slo`` and only disengages once
    the tail recovers to ``headroom * slo`` — the same dead-band
    discipline as the :class:`~repro.serve.scheduler.LoadShedder`.
    While engaged the router holds ALL batch admissions to the replica
    and sets its engine's ``slo_pressure`` (early load shedding).
    """

    def __init__(self, policy: SLOPolicy):
        self.policy = policy
        self.engaged = False
        self.breaches = 0

    def observe(self, p99_ms: float, n_samples: int) -> bool:
        p = self.policy
        if n_samples >= p.min_samples:
            if not self.engaged and p99_ms >= p.slo_itl_ms:
                self.engaged = True
                self.breaches += 1
            elif self.engaged and p99_ms <= p.headroom * p.slo_itl_ms:
                self.engaged = False
        return self.engaged

    def idle_reset(self) -> None:
        """Stand down: the replica has no interactive work pending, so
        there is no tail to protect — and its sample ring has frozen,
        meaning :meth:`observe` could never see a recovery.  Not a
        breach-count event."""
        self.engaged = False

    def batch_ok(self, p99_ms: float, n_samples: int) -> bool:
        """May a batch request land here without regressing the
        interactive tail?  (Callers bypass this entirely when the
        replica has no interactive work pending — in flight or
        waiting.)"""
        if self.engaged:
            return False
        if n_samples < self.policy.min_samples:
            # interactive in flight but tail still unmeasured: hold —
            # the no-interactive bypass bounds how long this lasts
            return False
        return p99_ms <= self.policy.headroom * self.policy.slo_itl_ms


class Replica:
    """One engine + its routing/health bookkeeping."""

    def __init__(self, index: int, engine: ServeEngine,
                 guard: ReplicaGuard, tracker: SLOTracker | None):
        self.index = index
        self.engine = engine
        self.guard = guard
        self.tracker = tracker
        self.routed = {p: 0 for p in PRIORITIES}
        self.peak_used_bytes = 0
        self.evacuated = False

    @property
    def healthy(self) -> bool:
        return self.guard.healthy(self.engine)


class ServeRouter:
    def __init__(self, run, params, *, replicas: int = 2,
                 devices: Sequence[Any] | None = None,
                 slo_itl_ms: float | None = None,
                 slo: SLOPolicy | None = None,
                 priority_aware: bool = True,
                 guard_policy: ReplicaGuardPolicy | None = None,
                 faults: FaultInjector | None = None,
                 seed: int = 0,
                 stall_rounds: int = 64,
                 batch_pressure_cap: float = 0.5,
                 **engine_kwargs):
        """Builds ``replicas`` engines from one ``(run, params)`` pair.

        ``devices`` places replica i on ``devices[i % len(devices)]``
        (pass ``jax.devices()`` for one replica per local device); None
        leaves placement implicit — correct but serialized on one
        device.  ``slo_itl_ms`` (or a full :class:`SLOPolicy` via
        ``slo``) arms SLO-aware batch admission; None admits batch
        purely by pressure.  ``priority_aware=False`` is the blind
        baseline: round-robin routing, single-FIFO schedulers, no SLO
        gate.  ``faults`` is split per replica
        (:meth:`~repro.serve.faults.FaultInjector.split`) so one chaos
        spec drives the fleet deterministically.
        ``batch_pressure_cap`` balances held-back batch across the
        fleet: when every SLO-gated replica frees up at once, batch is
        not dumped wholesale onto the first one — a batch request whose
        projected KV pressure would exceed the cap waits in the held
        queue as long as some other replica (even one still gated)
        has headroom under it.  Remaining kwargs go to every
        :class:`~repro.serve.engine.ServeEngine` verbatim (each
        replica seeds its PRNG with ``seed + index``)."""
        if replicas < 1:
            raise ValueError(f"need at least 1 replica, got {replicas}")
        if slo is None and slo_itl_ms is not None:
            slo = SLOPolicy(slo_itl_ms)
        self.slo = slo if priority_aware else None
        self.priority_aware = priority_aware
        self.stall_rounds = max(1, stall_rounds)
        self.batch_pressure_cap = float(batch_pressure_cap)
        self.replicas: list[Replica] = []
        for i in range(replicas):
            dev = devices[i % len(devices)] if devices else None
            inj = (faults.split(f"replica{i}")
                   if faults is not None and faults.active else faults)
            eng = ServeEngine(run, params, seed=seed + i, device=dev,
                              priority_aware=priority_aware,
                              faults=inj, **engine_kwargs)
            tracker = SLOTracker(self.slo) if self.slo else None
            self.replicas.append(
                Replica(i, eng, ReplicaGuard(guard_policy), tracker))
        #: batch requests the SLO gate is holding back from every
        #: replica (FIFO; drained at the top of each round)
        self.held: deque[Request] = deque()
        #: requests that went terminal at the router (held-queue
        #: deadline expiry) without ever reaching an engine
        self.rejected: list[Request] = []
        self.rounds = 0
        #: modeled data-parallel wall clock: sum over rounds of the
        #: slowest replica's step seconds (see module docstring)
        self.round_seconds = 0.0
        self.total_tokens = 0
        self._rr = 0          # round-robin cursor (blind mode)

    # -- routing -------------------------------------------------------------

    def _routable(self) -> list[Replica]:
        """Replicas in rotation.  Never empty: with every guard
        tripped, the least-broken non-evacuated replica (fewest step
        failures) stays routable and keeps serving — a degraded
        service beats a deadlocked queue."""
        healthy = [r for r in self.replicas if r.healthy]
        if healthy:
            return healthy
        alive = [r for r in self.replicas if not r.evacuated] \
            or self.replicas
        return [min(alive, key=lambda r: r.guard.step_failures)]

    def _pressure(self, rep: Replica) -> float:
        """KV pressure score: live pool bytes plus the projected bytes
        of queued + mid-prefill work, over pool capacity (falls back to
        stream-count occupancy for plans with no per-position bytes)."""
        eng = rep.engine
        sched, pool = eng.scheduler, eng.pool
        backlog = sum(len(r.prompt) + len(r.output) for r in sched.waiting)
        backlog += sum(ps.remaining for ps in sched.prefilling)
        cap = pool.capacity_bytes()
        if cap:
            return (pool.used_bytes()
                    + backlog * pool.bytes_per_token) / cap
        streams = (len(sched.live_slots()) + len(sched.prefilling)
                   + len(sched.waiting))
        return streams / max(pool.slots, 1)

    def _projected(self, rep: Replica, req: Request) -> float:
        """Pressure the replica would sit at with ``req``'s KV on top."""
        pool = rep.engine.pool
        cap = pool.capacity_bytes()
        if not cap:
            return self._pressure(rep)
        need = (len(req.prompt) + req.max_new_tokens) * pool.bytes_per_token
        return self._pressure(rep) + need / cap

    def _interactive_p99(self, rep: Replica) -> tuple[float, int]:
        ring = rep.engine.class_itl[PRIORITIES[0]]
        window = self.slo.window if self.slo else len(ring)
        recent = list(ring)[-max(1, window):]
        (p99,) = percentiles([g * 1e3 for g in recent], (99,))
        return p99, len(recent)

    def _batch_ok(self, rep: Replica) -> bool:
        if rep.tracker is None:
            return True
        if not rep.engine.scheduler.interactive_pending():
            # no interactive anywhere on the replica (in flight OR
            # waiting) — nothing to protect, admit freely
            return True
        p99, n = self._interactive_p99(rep)
        return rep.tracker.batch_ok(p99, n)

    def _pick(self, req: Request) -> Replica | None:
        """The replica this request should land on, or None when every
        routable replica's SLO gate is holding batch back."""
        pool_ = self._routable()
        if not self.priority_aware:
            rep = pool_[self._rr % len(pool_)]
            self._rr += 1
            return rep
        batch = req.priority != PRIORITIES[0]
        if batch and self.slo is not None:
            gated = [r for r in pool_ if not self._batch_ok(r)]
            pool_ = [r for r in pool_ if self._batch_ok(r)]
            if not pool_:
                return None
            # pressure-cap balance: when one replica frees up first,
            # don't dump the whole held queue on it — wait for a gated
            # replica that would still have headroom under the cap
            fits = [r for r in pool_
                    if self._projected(r, req) <= self.batch_pressure_cap]
            if not fits and any(
                    self._projected(r, req) <= self.batch_pressure_cap
                    for r in gated):
                return None
            if fits:
                pool_ = fits
        # radix prefix affinity first (paged pools; 0 on slot pools):
        # land where the prompt's blocks already are
        aff = [(r, r.engine.pool.prefix_affinity(req.prompt))
               for r in pool_]
        best = max(a for _, a in aff)
        if best > 0:
            pool_ = [r for r, a in aff if a == best]
        return min(pool_, key=self._pressure)

    def _submit(self, rep: Replica, req: Request) -> None:
        rep.engine.add_request(req)
        rep.routed[req.priority] += 1

    def add_request(self, req: Request) -> None:
        """Route one request (stamping ``submit_time`` now — held time
        counts against TTFT and queue deadlines)."""
        if req.priority not in PRIORITIES:
            raise ValueError(f"unknown priority {req.priority!r} "
                             f"(want one of {PRIORITIES})")
        if req.submit_time is None:
            req.submit_time = time.perf_counter()
        rep = self._pick(req)
        if rep is None:
            self.held.append(req)
            return
        self._submit(rep, req)

    # -- held-queue maintenance ---------------------------------------------

    def _expire_held(self) -> None:
        now = time.perf_counter()
        for req in list(self.held):
            over = any(
                budget is not None and req.submit_time is not None
                and now - req.submit_time > budget
                for budget in (req.deadline_s, req.max_queue_s))
            if over:
                self.held.remove(req)
                req.status = "deadline_exceeded"
                req.done = True
                self.rejected.append(req)

    def _drain_held(self) -> None:
        while self.held:
            rep = self._pick(self.held[0])     # FIFO — head blocks
            if rep is None:
                break
            self._submit(rep, self.held.popleft())

    # -- replica failure -----------------------------------------------------

    def _evacuate(self, rep: Replica) -> None:
        """Pull a tripped replica's work: preempt in-flight streams
        (they requeue holding their generated prefix — greedy-exact on
        resume) and re-route its whole waiting queue.  Nothing is
        published to the failed replica's radix."""
        if rep.evacuated:
            return
        rep.evacuated = True
        eng = rep.engine
        sched, pool = eng.scheduler, eng.pool
        for slot in list(sched.live_slots()):
            sched.preempt(slot)
            pool.release(slot, publish=False)
        for ps in list(sched.prefilling):
            sched.preempt(ps.slot)
            pool.release(ps.slot, publish=False)
        moved: list[Request] = []
        while sched.waiting:
            moved.append(sched.waiting.popleft())
        for req in moved:
            if req.done:        # retry budget spent mid-preempt
                continue
            target = self._pick(req)
            if target is None:
                self.held.append(req)
            else:
                self._submit(target, req)

    # -- the round loop ------------------------------------------------------

    def step(self) -> int:
        """One *round*: drain/expire the held queue, step every routable
        busy replica once, update SLO trackers and health verdicts.
        Returns tokens produced across the fleet; wall clock advances
        by the slowest replica's step time (data-parallel model)."""
        self.rounds += 1
        self._expire_held()
        self._drain_held()
        routable = self._routable()
        produced = 0
        round_s = 0.0
        for rep in self.replicas:
            if rep not in routable:
                # out of rotation: move its work to replicas that are
                # (no-op if already evacuated); unhealthiness detected
                # after this round's step is handled next round
                self._evacuate(rep)
                continue
            eng = rep.engine
            if not eng.scheduler.busy():
                continue
            t0 = time.perf_counter()
            try:
                produced += eng.step()
            except Exception as exc:  # noqa: BLE001 — contain, re-route
                rep.guard.record_failure(exc)
                continue
            round_s = max(round_s, time.perf_counter() - t0)
            self._observe(rep)
        self.total_tokens += produced
        self.round_seconds += round_s
        return produced

    def _observe(self, rep: Replica) -> None:
        rep.peak_used_bytes = max(rep.peak_used_bytes,
                                  rep.engine.pool.used_bytes())
        if rep.tracker is None:
            return
        if not rep.engine.scheduler.interactive_pending():
            # nothing to protect, and the interactive ring has frozen —
            # a stale engaged verdict would shed batch forever
            rep.tracker.idle_reset()
            return
        p99, n = self._interactive_p99(rep)
        if (rep.tracker.observe(p99, n)
                and rep.engine.scheduler.batch_pending()):
            # tail breached AND the replica has batch load to shed:
            # trip the engine's shedder one step before its own
            # pressure signals would (shedding a pure-interactive
            # replica would only slow the tail it protects)
            rep.engine.slo_pressure = True

    def busy(self) -> bool:
        return bool(self.held) or any(
            r.engine.scheduler.busy() for r in self.replicas
            if not r.evacuated)

    def finished(self) -> list[Request]:
        """Every terminal request, engine order then router-rejected."""
        out = [r for rep in self.replicas for r in rep.engine.finished]
        out.extend(self.rejected)
        return out

    def run_until_done(self, max_rounds: int = 10_000) -> list[Request]:
        """Drive rounds until every queue drains.  The same watchdogs
        as the engine loop: ``stall_rounds`` rounds of zero progress
        fail the survivors fleet-wide, and exhausting ``max_rounds``
        with work in flight raises."""
        start = len(self.finished())
        stalled = 0
        for _ in range(max_rounds):
            if not self.busy():
                break
            fin0 = len(self.finished())
            produced = self.step()
            progressed = produced > 0 or len(self.finished()) > fin0
            stalled = 0 if progressed else stalled + 1
            if stalled >= self.stall_rounds:
                for rep in self.replicas:
                    rep.engine._fail_survivors()
                while self.held:
                    req = self.held.popleft()
                    req.status = "failed"
                    req.done = True
                    self.rejected.append(req)
                break
        else:
            if self.busy():
                raise RuntimeError(
                    f"run_until_done: {max_rounds} rounds exhausted "
                    f"with {len(self.held)} held and "
                    f"{sum(r.engine.scheduler.busy() for r in self.replicas)}"
                    " busy replicas")
        return self.finished()[start:]

    # -- service-level stats -------------------------------------------------

    def set_slo(self, slo_itl_ms: float) -> None:
        """(Re)arm the SLO gate — e.g. after calibrating the target
        from a measured interactive-only baseline."""
        self.slo = SLOPolicy(slo_itl_ms) if self.priority_aware else None
        for rep in self.replicas:
            rep.tracker = SLOTracker(self.slo) if self.slo else None

    def reset_stats(self) -> None:
        """Zero every latency/throughput counter fleet-wide (keeps
        pools, params, and compiled functions — benches warm up, reset,
        then measure)."""
        self.rounds = 0
        self.round_seconds = 0.0
        self.total_tokens = 0
        for rep in self.replicas:
            eng = rep.engine
            eng.stats.clear()
            for ring in (*eng.class_itl.values(),
                         *eng.class_ttft.values()):
                ring.clear()
            rep.peak_used_bytes = 0
            rep.routed = {p: 0 for p in PRIORITIES}
            if rep.tracker is not None:
                rep.tracker = SLOTracker(self.slo)

    def class_stats(self, priority: str) -> dict:
        """Fleet-wide per-class p50/p99 ITL + TTFT over every replica's
        sample rings."""
        itl = [g for rep in self.replicas
               for g in rep.engine.class_itl[priority]]
        ttft = [t for rep in self.replicas
                for t in rep.engine.class_ttft[priority]]
        done = sum(1 for r in self.finished() if r.priority == priority)
        return latency_summary(itl, ttft, requests=done)

    def throughput(self) -> dict:
        """Service-level stats: modeled data-parallel tokens/s (tokens
        over max-per-round wall — replicas run concurrently on their
        own devices), fleet per-class latency, and per-replica detail
        (each engine's own ``throughput()`` plus routing/health/SLO
        counters)."""
        per = []
        for rep in self.replicas:
            d = rep.engine.throughput()
            d["replica"] = rep.index
            d["routed"] = dict(rep.routed)
            d["kv_peak_bytes"] = rep.peak_used_bytes
            d["kv_capacity_bytes"] = rep.engine.pool.capacity_bytes()
            d["healthy"] = rep.healthy
            d["tripped"] = rep.guard.tripped
            d["slo_engaged"] = (rep.tracker.engaged
                                if rep.tracker else False)
            d["slo_breaches"] = (rep.tracker.breaches
                                 if rep.tracker else 0)
            per.append(d)
        return {
            "replicas": len(self.replicas),
            "rounds": self.rounds,
            "tokens": self.total_tokens,
            "round_seconds": self.round_seconds,
            "tokens_per_s": self.total_tokens / max(self.round_seconds,
                                                    1e-9),
            "held_batch": len(self.held),
            "rejected": len(self.rejected),
            "slo_itl_ms": self.slo.slo_itl_ms if self.slo else None,
            "per_class": {p: self.class_stats(p) for p in PRIORITIES},
            "per_replica": per,
        }

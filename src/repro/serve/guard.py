"""Numerical watchdog: per-slot non-finite logit detection, fused into
the batched sampling call.

A poisoned stream (int8 scale overflow, corrupted block, a model bug)
must degrade **one** request, not the engine: NaN/Inf logits in one
slot row would otherwise flow through the shared
``jax.random.categorical`` call and, worse, keep writing garbage into
the shared KV pool every step.  :func:`sample_and_flag` is the
one-device-call answer — the same batched greedy/temperature sampler
the runner always ran, plus a per-row ``all(isfinite)`` reduction fused
into the same jitted computation.  The flags ride back on the single
host transfer the engine already pays for the sampled tokens, so the
happy path gains **no extra host syncs** and no second kernel launch.

Guarantees the chaos suite pins down:

* a flagged row's token is sampled from zeroed logits (deterministic,
  finite — never lets a NaN pick an out-of-range token id); the engine
  quarantines the stream before the token is ever appended;
* *clean* rows are bit-identical to the unguarded sampler: their logits
  pass through untouched, per-row argmax is independent across rows,
  and ``jax.random.categorical``'s gumbel noise depends only on
  ``(key, shape)`` — so quarantining slot ``i`` never perturbs slot
  ``j``'s greedy (or seeded-sampling) stream.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

__all__ = ["nonfinite_rows", "sample_and_flag", "ReplicaGuard",
           "ReplicaGuardPolicy"]


def nonfinite_rows(logits: jax.Array) -> jax.Array:
    """``(rows, V) -> (rows,)`` bool: True where ANY logit in the row is
    NaN/Inf.  One fused reduction; jit-safe."""
    return ~jnp.all(jnp.isfinite(logits), axis=-1)


def sample_and_flag(key: jax.Array, logits: jax.Array,
                    temps: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Batched sampling with a fused watchdog.

    ``logits (rows, V)``, ``temps (rows,)`` -> ``(tokens (rows,) int,
    bad (rows,) bool)``.  Greedy rows (``temps == 0``) take the per-row
    argmax; temperature rows draw categorically — exactly the runner's
    historical ``_sample_all`` on clean rows.  Bad rows sample from
    zeroed logits (token 0 under greedy) and are flagged for the engine
    to quarantine.
    """
    bad = nonfinite_rows(logits)
    clean = jnp.where(bad[:, None], 0.0, logits)
    greedy = jnp.argmax(clean, axis=-1)
    safe = jnp.where(temps > 0, temps, 1.0)
    sampled = jax.random.categorical(key, clean / safe[:, None], axis=-1)
    return jnp.where(temps > 0, sampled, greedy), bad


# ---------------------------------------------------------------------------
# Replica-level health (the per-stream watchdog's fleet twin)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ReplicaGuardPolicy:
    """When the router pulls a whole replica out of rotation.

    The per-stream watchdog above quarantines *one* poisoned request; a
    replica that keeps producing casualties (a corrupted pool, a bad
    device) or whose ``step`` raises outright is a fleet problem — its
    queued work should move to healthy replicas instead of feeding a
    failing engine."""
    #: per-stream quarantines before the replica itself is suspect
    max_quarantined: int = 4
    #: uncaught ``step()`` exceptions tolerated (0 = first one trips)
    max_step_failures: int = 0


class ReplicaGuard:
    """Health verdict over one replica engine.  Trips once, stays
    tripped (re-admitting a flapping replica mid-evacuation would
    split-brain its queue); the router guarantees at least one replica
    always stays routable regardless of verdicts."""

    def __init__(self, policy: ReplicaGuardPolicy | None = None):
        self.policy = policy or ReplicaGuardPolicy()
        self.step_failures = 0
        self.last_error: BaseException | None = None
        self.tripped: str | None = None

    def record_failure(self, exc: BaseException) -> None:
        """Count one uncaught step exception."""
        self.step_failures += 1
        self.last_error = exc

    def healthy(self, engine) -> bool:
        if self.tripped is not None:
            return False
        if self.step_failures > self.policy.max_step_failures:
            self.tripped = "step_failures"
        elif engine.quarantined >= max(1, self.policy.max_quarantined):
            self.tripped = "quarantined_streams"
        return self.tripped is None

"""Numerical watchdog: per-slot non-finite logit detection, fused into
the batched sampling call.

A poisoned stream (int8 scale overflow, corrupted block, a model bug)
must degrade **one** request, not the engine: NaN/Inf logits in one
slot row would otherwise flow through the shared
``jax.random.categorical`` call and, worse, keep writing garbage into
the shared KV pool every step.  :func:`sample_and_flag` is the
one-device-call answer — the same batched greedy/temperature sampler
the runner always ran, plus a per-row ``all(isfinite)`` reduction fused
into the same jitted computation.  The flags ride back on the single
host transfer the engine already pays for the sampled tokens, so the
happy path gains **no extra host syncs** and no second kernel launch.

Guarantees the chaos suite pins down:

* a flagged row's token is sampled from zeroed logits (deterministic,
  finite — never lets a NaN pick an out-of-range token id); the engine
  quarantines the stream before the token is ever appended;
* *clean* rows are bit-identical to the unguarded sampler: their logits
  pass through untouched, per-row argmax is independent across rows,
  and ``jax.random.categorical``'s gumbel noise depends only on
  ``(key, shape)`` — so quarantining slot ``i`` never perturbs slot
  ``j``'s greedy (or seeded-sampling) stream.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["nonfinite_rows", "sample_and_flag"]


def nonfinite_rows(logits: jax.Array) -> jax.Array:
    """``(rows, V) -> (rows,)`` bool: True where ANY logit in the row is
    NaN/Inf.  One fused reduction; jit-safe."""
    return ~jnp.all(jnp.isfinite(logits), axis=-1)


def sample_and_flag(key: jax.Array, logits: jax.Array,
                    temps: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Batched sampling with a fused watchdog.

    ``logits (rows, V)``, ``temps (rows,)`` -> ``(tokens (rows,) int,
    bad (rows,) bool)``.  Greedy rows (``temps == 0``) take the per-row
    argmax; temperature rows draw categorically — exactly the runner's
    historical ``_sample_all`` on clean rows.  Bad rows sample from
    zeroed logits (token 0 under greedy) and are flagged for the engine
    to quarantine.
    """
    bad = nonfinite_rows(logits)
    clean = jnp.where(bad[:, None], 0.0, logits)
    greedy = jnp.argmax(clean, axis=-1)
    safe = jnp.where(temps > 0, temps, 1.0)
    sampled = jax.random.categorical(key, clean / safe[:, None], axis=-1)
    return jnp.where(temps > 0, sampled, greedy), bad

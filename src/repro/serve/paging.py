"""BlockPool + RadixPrefixCache: the host side of the paged KV pool.

The slot pool reserves one contiguous ``(S_max, ...)`` region per
stream — a short request strands most of its slot and a shared system
prompt is re-prefilled and re-stored per request.  The paged pool cuts
KV into fixed-size blocks and lets streams share them:

* :class:`BlockPool` — a refcounted allocator over ``num_blocks``
  physical blocks.  Every block is in exactly one of three states:

  - **free**: virgin or evicted, on the free list;
  - **referenced**: ``ref > 0`` — held by one or more live streams
    (``used_bytes`` counts exactly these);
  - **cold**: ``ref == 0`` but its content is still registered in the
    radix cache — a future request with the same prefix re-attaches to
    it for free.  Cold blocks are recyclable: when the free list runs
    dry, the least-recently-cooled *leaf* block is evicted from the
    radix and reused (leaf-only eviction keeps every cached path
    reachable root-first; evicting a leaf can expose its parent as the
    next candidate).

* :class:`RadixPrefixCache` — a trie over *full* token blocks: one
  edge per ``block_size``-token chunk, each node pinned to the
  physical block holding that chunk's KV.  ``match`` walks the longest
  cached block-aligned prefix; ``insert`` registers new paths
  first-writer-wins (an existing path keeps its blocks, so a prefix's
  KV is stored exactly once no matter how many concurrent requests
  carry it).

Copy-on-write discipline: a **shared block is never written**.  A
stream attaches to matched prefix blocks read-only (refcount bump) and
allocates fresh blocks from the divergence point; the partial tail
block is always private.  Divergence therefore never copies — the
"write" of copy-on-write is the fresh allocation past the match.

Everything here is plain host Python over ints — device arrays, jit
and scatter/gather live in :class:`repro.serve.pool.PagedKVPoolManager`.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict, deque

#: default tokens per KV block (vLLM's default; small enough that a
#: short request wastes at most block_size - 1 positions)
DEFAULT_BLOCK_SIZE = 16


class PoolExhausted(RuntimeError):
    """No KV capacity for an allocation: the free list is dry and (for
    the paged pool) every cold block is an interior prefix of a live
    stream.  The serve tier treats this as *pressure*, not a crash —
    admission retries later, a mid-decode grow preempts the stream —
    so it gets its own type rather than a bare ``RuntimeError``."""


class _RadixNode:
    __slots__ = ("parent", "edge", "children", "block")

    def __init__(self, parent=None, edge=None, block=None):
        self.parent = parent
        self.edge = edge            # tuple of block_size token ids
        self.children = {}          # edge tuple -> _RadixNode
        self.block = block          # physical block id


class RadixPrefixCache:
    """Trie over full token blocks -> physical block ids."""

    def __init__(self, block_size: int):
        self.block_size = block_size
        self.root = _RadixNode()
        self.by_block: dict[int, _RadixNode] = {}

    def _chunks(self, tokens) -> list[tuple[int, ...]]:
        bs = self.block_size
        return [tuple(tokens[i:i + bs])
                for i in range(0, len(tokens) - bs + 1, bs)]

    def match(self, tokens) -> list[int]:
        """Physical block ids of the longest cached block-aligned
        prefix of ``tokens`` (full blocks only)."""
        node, ids = self.root, []
        for ch in self._chunks(tokens):
            nxt = node.children.get(ch)
            if nxt is None:
                break
            ids.append(nxt.block)
            node = nxt
        return ids

    def insert(self, tokens, block_ids) -> list[int]:
        """Register ``tokens``' full blocks under ``block_ids``,
        first-writer-wins: a path segment that already exists keeps its
        existing block.  Returns the ids now live along the path (the
        caller diffs against its own ids to find redundant blocks)."""
        node, kept = self.root, []
        for ch, bid in zip(self._chunks(tokens), block_ids):
            nxt = node.children.get(ch)
            if nxt is None:
                nxt = _RadixNode(node, ch, bid)
                node.children[ch] = nxt
                self.by_block[bid] = nxt
            kept.append(nxt.block)
            node = nxt
        return kept

    def __contains__(self, bid: int) -> bool:
        return bid in self.by_block

    def is_leaf(self, bid: int) -> bool:
        return not self.by_block[bid].children

    def forget(self, bid: int) -> None:
        """Drop a (leaf) block's path segment from the trie."""
        node = self.by_block.pop(bid)
        assert not node.children, "evicting an interior radix block"
        del node.parent.children[node.edge]


@dataclasses.dataclass
class BlockPoolStats:
    prefix_queries: int = 0      # admissions that consulted the radix
    prefix_block_hits: int = 0   # blocks attached instead of allocated
    evictions: int = 0           # cold blocks recycled under pressure


class BlockPool:
    """Refcounted fixed-size block allocator with prefix reuse."""

    def __init__(self, num_blocks: int, block_size: int):
        self.num_blocks = num_blocks
        self.block_size = block_size
        self.free: deque[int] = deque(range(num_blocks))
        self.ref = [0] * num_blocks
        self.radix = RadixPrefixCache(block_size)
        #: ref == 0 but radix-registered, LRU order (oldest first)
        self.cold: OrderedDict[int, None] = OrderedDict()
        self.stats = BlockPoolStats()

    # -- capacity -----------------------------------------------------------

    def free_capacity(self) -> int:
        """Blocks allocatable right now (free list + recyclable cold)."""
        return len(self.free) + len(self.cold)

    def used_blocks(self) -> int:
        """Blocks held live (ref > 0) — the byte-accounting base."""
        return self.num_blocks - self.free_capacity()

    # -- alloc / refcount ---------------------------------------------------

    def alloc(self) -> int:
        """A fresh private block (ref = 1); recycles the LRU cold leaf
        when the free list is dry."""
        if self.free:
            bid = self.free.popleft()
        else:
            bid = self._evict_cold()
        self.ref[bid] = 1
        return bid

    def _evict_cold(self) -> int:
        for bid in self.cold:            # LRU order, leaf-only
            if self.radix.is_leaf(bid):
                del self.cold[bid]
                self.radix.forget(bid)
                self.stats.evictions += 1
                return bid
        raise PoolExhausted(
            "paged KV pool exhausted: no free blocks and every cold "
            "block is an interior prefix of a live stream")

    def retain(self, bid: int) -> None:
        """Attach to an existing block (a radix prefix hit warms it)."""
        if self.ref[bid] == 0:
            self.cold.pop(bid, None)
        self.ref[bid] += 1

    def release(self, bid: int) -> None:
        """Drop one reference.  At zero, a radix-registered block goes
        cold (reusable by prefix, recyclable LRU); an unregistered one
        is freed outright."""
        assert self.ref[bid] > 0, bid
        self.ref[bid] -= 1
        if self.ref[bid] == 0:
            if bid in self.radix:
                self.cold[bid] = None
                self.cold.move_to_end(bid)
            else:
                self.free.append(bid)

    # -- prefix sharing -----------------------------------------------------

    def match_retain(self, tokens, max_tokens: int | None = None
                     ) -> list[int]:
        """Longest cached block-aligned prefix of ``tokens`` (capped at
        ``max_tokens``), every matched block retained."""
        ids = self.radix.match(tokens)
        if max_tokens is not None:
            ids = ids[:max_tokens // self.block_size]
        for bid in ids:
            self.retain(bid)
        self.stats.prefix_queries += 1
        self.stats.prefix_block_hits += len(ids)
        return ids

    def match_peek(self, tokens, max_tokens: int | None = None
                   ) -> list[int]:
        """:meth:`match_retain` without the retain or the stats —
        admission feasibility checks and insert-time dedup."""
        ids = self.radix.match(tokens)
        if max_tokens is not None:
            ids = ids[:max_tokens // self.block_size]
        return ids

    def register(self, tokens, block_ids) -> list[int]:
        """Publish ``tokens``' full blocks to the radix under
        ``block_ids`` (first-writer-wins; see
        :meth:`RadixPrefixCache.insert`)."""
        return self.radix.insert(tokens, block_ids)

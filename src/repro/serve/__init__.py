from repro.serve.engine import ServeEngine, Request  # noqa: F401
from repro.serve.pool import KVPoolManager  # noqa: F401
from repro.serve.runner import ModelRunner  # noqa: F401
from repro.serve.scheduler import PrefillStream, Scheduler  # noqa: F401

from repro.serve.engine import ServeEngine, Request  # noqa: F401
from repro.serve.faults import (FaultInjector, INJECTION_POINTS,  # noqa: F401
                                NULL_INJECTOR)
from repro.serve.paging import PoolExhausted  # noqa: F401
from repro.serve.pool import IntegrityError, KVPoolManager  # noqa: F401
from repro.serve.runner import ModelRunner  # noqa: F401
from repro.serve.scheduler import (DegradationPolicy,  # noqa: F401
                                   LoadShedder, PrefillStream, Scheduler,
                                   STATUSES)

from repro.serve.engine import ServeEngine, Request  # noqa: F401
from repro.serve.faults import (FaultInjector, INJECTION_POINTS,  # noqa: F401
                                NULL_INJECTOR)
from repro.serve.guard import (ReplicaGuard,  # noqa: F401
                               ReplicaGuardPolicy)
from repro.serve.metrics import latency_summary, percentiles  # noqa: F401
from repro.serve.paging import PoolExhausted  # noqa: F401
from repro.serve.pool import IntegrityError, KVPoolManager  # noqa: F401
from repro.serve.router import (Replica, ServeRouter,  # noqa: F401
                                SLOPolicy, SLOTracker)
from repro.serve.runner import ModelRunner  # noqa: F401
from repro.serve.scheduler import (ClassedQueue,  # noqa: F401
                                   DegradationPolicy, LoadShedder,
                                   PrefillStream, PRIORITIES, Scheduler,
                                   STATUSES)

"""Shared latency statistics for the serve tier.

One canonical percentile implementation used by the engine's per-class
stats, the router's SLO tracker, and (re-exported through
``benchmarks/common.py``) every bench sweep — so "p99 ITL" always means
the same interpolation everywhere a number is recorded or compared.
"""
from __future__ import annotations

from typing import Iterable, Sequence

import numpy as np

__all__ = ["percentiles", "latency_summary"]


def percentiles(xs: Iterable[float],
                qs: Sequence[float] = (50, 99)) -> tuple[float, ...]:
    """``(pq for q in qs)`` over ``xs``; all-zero when ``xs`` is empty
    (callers treat "no samples" as "no latency", never as an error)."""
    xs = np.asarray(list(xs), dtype=np.float64)
    if xs.size == 0:
        return tuple(0.0 for _ in qs)
    return tuple(float(np.percentile(xs, q)) for q in qs)


def latency_summary(itl_s: Iterable[float],
                    ttft_s: Iterable[float],
                    requests: int = 0) -> dict:
    """p50/p99 inter-token latency + TTFT (milliseconds) over raw
    second-valued samples — the per-class stats block shape shared by
    :meth:`repro.serve.engine.ServeEngine.throughput` and the router."""
    itl = list(itl_s)
    ttft = list(ttft_s)
    itl_p50, itl_p99 = percentiles([g * 1e3 for g in itl], (50, 99))
    ttft_p50, ttft_p99 = percentiles([t * 1e3 for t in ttft], (50, 99))
    return {
        "requests": requests,
        "itl_samples": len(itl),
        "itl_p50_ms": itl_p50,
        "itl_p99_ms": itl_p99,
        "ttft_p50_ms": ttft_p50,
        "ttft_p99_ms": ttft_p99,
    }

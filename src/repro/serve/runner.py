"""ModelRunner: the serve stack's single compute seam.

Owns the params and every jitted step function (whole prefill, chunked
prefill, decode, batched sampling) and exposes ONE entry —
:meth:`step` ``(tokens, positions, seg_kind, ...)`` — so the scheduler
and engine never touch ``jax.jit`` or the model API directly.  Segment
kinds:

* ``"decode"``:        tokens ``(slots, 1)``, positions ``(slots,)`` —
                       one token for every slot against the shared pool.
* ``"prefill_chunk"``: tokens ``(1, C)`` at sequence offset
                       ``start_pos`` against a batch=1 stream cache
                       (chunked continuous admission).
* ``"prefill"``:       tokens ``(1, S)`` whole-prompt prefill
                       (blocking admission; recurrent/MoE families).

Prefill token arrays are length-bucketed by the caller, so each segment
kind compiles once per bucket, not once per prompt length; ``start_pos``
and ``prompt_len`` ride along as traced scalars.  The chunk entry
donates the staging cache (in-place stream growth); the whole-pool
decode cache is NOT donated (the engine aliases it across steps).

The runner threads the :class:`repro.layers.cache.CachePlan` for each
segment's cache into the model (static metadata closed over by the
jitted fns): the *pool* plan (``kv_quantize`` family) for decode and
blocking whole-prefill, and the full-precision *stream* plan for
chunked-prefill staging caches — chunk attention runs over the exact
K/V prefix and the pool quantizes once at slot insert.
"""
from __future__ import annotations

import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.serve import guard
from repro.serve.faults import NULL_INJECTOR

PyTree = Any

SEG_KINDS = ("decode", "prefill_chunk", "prefill")


class ModelRunner:
    def __init__(self, model, params: PyTree, opts, *, max_seq: int,
                 kv_quantize: str | None = None, act_quantize: str | None = None,
                 paged=None, faults=None, device=None):
        self.model = model
        #: the replica's :class:`jax.Device`, or None for implicit
        #: placement.  Params are committed there, so every jitted step
        #: dispatches on it — data-parallel replicas never contend for
        #: one device's queue.
        self.device = device
        if device is not None:
            params = jax.device_put(params, device)
        self.params = params
        self.opts = opts
        self.max_seq = max_seq
        self.kv_quantize = kv_quantize
        self.act_quantize = act_quantize
        #: fault source for the `nan_logits` / `slow_step` points
        #: (inert by default)
        self.faults = faults if faults is not None else NULL_INJECTOR
        #: plan of the shared pool (slot or, given a PagedGeometry,
        #: block-table paged) and of blocking-admission staging
        self.pool_plan = model.cache_plan(kv_quantize, paged=paged)
        #: plan of a full-precision chunked-prefill staging cache
        self.stream_plan = model.cache_plan(None)
        mdl = model
        # Activation quantization is a prefill-segment decision: the
        # prefill/chunk closures run the M-large MXU-bound dots int8 x
        # int8, decode keeps full-width activations (M = batch rows are
        # too skinny for the throughput term and too noisy for per-row
        # scales).  Decode always closes over the caller's opts.
        prefill_opts = (opts._replace(act_quantize=True)
                        if act_quantize == "int8" else opts)
        self.prefill_opts = prefill_opts

        def _prefill(params, batch, cache1, last_pos):
            return mdl.prefill(params, batch, cache1, last_pos=last_pos,
                               cache_plan=self.pool_plan, opts=prefill_opts)

        def _prefill_chunk(params, batch, cache1, start_pos, prompt_len):
            return mdl.prefill_chunk(params, batch, cache1,
                                     start_pos=start_pos,
                                     prompt_len=prompt_len,
                                     cache_plan=self.stream_plan,
                                     opts=prefill_opts)

        def _decode(params, tokens, positions, cache):
            return mdl.decode_step(params, tokens, positions, cache,
                                   cache_plan=self.pool_plan, opts=opts)

        self.jit_prefill = jax.jit(_prefill)
        self.jit_prefill_chunk = jax.jit(_prefill_chunk,
                                         donate_argnums=(2,))
        self.jit_decode = jax.jit(_decode)
        def _sample_all(key, logits, temps):
            """One device call samples every slot AND runs the
            numerical watchdog (per-row non-finite flags fused into the
            same computation — see :mod:`repro.serve.guard`); the host
            indexes the result, no per-slot round-trips and no extra
            sync for the flags.  A per-runner closure so each engine's
            trace cache stays its own."""
            return guard.sample_and_flag(key, logits, temps)

        self.jit_sample_all = jax.jit(_sample_all)

    def new_stream_cache(self, kv_quantize: str | None = None) -> PyTree:
        """A fresh batch=1 cache for one stream.  Chunked prefill stages
        at full precision (``kv_quantize=None``) regardless of the pool
        dtype — chunk attention then runs over the exact K/V prefix, so
        chunked greedy == whole-prefill greedy bit-for-bit, and the pool
        quantizes once at slot insert."""
        cache1 = self.model.init_cache(1, self.max_seq,
                                       kv_quantize=kv_quantize)
        if self.device is not None:
            cache1 = jax.device_put(cache1, self.device)
        return cache1

    def step(self, tokens: jax.Array, positions: jax.Array | None,
             seg_kind: str, *, cache: PyTree,
             start_pos: jax.Array | None = None,
             prompt_len: jax.Array | None = None,
             last_pos: jax.Array | None = None,
             batch: dict | None = None) -> tuple[jax.Array, PyTree]:
        """Run one compiled segment.  Returns ``(logits, new_cache)``."""
        if seg_kind == "decode":
            out = self.jit_decode(self.params, tokens, positions, cache)
        elif seg_kind == "prefill_chunk":
            out = self.jit_prefill_chunk(self.params, {"tokens": tokens},
                                         cache, start_pos, prompt_len)
        elif seg_kind == "prefill":
            out = self.jit_prefill(self.params,
                                   batch or {"tokens": tokens},
                                   cache, last_pos)
        else:
            raise ValueError(
                f"unknown seg_kind {seg_kind!r} (want one of {SEG_KINDS})")
        if self.faults.active:
            out = self._inject(out, seg_kind)
        return out

    def _inject(self, out: tuple[jax.Array, PyTree],
                seg_kind: str) -> tuple[jax.Array, PyTree]:
        """Post-dispatch fault hooks: ``slow_step`` stalls the step
        wall-clock (straggler detection); ``nan_logits`` poisons the
        logits the model just produced (one slot row on decode —
        ``params={"nan_logits": {"slot": i}}`` — the whole segment on
        prefill paths).  A ``seg`` param restricts which segment kinds
        are even *consulted*, so schedule indices count only matching
        calls ("poison decode call #3")."""
        if self.faults.fire("slow_step"):
            time.sleep(float(self.faults.param("slow_step", "seconds",
                                               0.05)))
        seg = self.faults.param("nan_logits", "seg", None)
        if seg is not None and seg_kind != seg:
            return out
        if self.faults.fire("nan_logits"):
            logits, cache = out
            if seg_kind == "decode":
                row = int(self.faults.param("nan_logits", "slot", 0))
                logits = logits.at[row].set(jnp.nan)
            else:
                logits = jnp.full_like(logits, jnp.nan)
            out = (logits, cache)
        return out

    def sample(self, key: jax.Array, logits: jax.Array,
               temps: jax.Array) -> tuple[np.ndarray, np.ndarray]:
        """Batched greedy/temperature sampling with the fused watchdog;
        host-side ``(tokens, bad)`` arrays — ``bad[i]`` flags slot
        ``i``'s logits as non-finite (the engine quarantines it)."""
        toks, bad = self.jit_sample_all(key, logits, temps)
        return np.asarray(toks), np.asarray(bad)

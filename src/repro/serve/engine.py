"""Batched serving engine: continuous batching over a fixed slot pool.

The cache pytree is laid out ``(..., B_slots, S_max, ...)``; each request
owns one batch slot.  Admission: a new request is prefilled with batch=1
(prompt right-padded to a power-of-2 length *bucket* so admission does
not retrace per distinct prompt length) and its cache *inserted* into
its slot (a pytree scatter on the batch dim, masking the padded tail);
decode then advances **all active slots together** with per-slot positions
(our attention decode supports per-example ``cache_pos``).  Finished slots
free immediately and are refilled from the queue — no wave barriers.

``kv_quantize="int8"`` stores the KV pool quantized (int8 values +
per-(slot, head, channel) f32 scales, :mod:`repro.quant.kv`): prefill
quantizes on insert and the pool + slot scatter stay int8 throughout,
so every decode step streams ~4x fewer KV bytes — the fused kernel
(``kernels/decode_attention_q``) consumes them directly under
``lrd.use_pallas``.

Sampling: greedy or temperature; stop on EOS or max tokens.  One device
call samples all slots per step (and all admissions per admit round).
Throughput stats per step are kept for the benchmarks.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import RunConfig
from repro.models.api import get_model
from repro.train.steps import block_opts

PyTree = Any


@dataclasses.dataclass
class Request:
    uid: int
    prompt: list[int]
    max_new_tokens: int = 32
    temperature: float = 0.0
    eos_id: int | None = None
    # filled by the engine:
    output: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


#: admission pads prompts up to at least this power-of-2 length bucket
PREFILL_BUCKET_MIN = 8


class ServeEngine:
    def __init__(self, run: RunConfig, params: PyTree, *, slots: int = 4,
                 max_seq: int = 512, seed: int = 0,
                 quantize: str | None = None,
                 kv_quantize: str | None = None):
        """``quantize`` ("int8" | "fp8") quantizes the decomposed factors
        at load via :mod:`repro.quant` — apply_linear then dispatches on
        the rewritten keys, so the model/step code is untouched.
        ``kv_quantize`` ("int8") stores the runtime KV pool quantized
        (:mod:`repro.quant.kv`).  Both default to ``run.lrd``."""
        self.run = run
        self.model = get_model(run.model)
        assert run.model.has_decode, "serving needs a decoder"
        if quantize is None:
            quantize = run.lrd.quantize
        if quantize and quantize != "none":
            from repro.quant import quantize_tree
            params = quantize_tree(params, mode=quantize,
                                   targets=run.lrd.quant_targets)
        self.quantize = quantize
        if kv_quantize is None:
            kv_quantize = run.lrd.kv_quantize
        self.kv_quantize = None if kv_quantize == "none" else kv_quantize
        self.params = params
        # Execution plans, built once at load (not per call): every
        # linear subtree's kind / quantized-pair / kernel decision is
        # resolved here, and the aggregate gives honest weight-stream
        # accounting (param_count excludes scales; quant_bytes separate).
        from repro.layers import plan as lplan
        self.plans = lplan.build_plan_tree(params)
        self.plan_summary = lplan.tree_summary(self.plans)
        self.slots = slots
        self.max_seq = max_seq
        self.opts = block_opts(run)
        self.cache = self.model.init_cache(slots, max_seq,
                                           kv_quantize=self.kv_quantize)
        # Decode streams the entire KV pool (masked, not skipped) every
        # step — this is the runtime twin of ``weight_bytes`` in the
        # roofline, and where kv_quantize="int8" pays: 1 byte/elt plus
        # the f32 scale rows instead of the full-width pool.  Only the
        # attention KV leaves count (incl. MLA latents and VLM image
        # KV); SSM/conv state is recurrent state, not a KV stream.
        kv_keys = ("k", "v", "k_q", "v_q", "k_scale", "v_scale",
                   "ckv", "krope")
        self.plan_summary["kv_bytes_per_step"] = sum(
            leaf.size * leaf.dtype.itemsize
            for path, leaf in jax.tree_util.tree_flatten_with_path(
                self.cache)[0]
            if str(getattr(path[-1], "key", path[-1])) in kv_keys)
        self.positions = np.zeros((slots,), np.int32)   # next write pos
        self.active: list[Request | None] = [None] * slots
        self.queue: list[Request] = []
        self.finished: list[Request] = []
        self.key = jax.random.PRNGKey(seed)
        self.stats: list[dict] = []

        mdl, opts = self.model, self.opts

        def _prefill1(params, batch, cache1, last_pos):
            return mdl.prefill(params, batch, cache1, last_pos=last_pos,
                               opts=opts)

        def _decode(params, tokens, positions, cache):
            return mdl.decode_step(params, tokens, positions, cache,
                                   opts=opts)

        def _sample_all(key, logits, temps):
            """One device call samples every slot: greedy argmax rows and
            temperature rows resolve together; the host indexes the
            result (no per-slot round-trips on the decode hot path)."""
            greedy = jnp.argmax(logits, axis=-1)
            safe = jnp.where(temps > 0, temps, 1.0)
            sampled = jax.random.categorical(key, logits / safe[:, None],
                                             axis=-1)
            return jnp.where(temps > 0, sampled, greedy)

        self._jit_prefill = jax.jit(_prefill1)
        self._jit_decode = jax.jit(_decode)
        self._jit_insert = jax.jit(self._insert_slot, donate_argnums=(0,))
        self._jit_sample_all = jax.jit(_sample_all)

    # -- slot management -----------------------------------------------------

    # Sequence-axis position (from the right) of cache leaves that hold
    # per-position state, by leaf key: K/V pools are (..., S, KH, hd),
    # MLA latents are (..., S, r).  Everything else (scales, SSM states,
    # cross-attn image KV) has no prompt-length axis to mask.
    _SEQ_AXIS = {"k": -3, "v": -3, "k_q": -3, "v_q": -3,
                 "ckv": -2, "krope": -2}

    @classmethod
    def _insert_slot(cls, cache: PyTree, cache1: PyTree, slot: jax.Array,
                     length: jax.Array) -> PyTree:
        """Scatter a batch=1 cache into slot ``slot`` of the pool.

        Batch dim = the dim where pool and single differ (single == 1).
        ``length`` is the prompt's real token count: bucketed prefill
        right-pads the prompt, so positions ``>= length`` of the
        per-position leaves are zeroed before the scatter (int8 pools
        then dequantize the tail to exact zero; decode overwrites each
        position before it ever becomes attendable either way).
        """
        def leaf(path, pool, one):
            keys = [str(getattr(p, "key", p)) for p in path]
            ax = None if "cross_kv" in keys else cls._SEQ_AXIS.get(keys[-1])
            if ax is not None:
                idx = jnp.arange(one.shape[ax])
                mask = (idx < length).reshape(idx.shape + (1,) * (-ax - 1))
                one = jnp.where(mask, one, jnp.zeros_like(one))
            diff = [i for i, (a, b) in
                    enumerate(zip(pool.shape, one.shape)) if a != b]
            if not diff:                 # slots == 1: whole-pool replace
                return one.astype(pool.dtype)
            start = [0] * pool.ndim
            start[diff[0]] = slot
            return jax.lax.dynamic_update_slice(
                pool, one.astype(pool.dtype), tuple(start))
        return jax.tree_util.tree_map_with_path(leaf, cache, cache1)

    def add_request(self, req: Request) -> None:
        self.queue.append(req)

    def _free_slots(self) -> list[int]:
        return [i for i, r in enumerate(self.active) if r is None]

    #: families where prompt padding is inert: causal attention never
    #: lets a real token see a pad token.  SSM/hybrid recurrent state
    #: *advances* through pad tokens, and MoE expert-capacity routing
    #: lets pads displace real tokens — those families prefill unpadded.
    _BUCKET_FAMILIES = ("dense", "vlm")

    def _bucket_len(self, n: int) -> int:
        """Power-of-2 prefill length bucket — one compiled prefill per
        bucket instead of one per distinct prompt length."""
        if self.run.model.family not in self._BUCKET_FAMILIES:
            return n
        return min(max(PREFILL_BUCKET_MIN, 1 << (n - 1).bit_length()),
                   self.max_seq)

    def _admit(self) -> None:
        admitted: list[tuple[Request, jax.Array]] = []
        for slot in self._free_slots():
            if not self.queue:
                break
            req = self.queue.pop(0)
            n = len(req.prompt)
            padded = np.zeros((1, self._bucket_len(n)), np.int32)
            padded[0, :n] = req.prompt
            prompt = jnp.asarray(padded)
            cache1 = self.model.init_cache(1, self.max_seq,
                                           kv_quantize=self.kv_quantize)
            if self.run.model.family == "vlm":
                batch = {"tokens": prompt,
                         "image_embeds": jnp.zeros(
                             (1, self.run.model.num_image_tokens,
                              self.run.model.d_model), self.model.dtype)}
            else:
                batch = {"tokens": prompt}
            logits, cache1 = self._jit_prefill(
                self.params, batch, cache1, jnp.asarray(n - 1, jnp.int32))
            self.cache = self._jit_insert(self.cache, cache1,
                                          jnp.asarray(slot, jnp.int32),
                                          jnp.asarray(n, jnp.int32))
            self.positions[slot] = n
            self.active[slot] = req
            admitted.append((req, logits[0, -1, :]))
        if not admitted:
            return
        # First tokens for the whole admit round in ONE device call,
        # same greedy/temperature mix as the decode path.  Rows are
        # padded to ``slots`` so _sample_all keeps the decode path's
        # single compiled (slots, V) shape across admit-round sizes.
        k = len(admitted)
        lg = jnp.stack([l for _, l in admitted])
        if k < self.slots:
            lg = jnp.pad(lg, ((0, self.slots - k), (0, 0)))
        temps = np.zeros((self.slots,), np.float32)
        temps[:k] = [max(r.temperature, 0.0) for r, _ in admitted]
        self.key, sub = jax.random.split(self.key)
        toks = np.asarray(self._jit_sample_all(sub, lg, jnp.asarray(temps)))
        for (req, _), tok in zip(admitted, toks[:k]):
            req.output.append(int(tok))

    # -- main loop ----------------------------------------------------------

    def step(self) -> int:
        """Admit + one decode step for all active slots.  Returns the
        number of tokens produced."""
        self._admit()
        live = [i for i, r in enumerate(self.active) if r is not None]
        if not live:
            return 0
        t0 = time.perf_counter()
        tokens = np.zeros((self.slots, 1), np.int32)
        for i in live:
            tokens[i, 0] = self.active[i].output[-1]
        logits, self.cache = self._jit_decode(
            self.params, jnp.asarray(tokens),
            jnp.asarray(self.positions), self.cache)
        produced = 0
        lg = logits[:, 0, :]
        temps = np.zeros((self.slots,), np.float32)
        for i in live:
            temps[i] = max(self.active[i].temperature, 0.0)
        self.key, sub = jax.random.split(self.key)
        toks = np.asarray(self._jit_sample_all(sub, lg, jnp.asarray(temps)))
        for i in live:
            req = self.active[i]
            tok = int(toks[i])
            req.output.append(tok)
            produced += 1
            self.positions[i] += 1
            ended = (req.eos_id is not None and tok == req.eos_id)
            full = len(req.output) >= req.max_new_tokens \
                or self.positions[i] >= self.max_seq - 1
            if ended or full:
                req.done = True
                self.finished.append(req)
                self.active[i] = None
        self.stats.append({"live": len(live), "tokens": produced,
                           "seconds": time.perf_counter() - t0})
        return produced

    def run_until_done(self, max_steps: int = 10_000) -> list[Request]:
        """Drive the engine until queue + slots drain; returns the
        requests that completed during this call (in completion order)."""
        start = len(self.finished)
        for _ in range(max_steps):
            if not self.queue and all(r is None for r in self.active):
                break
            self.step()
        return self.finished[start:]

    def throughput(self) -> dict:
        if not self.stats:
            return {"tokens_per_s": 0.0, "steps": 0}
        tok = sum(s["tokens"] for s in self.stats)
        sec = sum(s["seconds"] for s in self.stats)
        return {"tokens_per_s": tok / max(sec, 1e-9), "steps": len(self.stats),
                "mean_batch": tok / len(self.stats)}

"""Batched serving engine: continuous batching over a fixed slot pool.

The cache pytree is laid out ``(..., B_slots, S_max, ...)``; each request
owns one batch slot.  Admission: a new request is prefilled with batch=1
and its cache *inserted* into its slot (a pytree scatter on the batch dim);
decode then advances **all active slots together** with per-slot positions
(our attention decode supports per-example ``cache_pos``).  Finished slots
free immediately and are refilled from the queue — no wave barriers.

Sampling: greedy or temperature; stop on EOS or max tokens.  Throughput
stats per step are kept for the benchmarks.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import RunConfig
from repro.models.api import get_model
from repro.train.steps import block_opts

PyTree = Any


@dataclasses.dataclass
class Request:
    uid: int
    prompt: list[int]
    max_new_tokens: int = 32
    temperature: float = 0.0
    eos_id: int | None = None
    # filled by the engine:
    output: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, run: RunConfig, params: PyTree, *, slots: int = 4,
                 max_seq: int = 512, seed: int = 0,
                 quantize: str | None = None):
        """``quantize`` ("int8" | "fp8") quantizes the decomposed factors
        at load via :mod:`repro.quant` — apply_linear then dispatches on
        the rewritten keys, so the model/step code is untouched.  Defaults
        to ``run.lrd.quantize``."""
        self.run = run
        self.model = get_model(run.model)
        assert run.model.has_decode, "serving needs a decoder"
        if quantize is None:
            quantize = run.lrd.quantize
        if quantize and quantize != "none":
            from repro.quant import quantize_tree
            params = quantize_tree(params, mode=quantize,
                                   targets=run.lrd.quant_targets)
        self.quantize = quantize
        self.params = params
        # Execution plans, built once at load (not per call): every
        # linear subtree's kind / quantized-pair / kernel decision is
        # resolved here, and the aggregate gives honest weight-stream
        # accounting (param_count excludes scales; quant_bytes separate).
        from repro.layers import plan as lplan
        self.plans = lplan.build_plan_tree(params)
        self.plan_summary = lplan.tree_summary(self.plans)
        self.slots = slots
        self.max_seq = max_seq
        self.opts = block_opts(run)
        self.cache = self.model.init_cache(slots, max_seq)
        self.positions = np.zeros((slots,), np.int32)   # next write pos
        self.active: list[Request | None] = [None] * slots
        self.queue: list[Request] = []
        self.finished: list[Request] = []
        self.key = jax.random.PRNGKey(seed)
        self.stats: list[dict] = []

        mdl, opts = self.model, self.opts

        def _prefill1(params, batch, cache1):
            return mdl.prefill(params, batch, cache1, opts=opts)

        def _decode(params, tokens, positions, cache):
            return mdl.decode_step(params, tokens, positions, cache,
                                   opts=opts)

        def _sample_all(key, logits, temps):
            """One device call samples every slot: greedy argmax rows and
            temperature rows resolve together; the host indexes the
            result (no per-slot round-trips on the decode hot path)."""
            greedy = jnp.argmax(logits, axis=-1)
            safe = jnp.where(temps > 0, temps, 1.0)
            sampled = jax.random.categorical(key, logits / safe[:, None],
                                             axis=-1)
            return jnp.where(temps > 0, sampled, greedy)

        self._jit_prefill = jax.jit(_prefill1)
        self._jit_decode = jax.jit(_decode)
        self._jit_insert = jax.jit(self._insert_slot, donate_argnums=(0,))
        self._jit_sample_all = jax.jit(_sample_all)

    # -- slot management -----------------------------------------------------

    @staticmethod
    def _insert_slot(cache: PyTree, cache1: PyTree, slot: jax.Array
                     ) -> PyTree:
        """Scatter a batch=1 cache into slot ``slot`` of the pool.

        Batch dim = the dim where pool and single differ (single == 1).
        """
        def leaf(pool, one):
            diff = [i for i, (a, b) in
                    enumerate(zip(pool.shape, one.shape)) if a != b]
            if not diff:                 # slots == 1: whole-pool replace
                return one.astype(pool.dtype)
            start = [0] * pool.ndim
            start[diff[0]] = slot
            return jax.lax.dynamic_update_slice(
                pool, one.astype(pool.dtype), tuple(start))
        return jax.tree.map(leaf, cache, cache1)

    def add_request(self, req: Request) -> None:
        self.queue.append(req)

    def _free_slots(self) -> list[int]:
        return [i for i, r in enumerate(self.active) if r is None]

    def _admit(self) -> None:
        for slot in self._free_slots():
            if not self.queue:
                break
            req = self.queue.pop(0)
            prompt = jnp.asarray(req.prompt, jnp.int32)[None, :]
            cache1 = self.model.init_cache(1, self.max_seq)
            if self.run.model.family == "vlm":
                batch = {"tokens": prompt,
                         "image_embeds": jnp.zeros(
                             (1, self.run.model.num_image_tokens,
                              self.run.model.d_model), self.model.dtype)}
            else:
                batch = {"tokens": prompt}
            logits, cache1 = self._jit_prefill(self.params, batch, cache1)
            tok = self._sample(logits[:, -1, :], req)
            req.output.append(int(tok[0]))
            self.cache = self._jit_insert(self.cache, cache1,
                                          jnp.asarray(slot, jnp.int32))
            self.positions[slot] = len(req.prompt)
            self.active[slot] = req

    def _sample(self, logits: jax.Array, req: Request) -> np.ndarray:
        if req.temperature <= 0:
            return np.asarray(jnp.argmax(logits, -1))
        self.key, sub = jax.random.split(self.key)
        return np.asarray(jax.random.categorical(
            sub, logits / req.temperature, axis=-1))

    # -- main loop ----------------------------------------------------------

    def step(self) -> int:
        """Admit + one decode step for all active slots.  Returns the
        number of tokens produced."""
        self._admit()
        live = [i for i, r in enumerate(self.active) if r is not None]
        if not live:
            return 0
        t0 = time.perf_counter()
        tokens = np.zeros((self.slots, 1), np.int32)
        for i in live:
            tokens[i, 0] = self.active[i].output[-1]
        logits, self.cache = self._jit_decode(
            self.params, jnp.asarray(tokens),
            jnp.asarray(self.positions), self.cache)
        produced = 0
        lg = logits[:, 0, :]
        temps = np.zeros((self.slots,), np.float32)
        for i in live:
            temps[i] = max(self.active[i].temperature, 0.0)
        self.key, sub = jax.random.split(self.key)
        toks = np.asarray(self._jit_sample_all(sub, lg, jnp.asarray(temps)))
        for i in live:
            req = self.active[i]
            tok = int(toks[i])
            req.output.append(tok)
            produced += 1
            self.positions[i] += 1
            ended = (req.eos_id is not None and tok == req.eos_id)
            full = len(req.output) >= req.max_new_tokens \
                or self.positions[i] >= self.max_seq - 1
            if ended or full:
                req.done = True
                self.finished.append(req)
                self.active[i] = None
        self.stats.append({"live": len(live), "tokens": produced,
                           "seconds": time.perf_counter() - t0})
        return produced

    def run_until_done(self, max_steps: int = 10_000) -> list[Request]:
        """Drive the engine until queue + slots drain; returns the
        requests that completed during this call (in completion order)."""
        start = len(self.finished)
        for _ in range(max_steps):
            if not self.queue and all(r is None for r in self.active):
                break
            self.step()
        return self.finished[start:]

    def throughput(self) -> dict:
        if not self.stats:
            return {"tokens_per_s": 0.0, "steps": 0}
        tok = sum(s["tokens"] for s in self.stats)
        sec = sum(s["seconds"] for s in self.stats)
        return {"tokens_per_s": tok / max(sec, 1e-9), "steps": len(self.stats),
                "mean_batch": tok / len(self.stats)}

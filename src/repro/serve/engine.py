"""Batched serving engine: continuous batching over a fixed slot pool.

``ServeEngine`` is a thin façade over three seams (one file each, one
responsibility each):

* :class:`repro.serve.scheduler.Scheduler` — request lifecycle + the
  per-step *token budget* plan: decode-first (every live stream decodes
  one token per step, unconditionally), then **chunked prefill**
  segments with the leftover budget.  A long prompt is processed
  ``prefill_chunk`` tokens at a time interleaved with decode, so it can
  never head-of-line-block live streams the way the old blocking
  per-admit prefill did.
* :class:`repro.serve.pool.KVPoolManager` — the cache pytree
  ``(..., B_slots, S_max, ...)`` in any :class:`repro.layers.cache.
  CachePlan` family (``gqa_f32 | gqa_int8 | mla_latent |
  mla_latent_int8``), slot allocation, plan-derived byte accounting,
  byte-budget admission, and **KV-pressure preemption**: the youngest
  stream is evicted and requeued with its generated prefix
  (bit-deterministic under greedy — chunked prefill == whole prefill
  == decode).
* :class:`repro.serve.runner.ModelRunner` — params + every jitted step
  function behind one ``step(tokens, positions, seg_kind)`` entry
  (``"decode"`` | ``"prefill_chunk"`` | ``"prefill"``), threading the
  right CachePlan into each segment.

Chunked ("continuous") admission is the default for the dense family —
plain GQA *and* MLA latent stacks (offset latent chunk writes make the
segmented prefill exact); recurrent (SSM/hybrid), MoE-capacity, and
VLM stacks keep the whole-prompt "blocking" admission path (prompt
chunking is not inert for them).  In-flight chunked prompts stage in a
full-precision batch=1 cache and land in the pool in one scatter
(quantizing on insert for int8 pools), so chunked greedy output streams
match whole-prefill exactly for BOTH cache dtypes.

Sampling: greedy or temperature; stop on EOS or max tokens.  One device
call samples all slots per step (and all prefill completions per step)
AND runs the numerical watchdog (:mod:`repro.serve.guard`): a stream
whose logits go non-finite is quarantined — terminated ``failed``, its
slot/blocks reclaimed without publishing to the radix — while its
co-batched neighbors' token streams stay bit-identical.  Per-step stats
(a bounded ring buffer) record decode, prefill, and admission seconds;
every request carries TTFT timestamps.

Hardening (the serve twin of :mod:`repro.train.fault_tolerance`):

* lifecycle — per-request ``deadline_s`` / ``max_queue_s`` expiry,
  :meth:`ServeEngine.cancel` from every state, a preemption-retry
  budget (``max_preemptions`` evictions, then ``dropped``), and a
  terminal :data:`repro.serve.scheduler.STATUSES` status on every
  request that leaves the engine;
* degradation — a :class:`repro.serve.scheduler.LoadShedder` watches
  preemption + admission-failure pressure over the stats window and,
  past its watermark (with hysteresis), shrinks the step token budget
  and pauses admission until pressure clears;
* watchdogs — a no-progress guard in :meth:`run_until_done` (stalled
  engines mark survivors ``failed`` instead of silently returning), a
  per-step :class:`repro.train.fault_tolerance.StragglerDetector`, and
  (under ``debug=True``) the pool's ``check_integrity()`` after every
  step;
* chaos — a :class:`repro.serve.faults.FaultInjector` threads named
  injection points through the pool, runner, and kernel gate
  (``tests/test_serve_faults.py`` drives them all).
"""
from __future__ import annotations

import contextlib
import time
from collections import deque
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import RunConfig
from repro.models.api import get_model
from repro.serve import paging
from repro.serve.faults import NULL_INJECTOR, FaultInjector
from repro.serve.metrics import latency_summary
from repro.serve.paging import PoolExhausted
from repro.serve.pool import KVPoolManager, PagedKVPoolManager
from repro.serve.runner import ModelRunner
from repro.serve.scheduler import (PREFILL_BUCKET_MIN, PRIORITIES,
                                   DegradationPolicy, LoadShedder,
                                   PrefillStream, Request, Scheduler)
from repro.train.fault_tolerance import StragglerDetector
from repro.train.steps import block_opts

__all__ = ["ServeEngine", "Request", "FaultInjector",
           "PREFILL_BUCKET_MIN"]

PyTree = Any

#: default tokens per chunked-prefill segment (LRDConfig.prefill_chunk
#: or the engine kwarg override it)
DEFAULT_PREFILL_CHUNK = 64

#: steps of stats kept (ring buffer — long-running engines must not
#: grow host memory without bound)
STATS_WINDOW = 4096

#: consecutive zero-progress steps (no tokens, no prefill, no
#: admissions, no completions) before :meth:`ServeEngine.run_until_done`
#: declares the engine stalled and fails the survivors
DEFAULT_STALL_STEPS = 64


class ServeEngine:
    #: families where prompt padding is inert: causal attention never
    #: lets a real token see a pad token.  SSM/hybrid recurrent state
    #: *advances* through pad tokens, and MoE expert-capacity routing
    #: lets pads displace real tokens — those families prefill unpadded.
    _BUCKET_FAMILIES = ("dense", "vlm")

    #: families served with chunked continuous admission: attention
    #: stacks where a chunk's K/V (or MLA latents) lands at a sequence
    #: offset and absolute causality makes the segmented prefill exact.
    #: VLM (image KV precompute), MoE capacity routing (per-chunk
    #: expert capacity != whole-prompt capacity), and recurrent state
    #: keep blocking whole-prompt admission.
    _CHUNK_FAMILIES = ("dense",)

    def __init__(self, run: RunConfig, params: PyTree, *, slots: int = 4,
                 max_seq: int = 512, seed: int = 0,
                 quantize: str | None = None,
                 sparsify: str | None = None,
                 kv_quantize: str | None = None,
                 act_quantize: str | None = None,
                 admission: str | None = None,
                 prefill_chunk: int | None = None,
                 step_token_budget: int | None = None,
                 kv_byte_budget: int | None = None,
                 kv_layout: str | None = None,
                 kv_block_size: int | None = None,
                 kv_num_blocks: int | None = None,
                 stats_window: int = STATS_WINDOW,
                 debug: bool = False,
                 faults: FaultInjector | None = None,
                 degradation: DegradationPolicy | bool = True,
                 stall_steps: int = DEFAULT_STALL_STEPS,
                 device: Any = None,
                 priority_aware: bool = True,
                 batch_share: float = 1.0):
        """``quantize`` ("int8" | "fp8") quantizes the decomposed factors
        at load via :mod:`repro.quant`; ``sparsify`` ("2:4") first
        2:4-prunes the ``run.lrd.sparse_targets`` factors
        (:mod:`repro.quant.sparse`), packing their kept values in the
        quantized dtype when ``quantize`` is also set (compound
        compression — the sparse pass subsumes quantization for the
        factors it packs, ``quantize_tree`` then handles the rest);
        ``kv_quantize`` ("int8") stores the runtime KV pool quantized
        (:mod:`repro.quant.kv`) — the GQA K/V pool on plain attention
        stacks, the latent cache on MLA stacks (cache family
        ``gqa_int8`` / ``mla_latent_int8``); ``act_quantize`` ("int8",
        requires ``quantize="int8"``) additionally quantizes prefill
        *activations* per-token on the fly so the fully-int8 plans run
        int8 x int8 on the MXU (prefill/chunk segments only — decode
        stays at full activation width).  All default to ``run.lrd``,
        as do ``prefill_chunk`` / ``step_token_budget`` (0 = engine
        defaults).

        ``admission`` is "continuous" (token-budget chunked prefill;
        default where supported) or "blocking" (one whole prefill per
        admit — the pre-scheduler behavior, kept for unsupported
        families and as the benchmark baseline).  ``kv_byte_budget``
        (bytes of per-position KV across all streams) gates admission
        and triggers youngest-first preemption when decode growth
        crosses it; None = never preempt.

        ``kv_layout`` ("slot" | "paged"; default ``run.lrd.kv_layout``)
        selects the pool memory layout.  "paged" backs the pool with
        fixed-size KV blocks behind per-slot block tables and a radix
        prefix cache (:mod:`repro.serve.paging`): requests sharing a
        block-aligned prompt prefix attach to the same physical blocks
        copy-on-write, and byte accounting / preemption go block-
        granular.  Paged serving needs chunked continuous admission
        (the prefix gather stages into the chunk path) and a dense
        non-MLA stack; ``kv_block_size`` (tokens per block, default
        ``run.lrd.kv_block_size`` or 16) must divide ``max_seq``, and
        ``kv_num_blocks`` sizes the physical pool (default
        ``slots * max_seq / block_size`` — the slot pool's capacity).

        ``debug=True`` runs the pool's ``check_integrity()`` after
        every step (invariant oracle — slow, test/diagnosis only).
        ``faults`` threads a :class:`repro.serve.faults.FaultInjector`
        through the pool, runner, and kernel gate (inert by default).
        ``degradation`` is a :class:`repro.serve.scheduler.
        DegradationPolicy` (True = defaults, False/None = off) for the
        pressure-watching load shedder.  ``stall_steps`` is the
        no-progress watchdog horizon in :meth:`run_until_done`.

        ``device`` pins this engine to one :class:`jax.Device`: params
        and the KV pool are committed there and every step dispatches
        there — how :class:`repro.serve.router.ServeRouter` places N
        replicas data-parallel across a host's devices.  ``None`` (the
        default) keeps JAX's implicit placement.  ``priority_aware`` /
        ``batch_share`` configure the scheduler's priority classes
        (interactive-first queueing and the in-flight batch prefill
        throttle — see :class:`repro.serve.scheduler.Scheduler`).
        """
        self.run = run
        self.model = get_model(run.model)
        assert run.model.has_decode, "serving needs a decoder"
        if quantize is None:
            quantize = run.lrd.quantize
        if sparsify is None:
            sparsify = run.lrd.sparsify
        if sparsify and sparsify != "none":
            # Sparsify BEFORE quantize: the pass prunes + packs (in the
            # quantized dtype when quantize is on), and quantize_tree
            # then skips the already-packed nodes and quantizes the
            # remaining plain factors (xc, non-divisible layers).
            from repro.quant import sparsify_tree
            params = sparsify_tree(
                params, pattern=sparsify,
                mode=(quantize if quantize and quantize != "none"
                      else "none"),
                targets=run.lrd.sparse_targets)
        self.sparsify = sparsify
        if quantize and quantize != "none":
            from repro.quant import quantize_tree
            params = quantize_tree(params, mode=quantize,
                                   targets=run.lrd.quant_targets)
        self.quantize = quantize
        if kv_quantize is None:
            kv_quantize = run.lrd.kv_quantize
        self.kv_quantize = None if kv_quantize == "none" else kv_quantize
        if act_quantize is None:
            act_quantize = getattr(run.lrd, "act_quantize", "none")
        self.act_quantize = None if act_quantize == "none" else act_quantize
        if self.act_quantize and self.act_quantize != "int8":
            raise ValueError(
                f"act_quantize {act_quantize!r} (want 'int8' or 'none')")
        if self.act_quantize and quantize != "int8":
            raise ValueError(
                "act_quantize='int8' needs quantize='int8' — the qa "
                "kernels run int8 x int8 against fully-int8 factor plans")
        self.device = device
        if device is not None:
            # commit the (possibly quantized) params: computations that
            # touch them dispatch on this replica's device regardless of
            # the process-global default
            params = jax.device_put(params, device)
        self.params = params
        # Execution plans, built once at load (not per call): every
        # linear subtree's kind / quantized-pair / kernel decision is
        # resolved here, and the aggregate gives honest weight-stream
        # accounting (param_count excludes scales; quant_bytes separate).
        from repro.layers import plan as lplan
        self.plans = lplan.build_plan_tree(params)
        self.plan_summary = lplan.tree_summary(self.plans)
        self.slots = slots
        self.max_seq = max_seq
        self.opts = block_opts(run)

        if admission is None:
            admission = ("continuous" if self._supports_chunked()
                         else "blocking")
        elif admission == "continuous" and not self._supports_chunked():
            raise ValueError(
                f"family {run.model.family!r} does not support chunked "
                "admission; use admission='blocking'")
        elif admission not in ("continuous", "blocking"):
            raise ValueError(admission)
        self.admission = admission
        chunk = prefill_chunk or run.lrd.prefill_chunk \
            or DEFAULT_PREFILL_CHUNK
        self.prefill_chunk = max(1, min(chunk, max_seq))
        self.step_token_budget = step_token_budget \
            or run.lrd.step_token_budget or (slots + self.prefill_chunk)

        if kv_layout is None:
            kv_layout = getattr(run.lrd, "kv_layout", "slot") or "slot"
        if kv_layout not in ("slot", "paged"):
            raise ValueError(
                f"kv_layout {kv_layout!r} (want 'slot' or 'paged')")
        self.kv_layout = kv_layout
        # pool before runner: the paged runner's pool plan needs the
        # pool's PagedGeometry (block count / size / tables)
        with self._on_device():
            if kv_layout == "paged":
                if self.admission != "continuous":
                    raise ValueError(
                        "kv_layout='paged' needs continuous admission "
                        "(the radix prefix gather stages into the "
                        "chunked prefill path)")
                self.pool = PagedKVPoolManager(
                    self.model, slots, max_seq,
                    kv_quantize=self.kv_quantize,
                    byte_budget=kv_byte_budget,
                    block_size=(kv_block_size or run.lrd.kv_block_size
                                or paging.DEFAULT_BLOCK_SIZE),
                    num_blocks=kv_num_blocks)
            else:
                self.pool = KVPoolManager(self.model, slots, max_seq,
                                          kv_quantize=self.kv_quantize,
                                          byte_budget=kv_byte_budget)
        if device is not None:
            # commit the pool cache too: later ops on it (insert, grow,
            # release) stay pinned even outside the step context
            self.pool.cache = jax.device_put(self.pool.cache, device)
        self.debug = debug
        self.faults = faults if faults is not None else NULL_INJECTOR
        self.pool.faults = self.faults
        if self.faults.configured("kernel_gate"):
            # module-global hook: kernel_fits is consulted at trace /
            # plan time, far from any serve object
            from repro.kernels import ops as kops
            kops.set_fault_injector(self.faults)
        self.runner = ModelRunner(self.model, params, self.opts,
                                  max_seq=max_seq,
                                  kv_quantize=self.kv_quantize,
                                  act_quantize=self.act_quantize,
                                  paged=getattr(self.pool, "geometry",
                                                None),
                                  faults=self.faults,
                                  device=device)
        self.scheduler = Scheduler(slots, prefill_chunk=self.prefill_chunk,
                                   step_token_budget=self.step_token_budget,
                                   priority_aware=priority_aware,
                                   batch_share=batch_share)
        if degradation is True:
            degradation = DegradationPolicy()
        self.shedder = (LoadShedder(degradation, self.step_token_budget)
                        if degradation else None)
        self.stragglers = StragglerDetector()
        self.stall_steps = max(1, stall_steps)
        self.quarantined = 0
        self.deadline_expired = 0
        self._step_idx = 0
        # Decode streams the entire KV pool (masked, not skipped) every
        # step — the runtime twin of ``weight_bytes`` in the roofline,
        # and where kv_quantize="int8" pays.  Both numbers derive from
        # the CachePlans (layers/cache.py), never from hand-kept key
        # lists, so every cache family is costed automatically.
        self.plan_summary["kv_bytes_per_step"] = self.pool.kv_bytes_per_step
        self.plan_summary["kv_layout"] = kv_layout
        if self.pool.plans:
            self.plan_summary["kv_cache_family"] = self.pool.plans[0].family
        self.key = jax.random.PRNGKey(seed)
        self.stats: deque[dict] = deque(maxlen=stats_window)
        # per-priority-class latency sample rings (seconds), bounded
        # like the step stats; they feed the per-class p50/p99 in
        # throughput() and the router's SLO tracker.  ITL samples are
        # *service-time* gaps: this engine's cumulative step seconds
        # between a stream's consecutive tokens — the token cadence a
        # dedicated-device replica delivers.  Wall gaps would charge a
        # replica for its co-tenants whenever several replicas
        # time-share one test device; TTFT stays wall-clock (queue
        # wait is real service latency).
        self.class_itl: dict[str, deque] = {
            p: deque(maxlen=stats_window) for p in PRIORITIES}
        self.class_ttft: dict[str, deque] = {
            p: deque(maxlen=stats_window) for p in PRIORITIES}
        #: set (externally, by the router's SLO tracker) to trip the
        #: load shedder one step early when the interactive ITL target
        #: would regress; consumed and cleared by :meth:`step`
        self.slo_pressure = False
        #: cumulative service seconds (sum of step admit+decode+prefill
        #: time) — the clock the class ITL rings sample against
        self.service_s = 0.0
        self._step_token_reqs: list = []

    def _supports_chunked(self) -> bool:
        return self.run.model.family in self._CHUNK_FAMILIES

    def _on_device(self):
        """Dispatch context: pin computation to this engine's device
        (no-op when the engine is unplaced)."""
        if self.device is None:
            return contextlib.nullcontext()
        return jax.default_device(self.device)

    # -- façade views (the pre-split engine surface) -------------------------

    @property
    def cache(self) -> PyTree:
        return self.pool.cache

    @cache.setter
    def cache(self, value: PyTree) -> None:
        self.pool.cache = value

    @property
    def positions(self) -> np.ndarray:
        return self.pool.positions

    @property
    def active(self) -> list[Request | None]:
        return self.scheduler.active

    @property
    def queue(self) -> deque[Request]:
        return self.scheduler.waiting

    @property
    def finished(self) -> list[Request]:
        return self.scheduler.finished

    @property
    def preemptions(self) -> int:
        return self.scheduler.preemptions

    @property
    def _jit_prefill(self):
        """The compiled admission prefill entry (chunked or whole)."""
        return (self.runner.jit_prefill_chunk
                if self.admission == "continuous"
                else self.runner.jit_prefill)

    @property
    def _jit_decode(self):
        return self.runner.jit_decode

    @property
    def _jit_sample_all(self):
        return self.runner.jit_sample_all

    @property
    def _jit_insert(self):
        return self.pool._jit_insert

    # -- admission helpers ---------------------------------------------------

    def add_request(self, req: Request) -> None:
        if len(req.prompt) > self.max_seq - 1:
            # reject up front: admission would otherwise consume a slot
            # and crash mid-prefill.  (Preemption-resumed prompts always
            # fit — decode stops one position short of max_seq.)
            raise ValueError(
                f"prompt of {len(req.prompt)} tokens does not fit "
                f"max_seq={self.max_seq} (need <= {self.max_seq - 1} "
                "to leave room for decode)")
        if req.submit_time is None:
            req.submit_time = time.perf_counter()
        self.scheduler.submit(req)

    def _bucket_len(self, n: int) -> int:
        """Power-of-2 prefill length bucket — one compiled prefill per
        bucket instead of one per distinct prompt (or chunk) length."""
        if self.run.model.family not in self._BUCKET_FAMILIES:
            return n
        return min(max(PREFILL_BUCKET_MIN, 1 << (n - 1).bit_length()),
                   self.max_seq)

    def _append_token(self, req: Request, tok: int, now: float) -> None:
        # ITL is sampled at end of step against self.service_s (the
        # step's duration is not known yet here)
        self._step_token_reqs.append(req)
        req.output.append(tok)
        req.token_times.append(now)
        if req.first_token_time is None:
            req.first_token_time = now
            if req.submit_time is not None:
                self.class_ttft[req.priority].append(
                    now - req.submit_time)

    def _maybe_finish(self, slot: int) -> bool:
        req = self.scheduler.active[slot]
        tok = req.output[-1]
        ended = req.eos_id is not None and tok == req.eos_id
        full = (len(req.output) >= req.max_new_tokens
                or self.pool.positions[slot] >= self.max_seq - 1)
        if ended or full:
            self.scheduler.finish(slot)
            self.pool.release(slot)
            return True
        return False

    def _sample_rows(self, rows: list[jax.Array],
                     temps_list: list[float]
                     ) -> tuple[np.ndarray, np.ndarray]:
        """Sample k <= slots logits rows in ONE device call, padded to
        the decode path's single compiled (slots, V) shape.  Returns
        ``(tokens, bad)`` — ``bad`` is the fused watchdog's per-row
        non-finite flag (padding rows are zeros, never flagged)."""
        k = len(rows)
        lg = jnp.stack(rows)
        if k < self.slots:
            lg = jnp.pad(lg, ((0, self.slots - k), (0, 0)))
        temps = np.zeros((self.slots,), np.float32)
        temps[:k] = temps_list
        self.key, sub = jax.random.split(self.key)
        toks, bad = self.runner.sample(sub, lg, jnp.asarray(temps))
        return toks[:k], bad[:k]

    def _quarantine(self, slot: int) -> None:
        """Numerical-watchdog casualty: terminate the stream in
        ``slot`` as ``failed`` and reclaim its slot/blocks WITHOUT
        publishing to the radix (a poisoned cache must never seed
        future prompts)."""
        self.scheduler.quarantine(slot)
        self.pool.release(slot, publish=False)
        self.quarantined += 1

    # -- blocking admission (pre-scheduler path; recurrent/MoE/VLM) ---------

    def _admit_blocking(self) -> tuple[int, int]:
        """One whole prefill per admitted request (admission policy is
        the Scheduler's — same resume/byte-budget rules as the chunked
        path).  Returns (first tokens sampled, prompt tokens prefilled)."""
        started = self.scheduler.admit(self.pool)
        if not started:
            return 0, 0
        pf_toks = 0
        rows: list[jax.Array] = []
        for ps in started:
            n = len(ps.tokens)
            padded = np.zeros((1, self._bucket_len(n)), np.int32)
            padded[0, :n] = ps.tokens
            prompt = jnp.asarray(padded)
            cache1 = self.runner.new_stream_cache(
                kv_quantize=self.kv_quantize)
            if self.run.model.family == "vlm":
                batch = {"tokens": prompt,
                         "image_embeds": jnp.zeros(
                             (1, self.run.model.num_image_tokens,
                              self.run.model.d_model), self.model.dtype)}
            else:
                batch = {"tokens": prompt}
            logits, cache1 = self.runner.step(
                prompt, None, "prefill", cache=cache1, batch=batch,
                last_pos=jnp.asarray(n - 1, jnp.int32))
            self.pool.insert(cache1, ps.slot, n)
            self.scheduler.activate(ps)
            pf_toks += n
            rows.append(logits[0, -1, :])
        toks, bad = self._sample_rows(rows, [max(ps.req.temperature, 0.0)
                                             for ps in started])
        now = time.perf_counter()
        first = 0
        for ps, tok, flagged in zip(started, toks, bad):
            if flagged:
                self._quarantine(ps.slot)
                continue
            self._append_token(ps.req, int(tok), now)
            first += 1
            self._maybe_finish(ps.slot)
        return first, pf_toks

    # -- continuous admission: chunked prefill under the token budget -------

    def _prefill_chunks(self, n_live: int) -> tuple[int, int]:
        """Spend the step's leftover token budget on prefill chunks.
        Returns (prompt tokens prefilled, first tokens sampled)."""
        plan = self.scheduler.chunk_plan(n_live)
        if not plan:
            return 0, 0
        completed: list[PrefillStream] = []
        pf_toks = 0
        for ps, c in plan:
            if ps.cache is None:
                # full-precision staging (even over an int8 pool): chunk
                # attention sees the exact K/V prefix, the pool
                # quantizes once at insert -> chunked == whole, bit-exact
                ps.cache = self.runner.new_stream_cache()
                if ps.written:
                    # paged prefix hit: the first `written` positions'
                    # KV is already pooled — gather it into the staging
                    # cache (dequantizing int8 blocks) and chunk-prefill
                    # only the suffix
                    ps.cache = self.pool.gather_prefix(
                        ps.cache, ps.slot, ps.written)
            b = self._bucket_len(c)
            if ps.written + b > self.max_seq:   # keep the offset write
                b = self.max_seq - ps.written   # inside the slot
            padded = np.zeros((1, b), np.int32)
            padded[0, :c] = ps.tokens[ps.written:ps.written + c]
            # prompt_len = the chunk's real end: bucket-pad rows beyond
            # it are zeroed at the K/V write (attention masks them), so
            # correctness never depends on a later chunk overwriting
            # them.  On the final chunk this is the prompt length, which
            # also places the logits gather at the last real token.
            eff_len = min(len(ps.tokens), ps.written + c)
            logits, ps.cache = self.runner.step(
                jnp.asarray(padded), None, "prefill_chunk", cache=ps.cache,
                start_pos=jnp.asarray(ps.written, jnp.int32),
                prompt_len=jnp.asarray(eff_len, jnp.int32))
            ps.written += c
            pf_toks += c
            ps.last_logits = logits[0, 0, :]
            if ps.remaining == 0:
                completed.append(ps)
        return pf_toks, self._finish_prefills(completed)

    def _finish_prefills(self, completed: list[PrefillStream]) -> int:
        if not completed:
            return 0
        for ps in completed:
            self.pool.insert(ps.cache, ps.slot, len(ps.tokens),
                             from_full_precision=True)
            self.scheduler.activate(ps)
            ps.cache = None
        toks, bad = self._sample_rows([ps.last_logits for ps in completed],
                                      [max(ps.req.temperature, 0.0)
                                       for ps in completed])
        now = time.perf_counter()
        first = 0
        for ps, tok, flagged in zip(completed, toks, bad):
            if flagged:
                self._quarantine(ps.slot)
                continue
            self._append_token(ps.req, int(tok), now)
            first += 1
            self._maybe_finish(ps.slot)
        return first

    # -- lifecycle: cancel / deadlines --------------------------------------

    def cancel(self, uid: int) -> bool:
        """Cancel request ``uid`` wherever it is — waiting (including
        preempted-and-requeued), chunked-prefilling, or decode-active —
        releasing its slot, blocks, and COW refcounts.  The request
        terminates with status ``cancelled``; returns False when
        ``uid`` is unknown or already terminal."""
        sched, pool = self.scheduler, self.pool
        for req in sched.waiting:
            if req.uid == uid:
                sched.waiting.remove(req)
                sched.terminal(req, "cancelled")
                return True
        for ps in sched.prefilling:
            if ps.req.uid == uid:
                sched.prefilling.remove(ps)
                sched.terminal(ps.req, "cancelled")
                # a mid-prefill slot holds allocated (paged: possibly
                # radix-shared) blocks but no landed KV — release drops
                # exactly the refcounts admission took
                pool.release(ps.slot)
                return True
        for slot, req in enumerate(sched.active):
            if req is not None and req.uid == uid:
                sched.active[slot] = None
                sched.terminal(req, "cancelled")
                pool.release(slot)
                return True
        return False

    def _expire_deadlines(self) -> int:
        """Terminate every request whose ``deadline_s`` (anywhere) or
        ``max_queue_s`` (waiting only) has elapsed; returns the count."""
        sched, pool = self.scheduler, self.pool
        now = time.perf_counter()

        def over(req, budget):
            return (budget is not None and req.submit_time is not None
                    and now - req.submit_time > budget)

        n = 0
        for req in list(sched.waiting):
            if over(req, req.deadline_s) or over(req, req.max_queue_s):
                sched.waiting.remove(req)
                sched.terminal(req, "deadline_exceeded")
                n += 1
        for ps in list(sched.prefilling):
            if over(ps.req, ps.req.deadline_s):
                sched.prefilling.remove(ps)
                sched.terminal(ps.req, "deadline_exceeded")
                pool.release(ps.slot)
                n += 1
        for slot, req in enumerate(sched.active):
            if req is not None and over(req, req.deadline_s):
                sched.active[slot] = None
                sched.terminal(req, "deadline_exceeded")
                pool.release(slot)
                n += 1
        self.deadline_expired += n
        return n

    # -- main loop ----------------------------------------------------------

    def _decode_live(self, live: list[int]) -> int:
        pool = self.pool
        tokens = np.zeros((self.slots, 1), np.int32)
        for i in live:
            tokens[i, 0] = self.active[i].output[-1]
        logits, pool.cache = self.runner.step(
            jnp.asarray(tokens), jnp.asarray(pool.positions), "decode",
            cache=pool.cache)
        lg = logits[:, 0, :]
        temps = np.zeros((self.slots,), np.float32)
        for i in live:
            temps[i] = max(self.active[i].temperature, 0.0)
        self.key, sub = jax.random.split(self.key)
        toks, bad = self.runner.sample(sub, lg, jnp.asarray(temps))
        now = time.perf_counter()
        produced = 0
        for i in live:
            if bad[i]:
                # non-finite logits: quarantine before the token is
                # appended or any KV growth is accounted — neighbors'
                # streams are untouched (per-row sampling)
                self._quarantine(i)
                continue
            self._append_token(self.active[i], int(toks[i]), now)
            # the KV this step wrote at the slot's position belongs to
            # the *input* token — the paged pool's prefix registry
            # tracks it so released blocks stay radix-matchable
            try:
                pool.grow(i, token=int(tokens[i, 0]))
            except PoolExhausted:
                # no block for the next write: preempt this stream (it
                # resumes by re-prefilling prompt + output, including
                # the token just sampled); `grow` is atomic, so state
                # is exactly pre-call
                self.scheduler.preempt(i)
                pool.release(i)
                produced += 1
                continue
            produced += 1
            self._maybe_finish(i)
        return produced

    def step(self) -> int:
        """One scheduler step: expire deadlines, preempt under KV
        pressure, admit (unless the load shedder pauses it), decode
        every live stream, then spend leftover budget on prefill
        chunks.  Returns tokens produced (decode + first tokens)."""
        with self._on_device():
            return self._step()

    def _step(self) -> int:
        sched, pool = self.scheduler, self.pool
        self._step_idx += 1
        self._step_token_reqs.clear()
        self.stragglers.start()
        self._expire_deadlines()
        victims = pool.pressure_victims()
        for slot in victims:
            sched.preempt(slot)
            pool.release(slot)
        admit_fail0 = sched.admit_failures
        shed = False
        if self.shedder is not None:
            # degraded mode: run with the shrunk budget; pause
            # admission only while work is already in flight (an idle
            # engine must always admit — shedding can never deadlock
            # the queue)
            sched.step_token_budget = self.shedder.budget
            shed = self.shedder.engaged and (
                bool(sched.prefilling)
                or any(r is not None for r in sched.active))
        if self.admission == "blocking":
            t0 = time.perf_counter()
            first, pf_toks = (0, 0) if shed else self._admit_blocking()
            admit_s = time.perf_counter() - t0
            live = sched.live_slots()
            produced, decode_s, prefill_s = 0, 0.0, 0.0
            if live:
                t0 = time.perf_counter()
                produced = self._decode_live(live)
                decode_s = time.perf_counter() - t0
            record = bool(live or first)
        else:
            if not shed:
                sched.admit(pool)
            live = sched.live_slots()
            t0 = time.perf_counter()
            produced = self._decode_live(live) if live else 0
            decode_s = time.perf_counter() - t0
            t0 = time.perf_counter()
            pf_toks, first = self._prefill_chunks(len(live))
            prefill_s = time.perf_counter() - t0
            admit_s = 0.0
            record = bool(live or pf_toks or first)
        event = self.stragglers.stop(self._step_idx)
        if self.shedder is not None:
            self.shedder.observe(bool(victims)
                                 or sched.admit_failures > admit_fail0
                                 or self.slo_pressure)
        self.slo_pressure = False
        if record:
            self.stats.append({"live": len(live), "tokens": produced,
                               "seconds": decode_s,
                               "prefill_tokens": pf_toks,
                               "prefill_seconds": prefill_s,
                               "first_tokens": first,
                               "admit_seconds": admit_s,
                               "preempted": len(victims),
                               "admit_failures":
                                   sched.admit_failures - admit_fail0,
                               "shed": int(shed),
                               "straggler": int(event is not None)})
        # service-time ITL: every non-first token produced this step
        # samples the service seconds since the stream's previous token
        # (usually exactly this step's duration; preemption gaps span
        # the resume's prefill steps too)
        self.service_s += admit_s + decode_s + prefill_s
        key = id(self)
        for req in self._step_token_reqs:
            mark = req.service_mark
            if mark is not None and mark[0] == key:
                self.class_itl[req.priority].append(
                    self.service_s - mark[1])
            req.service_mark = (key, self.service_s)
        self._step_token_reqs.clear()
        if self.debug:
            pool.check_integrity()
        return produced + first

    def _fail_survivors(self) -> int:
        """No-progress watchdog firing: terminate everything still in
        flight or queued as ``failed`` and reclaim its pool state, so
        a stalled engine surfaces explicit statuses instead of
        silently losing requests."""
        sched, pool = self.scheduler, self.pool
        n = 0
        while sched.waiting:
            sched.terminal(sched.waiting.popleft(), "failed")
            n += 1
        for ps in list(sched.prefilling):
            sched.prefilling.remove(ps)
            sched.terminal(ps.req, "failed")
            pool.release(ps.slot, publish=False)
            n += 1
        for slot, req in enumerate(sched.active):
            if req is not None:
                sched.active[slot] = None
                sched.terminal(req, "failed")
                pool.release(slot, publish=False)
                n += 1
        return n

    def run_until_done(self, max_steps: int = 10_000) -> list[Request]:
        """Drive the engine until queue + slots drain; returns the
        requests that completed (any terminal status) during this call,
        in completion order.

        Two watchdogs close the silent-loss holes of the naive loop:
        ``stall_steps`` consecutive steps with zero progress (no
        tokens, no prefill, no admissions, no terminal transitions)
        mark every survivor ``failed`` and return — a scheduler
        deadlock surfaces as explicit statuses; and exhausting
        ``max_steps`` with work still in flight raises instead of
        returning as if drained."""
        sched = self.scheduler
        start = len(self.finished)
        stalled = 0
        for _ in range(max_steps):
            if not sched.busy():
                break
            fin0 = len(self.finished)
            prev = self.stats[-1] if self.stats else None
            produced = self.step()
            entry = (self.stats[-1]
                     if self.stats and self.stats[-1] is not prev
                     else None)
            progressed = (produced > 0
                          or len(self.finished) > fin0
                          or bool(entry and entry["prefill_tokens"]))
            stalled = 0 if progressed else stalled + 1
            if stalled >= self.stall_steps:
                self._fail_survivors()
                break
        else:
            if sched.busy():
                raise RuntimeError(
                    f"run_until_done: {max_steps} steps exhausted with "
                    f"{len(sched.waiting)} waiting, "
                    f"{len(sched.prefilling)} prefilling, "
                    f"{len(sched.live_slots())} active requests still "
                    "in flight")
        return self.finished[start:]

    def class_stats(self, priority: str) -> dict:
        """Per-class p50/p99 inter-token latency + TTFT (milliseconds)
        over the bounded sample rings, plus terminal request count."""
        done = sum(1 for r in self.finished if r.priority == priority)
        return latency_summary(self.class_itl[priority],
                               self.class_ttft[priority], requests=done)

    def throughput(self) -> dict:
        """Aggregate serving stats over the (bounded) stats window.
        Unlike the pre-split engine, the denominator includes the time
        spent admitting/prefilling, not just decode steps — and TTFT is
        reported from per-request timestamps.

        The key set is identical whether or not any productive step was
        recorded (an idle engine reports zeros, not a narrower dict) —
        the only conditional keys are the ``shed_*``/``degradation_*``
        group, present iff the engine has a load shedder at all.
        """
        stats = list(self.stats)
        status_counts: dict[str, int] = {}
        for r in self.finished:
            key = r.status or "finished"
            status_counts[key] = status_counts.get(key, 0) + 1
        ttfts = [r.ttft for r in self.finished if r.ttft is not None]
        out = {"tokens_per_s": 0.0,
               "steps": len(stats),
               "mean_batch": 0.0,
               "decode_seconds": 0.0,
               "prefill_seconds": 0.0,
               "prefill_tokens": 0,
               "preemptions": self.scheduler.preemptions,
               # hardening counters
               "admit_failures": self.scheduler.admit_failures,
               "quarantined": self.quarantined,
               "deadline_expired": self.deadline_expired,
               "status_counts": status_counts,
               "slow_steps": len(self.stragglers.events),
               "step_ewma_s": self.stragglers.ewma,
               "ttft_mean_s": (sum(ttfts) / len(ttfts)) if ttfts else 0.0,
               "per_class": {p: self.class_stats(p) for p in PRIORITIES}}
        if self.shedder is not None:
            out["shed_steps"] = sum(s.get("shed", 0) for s in stats)
            out["degradation_engaged"] = self.shedder.engaged
            out["degradation_engages"] = self.shedder.engage_count
            out["degradation_recoveries"] = self.shedder.recover_count
        if stats:
            dec = sum(s["tokens"] for s in stats)
            first = sum(s.get("first_tokens", 0) for s in stats)
            dec_s = sum(s["seconds"] for s in stats)
            pf_s = sum(s.get("prefill_seconds", 0.0) for s in stats)
            ad_s = sum(s.get("admit_seconds", 0.0) for s in stats)
            out["tokens_per_s"] = (dec + first) / max(dec_s + pf_s + ad_s,
                                                      1e-9)
            out["mean_batch"] = dec / len(stats)
            out["decode_seconds"] = dec_s
            out["prefill_seconds"] = pf_s + ad_s
            out["prefill_tokens"] = sum(s.get("prefill_tokens", 0)
                                        for s in stats)
        return out

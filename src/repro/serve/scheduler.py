"""Scheduler: token-budget continuous batching (decode-first policy).

One engine step is one *step plan* filled against ``step_token_budget``:

1. **Preempt** — if the :class:`~repro.serve.pool.KVPoolManager` is
   over its byte budget, the youngest stream(s) are evicted.  An
   evicted request re-enters the waiting queue (at the front) holding
   its generated prefix: on readmission it prefills
   ``prompt + output`` and keeps decoding — bit-exact under greedy
   sampling because chunked prefill == whole prefill == decode.
2. **Admit** — waiting requests take free slots while the pool's byte
   budget allows.  The queue is a :class:`ClassedQueue`: FIFO within a
   priority class, ``interactive`` ahead of ``batch`` across classes
   (pure submission-order FIFO when ``priority_aware=False``).
   Admission only *starts* a prefill stream; there is no blocking
   whole-prompt prefill on this path.
3. **Decode first** — every live stream decodes one token per step,
   unconditionally.  A long prompt can never head-of-line-block live
   decode streams.
4. **Prefill with the remainder** — leftover budget
   (``step_token_budget - live``) is spent on chunked-prefill segments
   of at most ``prefill_chunk`` tokens, oldest prefilling stream
   first.  Chunk *compute* shapes are power-of-2 bucketed by the
   engine (compile once per bucket); the budget counts real tokens.

If the budget is smaller than the live batch, decode still runs in
full (decode-first is strict) and prefill waits; with no live streams
at least one bucket of prefill always proceeds, so the queue can never
deadlock.

The scheduler is family-agnostic — which families take continuous
admission is the engine's gate (dense GQA *and* dense MLA latent
stacks chunk; recurrent/MoE-capacity/VLM stay blocking), and cache
layout is the :class:`repro.layers.cache.CachePlan`'s concern.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any

from repro.serve.paging import PoolExhausted

PyTree = Any

#: admission pads prompts (and prefill chunks) up to at least this
#: power-of-2 length bucket
PREFILL_BUCKET_MIN = 8

#: terminal request states.  Every request that leaves the engine
#: carries exactly one of these in :attr:`Request.status`:
#:
#: * ``finished`` — ran to EOS / ``max_new_tokens``;
#: * ``cancelled`` — :meth:`repro.serve.engine.ServeEngine.cancel`;
#: * ``deadline_exceeded`` — ``deadline_s`` / ``max_queue_s`` expired;
#: * ``failed`` — quarantined by the numerical watchdog, or swept by
#:   the no-progress watchdog;
#: * ``dropped`` — preemption-retry budget spent (``max_preemptions``
#:   evictions) — terminated instead of thrashing the pool forever.
STATUSES = ("finished", "cancelled", "deadline_exceeded", "failed",
            "dropped")

#: request priority classes, highest first.  ``interactive`` streams
#: are admitted and chunk-planned ahead of ``batch`` at every decision
#: point; ``batch`` fills whatever budget is left.
PRIORITIES = ("interactive", "batch")


@dataclasses.dataclass
class Request:
    uid: int
    prompt: list[int]
    max_new_tokens: int = 32
    temperature: float = 0.0
    eos_id: int | None = None
    #: wall-clock SLO: seconds from submit to completion; expired
    #: requests terminate ``deadline_exceeded`` wherever they are
    #: (waiting, prefilling, or decoding)
    deadline_s: float | None = None
    #: max seconds a request may sit *unadmitted* in the waiting queue
    max_queue_s: float | None = None
    #: preemption-retry budget: one more eviction than this terminates
    #: the request ``dropped``
    max_preemptions: int = 8
    #: one of :data:`PRIORITIES` — interactive streams decode/admit
    #: first, batch fills residual budget
    priority: str = "interactive"
    # filled by the engine:
    output: list[int] = dataclasses.field(default_factory=list)
    done: bool = False
    #: one of :data:`STATUSES` once terminal, else ``None``
    status: str | None = None
    # timing / lifecycle bookkeeping (engine-filled):
    submit_time: float | None = None
    first_token_time: float | None = None
    token_times: list[float] = dataclasses.field(default_factory=list)
    preemptions: int = 0
    #: ``(engine key, engine service seconds at last token)`` — the
    #: engine's service-time ITL accounting; the key guards against a
    #: stale mark after an evacuation re-routes the request
    service_mark: tuple[int, float] | None = None

    @property
    def ttft(self) -> float | None:
        """Time-to-first-token (seconds), once both ends are stamped."""
        if self.submit_time is None or self.first_token_time is None:
            return None
        return self.first_token_time - self.submit_time


@dataclasses.dataclass
class PrefillStream:
    """An admitted request whose prompt is being prefilled in chunks."""
    req: Request
    slot: int
    tokens: list[int]            # prompt (+ generated prefix if resumed)
    written: int = 0             # real prompt tokens already processed
    cache: PyTree = None         # full-precision staging cache (lazy)
    last_logits: Any = None      # (V,) logits at the last real row seen

    @property
    def remaining(self) -> int:
        return len(self.tokens) - self.written


class ClassedQueue:
    """Per-priority-class waiting queues behind the old single-deque
    surface.

    Iteration/peek/popleft order is *interactive first, FIFO within
    class* when ``aware`` (the default), or pure submission-order FIFO
    when priority-blind (the baseline the router bench compares
    against).  Every deque operation the engine performs on
    ``Scheduler.waiting`` — truthiness, ``len``, iteration, ``remove``,
    ``append``/``appendleft``/``popleft``, head peek — works unchanged,
    so all existing single-class behavior is bit-identical (a lone
    class is just a lone deque).
    """

    def __init__(self, aware: bool = True):
        self.aware = aware
        self.by_class: dict[str, deque[Request]] = (
            {p: deque() for p in PRIORITIES} if aware
            else {PRIORITIES[0]: deque()})

    def _cls(self, req: Request) -> str:
        return req.priority if self.aware else PRIORITIES[0]

    def append(self, req: Request) -> None:
        self.by_class[self._cls(req)].append(req)

    def appendleft(self, req: Request) -> None:
        self.by_class[self._cls(req)].appendleft(req)

    def popleft(self) -> Request:
        for q in self.by_class.values():
            if q:
                return q.popleft()
        raise IndexError("pop from an empty ClassedQueue")

    def remove(self, req: Request) -> None:
        self.by_class[self._cls(req)].remove(req)

    def count(self, priority: str) -> int:
        if not self.aware:
            return sum(1 for r in self.by_class[PRIORITIES[0]]
                       if r.priority == priority)
        return len(self.by_class[priority])

    def __iter__(self):
        for q in self.by_class.values():
            yield from q

    def __len__(self) -> int:
        return sum(len(q) for q in self.by_class.values())

    def __bool__(self) -> bool:
        return any(self.by_class.values())

    def __getitem__(self, i: int):
        if i == 0:          # head peek — the only index the engine uses
            for q in self.by_class.values():
                if q:
                    return q[0]
            raise IndexError("peek at an empty ClassedQueue")
        return list(self)[i]


class Scheduler:
    """Request lifecycle + per-step segment planning."""

    def __init__(self, slots: int, *, prefill_chunk: int,
                 step_token_budget: int, priority_aware: bool = True,
                 batch_share: float = 1.0):
        self.slots = slots
        self.prefill_chunk = max(1, prefill_chunk)
        self.step_token_budget = max(1, step_token_budget)
        #: honor :attr:`Request.priority` in queueing and planning;
        #: ``False`` degrades to the old single-FIFO behavior (the
        #: priority-blind baseline)
        self.priority_aware = priority_aware
        #: fraction of the per-step prefill quota that ``batch``
        #: prefill segments may take *while interactive work is in
        #: flight* (1.0 = no throttle; batch always gets the full
        #: residual quota once interactive traffic drains)
        self.batch_share = min(max(float(batch_share), 0.0), 1.0)
        self.waiting = ClassedQueue(priority_aware)
        self.prefilling: list[PrefillStream] = []
        self.active: list[Request | None] = [None] * slots
        self.finished: list[Request] = []
        self.preemptions = 0
        #: admissions refused for capacity (byte budget or a real
        #: :class:`~repro.serve.paging.PoolExhausted`) — one of the two
        #: pressure signals the load shedder watches
        self.admit_failures = 0

    # -- lifecycle ----------------------------------------------------------

    def submit(self, req: Request) -> None:
        if req.priority not in PRIORITIES:
            raise ValueError(f"unknown priority {req.priority!r} "
                             f"(want one of {PRIORITIES})")
        self.waiting.append(req)

    def terminal(self, req: Request, status: str) -> Request:
        """Move ``req`` to its terminal state: stamp ``status``, mark
        done, record in ``finished``.  The single exit point every path
        (finish, cancel, deadline, quarantine, drop) funnels through —
        no request leaves the engine without an explicit status."""
        if status not in STATUSES:
            raise ValueError(
                f"unknown terminal status {status!r} "
                f"(want one of {STATUSES})")
        req.status = status
        req.done = True
        self.finished.append(req)
        return req

    def busy(self) -> bool:
        return bool(self.waiting or self.prefilling
                    or any(r is not None for r in self.active))

    def interactive_inflight(self) -> bool:
        """Any interactive stream currently decoding or prefilling?
        (Waiting does not count — an unadmitted request has no tail to
        protect yet.)"""
        return (any(r is not None and r.priority == PRIORITIES[0]
                    for r in self.active)
                or any(ps.req.priority == PRIORITIES[0]
                       for ps in self.prefilling))

    def interactive_pending(self) -> bool:
        """Any interactive work at all — in flight *or* still waiting?
        The router's SLO gate uses this: a batch request admitted while
        interactive requests sit unadmitted would steal their slots and
        prefill budget before the tail is even measurable."""
        return (self.interactive_inflight()
                or self.waiting.count(PRIORITIES[0]) > 0)

    def batch_pending(self) -> bool:
        """Any batch work in flight or waiting?  The router only
        asserts ``slo_pressure`` (early load shedding) on a replica
        that actually has batch load to shed — shedding a
        pure-interactive replica could only hurt the tail it is meant
        to protect."""
        batch = PRIORITIES[1]
        return (any(r is not None and r.priority == batch
                    for r in self.active)
                or any(ps.req.priority == batch for ps in self.prefilling)
                or self.waiting.count(batch) > 0)

    def live_slots(self) -> list[int]:
        return [i for i, r in enumerate(self.active) if r is not None]

    def admit(self, pool) -> list[PrefillStream]:
        """Move waiting requests into free slots while the byte budget
        allows (FIFO — the head blocks rather than being skipped).
        Capacity refusals — ``can_admit`` saying no, or ``allocate``
        itself raising :class:`~repro.serve.paging.PoolExhausted` (the
        radix-informed feasibility check is optimistic about shared
        blocks) — count into ``admit_failures`` and leave the request
        queued at the head; it retries next step."""
        started: list[PrefillStream] = []
        for slot in pool.free_slots():
            if not self.waiting:
                break
            req = self.waiting[0]
            # a preempted request resumes by re-prefilling its prompt
            # plus everything it already generated
            toks = list(req.prompt) + list(req.output)
            if not pool.can_admit(len(toks), tokens=toks):
                self.admit_failures += 1
                break
            # a paged pool prefix-matches the prompt against its radix
            # cache: `matched` leading tokens are already pooled, so the
            # stream starts with them written (the engine gathers their
            # KV into the staging cache before the first chunk)
            try:
                matched = pool.allocate(slot, len(toks), tokens=toks)
            except PoolExhausted:
                self.admit_failures += 1
                break
            self.waiting.popleft()
            ps = PrefillStream(req, slot, toks, written=matched)
            self.prefilling.append(ps)
            started.append(ps)
        return started

    def activate(self, ps: PrefillStream) -> None:
        self.prefilling.remove(ps)
        self.active[ps.slot] = ps.req

    def finish(self, slot: int) -> Request:
        req = self.active[slot]
        self.terminal(req, "finished")
        self.active[slot] = None
        return req

    def quarantine(self, slot: int) -> Request:
        """Terminate the stream in ``slot`` (decode-live or
        mid-prefill) as ``failed`` — the numerical watchdog flagged its
        logits.  The caller reclaims the pool slot (with
        ``publish=False``: a poisoned cache must never enter the shared
        radix)."""
        req = self.active[slot]
        if req is not None:
            self.active[slot] = None
        else:
            ps = next(p for p in self.prefilling if p.slot == slot)
            self.prefilling.remove(ps)
            req = ps.req
        return self.terminal(req, "failed")

    def preempt(self, slot: int) -> Request:
        """Evict the stream in ``slot`` (decode-live or mid-prefill).
        Within its retry budget it requeues at the queue head with its
        generated prefix; past the budget it terminates ``dropped``
        (bounded work per request — no preemption thrashing)."""
        req = self.active[slot]
        if req is not None:
            self.active[slot] = None
        else:
            ps = next(p for p in self.prefilling if p.slot == slot)
            self.prefilling.remove(ps)
            req = ps.req
        req.preemptions += 1
        self.preemptions += 1
        if req.preemptions > req.max_preemptions:
            self.terminal(req, "dropped")
        else:
            self.waiting.appendleft(req)
        return req

    # -- per-step planning --------------------------------------------------

    def prefill_quota(self, n_live: int) -> int:
        """Real prefill tokens this step may spend: whatever the budget
        leaves after decode-first, but never zero when nothing is
        decoding (guaranteed progress — the queue cannot stall)."""
        quota = self.step_token_budget - n_live
        if n_live == 0:
            quota = max(quota, 1)
        return max(quota, 0)

    def chunk_plan(self, n_live: int) -> list[tuple[PrefillStream, int]]:
        """(stream, real-token chunk length) segments for this step,
        oldest prefilling stream first, until the quota is spent.

        When :attr:`priority_aware`, interactive streams plan ahead of
        batch regardless of admission order, and — while interactive
        work is in flight — batch segments are additionally capped to
        ``batch_share`` of the quota (interactive prefill takes the
        rest; batch gets the full quota back once interactive drains).
        Progress is guaranteed: with nothing decoding, at least one
        stream always gets a non-empty segment, share-capped or not.

        Non-final segments are always exactly :attr:`prefill_chunk`
        real tokens: a runt segment (leftover quota smaller than the
        chunk) would be a fresh compile shape per distinct residual —
        several streams splitting one step's quota used to generate
        3-token prefill launches whose first-time compiles dwarfed the
        tokens they carried.  A stream whose turn only has runt quota
        left simply waits for the next step; final chunks stay
        arbitrary-length (the engine buckets them to a bounded shape
        set).
        """
        quota = self.prefill_quota(n_live)
        streams = self.prefilling
        batch_quota = quota
        if self.priority_aware:
            first = [ps for ps in self.prefilling
                     if ps.req.priority == PRIORITIES[0]]
            rest = [ps for ps in self.prefilling
                    if ps.req.priority != PRIORITIES[0]]
            streams = first + rest
            if self.batch_share < 1.0 and self.interactive_inflight():
                batch_quota = int(quota * self.batch_share)
        plan: list[tuple[PrefillStream, int]] = []
        for ps in streams:
            if quota <= 0:
                break
            c = min(self.prefill_chunk, quota, ps.remaining)
            if self.priority_aware and ps.req.priority != PRIORITIES[0]:
                c = min(c, batch_quota)
            if c <= 0:
                continue
            if c < self.prefill_chunk and c < ps.remaining:
                continue    # runt non-final segment — wait a step
            plan.append((ps, c))
            quota -= c
            if self.priority_aware and ps.req.priority != PRIORITIES[0]:
                batch_quota -= c
        if not plan and self.prefilling and n_live == 0:
            # every stream was share-capped to zero and nothing is
            # decoding: force one segment so the queue can never stall
            ps = self.prefilling[0]
            c = min(self.prefill_chunk, max(quota, 1), ps.remaining)
            if c > 0:
                plan.append((ps, c))
        return plan


# ---------------------------------------------------------------------------
# Graceful degradation
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class DegradationPolicy:
    """Watermarks for the :class:`LoadShedder` hysteresis.

    Pressure events (a preemption or an admission failure in a step)
    are counted over a rolling ``window`` of steps.  At or above
    ``engage * window`` events the shedder engages; it only disengages
    once the count falls to ``disengage * window`` or below AND at
    least ``min_engaged_steps`` have passed — the dead band plus the
    minimum dwell prevents flapping at the watermark.
    """
    window: int = 16
    engage: float = 0.5
    disengage: float = 0.0625          # <= 1 event left in the window
    #: engaged ``step_token_budget`` multiplier (less prefill per step
    #: -> fewer concurrent residents -> pool pressure drains)
    budget_factor: float = 0.5
    min_engaged_steps: int = 8


class LoadShedder:
    """Pressure-watching hysteresis switch over the step loop.

    One :meth:`observe` call per engine step with that step's pressure
    bit.  While engaged, the engine (a) runs with ``budget`` — a shrunk
    ``step_token_budget`` — and (b) pauses admission whenever work is
    already in flight (never when the engine is idle: an empty engine
    must always be allowed to start, so shedding can never deadlock the
    queue).  Recovery is automatic when pressure clears.
    """

    def __init__(self, policy: DegradationPolicy, base_budget: int):
        self.policy = policy
        self.base_budget = base_budget
        self.events: deque[int] = deque(maxlen=policy.window)
        self.engaged = False
        self.engaged_steps = 0
        self.engage_count = 0
        self.recover_count = 0

    @property
    def pressure_events(self) -> int:
        return sum(self.events)

    def observe(self, pressure: bool) -> bool:
        """Record one step's pressure bit; returns the (possibly
        toggled) engaged state."""
        self.events.append(1 if pressure else 0)
        p = self.policy
        if self.engaged:
            self.engaged_steps += 1
            if (self.engaged_steps >= p.min_engaged_steps
                    and self.pressure_events <= p.disengage * p.window):
                self.engaged = False
                self.recover_count += 1
        elif self.pressure_events >= p.engage * p.window:
            self.engaged = True
            self.engaged_steps = 0
            self.engage_count += 1
        return self.engaged

    @property
    def budget(self) -> int:
        """The step token budget to run with right now."""
        if self.engaged:
            return max(1, int(self.base_budget * self.policy.budget_factor))
        return self.base_budget

"""Scheduler: token-budget continuous batching (decode-first policy).

One engine step is one *step plan* filled against ``step_token_budget``:

1. **Preempt** — if the :class:`~repro.serve.pool.KVPoolManager` is
   over its byte budget, the youngest stream(s) are evicted.  An
   evicted request re-enters the waiting queue (at the front) holding
   its generated prefix: on readmission it prefills
   ``prompt + output`` and keeps decoding — bit-exact under greedy
   sampling because chunked prefill == whole prefill == decode.
2. **Admit** — waiting requests (FIFO ``deque``) take free slots while
   the pool's byte budget allows.  Admission only *starts* a prefill
   stream; there is no blocking whole-prompt prefill on this path.
3. **Decode first** — every live stream decodes one token per step,
   unconditionally.  A long prompt can never head-of-line-block live
   decode streams.
4. **Prefill with the remainder** — leftover budget
   (``step_token_budget - live``) is spent on chunked-prefill segments
   of at most ``prefill_chunk`` tokens, oldest prefilling stream
   first.  Chunk *compute* shapes are power-of-2 bucketed by the
   engine (compile once per bucket); the budget counts real tokens.

If the budget is smaller than the live batch, decode still runs in
full (decode-first is strict) and prefill waits; with no live streams
at least one bucket of prefill always proceeds, so the queue can never
deadlock.

The scheduler is family-agnostic — which families take continuous
admission is the engine's gate (dense GQA *and* dense MLA latent
stacks chunk; recurrent/MoE-capacity/VLM stay blocking), and cache
layout is the :class:`repro.layers.cache.CachePlan`'s concern.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any

PyTree = Any

#: admission pads prompts (and prefill chunks) up to at least this
#: power-of-2 length bucket
PREFILL_BUCKET_MIN = 8


@dataclasses.dataclass
class Request:
    uid: int
    prompt: list[int]
    max_new_tokens: int = 32
    temperature: float = 0.0
    eos_id: int | None = None
    # filled by the engine:
    output: list[int] = dataclasses.field(default_factory=list)
    done: bool = False
    # timing / lifecycle bookkeeping (engine-filled):
    submit_time: float | None = None
    first_token_time: float | None = None
    token_times: list[float] = dataclasses.field(default_factory=list)
    preemptions: int = 0

    @property
    def ttft(self) -> float | None:
        """Time-to-first-token (seconds), once both ends are stamped."""
        if self.submit_time is None or self.first_token_time is None:
            return None
        return self.first_token_time - self.submit_time


@dataclasses.dataclass
class PrefillStream:
    """An admitted request whose prompt is being prefilled in chunks."""
    req: Request
    slot: int
    tokens: list[int]            # prompt (+ generated prefix if resumed)
    written: int = 0             # real prompt tokens already processed
    cache: PyTree = None         # full-precision staging cache (lazy)
    last_logits: Any = None      # (V,) logits at the last real row seen

    @property
    def remaining(self) -> int:
        return len(self.tokens) - self.written


class Scheduler:
    """Request lifecycle + per-step segment planning."""

    def __init__(self, slots: int, *, prefill_chunk: int,
                 step_token_budget: int):
        self.slots = slots
        self.prefill_chunk = max(1, prefill_chunk)
        self.step_token_budget = max(1, step_token_budget)
        self.waiting: deque[Request] = deque()
        self.prefilling: list[PrefillStream] = []
        self.active: list[Request | None] = [None] * slots
        self.finished: list[Request] = []
        self.preemptions = 0

    # -- lifecycle ----------------------------------------------------------

    def submit(self, req: Request) -> None:
        self.waiting.append(req)

    def busy(self) -> bool:
        return bool(self.waiting or self.prefilling
                    or any(r is not None for r in self.active))

    def live_slots(self) -> list[int]:
        return [i for i, r in enumerate(self.active) if r is not None]

    def admit(self, pool) -> list[PrefillStream]:
        """Move waiting requests into free slots while the byte budget
        allows (FIFO — the head blocks rather than being skipped)."""
        started: list[PrefillStream] = []
        for slot in pool.free_slots():
            if not self.waiting:
                break
            req = self.waiting[0]
            # a preempted request resumes by re-prefilling its prompt
            # plus everything it already generated
            toks = list(req.prompt) + list(req.output)
            if not pool.can_admit(len(toks), tokens=toks):
                break
            self.waiting.popleft()
            # a paged pool prefix-matches the prompt against its radix
            # cache: `matched` leading tokens are already pooled, so the
            # stream starts with them written (the engine gathers their
            # KV into the staging cache before the first chunk)
            matched = pool.allocate(slot, len(toks), tokens=toks)
            ps = PrefillStream(req, slot, toks, written=matched)
            self.prefilling.append(ps)
            started.append(ps)
        return started

    def activate(self, ps: PrefillStream) -> None:
        self.prefilling.remove(ps)
        self.active[ps.slot] = ps.req

    def finish(self, slot: int) -> Request:
        req = self.active[slot]
        req.done = True
        self.finished.append(req)
        self.active[slot] = None
        return req

    def preempt(self, slot: int) -> Request:
        """Evict the stream in ``slot`` (decode-live or mid-prefill) and
        requeue it at the queue head with its generated prefix."""
        req = self.active[slot]
        if req is not None:
            self.active[slot] = None
        else:
            ps = next(p for p in self.prefilling if p.slot == slot)
            self.prefilling.remove(ps)
            req = ps.req
        req.preemptions += 1
        self.preemptions += 1
        self.waiting.appendleft(req)
        return req

    # -- per-step planning --------------------------------------------------

    def prefill_quota(self, n_live: int) -> int:
        """Real prefill tokens this step may spend: whatever the budget
        leaves after decode-first, but never zero when nothing is
        decoding (guaranteed progress — the queue cannot stall)."""
        quota = self.step_token_budget - n_live
        if n_live == 0:
            quota = max(quota, 1)
        return max(quota, 0)

    def chunk_plan(self, n_live: int) -> list[tuple[PrefillStream, int]]:
        """(stream, real-token chunk length) segments for this step,
        oldest prefilling stream first, until the quota is spent."""
        quota = self.prefill_quota(n_live)
        plan: list[tuple[PrefillStream, int]] = []
        for ps in self.prefilling:
            if quota <= 0:
                break
            c = min(self.prefill_chunk, quota, ps.remaining)
            if c <= 0:
                continue
            plan.append((ps, c))
            quota -= c
        return plan

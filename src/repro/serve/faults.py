"""Deterministic fault injection for the serve tier.

The serve-side twin of :mod:`repro.train.fault_tolerance`'s
fault-injection-driven testing discipline: a seeded
:class:`FaultInjector` with **named injection points** threaded through
the pool managers (allocation, radix matching, scale corruption), the
:class:`repro.serve.runner.ModelRunner` step (NaN logits, slow steps),
and kernel dispatch (:func:`repro.kernels.ops.kernel_fits` rejection).
Everything is *off by default* — an engine built without an injector
carries :data:`NULL_INJECTOR`, whose :meth:`~FaultInjector.fire` is a
constant ``False`` — and completely deterministic when on: each point
draws from its own ``random.Random`` stream keyed by ``(seed, point)``,
so one point's firing pattern never depends on how often another point
was consulted.

The chaos suite (``tests/test_serve_faults.py``) drives every point
against both pool layouts and both cache dtypes and asserts the engine
always converges to a consistent terminal state: every request carries
an explicit :class:`~repro.serve.scheduler.Request` status,
``check_integrity()`` passes, and ``used_bytes() == 0`` after drain.

Injection points
----------------

``pool_alloc``
    Slot/block allocation raises
    :class:`repro.serve.paging.PoolExhausted` (the exception the real
    paged pool raises when the free list AND the cold LRU are dry).
    Fired in ``allocate`` on both managers and in the paged ``grow``
    when a decode write crosses into an unallocated block.
``radix_match``
    The admission radix lookup returns no hits — prefix reuse silently
    disabled for that admission (the stream must re-prefill, and the
    blocks ``can_admit`` assumed shared must be allocated fresh, which
    can in turn exhaust the pool).
``nan_logits``
    A runner step's logits are poisoned with NaN after the jitted call
    (one slot row on decode — ``params={"nan_logits": {"slot": i}}`` —
    the whole segment on prefill paths).  Drives the
    :mod:`repro.serve.guard` quarantine path.
``kernel_gate``
    :func:`repro.kernels.ops.kernel_fits` rejects, forcing the jnp
    reference fallback at trace time (module-global hook — see
    :func:`repro.kernels.ops.set_fault_injector`).
``block_scale``
    One freshly inserted int8 scale row (slot pool: the stream's slot;
    paged pool: the stream's first physical block) is corrupted to
    ``+inf`` — dequantized KV goes non-finite and the stream's next
    logits trip the watchdog.  A no-op on f32 pools.
``slow_step``
    The runner step sleeps ``params={"slow_step": {"seconds": s}}``
    (default 0.05) — drives the serve
    :class:`~repro.train.fault_tolerance.StragglerDetector`.
"""
from __future__ import annotations

import random
from typing import Any, Iterable, Mapping

__all__ = ["FaultInjector", "NULL_INJECTOR", "INJECTION_POINTS"]

#: every named injection point (typo guard: specs naming anything else
#: raise at construction)
INJECTION_POINTS = (
    "pool_alloc",
    "radix_match",
    "nan_logits",
    "kernel_gate",
    "block_scale",
    "slow_step",
)


class FaultInjector:
    """Seeded, per-point-deterministic fault source.

    ``rates`` maps point -> probability per consultation; ``schedule``
    maps point -> 1-based consultation indices that fire exactly (tests
    pin "poison decode call #3" this way); ``max_fires`` caps total
    fires per point (e.g. poison exactly one step under a rate);
    ``params`` carries per-point knobs read via :meth:`param`.
    """

    def __init__(self, seed: int = 0,
                 rates: Mapping[str, float] | None = None,
                 schedule: Mapping[str, Iterable[int]] | None = None,
                 params: Mapping[str, Mapping[str, Any]] | None = None,
                 max_fires: Mapping[str, int] | None = None):
        self.seed = seed
        self.rates = dict(rates or {})
        self.schedule = {k: frozenset(int(i) for i in v)
                         for k, v in (schedule or {}).items()}
        self.params = {k: dict(v) for k, v in (params or {}).items()}
        self.max_fires = dict(max_fires or {})
        for point in (set(self.rates) | set(self.schedule)
                      | set(self.params) | set(self.max_fires)):
            if point not in INJECTION_POINTS:
                raise ValueError(
                    f"unknown injection point {point!r} "
                    f"(want one of {INJECTION_POINTS})")
        self.calls: dict[str, int] = {p: 0 for p in INJECTION_POINTS}
        self.fired: dict[str, int] = {p: 0 for p in INJECTION_POINTS}
        # one independent stream per point: firing decisions depend
        # only on (seed, point, consultation index), never on how often
        # other points were consulted
        self._rng = {p: random.Random(f"{seed}:{p}")
                     for p in INJECTION_POINTS}

    def configured(self, point: str) -> bool:
        """Can this point ever fire?  (Cheap pre-check so hot paths
        skip the bookkeeping entirely for unconfigured points.)"""
        return point in self.rates or point in self.schedule

    @property
    def active(self) -> bool:
        return bool(self.rates or self.schedule)

    def fire(self, point: str) -> bool:
        """One consultation of ``point``: returns True when the fault
        should be injected (and counts it)."""
        if point not in INJECTION_POINTS:
            raise ValueError(f"unknown injection point {point!r}")
        if not self.configured(point):
            return False
        self.calls[point] += 1
        cap = self.max_fires.get(point)
        if cap is not None and self.fired[point] >= cap:
            return False
        hit = self.calls[point] in self.schedule.get(point, ())
        rate = self.rates.get(point, 0.0)
        if not hit and rate > 0.0:
            hit = self._rng[point].random() < rate
        if hit:
            self.fired[point] += 1
        return hit

    def split(self, tag: str) -> "FaultInjector":
        """Derive an independent injector with the same spec: streams
        keyed ``(seed, tag, point)``, so one shared chaos spec drives a
        whole replica fleet with per-replica-deterministic firing —
        replica i's consultations never shift replica j's pattern, and
        the parent's own streams stay untouched (the default
        ``(seed, point)`` keying is unchanged)."""
        child = FaultInjector(seed=self.seed, rates=self.rates,
                              schedule=self.schedule, params=self.params,
                              max_fires=self.max_fires)
        child.tag = tag
        child._rng = {p: random.Random(f"{self.seed}/{tag}:{p}")
                      for p in INJECTION_POINTS}
        return child

    def param(self, point: str, key: str, default: Any = None) -> Any:
        return self.params.get(point, {}).get(key, default)

    def report(self) -> dict:
        """Consultations and fires per configured point."""
        pts = [p for p in INJECTION_POINTS if self.configured(p)]
        return {p: {"calls": self.calls[p], "fired": self.fired[p]}
                for p in pts}


#: shared inert injector: never configured, never fires — the default
#: every serve component carries so hot paths stay branch-cheap
NULL_INJECTOR = FaultInjector()

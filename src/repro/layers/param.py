"""Parameter construction + the linear-op dispatch seam.

Params are plain nested dicts of ``jnp`` arrays.  Every init function builds
two parallel trees through :class:`ParamBuilder`:

* ``params`` — the arrays (or ShapeDtypeStructs under ``jax.eval_shape``),
* ``axes``  — matching tuples of *logical axis names* used by
  ``repro.parallel.sharding`` to resolve ``NamedSharding``s.

The LRD surgery (repro.core.surgery) replaces a dense leaf ``{"w": W}`` with
``{"w0": ..., "w1": ...}`` (SVD pair) or ``{"u": ..., "xc": ..., "v": ...}``
(branched, block-diagonal core).  :func:`apply_linear` dispatches on the keys
present so *model code never changes* when a layer is decomposed — the
paper's technique is a pure parameter-tree transform.
"""
from __future__ import annotations

import math
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax import lax

PyTree = Any

# Logical axis names (resolved to mesh axes by parallel/sharding.py).
LAYERS = "layers"        # stacked-layer leading axis (scan dim; never sharded)
BATCH = "batch"
SEQ = "seq"
EMBED = "embed"          # d_model
FFN = "ffn"              # hidden / intermediate
HEADS = "heads"          # query heads
KV_HEADS = "kv_heads"
HEAD_DIM = "head_dim"
QKV = "qkv"              # flattened heads*head_dim projection output
VOCAB = "vocab"
EXPERTS = "experts"
RANK = "rank"            # low-rank inner dimension
BRANCH = "branch"        # branched-LRD branch axis
CONV = "conv"            # conv spatial/window dims
STATE = "state"          # SSM state dim
INNER = "inner"          # SSM d_inner
NONE = None


class ParamBuilder:
    """Builds ``(params, axes)`` trees with per-leaf RNG splitting."""

    def __init__(self, key: jax.Array, dtype: jnp.dtype):
        self._key = key
        self.dtype = dtype
        self.params: dict = {}
        self.axes: dict = {}

    def _next_key(self) -> jax.Array:
        self._key, sub = jax.random.split(self._key)
        return sub

    def param(self, name: str, shape: tuple[int, ...], axes: tuple,
              init: str = "normal", scale: float | None = None,
              dtype: jnp.dtype | None = None) -> None:
        assert len(shape) == len(axes), (name, shape, axes)
        dtype = dtype or self.dtype
        if init == "normal":
            fan_in = shape[0] if len(shape) >= 2 else shape[-1]
            std = scale if scale is not None else 1.0 / math.sqrt(max(fan_in, 1))
            v = (jax.random.normal(self._next_key(), shape, jnp.float32) * std)
        elif init == "zeros":
            v = jnp.zeros(shape, jnp.float32)
        elif init == "ones":
            v = jnp.ones(shape, jnp.float32)
        elif init == "embed":
            std = scale if scale is not None else 1.0
            v = jax.random.normal(self._next_key(), shape, jnp.float32) * std
        else:
            raise ValueError(f"unknown init {init}")
        self.params[name] = v.astype(dtype)
        self.axes[name] = tuple(axes)

    def child(self, name: str) -> "ParamBuilder":
        sub = ParamBuilder(self._next_key(), self.dtype)
        self.params[name] = sub.params
        self.axes[name] = sub.axes
        return sub

    def attach(self, name: str, params: PyTree, axes: PyTree) -> None:
        self.params[name] = params
        self.axes[name] = axes


# ---------------------------------------------------------------------------
# Linear-op dispatch — thin wrappers over repro.layers.plan.LinearPlan
# ---------------------------------------------------------------------------

def init_linear(pb: ParamBuilder, name: str, d_in: int, d_out: int,
                axes_in, axes_out, scale: float | None = None) -> None:
    """A dense linear op; LRD surgery may later rewrite the subtree."""
    sub = pb.child(name)
    sub.param("w", (d_in, d_out), (axes_in, axes_out), scale=scale)


def linear_kind(p: dict) -> str:
    """Classify a linear subtree; quantized trees (repro/quant key
    convention ``k_q``/``k_scale``) map to the same kind as their
    unquantized originals."""
    from repro.layers.plan import classify
    return classify(p)


def apply_linear(p: dict, x: jax.Array, *,
                 freeze_factors: bool = False,
                 use_pallas: bool = False,
                 act_quantize: bool = False,
                 accum_dtype=jnp.float32) -> jax.Array:
    """Apply a (possibly decomposed) linear op to ``x`` (..., d_in).

    Thin executor over :class:`repro.layers.plan.LinearPlan`: the plan
    (built once per subtree geometry) owns the kind classification,
    quantized-pair handling, the §2.2 freeze policy (``w0`` for SVD
    pairs; ``u``/``v`` for branched receive no gradient) and the fused
    kernel / reference decision.  ``act_quantize`` opts into the
    activation-quantized int8 x int8 kernels on fully-int8 plans.
    """
    from repro.layers.plan import build_plan
    return build_plan(p).execute(p, x, freeze_factors=freeze_factors,
                                 use_pallas=use_pallas,
                                 act_quantize=act_quantize,
                                 accum_dtype=accum_dtype)


def linear_out_dim(p: dict) -> int:
    from repro.layers.plan import build_plan
    return build_plan(p).d_out


def linear_param_count(p: dict) -> int:
    """Stored model parameters of one linear subtree.  ``*_scale`` and
    ``*_idx`` leaves are quantization / 2:4-packing metadata, not
    parameters — they are excluded (quantized ``*_q`` values count at
    their logical element count; packed ``*_sp`` values at the kept
    count)."""
    from repro.layers.plan import build_plan, is_linear_subtree
    if is_linear_subtree(p):
        return build_plan(p).param_count
    return sum(int(math.prod(v.shape)) for v in jax.tree.leaves(p))


def linear_quant_bytes(p: dict) -> int:
    """Bytes of quantized storage (narrow values + scales) in one linear
    subtree — reported separately from the parameter count."""
    from repro.layers.plan import build_plan, is_linear_subtree
    if not is_linear_subtree(p):
        return 0
    return build_plan(p).quant_bytes


def linear_flops(p: dict, n_tokens: int) -> float:
    """Forward matmul FLOPs for ``n_tokens`` rows through this op."""
    from repro.layers.plan import build_plan
    return build_plan(p).flops_per_token * n_tokens


# ---------------------------------------------------------------------------
# Activation sharding constraints (resolved lazily; no-op without a mesh)
# ---------------------------------------------------------------------------

_ACT_RESOLVER: Callable | None = None


def set_activation_resolver(fn: Callable | None) -> None:
    """parallel.sharding installs a (logical axes -> NamedSharding) resolver."""
    global _ACT_RESOLVER
    _ACT_RESOLVER = fn


def shard_act(x: jax.Array, *logical_axes) -> jax.Array:
    if _ACT_RESOLVER is None:
        return x
    sharding = _ACT_RESOLVER(logical_axes, x.shape)
    if sharding is None:
        return x
    return lax.with_sharding_constraint(x, sharding)

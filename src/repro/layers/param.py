"""Parameter construction + the linear-op dispatch seam.

Params are plain nested dicts of ``jnp`` arrays.  Every init function builds
two parallel trees through :class:`ParamBuilder`:

* ``params`` — the arrays (or ShapeDtypeStructs under ``jax.eval_shape``),
* ``axes``  — matching tuples of *logical axis names* used by
  ``repro.parallel.sharding`` to resolve ``NamedSharding``s.

The LRD surgery (repro.core.surgery) replaces a dense leaf ``{"w": W}`` with
``{"w0": ..., "w1": ...}`` (SVD pair) or ``{"u": ..., "xc": ..., "v": ...}``
(branched, block-diagonal core).  :func:`apply_linear` dispatches on the keys
present so *model code never changes* when a layer is decomposed — the
paper's technique is a pure parameter-tree transform.
"""
from __future__ import annotations

import math
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax import lax

PyTree = Any

# Logical axis names (resolved to mesh axes by parallel/sharding.py).
LAYERS = "layers"        # stacked-layer leading axis (scan dim; never sharded)
BATCH = "batch"
SEQ = "seq"
EMBED = "embed"          # d_model
FFN = "ffn"              # hidden / intermediate
HEADS = "heads"          # query heads
KV_HEADS = "kv_heads"
HEAD_DIM = "head_dim"
QKV = "qkv"              # flattened heads*head_dim projection output
VOCAB = "vocab"
EXPERTS = "experts"
RANK = "rank"            # low-rank inner dimension
BRANCH = "branch"        # branched-LRD branch axis
CONV = "conv"            # conv spatial/window dims
STATE = "state"          # SSM state dim
INNER = "inner"          # SSM d_inner
NONE = None


class ParamBuilder:
    """Builds ``(params, axes)`` trees with per-leaf RNG splitting."""

    def __init__(self, key: jax.Array, dtype: jnp.dtype):
        self._key = key
        self.dtype = dtype
        self.params: dict = {}
        self.axes: dict = {}

    def _next_key(self) -> jax.Array:
        self._key, sub = jax.random.split(self._key)
        return sub

    def param(self, name: str, shape: tuple[int, ...], axes: tuple,
              init: str = "normal", scale: float | None = None,
              dtype: jnp.dtype | None = None) -> None:
        assert len(shape) == len(axes), (name, shape, axes)
        dtype = dtype or self.dtype
        if init == "normal":
            fan_in = shape[0] if len(shape) >= 2 else shape[-1]
            std = scale if scale is not None else 1.0 / math.sqrt(max(fan_in, 1))
            v = (jax.random.normal(self._next_key(), shape, jnp.float32) * std)
        elif init == "zeros":
            v = jnp.zeros(shape, jnp.float32)
        elif init == "ones":
            v = jnp.ones(shape, jnp.float32)
        elif init == "embed":
            std = scale if scale is not None else 1.0
            v = jax.random.normal(self._next_key(), shape, jnp.float32) * std
        else:
            raise ValueError(f"unknown init {init}")
        self.params[name] = v.astype(dtype)
        self.axes[name] = tuple(axes)

    def child(self, name: str) -> "ParamBuilder":
        sub = ParamBuilder(self._next_key(), self.dtype)
        self.params[name] = sub.params
        self.axes[name] = sub.axes
        return sub

    def attach(self, name: str, params: PyTree, axes: PyTree) -> None:
        self.params[name] = params
        self.axes[name] = axes


# ---------------------------------------------------------------------------
# Linear-op dispatch (dense | low-rank | branched low-rank)
# ---------------------------------------------------------------------------

def init_linear(pb: ParamBuilder, name: str, d_in: int, d_out: int,
                axes_in, axes_out, scale: float | None = None) -> None:
    """A dense linear op; LRD surgery may later rewrite the subtree."""
    sub = pb.child(name)
    sub.param("w", (d_in, d_out), (axes_in, axes_out), scale=scale)


def linear_kind(p: dict) -> str:
    """Classify a linear subtree; quantized trees (repro/quant key
    convention ``k_q``/``k_scale``) map to the same kind as their
    unquantized originals."""
    if "w" in p:
        return "dense"
    if "xc" in p or "xc_q" in p:
        return "branched"
    if "w0" in p or "w0_q" in p:
        return "lowrank"
    raise ValueError(f"not a linear param subtree: {list(p)}")


def _factor(p: dict, key: str, dtype=None) -> jax.Array:
    """Fetch factor ``key``, dequantizing a ``key_q``/``key_scale`` pair
    on the fly (dtype defaults to bf16 — the serving activation dtype)."""
    if key in p:
        return p[key]
    from repro.quant.quantize import dequantize_array
    return dequantize_array(p[key + "_q"], p[key + "_scale"],
                            dtype or jnp.bfloat16)


def apply_linear(p: dict, x: jax.Array, *,
                 freeze_factors: bool = False,
                 use_pallas: bool = False,
                 accum_dtype=jnp.float32) -> jax.Array:
    """Apply a (possibly decomposed) linear op to ``x`` (..., d_in).

    ``freeze_factors`` implements paper §2.2: the teacher-derived factors
    (``w0`` for SVD pairs; ``u``/``v`` for branched) receive no gradient.
    """
    kind = linear_kind(p)
    if kind == "dense":
        return _matmul(x, p["w"], accum_dtype)
    if kind == "lowrank":
        if "w0_q" in p or "w1_q" in p:
            # Quantized factors (repro/quant): serve-time weight-only
            # int8/fp8 — no gradients flow, so freezing is moot.  The
            # fused kernel needs both factors quantized; quant_targets
            # may select a subset, which takes the dequant path.
            if use_pallas and x.ndim == 2 and "w0_q" in p and "w1_q" in p:
                from repro.kernels import ops as kops
                return kops.lowrank_matmul_q(
                    x, p["w0_q"], p["w0_scale"], p["w1_q"], p["w1_scale"])
            w0 = _factor(p, "w0", x.dtype)
            w1 = _factor(p, "w1", x.dtype)
            h = _matmul(x, w0, accum_dtype)
            return _matmul(h, w1, accum_dtype)
        w0, w1 = p["w0"], p["w1"]
        if freeze_factors:
            w0 = lax.stop_gradient(w0)
        if use_pallas and x.ndim == 2:
            from repro.kernels import ops as kops
            return kops.lowrank_matmul(x, w0, w1)
        h = _matmul(x, w0, accum_dtype)
        return _matmul(h, w1, accum_dtype)
    # Branched: u (N, d_in, r1), xc (N, r1, r2), v (N, r2, d_out);
    # y = sum_j ((x @ u_j) @ xc_j) @ v_j      (paper Eq. 17)
    if any(k in p for k in ("u_q", "xc_q", "v_q")):
        u = _factor(p, "u", x.dtype)
        xc = _factor(p, "xc", x.dtype)
        v = _factor(p, "v", x.dtype)
        freeze_factors = False
    else:
        u, xc, v = p["u"], p["xc"], p["v"]
    if freeze_factors:
        u = lax.stop_gradient(u)
        v = lax.stop_gradient(v)
    if use_pallas and x.ndim == 2:
        from repro.kernels import ops as kops
        return kops.branched_matmul(x, u, xc, v)
    h = jnp.einsum("...d,ndr->n...r", x, u,
                   preferred_element_type=accum_dtype).astype(x.dtype)
    h = jnp.einsum("n...r,nrs->n...s", h, xc,
                   preferred_element_type=accum_dtype).astype(x.dtype)
    y = jnp.einsum("n...s,nso->...o", h, v,
                   preferred_element_type=accum_dtype)
    return y.astype(x.dtype)


def _matmul(x: jax.Array, w: jax.Array, accum_dtype) -> jax.Array:
    y = jnp.einsum("...d,do->...o", x, w, preferred_element_type=accum_dtype)
    return y.astype(x.dtype)


def _factor_shape(p: dict, key: str) -> tuple[int, ...]:
    return tuple(p[key].shape if key in p else p[key + "_q"].shape)


def linear_out_dim(p: dict) -> int:
    kind = linear_kind(p)
    if kind == "dense":
        return p["w"].shape[-1]
    if kind == "lowrank":
        return _factor_shape(p, "w1")[-1]
    return _factor_shape(p, "v")[-1]


def linear_param_count(p: dict) -> int:
    return sum(int(math.prod(v.shape)) for v in jax.tree.leaves(p))


def linear_flops(p: dict, n_tokens: int) -> float:
    """Forward matmul FLOPs for ``n_tokens`` rows through this op."""
    kind = linear_kind(p)
    if kind == "dense":
        c, s = p["w"].shape
        return 2.0 * n_tokens * c * s
    if kind == "lowrank":
        c, r = _factor_shape(p, "w0")
        _, s = _factor_shape(p, "w1")
        return 2.0 * n_tokens * r * (c + s)
    n, c, r1 = _factor_shape(p, "u")
    _, _, r2 = _factor_shape(p, "xc")
    _, _, s = _factor_shape(p, "v")
    return 2.0 * n_tokens * n * (c * r1 + r1 * r2 + r2 * s)


# ---------------------------------------------------------------------------
# Activation sharding constraints (resolved lazily; no-op without a mesh)
# ---------------------------------------------------------------------------

_ACT_RESOLVER: Callable | None = None


def set_activation_resolver(fn: Callable | None) -> None:
    """parallel.sharding installs a (logical axes -> NamedSharding) resolver."""
    global _ACT_RESOLVER
    _ACT_RESOLVER = fn


def shard_act(x: jax.Array, *logical_axes) -> jax.Array:
    if _ACT_RESOLVER is None:
        return x
    sharding = _ACT_RESOLVER(logical_axes, x.shape)
    if sharding is None:
        return x
    return lax.with_sharding_constraint(x, sharding)

"""Mamba2 / SSD (state-space duality) block — arXiv:2405.21060.

Train/prefill run the *chunked* SSD algorithm: within-chunk attention-like
diagonal blocks plus an inter-chunk recurrence over chunk states carried by
``lax.scan``.  Memory is O(S * d_inner + n_chunks * d_state) — this is what
makes the ``long_500k`` cell feasible for the SSM/hybrid archs.

Decode keeps a per-layer recurrent state ``(B, nh, hd, N)`` plus a small
conv ring buffer; one step is O(1) in sequence length.

The in/out projections go through :func:`apply_linear`, so the paper's LRD
targets them (``ssm_in`` / ``ssm_out``); the depthwise conv1d is already
diagonal (each channel its own filter) and is *not decomposable further* —
DESIGN.md §Arch-applicability.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.layers.param import (
    ParamBuilder, apply_linear, init_linear, shard_act,
    BATCH, SEQ, EMBED, INNER, STATE, CONV,
)
from repro.layers.norm import init_rms_norm, gated_rms_norm


class SSMOpts(NamedTuple):
    freeze_factors: bool = False
    use_pallas: bool = False
    act_quantize: bool = False


class SSMDims(NamedTuple):
    d_model: int
    d_inner: int
    n_heads: int
    head_dim: int
    d_state: int
    conv_width: int
    chunk: int

    @property
    def conv_dim(self) -> int:
        return self.d_inner + 2 * self.d_state

    @property
    def in_dim(self) -> int:
        # [z (di), x (di), B (N), C (N), dt (nh)]
        return 2 * self.d_inner + 2 * self.d_state + self.n_heads


def dims_from_config(cfg) -> SSMDims:
    di = cfg.d_inner
    nh = cfg.resolved_ssm_heads
    return SSMDims(cfg.d_model, di, nh, di // nh, cfg.ssm_state,
                   cfg.ssm_conv_width, cfg.ssm_chunk)


def init_ssm(pb: ParamBuilder, name: str, dims: SSMDims) -> None:
    """The input projection is *split by consumer* (z | x | BC | dt) so the
    TP sharding of d_inner never slices across an unaligned concat boundary
    (GSPMD would reshard); XLA fuses the four dots back together."""
    sub = pb.child(name)
    init_linear(sub, "in_proj", dims.d_model, 2 * dims.d_inner, EMBED, INNER)
    init_linear(sub, "bc_proj", dims.d_model, 2 * dims.d_state, EMBED, None)
    init_linear(sub, "dt_proj", dims.d_model, dims.n_heads, EMBED, None)
    sub.param("conv_x_w", (dims.conv_width, dims.d_inner), (CONV, INNER),
              scale=1.0 / dims.conv_width)
    sub.param("conv_x_b", (dims.d_inner,), (INNER,), init="zeros")
    sub.param("conv_bc_w", (dims.conv_width, 2 * dims.d_state), (CONV, None),
              scale=1.0 / dims.conv_width)
    sub.param("conv_bc_b", (2 * dims.d_state,), (None,), init="zeros")
    sub.param("a_log", (dims.n_heads,), (None,), init="zeros")
    sub.param("d_skip", (dims.n_heads,), (None,), init="ones")
    sub.param("dt_bias", (dims.n_heads,), (None,), init="zeros")
    init_rms_norm(sub, "norm", dims.d_inner)
    init_linear(sub, "out_proj", dims.d_inner, dims.d_model, INNER, EMBED)


# ---------------------------------------------------------------------------
# Chunked SSD scan (train / prefill)
# ---------------------------------------------------------------------------

def _ssd_chunked(x, dt, a, b, c, dims: SSMDims, init_state=None):
    """SSD over full sequences, scanned one chunk at a time.

    x (B,S,nh,hd); dt (B,S,nh) post-softplus; a (nh,) negative;
    b,c (B,S,N).  Returns (y (B,S,nh,hd), final_state (B,nh,hd,N)).

    Live memory is one chunk's (B,Q,Q,nh) decay block — sequence length
    only enters through the scan trip count, which is what makes the
    500k-context cell feasible.
    """
    bsz, s_orig, nh, hd = x.shape
    n = dims.d_state
    q = min(dims.chunk, s_orig)
    # Pad to a chunk multiple with dt=0 tokens: zero dt means zero state
    # contribution and no decay, so padding is exact (outputs sliced off).
    pad = (-s_orig) % q
    if pad:
        padfn = lambda t: jnp.pad(t, [(0, 0), (0, pad)]
                                  + [(0, 0)] * (t.ndim - 2))
        x, dt, b, c = padfn(x), padfn(dt), padfn(b), padfn(c)
    s = s_orig + pad
    nc = s // q
    mask = jnp.tril(jnp.ones((q, q), bool))

    def chunk_body(carry, inp):
        # carry (B,nh,hd,N) f32 — state *before* this chunk
        xq, dtq, bq, cq = inp          # (B,Q,nh,hd),(B,Q,nh),(B,Q,N),(B,Q,N)
        da = dtq * a[None, None, :]                        # (B,Q,nh) f32
        seg = jnp.cumsum(da, axis=1)                       # inclusive
        total = seg[:, -1, :]                              # (B,nh)
        # within-chunk: att[i,j] = C_i.B_j exp(seg_i-seg_j) dt_j  (i>=j)
        rel = seg[:, :, None, :] - seg[:, None, :, :]      # (B,Q,Q,nh)
        decay = jnp.exp(jnp.where(mask[None, :, :, None], rel, -jnp.inf))
        cb = jnp.einsum("bin,bjn->bij", cq, bq)            # (B,Q,Q)
        att = cb[..., None] * decay * dtq[:, None, :, :]   # (B,Q,Q,nh)
        y_diag = jnp.einsum("bijh,bjhd->bihd",
                            att.astype(x.dtype), xq)
        # inter-chunk: y_i += C_i exp(seg_i) . state_before
        y_inter = jnp.einsum("bin,bih,bhdn->bihd", cq,
                             jnp.exp(seg).astype(jnp.float32),
                             carry).astype(x.dtype)
        # state update: exp(total) state + sum_j exp(total-seg_j) dt_j B_j x_j
        w = jnp.exp(total[:, None, :] - seg) * dtq         # (B,Q,nh)
        st = jnp.einsum("bjh,bjn,bjhd->bhdn", w, bq,
                        xq.astype(jnp.float32))
        new = jnp.exp(total)[:, :, None, None] * carry + st
        return new, y_diag + y_inter

    s0 = (jnp.zeros((bsz, nh, hd, n), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))
    to_chunks = lambda t: jnp.moveaxis(
        t.reshape(bsz, nc, q, *t.shape[2:]), 1, 0)
    final, y = lax.scan(
        chunk_body, s0,
        (to_chunks(x), to_chunks(dt.astype(jnp.float32)),
         to_chunks(b.astype(jnp.float32)), to_chunks(c.astype(jnp.float32))))
    y = jnp.moveaxis(y, 0, 1).reshape(bsz, s, nh, hd)[:, :s_orig]
    return y, final.astype(x.dtype)


def _causal_conv(seq: jax.Array, w: jax.Array, b: jax.Array,
                 tail: jax.Array | None = None) -> jax.Array:
    """Depthwise causal conv1d. seq (B,S,D), w (K,D) -> (B,S,D).

    ``tail`` (B,K-1,D) holds the previous tokens' inputs (decode/chunked
    prefill); zeros when absent.
    """
    k = w.shape[0]
    if tail is None:
        tail = jnp.zeros((seq.shape[0], k - 1, seq.shape[-1]), seq.dtype)
    padded = jnp.concatenate([tail, seq], axis=1)         # (B,S+K-1,D)
    out = sum(padded[:, i:i + seq.shape[1], :]
              * w[i][None, None, :] for i in range(k))
    return jax.nn.silu(out + b[None, None, :])


def apply_ssm(p: dict, x: jax.Array, dims: SSMDims, *,
              state: dict | None = None, opts: SSMOpts = SSMOpts(),
              norm_eps: float = 1e-5) -> tuple[jax.Array, dict | None]:
    """Full-sequence SSD (train / prefill).  Returns (y, final_state|None).

    ``state`` (if given) receives the final recurrent state + conv tail so
    decode can continue the sequence.
    """
    bsz, s, _ = x.shape
    kw = dict(freeze_factors=opts.freeze_factors, use_pallas=opts.use_pallas,
              act_quantize=opts.act_quantize)
    di, n, nh = dims.d_inner, dims.d_state, dims.n_heads
    zx = apply_linear(p["in_proj"], x, **kw)              # (B,S,2di)
    z, xc = jnp.split(zx, [di], axis=-1)
    bc = apply_linear(p["bc_proj"], x, **kw)              # (B,S,2N)
    dt = apply_linear(p["dt_proj"], x, **kw)              # (B,S,nh)

    xc = _causal_conv(xc, p["conv_x_w"], p["conv_x_b"])
    bc = _causal_conv(bc, p["conv_bc_w"], p["conv_bc_b"])
    b, c = jnp.split(bc, [n], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    xh = xc.reshape(bsz, s, nh, dims.head_dim)
    xh = shard_act(xh, BATCH, SEQ, INNER, None)

    y, final = _ssd_chunked(xh, dt, a, b.astype(jnp.float32),
                            c.astype(jnp.float32), dims)
    y = y + xh * p["d_skip"].astype(x.dtype)[None, None, :, None]
    y = y.reshape(bsz, s, di)
    y = gated_rms_norm(p["norm"], y, z, norm_eps)
    out = apply_linear(p["out_proj"], y, **kw)

    new_state = None
    if state is not None:
        tail = dims.conv_width - 1
        # note: tails hold the *pre-conv* streams (inputs to the window)
        new_state = {"ssm": final,
                     "conv_x": zx[:, -tail:, di:],
                     "conv_bc": apply_linear(p["bc_proj"], x[:, -tail:, :],
                                             **kw)}
    return out, new_state


def init_ssm_state(batch: int, dims: SSMDims, dtype) -> dict:
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        ssm_state_spec(batch, dims, dtype))


def ssm_state_spec(batch: int, dims: SSMDims, dtype) -> dict:
    tail = dims.conv_width - 1
    return {
        "ssm": jax.ShapeDtypeStruct(
            (batch, dims.n_heads, dims.head_dim, dims.d_state), dtype),
        "conv_x": jax.ShapeDtypeStruct((batch, tail, dims.d_inner), dtype),
        "conv_bc": jax.ShapeDtypeStruct((batch, tail, 2 * dims.d_state),
                                        dtype),
    }


def apply_ssm_decode(p: dict, x: jax.Array, dims: SSMDims, state: dict, *,
                     opts: SSMOpts = SSMOpts(), norm_eps: float = 1e-5
                     ) -> tuple[jax.Array, dict]:
    """One decode step. x (B,1,d); state {"ssm","conv_x","conv_bc"};
    O(1) in sequence length."""
    bsz = x.shape[0]
    kw = dict(freeze_factors=opts.freeze_factors, use_pallas=opts.use_pallas,
              act_quantize=opts.act_quantize)
    di, n, nh = dims.d_inner, dims.d_state, dims.n_heads
    zx = apply_linear(p["in_proj"], x, **kw)
    z, xc = jnp.split(zx, [di], axis=-1)
    bc = apply_linear(p["bc_proj"], x, **kw)
    dt = apply_linear(p["dt_proj"], x, **kw)

    new_conv_x = jnp.concatenate([state["conv_x"], xc], axis=1)[:, 1:, :]
    new_conv_bc = jnp.concatenate([state["conv_bc"], bc], axis=1)[:, 1:, :]
    xc = _causal_conv(xc, p["conv_x_w"], p["conv_x_b"], tail=state["conv_x"])
    bc = _causal_conv(bc, p["conv_bc_w"], p["conv_bc_b"],
                      tail=state["conv_bc"])
    b, c = jnp.split(bc, [n], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))  # (B,1,nh)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    da = jnp.exp(dt[:, 0, :] * a[None, :])                # (B,nh)
    xh = xc[:, 0].reshape(bsz, nh, dims.head_dim)

    # state' = exp(dt a) state + dt * B x^T ; y = C . state' + D x
    sf = state["ssm"].astype(jnp.float32)
    upd = jnp.einsum("bh,bn,bhd->bhdn", dt[:, 0, :], b[:, 0].astype(jnp.float32),
                     xh.astype(jnp.float32))
    new_ssm = da[:, :, None, None] * sf + upd
    y = jnp.einsum("bn,bhdn->bhd", c[:, 0].astype(jnp.float32), new_ssm)
    y = y.astype(x.dtype) + xh * p["d_skip"].astype(x.dtype)[None, :, None]
    y = y.reshape(bsz, 1, di)
    y = gated_rms_norm(p["norm"], y, z, norm_eps)
    out = apply_linear(p["out_proj"], y, **kw)
    return out, {"ssm": new_ssm.astype(state["ssm"].dtype),
                 "conv_x": new_conv_x, "conv_bc": new_conv_bc}

"""CachePlan — the declarative execution-plan seam for every KV-cache family.

The serve stack grew three parallel cache layouts: plain GQA K/V pools,
int8-quantized GQA pools (:mod:`repro.quant.kv`), and the MLA latent
cache — and :mod:`repro.layers.attention` dispatched between them by
sniffing raw dict keys (``"k"`` vs ``"k_q"``/``"k_scale"`` vs ``"ckv"``)
in three places per segment kind.  Every new family multiplied the
branching, and the byte accounting in :mod:`repro.serve.pool` and
:mod:`repro.core.cost_model` re-derived the layouts by hand.

A :class:`CachePlan` is the cache twin of :class:`repro.layers.plan.
LinearPlan`: one plan per attention layer declaring

* **family** — ``gqa_f32 | gqa_int8 | mla_latent | mla_latent_int8``
  (``*_f32``/unsuffixed families hold the model dtype, f32 *or* bf16;
  the name records "full width");
* **leaves** — per-leaf :class:`CacheLeafSpec` (shape template, dtype,
  which axis is the sequence axis, and the quantized-pair ref tying a
  ``*_q`` value leaf to its ``*_scale`` row);
* **bytes** — ``bytes_per_token`` (per-position bytes of one stream),
  ``bytes_per_slot`` (per-slot constants: the f32 scale rows) and
  ``bytes_per_step(slots, seq)`` (the full-pool decode read) — the
  single source of truth behind :class:`repro.serve.pool.KVPoolManager`
  accounting and the roofline's ``kv_bytes`` term;
* **executors** — the write path for all three segment kinds
  (:meth:`write_prefill`, :meth:`write_chunk`, :meth:`write_decode`)
  and the cache-coupled decode attention (:meth:`attend_decode` for GQA
  families, :meth:`attend_decode_latent` for the MLA absorbed form,
  which dispatches the fused int8 kernels behind the shared
  ``ops.kernel_fits`` gate).

``apply_attention`` / ``apply_mla`` are thin executors over the plan:
they own projections, RoPE, and the prefill softmax (which runs on the
full-precision values computed in-layer, never on the cache), while the
plan owns every layout-dependent decision.  :func:`plan_from_cache` is
the ONE place left that classifies a cache dict by its keys — the
fallback when a caller does not thread a plan explicitly.

Plans are static metadata (no array refs), cached per geometry, and safe
to close over inside ``jax.jit``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.quant import kv as kvq

PyTree = Any

FAMILY_GQA = "gqa_f32"
FAMILY_GQA_INT8 = "gqa_int8"
FAMILY_MLA = "mla_latent"
FAMILY_MLA_INT8 = "mla_latent_int8"

FAMILIES = (FAMILY_GQA, FAMILY_GQA_INT8, FAMILY_MLA, FAMILY_MLA_INT8)

#: sequence-axis position (from the right) of every per-position cache
#: leaf, by key — K/V pools are (..., S, KH, hd), latents (..., S, r).
#: Scale rows have no sequence axis.  The pool's slot scatter and the
#: plans' leaf specs both read this one map.
SEQ_AXIS: dict[str, int] = {
    "k": -3, "v": -3, "k_q": -3, "v_q": -3,
    "ckv": -2, "krope": -2, "ckv_q": -2, "krope_q": -2,
}

_NEG_INF = -1e30


@dataclasses.dataclass(frozen=True)
class CacheLeafSpec:
    """One leaf of a per-layer cache dict.  Static metadata only."""

    name: str                       # cache key ("k", "k_q", "ckv_scale", ...)
    tail_shape: tuple[int, ...]     # dims after (batch[, seq]): (KH, D) / (r,)
    dtype: Any
    seq_axis: int | None            # from the right; None = per-slot constant
    scale_of: str | None = None     # "k_scale" -> scales the "k_q" leaf

    def shape(self, batch: int, seq_len: int) -> tuple[int, ...]:
        if self.seq_axis is None:
            return (batch, *self.tail_shape)
        return (batch, seq_len, *self.tail_shape)

    @property
    def bytes_per_position(self) -> int:
        """Bytes one position of one stream occupies (0 for scale rows)."""
        if self.seq_axis is None:
            return 0
        return int(math.prod(self.tail_shape)) * jnp.dtype(self.dtype).itemsize

    @property
    def bytes_per_slot(self) -> int:
        """Per-slot constant bytes (scale rows; 0 for per-position leaves)."""
        if self.seq_axis is not None:
            return 0
        return int(math.prod(self.tail_shape)) * jnp.dtype(self.dtype).itemsize


@dataclasses.dataclass(frozen=True)
class CachePlan:
    """How one attention layer's cache is laid out, costed, and executed."""

    family: str
    leaves: tuple[CacheLeafSpec, ...]

    # -- contract -----------------------------------------------------------

    @property
    def quantized(self) -> bool:
        return self.family in (FAMILY_GQA_INT8, FAMILY_MLA_INT8)

    @property
    def mla(self) -> bool:
        return self.family in (FAMILY_MLA, FAMILY_MLA_INT8)

    def leaf(self, name: str) -> CacheLeafSpec:
        for l in self.leaves:
            if l.name == name:
                return l
        raise KeyError(name)

    @property
    def quant_pairs(self) -> dict[str, str]:
        """``{value_leaf: scale_leaf}`` refs for the quantized leaves."""
        return {l.scale_of: l.name for l in self.leaves if l.scale_of}

    # -- construction -------------------------------------------------------

    def spec(self, batch: int, seq_len: int) -> dict:
        return {l.name: jax.ShapeDtypeStruct(l.shape(batch, seq_len), l.dtype)
                for l in self.leaves}

    def init(self, batch: int, seq_len: int) -> dict:
        """Zero-initialized cache (zero scales dequantize to zeros)."""
        return {l.name: jnp.zeros(l.shape(batch, seq_len), l.dtype)
                for l in self.leaves}

    # -- accounting (single source of truth for pool / roofline) ------------

    @property
    def bytes_per_token(self) -> int:
        """Per-position cache bytes of ONE stream, this layer."""
        return sum(l.bytes_per_position for l in self.leaves)

    @property
    def bytes_per_slot(self) -> int:
        """Per-slot constant bytes (f32 scale rows), this layer."""
        return sum(l.bytes_per_slot for l in self.leaves)

    def bytes_per_step(self, slots: int, seq_len: int) -> int:
        """HBM bytes this layer's pool streams per decode step — decode
        reads every slot's full ``seq_len`` (masked, not skipped)."""
        return slots * (seq_len * self.bytes_per_token + self.bytes_per_slot)

    # -- write executors ----------------------------------------------------
    # ``new`` carries the layer's full-precision values under their
    # LOGICAL names: {"k", "v"} (B, S, KH, D) for GQA families,
    # {"ckv"} (B, S, r) + {"krope"} (B, S, rope) for MLA families
    # (decode passes one-token values without the S axis).

    def _mask_new(self, new: dict, start_pos, prompt_len) -> dict:
        """Zero rows at absolute positions ``>= prompt_len`` (bucket-pad
        tail) so they can neither land garbage in the pool nor inflate
        the int8 running-max scales."""
        if prompt_len is None:
            return new
        out = {}
        for key, x in new.items():
            sq = x.shape[1]
            pad = (1,) * (-SEQ_AXIS[key] - 1)
            pm = (start_pos + jnp.arange(sq) < prompt_len).reshape(
                (1, sq, *pad))
            out[key] = jnp.where(pm, x, 0.0)
        return out

    def write_prefill(self, cache: dict, new: dict,
                      prompt_len: jax.Array | None = None) -> dict:
        """Whole-prompt write at position 0 (quantize-on-insert for the
        int8 families, one-shot scales over the real prompt)."""
        if not self.quantized:
            return {k: lax.dynamic_update_slice_in_dim(cache[k], v, 0, 1)
                    for k, v in new.items()}
        new = self._mask_new(new, 0, prompt_len)
        out = {}
        for key, x in new.items():
            q, scale = kvq.quantize_kv_prefill(x)
            out[key + "_q"] = lax.dynamic_update_slice_in_dim(
                cache[key + "_q"], q, 0, 1)
            out[key + "_scale"] = scale
        return out

    def write_chunk(self, cache: dict, new: dict, start_pos: jax.Array,
                    prompt_len: jax.Array | None = None
                    ) -> tuple[dict, dict]:
        """Chunk write at a sequence offset.  Returns ``(new_cache,
        views)`` where ``views`` holds the full-precision whole-pool
        attend views under the logical names (the written pool for
        full-width families, the dequantized pool for int8 — serve
        stages chunked prompts full-precision instead, for exactness).
        Pad rows beyond ``prompt_len`` (the chunk's real end) are zeroed
        at the write for BOTH dtypes: a later chunk's bucket is not
        guaranteed to overwrite them before they become attendable.
        """
        new = self._mask_new(new, start_pos, prompt_len)
        if not self.quantized:
            out = {k: lax.dynamic_update_slice_in_dim(cache[k], v,
                                                      start_pos, 1)
                   for k, v in new.items()}
            return out, out
        out, views = {}, {}
        for key, x in new.items():
            q, scale = kvq.kv_write_chunk(cache[key + "_q"],
                                          cache[key + "_scale"], x,
                                          start_pos)
            out[key + "_q"] = q
            out[key + "_scale"] = scale
            views[key] = kvq.dequantize_kv(q, scale, x.dtype)
        return out, views

    def write_decode(self, cache: dict, new: dict,
                     cache_pos: jax.Array) -> dict:
        """One-token scatter at per-slot positions ``cache_pos`` (B,).
        ``new`` values carry no S axis: (B, KH, D) / (B, r).  Int8
        families take the incremental running-max scale update
        (:func:`repro.quant.kv.kv_write_token`)."""
        bidx = jnp.arange(cache_pos.shape[0])
        if not self.quantized:
            return {k: cache[k].at[bidx, cache_pos].set(v)
                    for k, v in new.items()}
        out = {}
        for key, x in new.items():
            q, scale = kvq.kv_write_token(cache[key + "_q"],
                                          cache[key + "_scale"], x,
                                          cache_pos)
            out[key + "_q"] = q
            out[key + "_scale"] = scale
        return out

    # -- decode attention (the cache-coupled read) --------------------------

    def attend_decode(self, q: jax.Array, cache: dict,
                      cache_pos: jax.Array, *, softcap: float = 0.0,
                      use_pallas: bool = False) -> jax.Array:
        """GQA decode: one query row vs the whole pool.  q (B, 1, H, D)
        -> (B, 1, H, D).  Int8 pools run the fused kernel under
        ``use_pallas`` (VMEM-fit fallback inside the ops wrapper) or the
        jnp dequant oracle — a full-precision pool copy never lands in
        HBM on the kernel path."""
        if self.mla:
            raise ValueError("latent families attend via "
                             "attend_decode_latent")
        if not self.quantized:
            skv = cache["k"].shape[1]
            valid = jnp.arange(skv)[None, :] <= cache_pos[:, None]  # (B,S)
            return gqa_decode_attention(q, cache["k"], cache["v"], valid,
                                        softcap)
        from repro.kernels import ops as kops
        from repro.kernels import ref as kref
        fn = kops.decode_attention_q if use_pallas \
            else kref.decode_attention_q_ref
        return fn(q, cache["k_q"], cache["k_scale"], cache["v_q"],
                  cache["v_scale"], cache_pos, softcap=softcap)

    def attend_decode_latent(self, q_lat: jax.Array, q_rope: jax.Array,
                             cache: dict, cache_pos: jax.Array, *,
                             scale: float,
                             use_pallas: bool = False) -> jax.Array:
        """MLA absorbed decode: latent-space queries vs the latent pool.
        q_lat (B, 1, H, r); q_rope (B, 1, H, rope) -> context latents
        (B, 1, H, r) — attention runs entirely against the cached
        latents, per-head K/V are never materialized.  Int8 pools run
        the fused latent kernel (ckv/krope scales folded into the
        latent query rows, ckv scales into the context output) under
        ``use_pallas``, else the dequant oracle."""
        if not self.mla:
            raise ValueError("GQA families attend via attend_decode")
        if not self.quantized:
            cc, cr = cache["ckv"], cache["krope"]
            s = (jnp.einsum("bqhl,bsl->bhqs", q_lat, cc,
                            preferred_element_type=jnp.float32)
                 + jnp.einsum("bqhr,bsr->bhqs", q_rope, cr,
                              preferred_element_type=jnp.float32)) * scale
            valid = jnp.arange(cc.shape[1])[None, :] <= cache_pos[:, None]
            s = jnp.where(valid[:, None, None, :], s, _NEG_INF)
            attn = jax.nn.softmax(s, axis=-1).astype(q_lat.dtype)
            return jnp.einsum("bhqs,bsl->bqhl", attn, cc)
        from repro.kernels import ops as kops
        from repro.kernels import ref as kref
        fn = kops.decode_attention_latent_q if use_pallas \
            else kref.decode_attention_latent_q_ref
        return fn(q_lat, q_rope, cache["ckv_q"], cache["ckv_scale"],
                  cache["krope_q"], cache["krope_scale"], cache_pos,
                  scale=scale)


def gqa_decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                         valid: jax.Array, softcap: float) -> jax.Array:
    """Full-width GQA decode attention: q (B, 1, H, D) vs k/v
    (B, S, KH, D), slot validity (B, S) masked into the f32 logits."""
    b, sq, h, hd = q.shape
    kh = k.shape[2]
    qg = q.reshape(b, sq, kh, h // kh, hd)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qg, k,
                   preferred_element_type=jnp.float32) * (1.0 / math.sqrt(hd))
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    s = jnp.where(valid[:, None, None, None, :], s, _NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    o = jnp.einsum("bkgqs,bskh->bqkgh", p, v)
    return o.reshape(b, sq, h, hd)


# ---------------------------------------------------------------------------
# Plan construction (cached — one plan object per geometry)
# ---------------------------------------------------------------------------

_PLAN_CACHE: dict[tuple, CachePlan] = {}


def _check_quantize(quantize: str | None) -> bool:
    if quantize in (None, "none"):
        return False
    if quantize not in kvq.KV_MODES:
        raise ValueError(
            f"unknown kv quant mode {quantize!r} (want one of "
            f"{kvq.KV_MODES})")
    return True


def gqa_plan(num_kv_heads: int, head_dim: int, dtype,
             quantize: str | None = None) -> CachePlan:
    """The plan for one GQA/MHA attention layer's K/V cache."""
    q = _check_quantize(quantize)
    key = ("gqa", num_kv_heads, head_dim, jnp.dtype(dtype).name, q)
    plan = _PLAN_CACHE.get(key)
    if plan is None:
        tail = (num_kv_heads, head_dim)
        if q:
            leaves = []
            for name in ("k", "v"):
                leaves.append(CacheLeafSpec(name + "_q", tail, jnp.int8,
                                            SEQ_AXIS[name + "_q"]))
                leaves.append(CacheLeafSpec(name + "_scale", tail,
                                            jnp.float32, None,
                                            scale_of=name + "_q"))
            plan = CachePlan(FAMILY_GQA_INT8, tuple(leaves))
        else:
            plan = CachePlan(FAMILY_GQA, tuple(
                CacheLeafSpec(n, tail, jnp.dtype(dtype), SEQ_AXIS[n])
                for n in ("k", "v")))
        _PLAN_CACHE[key] = plan
    return plan


def mla_plan(kv_lora_rank: int, qk_rope_dim: int, dtype,
             quantize: str | None = None) -> CachePlan:
    """The plan for one MLA layer's latent cache.  The latent *is* the
    rank-compressed K/V factor; the int8 family compresses it again with
    per-(slot, channel) scales (no head axis — all heads share the one
    latent stream)."""
    q = _check_quantize(quantize)
    key = ("mla", kv_lora_rank, qk_rope_dim, jnp.dtype(dtype).name, q)
    plan = _PLAN_CACHE.get(key)
    if plan is None:
        dims = {"ckv": (kv_lora_rank,), "krope": (qk_rope_dim,)}
        if q:
            leaves = []
            for name, tail in dims.items():
                leaves.append(CacheLeafSpec(name + "_q", tail, jnp.int8,
                                            SEQ_AXIS[name + "_q"]))
                leaves.append(CacheLeafSpec(name + "_scale", tail,
                                            jnp.float32, None,
                                            scale_of=name + "_q"))
            plan = CachePlan(FAMILY_MLA_INT8, tuple(leaves))
        else:
            plan = CachePlan(FAMILY_MLA, tuple(
                CacheLeafSpec(n, tail, jnp.dtype(dtype), SEQ_AXIS[n])
                for n, tail in dims.items()))
        _PLAN_CACHE[key] = plan
    return plan


def build_cache_plan(cfg, dtype, kv_quantize: str | None = None) -> CachePlan:
    """The per-attention-layer plan for a model config (``cfg.mla``
    selects the latent families)."""
    if cfg.mla:
        return mla_plan(cfg.kv_lora_rank, cfg.qk_rope_dim, dtype,
                        kv_quantize)
    return gqa_plan(cfg.num_kv_heads, cfg.resolved_head_dim, dtype,
                    kv_quantize)


def plan_from_cache(cache: dict, dtype=jnp.float32) -> CachePlan:
    """Classify a per-layer cache dict into its plan — the ONE remaining
    key-sniffing point, used when a caller has no plan threaded (direct
    layer-level use; the serve stack always threads plans).  Geometry
    comes from the leaf shapes; ``dtype`` is only needed for int8
    families (full-width leaves carry theirs)."""
    if "ckv_q" in cache:
        return mla_plan(cache["ckv_q"].shape[-1], cache["krope_q"].shape[-1],
                        dtype, "int8")
    if "ckv" in cache:
        return mla_plan(cache["ckv"].shape[-1], cache["krope"].shape[-1],
                        cache["ckv"].dtype, None)
    if "k_q" in cache:
        kh, hd = cache["k_q"].shape[-2:]
        return gqa_plan(kh, hd, dtype, "int8")
    if "k" in cache:
        kh, hd = cache["k"].shape[-2:]
        return gqa_plan(kh, hd, cache["k"].dtype, None)
    raise ValueError(f"not a KV cache dict: {sorted(cache)}")

"""CachePlan — the declarative execution-plan seam for every KV-cache family.

The serve stack grew three parallel cache layouts: plain GQA K/V pools,
int8-quantized GQA pools (:mod:`repro.quant.kv`), and the MLA latent
cache — and :mod:`repro.layers.attention` dispatched between them by
sniffing raw dict keys (``"k"`` vs ``"k_q"``/``"k_scale"`` vs ``"ckv"``)
in three places per segment kind.  Every new family multiplied the
branching, and the byte accounting in :mod:`repro.serve.pool` and
:mod:`repro.core.cost_model` re-derived the layouts by hand.

A :class:`CachePlan` is the cache twin of :class:`repro.layers.plan.
LinearPlan`: one plan per attention layer declaring

* **family** — ``gqa_f32 | gqa_int8 | mla_latent | mla_latent_int8 |
  gqa_paged_f32 | gqa_paged_int8`` (``*_f32``/unsuffixed families hold
  the model dtype, f32 *or* bf16; the name records "full width"; the
  paged families lay K/V out as fixed-size physical blocks addressed
  through per-stream block tables — see :class:`PagedGeometry` and
  :mod:`repro.serve.paging`);
* **leaves** — per-leaf :class:`CacheLeafSpec` (shape template, dtype,
  which axis is the sequence axis, and the quantized-pair ref tying a
  ``*_q`` value leaf to its ``*_scale`` row);
* **bytes** — ``bytes_per_token`` (per-position bytes of one stream),
  ``bytes_per_slot`` (per-slot constants: the f32 scale rows) and
  ``bytes_per_step(slots, seq)`` (the full-pool decode read) — the
  single source of truth behind :class:`repro.serve.pool.KVPoolManager`
  accounting and the roofline's ``kv_bytes`` term;
* **executors** — the write path for all three segment kinds
  (:meth:`write_prefill`, :meth:`write_chunk`, :meth:`write_decode`)
  and the cache-coupled decode attention (:meth:`attend_decode` for GQA
  families, :meth:`attend_decode_latent` for the MLA absorbed form,
  which dispatches the fused int8 kernels behind the shared
  ``ops.kernel_fits`` gate).

``apply_attention`` / ``apply_mla`` are thin executors over the plan:
they own projections, RoPE, and the prefill softmax (which runs on the
full-precision values computed in-layer, never on the cache), while the
plan owns every layout-dependent decision.  :func:`plan_from_cache` is
the ONE place left that classifies a cache dict by its keys — the
fallback when a caller does not thread a plan explicitly.

Plans are static metadata (no array refs), cached per geometry, and safe
to close over inside ``jax.jit``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.quant import kv as kvq

PyTree = Any

FAMILY_GQA = "gqa_f32"
FAMILY_GQA_INT8 = "gqa_int8"
FAMILY_MLA = "mla_latent"
FAMILY_MLA_INT8 = "mla_latent_int8"
FAMILY_GQA_PAGED = "gqa_paged_f32"
FAMILY_GQA_PAGED_INT8 = "gqa_paged_int8"

FAMILIES = (FAMILY_GQA, FAMILY_GQA_INT8, FAMILY_MLA, FAMILY_MLA_INT8,
            FAMILY_GQA_PAGED, FAMILY_GQA_PAGED_INT8)

#: sequence-axis position (from the right) of every per-position cache
#: leaf, by key — K/V pools are (..., S, KH, hd), latents (..., S, r).
#: Scale rows have no sequence axis.  The pool's slot scatter and the
#: plans' leaf specs both read this one map.
SEQ_AXIS: dict[str, int] = {
    "k": -3, "v": -3, "k_q": -3, "v_q": -3,
    "ckv": -2, "krope": -2, "ckv_q": -2, "krope_q": -2,
}

_NEG_INF = -1e30


@dataclasses.dataclass(frozen=True)
class PagedGeometry:
    """Static geometry of a paged KV pool.

    Device K/V leaves are laid out ``(num_blocks + 1, block_size, ...)``
    — the batch axis indexes *physical blocks*, not streams.  Physical
    block id ``num_blocks`` is a reserved garbage block: idle slots'
    block-table rows point at it, so their (discarded) decode scatters
    and reads never touch live data.  A per-layer ``block_tables`` leaf
    ``(slots, blocks_per_slot) int32`` maps each stream's logical block
    index to its physical block.
    """

    block_size: int       #: tokens per KV block
    num_blocks: int       #: usable blocks (device arrays hold +1 dummy)
    slots: int            #: concurrent streams (block-table rows)
    blocks_per_slot: int  #: max_seq // block_size (table row width)

    @property
    def dummy_block(self) -> int:
        return self.num_blocks

    @property
    def max_seq(self) -> int:
        return self.block_size * self.blocks_per_slot


@dataclasses.dataclass(frozen=True)
class CacheLeafSpec:
    """One leaf of a per-layer cache dict.  Static metadata only."""

    name: str                       # cache key ("k", "k_q", "ckv_scale", ...)
    tail_shape: tuple[int, ...]     # dims after (batch[, seq]): (KH, D) / (r,)
    dtype: Any
    seq_axis: int | None            # from the right; None = per-slot constant
    scale_of: str | None = None     # "k_scale" -> scales the "k_q" leaf

    def shape(self, batch: int, seq_len: int) -> tuple[int, ...]:
        if self.seq_axis is None:
            return (batch, *self.tail_shape)
        return (batch, seq_len, *self.tail_shape)

    @property
    def bytes_per_position(self) -> int:
        """Bytes one position of one stream occupies (0 for scale rows)."""
        if self.seq_axis is None:
            return 0
        return int(math.prod(self.tail_shape)) * jnp.dtype(self.dtype).itemsize

    @property
    def bytes_per_slot(self) -> int:
        """Per-slot constant bytes (scale rows; 0 for per-position leaves)."""
        if self.seq_axis is not None:
            return 0
        return int(math.prod(self.tail_shape)) * jnp.dtype(self.dtype).itemsize


@dataclasses.dataclass(frozen=True)
class CachePlan:
    """How one attention layer's cache is laid out, costed, and executed."""

    family: str
    leaves: tuple[CacheLeafSpec, ...]
    #: paged families carry their block geometry; slot families None.
    paged: PagedGeometry | None = None

    # -- contract -----------------------------------------------------------

    @property
    def quantized(self) -> bool:
        return self.family in (FAMILY_GQA_INT8, FAMILY_MLA_INT8,
                               FAMILY_GQA_PAGED_INT8)

    @property
    def mla(self) -> bool:
        return self.family in (FAMILY_MLA, FAMILY_MLA_INT8)

    def leaf(self, name: str) -> CacheLeafSpec:
        for l in self.leaves:
            if l.name == name:
                return l
        raise KeyError(name)

    @property
    def quant_pairs(self) -> dict[str, str]:
        """``{value_leaf: scale_leaf}`` refs for the quantized leaves."""
        return {l.scale_of: l.name for l in self.leaves if l.scale_of}

    # -- construction -------------------------------------------------------

    def _leaf_shape(self, l: CacheLeafSpec, batch: int,
                    seq_len: int) -> tuple[int, ...]:
        # Paged block tables are (slots, blocks_per_slot) regardless of
        # the pool's (num_blocks + 1, block_size) leaf geometry.
        if self.paged is not None and l.name == "block_tables":
            return (self.paged.slots, self.paged.blocks_per_slot)
        return l.shape(batch, seq_len)

    def spec(self, batch: int, seq_len: int) -> dict:
        return {l.name: jax.ShapeDtypeStruct(
                    self._leaf_shape(l, batch, seq_len), l.dtype)
                for l in self.leaves}

    def init(self, batch: int, seq_len: int) -> dict:
        """Zero-initialized cache (zero scales dequantize to zeros).
        Paged block tables initialize to the reserved dummy block so an
        unallocated stream can never alias live data."""
        out = {}
        for l in self.leaves:
            shape = self._leaf_shape(l, batch, seq_len)
            if self.paged is not None and l.name == "block_tables":
                out[l.name] = jnp.full(shape, self.paged.dummy_block,
                                       l.dtype)
            else:
                out[l.name] = jnp.zeros(shape, l.dtype)
        return out

    # -- accounting (single source of truth for pool / roofline) ------------

    @property
    def bytes_per_token(self) -> int:
        """Per-position cache bytes of ONE stream, this layer."""
        return sum(l.bytes_per_position for l in self.leaves)

    @property
    def bytes_per_slot(self) -> int:
        """Per-slot constant bytes (f32 scale rows), this layer.  For
        paged families "slot" means one physical block (the scale rows
        are per-block); the int32 block tables are metadata, not KV."""
        return sum(l.bytes_per_slot for l in self.leaves
                   if l.name != "block_tables")

    @property
    def bytes_per_block(self) -> int:
        """KV bytes of ONE physical block (paged families only):
        ``block_size`` positions of values plus the per-block scale
        rows."""
        if self.paged is None:
            raise ValueError(f"{self.family} is not a paged family")
        return (self.paged.block_size * self.bytes_per_token
                + self.bytes_per_slot)

    def bytes_per_step(self, slots: int, seq_len: int) -> int:
        """HBM bytes this layer's pool streams per decode step — decode
        reads every slot's full ``seq_len`` (masked, not skipped).  The
        paged kernel streams one block per table entry (cold entries
        alias the dummy block) plus the tables themselves."""
        if self.paged is not None:
            nblk = seq_len // self.paged.block_size
            return slots * (nblk * self.bytes_per_block
                            + nblk * jnp.dtype(jnp.int32).itemsize)
        return slots * (seq_len * self.bytes_per_token + self.bytes_per_slot)

    # -- write executors ----------------------------------------------------
    # ``new`` carries the layer's full-precision values under their
    # LOGICAL names: {"k", "v"} (B, S, KH, D) for GQA families,
    # {"ckv"} (B, S, r) + {"krope"} (B, S, rope) for MLA families
    # (decode passes one-token values without the S axis).

    def _mask_new(self, new: dict, start_pos, prompt_len) -> dict:
        """Zero rows at absolute positions ``>= prompt_len`` (bucket-pad
        tail) so they can neither land garbage in the pool nor inflate
        the int8 running-max scales."""
        if prompt_len is None:
            return new
        out = {}
        for key, x in new.items():
            sq = x.shape[1]
            pad = (1,) * (-SEQ_AXIS[key] - 1)
            pm = (start_pos + jnp.arange(sq) < prompt_len).reshape(
                (1, sq, *pad))
            out[key] = jnp.where(pm, x, 0.0)
        return out

    def write_prefill(self, cache: dict, new: dict,
                      prompt_len: jax.Array | None = None) -> dict:
        """Whole-prompt write at position 0 (quantize-on-insert for the
        int8 families, one-shot scales over the real prompt)."""
        if self.paged is not None:
            raise ValueError(
                "paged pools take no sequential prefill writes — serve "
                "stages prompts in a contiguous stream cache and the "
                "pool manager scatters whole blocks at insert")
        if not self.quantized:
            return {k: lax.dynamic_update_slice_in_dim(cache[k], v, 0, 1)
                    for k, v in new.items()}
        new = self._mask_new(new, 0, prompt_len)
        out = {}
        for key, x in new.items():
            q, scale = kvq.quantize_kv_prefill(x)
            out[key + "_q"] = lax.dynamic_update_slice_in_dim(
                cache[key + "_q"], q, 0, 1)
            out[key + "_scale"] = scale
        return out

    def write_chunk(self, cache: dict, new: dict, start_pos: jax.Array,
                    prompt_len: jax.Array | None = None
                    ) -> tuple[dict, dict]:
        """Chunk write at a sequence offset.  Returns ``(new_cache,
        views)`` where ``views`` holds the full-precision whole-pool
        attend views under the logical names (the written pool for
        full-width families, the dequantized pool for int8 — serve
        stages chunked prompts full-precision instead, for exactness).
        Pad rows beyond ``prompt_len`` (the chunk's real end) are zeroed
        at the write for BOTH dtypes: a later chunk's bucket is not
        guaranteed to overwrite them before they become attendable.
        """
        if self.paged is not None:
            raise ValueError(
                "paged pools take no chunk writes — chunked prefill "
                "stages into a contiguous stream cache")
        new = self._mask_new(new, start_pos, prompt_len)
        if not self.quantized:
            out = {k: lax.dynamic_update_slice_in_dim(cache[k], v,
                                                      start_pos, 1)
                   for k, v in new.items()}
            return out, out
        out, views = {}, {}
        for key, x in new.items():
            q, scale = kvq.kv_write_chunk(cache[key + "_q"],
                                          cache[key + "_scale"], x,
                                          start_pos)
            out[key + "_q"] = q
            out[key + "_scale"] = scale
            views[key] = kvq.dequantize_kv(q, scale, x.dtype)
        return out, views

    def write_decode(self, cache: dict, new: dict,
                     cache_pos: jax.Array) -> dict:
        """One-token scatter at per-slot positions ``cache_pos`` (B,).
        ``new`` values carry no S axis: (B, KH, D) / (B, r).  Int8
        families take the incremental running-max scale update
        (:func:`repro.quant.kv.kv_write_token`)."""
        if self.paged is not None:
            return self._write_decode_paged(cache, new, cache_pos)
        bidx = jnp.arange(cache_pos.shape[0])
        if not self.quantized:
            return {k: cache[k].at[bidx, cache_pos].set(v)
                    for k, v in new.items()}
        out = {}
        for key, x in new.items():
            q, scale = kvq.kv_write_token(cache[key + "_q"],
                                          cache[key + "_scale"], x,
                                          cache_pos)
            out[key + "_q"] = q
            out[key + "_scale"] = scale
        return out

    def _write_decode_paged(self, cache: dict, new: dict,
                            cache_pos: jax.Array) -> dict:
        """One-token scatter through the block tables: the target block
        is ``tables[slot, pos // bs]`` and the row ``pos % bs``.  Slots
        whose table row still points at the dummy block (idle) write
        garbage into the dummy — harmless by construction.  Int8 takes
        the running-max scale update on the ONE gathered block, then
        scatters block + scale row back (a requant touches only that
        block, never the shared prefix blocks — which are never the
        write target: decode always lands past the shared prefix)."""
        geom = self.paged
        bt = cache["block_tables"]
        bidx = jnp.arange(cache_pos.shape[0])
        blk = jnp.minimum(cache_pos // geom.block_size,
                          geom.blocks_per_slot - 1)
        phys = bt[bidx, blk]                              # (B,) physical ids
        # a position past the table (slot-pool scatters drop it as OOB)
        # must land in the dummy, not clamp into the slot's last block
        phys = jnp.where(cache_pos < geom.max_seq, phys, geom.dummy_block)
        row = cache_pos % geom.block_size
        out = {"block_tables": bt}
        if not self.quantized:
            for key, x in new.items():
                out[key] = cache[key].at[phys, row].set(
                    x.astype(cache[key].dtype))
            return out
        for key, x in new.items():
            blk = cache[key + "_q"][phys]                 # (B, bs, KH, D)
            sc = cache[key + "_scale"][phys]              # (B, KH, D)
            blk, sc = kvq.kv_write_token(blk, sc, x, row)
            out[key + "_q"] = cache[key + "_q"].at[phys].set(blk)
            out[key + "_scale"] = cache[key + "_scale"].at[phys].set(sc)
        return out

    # -- decode attention (the cache-coupled read) --------------------------

    def attend_decode(self, q: jax.Array, cache: dict,
                      cache_pos: jax.Array, *, softcap: float = 0.0,
                      use_pallas: bool = False) -> jax.Array:
        """GQA decode: one query row vs the whole pool.  q (B, 1, H, D)
        -> (B, 1, H, D).  Int8 pools run the fused kernel under
        ``use_pallas`` (VMEM-fit fallback inside the ops wrapper) or the
        jnp dequant oracle — a full-precision pool copy never lands in
        HBM on the kernel path."""
        if self.mla:
            raise ValueError("latent families attend via "
                             "attend_decode_latent")
        if self.paged is not None:
            from repro.kernels import ops as kops
            from repro.kernels import ref as kref
            bt = cache["block_tables"]
            if not self.quantized:
                fn = kops.decode_attention_paged if use_pallas \
                    else kref.decode_attention_paged_ref
                return fn(q, cache["k"], cache["v"], bt, cache_pos,
                          softcap=softcap)
            fn = kops.decode_attention_paged_q if use_pallas \
                else kref.decode_attention_paged_q_ref
            return fn(q, cache["k_q"], cache["k_scale"], cache["v_q"],
                      cache["v_scale"], bt, cache_pos, softcap=softcap)
        if not self.quantized:
            skv = cache["k"].shape[1]
            valid = jnp.arange(skv)[None, :] <= cache_pos[:, None]  # (B,S)
            return gqa_decode_attention(q, cache["k"], cache["v"], valid,
                                        softcap)
        from repro.kernels import ops as kops
        from repro.kernels import ref as kref
        fn = kops.decode_attention_q if use_pallas \
            else kref.decode_attention_q_ref
        return fn(q, cache["k_q"], cache["k_scale"], cache["v_q"],
                  cache["v_scale"], cache_pos, softcap=softcap)

    def attend_decode_latent(self, q_lat: jax.Array, q_rope: jax.Array,
                             cache: dict, cache_pos: jax.Array, *,
                             scale: float,
                             use_pallas: bool = False) -> jax.Array:
        """MLA absorbed decode: latent-space queries vs the latent pool.
        q_lat (B, 1, H, r); q_rope (B, 1, H, rope) -> context latents
        (B, 1, H, r) — attention runs entirely against the cached
        latents, per-head K/V are never materialized.  Int8 pools run
        the fused latent kernel (ckv/krope scales folded into the
        latent query rows, ckv scales into the context output) under
        ``use_pallas``, else the dequant oracle."""
        if not self.mla:
            raise ValueError("GQA families attend via attend_decode")
        if not self.quantized:
            cc, cr = cache["ckv"], cache["krope"]
            s = (jnp.einsum("bqhl,bsl->bhqs", q_lat, cc,
                            preferred_element_type=jnp.float32)
                 + jnp.einsum("bqhr,bsr->bhqs", q_rope, cr,
                              preferred_element_type=jnp.float32)) * scale
            valid = jnp.arange(cc.shape[1])[None, :] <= cache_pos[:, None]
            s = jnp.where(valid[:, None, None, :], s, _NEG_INF)
            attn = jax.nn.softmax(s, axis=-1).astype(q_lat.dtype)
            return jnp.einsum("bhqs,bsl->bqhl", attn, cc)
        from repro.kernels import ops as kops
        from repro.kernels import ref as kref
        fn = kops.decode_attention_latent_q if use_pallas \
            else kref.decode_attention_latent_q_ref
        return fn(q_lat, q_rope, cache["ckv_q"], cache["ckv_scale"],
                  cache["krope_q"], cache["krope_scale"], cache_pos,
                  scale=scale)


def gqa_decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                         valid: jax.Array, softcap: float) -> jax.Array:
    """Full-width GQA decode attention: q (B, 1, H, D) vs k/v
    (B, S, KH, D), slot validity (B, S) masked into the f32 logits."""
    b, sq, h, hd = q.shape
    kh = k.shape[2]
    qg = q.reshape(b, sq, kh, h // kh, hd)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qg, k,
                   preferred_element_type=jnp.float32) * (1.0 / math.sqrt(hd))
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    s = jnp.where(valid[:, None, None, None, :], s, _NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    o = jnp.einsum("bkgqs,bskh->bqkgh", p, v)
    return o.reshape(b, sq, h, hd)


# ---------------------------------------------------------------------------
# Plan construction (cached — one plan object per geometry)
# ---------------------------------------------------------------------------

_PLAN_CACHE: dict[tuple, CachePlan] = {}


def _check_quantize(quantize: str | None) -> bool:
    if quantize in (None, "none"):
        return False
    if quantize not in kvq.KV_MODES:
        raise ValueError(
            f"unknown kv quant mode {quantize!r} (want one of "
            f"{kvq.KV_MODES})")
    return True


def gqa_plan(num_kv_heads: int, head_dim: int, dtype,
             quantize: str | None = None) -> CachePlan:
    """The plan for one GQA/MHA attention layer's K/V cache."""
    q = _check_quantize(quantize)
    key = ("gqa", num_kv_heads, head_dim, jnp.dtype(dtype).name, q)
    plan = _PLAN_CACHE.get(key)
    if plan is None:
        tail = (num_kv_heads, head_dim)
        if q:
            leaves = []
            for name in ("k", "v"):
                leaves.append(CacheLeafSpec(name + "_q", tail, jnp.int8,
                                            SEQ_AXIS[name + "_q"]))
                leaves.append(CacheLeafSpec(name + "_scale", tail,
                                            jnp.float32, None,
                                            scale_of=name + "_q"))
            plan = CachePlan(FAMILY_GQA_INT8, tuple(leaves))
        else:
            plan = CachePlan(FAMILY_GQA, tuple(
                CacheLeafSpec(n, tail, jnp.dtype(dtype), SEQ_AXIS[n])
                for n in ("k", "v")))
        _PLAN_CACHE[key] = plan
    return plan


def gqa_paged_plan(num_kv_heads: int, head_dim: int, dtype,
                   quantize: str | None = None, *,
                   geometry: PagedGeometry) -> CachePlan:
    """The plan for one GQA layer's *paged* K/V pool.  Value leaves are
    ``(num_blocks + 1, block_size, KH, D)`` — batch axis = physical
    block — plus a ``(slots, blocks_per_slot)`` int32 ``block_tables``
    leaf.  The int8 family blocks quantized values and their scale rows
    together: one ``(KH, D)`` f32 scale row per physical block, so a
    shared prefix block travels with its own scales."""
    q = _check_quantize(quantize)
    key = ("gqa_paged", num_kv_heads, head_dim, jnp.dtype(dtype).name, q,
           geometry)
    plan = _PLAN_CACHE.get(key)
    if plan is None:
        tail = (num_kv_heads, head_dim)
        leaves = []
        if q:
            for name in ("k", "v"):
                leaves.append(CacheLeafSpec(name + "_q", tail, jnp.int8,
                                            SEQ_AXIS[name + "_q"]))
                leaves.append(CacheLeafSpec(name + "_scale", tail,
                                            jnp.float32, None,
                                            scale_of=name + "_q"))
            family = FAMILY_GQA_PAGED_INT8
        else:
            leaves = [CacheLeafSpec(n, tail, jnp.dtype(dtype), SEQ_AXIS[n])
                      for n in ("k", "v")]
            family = FAMILY_GQA_PAGED
        leaves.append(CacheLeafSpec("block_tables", (), jnp.int32, None))
        plan = CachePlan(family, tuple(leaves), paged=geometry)
        _PLAN_CACHE[key] = plan
    return plan


def mla_plan(kv_lora_rank: int, qk_rope_dim: int, dtype,
             quantize: str | None = None) -> CachePlan:
    """The plan for one MLA layer's latent cache.  The latent *is* the
    rank-compressed K/V factor; the int8 family compresses it again with
    per-(slot, channel) scales (no head axis — all heads share the one
    latent stream)."""
    q = _check_quantize(quantize)
    key = ("mla", kv_lora_rank, qk_rope_dim, jnp.dtype(dtype).name, q)
    plan = _PLAN_CACHE.get(key)
    if plan is None:
        dims = {"ckv": (kv_lora_rank,), "krope": (qk_rope_dim,)}
        if q:
            leaves = []
            for name, tail in dims.items():
                leaves.append(CacheLeafSpec(name + "_q", tail, jnp.int8,
                                            SEQ_AXIS[name + "_q"]))
                leaves.append(CacheLeafSpec(name + "_scale", tail,
                                            jnp.float32, None,
                                            scale_of=name + "_q"))
            plan = CachePlan(FAMILY_MLA_INT8, tuple(leaves))
        else:
            plan = CachePlan(FAMILY_MLA, tuple(
                CacheLeafSpec(n, tail, jnp.dtype(dtype), SEQ_AXIS[n])
                for n, tail in dims.items()))
        _PLAN_CACHE[key] = plan
    return plan


def build_cache_plan(cfg, dtype, kv_quantize: str | None = None,
                     paged: PagedGeometry | None = None) -> CachePlan:
    """The per-attention-layer plan for a model config (``cfg.mla``
    selects the latent families; a ``paged`` geometry selects the paged
    GQA families)."""
    if paged is not None:
        if cfg.mla:
            raise ValueError("paged KV pools serve the GQA families "
                             "only (no paged MLA latent cache yet)")
        return gqa_paged_plan(cfg.num_kv_heads, cfg.resolved_head_dim,
                              dtype, kv_quantize, geometry=paged)
    if cfg.mla:
        return mla_plan(cfg.kv_lora_rank, cfg.qk_rope_dim, dtype,
                        kv_quantize)
    return gqa_plan(cfg.num_kv_heads, cfg.resolved_head_dim, dtype,
                    kv_quantize)


def plan_from_cache(cache: dict, dtype=jnp.float32) -> CachePlan:
    """Classify a per-layer cache dict into its plan — the ONE remaining
    key-sniffing point, used when a caller has no plan threaded (direct
    layer-level use; the serve stack always threads plans).  Geometry
    comes from the leaf shapes; ``dtype`` is only needed for int8
    families (full-width leaves carry theirs)."""
    if "block_tables" in cache:
        val = cache.get("k", cache.get("k_q"))
        nb1, bs, kh, hd = val.shape[-4:]
        slots, bpslot = cache["block_tables"].shape[-2:]
        geom = PagedGeometry(bs, nb1 - 1, slots, bpslot)
        if "k_q" in cache:
            return gqa_paged_plan(kh, hd, dtype, "int8", geometry=geom)
        return gqa_paged_plan(kh, hd, cache["k"].dtype, None,
                              geometry=geom)
    if "ckv_q" in cache:
        return mla_plan(cache["ckv_q"].shape[-1], cache["krope_q"].shape[-1],
                        dtype, "int8")
    if "ckv" in cache:
        return mla_plan(cache["ckv"].shape[-1], cache["krope"].shape[-1],
                        cache["ckv"].dtype, None)
    if "k_q" in cache:
        kh, hd = cache["k_q"].shape[-2:]
        return gqa_plan(kh, hd, dtype, "int8")
    if "k" in cache:
        kh, hd = cache["k"].shape[-2:]
        return gqa_plan(kh, hd, cache["k"].dtype, None)
    raise ValueError(f"not a KV cache dict: {sorted(cache)}")

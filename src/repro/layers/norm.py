"""Normalization layers (RMSNorm / LayerNorm), f32 internals."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.layers.param import ParamBuilder, EMBED


def init_rms_norm(pb: ParamBuilder, name: str, dim: int) -> None:
    sub = pb.child(name)
    sub.param("scale", (dim,), (EMBED,), init="ones")


def rms_norm(p: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


def init_layer_norm(pb: ParamBuilder, name: str, dim: int) -> None:
    sub = pb.child(name)
    sub.param("scale", (dim,), (EMBED,), init="ones")
    sub.param("bias", (dim,), (EMBED,), init="zeros")


def layer_norm(p: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


def gated_rms_norm(p: dict, x: jax.Array, gate: jax.Array,
                   eps: float = 1e-5) -> jax.Array:
    """Mamba2's RMSNormGated: normalize(x * silu(gate))."""
    xf = x.astype(jnp.float32) * jax.nn.silu(gate.astype(jnp.float32))
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)

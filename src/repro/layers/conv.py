"""Conv ops with LRD dispatch (dense | Tucker-2 | branched Tucker).

Weights are HWIO ``(k, k, C, S)``; activations NHWC.  The LRD surgery
rewrites a conv subtree to the Tucker triple (paper Fig. 1b) or its
branched form (Fig. 4); :func:`apply_conv` dispatches on the keys present,
so ResNet model code is decomposition-agnostic — the same seam as
``apply_linear``.

The branched core runs as a *grouped convolution*
(``feature_group_count=N``) exactly as the paper's Fig. 4 equivalence.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.layers.param import ParamBuilder, CONV, EMBED, FFN


def init_conv(pb: ParamBuilder, name: str, c_in: int, c_out: int, k: int,
              scale: float | None = None) -> None:
    sub = pb.child(name)
    fan_in = c_in * k * k
    sub.param("w", (k, k, c_in, c_out), (CONV, CONV, EMBED, FFN),
              scale=scale if scale is not None else fan_in ** -0.5)


def _conv(x: jax.Array, w: jax.Array, stride: int, groups: int = 1,
          padding: str = "SAME") -> jax.Array:
    return lax.conv_general_dilated(
        x, w, window_strides=(stride, stride), padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=groups)


def apply_conv(p: dict, x: jax.Array, *, stride: int = 1,
               padding: str = "SAME",
               freeze_factors: bool = False) -> jax.Array:
    """NHWC conv through a (possibly decomposed) weight subtree."""
    from repro.quant.quantize import dequantize_subtree, is_quantized
    if is_quantized(p):
        p = dequantize_subtree(p, x.dtype)
        freeze_factors = False                     # serve-time, no grads
    if "w" in p:                                   # dense
        return _conv(x, p["w"], stride, padding=padding)
    if "w0" in p:                                  # 1x1 conv = SVD pair
        w0, w1 = p["w0"], p["w1"]
        if freeze_factors:
            w0 = lax.stop_gradient(w0)
        h = _conv(x, w0[None, None, :, :], stride, padding="VALID")
        return _conv(h, w1[None, None, :, :], 1, padding="VALID")
    if "tucker_u" in p:                            # Tucker-2 triple
        u, core, v = p["tucker_u"], p["core"], p["tucker_v"]
        if freeze_factors:
            u = lax.stop_gradient(u)
            v = lax.stop_gradient(v)
        h = _conv(x, u[None, None, :, :], 1, padding="VALID")
        h = _conv(h, core, stride, padding=padding)
        return _conv(h, v[None, None, :, :], 1, padding="VALID")
    # Branched Tucker: u (N,C,r1), core (N,k,k,r1,r2), v (N,r2,S).
    u, core, v = p["u"], p["core"], p["v"]
    if freeze_factors:
        u = lax.stop_gradient(u)
        v = lax.stop_gradient(v)
    n, c, r1 = u.shape
    _, kh, kw, _, r2 = core.shape
    s = v.shape[-1]
    # 1) project into all branches at once: (C, N*r1)
    u_cat = jnp.moveaxis(u, 0, 1).reshape(c, n * r1)
    h = _conv(x, u_cat[None, None, :, :], 1, padding="VALID")
    # 2) grouped kxk conv: block-diagonal core == feature_group_count=N
    #    HWIO for grouped conv wants I = r1 (per-group), O = N*r2.
    core_g = jnp.concatenate([core[j] for j in range(n)], axis=-1)
    h = _conv(h, core_g, stride, groups=n, padding=padding)
    # 3) combine branches: block-diag (N*r2, S) == sum_j h_j @ v_j
    v_cat = v.reshape(n * r2, s)
    return _conv(h, v_cat[None, None, :, :], 1, padding="VALID")


def conv_out_channels(p: dict) -> int:
    for key in ("w", "tucker_v", "tucker_v_q", "w1", "w1_q", "v", "v_q"):
        if key in p:
            return p[key].shape[-1]
    raise ValueError(f"not a conv param subtree: {list(p)}")

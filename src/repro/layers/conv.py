"""Conv ops with LRD dispatch (dense | Tucker-2 | branched Tucker).

Weights are HWIO ``(k, k, C, S)``; activations NHWC.  The LRD surgery
rewrites a conv subtree to the Tucker triple (paper Fig. 1b) or its
branched form (Fig. 4); :func:`apply_conv` dispatches on the keys present,
so ResNet model code is decomposition-agnostic — the same seam as
``apply_linear``.

The branched core runs as a *grouped convolution*
(``feature_group_count=N``) exactly as the paper's Fig. 4 equivalence.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.layers.param import ParamBuilder, CONV, EMBED, FFN


def init_conv(pb: ParamBuilder, name: str, c_in: int, c_out: int, k: int,
              scale: float | None = None) -> None:
    sub = pb.child(name)
    fan_in = c_in * k * k
    sub.param("w", (k, k, c_in, c_out), (CONV, CONV, EMBED, FFN),
              scale=scale if scale is not None else fan_in ** -0.5)


def _conv(x: jax.Array, w: jax.Array, stride: int, groups: int = 1,
          padding: str = "SAME") -> jax.Array:
    return lax.conv_general_dilated(
        x, w, window_strides=(stride, stride), padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=groups)


def apply_conv(p: dict, x: jax.Array, *, stride: int = 1,
               padding: str = "SAME",
               freeze_factors: bool = False) -> jax.Array:
    """NHWC conv through a (possibly decomposed) weight subtree.

    Thin executor over :class:`repro.layers.plan.LinearPlan` — the plan
    classifies the subtree (quantized or not) and hands back each factor
    with on-the-fly dequantization and the §2.2 freeze policy applied
    (``tucker_u``/``tucker_v`` and branched ``u``/``v`` are the frozen,
    teacher-derived factors; quantized factors carry no gradient).
    """
    from repro.layers import plan as lplan
    plan = lplan.build_plan(p)

    def get(name: str) -> jax.Array:
        return plan.value(p, name, x.dtype, freeze=freeze_factors)

    if plan.kind == lplan.KIND_DENSE:
        return _conv(x, get("w"), stride, padding=padding)
    if plan.kind == lplan.KIND_LOWRANK:            # 1x1 conv = SVD pair
        w0, w1 = get("w0"), get("w1")
        h = _conv(x, w0[None, None, :, :], stride, padding="VALID")
        return _conv(h, w1[None, None, :, :], 1, padding="VALID")
    if plan.kind == lplan.KIND_TUCKER_CONV:        # Tucker-2 triple
        u, core, v = get("tucker_u"), get("core"), get("tucker_v")
        h = _conv(x, u[None, None, :, :], 1, padding="VALID")
        h = _conv(h, core, stride, padding=padding)
        return _conv(h, v[None, None, :, :], 1, padding="VALID")
    # Branched Tucker: u (N,C,r1), core (N,k,k,r1,r2), v (N,r2,S).
    u, core, v = get("u"), get("core"), get("v")
    n, c, r1 = u.shape
    _, kh, kw, _, r2 = core.shape
    s = v.shape[-1]
    # 1) project into all branches at once: (C, N*r1)
    u_cat = jnp.moveaxis(u, 0, 1).reshape(c, n * r1)
    h = _conv(x, u_cat[None, None, :, :], 1, padding="VALID")
    # 2) grouped kxk conv: block-diagonal core == feature_group_count=N
    #    HWIO for grouped conv wants I = r1 (per-group), O = N*r2.
    core_g = jnp.concatenate([core[j] for j in range(n)], axis=-1)
    h = _conv(h, core_g, stride, groups=n, padding=padding)
    # 3) combine branches: block-diag (N*r2, S) == sum_j h_j @ v_j
    v_cat = v.reshape(n * r2, s)
    return _conv(h, v_cat[None, None, :, :], 1, padding="VALID")


def conv_out_channels(p: dict) -> int:
    from repro.layers.plan import build_plan
    return build_plan(p).d_out

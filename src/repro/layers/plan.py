"""LinearPlan — the single execution-plan seam for every linear flavour.

The paper's central tension (its §1 "more layers = more latency"
complaint) is that decomposition shrinks *parameters* but doubles layer
*depth*: a dense ``y = x W`` becomes the chain ``y = (x W0) W1``
(Eq. 5), or the branched block-diagonal form of **Eq. 17**

    y = sum_j ((x @ u_j) @ xc_j) @ v_j

whose per-branch factors ``u_j (C, r1)``, ``xc_j (r1, r2)``,
``v_j (r2, S)`` are exactly the :class:`FactorSpec` entries of a
``kind="branched"`` plan (``u`` / ``xc`` / ``v`` carry the stacked
``(N, ., .)`` branch axis).  The Tucker-2 conv triple (paper Fig. 1b)
maps the same way: ``tucker_u`` / ``core`` / ``tucker_v`` are the three
FactorSpecs of a ``kind="tucker_conv"`` plan.

Before this module, every consumer re-derived "what kind of linear is
this and how should it run" by sniffing dict keys: ``apply_linear`` /
``apply_conv`` if-chains, the ``*_q``/``*_scale`` convention from
:mod:`repro.quant`, per-op VMEM-fit checks in :mod:`repro.kernels.ops`,
and ``parallel/sharding.py`` was blind to quantized keys entirely.  A
:class:`LinearPlan` centralizes that seam:

* **kind** — ``dense | lowrank | branched | tucker_conv |
  branched_tucker_conv``, classified once from the keys present
  (quantized or not);
* **per-factor** :class:`FactorSpec` — logical name, shape/dtype,
  whether the value lives as a plain array, a quantized
  ``k_q``/``k_scale`` pair, or a 2:4-packed ``k_sp``/``k_idx``
  (+ optional ``k_scale``) triple, and the freeze policy (paper §2.2:
  the teacher-derived factors receive no gradient);
* **kernel eligibility + VMEM fit** — :meth:`LinearPlan.kernel_for`
  decides fused-Pallas vs jnp-reference once, using the kernels' own
  footprint formulas (``repro.kernels.ops.kernel_fits``).  Leading batch
  dims are flattened by the kernel wrappers, so decode-shaped
  ``(B, 1, d)`` activations are eligible (the old ``x.ndim == 2`` gate
  is gone);
* **accounting** — ``param_count`` (logical weights; scales are *not*
  model parameters), ``quant_bytes`` (quantized storage incl. scales,
  reported separately), ``weight_bytes`` (HBM bytes the weight stream
  moves), ``flops_per_token``.

Plans are static metadata — no array refs — so they are built once per
distinct subtree geometry (an internal cache keyed on
``(key, shape, dtype)`` tuples) and are safe to build from
``ShapeDtypeStruct`` trees, traced values, or concrete arrays alike.
``build_plan_tree`` maps a whole param tree to its plans (the serve
engine does this at load time).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.quant.quantize import (IDX_SUFFIX as _IDX_SUFFIX,
                                  QUANT_SUFFIX as _QUANT_SUFFIX,
                                  SCALE_SUFFIX as _SCALE_SUFFIX,
                                  SP_SUFFIX as _SP_SUFFIX)

PyTree = Any

KIND_DENSE = "dense"
KIND_LOWRANK = "lowrank"
KIND_BRANCHED = "branched"
KIND_TUCKER_CONV = "tucker_conv"
KIND_BRANCHED_TUCKER_CONV = "branched_tucker_conv"

#: kinds executable by apply_linear / LinearPlan.execute
LINEAR_KINDS = (KIND_DENSE, KIND_LOWRANK, KIND_BRANCHED)
#: kinds executable only through apply_conv (spatial weights)
CONV_KINDS = (KIND_TUCKER_CONV, KIND_BRANCHED_TUCKER_CONV)

# Factor names per kind, in execution (chain) order, plus which of them
# the §2.2 freeze policy stops gradients through (the teacher-derived
# outer factors; the trainable core/xc keeps its gradient).
_KIND_FACTORS: dict[str, tuple[str, ...]] = {
    KIND_DENSE: ("w",),
    KIND_LOWRANK: ("w0", "w1"),
    KIND_BRANCHED: ("u", "xc", "v"),
    KIND_TUCKER_CONV: ("tucker_u", "core", "tucker_v"),
    KIND_BRANCHED_TUCKER_CONV: ("u", "core", "v"),
}
_KIND_FROZEN: dict[str, frozenset] = {
    KIND_DENSE: frozenset(),
    KIND_LOWRANK: frozenset({"w0"}),
    KIND_BRANCHED: frozenset({"u", "v"}),
    KIND_TUCKER_CONV: frozenset({"tucker_u", "tucker_v"}),
    KIND_BRANCHED_TUCKER_CONV: frozenset({"u", "v"}),
}


@dataclasses.dataclass(frozen=True)
class FactorSpec:
    """One factor of a (possibly decomposed, possibly quantized) linear.

    Static metadata only: the arrays themselves stay in the param tree
    and are fetched by :meth:`LinearPlan.value` at execution time.
    """

    name: str                      # logical key ("w0", "xc", "tucker_u", ...)
    shape: tuple[int, ...]         # logical (unquantized, dense) shape
    dtype: Any                     # value dtype (q/packed dtype when narrow)
    quantized: bool                # stored as name_q / name_scale pair
    frozen: bool                   # §2.2: stop_gradient under freeze policy
    scale_shape: tuple[int, ...] | None = None
    sparsity: str | None = None    # "2:4" when stored name_sp / name_idx
    idx_shape: tuple[int, ...] | None = None

    @property
    def density(self) -> float:
        """Kept fraction of the logical values (1.0 when dense)."""
        if self.sparsity is None:
            return 1.0
        keep, group = (int(t) for t in self.sparsity.split(":"))
        return keep / group

    @property
    def size(self) -> int:
        return int(math.prod(self.shape))

    @property
    def stored_size(self) -> int:
        """Values actually stored (the 2:4 packing keeps half of them)."""
        return int(round(self.size * self.density))

    @property
    def bytes(self) -> int:
        """HBM bytes this factor's storage occupies (incl. scale and
        sparse-index metadata)."""
        n = self.stored_size * jnp.dtype(self.dtype).itemsize
        if self.idx_shape is not None:
            n += int(math.prod(self.idx_shape))         # int8 indices
        if self.quantized and self.scale_shape is not None:
            n += int(math.prod(self.scale_shape)) * 4   # f32 scales
        return n


def _spec_from(p: dict, kind: str, name: str) -> FactorSpec:
    frozen = name in _KIND_FROZEN[kind]
    if name in p:
        v = p[name]
        return FactorSpec(name, tuple(int(d) for d in v.shape),
                          jnp.dtype(v.dtype), False, frozen)
    if name + _SP_SUFFIX in p:
        # 2:4-packed factor: slot-major (..., 2, G, S) values + index
        # metadata; the logical dense shape has 4G input rows.
        sp = p[name + _SP_SUFFIX]
        idx = p[name + _IDX_SUFFIX]
        scale = p.get(name + _SCALE_SUFFIX)
        shape = (*(int(d) for d in sp.shape[:-3]),
                 4 * int(sp.shape[-2]), int(sp.shape[-1]))
        return FactorSpec(name, shape, jnp.dtype(sp.dtype),
                          scale is not None, False,
                          tuple(int(d) for d in scale.shape)
                          if scale is not None else None,
                          sparsity="2:4",
                          idx_shape=tuple(int(d) for d in idx.shape))
    q = p[name + _QUANT_SUFFIX]
    scale = p[name + _SCALE_SUFFIX]
    # Quantized factors carry no gradient (serve-time transform), so the
    # freeze policy is moot — record them unfrozen.
    return FactorSpec(name, tuple(int(d) for d in q.shape),
                      jnp.dtype(q.dtype), True, False,
                      tuple(int(d) for d in scale.shape))


@dataclasses.dataclass(frozen=True)
class LinearPlan:
    """How one linear subtree executes: kind, factors, kernel decision."""

    kind: str
    factors: tuple[FactorSpec, ...]

    # -- factor access ------------------------------------------------------

    def factor(self, name: str) -> FactorSpec:
        for f in self.factors:
            if f.name == name:
                return f
        raise KeyError(name)

    def value(self, p: dict, name: str, dtype=None, *,
              freeze: bool = False) -> jax.Array:
        """Fetch factor ``name`` from tree ``p``: dequantizes a
        ``k_q``/``k_scale`` pair on the fly (to ``dtype``, default bf16
        — the serving activation dtype), expands a 2:4-packed
        ``k_sp``/``k_idx`` factor back to dense, and applies the §2.2
        freeze policy to plain factors."""
        spec = self.factor(name)
        if spec.sparsity is not None:
            from repro.quant.sparse import expand_sparse
            return expand_sparse(p[name + _SP_SUFFIX], p[name + _IDX_SUFFIX],
                                 p.get(name + _SCALE_SUFFIX), dtype)
        if spec.quantized:
            from repro.quant.quantize import dequantize_array
            return dequantize_array(p[name + _QUANT_SUFFIX],
                                    p[name + _SCALE_SUFFIX],
                                    dtype or jnp.bfloat16)
        v = p[name]
        if freeze and spec.frozen:
            v = lax.stop_gradient(v)
        return v

    # -- derived geometry ---------------------------------------------------

    @property
    def quantized(self) -> bool:
        """Any factor stored quantized."""
        return any(f.quantized for f in self.factors)

    @property
    def fully_quantized(self) -> bool:
        """Every factor quantized — the fused-q kernels need all of them."""
        return all(f.quantized for f in self.factors)

    @property
    def sparse(self) -> bool:
        """Any factor stored 2:4-packed."""
        return any(f.sparsity is not None for f in self.factors)

    @property
    def d_in(self) -> int:
        return self.factors[0].shape[-2]

    @property
    def d_out(self) -> int:
        return self.factors[-1].shape[-1]

    @property
    def branches(self) -> int:
        if self.kind in (KIND_BRANCHED, KIND_BRANCHED_TUCKER_CONV):
            return self.factors[0].shape[-3]
        return 1

    # -- accounting ---------------------------------------------------------

    @property
    def param_count(self) -> int:
        """Stored model parameters.  Quantized / 2:4-packed values count
        (they *are* the weights, in narrow storage, at the *kept* count
        for sparse factors); the f32 ``*_scale`` rows and int8 ``*_idx``
        position metadata are codebook bookkeeping, not parameters —
        counting them skewed the compression ratios."""
        return sum(f.stored_size for f in self.factors)

    @property
    def quant_bytes(self) -> int:
        """Bytes of quantized storage (narrow values + scales) —
        reported separately from ``param_count``."""
        return sum(f.bytes for f in self.factors if f.quantized)

    @property
    def weight_bytes(self) -> int:
        """HBM bytes the weight stream moves per full pass (the decode
        roofline's memory term)."""
        return sum(f.bytes for f in self.factors)

    def matmul_chain(self) -> list[tuple[int, int, int]]:
        """The matmul chain as ``(mult, k, n)`` triples — ``mult``
        repetitions of an ``(M, k) @ (k, n)`` — for the cost model."""
        s = {f.name: f.shape for f in self.factors}
        if self.kind == KIND_DENSE:
            kh = kw = 1
            if len(s["w"]) >= 4:                      # spatial conv weight
                kh, kw = s["w"][-4], s["w"][-3]
            return [(1, kh * kw * s["w"][-2], s["w"][-1])]
        if self.kind == KIND_LOWRANK:
            c, r = s["w0"][-2], s["w0"][-1]
            return [(1, c, r), (1, r, s["w1"][-1])]
        if self.kind == KIND_BRANCHED:
            n = self.branches
            c, r1 = s["u"][-2], s["u"][-1]
            r2 = s["xc"][-1]
            return [(n, c, r1), (n, r1, r2), (n, r2, s["v"][-1])]
        if self.kind == KIND_TUCKER_CONV:
            c, r1 = s["tucker_u"][-2], s["tucker_u"][-1]
            kh, kw, _, r2 = s["core"][-4:]
            return [(1, c, r1), (1, kh * kw * r1, r2),
                    (1, r2, s["tucker_v"][-1])]
        n = self.branches                             # branched tucker
        c, r1 = s["u"][-2], s["u"][-1]
        kh, kw, _, r2 = s["core"][-4:]
        return [(n, c, r1), (n, kh * kw * r1, r2), (n, r2, s["v"][-1])]

    def chain_factors(self) -> tuple[FactorSpec, ...]:
        """Per-matmul :class:`FactorSpec`, aligned with
        :meth:`matmul_chain` — the weight operand of each dot."""
        return tuple(self.factor(name)
                     for name in _KIND_FACTORS[self.kind])

    def chain_density(self) -> tuple[float, ...]:
        """Per-matmul kept fraction, aligned with :meth:`matmul_chain`
        (2:4 factors feed sparsity-capable MXUs at half the FLOPs)."""
        return tuple(f.density for f in self.chain_factors())

    @property
    def flops_per_token(self) -> float:
        """Forward matmul FLOPs per input row (per output pixel for
        spatial conv kinds), density-scaled for 2:4 factors."""
        return sum(2.0 * mult * k * n * d
                   for (mult, k, n), d in zip(self.matmul_chain(),
                                              self.chain_density()))

    # -- kernel dispatch ----------------------------------------------------

    def kernel_for(self, x_shape: tuple[int, ...], use_pallas: bool,
                   act_quantize: bool = False) -> str | None:
        """Which fused Pallas kernel (if any) executes this plan for an
        activation of ``x_shape``.

        The kernel wrappers flatten leading batch dims themselves, so
        any ``(..., d_in)`` activation is eligible — including
        decode-shaped ``(B, 1, d)`` — the fit decision runs on
        ``M = prod(leading dims)``.  Returns one of ``"lowrank"``,
        ``"lowrank_q"``, ``"lowrank_qa"``, ``"lowrank_sq"``,
        ``"branched"``, ``"branched_q"``, ``"branched_qa"``,
        ``"branched_sq"`` or ``None`` (jnp reference path).

        ``act_quantize`` asks for the activation-quantized int8 x int8
        kernels; they engage only on fully-int8 non-sparse plans (fp8
        weights and 2:4 layouts keep their own kernels) and fall back
        to the weight-only dispatch when ineligible — the runner sets
        it for prefill/chunk segments, never decode.
        """
        if not use_pallas or len(x_shape) < 2:
            return None
        if self.kind not in (KIND_LOWRANK, KIND_BRANCHED):
            return None
        # Stacked (scan-dim) factors never reach the kernels directly.
        want_ndim = 2 if self.kind == KIND_LOWRANK else 3
        if any(len(f.shape) != want_ndim for f in self.factors):
            return None
        from repro.kernels import ops as kops
        m = int(math.prod(x_shape[:-1]))
        chain = self.matmul_chain()
        if self.sparse:
            # The fused sq kernels want the canonical compound layout:
            # every sparse factor also int8 (sp + idx + scale), and for
            # branched the small core plain-int8 (sparsity excluded from
            # its default targets).  Anything else — bf16-sparse
            # (mode="none") or a partial sparse_targets mix — expands
            # through the reference path.
            if self.kind == KIND_LOWRANK:
                if not all(f.sparsity is not None and f.quantized
                           for f in self.factors):
                    return None
                fits = kops.kernel_fits("lowrank_sq", m, c=chain[0][1],
                                        r=chain[0][2], s=self.d_out)
                return "lowrank_sq" if fits else None
            u, xc, v = (self.factor(n) for n in ("u", "xc", "v"))
            if not (u.sparsity is not None and u.quantized
                    and v.sparsity is not None and v.quantized
                    and xc.quantized and xc.sparsity is None):
                return None
            fits = kops.kernel_fits("branched_sq", m, c=chain[0][1],
                                    r1=chain[0][2], r2=chain[1][2],
                                    s=self.d_out)
            return "branched_sq" if fits else None
        # Mixed plain/quantized subtrees (partial quant_targets) take
        # the dequant reference path.
        if self.quantized and not self.fully_quantized:
            return None
        q_bytes = (jnp.dtype(self.factors[0].dtype).itemsize
                   if self.fully_quantized else 1)
        if (act_quantize and self.fully_quantized
                and all(jnp.dtype(f.dtype) == jnp.int8
                        for f in self.factors)):
            if self.kind == KIND_LOWRANK:
                if kops.kernel_fits("lowrank_qa", m, c=chain[0][1],
                                    r=chain[0][2], s=self.d_out,
                                    q_bytes=q_bytes):
                    return "lowrank_qa"
            elif kops.kernel_fits("branched_qa", m, c=chain[0][1],
                                  r1=chain[0][2], r2=chain[1][2],
                                  s=self.d_out, q_bytes=q_bytes):
                return "branched_qa"
        if self.kind == KIND_LOWRANK:
            name = "lowrank_q" if self.fully_quantized else "lowrank"
            fits = kops.kernel_fits(name, m, c=chain[0][1], r=chain[0][2],
                                    s=self.d_out, q_bytes=q_bytes)
        else:
            name = "branched_q" if self.fully_quantized else "branched"
            fits = kops.kernel_fits(name, m, c=chain[0][1], r1=chain[0][2],
                                    r2=chain[1][2], s=self.d_out,
                                    q_bytes=q_bytes)
        return name if fits else None

    # -- execution ----------------------------------------------------------

    def execute(self, p: dict, x: jax.Array, *,
                freeze_factors: bool = False, use_pallas: bool = False,
                act_quantize: bool = False,
                accum_dtype=jnp.float32) -> jax.Array:
        """Apply this plan's linear op to ``x`` (..., d_in).

        Thin executor: one kernel-or-reference decision, then the
        matmul chain.  Spatial conv kinds execute through
        :func:`repro.layers.conv.apply_conv` instead.
        """
        if self.kind not in LINEAR_KINDS:
            raise ValueError(
                f"kind {self.kind!r} is a conv plan; use apply_conv")
        if self.kind == KIND_DENSE:
            return _matmul(x, self.value(p, "w", x.dtype,
                                         freeze=freeze_factors),
                           accum_dtype)
        kernel = self.kernel_for(x.shape, use_pallas, act_quantize)
        from repro.kernels import ops as kops
        if self.kind == KIND_LOWRANK:
            if kernel == "lowrank_qa":
                return kops.lowrank_matmul_qa(
                    x, p["w0_q"], p["w0_scale"], p["w1_q"], p["w1_scale"],
                    force_kernel=True)
            if kernel == "lowrank_sq":
                return kops.lowrank_matmul_sq(
                    x, p["w0_sp"], p["w0_idx"], p["w0_scale"],
                    p["w1_sp"], p["w1_idx"], p["w1_scale"],
                    force_kernel=True)
            if kernel == "lowrank_q":
                return kops.lowrank_matmul_q(
                    x, p["w0_q"], p["w0_scale"], p["w1_q"], p["w1_scale"],
                    force_kernel=True)
            w0 = self.value(p, "w0", x.dtype, freeze=freeze_factors)
            w1 = self.value(p, "w1", x.dtype, freeze=freeze_factors)
            if kernel == "lowrank":
                return kops.lowrank_matmul(x, w0, w1, force_kernel=True)
            h = _matmul(x, w0, accum_dtype)
            return _matmul(h, w1, accum_dtype)
        # branched: y = sum_j ((x @ u_j) @ xc_j) @ v_j   (paper Eq. 17)
        if kernel == "branched_qa":
            return kops.branched_matmul_qa(
                x, p["u_q"], p["u_scale"], p["xc_q"], p["xc_scale"],
                p["v_q"], p["v_scale"], force_kernel=True)
        if kernel == "branched_sq":
            return kops.branched_matmul_sq(
                x, p["u_sp"], p["u_idx"], p["u_scale"],
                p["xc_q"], p["xc_scale"],
                p["v_sp"], p["v_idx"], p["v_scale"], force_kernel=True)
        if kernel == "branched_q":
            return kops.branched_matmul_q(
                x, p["u_q"], p["u_scale"], p["xc_q"], p["xc_scale"],
                p["v_q"], p["v_scale"], force_kernel=True)
        u = self.value(p, "u", x.dtype, freeze=freeze_factors)
        xc = self.value(p, "xc", x.dtype, freeze=freeze_factors)
        v = self.value(p, "v", x.dtype, freeze=freeze_factors)
        if kernel == "branched":
            return kops.branched_matmul(x, u, xc, v, force_kernel=True)
        h = jnp.einsum("...d,ndr->n...r", x, u,
                       preferred_element_type=accum_dtype).astype(x.dtype)
        h = jnp.einsum("n...r,nrs->n...s", h, xc,
                       preferred_element_type=accum_dtype).astype(x.dtype)
        y = jnp.einsum("n...s,nso->...o", h, v,
                       preferred_element_type=accum_dtype)
        return y.astype(x.dtype)


def _matmul(x: jax.Array, w: jax.Array, accum_dtype) -> jax.Array:
    y = jnp.einsum("...d,do->...o", x, w, preferred_element_type=accum_dtype)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# Plan construction (cached — built once per distinct subtree geometry)
# ---------------------------------------------------------------------------

def _has(p: dict, key: str) -> bool:
    return key in p or key + _QUANT_SUFFIX in p or key + _SP_SUFFIX in p


def classify(p: dict) -> str:
    """Kind of a linear/conv subtree from the keys present (quantized
    ``k_q``/``k_scale`` and 2:4-packed ``k_sp``/``k_idx`` trees classify
    as their dense originals)."""
    if _has(p, "w"):
        return KIND_DENSE
    if _has(p, "tucker_u"):
        return KIND_TUCKER_CONV
    if _has(p, "xc"):
        return KIND_BRANCHED
    if _has(p, "core"):
        return KIND_BRANCHED_TUCKER_CONV
    if _has(p, "w0"):
        return KIND_LOWRANK
    raise ValueError(f"not a linear param subtree: {sorted(p)}")


def is_linear_subtree(node: Any) -> bool:
    """Does this dict node hold the factors of one linear/conv op?"""
    if not isinstance(node, dict):
        return False
    for key in ("w", "w0", "xc", "tucker_u", "core", "u"):
        v = node.get(key, node.get(key + _QUANT_SUFFIX,
                                   node.get(key + _SP_SUFFIX)))
        if v is not None and hasattr(v, "shape"):
            return True
    return False


_PLAN_CACHE: dict[tuple, LinearPlan] = {}


def _cache_key(p: dict) -> tuple:
    return tuple(sorted(
        (k, tuple(int(d) for d in v.shape), jnp.dtype(v.dtype).name)
        for k, v in p.items()))


def build_plan(p: dict) -> LinearPlan:
    """The plan for one linear subtree.  Static metadata only, cached on
    the subtree's ``(key, shape, dtype)`` geometry — safe under jit
    tracing and on ``ShapeDtypeStruct`` trees."""
    key = _cache_key(p)
    plan = _PLAN_CACHE.get(key)
    if plan is None:
        kind = classify(p)
        factors = tuple(_spec_from(p, kind, name)
                        for name in _KIND_FACTORS[kind])
        plan = LinearPlan(kind=kind, factors=factors)
        _PLAN_CACHE[key] = plan
    return plan


def build_plan_tree(params: PyTree) -> PyTree:
    """Map every linear/conv subtree of a param tree to its LinearPlan
    (other subtrees recurse; non-linear leaves map to ``None``).

    The serve engine calls this once at load so every plan (and its
    kernel decision) exists before the first token, and uses the result
    for weight-stream accounting."""
    def walk(node: Any) -> Any:
        if is_linear_subtree(node):
            return build_plan(node)
        if isinstance(node, dict):
            return {k: walk(v) for k, v in node.items()}
        return None
    return walk(params)


def tree_summary(plan_tree: PyTree) -> dict:
    """Aggregate accounting over a ``build_plan_tree`` result."""
    plans = [x for x in jax.tree.leaves(
        plan_tree, is_leaf=lambda n: isinstance(n, LinearPlan))
        if isinstance(x, LinearPlan)]
    return {
        "linears": len(plans),
        "by_kind": {k: sum(1 for p in plans if p.kind == k)
                    for k in sorted({p.kind for p in plans})},
        "quantized": sum(1 for p in plans if p.quantized),
        "sparse": sum(1 for p in plans if p.sparse),
        "param_count": sum(p.param_count for p in plans),
        "weight_bytes": sum(p.weight_bytes for p in plans),
        "quant_bytes": sum(p.quant_bytes for p in plans),
    }

"""Mixture-of-Experts FFN with sort-based token dispatch (EP-friendly).

The dispatch is the compile-friendly "sort by expert, grouped batched
matmul, unsort" pattern:

  router gates (T,E) -> top-k -> flatten (T*k) assignments
  -> counts per expert (bincount) -> position-in-expert (stable sort order)
  -> scatter token ids into an (E, capacity) grid -> gather activations
  -> grouped einsum over the expert axis (shards over `model` = EP)
  -> combine back with gate weights.

Everything is static-shaped (capacity = ceil(T*k/E * capacity_factor)), so
it lowers under pjit; GSPMD turns the gathers into all-to-alls when tokens
are data-sharded and experts model-sharded.

Expert weights live under ``experts/{up,gate,down}`` with a leading expert
axis; LRD surgery decomposes them with the same leading axis (a batched SVD),
so the paper's technique composes with EP.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.layers.param import (
    ParamBuilder, shard_act, linear_kind,
    BATCH, SEQ, EMBED, FFN, EXPERTS, RANK, BRANCH,
)


class MoEOpts(NamedTuple):
    freeze_factors: bool = False
    use_pallas: bool = False
    act_quantize: bool = False


def init_moe(pb: ParamBuilder, name: str, d_model: int, d_ff: int,
             num_experts: int, num_shared: int, act: str = "swiglu") -> None:
    sub = pb.child(name)
    sub.param("router", (d_model, num_experts), (EMBED, EXPERTS),
              scale=0.02)
    ex = sub.child("experts")
    # Each expert bank is a {"w": ...} subtree so LRD surgery and
    # _expert_matmul dispatch uniformly (batched SVD over the expert axis).
    ex.child("up").param("w", (num_experts, d_model, d_ff),
                         (EXPERTS, EMBED, FFN))
    if act == "swiglu":
        ex.child("gate").param("w", (num_experts, d_model, d_ff),
                               (EXPERTS, EMBED, FFN))
    ex.child("down").param("w", (num_experts, d_ff, d_model),
                           (EXPERTS, FFN, EMBED))
    if num_shared:
        sh = sub.child("shared")
        from repro.layers.param import init_linear
        init_linear(sh, "up", d_model, num_shared * d_ff, EMBED, FFN)
        if act == "swiglu":
            init_linear(sh, "gate", d_model, num_shared * d_ff, EMBED, FFN)
        init_linear(sh, "down", num_shared * d_ff, d_model, FFN, EMBED)


def _expert_matmul(w: dict | jax.Array, x: jax.Array, kind_hint: str,
                   opts: MoEOpts) -> jax.Array:
    """x (E, C, d_in) @ per-expert weights -> (E, C, d_out).

    Supports dense (E,d_in,d_out), low-rank {w0 (E,d_in,R), w1 (E,R,d_out)}
    and branched {u (E,N,d_in,r1), xc (E,N,r1,r2), v (E,N,r2,d_out)}.
    """
    if isinstance(w, dict):
        kind = linear_kind(w)
        if kind == "lowrank":
            w0, w1 = w["w0"], w["w1"]
            if opts.freeze_factors:
                w0 = lax.stop_gradient(w0)
            h = jnp.einsum("ecd,edr->ecr", x, w0,
                           preferred_element_type=jnp.float32).astype(x.dtype)
            return jnp.einsum("ecr,ero->eco", h, w1,
                              preferred_element_type=jnp.float32
                              ).astype(x.dtype)
        if kind == "branched":
            u, xc, v = w["u"], w["xc"], w["v"]
            if opts.freeze_factors:
                u = lax.stop_gradient(u)
                v = lax.stop_gradient(v)
            h = jnp.einsum("ecd,endr->necr", x, u,
                           preferred_element_type=jnp.float32).astype(x.dtype)
            h = jnp.einsum("necr,enrs->necs", h, xc,
                           preferred_element_type=jnp.float32).astype(x.dtype)
            return jnp.einsum("necs,enso->eco", h, v,
                              preferred_element_type=jnp.float32
                              ).astype(x.dtype)
        w = w["w"]
    return jnp.einsum("ecd,edo->eco", x, w,
                      preferred_element_type=jnp.float32).astype(x.dtype)


def _dispatch(xt: jax.Array, router: jax.Array, top_k: int, cap: int
              ) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Sort-based dispatch of ``xt (T, d)`` into ``(E, cap, d)`` slots.

    Returns (xe, slot_tok, slot_gate, aux_loss).  Pure per-group function —
    the hierarchical path vmaps it over data-local token groups.
    """
    t, d = xt.shape
    e = router.shape[-1]
    logits = jnp.einsum("td,de->te", xt, router,
                        preferred_element_type=jnp.float32)
    gates = jax.nn.softmax(logits, axis=-1)
    gate_vals, eids = lax.top_k(gates, top_k)                 # (T,k)
    gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)

    # Load-balance auxiliary loss (Switch-style).
    density = jnp.mean(jax.nn.one_hot(eids[:, 0], e, dtype=jnp.float32), 0)
    aux = e * jnp.sum(density * jnp.mean(gates, axis=0))

    flat_e = eids.reshape(-1)                                  # (T*k,)
    flat_tok = jnp.repeat(jnp.arange(t), top_k)
    order = jnp.argsort(flat_e, stable=True)
    se, st = flat_e[order], flat_tok[order]
    counts = jnp.bincount(flat_e, length=e)
    starts = jnp.concatenate([jnp.zeros(1, counts.dtype),
                              jnp.cumsum(counts)[:-1]])
    pos_in_e = jnp.arange(t * top_k) - starts[se]              # (T*k,)
    keep = pos_in_e < cap
    slot = jnp.where(keep, se * cap + pos_in_e, e * cap)       # overflow slot
    # token id per (expert, capacity) slot; t = "empty" sentinel
    slot_tok = jnp.full((e * cap + 1,), t, dtype=jnp.int32)
    slot_tok = slot_tok.at[slot].set(st.astype(jnp.int32), mode="drop")
    slot_tok = slot_tok[:e * cap]
    slot_valid = slot_tok < t
    safe_tok = jnp.where(slot_valid, slot_tok, 0)

    xe = xt[safe_tok].reshape(e, cap, d)
    xe = xe * slot_valid.reshape(e, cap, 1).astype(xe.dtype)

    flat_gate = gate_vals.reshape(-1)[order]
    slot_gate = jnp.zeros((e * cap + 1,), jnp.float32)
    slot_gate = slot_gate.at[slot].set(flat_gate, mode="drop")[:e * cap]
    return xe, slot_tok, slot_gate, aux


def _combine(ye: jax.Array, slot_tok: jax.Array, slot_gate: jax.Array,
             t: int, dtype) -> jax.Array:
    """Scatter-add expert outputs ``ye (E*cap, d)`` back to (T, d)."""
    d = ye.shape[-1]
    y = jnp.zeros((t + 1, d), jnp.float32)
    y = y.at[slot_tok].add(ye.astype(jnp.float32)
                           * slot_gate[:, None], mode="drop")
    return y[:t].astype(dtype)


def apply_moe(p: dict, x: jax.Array, *, top_k: int, capacity_factor: float,
              act: str = "swiglu", opts: MoEOpts = MoEOpts(),
              dispatch_groups: int = 0) -> tuple[jax.Array, jax.Array]:
    """x (B, S, d) -> (y (B, S, d), aux_loss scalar).

    ``dispatch_groups = 0``: one global dispatch (the GSPMD-naive
    baseline — the token gather crosses data shards, which the dry-run
    shows GSPMD resolving with full activation all-gathers inside the
    layer scan).

    ``dispatch_groups = G``: hierarchical dispatch — tokens are grouped
    into G data-local groups (G = the data-axis size), each group sorts
    and packs *its own* tokens (everything local), and only the packed
    ``(G, E, cap_g, d)`` expert batches cross the network, as the
    all-to-all that EP actually requires.  Capacity becomes per-group
    (standard practice).  See EXPERIMENTS.md §Perf.
    """
    b, s, d = x.shape
    t = b * s
    e = p["router"].shape[-1]
    ex = p["experts"]

    def expert_ffn(xe):
        up = _expert_matmul(ex["up"], xe, "up", opts)
        if act == "swiglu":
            gate = _expert_matmul(ex["gate"], xe, "gate", opts)
            h = jax.nn.silu(gate.astype(jnp.float32)).astype(xe.dtype) * up
        else:
            h = jax.nn.gelu(up.astype(jnp.float32)).astype(xe.dtype)
        return _expert_matmul(ex["down"], h, "down", opts)     # (E,C,d)

    if dispatch_groups and t % dispatch_groups == 0 \
            and t // dispatch_groups >= e:
        g = dispatch_groups
        tg = t // g
        cap = int(max(1, round(tg * top_k / e * capacity_factor)))
        xg = x.reshape(g, tg, d)
        xg = shard_act(xg, BATCH, None, None)
        xe, slot_tok, slot_gate, aux = jax.vmap(
            lambda xt: _dispatch(xt, p["router"], top_k, cap))(xg)
        # (G, E, cap, d): groups stay on their data shard, experts move to
        # their model shard — the reshard below IS the EP all-to-all.
        xe = shard_act(xe, BATCH, EXPERTS, None, None)
        ye = jax.vmap(expert_ffn)(xe)                          # (G,E,cap,d)
        # pin the output like the input: keeps the backward dW contraction
        # (sum over G x cap) as local-partial + small AR of dW, instead of
        # GSPMD all-gathering the (G,E,cap,d) activations over `data`
        # (observed: 557 GB/step vs ~14 GB/step of dW all-reduces).
        ye = shard_act(ye, BATCH, EXPERTS, None, None)
        ye = ye.reshape(g, e * cap, d)
        y = jax.vmap(lambda yg, st_, sg: _combine(yg, st_, sg, tg,
                                                  x.dtype))(
            ye, slot_tok, slot_gate)
        y = y.reshape(t, d)
        aux = jnp.mean(aux)
    else:
        cap = int(max(1, round(t * top_k / e * capacity_factor)))
        xt = x.reshape(t, d)
        xe, slot_tok, slot_gate, aux = _dispatch(xt, p["router"], top_k,
                                                 cap)
        xe = shard_act(xe, EXPERTS, BATCH, None)
        ye = expert_ffn(xe).reshape(e * cap, d)
        y = _combine(ye, slot_tok, slot_gate, t, x.dtype)

    xt = x.reshape(t, d)

    if "shared" in p:
        sh = p["shared"]
        from repro.layers.param import apply_linear
        kw = dict(freeze_factors=opts.freeze_factors,
                  use_pallas=opts.use_pallas,
                  act_quantize=opts.act_quantize)
        up_s = apply_linear(sh["up"], xt, **kw)
        if act == "swiglu":
            g_s = apply_linear(sh["gate"], xt, **kw)
            h_s = jax.nn.silu(g_s.astype(jnp.float32)).astype(x.dtype) * up_s
        else:
            h_s = jax.nn.gelu(up_s.astype(jnp.float32)).astype(x.dtype)
        y = y + apply_linear(sh["down"], h_s, **kw)

    return y.reshape(b, s, d), aux

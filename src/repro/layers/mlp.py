"""Feed-forward blocks (SwiGLU / GELU) over the linear-op dispatch seam."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.layers.param import (
    ParamBuilder, apply_linear, init_linear, shard_act,
    BATCH, SEQ, EMBED, FFN,
)


def init_mlp(pb: ParamBuilder, name: str, d_model: int, d_ff: int,
             act: str = "swiglu") -> None:
    sub = pb.child(name)
    init_linear(sub, "up", d_model, d_ff, EMBED, FFN)
    if act == "swiglu":
        init_linear(sub, "gate", d_model, d_ff, EMBED, FFN)
    init_linear(sub, "down", d_ff, d_model, FFN, EMBED)


def apply_mlp(p: dict, x: jax.Array, act: str = "swiglu", *,
              freeze_factors: bool = False,
              use_pallas: bool = False,
              act_quantize: bool = False) -> jax.Array:
    kw = dict(freeze_factors=freeze_factors, use_pallas=use_pallas,
              act_quantize=act_quantize)
    up = apply_linear(p["up"], x, **kw)
    if act == "swiglu":
        gate = apply_linear(p["gate"], x, **kw)
        h = jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype) * up
    elif act == "gelu":
        h = jax.nn.gelu(up.astype(jnp.float32)).astype(x.dtype)
    else:
        raise ValueError(f"unknown act {act}")
    h = shard_act(h, BATCH, SEQ, FFN)
    return apply_linear(p["down"], h, **kw)

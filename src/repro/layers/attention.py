"""Attention: RoPE, GQA (train/prefill/decode), MLA (DeepSeek-V2), cross-attn.

Prefill/train attention is computed with a *query-chunked* online pass
(`scan` over query blocks, full KV per block, f32 logits) so the logits
tensor never exceeds ``chunk x kv_len`` per (batch, head) — the jnp analogue
of flash attention, and the shape the TPU splash kernel would take.

Decode attends one token against a cache of ``S`` slots; the new token's K/V
is written at ``pos`` via dynamic_update_slice (works on sharded dims under
GSPMD).

Cache layout is the :class:`repro.layers.cache.CachePlan`'s concern:
one plan per attention layer declares the family (``gqa_f32 |
gqa_int8 | mla_latent | mla_latent_int8``), and ``apply_attention`` /
``apply_mla`` are thin executors over it — they own projections, RoPE,
and the prefill softmax (computed on the in-layer full-precision
values), while every write (prefill / chunk-at-offset / decode
scatter), quantize-on-insert, dequant view, and fused-kernel decision
lives on the plan.  The serve stack threads plans explicitly
(``models/blocks.py`` → ``models/lm.py`` → ``serve/runner.py``);
direct layer-level callers fall back to
:func:`repro.layers.cache.plan_from_cache`, the one remaining place a
cache dict's keys are sniffed.

All projections go through :func:`repro.layers.param.apply_linear`, so LRD
surgery (SVD pairs / branched factors) applies transparently — and the
*merged attention* variant (paper §2.3 mapped to QK^T/V·O joint
factorization, DESIGN.md §4) lives here as ``init_merged_attention``.
"""
from __future__ import annotations

import math
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.layers import cache as cache_mod
from repro.layers.cache import CachePlan
from repro.layers.param import (
    ParamBuilder, apply_linear, init_linear, shard_act,
    BATCH, SEQ, EMBED, QKV, RANK, HEADS, KV_HEADS, HEAD_DIM,
)
from repro.layers.norm import init_rms_norm, rms_norm

Q_CHUNK = 1024


class AttnOpts(NamedTuple):
    freeze_factors: bool = False
    use_pallas: bool = False
    softcap: float = 0.0
    act_quantize: bool = False


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_sincos(positions: jax.Array, dim: int, theta: float):
    """positions (...,) -> sin/cos (..., dim/2) in f32."""
    half = dim // 2
    freqs = jnp.exp(-math.log(theta) * jnp.arange(half, dtype=jnp.float32)
                    / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.sin(ang), jnp.cos(ang)


def apply_rope(x: jax.Array, sin: jax.Array, cos: jax.Array) -> jax.Array:
    """x (..., S, n_heads, dim); sin/cos (..., S, dim/2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    s = sin[..., None, :].astype(jnp.float32)
    c = cos[..., None, :].astype(jnp.float32)
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [x1f * c - x2f * s, x2f * c + x1f * s], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# Core softmax-attention passes
# ---------------------------------------------------------------------------

def _scaled_logits(q, k, scale, softcap):
    # q (B,Sq,KH,G,hd) k (B,Skv,KH,hd) -> (B,KH,G,Sq,Skv), f32
    s = jnp.einsum("bqkgh,bskh->bkgqs", q, k,
                   preferred_element_type=jnp.float32) * scale
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    return s


def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      causal: bool, q_offset: jax.Array | int = 0,
                      softcap: float = 0.0, q_chunk: int = Q_CHUNK,
                      scale: float | None = None) -> jax.Array:
    """q (B,Sq,H,hd), k/v (B,Skv,KH,hd) -> (B,Sq,H,hd).

    Query-chunked: memory O(q_chunk * Skv) per (b, kv-head-group).
    ``q_offset`` is the absolute position of q[0] for causal masking.
    """
    b, sq, h, hd = q.shape
    _, skv, kh, _ = k.shape
    g = h // kh
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    qg = q.reshape(b, sq, kh, g, hd)

    def attend(qc, qpos):
        s = _scaled_logits(qc, k, scale, softcap)          # (B,KH,G,qc,Skv)
        if causal:
            kpos = jnp.arange(skv)
            mask = qpos[:, None] >= kpos[None, :]
            s = jnp.where(mask[None, None, None], s, -1e30)
        p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
        o = jnp.einsum("bkgqs,bskh->bqkgh", p, v)
        return o.reshape(b, qc.shape[1], h, hd)

    if sq <= q_chunk:
        qpos = q_offset + jnp.arange(sq)
        return attend(qg, qpos)

    n_chunks = sq // q_chunk
    assert sq % q_chunk == 0, (sq, q_chunk)
    qs = qg.reshape(b, n_chunks, q_chunk, kh, g, hd)

    def body(_, xs):
        qc, idx = xs                     # qc (B, q_chunk, KH, G, hd)
        qpos = q_offset + idx * q_chunk + jnp.arange(q_chunk)
        return None, attend(qc, qpos)

    _, out = lax.scan(body, None,
                      (jnp.moveaxis(qs, 1, 0), jnp.arange(n_chunks)))
    # out (n_chunks, B, q_chunk, H, hd) -> (B, Sq, H, hd)
    return jnp.moveaxis(out, 0, 1).reshape(b, sq, h, hd)


# ---------------------------------------------------------------------------
# GQA attention layer
# ---------------------------------------------------------------------------

def init_attention(pb: ParamBuilder, name: str, d_model: int, num_heads: int,
                   num_kv_heads: int, head_dim: int) -> None:
    sub = pb.child(name)
    init_linear(sub, "q", d_model, num_heads * head_dim, EMBED, QKV)
    init_linear(sub, "k", d_model, num_kv_heads * head_dim, EMBED, QKV)
    init_linear(sub, "v", d_model, num_kv_heads * head_dim, EMBED, QKV)
    init_linear(sub, "o", num_heads * head_dim, d_model, QKV, EMBED)


def init_kv_cache(batch: int, seq_len: int, num_kv_heads: int, head_dim: int,
                  dtype, quantize: str | None = None) -> dict:
    return cache_mod.gqa_plan(num_kv_heads, head_dim, dtype,
                              quantize).init(batch, seq_len)


def kv_cache_spec(batch: int, seq_len: int, num_kv_heads: int, head_dim: int,
                  dtype, quantize: str | None = None) -> dict:
    return cache_mod.gqa_plan(num_kv_heads, head_dim, dtype,
                              quantize).spec(batch, seq_len)


def apply_attention(p: dict, x: jax.Array, *, num_heads: int,
                    num_kv_heads: int, head_dim: int, rope_theta: float,
                    positions: jax.Array, causal: bool = True,
                    cache: dict | None = None,
                    cache_pos: jax.Array | None = None,
                    prompt_len: jax.Array | None = None,
                    start_pos: jax.Array | None = None,
                    plan: CachePlan | None = None,
                    opts: AttnOpts = AttnOpts()) -> tuple[jax.Array, dict | None]:
    """Self-attention. Returns (output, updated_cache).

    * train:   cache=None — pure causal attention over x.
    * prefill: cache provided (zeros) — fills cache[0:S], causal.
      ``prompt_len`` (scalar) marks the real token count of a
      right-padded prompt: the plan's quantized prefill write zeroes
      pad positions' K/V before the scale reduction, so bucket padding
      cannot inflate the per-channel scales (causality already hides
      pad *keys* from real queries, padded or not).
    * prefill chunk: ``start_pos`` (scalar) given — x holds prompt
      positions ``[start_pos, start_pos + Sq)`` of a prompt whose
      ``[0, start_pos)`` K/V prefix is already in ``cache``.  The
      chunk's K/V is written at the offset and attention runs over the
      plan's *whole-pool* view with absolute causal masking —
      positions beyond the written prefix can never satisfy
      ``key_pos <= q_pos``, so the full-pool read is exact.
      ``positions`` must carry the absolute offsets.
    * decode:  x has Sq=1, cache full; writes K/V at ``cache_pos`` and
               attends over the whole cache via the plan (fused int8
               kernel under ``use_pallas``).

    ``plan`` is the layer's :class:`repro.layers.cache.CachePlan`; when
    None it is classified from the cache once (static metadata, safe
    under jit).
    """
    b, sq, _ = x.shape
    kw = dict(freeze_factors=opts.freeze_factors, use_pallas=opts.use_pallas,
              act_quantize=opts.act_quantize)
    q = apply_linear(p["q"], x, **kw).reshape(b, sq, num_heads, head_dim)
    k = apply_linear(p["k"], x, **kw).reshape(b, sq, num_kv_heads, head_dim)
    v = apply_linear(p["v"], x, **kw).reshape(b, sq, num_kv_heads, head_dim)

    sin, cos = rope_sincos(positions, head_dim, rope_theta)
    q = apply_rope(q, sin, cos)
    k = apply_rope(k, sin, cos)
    q = shard_act(q, BATCH, SEQ, HEADS, HEAD_DIM)
    k = shard_act(k, BATCH, SEQ, KV_HEADS, HEAD_DIM)
    v = shard_act(v, BATCH, SEQ, KV_HEADS, HEAD_DIM)

    new_cache = None
    if cache is None:
        o = chunked_attention(q, k, v, causal=causal, softcap=opts.softcap)
    else:
        if plan is None:
            plan = cache_mod.plan_from_cache(cache, x.dtype)
        if cache_pos is not None:    # decode: per-slot positions (B,)
            assert sq == 1, sq
            new_cache = plan.write_decode(cache, {"k": k[:, 0], "v": v[:, 0]},
                                          cache_pos)
            o = plan.attend_decode(q, new_cache, cache_pos,
                                   softcap=opts.softcap,
                                   use_pallas=opts.use_pallas)
        elif start_pos is not None:  # prefill chunk at a sequence offset
            new_cache, view = plan.write_chunk(cache, {"k": k, "v": v},
                                               start_pos, prompt_len)
            o = chunked_attention(q, view["k"], view["v"], causal=causal,
                                  q_offset=start_pos, softcap=opts.softcap)
        else:                        # prefill (any length, incl. 1 token)
            new_cache = plan.write_prefill(cache, {"k": k, "v": v},
                                           prompt_len)
            o = chunked_attention(q, k, v, causal=causal,
                                  softcap=opts.softcap)
    o = o.reshape(b, sq, num_heads * head_dim)
    out = apply_linear(p["o"], o, **kw)
    return out, new_cache


#: full-width decode attention (kept under its historical name — the
#: plan's ``attend_decode`` is the dispatching entry)
_decode_attention = cache_mod.gqa_decode_attention


# ---------------------------------------------------------------------------
# Merged attention (paper §2.3 mapped to transformers, DESIGN.md §4)
# ---------------------------------------------------------------------------

def init_merged_attention(pb: ParamBuilder, name: str, d_model: int,
                          num_heads: int, head_dim: int, qk_rank: int,
                          vo_rank: int) -> None:
    """Joint factorization of the weight *products* W_q W_k^T and W_v W_o.

    Per head group: logits = (x A_q)(x A_k)^T with A_q (d, H, qk_rank),
    A_k (d, qk_rank) shared latent (MLA-style); context = attn · (x B_v) and
    out = ctx · B_o with a vo_rank bottleneck.  Layer count matches the
    original attention (4 matmuls), parameters shrink by rank/d — the
    transformer realization of "layer merging keeps the original depth".
    """
    sub = pb.child(name)
    sub.param("aq", (d_model, num_heads, qk_rank), (EMBED, HEADS, RANK))
    sub.param("ak", (d_model, qk_rank), (EMBED, RANK))
    sub.param("bv", (d_model, vo_rank), (EMBED, RANK))
    sub.param("bo", (vo_rank, num_heads, d_model), (RANK, HEADS, EMBED))


def apply_merged_attention(p: dict, x: jax.Array, *, positions: jax.Array,
                           causal: bool = True,
                           opts: AttnOpts = AttnOpts()) -> jax.Array:
    b, s, d = x.shape
    h = p["aq"].shape[1]
    r = p["aq"].shape[2]
    aq, ak, bv, bo = p["aq"], p["ak"], p["bv"], p["bo"]
    if opts.freeze_factors:
        ak = lax.stop_gradient(ak)
        bv = lax.stop_gradient(bv)
    q = jnp.einsum("bsd,dhr->bshr", x, aq)          # (B,S,H,r)
    k = jnp.einsum("bsd,dr->bsr", x, ak)            # shared latent keys
    sin, cos = rope_sincos(positions, r, 1e4)
    q = apply_rope(q, sin, cos)
    k = apply_rope(k[:, :, None, :], sin, cos)[:, :, 0]
    vlat = jnp.einsum("bsd,dr->bsr", x, bv)         # (B,S,vo_rank)
    o = chunked_attention(q, k[:, :, None, :],
                          vlat[:, :, None, :], causal=causal,
                          softcap=opts.softcap, scale=1.0 / math.sqrt(r))
    out = jnp.einsum("bshr,rhd->bsd", o.reshape(b, s, h, -1), bo)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2) — inherently the paper's merged/low-rank attention
# ---------------------------------------------------------------------------

def init_mla(pb: ParamBuilder, name: str, cfg) -> None:
    sub = pb.child(name)
    d = cfg.d_model
    h = cfg.num_heads
    qk = cfg.qk_nope_dim + cfg.qk_rope_dim
    if cfg.q_lora_rank:
        init_linear(sub, "q_a", d, cfg.q_lora_rank, EMBED, RANK)
        init_rms_norm(sub, "q_norm", cfg.q_lora_rank)
        init_linear(sub, "q_b", cfg.q_lora_rank, h * qk, RANK, QKV)
    else:
        init_linear(sub, "q_b", d, h * qk, EMBED, QKV)
    init_linear(sub, "kv_a", d, cfg.kv_lora_rank + cfg.qk_rope_dim, EMBED, RANK)
    init_rms_norm(sub, "kv_norm", cfg.kv_lora_rank)
    init_linear(sub, "kv_b", cfg.kv_lora_rank,
                h * (cfg.qk_nope_dim + cfg.v_head_dim), RANK, QKV)
    init_linear(sub, "o", h * cfg.v_head_dim, d, QKV, EMBED)


def mla_cache_spec(batch: int, seq_len: int, cfg, dtype,
                   quantize: str | None = None) -> dict:
    return cache_mod.mla_plan(cfg.kv_lora_rank, cfg.qk_rope_dim, dtype,
                              quantize).spec(batch, seq_len)


def init_mla_cache(batch: int, seq_len: int, cfg, dtype,
                   quantize: str | None = None) -> dict:
    return cache_mod.mla_plan(cfg.kv_lora_rank, cfg.qk_rope_dim, dtype,
                              quantize).init(batch, seq_len)


def _mla_qkr(p, x, cfg, positions, kw):
    b, sq, _ = x.shape
    h = cfg.num_heads
    if cfg.q_lora_rank:
        qa = rms_norm(p["q_norm"], apply_linear(p["q_a"], x, **kw),
                      cfg.norm_eps)
        q = apply_linear(p["q_b"], qa, **kw)
    else:
        q = apply_linear(p["q_b"], x, **kw)
    q = q.reshape(b, sq, h, cfg.qk_nope_dim + cfg.qk_rope_dim)
    q_nope, q_rope = jnp.split(q, [cfg.qk_nope_dim], axis=-1)
    sin, cos = rope_sincos(positions, cfg.qk_rope_dim, cfg.rope_theta)
    q_rope = apply_rope(q_rope, sin, cos)
    kva = apply_linear(p["kv_a"], x, **kw)
    ckv, k_rope = jnp.split(kva, [cfg.kv_lora_rank], axis=-1)
    ckv = rms_norm(p["kv_norm"], ckv, cfg.norm_eps)
    k_rope = apply_rope(k_rope[:, :, None, :], sin, cos)[:, :, 0]
    return q_nope, q_rope, ckv, k_rope


def apply_mla(p: dict, x: jax.Array, cfg, *, positions: jax.Array,
              causal: bool = True, cache: dict | None = None,
              cache_pos: jax.Array | None = None,
              prompt_len: jax.Array | None = None,
              start_pos: jax.Array | None = None,
              plan: CachePlan | None = None,
              opts: AttnOpts = AttnOpts()) -> tuple[jax.Array, dict | None]:
    """Multi-head latent attention. Decode uses the *absorbed* form:
    queries projected into the kv_lora latent space, attention runs entirely
    against the cached latents (never materializing per-head K/V) — this is
    exactly the paper's layer-merging executed at inference time.  The
    latent cache is the plan's concern: ``mla_latent`` stores it full
    width, ``mla_latent_int8`` as int8 values + per-(slot, channel) f32
    running-max scales, attended through the fused latent kernel.

    ``start_pos`` (scalar) switches prefill into chunk mode: the chunk's
    latents land at the sequence offset and K/V for attention are
    re-expanded from the *whole* cached latent prefix (unwritten
    positions are zero latents, hidden by the absolute causal mask).
    ``prompt_len`` (scalar) marks the real end of a right-padded chunk
    or prompt — pad rows are zeroed at the latent write, mirroring the
    GQA path, so bucketed chunked prefill is exact for MLA stacks too.
    """
    b, sq, _ = x.shape
    h, nope, rope_d = cfg.num_heads, cfg.qk_nope_dim, cfg.qk_rope_dim
    vd = cfg.v_head_dim
    kw = dict(freeze_factors=opts.freeze_factors, use_pallas=opts.use_pallas,
              act_quantize=opts.act_quantize)
    q_nope, q_rope, ckv, k_rope = _mla_qkr(p, x, cfg, positions, kw)
    scale = 1.0 / math.sqrt(nope + rope_d)

    new_cache = None
    if cache is not None and plan is None:
        plan = cache_mod.plan_from_cache(cache, x.dtype)
    if cache is not None and cache_pos is not None:  # absorbed decode
        assert sq == 1, sq
        new_cache = plan.write_decode(
            cache, {"ckv": ckv[:, 0], "krope": k_rope[:, 0]}, cache_pos)
        # Absorbed decode: fold kv_b's K-half into q, V-half into output.
        wkv = _kv_b_matrix(p["kv_b"], cfg)             # (lora, h, nope+vd)
        wk, wv = wkv[..., :nope], wkv[..., nope:]
        q_lat = jnp.einsum("bqhn,lhn->bqhl", q_nope, wk)     # (B,1,H,lora)
        ctx_lat = plan.attend_decode_latent(q_lat, q_rope, new_cache,
                                            cache_pos, scale=scale,
                                            use_pallas=opts.use_pallas)
        o = jnp.einsum("bqhl,lhv->bqhv", ctx_lat, wv)
    else:
        if cache is not None and start_pos is not None:
            # chunk: write at the offset, attend over the whole cached
            # latent prefix (the plan's full-precision view)
            new_cache, view = plan.write_chunk(
                cache, {"ckv": ckv, "krope": k_rope}, start_pos, prompt_len)
            src_ckv, src_rope = view["ckv"], view["krope"]
            skv, q_off = src_ckv.shape[1], start_pos
        else:
            if cache is not None:   # whole prefill: fill the latent cache
                new_cache = plan.write_prefill(
                    cache, {"ckv": ckv, "krope": k_rope}, prompt_len)
            src_ckv, src_rope, skv, q_off = ckv, k_rope, sq, 0
        kv = apply_linear(p["kv_b"], src_ckv, **kw).reshape(b, skv, h,
                                                            nope + vd)
        k_nope, v = kv[..., :nope], kv[..., nope:]
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(src_rope[:, :, None, :],
                                      (b, skv, h, rope_d))], axis=-1)
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        # pad v to qk dim for the shared attention kernel, then slice
        o = chunked_attention(q, k, _pad_last(v, nope + rope_d - vd),
                              causal=causal, q_offset=q_off,
                              softcap=opts.softcap, scale=scale)[..., :vd]
    out = apply_linear(p["o"], o.reshape(b, sq, h * vd), **kw)
    return out, new_cache


def _kv_b_matrix(p: dict, cfg) -> jax.Array:
    """kv_b as a dense (lora, h, nope+vd) tensor (recompose if decomposed)."""
    from repro.layers.param import linear_kind
    if linear_kind(p) == "dense":
        w = p["w"]
    elif linear_kind(p) == "lowrank":
        w = p["w0"] @ p["w1"]
    else:
        w = jnp.einsum("ncr,nrs,nso->co", p["u"], p["xc"], p["v"])
    return w.reshape(cfg.kv_lora_rank, cfg.num_heads,
                     cfg.qk_nope_dim + cfg.v_head_dim)


def _pad_last(x, n):
    if n <= 0:
        return x
    pad = [(0, 0)] * (x.ndim - 1) + [(0, n)]
    return jnp.pad(x, pad)


# ---------------------------------------------------------------------------
# Cross attention (VLM): queries from text, K/V from image embeddings
# ---------------------------------------------------------------------------

def init_cross_attention(pb: ParamBuilder, name: str, d_model: int,
                         num_heads: int, num_kv_heads: int,
                         head_dim: int, kv_dim: int) -> None:
    sub = pb.child(name)
    init_linear(sub, "q", d_model, num_heads * head_dim, EMBED, QKV)
    init_linear(sub, "k", kv_dim, num_kv_heads * head_dim, EMBED, QKV)
    init_linear(sub, "v", kv_dim, num_kv_heads * head_dim, EMBED, QKV)
    init_linear(sub, "o", num_heads * head_dim, d_model, QKV, EMBED)
    sub.param("gate", (), (), init="zeros")


def cross_attn_kv(p: dict, kv_feats: jax.Array, *, num_kv_heads: int,
                  head_dim: int, opts: AttnOpts = AttnOpts()) -> dict:
    """Precompute cross-attention K/V from image features (cached at
    prefill — image tokens never change during decode)."""
    b, t, _ = kv_feats.shape
    kw = dict(freeze_factors=opts.freeze_factors, use_pallas=opts.use_pallas,
              act_quantize=opts.act_quantize)
    k = apply_linear(p["k"], kv_feats, **kw).reshape(b, t, num_kv_heads,
                                                     head_dim)
    v = apply_linear(p["v"], kv_feats, **kw).reshape(b, t, num_kv_heads,
                                                     head_dim)
    return {"k": k, "v": v}


def apply_cross_attention(p: dict, x: jax.Array,
                          kv_feats: jax.Array | None = None, *,
                          num_heads: int, num_kv_heads: int, head_dim: int,
                          kv: dict | None = None,
                          opts: AttnOpts = AttnOpts()) -> jax.Array:
    b, sq, _ = x.shape
    kw = dict(freeze_factors=opts.freeze_factors, use_pallas=opts.use_pallas,
              act_quantize=opts.act_quantize)
    if kv is None:
        kv = cross_attn_kv(p, kv_feats, num_kv_heads=num_kv_heads,
                           head_dim=head_dim, opts=opts)
    q = apply_linear(p["q"], x, **kw).reshape(b, sq, num_heads, head_dim)
    o = chunked_attention(q, kv["k"], kv["v"], causal=False,
                          softcap=opts.softcap)
    o = apply_linear(p["o"], o.reshape(b, sq, num_heads * head_dim), **kw)
    return jnp.tanh(p["gate"].astype(jnp.float32)).astype(x.dtype) * o

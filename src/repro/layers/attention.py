"""Attention: RoPE, GQA (train/prefill/decode), MLA (DeepSeek-V2), cross-attn.

Prefill/train attention is computed with a *query-chunked* online pass
(`scan` over query blocks, full KV per block, f32 logits) so the logits
tensor never exceeds ``chunk x kv_len`` per (batch, head) — the jnp analogue
of flash attention, and the shape the TPU splash kernel would take.

Decode attends one token against a cache of ``S`` slots; the new token's K/V
is written at ``pos`` via dynamic_update_slice (works on sharded dims under
GSPMD).

The KV cache may be stored quantized (``repro.quant.kv``: int8 values +
per-(slot, head, channel) f32 scales — keys ``k_q``/``k_scale``/``v_q``/
``v_scale`` instead of ``k``/``v``).  ``apply_attention`` branches on the
keys present, so the model/trunk code is identical for both layouts:
prefill quantizes the prompt's K/V on insert, decode updates the int8
pool incrementally and attends through the fused int8 kernel
(``kernels/decode_attention_q``) under ``use_pallas``, or its jnp
dequant oracle otherwise.

All projections go through :func:`repro.layers.param.apply_linear`, so LRD
surgery (SVD pairs / branched factors) applies transparently — and the
*merged attention* variant (paper §2.3 mapped to QK^T/V·O joint
factorization, DESIGN.md §4) lives here as ``init_merged_attention``.
"""
from __future__ import annotations

import math
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.layers.param import (
    ParamBuilder, apply_linear, init_linear, shard_act,
    BATCH, SEQ, EMBED, QKV, RANK, HEADS, KV_HEADS, HEAD_DIM,
)
from repro.layers.norm import init_rms_norm, rms_norm
from repro.quant import kv as kvq

Q_CHUNK = 1024


class AttnOpts(NamedTuple):
    freeze_factors: bool = False
    use_pallas: bool = False
    softcap: float = 0.0


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_sincos(positions: jax.Array, dim: int, theta: float):
    """positions (...,) -> sin/cos (..., dim/2) in f32."""
    half = dim // 2
    freqs = jnp.exp(-math.log(theta) * jnp.arange(half, dtype=jnp.float32)
                    / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.sin(ang), jnp.cos(ang)


def apply_rope(x: jax.Array, sin: jax.Array, cos: jax.Array) -> jax.Array:
    """x (..., S, n_heads, dim); sin/cos (..., S, dim/2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    s = sin[..., None, :].astype(jnp.float32)
    c = cos[..., None, :].astype(jnp.float32)
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [x1f * c - x2f * s, x2f * c + x1f * s], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# Core softmax-attention passes
# ---------------------------------------------------------------------------

def _scaled_logits(q, k, scale, softcap):
    # q (B,Sq,KH,G,hd) k (B,Skv,KH,hd) -> (B,KH,G,Sq,Skv), f32
    s = jnp.einsum("bqkgh,bskh->bkgqs", q, k,
                   preferred_element_type=jnp.float32) * scale
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    return s


def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      causal: bool, q_offset: jax.Array | int = 0,
                      softcap: float = 0.0, q_chunk: int = Q_CHUNK,
                      scale: float | None = None) -> jax.Array:
    """q (B,Sq,H,hd), k/v (B,Skv,KH,hd) -> (B,Sq,H,hd).

    Query-chunked: memory O(q_chunk * Skv) per (b, kv-head-group).
    ``q_offset`` is the absolute position of q[0] for causal masking.
    """
    b, sq, h, hd = q.shape
    _, skv, kh, _ = k.shape
    g = h // kh
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    qg = q.reshape(b, sq, kh, g, hd)

    def attend(qc, qpos):
        s = _scaled_logits(qc, k, scale, softcap)          # (B,KH,G,qc,Skv)
        if causal:
            kpos = jnp.arange(skv)
            mask = qpos[:, None] >= kpos[None, :]
            s = jnp.where(mask[None, None, None], s, -1e30)
        p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
        o = jnp.einsum("bkgqs,bskh->bqkgh", p, v)
        return o.reshape(b, qc.shape[1], h, hd)

    if sq <= q_chunk:
        qpos = q_offset + jnp.arange(sq)
        return attend(qg, qpos)

    n_chunks = sq // q_chunk
    assert sq % q_chunk == 0, (sq, q_chunk)
    qs = qg.reshape(b, n_chunks, q_chunk, kh, g, hd)

    def body(_, xs):
        qc, idx = xs                     # qc (B, q_chunk, KH, G, hd)
        qpos = q_offset + idx * q_chunk + jnp.arange(q_chunk)
        return None, attend(qc, qpos)

    _, out = lax.scan(body, None,
                      (jnp.moveaxis(qs, 1, 0), jnp.arange(n_chunks)))
    # out (n_chunks, B, q_chunk, H, hd) -> (B, Sq, H, hd)
    return jnp.moveaxis(out, 0, 1).reshape(b, sq, h, hd)


# ---------------------------------------------------------------------------
# GQA attention layer
# ---------------------------------------------------------------------------

def init_attention(pb: ParamBuilder, name: str, d_model: int, num_heads: int,
                   num_kv_heads: int, head_dim: int) -> None:
    sub = pb.child(name)
    init_linear(sub, "q", d_model, num_heads * head_dim, EMBED, QKV)
    init_linear(sub, "k", d_model, num_kv_heads * head_dim, EMBED, QKV)
    init_linear(sub, "v", d_model, num_kv_heads * head_dim, EMBED, QKV)
    init_linear(sub, "o", num_heads * head_dim, d_model, QKV, EMBED)


def init_kv_cache(batch: int, seq_len: int, num_kv_heads: int, head_dim: int,
                  dtype, quantize: str | None = None) -> dict:
    if quantize and quantize != "none":
        return kvq.init_kv_cache_q(batch, seq_len, num_kv_heads, head_dim,
                                   quantize)
    shape = (batch, seq_len, num_kv_heads, head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def kv_cache_spec(batch: int, seq_len: int, num_kv_heads: int, head_dim: int,
                  dtype, quantize: str | None = None) -> dict:
    if quantize and quantize != "none":
        return kvq.kv_cache_spec_q(batch, seq_len, num_kv_heads, head_dim,
                                   quantize)
    shape = (batch, seq_len, num_kv_heads, head_dim)
    return {"k": jax.ShapeDtypeStruct(shape, dtype),
            "v": jax.ShapeDtypeStruct(shape, dtype)}


def apply_attention(p: dict, x: jax.Array, *, num_heads: int,
                    num_kv_heads: int, head_dim: int, rope_theta: float,
                    positions: jax.Array, causal: bool = True,
                    cache: dict | None = None,
                    cache_pos: jax.Array | None = None,
                    prompt_len: jax.Array | None = None,
                    start_pos: jax.Array | None = None,
                    opts: AttnOpts = AttnOpts()) -> tuple[jax.Array, dict | None]:
    """Self-attention. Returns (output, updated_cache).

    * train:   cache=None — pure causal attention over x.
    * prefill: cache provided (zeros) — fills cache[0:S], causal.
      ``prompt_len`` (scalar) marks the real token count of a
      right-padded prompt: quantized-KV prefill zeroes pad positions'
      K/V before the scale reduction, so bucket padding cannot inflate
      the per-channel scales (causality already hides pad *keys* from
      real queries, padded or not).
    * prefill chunk: ``start_pos`` (scalar) given — x holds prompt
      positions ``[start_pos, start_pos + Sq)`` of a prompt whose
      ``[0, start_pos)`` K/V prefix is already in ``cache``.  The
      chunk's K/V is written at the offset (quantized caches take the
      amortized :func:`repro.quant.kv.kv_write_chunk` running-max
      update) and attention runs over the *whole* cached prefix with
      absolute causal masking — positions beyond the written prefix
      can never satisfy ``key_pos <= q_pos``, so the full-pool read is
      exact.  ``positions`` must carry the absolute offsets.
    * decode:  x has Sq=1, cache full; writes K/V at ``cache_pos`` and
               attends over the whole cache.
    """
    b, sq, _ = x.shape
    kw = dict(freeze_factors=opts.freeze_factors, use_pallas=opts.use_pallas)
    q = apply_linear(p["q"], x, **kw).reshape(b, sq, num_heads, head_dim)
    k = apply_linear(p["k"], x, **kw).reshape(b, sq, num_kv_heads, head_dim)
    v = apply_linear(p["v"], x, **kw).reshape(b, sq, num_kv_heads, head_dim)

    sin, cos = rope_sincos(positions, head_dim, rope_theta)
    q = apply_rope(q, sin, cos)
    k = apply_rope(k, sin, cos)
    q = shard_act(q, BATCH, SEQ, HEADS, HEAD_DIM)
    k = shard_act(k, BATCH, SEQ, KV_HEADS, HEAD_DIM)
    v = shard_act(v, BATCH, SEQ, KV_HEADS, HEAD_DIM)

    new_cache = None
    if cache is None:
        o = chunked_attention(q, k, v, causal=causal, softcap=opts.softcap)
    elif cache_pos is None and start_pos is not None:
        # prefill chunk at a sequence offset against an existing slot.
        # Zero pad rows BEFORE the write (both dtypes): callers pass
        # prompt_len as the chunk's real end (min(prompt end, chunk
        # end)), so bucket padding can never land garbage K/V at
        # mid-prompt positions a later query would attend, nor inflate
        # the int8 running-max scales.
        if prompt_len is not None:
            pm = (start_pos + jnp.arange(sq)
                  < prompt_len)[None, :, None, None]
            k = jnp.where(pm, k, 0.0)
            v = jnp.where(pm, v, 0.0)
        if kvq.is_quantized_kv(cache):
            ck, ks = kvq.kv_write_chunk(cache["k_q"], cache["k_scale"],
                                        k, start_pos)
            cv, vs = kvq.kv_write_chunk(cache["v_q"], cache["v_scale"],
                                        v, start_pos)
            new_cache = {"k_q": ck, "k_scale": ks, "v_q": cv, "v_scale": vs}
            # int8 prefix: attend through the dequant view (the serve
            # scheduler stages in full precision instead, for exactness)
            kk = kvq.dequantize_kv(ck, ks, k.dtype)
            vv = kvq.dequantize_kv(cv, vs, v.dtype)
        else:
            ck = lax.dynamic_update_slice_in_dim(cache["k"], k, start_pos, 1)
            cv = lax.dynamic_update_slice_in_dim(cache["v"], v, start_pos, 1)
            new_cache = {"k": ck, "v": cv}
            kk, vv = ck, cv
        o = chunked_attention(q, kk, vv, causal=causal, q_offset=start_pos,
                              softcap=opts.softcap)
    elif cache_pos is None:  # prefill (any length, incl. 1-token prompts)
        if kvq.is_quantized_kv(cache):
            # Quantize on insert: pool + scatter stay int8 throughout.
            if prompt_len is not None:
                pm = (jnp.arange(sq) < prompt_len)[None, :, None, None]
                k = jnp.where(pm, k, 0.0)
                v = jnp.where(pm, v, 0.0)
            k_q, k_scale = kvq.quantize_kv_prefill(k)
            v_q, v_scale = kvq.quantize_kv_prefill(v)
            new_cache = {
                "k_q": lax.dynamic_update_slice_in_dim(cache["k_q"], k_q,
                                                       0, 1),
                "k_scale": k_scale,
                "v_q": lax.dynamic_update_slice_in_dim(cache["v_q"], v_q,
                                                       0, 1),
                "v_scale": v_scale}
        else:
            new_cache = {
                "k": lax.dynamic_update_slice_in_dim(cache["k"], k, 0, 1),
                "v": lax.dynamic_update_slice_in_dim(cache["v"], v, 0, 1)}
        o = chunked_attention(q, k, v, causal=causal, softcap=opts.softcap)
    else:  # decode: per-example positions (B,) — scatter into cache slots
        assert sq == 1, sq
        if kvq.is_quantized_kv(cache):
            ck, ks = kvq.kv_write_token(cache["k_q"], cache["k_scale"],
                                        k[:, 0], cache_pos)
            cv, vs = kvq.kv_write_token(cache["v_q"], cache["v_scale"],
                                        v[:, 0], cache_pos)
            new_cache = {"k_q": ck, "k_scale": ks, "v_q": cv, "v_scale": vs}
            o = _decode_attention_q(q, ck, ks, cv, vs, cache_pos,
                                    opts.softcap, opts.use_pallas)
        else:
            bidx = jnp.arange(b)
            ck = cache["k"].at[bidx, cache_pos].set(k[:, 0])
            cv = cache["v"].at[bidx, cache_pos].set(v[:, 0])
            new_cache = {"k": ck, "v": cv}
            skv = ck.shape[1]
            # mask out slots beyond each example's position
            valid = jnp.arange(skv)[None, :] <= cache_pos[:, None]  # (B,S)
            o = _decode_attention(q, ck, cv, valid, opts.softcap)
    o = o.reshape(b, sq, num_heads * head_dim)
    out = apply_linear(p["o"], o, **kw)
    return out, new_cache


def _decode_attention_q(q, k_q, k_scale, v_q, v_scale, cache_pos, softcap,
                        use_pallas):
    """Decode over an int8 pool: fused kernel under ``use_pallas`` (with
    the shared VMEM-fit fallback inside the ops wrapper), jnp dequant
    oracle otherwise — a full-precision copy of the pool never lands in
    HBM on the kernel path."""
    from repro.kernels import ops as kops
    from repro.kernels import ref as kref
    if use_pallas:
        return kops.decode_attention_q(q, k_q, k_scale, v_q, v_scale,
                                       cache_pos, softcap=softcap)
    return kref.decode_attention_q_ref(q, k_q, k_scale, v_q, v_scale,
                                       cache_pos, softcap=softcap)


def _decode_attention(q, k, v, valid, softcap):
    b, sq, h, hd = q.shape
    kh = k.shape[2]
    qg = q.reshape(b, sq, kh, h // kh, hd)
    s = _scaled_logits(qg, k, 1.0 / math.sqrt(hd), softcap)
    s = jnp.where(valid[:, None, None, None, :], s, -1e30)   # valid (B,Skv)
    p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    o = jnp.einsum("bkgqs,bskh->bqkgh", p, v)
    return o.reshape(b, sq, h, hd)


# ---------------------------------------------------------------------------
# Merged attention (paper §2.3 mapped to transformers, DESIGN.md §4)
# ---------------------------------------------------------------------------

def init_merged_attention(pb: ParamBuilder, name: str, d_model: int,
                          num_heads: int, head_dim: int, qk_rank: int,
                          vo_rank: int) -> None:
    """Joint factorization of the weight *products* W_q W_k^T and W_v W_o.

    Per head group: logits = (x A_q)(x A_k)^T with A_q (d, H, qk_rank),
    A_k (d, qk_rank) shared latent (MLA-style); context = attn · (x B_v) and
    out = ctx · B_o with a vo_rank bottleneck.  Layer count matches the
    original attention (4 matmuls), parameters shrink by rank/d — the
    transformer realization of "layer merging keeps the original depth".
    """
    sub = pb.child(name)
    sub.param("aq", (d_model, num_heads, qk_rank), (EMBED, HEADS, RANK))
    sub.param("ak", (d_model, qk_rank), (EMBED, RANK))
    sub.param("bv", (d_model, vo_rank), (EMBED, RANK))
    sub.param("bo", (vo_rank, num_heads, d_model), (RANK, HEADS, EMBED))


def apply_merged_attention(p: dict, x: jax.Array, *, positions: jax.Array,
                           causal: bool = True,
                           opts: AttnOpts = AttnOpts()) -> jax.Array:
    b, s, d = x.shape
    h = p["aq"].shape[1]
    r = p["aq"].shape[2]
    aq, ak, bv, bo = p["aq"], p["ak"], p["bv"], p["bo"]
    if opts.freeze_factors:
        ak = lax.stop_gradient(ak)
        bv = lax.stop_gradient(bv)
    q = jnp.einsum("bsd,dhr->bshr", x, aq)          # (B,S,H,r)
    k = jnp.einsum("bsd,dr->bsr", x, ak)            # shared latent keys
    sin, cos = rope_sincos(positions, r, 1e4)
    q = apply_rope(q, sin, cos)
    k = apply_rope(k[:, :, None, :], sin, cos)[:, :, 0]
    vlat = jnp.einsum("bsd,dr->bsr", x, bv)         # (B,S,vo_rank)
    o = chunked_attention(q, k[:, :, None, :],
                          vlat[:, :, None, :], causal=causal,
                          softcap=opts.softcap, scale=1.0 / math.sqrt(r))
    out = jnp.einsum("bshr,rhd->bsd", o.reshape(b, s, h, -1), bo)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2) — inherently the paper's merged/low-rank attention
# ---------------------------------------------------------------------------

def init_mla(pb: ParamBuilder, name: str, cfg) -> None:
    sub = pb.child(name)
    d = cfg.d_model
    h = cfg.num_heads
    qk = cfg.qk_nope_dim + cfg.qk_rope_dim
    if cfg.q_lora_rank:
        init_linear(sub, "q_a", d, cfg.q_lora_rank, EMBED, RANK)
        init_rms_norm(sub, "q_norm", cfg.q_lora_rank)
        init_linear(sub, "q_b", cfg.q_lora_rank, h * qk, RANK, QKV)
    else:
        init_linear(sub, "q_b", d, h * qk, EMBED, QKV)
    init_linear(sub, "kv_a", d, cfg.kv_lora_rank + cfg.qk_rope_dim, EMBED, RANK)
    init_rms_norm(sub, "kv_norm", cfg.kv_lora_rank)
    init_linear(sub, "kv_b", cfg.kv_lora_rank,
                h * (cfg.qk_nope_dim + cfg.v_head_dim), RANK, QKV)
    init_linear(sub, "o", h * cfg.v_head_dim, d, QKV, EMBED)


def mla_cache_spec(batch: int, seq_len: int, cfg, dtype) -> dict:
    return {
        "ckv": jax.ShapeDtypeStruct((batch, seq_len, cfg.kv_lora_rank), dtype),
        "krope": jax.ShapeDtypeStruct((batch, seq_len, cfg.qk_rope_dim), dtype),
    }


def init_mla_cache(batch: int, seq_len: int, cfg, dtype) -> dict:
    return {"ckv": jnp.zeros((batch, seq_len, cfg.kv_lora_rank), dtype),
            "krope": jnp.zeros((batch, seq_len, cfg.qk_rope_dim), dtype)}


def _mla_qkr(p, x, cfg, positions, kw):
    b, sq, _ = x.shape
    h = cfg.num_heads
    if cfg.q_lora_rank:
        qa = rms_norm(p["q_norm"], apply_linear(p["q_a"], x, **kw),
                      cfg.norm_eps)
        q = apply_linear(p["q_b"], qa, **kw)
    else:
        q = apply_linear(p["q_b"], x, **kw)
    q = q.reshape(b, sq, h, cfg.qk_nope_dim + cfg.qk_rope_dim)
    q_nope, q_rope = jnp.split(q, [cfg.qk_nope_dim], axis=-1)
    sin, cos = rope_sincos(positions, cfg.qk_rope_dim, cfg.rope_theta)
    q_rope = apply_rope(q_rope, sin, cos)
    kva = apply_linear(p["kv_a"], x, **kw)
    ckv, k_rope = jnp.split(kva, [cfg.kv_lora_rank], axis=-1)
    ckv = rms_norm(p["kv_norm"], ckv, cfg.norm_eps)
    k_rope = apply_rope(k_rope[:, :, None, :], sin, cos)[:, :, 0]
    return q_nope, q_rope, ckv, k_rope


def apply_mla(p: dict, x: jax.Array, cfg, *, positions: jax.Array,
              causal: bool = True, cache: dict | None = None,
              cache_pos: jax.Array | None = None,
              start_pos: jax.Array | None = None,
              opts: AttnOpts = AttnOpts()) -> tuple[jax.Array, dict | None]:
    """Multi-head latent attention. Decode uses the *absorbed* form:
    queries projected into the kv_lora latent space, attention runs entirely
    against the cached latents (never materializing per-head K/V) — this is
    exactly the paper's layer-merging executed at inference time.

    ``start_pos`` (scalar) switches prefill into chunk mode: the chunk's
    latents land at the sequence offset and K/V for attention are
    re-expanded from the *whole* cached latent prefix (unwritten
    positions are zero latents, hidden by the absolute causal mask).
    Chunks must not be right-padded short of the prompt end (there is
    no ``prompt_len`` pad masking here; the serve scheduler never
    chunks MLA stacks).
    """
    b, sq, _ = x.shape
    h, nope, rope_d = cfg.num_heads, cfg.qk_nope_dim, cfg.qk_rope_dim
    vd = cfg.v_head_dim
    kw = dict(freeze_factors=opts.freeze_factors, use_pallas=opts.use_pallas)
    q_nope, q_rope, ckv, k_rope = _mla_qkr(p, x, cfg, positions, kw)
    scale = 1.0 / math.sqrt(nope + rope_d)

    new_cache = None
    if cache is not None and cache_pos is not None:  # absorbed decode
        bidx = jnp.arange(b)
        cc = cache["ckv"].at[bidx, cache_pos].set(ckv[:, 0])
        cr = cache["krope"].at[bidx, cache_pos].set(k_rope[:, 0])
        new_cache = {"ckv": cc, "krope": cr}
        # Absorbed decode: fold kv_b's K-half into q, V-half into output.
        wkv = _kv_b_matrix(p["kv_b"], cfg)             # (lora, h, nope+vd)
        wk, wv = wkv[..., :nope], wkv[..., nope:]
        q_lat = jnp.einsum("bqhn,lhn->bqhl", q_nope, wk)     # (B,1,H,lora)
        s = (jnp.einsum("bqhl,bsl->bhqs", q_lat, cc,
                        preferred_element_type=jnp.float32)
             + jnp.einsum("bqhr,bsr->bhqs", q_rope, cr,
                          preferred_element_type=jnp.float32)) * scale
        valid = jnp.arange(cc.shape[1])[None, :] <= cache_pos[:, None]
        s = jnp.where(valid[:, None, None, :], s, -1e30)
        attn = jax.nn.softmax(s, axis=-1).astype(x.dtype)
        ctx_lat = jnp.einsum("bhqs,bsl->bqhl", attn, cc)     # (B,1,H,lora)
        o = jnp.einsum("bqhl,lhv->bqhv", ctx_lat, wv)
    else:
        if cache is not None:  # prefill: fill latent cache (maybe at offset)
            off = 0 if start_pos is None else start_pos
            new_cache = {
                "ckv": lax.dynamic_update_slice_in_dim(cache["ckv"], ckv,
                                                       off, 1),
                "krope": lax.dynamic_update_slice_in_dim(cache["krope"],
                                                         k_rope, off, 1)}
        if start_pos is None:
            src_ckv, src_rope, skv, q_off = ckv, k_rope, sq, 0
        else:
            # chunk: attend over the whole cached latent prefix
            src_ckv, src_rope = new_cache["ckv"], new_cache["krope"]
            skv, q_off = src_ckv.shape[1], start_pos
        kv = apply_linear(p["kv_b"], src_ckv, **kw).reshape(b, skv, h,
                                                            nope + vd)
        k_nope, v = kv[..., :nope], kv[..., nope:]
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(src_rope[:, :, None, :],
                                      (b, skv, h, rope_d))], axis=-1)
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        # pad v to qk dim for the shared attention kernel, then slice
        o = chunked_attention(q, k, _pad_last(v, nope + rope_d - vd),
                              causal=causal, q_offset=q_off,
                              softcap=opts.softcap, scale=scale)[..., :vd]
    out = apply_linear(p["o"], o.reshape(b, sq, h * vd), **kw)
    return out, new_cache


def _kv_b_matrix(p: dict, cfg) -> jax.Array:
    """kv_b as a dense (lora, h, nope+vd) tensor (recompose if decomposed)."""
    from repro.layers.param import linear_kind
    if linear_kind(p) == "dense":
        w = p["w"]
    elif linear_kind(p) == "lowrank":
        w = p["w0"] @ p["w1"]
    else:
        w = jnp.einsum("ncr,nrs,nso->co", p["u"], p["xc"], p["v"])
    return w.reshape(cfg.kv_lora_rank, cfg.num_heads,
                     cfg.qk_nope_dim + cfg.v_head_dim)


def _pad_last(x, n):
    if n <= 0:
        return x
    pad = [(0, 0)] * (x.ndim - 1) + [(0, n)]
    return jnp.pad(x, pad)


# ---------------------------------------------------------------------------
# Cross attention (VLM): queries from text, K/V from image embeddings
# ---------------------------------------------------------------------------

def init_cross_attention(pb: ParamBuilder, name: str, d_model: int,
                         num_heads: int, num_kv_heads: int,
                         head_dim: int, kv_dim: int) -> None:
    sub = pb.child(name)
    init_linear(sub, "q", d_model, num_heads * head_dim, EMBED, QKV)
    init_linear(sub, "k", kv_dim, num_kv_heads * head_dim, EMBED, QKV)
    init_linear(sub, "v", kv_dim, num_kv_heads * head_dim, EMBED, QKV)
    init_linear(sub, "o", num_heads * head_dim, d_model, QKV, EMBED)
    sub.param("gate", (), (), init="zeros")


def cross_attn_kv(p: dict, kv_feats: jax.Array, *, num_kv_heads: int,
                  head_dim: int, opts: AttnOpts = AttnOpts()) -> dict:
    """Precompute cross-attention K/V from image features (cached at
    prefill — image tokens never change during decode)."""
    b, t, _ = kv_feats.shape
    kw = dict(freeze_factors=opts.freeze_factors, use_pallas=opts.use_pallas)
    k = apply_linear(p["k"], kv_feats, **kw).reshape(b, t, num_kv_heads,
                                                     head_dim)
    v = apply_linear(p["v"], kv_feats, **kw).reshape(b, t, num_kv_heads,
                                                     head_dim)
    return {"k": k, "v": v}


def apply_cross_attention(p: dict, x: jax.Array,
                          kv_feats: jax.Array | None = None, *,
                          num_heads: int, num_kv_heads: int, head_dim: int,
                          kv: dict | None = None,
                          opts: AttnOpts = AttnOpts()) -> jax.Array:
    b, sq, _ = x.shape
    kw = dict(freeze_factors=opts.freeze_factors, use_pallas=opts.use_pallas)
    if kv is None:
        kv = cross_attn_kv(p, kv_feats, num_kv_heads=num_kv_heads,
                           head_dim=head_dim, opts=opts)
    q = apply_linear(p["q"], x, **kw).reshape(b, sq, num_heads, head_dim)
    o = chunked_attention(q, kv["k"], kv["v"], causal=False,
                          softcap=opts.softcap)
    o = apply_linear(p["o"], o.reshape(b, sq, num_heads * head_dim), **kw)
    return jnp.tanh(p["gate"].astype(jnp.float32)).astype(x.dtype) * o

"""Rank selection: ratio ranks, the paper's Algorithm 1, and TPU alignment.

Three ways to pick the rank of a decomposed layer:

* ``ratio_rank``       — from the target compression ratio (paper Eq. 7 /
                         §2 "desired compression ratio"). Produces "odd"
                         ranks like 309.
* ``algorithm1``       — the paper's search (§2.1): time the decomposed
                         layer at every rank in [R_min, R], find the rank
                         just below the biggest latency cliff, use it only
                         if it beats the original layer (else ``ORG``).
                         The timer is pluggable: TPU cost model
                         (:mod:`repro.core.cost_model`) or measured
                         wall-clock (paper-faithful).
* ``align_rank``       — the closed-form TPU shortcut: on a stepwise
                         padded-tile cost model, Algorithm 1 provably
                         returns a rank on a tile boundary, so production
                         configs just snap down to a multiple of 128.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

from repro.core.svd import compression_of_rank, ratio_rank
from repro.core import cost_model as cm


ORG = -1   # sentinel: keep the original (dense) layer


@dataclass(frozen=True)
class RankDecision:
    rank: int                 # chosen rank, or ORG
    t_dense: float            # timer value of the original layer
    t_chosen: float           # timer value at the chosen rank (== t_dense if ORG)
    searched: tuple[int, ...] = ()

    @property
    def keep_original(self) -> bool:
        return self.rank == ORG

    def speedup(self) -> float:
        return self.t_dense / self.t_chosen if self.t_chosen > 0 else 1.0


def algorithm1(timer: Callable[[int], float], t_dense: float, rank: int,
               rank_min: int, *, step: int = 1) -> RankDecision:
    """Paper Algorithm 1 with a pluggable timer.

    Scans r from ``rank`` down to ``rank_min`` recording t(r); the latency
    drop achieved by stepping *down to* r is ``delta(r) = t(r + step) -
    t(r)``.  R_opt is the rank with the largest drop (ties -> larger rank,
    preserving accuracy).  If even t(R_opt) is no faster than the dense
    layer, the layer stays original (the paper's ``ORG`` rows in Table 2).
    """
    rank_min = max(1, rank_min)
    ranks = list(range(rank, rank_min - 1, -step))
    times = {r: timer(r) for r in ranks}
    best_r, best_drop = None, 0.0
    for r_hi, r_lo in zip(ranks[:-1], ranks[1:]):
        drop = times[r_hi] - times[r_lo]
        if drop > best_drop + 1e-30:
            best_r, best_drop = r_lo, drop
    if best_r is None:
        # Monotone / flat t(r): fall back to the fastest rank (largest on tie).
        best_t = min(times.values())
        best_r = max(r for r, t in times.items() if t <= best_t * (1 + 1e-12))
    if times[best_r] < t_dense:
        return RankDecision(best_r, t_dense, times[best_r], tuple(ranks))
    return RankDecision(ORG, t_dense, t_dense, tuple(ranks))


def align_rank(rank: int, align: int = 128, *, min_rank: int = 8,
               mode: str = "down") -> int:
    """Snap a rank to the MXU tile grid (the closed-form TPU Algorithm 1).

    ``down`` snaps toward more compression; ``nearest`` rounds.  Ranks that
    would vanish snap to the sublane floor ``min_rank`` instead.
    """
    if rank <= min_rank:
        return min_rank
    if rank < align:
        # below one tile: snap to sublane granularity
        snapped = (rank // min_rank) * min_rank if mode == "down" else \
            int(round(rank / min_rank)) * min_rank
        return max(min_rank, snapped)
    if mode == "down":
        return (rank // align) * align
    if mode == "nearest":
        return max(align, int(round(rank / align)) * align)
    raise ValueError(mode)


def select_rank(c: int, s: int, *, compression: float, mode: str,
                align: int = 128, rank_min_frac: float = 0.25,
                m_tokens: int = 4096,
                timer: Callable[[int], float] | None = None,
                t_dense: float | None = None) -> int:
    """Unified entry used by surgery.py — returns a rank or ``ORG``.

    mode:
      "ratio"   — paper's compression-ratio rank, unmodified.
      "aligned" — ratio rank snapped down to the MXU tile.
      "search"  — Algorithm 1 (cost-model timer unless one is injected).
    """
    r0 = ratio_rank(c, s, compression)
    if mode == "ratio":
        return r0
    if mode == "aligned":
        r = align_rank(r0, align)
        # alignment must not *increase* params beyond the dense layer
        return r if compression_of_rank(c, s, r) > 1.0 else ORG
    if mode == "search":
        if timer is None:
            timer = cm.make_model_timer(m_tokens, c, s)
        if t_dense is None:
            t_dense = cm.make_dense_time(m_tokens, c, s)
        r_min = max(1, int(r0 * rank_min_frac))
        # step at sublane granularity for tractable search on big layers;
        # start step-aligned so latency cliffs land exactly on tile
        # boundaries (the search then returns MXU-aligned ranks).
        step = 1 if r0 <= 512 else 8
        r_start = (r0 // step) * step
        return algorithm1(timer, t_dense, r_start, r_min, step=step).rank
    raise ValueError(f"unknown rank mode {mode!r}")


def max_branches(rank: int, *, min_branch_rank: int = 128) -> int:
    """Largest N with rank/N >= one MXU tile (DESIGN.md §3: under-fill guard)."""
    return max(1, rank // min_branch_rank)

"""Whole-model LRD surgery — applies the paper's technique to a param tree.

``decompose_model(params, axes, lrd)`` walks the ``(params, axes)`` trees
produced by :class:`repro.layers.param.ParamBuilder`, classifies every
linear subtree (``{"w": ...}``) by its path, decides a rank per
:mod:`repro.core.rank_selection`, and rewrites the subtree in place:

    {"w": (.., C, S)}          dense
      -> {"w0": (.., C, R), "w1": (.., R, S)}                  SVD pair
      -> {"u": (.., N, C, r), "xc": (.., N, r, r),
          "v": (.., N, r, S)}                                  branched
      -> unchanged ("ORG")     when Algorithm 1 keeps the original layer

Stacked-layer weights (leading ``layers`` axis) and MoE expert banks
(leading ``experts`` axis) decompose batched — every layer in a stack
shares geometry, hence rank, which keeps ``lax.scan`` homogeneous.

4D conv weights (ResNet path) go through Tucker-2 instead:

    {"w": (k, k, C, S)} -> {"tucker_u": (C, R1), "core": (k, k, R1, R2),
                            "tucker_v": (R2, S)}
    or branched          -> {"u": (N, C, r1), "core": (N, k, k, r1, r2),
                             "v": (N, r2, S)}

Model code never changes: ``apply_linear`` / ``apply_conv`` dispatch on the
keys present.  The surgery also emits a :class:`SurgeryReport` with the
per-layer decisions and param/FLOP accounting used by the benchmarks.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import LRDConfig
from repro.core import cost_model as cm
from repro.core import rank_selection as rs
from repro.core.branching import branch_svd, branch_tucker, quantize_ranks
from repro.core.svd import decompose_auto, ratio_rank
from repro.core.tucker import ratio_ranks, tucker2_decompose
from repro.layers.param import BRANCH, CONV, EXPERTS, LAYERS, RANK

PyTree = Any


# ---------------------------------------------------------------------------
# Path classification
# ---------------------------------------------------------------------------

#: map from a path component pair (parent, leaf-ish) to a target label.
_LABELS: dict[tuple[str, str], str] = {
    ("attn", "q"): "attn_q", ("attn", "k"): "attn_k",
    ("attn", "v"): "attn_v", ("attn", "o"): "attn_o",
    ("cross_attn", "q"): "attn_q", ("cross_attn", "k"): "attn_k",
    ("cross_attn", "v"): "attn_v", ("cross_attn", "o"): "attn_o",
    ("mla", "o"): "attn_o",
    ("mla", "q_a"): "mla_qa", ("mla", "q_b"): "mla_qb",
    ("mla", "kv_a"): "mla_kva", ("mla", "kv_b"): "mla_kvb",
    ("mlp", "up"): "ffn_up", ("mlp", "gate"): "ffn_gate",
    ("mlp", "down"): "ffn_down",
    ("shared", "up"): "ffn_up", ("shared", "gate"): "ffn_gate",
    ("shared", "down"): "ffn_down",
    ("experts", "up"): "moe_up", ("experts", "gate"): "moe_gate",
    ("experts", "down"): "moe_down",
    ("ssm", "in_proj"): "ssm_in", ("ssm", "out_proj"): "ssm_out",
}


def classify_path(path: tuple[str, ...]) -> str:
    """Target label for a linear subtree at ``path`` (ends at the subtree)."""
    if not path:
        return "unknown"
    leaf = path[-1]
    parent = path[-2] if len(path) > 1 else ""
    if (parent, leaf) in _LABELS:
        return _LABELS[(parent, leaf)]
    if leaf == "unembed":
        return "unembed"
    if leaf == "embed":
        return "embed"
    if leaf == "router":
        return "router"
    if leaf.startswith("conv") or leaf == "downsample":
        return "conv"
    if leaf == "fc":
        return "fc"
    return leaf


@dataclasses.dataclass
class LayerDecision:
    path: str
    label: str
    kind: str                  # "svd" | "branched" | "tucker" | "org" | "skip"
    shape: tuple[int, ...]
    rank: int | tuple[int, int] | None
    params_before: int
    params_after: int
    flops_before: float        # per input row/pixel (forward)
    flops_after: float
    reason: str = ""


@dataclasses.dataclass
class SurgeryReport:
    decisions: list[LayerDecision] = dataclasses.field(default_factory=list)

    @property
    def params_before(self) -> int:
        return sum(d.params_before for d in self.decisions)

    @property
    def params_after(self) -> int:
        return sum(d.params_after for d in self.decisions)

    @property
    def decomposed(self) -> list[LayerDecision]:
        return [d for d in self.decisions if d.kind not in ("org", "skip")]

    def summary(self) -> dict:
        fb = sum(d.flops_before for d in self.decisions)
        fa = sum(d.flops_after for d in self.decisions)
        return {
            "layers_seen": len(self.decisions),
            "layers_decomposed": len(self.decomposed),
            "params_before": self.params_before,
            "params_after": self.params_after,
            "param_ratio": self.params_after / max(1, self.params_before),
            "flops_ratio": fa / max(1e-30, fb),
        }


# ---------------------------------------------------------------------------
# Per-subtree decomposition
# ---------------------------------------------------------------------------

def _is_linear_node(node: Any) -> bool:
    return (isinstance(node, dict) and set(node) == {"w"}
            and hasattr(node["w"], "ndim"))


def _batch_dims(ax: tuple) -> int:
    n = 0
    for a in ax:
        if a in (LAYERS, EXPERTS):
            n += 1
        else:
            break
    return n


def _is_conv(ax: tuple, nd_batch: int) -> bool:
    core = ax[nd_batch:]
    return len(core) == 4 and core[0] == CONV and core[1] == CONV


def _decide_rank(c: int, s: int, lrd: LRDConfig, m_tokens: int,
                 _cache: dict) -> int:
    key = (c, s)
    if key not in _cache:
        _cache[key] = rs.select_rank(
            c, s, compression=lrd.compression, mode=lrd.rank_mode,
            align=lrd.rank_align, rank_min_frac=lrd.rank_min_frac,
            m_tokens=m_tokens)
    return _cache[key]


def _decompose_linear(w: jax.Array, ax: tuple, lrd: LRDConfig,
                      m_tokens: int, cache: dict
                      ) -> tuple[dict | None, dict | None, str, Any]:
    """Returns (new_params, new_axes, kind, rank) or (None,..,"org"/reason)."""
    nb = _batch_dims(ax)
    c, s = int(w.shape[-2]), int(w.shape[-1])
    if min(c, s) < lrd.min_dim:
        return None, None, "skip", f"min_dim({min(c, s)}<{lrd.min_dim})"
    rank = _decide_rank(c, s, lrd, m_tokens, cache)
    if rank == rs.ORG:
        return None, None, "org", "algorithm1: dense layer faster"
    batch_ax = ax[:nb]
    in_ax, out_ax = ax[-2], ax[-1]
    n = lrd.branches
    if n > 1 and rank // n >= max(lrd.rank_align, 1):
        f = branch_svd(w, rank, n)
        params = {"u": f.u, "xc": f.xc, "v": f.v}
        axes = {"u": (*batch_ax, BRANCH, in_ax, RANK),
                "xc": (*batch_ax, BRANCH, RANK, RANK),
                "v": (*batch_ax, BRANCH, RANK, out_ax)}
        return params, axes, "branched", quantize_ranks(rank, rank, n)[0]
    f = decompose_auto(w, rank)
    params = {"w0": f.w0, "w1": f.w1}
    axes = {"w0": (*batch_ax, in_ax, RANK), "w1": (*batch_ax, RANK, out_ax)}
    return params, axes, "svd", rank


def _decompose_conv(w: jax.Array, ax: tuple, lrd: LRDConfig,
                    m_tokens: int) -> tuple[dict | None, dict | None, str, Any]:
    kh, kw, c, s = (int(d) for d in w.shape)
    if min(c, s) < lrd.min_dim // 4:     # convs are smaller than FC layers
        return None, None, "skip", f"min_dim({min(c, s)})"
    r1, r2 = ratio_ranks(c, s, kh, lrd.compression)
    if lrd.rank_mode == "aligned":
        r1 = rs.align_rank(r1, min(lrd.rank_align, max(8, c // 2)))
        r2 = rs.align_rank(r2, min(lrd.rank_align, max(8, s // 2)))
    elif lrd.rank_mode == "search":
        m_hw = int(m_tokens ** 0.5) or 1
        t_dense = cm.conv_time(m_hw, c, s, kh)
        beta = s / c
        timer = cm.make_model_timer(m_tokens, c, s, kind="tucker", k=kh,
                                    beta=beta)
        dec = rs.algorithm1(timer, t_dense, r1, max(1, int(r1 * lrd.rank_min_frac)),
                            step=1 if r1 <= 512 else 8)
        if dec.rank == rs.ORG:
            return None, None, "org", "algorithm1: dense conv faster"
        r1 = dec.rank
        r2 = max(1, int(round(beta * r1)))
    n = lrd.branches
    if n > 1 and min(r1, r2) // n >= 8:
        f = branch_tucker(w, r1, r2, n)
        params = {"u": f.u, "core": f.core, "v": f.v}
        axes = {"u": (BRANCH, ax[-2], RANK),
                "core": (BRANCH, CONV, CONV, RANK, RANK),
                "v": (BRANCH, RANK, ax[-1])}
        return params, axes, "branched_tucker", quantize_ranks(r1, r2, n)
    f = tucker2_decompose(w, r1, r2)
    params = {"tucker_u": f.u, "core": f.core, "tucker_v": f.v}
    axes = {"tucker_u": (ax[-2], RANK), "core": (CONV, CONV, RANK, RANK),
            "tucker_v": (RANK, ax[-1])}
    return params, axes, "tucker", (r1, r2)


def _count(tree: Any) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(tree))


def _fwd_flops(params: dict | jax.Array, conv: bool) -> float:
    """Forward FLOPs per row (linear) or per output pixel (conv)."""
    from repro.layers.param import linear_flops
    if conv:
        if isinstance(params, dict) and "w" in params:
            kh, kw, c, s = params["w"].shape[-4:]
            return 2.0 * kh * kw * c * s
        if "tucker_u" in params:
            c, r1 = params["tucker_u"].shape[-2:]
            kh, kw, _, r2 = params["core"].shape[-4:]
            s = params["tucker_v"].shape[-1]
            return 2.0 * (c * r1 + kh * kw * r1 * r2 + r2 * s)
        # branched tucker
        n, c, r1 = params["u"].shape[-3:]
        _, kh, kw, _, r2 = params["core"].shape[-5:]
        s = params["v"].shape[-1]
        return 2.0 * n * (c * r1 + kh * kw * r1 * r2 + r2 * s)
    # linear: reuse the layers accounting on the innermost 2 dims
    leaf = {k: v[(0,) * (v.ndim - (3 if k in ("u", "xc", "v") else 2))]
            if v.ndim > (3 if k in ("u", "xc", "v") else 2) else v
            for k, v in params.items()}
    return linear_flops(leaf, 1)


# ---------------------------------------------------------------------------
# The tree walker
# ---------------------------------------------------------------------------

def decompose_model(params: PyTree, axes: PyTree, lrd: LRDConfig, *,
                    m_tokens: int = 4096,
                    exclude: Callable[[str], bool] | None = None,
                    ) -> tuple[PyTree, PyTree, SurgeryReport]:
    """Apply LRD to every targeted linear/conv subtree. Pure function of the
    input trees; returns rewritten copies plus the decision report."""
    report = SurgeryReport()
    if not lrd.enabled:
        return params, axes, report
    targets = set(lrd.targets)
    rank_cache: dict = {}

    def walk(p: Any, a: Any, path: tuple[str, ...]) -> tuple[Any, Any]:
        if _is_linear_node(p):
            label = classify_path(path)
            w, ax = p["w"], a["w"]
            nb = _batch_dims(ax)
            conv = _is_conv(ax, nb)
            pstr = "/".join(path)
            if conv and int(w.shape[0]) == 1 and int(w.shape[1]) == 1:
                # 1x1 convs are FC layers (paper Fig. 1a): SVD, not Tucker
                label = "conv1x1"
                conv = False
                conv1x1 = True
            else:
                conv1x1 = False
            before_params, before_flops = _count(p), _fwd_flops(p, conv)
            if label not in targets:
                report.decisions.append(LayerDecision(
                    pstr, label, "skip", tuple(w.shape), None,
                    before_params, before_params, before_flops, before_flops,
                    "label not targeted"))
                return p, a
            if conv:
                np_, na, kind, rank = _decompose_conv(w, ax, lrd, m_tokens)
            elif conv1x1:
                w2 = w.reshape(w.shape[-2], w.shape[-1])
                np_, na, kind, rank = _decompose_linear(
                    w2, ax[-2:], lrd, m_tokens, rank_cache)
            else:
                np_, na, kind, rank = _decompose_linear(w, ax, lrd, m_tokens,
                                                        rank_cache)
            if np_ is None:
                report.decisions.append(LayerDecision(
                    pstr, label, kind, tuple(w.shape), None,
                    before_params, before_params, before_flops, before_flops,
                    str(rank)))
                return p, a
            report.decisions.append(LayerDecision(
                pstr, label, kind, tuple(w.shape), rank,
                before_params, _count(np_), before_flops,
                _fwd_flops(np_, conv)))
            return np_, na
        if isinstance(p, dict):
            new_p, new_a = {}, {}
            for k in p:
                if exclude is not None and exclude("/".join((*path, k))):
                    new_p[k], new_a[k] = p[k], a[k]
                    continue
                new_p[k], new_a[k] = walk(p[k], a[k], (*path, k))
            return new_p, new_a
        return p, a

    new_params, new_axes = walk(params, axes, ())
    return new_params, new_axes, report


# ---------------------------------------------------------------------------
# 2:4 sparsification pass (compound compression, after decomposition)
# ---------------------------------------------------------------------------

def sparsify_model(params: PyTree, axes: PyTree, lrd: LRDConfig, *,
                   mode: str | None = None) -> tuple[PyTree, PyTree]:
    """Magnitude-based 2:4 sparsification of the decomposed factors.

    The third compression axis, applied *after* :func:`decompose_model`:
    every ``lrd.sparse_targets`` factor whose input dim divides the
    group size is rewritten to the packed ``k_sp``/``k_idx``
    (+ ``k_scale``) convention of :mod:`repro.quant.sparse` — keeping,
    per group of 4 input rows, the 2 with the largest L1 row norm
    (mask shared across the output axis, so the index metadata costs
    one int8 per group instead of two bits per value).  ``mode``
    defaults to ``lrd.quantize``: when the factors are also being
    quantized the kept values pack straight to the narrow dtype
    (compound 2:4 x int8); otherwise they stay in the source dtype
    (reference-path only — no fused kernel serves bf16-sparse).

    Returns rewritten ``(params, axes)``; a no-op when
    ``lrd.sparsify == "none"``.
    """
    if lrd.sparsify == "none":
        return params, axes
    from repro.quant.sparse import sparsify_tree
    quant = lrd.quantize if mode is None else mode
    return sparsify_tree(params, pattern=lrd.sparsify,
                         mode=quant if quant != "none" else "none",
                         targets=lrd.sparse_targets, axes=axes)

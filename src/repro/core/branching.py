"""Branched (block-diagonal) low-rank decomposition — paper §2.4, Eq. 12-17.

A rank-(r1, r2) Tucker factorization splits into ``N`` parallel branches of
ranks (r1/N, r2/N) by keeping only the *diagonal blocks* of the core
(Eq. 17).  The core shrinks by ``N x`` without reducing the total rank
(Eq. 18-20), and the whole structure executes as one grouped matmul
(Fig. 4) — the TPU-native analogue of grouped convolution, implemented in
:mod:`repro.kernels.branched_matmul`.

Two initialization paths:

* FC / linear (SVD): ``W = W0 @ W1`` splits column-wise into branch factors
  with **identity cores** — exact at init (the SVD "core" sqrt(S)·sqrt(S)
  is diagonal, and a diagonal matrix *is* block-diagonal).  The cores then
  train as free per-branch (r1/N x r2/N) mixers.
* Conv (Tucker-2): the HOSVD core is dense, so branching drops its
  off-diagonal blocks — an approximation (quantified by
  :func:`branch_error`), traded for the N x core compression exactly as in
  the paper.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.svd import svd_decompose
from repro.core.tucker import TuckerFactors, tucker2_decompose


class BranchedFactors(NamedTuple):
    u: jax.Array     # (N, C, r1/N)
    xc: jax.Array    # (N, r1/N, r2/N)          block-diagonal core
    v: jax.Array     # (N, r2/N, S)


class BranchedConvFactors(NamedTuple):
    u: jax.Array     # (N, C, r1/N)             per-branch 1x1 in
    core: jax.Array  # (N, k, k, r1/N, r2/N)    per-branch kxk core
    v: jax.Array     # (N, r2/N, S)             per-branch 1x1 out


def quantize_ranks(r1: int, r2: int, branches: int) -> tuple[int, int]:
    """Ranks quantized to multiples of N (paper Eq. 10-11), rounding up."""
    n = branches
    q = lambda r: max(n, ((r + n - 1) // n) * n)
    return q(r1), q(r2)


def branch_svd(w: jax.Array, rank: int, branches: int) -> BranchedFactors:
    """Branched factors for a linear layer ``w (..., C, S)`` — exact at init.

    Batch dims (stacked layers / expert banks) pass through: outputs are
    ``u (..., N, C, rb)``, ``xc (..., N, rb, rb)``, ``v (..., N, rb, S)``.
    """
    n = branches
    rank, _ = quantize_ranks(rank, rank, n)
    c, s = int(w.shape[-2]), int(w.shape[-1])
    rank = min(rank, (min(c, s) // n) * n) or n
    rb = rank // n
    f = svd_decompose(w, rank)
    batch = w.shape[:-2]
    # w0 (..., C, N*rb) -> (..., N, C, rb);  w1 (..., N*rb, S) -> (..., N, rb, S)
    u = jnp.moveaxis(f.w0.reshape(*batch, c, n, rb), -2, -3)
    v = f.w1.reshape(*batch, n, rb, s)
    xc = jnp.broadcast_to(jnp.eye(rb, dtype=w.dtype), (*batch, n, rb, rb))
    return BranchedFactors(u, jnp.array(xc), v)


def branch_tucker(w: jax.Array, r1: int, r2: int,
                  branches: int) -> BranchedConvFactors:
    """Branched Tucker-2 of conv ``w (k, k, C, S)`` — paper Eq. 17.

    Keeps the N diagonal (r1/N x r2/N) blocks of the HOSVD core; the
    off-diagonal blocks are the approximation cost the paper trades for
    the N x compression of Eq. 18-20.
    """
    n = branches
    r1, r2 = quantize_ranks(r1, r2, n)
    kh, kw, c, s = w.shape
    r1 = min(r1, (c // n) * n) or n
    r2 = min(r2, (s // n) * n) or n
    b1, b2 = r1 // n, r2 // n
    f = tucker2_decompose(w, r1, r2)
    u = jnp.stack([f.u[:, j * b1:(j + 1) * b1] for j in range(n)])
    v = jnp.stack([f.v[j * b2:(j + 1) * b2, :] for j in range(n)])
    core = jnp.stack([f.core[:, :, j * b1:(j + 1) * b1, j * b2:(j + 1) * b2]
                      for j in range(n)])
    return BranchedConvFactors(u, core, v)


def reconstruct(f: BranchedFactors) -> jax.Array:
    """W' = sum_j U_j X_j V_j (paper Eq. 17, FC form)."""
    return jnp.einsum("ncr,nrs,nso->co",
                      f.u.astype(jnp.float32), f.xc.astype(jnp.float32),
                      f.v.astype(jnp.float32)).astype(f.u.dtype)


def reconstruct_conv(f: BranchedConvFactors) -> jax.Array:
    return jnp.einsum("ncp,nhwpq,nqs->hwcs",
                      f.u.astype(jnp.float32), f.core.astype(jnp.float32),
                      f.v.astype(jnp.float32)).astype(f.u.dtype)


def branch_error(w: jax.Array, f: BranchedConvFactors) -> float:
    """Relative Frobenius error of the block-diagonal truncation."""
    wf = w.astype(jnp.float32)
    err = jnp.linalg.norm((wf - reconstruct_conv(f).astype(jnp.float32)
                           ).ravel())
    return float(err / (jnp.linalg.norm(wf.ravel()) + 1e-30))


def branched_linear_params(c: int, s: int, r1: int, r2: int,
                           branches: int) -> int:
    n = branches
    return c * r1 + (r1 // n) * (r2 // n) * n + r2 * s


def branched_conv_params(c: int, s: int, k: int, r1: int, r2: int,
                         branches: int) -> int:
    """Paper Eq. 18-20: core shrinks by N."""
    n = branches
    return c * r1 + n * (r1 // n) * (r2 // n) * k * k + r2 * s

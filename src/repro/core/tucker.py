"""Tucker-2 decomposition of conv weight tensors (paper Eq. 4-6, Fig. 1b).

A conv kernel ``W (C, S, k, k)`` (in-ch, out-ch, spatial) decomposes into

    1x1 conv  U  (C, R1)
    k x k core X (R1, R2, k, k)
    1x1 conv  V  (R2, S)

via HOSVD: mode-C and mode-S unfoldings give the factor matrices, the core
is the double contraction of W with them.  This is the "Tucker2" used by
the paper (spatial modes too small to be worth decomposing).

Layout note: we store conv weights as (k, k, C, S) = HWIO (the JAX
``conv_general_dilated`` rhs convention); the math below unfolds on the
I/O modes.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class TuckerFactors(NamedTuple):
    u: jax.Array      # (C, R1)   first 1x1
    core: jax.Array   # (k, k, R1, R2)
    v: jax.Array      # (R2, S)   last 1x1


def tucker2_decompose(w: jax.Array, r1: int, r2: int) -> TuckerFactors:
    """HOSVD Tucker-2 of ``w (k, k, C, S)`` with channel ranks (r1, r2)."""
    orig_dtype = w.dtype
    wf = w.astype(jnp.float32)
    kh, kw, c, s = wf.shape
    r1 = min(r1, c)
    r2 = min(r2, s)
    # Mode-C unfolding: (C, k*k*S)
    unfold_c = jnp.transpose(wf, (2, 0, 1, 3)).reshape(c, -1)
    uc, _, _ = jnp.linalg.svd(unfold_c, full_matrices=False)
    u = uc[:, :r1]                                          # (C, R1)
    # Mode-S unfolding: (S, k*k*C)
    unfold_s = jnp.transpose(wf, (3, 0, 1, 2)).reshape(s, -1)
    us, _, _ = jnp.linalg.svd(unfold_s, full_matrices=False)
    v = us[:, :r2]                                          # (S, R2)
    # Core: contract both channel modes with the factor transposes.
    core = jnp.einsum("hwcs,cp,sq->hwpq", wf, u, v)         # (k,k,R1,R2)
    return TuckerFactors(u.astype(orig_dtype), core.astype(orig_dtype),
                         jnp.transpose(v).astype(orig_dtype))


def reconstruct(f: TuckerFactors) -> jax.Array:
    """W' = core ×_C U ×_S V (paper Eq. 4)."""
    cf = f.core.astype(jnp.float32)
    return jnp.einsum("hwpq,cp,qs->hwcs", cf, f.u.astype(jnp.float32),
                      f.v.astype(jnp.float32)).astype(f.core.dtype)


def approximation_error(w: jax.Array, f: TuckerFactors) -> float:
    wf = w.astype(jnp.float32)
    err = jnp.linalg.norm((wf - reconstruct(f).astype(jnp.float32)).ravel())
    return float(err / (jnp.linalg.norm(wf.ravel()) + 1e-30))


def tucker2_params(c: int, s: int, k: int, r1: int, r2: int) -> int:
    return c * r1 + r1 * r2 * k * k + r2 * s


def dense_conv_params(c: int, s: int, k: int) -> int:
    return c * s * k * k


def tucker2_flops(c: int, s: int, k: int, r1: int, r2: int,
                  out_hw: int) -> float:
    """Forward FLOPs for one image at output spatial size out_hw^2."""
    m = out_hw * out_hw
    return 2.0 * m * (c * r1 + r1 * r2 * k * k + r2 * s)


def dense_conv_flops(c: int, s: int, k: int, out_hw: int) -> float:
    return 2.0 * out_hw * out_hw * c * s * k * k


def ratio_ranks(c: int, s: int, k: int, compression: float,
                beta: float | None = None) -> tuple[int, int]:
    """Ranks (r1, r2) hitting a target compression ratio (paper Eq. 7).

    ``beta = r2/r1`` defaults to S/C (keeps the core square-ish in the
    same aspect ratio as the layer).  Solves
        c*r1 + beta*k^2*r1^2 + beta*r1*s = c*s*k^2 / alpha
    for r1 (positive quadratic root — Eq. 7 of the paper).
    """
    if beta is None:
        beta = s / c
    a = beta * k * k
    b = c + beta * s
    rhs = c * s * k * k / compression
    r1 = (-b + (b * b + 4.0 * a * rhs) ** 0.5) / (2.0 * a)
    r1 = max(1, min(int(r1), c))
    r2 = max(1, min(int(round(beta * r1)), s))
    return r1, r2

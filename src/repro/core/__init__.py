"""The paper's contribution: LRD as an acceleration technique.

* :mod:`repro.core.svd` / :mod:`repro.core.tucker` — the decompositions
  (paper Eq. 1-6).
* :mod:`repro.core.rank_selection` — Algorithm 1 + TPU tile alignment (§2.1).
* :mod:`repro.core.cost_model` — the TPU timer behind Algorithm 1.
* :mod:`repro.core.freezing` — factor freezing (§2.2).
* :mod:`repro.core.merging` — layer merging incl. QK/VO products (§2.3).
* :mod:`repro.core.branching` — block-diagonal branched LRD (§2.4).
* :mod:`repro.core.surgery` — whole-model decomposition driver.
"""
from repro.core.svd import (  # noqa: F401
    SVDFactors, svd_decompose, randomized_svd, decompose_auto,
    ratio_rank, compression_of_rank, energy_rank,
)
from repro.core.tucker import (  # noqa: F401
    TuckerFactors, tucker2_decompose, ratio_ranks,
)
from repro.core.rank_selection import (  # noqa: F401
    ORG, RankDecision, algorithm1, align_rank, select_rank, max_branches,
)
from repro.core.branching import (  # noqa: F401
    BranchedFactors, branch_svd, branch_tucker, quantize_ranks,
)
from repro.core.merging import (  # noqa: F401
    MergedAttnFactors, merge_attention, merge_linear,
)
from repro.core.freezing import trainable_mask  # noqa: F401
from repro.core.surgery import (  # noqa: F401
    SurgeryReport, LayerDecision, decompose_model, classify_path,
)

"""Layer merging — paper §2.3 (Fig. 3) and its transformer realization.

Adjacent linear maps with no nonlinearity between them compose into one
matrix, so after decomposition the factor layers can be *multiplied back
into their neighbours*: the model keeps the original layer count but the
parameter/FLOP savings of the decomposition.

Two concrete forms:

* **CNN bottleneck merging** (the paper's Fig. 3): Tucker-decompose only
  the middle kxk conv; absorb its ``U`` 1x1 factor into the preceding 1x1
  conv and its ``V`` factor into the following 1x1 conv.  Layer count of
  the block: unchanged (3 convs); params/FLOPs: reduced.  Exactness
  caveat: in a real bottleneck a norm+ReLU sits between conv1 and conv2 —
  merging is exact w.r.t. the *linear* composition; we fold the norm scale
  through the merge (see :func:`fold_scale`) and the ReLU stays where it
  was (it acts on the merged layer's output channels, which now live in
  the Tucker R1 basis).  This matches the paper's accounting (their merged
  ResNet keeps exactly the original layer count, Table 3).

* **Attention product merging** (DESIGN.md §4): the attention scores see
  only the *product* W_q W_k^T and the output path only W_v W_o, so a
  decomposed attention can be re-merged into four thin matrices
  ``aq (d,H,r) / ak (d,r) / bv (d,r) / bo (r,H,d)`` — same layer count as
  q/k/v/o, params shrink by ~r/d, and the KV cache shrinks to the shared
  latent (this is structurally DeepSeek-MLA, which hard-codes the paper's
  merging).  Initialized from the dense weights by joint SVD of the
  stacked per-head products.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Plain linear merging
# ---------------------------------------------------------------------------

def merge_linear(a: jax.Array, b: jax.Array) -> jax.Array:
    """(C,R) @ (R,S) -> (C,S): undo a decomposition into one dense layer."""
    return jnp.matmul(a.astype(jnp.float32),
                      b.astype(jnp.float32)).astype(a.dtype)


def merge_conv1x1_into_u(conv1: jax.Array, u: jax.Array) -> jax.Array:
    """Absorb Tucker's U (C_mid, R1) into the preceding 1x1 conv.

    conv1: (1, 1, C_in, C_mid) HWIO -> (1, 1, C_in, R1).
    """
    w = jnp.einsum("hwim,mr->hwir", conv1.astype(jnp.float32),
                   u.astype(jnp.float32))
    return w.astype(conv1.dtype)


def merge_v_into_conv1x1(v: jax.Array, conv3: jax.Array) -> jax.Array:
    """Absorb Tucker's V (R2, C_mid) into the following 1x1 conv.

    conv3: (1, 1, C_mid, C_out) -> (1, 1, R2, C_out).
    """
    w = jnp.einsum("rm,hwmo->hwro", v.astype(jnp.float32),
                   conv3.astype(jnp.float32))
    return w.astype(conv3.dtype)


def fold_scale(w: jax.Array, scale: jax.Array, axis: int) -> jax.Array:
    """Fold a per-channel norm scale through a linear map (merge helper)."""
    shape = [1] * w.ndim
    shape[axis] = w.shape[axis]
    return (w.astype(jnp.float32)
            * scale.astype(jnp.float32).reshape(shape)).astype(w.dtype)


# ---------------------------------------------------------------------------
# Attention product merging (QK^T / V.O joint factorization)
# ---------------------------------------------------------------------------

class MergedAttnFactors(NamedTuple):
    aq: jax.Array    # (d, H, qk_rank)
    ak: jax.Array    # (d, qk_rank)        shared key latent
    bv: jax.Array    # (d, vo_rank)        shared value latent
    bo: jax.Array    # (vo_rank, H, d)


def merge_attention(wq: jax.Array, wk: jax.Array, wv: jax.Array,
                    wo: jax.Array, *, num_heads: int, qk_rank: int,
                    vo_rank: int) -> MergedAttnFactors:
    """Jointly factorize the per-head products M_h = Wq_h Wk_h^T and
    P_h = Wv_h Wo_h with latents *shared across heads*.

    The shared right factor comes from the SVD of the head-stacked product
    matrix; per-head left factors are the projections onto it.  With
    orthonormal latent columns the per-head recovery is exact for
    rank >= head_dim and least-squares optimal below.

    Shapes: wq/wk/wv (d, H*hd); wo (H*hd, d).  GQA inputs should be
    broadcast to full heads by the caller.
    """
    d = wq.shape[0]
    hd = wq.shape[1] // num_heads
    q = wq.astype(jnp.float32).reshape(d, num_heads, hd)
    k = wk.astype(jnp.float32).reshape(d, num_heads, hd)
    v = wv.astype(jnp.float32).reshape(d, num_heads, hd)
    o = wo.astype(jnp.float32).reshape(num_heads, hd, d)

    # --- QK^T ---------------------------------------------------------
    m = jnp.einsum("dhe,fhe->hdf", q, k)            # (H, d, d) products
    stacked = m.reshape(num_heads * d, d)
    _, _, vt = jnp.linalg.svd(stacked, full_matrices=False)
    ak = vt[:qk_rank, :].T                          # (d, r) orthonormal
    aq = jnp.einsum("hdf,fr->dhr", m, ak)           # least-squares left

    # --- V.O ------------------------------------------------------------
    p = jnp.einsum("dhe,hef->hdf", v, o)            # (H, d, d)
    stacked_p = jnp.transpose(p, (1, 0, 2)).reshape(d, num_heads * d)
    uu, _, _ = jnp.linalg.svd(stacked_p, full_matrices=False)
    bv = uu[:, :vo_rank]                            # (d, r) orthonormal
    bo = jnp.einsum("dr,hdf->rhf", bv, p)           # (r, H, d)

    dt = wq.dtype
    return MergedAttnFactors(aq.astype(dt), ak.astype(dt),
                             bv.astype(dt), bo.astype(dt))


def merged_attention_error(wq, wk, wv, wo, f: MergedAttnFactors,
                           num_heads: int) -> tuple[float, float]:
    """Relative errors of the QK and VO product reconstructions."""
    d = wq.shape[0]
    hd = wq.shape[1] // num_heads
    q = wq.astype(jnp.float32).reshape(d, num_heads, hd)
    k = wk.astype(jnp.float32).reshape(d, num_heads, hd)
    v = wv.astype(jnp.float32).reshape(d, num_heads, hd)
    o = wo.astype(jnp.float32).reshape(num_heads, hd, d)
    m = jnp.einsum("dhe,fhe->hdf", q, k)
    p = jnp.einsum("dhe,hef->hdf", v, o)
    m_hat = jnp.einsum("dhr,fr->hdf", f.aq.astype(jnp.float32),
                       f.ak.astype(jnp.float32))
    p_hat = jnp.einsum("dr,rhf->hdf", f.bv.astype(jnp.float32),
                       f.bo.astype(jnp.float32))
    err = lambda a, b: float(jnp.linalg.norm((a - b).ravel())
                             / (jnp.linalg.norm(a.ravel()) + 1e-30))
    return err(m, m_hat), err(p, p_hat)


def merged_attention_params(d: int, num_heads: int, qk_rank: int,
                            vo_rank: int) -> int:
    return d * num_heads * qk_rank + d * qk_rank + d * vo_rank \
        + vo_rank * num_heads * d


def dense_attention_params(d: int, num_heads: int, num_kv_heads: int,
                           head_dim: int) -> int:
    return (d * num_heads * head_dim * 2
            + d * num_kv_heads * head_dim * 2)


# ---------------------------------------------------------------------------
# Factor-into-neighbour merging for decomposed param trees
# ---------------------------------------------------------------------------

def merge_lowrank_subtree(p: dict) -> dict:
    """Collapse a {"w0","w1"} pair back to dense {"w"} (used when Algorithm 1
    decides the decomposed layer is slower, or by the un-decompose path)."""
    return {"w": merge_linear(p["w0"], p["w1"])}

"""Layer freezing — paper §2.2.

The decomposed factors are computed *from the teacher's weights*, so they
are near-optimal transforms already; freezing all but one factor per
decomposed layer removes their gradient and optimizer-state cost during
fine-tuning (the paper's +25-32% training speedup) while leaving inference
untouched.

Freezing is realized twice, consistently:

* **forward**: ``apply_linear(..., freeze_factors=True)`` wraps the frozen
  factor in ``lax.stop_gradient`` — its cotangent is never formed, so the
  backward FLOPs visibly drop in the compiled HLO (measured by the
  dry-run).
* **optimizer**: :func:`trainable_mask` marks the frozen leaves so the
  optimizer allocates no moment state for them (memory win, visible in
  ``memory_analysis()``).

Policy (matching the paper's choice in §2.2): freeze ``w0`` of every SVD
pair — and for branched factors freeze ``u``/``v`` (keep the small cores
training); for Tucker convs freeze the first and last 1x1 factors.
"""
from __future__ import annotations

from typing import Any

import jax

PyTree = Any

# Leaf names considered "teacher-derived transforms" per decomposition kind.
FROZEN_LEAVES = {
    "w0",          # SVD pair: first factor (U sqrt(S))
    "u", "v",      # branched: per-branch outer factors (cores stay live)
    "tucker_u", "tucker_v",  # conv Tucker 1x1 factors
}


def trainable_mask(params: PyTree, *, enabled: bool = True) -> PyTree:
    """Boolean pytree: True = trainable, False = frozen (paper §2.2)."""
    def leaf_mask(path, leaf):
        if not enabled:
            return True
        names = {getattr(k, "key", getattr(k, "name", None)) for k in path}
        return not (names & FROZEN_LEAVES)
    return jax.tree_util.tree_map_with_path(leaf_mask, params)


def frozen_param_count(params: PyTree, mask: PyTree) -> int:
    counts = jax.tree.map(
        lambda p, m: 0 if m else int(p.size), params, mask)
    return sum(jax.tree.leaves(counts))


def trainable_param_count(params: PyTree, mask: PyTree) -> int:
    counts = jax.tree.map(
        lambda p, m: int(p.size) if m else 0, params, mask)
    return sum(jax.tree.leaves(counts))

"""Truncated-SVD low-rank decomposition of linear layers (paper Eq. 1-3).

Each dense weight ``W (C, S)`` decomposes into the balanced factor pair

    W0 = U' sqrt(S'),   W1 = sqrt(S') V'^T          (Eq. 3)

with ``W0 (C, R)``, ``W1 (R, S)``.  The balanced split (sqrt of the singular
values on both sides) keeps the two factors at comparable norms, which
matters for fine-tuning stability and for the paper's freezing variant
(§2.2: the frozen factor is a near-orthogonal transform).

Batched variants (leading expert / branch axes) reuse the same code through
vmap so MoE expert banks decompose in one call.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class SVDFactors(NamedTuple):
    w0: jax.Array        # (..., C, R)
    w1: jax.Array        # (..., R, S)


def svd_decompose(w: jax.Array, rank: int) -> SVDFactors:
    """Truncated SVD of ``w (..., C, S)`` into balanced rank-``rank`` factors.

    Computed in float32 regardless of the input dtype (bf16 SVD is
    numerically useless); factors are cast back to ``w.dtype``.
    """
    orig_dtype = w.dtype
    wf = w.astype(jnp.float32)
    u, s, vt = jnp.linalg.svd(wf, full_matrices=False)
    r = min(rank, s.shape[-1])
    sq = jnp.sqrt(s[..., :r])
    w0 = u[..., :, :r] * sq[..., None, :]
    w1 = sq[..., :, None] * vt[..., :r, :]
    return SVDFactors(w0.astype(orig_dtype), w1.astype(orig_dtype))


def reconstruct(f: SVDFactors) -> jax.Array:
    """W' = W0 @ W1 (paper Eq. 2/3)."""
    return jnp.matmul(f.w0.astype(jnp.float32),
                      f.w1.astype(jnp.float32)).astype(f.w0.dtype)


def approximation_error(w: jax.Array, f: SVDFactors) -> float:
    """Relative Frobenius error ||W - W0 W1||_F / ||W||_F."""
    wf = w.astype(jnp.float32)
    err = jnp.linalg.norm(wf - reconstruct(f).astype(jnp.float32))
    return float(err / (jnp.linalg.norm(wf) + 1e-30))


def energy_rank(w: jax.Array, energy: float) -> int:
    """Smallest rank whose singular values keep ``energy`` of sum sigma_i^2."""
    s = jnp.linalg.svd(w.astype(jnp.float32), compute_uv=False)
    e = jnp.cumsum(s**2)
    e = e / e[-1]
    return int(jnp.searchsorted(e, energy) + 1)


def ratio_rank(c: int, s: int, compression: float) -> int:
    """Rank giving ``compression``x fewer params: R = C*S / (alpha*(C+S))."""
    r = int(math.floor(c * s / (compression * (c + s))))
    return max(1, min(r, min(c, s)))


def compression_of_rank(c: int, s: int, rank: int) -> float:
    """Achieved parameter compression ratio for a rank-R pair."""
    return (c * s) / (rank * (c + s))


def lowrank_params(c: int, s: int, rank: int) -> int:
    return rank * (c + s)


def svd_flops_per_row(c: int, s: int, rank: int) -> float:
    """Forward matmul FLOPs per input row (2 matmuls through the bottleneck)."""
    return 2.0 * rank * (c + s)


def dense_flops_per_row(c: int, s: int) -> float:
    return 2.0 * c * s


def randomized_svd(w: jax.Array, rank: int, *, oversample: int = 8,
                   n_iter: int = 2, key: jax.Array | None = None
                   ) -> SVDFactors:
    """Halko-style randomized SVD — O(C*S*R) instead of O(C*S*min(C,S)).

    Used by surgery on very large matrices (e.g. 163840x2048 embeddings)
    where full SVD on host would dominate decomposition time; the paper's
    "takes only a few seconds" property is preserved this way.
    """
    orig_dtype = w.dtype
    wf = w.astype(jnp.float32)
    c, s = wf.shape[-2:]
    k = min(rank + oversample, min(c, s))
    if key is None:
        key = jax.random.PRNGKey(0)
    omega = jax.random.normal(key, (*wf.shape[:-2], s, k), jnp.float32)
    y = wf @ omega                                     # (..., C, k)
    for _ in range(n_iter):                            # power iterations
        y = wf @ (jnp.swapaxes(wf, -1, -2) @ y)
        y, _ = jnp.linalg.qr(y)
    q, _ = jnp.linalg.qr(y)                            # (..., C, k)
    b = jnp.swapaxes(q, -1, -2) @ wf                   # (..., k, S)
    ub, sb, vtb = jnp.linalg.svd(b, full_matrices=False)
    r = min(rank, sb.shape[-1])
    sq = jnp.sqrt(sb[..., :r])
    w0 = (q @ ub[..., :, :r]) * sq[..., None, :]
    w1 = sq[..., :, None] * vtb[..., :r, :]
    return SVDFactors(w0.astype(orig_dtype), w1.astype(orig_dtype))


def decompose_auto(w: jax.Array, rank: int, *, randomized_threshold: int = 4096,
                   key: jax.Array | None = None) -> SVDFactors:
    """Full SVD for small matrices, randomized for big ones."""
    c, s = int(w.shape[-2]), int(w.shape[-1])
    if min(c, s) > randomized_threshold and rank < min(c, s) // 4:
        return randomized_svd(w, rank, key=key)
    return svd_decompose(w, rank)


def host_svd_decompose(w: np.ndarray, rank: int) -> tuple[np.ndarray, np.ndarray]:
    """NumPy twin for checkpoint-surgery paths that never touch devices."""
    u, s, vt = np.linalg.svd(w.astype(np.float32), full_matrices=False)
    r = min(rank, s.shape[-1])
    sq = np.sqrt(s[:r])
    return (u[:, :r] * sq[None, :]).astype(w.dtype), \
           (sq[:, None] * vt[:r, :]).astype(w.dtype)

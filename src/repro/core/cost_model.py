"""TPU layer-latency cost model — the timer behind Algorithm 1 on TPU.

The paper times each candidate decomposition with the PyTorch profiler on
GPU.  On TPU the dominant effect is *tile quantization*: a matmul operand
dim is padded to the 128-lane MXU width (and 8 sublanes), so a rank of 309
costs the MXU exactly what 384 costs, while 256 saves a full tile-row.

``matmul_time`` therefore models a (M x K) @ (K x N) as

    t = max(compute, memory)
    compute = 2 * M' * K' * N' / peak_flops      (padded dims)
    memory  = bytes(A) + bytes(B) + bytes(C) / hbm_bw   (unpadded, streamed)

which is a two-term roofline per op.  It is deliberately simple — the point
(paper Fig. 2) is the *staircase* in t(r), and the staircase comes entirely
from the padding.  A ``measured`` timer (jit wall-clock on the current
backend) is provided for paper-faithful mode and used in tests to sanity-
check the model's ordering on CPU-sized problems.
"""
from __future__ import annotations

import functools
import time
from typing import Callable

import jax
import jax.numpy as jnp

from repro.analysis.hw_specs import DEFAULT, HardwareSpec, mxu_padded


def matmul_time(m: int, k: int, n: int, *, dtype_bytes: int = 2,
                spec: HardwareSpec = DEFAULT) -> float:
    """Modelled seconds for (m,k)@(k,n) on one chip."""
    mp, kp, np_ = mxu_padded(m, spec), mxu_padded(k, spec), mxu_padded(n, spec)
    compute = 2.0 * mp * kp * np_ / spec.peak_flops_bf16
    memory = dtype_bytes * (m * k + k * n + m * n) / spec.hbm_bandwidth
    return max(compute, memory)


def dense_layer_time(m: int, c: int, s: int, **kw) -> float:
    """Original FC layer: one (m,c)@(c,s)."""
    return matmul_time(m, c, s, **kw)


def lowrank_layer_time(m: int, c: int, s: int, rank: int, **kw) -> float:
    """SVD pair: (m,c)@(c,r) then (m,r)@(r,s).  Two HBM round-trips."""
    return matmul_time(m, c, rank, **kw) + matmul_time(m, rank, s, **kw)


def branched_layer_time(m: int, c: int, s: int, r1: int, r2: int,
                        branches: int, *, dtype_bytes: int = 2,
                        spec: HardwareSpec = DEFAULT) -> float:
    """Block-diagonal branched LRD (paper Eq. 17 / Fig. 4) as executed by
    the fused grouped kernel (kernels/branched_matmul.py).

    Compute: branches run back-to-back on the MXU (time adds) with
    per-branch K dims of r/N.  Memory: the kernel keeps the x tile and the
    branch accumulator in VMEM, so HBM traffic is x + all branch weights +
    the output — x is NOT re-read per branch.
    """
    n = branches
    b1, b2 = max(1, r1 // n), max(1, r2 // n)
    mp = mxu_padded(m, spec)
    # MXU FLOP-time per branch chain on padded dims, summed over branches.
    flops = n * 2.0 * mp * (mxu_padded(c, spec) * mxu_padded(b1, spec)
                            + mxu_padded(b1, spec) * mxu_padded(b2, spec)
                            + mxu_padded(b2, spec) * mxu_padded(s, spec))
    compute = flops / spec.peak_flops_bf16
    weights = n * (c * b1 + b1 * b2 + b2 * s)
    memory = dtype_bytes * (m * c + weights + m * s) / spec.hbm_bandwidth
    return max(compute, memory)


def plan_layer_time(plan, m: int, *, act_bytes: int = 2, kv_bytes: int = 0,
                    act_quantize: bool = False,
                    spec: HardwareSpec = DEFAULT) -> float:
    """Modelled seconds for one :class:`repro.layers.plan.LinearPlan` at
    ``m`` tokens (rows / output pixels) — the plan-driven, quant-aware
    generalization of the per-kind timers above.

    Compute walks the plan's matmul chain on MXU-padded dims, scaled by
    each factor's ``chain_density()`` (2:4 factors run at half rate on
    sparsity-capable MXUs) and costed at a *dtype-aware* MXU rate
    (``spec.peak_flops``): a dot whose weight operand is a plain-int8
    factor AND whose activation side is quantized (``act_quantize`` —
    the prefill qa kernels) issues int8 x int8 at ~2x the bf16 rate;
    int8 weights dequantized in VMEM against full-width activations run
    at the base rate (the MXU sees wide operands either way).  Memory
    streams the activations at ``act_bytes`` (halved-ish under
    ``act_quantize``: int8 values + one f32 scale per row) plus the
    plan's ``weight_bytes`` — which is where int8/fp8 factors pay off:
    a quantized plan moves half the weight bytes of its bf16 twin, so
    the memory-bound decode term drops, and a 2:4-packed plan halves
    the int8 value bytes again.

    ``kv_bytes`` adds a runtime stream to the same memory term: the KV
    pool bytes this layer reads per step (decode attention streams the
    *whole* pool).  Derive it from the layer's declarative cache plan
    via :func:`plan_kv_bytes` — NOT from a hand-computed formula — so
    every cache family (f32/int8 GQA pools, f32/int8 MLA latents) is
    costed by the same source of truth the serve pool uses.  At
    serve-time batch sizes the decode roofline is memory-bound on
    exactly these two streams, so the model predicts the KV-quant win
    the serve benchmark then measures; at prefill batch sizes it is
    compute-bound and predicts the int8 x int8 throughput win.
    """
    mp = mxu_padded(m, spec)
    qa = plan_qa_eligible(plan, act_quantize)
    rate = spec.peak_flops(1 if qa else act_bytes)
    flops = sum(2.0 * mult * mp * mxu_padded(k, spec) * mxu_padded(n, spec)
                * density
                for (mult, k, n), density in zip(plan.matmul_chain(),
                                                 plan.chain_density()))
    compute = flops / rate
    memory = (plan_act_stream_bytes(plan, act_bytes=act_bytes,
                                    act_quantize=act_quantize) * m
              + plan.weight_bytes + kv_bytes) / spec.hbm_bandwidth
    return max(compute, memory)


def plan_qa_eligible(plan, act_quantize: bool = True) -> bool:
    """qa dispatch mirror (LinearPlan.kernel_for): every factor plain
    int8 — then the whole chain runs int8 x int8 and the activation
    stream narrows to int8 values + one f32 scale per token row."""
    return act_quantize and all(
        f.quantized and f.sparsity is None
        and jnp.dtype(f.dtype).itemsize == 1 for f in plan.chain_factors())


def plan_act_stream_bytes(plan, *, act_bytes: int = 2,
                          act_quantize: bool = False) -> float:
    """Per-token activation HBM bytes of one plan's linear — input plus
    output rows at ``act_bytes``, narrowed to int8 values + one f32
    row scale when the qa kernels take the layer.  Shared by
    :func:`plan_layer_time` and the prefill benchmark's byte
    accounting so the model and the report can't drift apart."""
    if plan_qa_eligible(plan, act_quantize):
        act_bytes = 1 + 4.0 / max(1, plan.d_in)
    return act_bytes * (plan.d_in + plan.d_out)


def plan_kv_bytes(cache_plan, slots: int, seq_len: int) -> int:
    """Per-decode-step KV stream bytes of one layer, from its
    :class:`repro.layers.cache.CachePlan` — the plan-derived ``kv_bytes``
    input to :func:`plan_layer_time`.  Decode reads every slot's full
    ``seq_len`` (masked, not skipped), so this is the whole pool:
    per-position value bytes times occupancy plus the per-slot f32
    scale rows for the int8 families.  Single source of truth with
    :class:`repro.serve.pool.KVPoolManager`'s accounting and the
    engine's ``plan_summary["kv_bytes_per_step"]``.
    """
    return cache_plan.bytes_per_step(slots, seq_len)


def conv_time(m_hw: int, c: int, s: int, k: int, *, dtype_bytes: int = 2,
              spec: HardwareSpec = DEFAULT) -> float:
    """kxk conv at output spatial size m_hw^2 == matmul with K = c*k*k."""
    return matmul_time(m_hw * m_hw, c * k * k, s,
                       dtype_bytes=dtype_bytes, spec=spec)


def tucker2_time(m_hw: int, c: int, s: int, k: int, r1: int, r2: int,
                 **kw) -> float:
    """1x1 (c->r1) + kxk core (r1->r2) + 1x1 (r2->s)."""
    m = m_hw * m_hw
    return (matmul_time(m, c, r1, **kw)
            + matmul_time(m, r1 * k * k, r2, **kw)
            + matmul_time(m, r2, s, **kw))


# ---------------------------------------------------------------------------
# Timer protocol for Algorithm 1 (rank_selection.py)
# ---------------------------------------------------------------------------
# A timer maps rank -> seconds for a fixed layer geometry. ``make_model_timer``
# builds one from the cost model; ``make_measured_timer`` times a real jit'd
# layer on the current backend (paper-faithful mode).

def make_model_timer(m: int, c: int, s: int, *, kind: str = "svd",
                     k: int = 1, beta: float | None = None,
                     spec: HardwareSpec = DEFAULT) -> Callable[[int], float]:
    if kind == "svd":
        def timer(r: int) -> float:
            return lowrank_layer_time(m, c, s, r, spec=spec)
    elif kind == "tucker":
        bb = beta if beta is not None else s / c
        def timer(r: int) -> float:
            r2 = max(1, int(round(bb * r)))
            return tucker2_time(int(m ** 0.5) or 1, c, s, k, r, r2, spec=spec)
    else:
        raise ValueError(kind)
    return timer


def make_dense_time(m: int, c: int, s: int, *, kind: str = "svd", k: int = 1,
                    spec: HardwareSpec = DEFAULT) -> float:
    if kind == "svd":
        return dense_layer_time(m, c, s, spec=spec)
    return conv_time(int(m ** 0.5) or 1, c, s, k, spec=spec)


def make_measured_timer(m: int, c: int, s: int, *, dtype=jnp.float32,
                        iters: int = 5) -> Callable[[int], float]:
    """Wall-clock timer on the current backend (the paper's method verbatim).

    Times ``(x @ w0) @ w1`` end to end for each candidate rank. Meaningful
    ordering on CPU for moderate sizes; on TPU it times the real MXU.
    """
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (m, c), dtype)

    @functools.lru_cache(maxsize=None)
    def timer(r: int) -> float:
        w0 = jax.random.normal(key, (c, r), dtype)
        w1 = jax.random.normal(key, (r, s), dtype)
        f = jax.jit(lambda a, b0, b1: (a @ b0) @ b1)
        f(x, w0, w1).block_until_ready()          # compile
        t0 = time.perf_counter()
        for _ in range(iters):
            f(x, w0, w1).block_until_ready()
        return (time.perf_counter() - t0) / iters

    return timer


def measured_dense_time(m: int, c: int, s: int, *, dtype=jnp.float32,
                        iters: int = 5) -> float:
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (m, c), dtype)
    w = jax.random.normal(key, (c, s), dtype)
    f = jax.jit(lambda a, b: a @ b)
    f(x, w).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        f(x, w).block_until_ready()
    return (time.perf_counter() - t0) / iters

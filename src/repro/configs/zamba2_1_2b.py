"""zamba2-1.2b — Mamba2 backbone + shared attention blocks.

[arXiv:2411.15242; hf-verified tier]
38L d_model=2048 32H (kv=32) d_ff=8192 vocab=32000, ssm_state=64.
A single *shared* attention+MLP block is applied every ``hybrid_attn_every``
mamba layers (weights reused each invocation — Zamba's signature trick).
"""
from repro.configs.base import ModelConfig, ParallelConfig, FAMILY_HYBRID
from repro.configs.registry import ArchEntry, register

FULL = ModelConfig(
    name="zamba2-1.2b",
    family=FAMILY_HYBRID,
    num_layers=38,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=32000,
    ssm_state=64,
    ssm_expand=2,
    ssm_chunk=256,
    hybrid_attn_every=6,
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="zamba2-smoke",
    family=FAMILY_HYBRID,
    num_layers=4,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    ssm_state=16,
    ssm_expand=2,
    ssm_chunk=32,
    hybrid_attn_every=2,
    tie_embeddings=True,
)


def _parallel(kind: str) -> ParallelConfig:
    if kind == "train":
        return ParallelConfig(seq_shard=True, remat="full")
    if kind == "prefill":
        return ParallelConfig(seq_shard=True)
    return ParallelConfig(decode_seq_shard=True)


register(ArchEntry(
    name="zamba2-1.2b", full=FULL, smoke=SMOKE, parallel=_parallel,
    notes="Hybrid -> runs long_500k. Shared attn block decomposes ONCE "
          "(factors shared across invocations); freezing the shared factors "
          "freezes 6 invocations at once — best-case for paper §2.2.",
))

"""minitron-4b — pruned Nemotron.

[arXiv:2407.14679; hf-verified tier]
32L d_model=3072 24H (GQA kv=8) d_ff=9216 vocab=256000.

24 heads do not divide the 16-way `model` axis: attention projections fall
back to flat-dim sharding and the per-head attention runs with heads
replicated (see parallel/sharding.py divisibility fallback + DESIGN.md §5).
"""
from repro.configs.base import ModelConfig, ParallelConfig, FAMILY_DENSE
from repro.configs.registry import ArchEntry, register

FULL = ModelConfig(
    name="minitron-4b",
    family=FAMILY_DENSE,
    num_layers=32,
    d_model=3072,
    num_heads=24,
    num_kv_heads=8,
    head_dim=128,
    d_ff=9216,
    vocab_size=256000,
    act="gelu",            # nemotron uses squared-relu; gelu is our stand-in
    rope_theta=10000.0,
)

SMOKE = ModelConfig(
    name="minitron-smoke",
    family=FAMILY_DENSE,
    num_layers=2,
    d_model=48,
    num_heads=3,           # keep the non-divisible head count in the smoke
    num_kv_heads=1,
    head_dim=16,
    d_ff=144,
    vocab_size=256,
    act="gelu",
)


def _parallel(kind: str) -> ParallelConfig:
    if kind == "train":
        return ParallelConfig(seq_shard=True, remat="full")
    if kind == "prefill":
        return ParallelConfig(seq_shard=True)
    return ParallelConfig(decode_seq_shard=True)


register(ArchEntry(
    name="minitron-4b", full=FULL, smoke=SMOKE, parallel=_parallel,
    notes="vocab 256000 dominates params (786M embed+unembed of ~4B): the "
          "paper's unembed LRD is maximal here. long_500k skipped: full attn.",
))

"""mistral-nemo-12b — dense 128k-context decoder.

[hf:mistralai/Mistral-Nemo-Base-2407; hf-verified tier]
40L d_model=5120 32H (GQA kv=8, head_dim=128) d_ff=14336 vocab=131072.
"""
from repro.configs.base import ModelConfig, ParallelConfig, FAMILY_DENSE
from repro.configs.registry import ArchEntry, register

FULL = ModelConfig(
    name="mistral-nemo-12b",
    family=FAMILY_DENSE,
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=131072,
    rope_theta=1000000.0,
)

SMOKE = ModelConfig(
    name="mistral-nemo-smoke",
    family=FAMILY_DENSE,
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=192,
    vocab_size=256,
)


def _parallel(kind: str) -> ParallelConfig:
    if kind == "train":
        return ParallelConfig(seq_shard=True, remat="full", fsdp=True)
    if kind == "prefill":
        return ParallelConfig(seq_shard=True)
    return ParallelConfig(decode_seq_shard=True)


register(ArchEntry(
    name="mistral-nemo-12b", full=FULL, smoke=SMOKE, parallel=_parallel,
    notes="long_500k skipped: pure full attention.",
))

"""ResNet-50/101/152 — the paper's own benchmark architectures.

[He et al. 2016] Bottleneck ResNets; these are the models Tables 1 & 3-6 of
the paper are measured on. Registered with a ``resnet`` prefix so they are
selectable via ``--arch`` but excluded from the assigned-architecture sweep.
"""
from repro.configs.base import ModelConfig, ParallelConfig, FAMILY_RESNET
from repro.configs.registry import ArchEntry, register


def _cfg(name: str, blocks) -> ModelConfig:
    return ModelConfig(
        name=name,
        family=FAMILY_RESNET,
        resnet_stage_blocks=tuple(blocks),
        resnet_width=64,
        num_classes=1000,
        img_size=224,
        dtype="float32",
    )


RESNET50 = _cfg("resnet50", (3, 4, 6, 3))
RESNET101 = _cfg("resnet101", (3, 4, 23, 3))
RESNET152 = _cfg("resnet152", (3, 8, 36, 3))

SMOKE = ModelConfig(
    name="resnet-smoke",
    family=FAMILY_RESNET,
    resnet_stage_blocks=(1, 1, 1, 1),
    resnet_width=16,
    num_classes=10,
    img_size=32,
    dtype="float32",
)


def _parallel(kind: str) -> ParallelConfig:
    return ParallelConfig()


for _name, _full in (("resnet50", RESNET50), ("resnet101", RESNET101),
                     ("resnet152", RESNET152)):
    register(ArchEntry(name=_name, full=_full, smoke=SMOKE,
                       parallel=_parallel,
                       notes="paper's own arch; Tucker-2 LRD path"))

from repro.configs.base import (
    LRDConfig, ModelConfig, ParallelConfig, RunConfig, ShapeConfig,
    SHAPES, TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K,
    applicable_shapes, skip_reason,
)
from repro.configs import registry

__all__ = [
    "LRDConfig", "ModelConfig", "ParallelConfig", "RunConfig", "ShapeConfig",
    "SHAPES", "TRAIN_4K", "PREFILL_32K", "DECODE_32K", "LONG_500K",
    "applicable_shapes", "skip_reason", "registry",
]

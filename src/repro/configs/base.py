"""Configuration dataclasses for the repro framework.

Everything in the framework is driven by three config objects:

* :class:`ModelConfig` — architecture hyper-parameters (one instance per
  assigned architecture lives in ``repro/configs/<arch>.py``).
* :class:`LRDConfig` — the paper's technique: which layers to decompose, how
  ranks are chosen (including the Algorithm-1 search and TPU alignment), and
  which acceleration variants (freezing / merging / branching) are active.
* :class:`ParallelConfig` — mesh axes and sharding strategy knobs
  (DP/FSDP/TP/EP/SP, remat, grad-accum, compression).

Configs are plain frozen dataclasses so they hash, print, and diff cleanly and
can be embedded into jit static args.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Mapping, Sequence


# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------

FAMILY_DENSE = "dense"          # pre-norm decoder, GQA, SwiGLU
FAMILY_MOE = "moe"              # as dense but MoE FFN (optionally MLA)
FAMILY_VLM = "vlm"              # dense decoder + interleaved cross-attn layers
FAMILY_HYBRID = "hybrid"        # mamba2 blocks + shared attention block
FAMILY_SSM = "ssm"              # pure mamba2 (attention-free)
FAMILY_ENCODER = "encoder"      # bidirectional encoder (audio backbone)
FAMILY_RESNET = "resnet"        # the paper's own CNN family

FAMILIES = (
    FAMILY_DENSE, FAMILY_MOE, FAMILY_VLM, FAMILY_HYBRID,
    FAMILY_SSM, FAMILY_ENCODER, FAMILY_RESNET,
)


@dataclass(frozen=True)
class ModelConfig:
    """Architecture definition. Defaults describe a small dense decoder."""

    name: str = "tiny"
    family: str = FAMILY_DENSE

    # Transformer trunk.
    num_layers: int = 2
    d_model: int = 256
    num_heads: int = 4
    num_kv_heads: int = 4
    head_dim: int = 0                  # 0 -> d_model // num_heads
    d_ff: int = 1024
    vocab_size: int = 512
    max_seq_len: int = 131072
    rope_theta: float = 500000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    act: str = "swiglu"                # "swiglu" | "gelu"
    attn_logit_softcap: float = 0.0

    # MoE.
    moe_num_experts: int = 0           # 0 -> dense FFN
    moe_top_k: int = 2
    moe_num_shared: int = 0            # always-on shared experts
    moe_d_ff: int = 0                  # expert hidden dim (0 -> d_ff)
    moe_every: int = 1                 # MoE FFN every k-th layer (1 = all)
    moe_first_dense: int = 0           # first k layers use dense FFN
    moe_capacity_factor: float = 1.25
    moe_dispatch_groups: int = 0       # 0 = global dispatch; G = data-local
                                       # hierarchical dispatch (see §Perf)

    # Multi-head Latent Attention (deepseek-v2 style).
    mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_rope_dim: int = 64
    qk_nope_dim: int = 128
    v_head_dim: int = 128

    # SSM (mamba2 / SSD).
    ssm_state: int = 0                 # N (state dim); 0 -> no SSM
    ssm_expand: int = 2                # d_inner = expand * d_model
    ssm_heads: int = 0                 # 0 -> d_inner // 64
    ssm_chunk: int = 256               # SSD chunk length
    ssm_conv_width: int = 4

    # Hybrid (zamba2-style shared attention block).
    hybrid_attn_every: int = 6         # shared attn block applied every k layers

    # VLM (llama-3.2-vision-style cross attention).
    cross_attn_every: int = 0          # 0 -> no cross-attn layers
    num_image_tokens: int = 1601       # stub frontend output length
    vision_d_model: int = 0            # 0 -> d_model

    # Encoder-only (hubert) specifics.
    is_encoder: bool = False           # bidirectional attention, no KV cache
    frontend_dim: int = 0              # stub frame-embedding dim (0 -> d_model)

    # ResNet family (paper's own benchmark architecture).
    resnet_stage_blocks: Sequence[int] = ()
    resnet_width: int = 64
    num_classes: int = 1000
    img_size: int = 224

    # Numerics.
    dtype: str = "bfloat16"            # activation / param dtype
    accum_dtype: str = "float32"
    pad_vocab: bool = True             # pad embed/unembed vocab dim to a
                                       # multiple of 128 (shardable +
                                       # MXU-aligned; padded logits masked)

    def __post_init__(self):
        if self.family not in FAMILIES:
            raise ValueError(f"unknown family {self.family!r}")

    # -- derived quantities -------------------------------------------------

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.num_heads if self.num_heads else 0

    @property
    def resolved_moe_d_ff(self) -> int:
        return self.moe_d_ff or self.d_ff

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def resolved_ssm_heads(self) -> int:
        return self.ssm_heads or max(1, self.d_inner // 64)

    @property
    def attention_free(self) -> bool:
        return self.family == FAMILY_SSM

    @property
    def subquadratic(self) -> bool:
        """Can this arch run the 500k-context decode cell?"""
        return self.family in (FAMILY_SSM, FAMILY_HYBRID)

    @property
    def has_decode(self) -> bool:
        return not (self.is_encoder or self.family == FAMILY_RESNET)

    def param_count(self, active_only: bool = False) -> int:
        """Exact parameter count from the real model's ``eval_shape`` tree.

        ``active_only`` scales routed MoE expert banks by top_k/num_experts
        (shared experts stay fully active).
        """
        return _param_count_cached(self, active_only)

    def matmul_param_count(self, active_only: bool = True) -> int:
        """Params participating in matmuls per token: excludes the embedding
        *gather* table (tied tables count once — they are the unembed)."""
        total = self.param_count(active_only=active_only)
        if self.family == FAMILY_RESNET:
            return total
        if not self.tie_embeddings:
            total -= self.vocab_size * self.d_model
        return total

    def flops_per_token(self, active_only: bool = True) -> float:
        """~6 * N_active per training token (fwd+bwd); use /3 for fwd-only."""
        return 6.0 * self.matmul_param_count(active_only=active_only)


import functools


@functools.lru_cache(maxsize=None)
def _param_count_cached(cfg: "ModelConfig", active_only: bool) -> int:
    import jax  # lazy: keep configs importable without touching jax devices
    from repro.models.api import get_model  # lazy, avoids cycle
    m = get_model(cfg)
    shapes = jax.eval_shape(lambda k: m.init(k)[0], jax.random.PRNGKey(0))
    total = expert = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(shapes)[0]:
        n = int(leaf.size)
        total += n
        names = {getattr(k, "key", None) for k in path}
        if "experts" in names:
            expert += n
    if active_only and cfg.moe_num_experts:
        total -= expert
        total += int(expert * cfg.moe_top_k / cfg.moe_num_experts)
    return int(total)


# ---------------------------------------------------------------------------
# LRD (paper technique) configuration
# ---------------------------------------------------------------------------

RANK_MODE_RATIO = "ratio"        # rank from target compression ratio (paper Eq. 7)
RANK_MODE_ALIGNED = "aligned"    # ratio rank snapped to TPU tile (ours)
RANK_MODE_SEARCH = "search"      # Algorithm 1 (cost-model or measured timer)
RANK_MODE_ENERGY = "energy"      # keep singular values covering `energy` mass


@dataclass(frozen=True)
class LRDConfig:
    """The paper's LRD acceleration technique, as a config."""

    enabled: bool = False
    compression: float = 2.0          # target per-layer compression ratio (α)
    rank_mode: str = RANK_MODE_ALIGNED
    rank_align: int = 128             # MXU lane width on TPU
    rank_min_frac: float = 0.25       # Algorithm-1 search floor: R_min = frac*R
    energy: float = 0.95              # for RANK_MODE_ENERGY
    min_dim: int = 256                # don't decompose layers smaller than this
    targets: Sequence[str] = (        # which logical layers to decompose
        "attn_q", "attn_k", "attn_v", "attn_o",
        "ffn_up", "ffn_gate", "ffn_down",
        "moe_up", "moe_gate", "moe_down",
        "unembed", "ssm_in", "ssm_out",
        "conv", "conv1x1", "fc",      # ResNet path (paper §2)
    )
    # Acceleration variants (paper §2.1-2.4).
    freeze: bool = False              # §2.2 freeze W0 factors during fine-tune
    merge: bool = False               # §2.3 merge factors into neighbours / QK-VO
    branches: int = 1                 # §2.4 branched (block-diagonal) LRD; 1=off
    # Kernel dispatch.
    use_pallas: bool = False          # route low-rank matmuls through kernels/
    # Factor quantization (repro/quant): serve-time weight-only compression
    # of the decomposed factors — the second compression axis on top of
    # the rank reduction.  "int8" = per-channel symmetric int8; "fp8" =
    # e4m3 (emulated in bf16 storage when the dtype is unavailable).
    quantize: str = "none"            # "none" | "int8" | "fp8"
    quant_targets: Sequence[str] = (  # which factor keys to quantize
        "w0", "w1", "u", "xc", "v", "tucker_u", "core", "tucker_v",
    )
    # 2:4 semi-structured sparsity of the decomposed factors
    # (repro/quant/sparse): the third compression axis, composable with
    # `quantize` — the packed values adopt the quantized dtype, so
    # 2:4 + int8 roughly halves the int8 factor bytes again.  The small
    # branched core ``xc`` is excluded by default (pruning the already-
    # tiny trainable core buys little and costs accuracy).
    sparsify: str = "none"            # "none" | "2:4"
    sparse_targets: Sequence[str] = ("w0", "w1", "u", "v")
    # Runtime KV-cache quantization (repro/quant/kv): the decode step's
    # *activation* stream — int8 K/V pool + per-(slot, head, channel)
    # scales on GQA stacks, int8 MLA latents + per-(slot, channel)
    # scales on MLA stacks (cache family gqa_int8 / mla_latent_int8 of
    # repro/layers/cache), read by the fused decode-attention kernels.
    kv_quantize: str = "none"         # "none" | "int8"
    # Dynamic activation quantization for the prefill matmul path
    # (kernels/*_qa): per-token absmax int8 activation rows so the
    # fully-int8 factor plans run int8 x int8 on the MXU.  Engages on
    # prefill / chunked-prefill segments only — decode's M = batch dots
    # stay at full activation width.  Requires quantize="int8".
    act_quantize: str = "none"        # "none" | "int8"
    # Continuous-batching serve stack (repro/serve): tokens of prompt
    # processed per chunked-prefill segment, and the per-step token
    # budget the scheduler fills decode-first, then with prefill chunk
    # tokens.  0 = engine defaults (chunk 64; budget slots + chunk).
    prefill_chunk: int = 0
    step_token_budget: int = 0
    # KV pool memory layout: "slot" reserves one contiguous (S_max, ...)
    # region per stream; "paged" cuts KV into fixed-size blocks behind
    # per-slot block tables with radix-tree copy-on-write prefix sharing
    # (repro/serve/paging — dense non-MLA stacks, continuous admission).
    kv_layout: str = "slot"           # "slot" | "paged"
    kv_block_size: int = 0            # tokens per KV block (0 = 16)


# ---------------------------------------------------------------------------
# Parallelism configuration
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ParallelConfig:
    """Mesh + sharding strategy. Axis names match launch/mesh.py."""

    multi_pod: bool = False
    fsdp: bool = False                # shard params/opt-state over `data`
    seq_shard: bool = False           # sequence parallelism on activations
    remat: str = "none"               # "none" | "dots" | "full"
    grad_accum: int = 1               # microbatch steps per optimizer step
    grad_compression_rank: int = 0    # 0 = off; PowerSGD rank otherwise
    shard_vocab: bool = True
    decode_seq_shard: bool = False    # shard KV/state over data for B < data
    shard_rank: bool = False          # shard low-rank RANK dims over `model`
                                      # (beyond-paper TP variant, see §Perf)


# ---------------------------------------------------------------------------
# Input shape cells (assigned shapes)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                         # "train" | "prefill" | "decode"


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")

SHAPES: Mapping[str, ShapeConfig] = {
    s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
}


def applicable_shapes(model: ModelConfig) -> list[ShapeConfig]:
    """Shape cells that are defined for this architecture (spec skips)."""
    out = [TRAIN_4K, PREFILL_32K]
    if model.has_decode:
        out.append(DECODE_32K)
        if model.subquadratic:
            out.append(LONG_500K)
    return out


def skip_reason(model: ModelConfig, shape: ShapeConfig) -> str | None:
    if shape.kind == "decode" and not model.has_decode:
        return "encoder-only: no decode step"
    if shape.name == "long_500k" and not model.subquadratic:
        return "pure full-attention arch: 500k decode cell skipped per spec"
    return None


# ---------------------------------------------------------------------------
# Experiment = model + lrd + parallel (+shape at call sites)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class RunConfig:
    model: ModelConfig
    lrd: LRDConfig = LRDConfig()
    parallel: ParallelConfig = ParallelConfig()
    seed: int = 0

    def replace(self, **kw: Any) -> "RunConfig":
        return dataclasses.replace(self, **kw)

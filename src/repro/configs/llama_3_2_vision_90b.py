"""llama-3.2-vision-90b — VLM decoder with interleaved cross-attention.

[hf:meta-llama/Llama-3.2-11B-Vision (scaled); unverified tier]
100L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256.
Every 5th layer is a cross-attention layer over precomputed image patch
embeddings (the modality frontend is a STUB per spec: ``input_specs()``
provides (B, n_img_tokens, d) embeddings).
"""
from repro.configs.base import ModelConfig, ParallelConfig, FAMILY_VLM
from repro.configs.registry import ArchEntry, register

FULL = ModelConfig(
    name="llama-3.2-vision-90b",
    family=FAMILY_VLM,
    num_layers=100,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=128256,
    cross_attn_every=5,
    num_image_tokens=1601,
    rope_theta=500000.0,
)

SMOKE = ModelConfig(
    name="llama-vision-smoke",
    family=FAMILY_VLM,
    num_layers=5,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=192,
    vocab_size=256,
    cross_attn_every=5,
    num_image_tokens=17,
)


def _parallel(kind: str) -> ParallelConfig:
    if kind == "train":
        return ParallelConfig(seq_shard=True, fsdp=True, remat="full")
    if kind == "prefill":
        return ParallelConfig(fsdp=True, seq_shard=True)
    return ParallelConfig(fsdp=True, decode_seq_shard=True)


register(ArchEntry(
    name="llama-3.2-vision-90b", full=FULL, smoke=SMOKE, parallel=_parallel,
    notes="Backbone-only per spec; image embeddings arrive precomputed. "
          "kv_heads=8 < model axis 16 -> KV cache shards over (batch, seq) "
          "instead of heads (see parallel/sharding.py fallback rule). "
          "long_500k skipped: pure full attention.",
))

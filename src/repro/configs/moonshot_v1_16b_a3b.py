"""moonshot-v1-16b-a3b — Kimi/Moonlight MoE LM.

[hf:moonshotai/Moonlight-16B-A3B; hf-verified tier]
48L d_model=2048 16H (GQA kv=16) expert d_ff=1408 vocab=163840, MoE 64
routed experts top-6 (+2 shared, per the HF reference config).
"""
from repro.configs.base import ModelConfig, ParallelConfig, FAMILY_MOE
from repro.configs.registry import ArchEntry, register

FULL = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family=FAMILY_MOE,
    num_layers=48,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    moe_d_ff=1408,
    vocab_size=163840,
    moe_num_experts=64,
    moe_top_k=6,
    moe_num_shared=2,
    # production default: data-local hierarchical dispatch
    # (EXPERIMENTS.md §Perf: 2-4x step-time on train cells)
    moe_dispatch_groups=16,
    rope_theta=50000.0,
)

SMOKE = ModelConfig(
    name="moonshot-smoke",
    family=FAMILY_MOE,
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    head_dim=16,
    d_ff=96,
    moe_d_ff=96,
    vocab_size=256,
    moe_num_experts=8,
    moe_top_k=2,
    moe_num_shared=1,
)


def _parallel(kind: str) -> ParallelConfig:
    if kind == "train":
        return ParallelConfig(seq_shard=True, fsdp=True, remat="full")
    if kind == "prefill":
        return ParallelConfig(seq_shard=True)
    return ParallelConfig(decode_seq_shard=True)


register(ArchEntry(
    name="moonshot-v1-16b-a3b", full=FULL, smoke=SMOKE, parallel=_parallel,
    notes="MoE: experts shard over `model` (EP); LRD targets expert FFNs + "
          "dense projections; vocab 163840 is the largest LRD win "
          "(163840x2048 unembed -> rank-512 pair is 7.9x smaller).",
))

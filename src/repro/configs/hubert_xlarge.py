"""hubert-xlarge — encoder-only audio backbone (w2v2 architecture).

[arXiv:2106.07447; unverified tier]
48L d_model=1280 16H (kv=16) d_ff=5120 vocab=504 (target units).
Encoder-only: bidirectional attention, no KV cache/decode shapes.
The conv waveform frontend is a STUB per spec: ``input_specs()`` provides
precomputed frame embeddings (B, T, d_model).
"""
from repro.configs.base import ModelConfig, ParallelConfig, FAMILY_ENCODER
from repro.configs.registry import ArchEntry, register

FULL = ModelConfig(
    name="hubert-xlarge",
    family=FAMILY_ENCODER,
    num_layers=48,
    d_model=1280,
    num_heads=16,
    num_kv_heads=16,
    head_dim=80,
    d_ff=5120,
    vocab_size=504,
    is_encoder=True,
    act="gelu",
    frontend_dim=1280,
)

SMOKE = ModelConfig(
    name="hubert-smoke",
    family=FAMILY_ENCODER,
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab_size=64,
    is_encoder=True,
    act="gelu",
    frontend_dim=64,
)


def _parallel(kind: str) -> ParallelConfig:
    if kind == "train":
        return ParallelConfig(seq_shard=True, remat="full")
    return ParallelConfig(seq_shard=True)


register(ArchEntry(
    name="hubert-xlarge", full=FULL, smoke=SMOKE, parallel=_parallel,
    notes="decode_32k/long_500k skipped: encoder-only. vocab=504 not "
          "divisible by 16 -> unembed replicated (tiny). head_dim=80 is "
          "MXU-unfriendly (not 128-multiple): rank-selection demo case.",
))

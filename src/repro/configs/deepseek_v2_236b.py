"""deepseek-v2-236b — MLA + fine-grained MoE.

[arXiv:2405.04434; hf-verified tier]
60L d_model=5120 128H d_ff(expert)=1536 vocab=102400, MoE 160 routed top-6 +
2 shared; MLA kv_lora=512, q_lora=1536, qk_rope=64, qk_nope=128, v_head=128.

Note (DESIGN.md §4): MLA *is* the paper's layer-merging technique hard-coded —
K/V projections are stored as a rank-512 joint factorization. LRD therefore
targets only expert FFNs, o-proj and the q factors; the kv path is recorded
as "inherently decomposed".
"""
from repro.configs.base import ModelConfig, ParallelConfig, FAMILY_MOE
from repro.configs.registry import ArchEntry, register

FULL = ModelConfig(
    name="deepseek-v2-236b",
    family=FAMILY_MOE,
    num_layers=60,
    d_model=5120,
    num_heads=128,
    num_kv_heads=128,
    d_ff=12288,            # dense FFN used for the first layer (per HF config)
    moe_d_ff=1536,
    vocab_size=102400,
    moe_num_experts=160,
    moe_top_k=6,
    moe_num_shared=2,
    # production default: data-local hierarchical dispatch
    # (EXPERIMENTS.md §Perf: 2-4x step-time on train cells)
    moe_dispatch_groups=16,
    moe_first_dense=1,
    mla=True,
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_rope_dim=64,
    qk_nope_dim=128,
    v_head_dim=128,
    rope_theta=10000.0,
)

SMOKE = ModelConfig(
    name="deepseek-smoke",
    family=FAMILY_MOE,
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=128,
    moe_d_ff=48,
    vocab_size=256,
    moe_num_experts=8,
    moe_top_k=2,
    moe_num_shared=1,
    moe_first_dense=1,
    mla=True,
    q_lora_rank=32,
    kv_lora_rank=16,
    qk_rope_dim=8,
    qk_nope_dim=16,
    v_head_dim=16,
)


def _parallel(kind: str) -> ParallelConfig:
    if kind == "train":
        return ParallelConfig(seq_shard=True, fsdp=True, remat="full", grad_accum=1)
    if kind == "prefill":
        return ParallelConfig(fsdp=True, seq_shard=True)
    # 236B bf16 does not fit 16-way TP: decode also shards expert ffn over
    # `data` (2D weight sharding), see parallel/sharding.py.
    return ParallelConfig(fsdp=True, decode_seq_shard=True)


register(ArchEntry(
    name="deepseek-v2-236b", full=FULL, smoke=SMOKE, parallel=_parallel,
    notes="MLA == paper's layer merging; hillclimb target (most "
          "paper-representative). 2D weight sharding mandatory at 236B.",
))

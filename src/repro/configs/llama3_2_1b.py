"""llama3.2-1b — small dense llama3.

[hf:meta-llama/Llama-3.2-1B; unverified tier]
16L d_model=2048 32H (GQA kv=8) d_ff=8192 vocab=128256.
"""
from repro.configs.base import ModelConfig, ParallelConfig, FAMILY_DENSE
from repro.configs.registry import ArchEntry, register

FULL = ModelConfig(
    name="llama3.2-1b",
    family=FAMILY_DENSE,
    num_layers=16,
    d_model=2048,
    num_heads=32,
    num_kv_heads=8,
    head_dim=64,
    d_ff=8192,
    vocab_size=128256,
    tie_embeddings=True,
    rope_theta=500000.0,
)

SMOKE = ModelConfig(
    name="llama3.2-1b-smoke",
    family=FAMILY_DENSE,
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=192,
    vocab_size=256,
    tie_embeddings=True,
)


def _parallel(kind: str) -> ParallelConfig:
    if kind == "train":
        return ParallelConfig(seq_shard=True, remat="full")
    if kind == "prefill":
        return ParallelConfig(seq_shard=True)
    return ParallelConfig(decode_seq_shard=True)


register(ArchEntry(
    name="llama3.2-1b", full=FULL, smoke=SMOKE, parallel=_parallel,
    notes="Smallest assigned arch; at 256 chips it is collective-bound by "
          "construction -> hillclimb candidate (worst roofline fraction). "
          "long_500k skipped: pure full attention.",
))

"""Architecture registry: ``--arch <id>`` resolution.

Every assigned architecture registers a full-size :class:`ModelConfig`, a
reduced smoke-test config of the same family, and its default
:class:`ParallelConfig` for each shape kind.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

from repro.configs.base import (
    ModelConfig, ParallelConfig, RunConfig, LRDConfig,
)

_REGISTRY: dict[str, "ArchEntry"] = {}


@dataclasses.dataclass(frozen=True)
class ArchEntry:
    name: str
    full: ModelConfig
    smoke: ModelConfig
    parallel: Callable[[str], ParallelConfig]  # shape-kind -> ParallelConfig
    notes: str = ""


def register(entry: ArchEntry) -> ArchEntry:
    if entry.name in _REGISTRY:
        raise ValueError(f"duplicate arch {entry.name}")
    _REGISTRY[entry.name] = entry
    return entry


def get(name: str) -> ArchEntry:
    _ensure_loaded()
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown arch {name!r}; known: {sorted(_REGISTRY)}") from None


def names() -> list[str]:
    _ensure_loaded()
    return sorted(_REGISTRY)


def assigned_names() -> list[str]:
    """The 10 assigned LM-family architectures (excludes the ResNet repro)."""
    _ensure_loaded()
    return [n for n in sorted(_REGISTRY) if not n.startswith("resnet")]


def run_config(name: str, shape_kind: str = "train",
               lrd: LRDConfig | None = None) -> RunConfig:
    e = get(name)
    return RunConfig(model=e.full, parallel=e.parallel(shape_kind),
                     lrd=lrd or LRDConfig())


_LOADED = False


def _ensure_loaded() -> None:
    global _LOADED
    if _LOADED:
        return
    _LOADED = True
    # Importing the modules runs their register() calls.
    from repro.configs import (  # noqa: F401
        moonshot_v1_16b_a3b, deepseek_v2_236b, llama_3_2_vision_90b,
        mistral_nemo_12b, llama3_2_1b, granite_8b, minitron_4b,
        zamba2_1_2b, hubert_xlarge, mamba2_2_7b, resnet,
    )

"""mamba2-2.7b — pure SSM (SSD / state-space duality) LM.

[arXiv:2405.21060; unverified tier]
64L d_model=2560 (attention-free) vocab=50280, ssm_state=128.
d_inner = 2*2560 = 5120, 80 SSD heads of 64.
"""
from repro.configs.base import ModelConfig, ParallelConfig, FAMILY_SSM
from repro.configs.registry import ArchEntry, register

FULL = ModelConfig(
    name="mamba2-2.7b",
    family=FAMILY_SSM,
    num_layers=64,
    d_model=2560,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_expand=2,
    ssm_chunk=256,
    ssm_conv_width=4,
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="mamba2-smoke",
    family=FAMILY_SSM,
    num_layers=2,
    d_model=64,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=256,
    ssm_state=16,
    ssm_expand=2,
    ssm_chunk=32,
    tie_embeddings=True,
)


def _parallel(kind: str) -> ParallelConfig:
    if kind == "train":
        return ParallelConfig(seq_shard=True, remat="full")
    if kind == "prefill":
        return ParallelConfig(seq_shard=True)
    return ParallelConfig(decode_seq_shard=True)


register(ArchEntry(
    name="mamba2-2.7b", full=FULL, smoke=SMOKE, parallel=_parallel,
    notes="Attention-free -> runs long_500k (state is O(1) in seq). "
          "LRD targets in/out projections; depthwise conv1d is already "
          "diagonal (not decomposable, DESIGN.md §4). vocab 50280 not "
          "divisible by 16 -> replicated embed/unembed.",
))

"""granite-8b — llama-architecture code model.

[arXiv:2405.04324; hf-verified tier]
36L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=49152.
"""
from repro.configs.base import ModelConfig, ParallelConfig, FAMILY_DENSE
from repro.configs.registry import ArchEntry, register

FULL = ModelConfig(
    name="granite-8b",
    family=FAMILY_DENSE,
    num_layers=36,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=49152,
    rope_theta=10000000.0,
)

SMOKE = ModelConfig(
    name="granite-smoke",
    family=FAMILY_DENSE,
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=192,
    vocab_size=256,
)


def _parallel(kind: str) -> ParallelConfig:
    if kind == "train":
        return ParallelConfig(seq_shard=True, remat="full")
    if kind == "prefill":
        return ParallelConfig(seq_shard=True)
    return ParallelConfig(decode_seq_shard=True)


register(ArchEntry(
    name="granite-8b", full=FULL, smoke=SMOKE, parallel=_parallel,
    notes="long_500k skipped: pure full attention.",
))

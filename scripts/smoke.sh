#!/usr/bin/env bash
# Tier-1 smoke: the full test suite + the quant benchmarks in CPU
# interpret mode. This is what CI runs (see .github/workflows/smoke.yml).
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

python -m pytest -x -q
python -m benchmarks.run --list
python -m benchmarks.bench_quant --dry-run
python -m benchmarks.bench_branched_quant --dry-run
python -m benchmarks.bench_serve_decode --sweep kv --dry-run
python -m benchmarks.bench_serve_decode --sweep mla --dry-run
python -m benchmarks.bench_serve_decode --sweep sched --dry-run
python -m benchmarks.bench_serve_decode --sweep paged --dry-run
python -m benchmarks.bench_serve_decode --sweep faults --dry-run
python -m benchmarks.bench_serve_decode --sweep prefill --dry-run
python -m benchmarks.bench_serve_decode --sweep router --dry-run
python -m benchmarks.bench_frontier --dry-run

"""End-to-end driver: train a ~100M-param LM for a few hundred steps with
the full production stack — LRD surgery, masked AdamW, checkpoints with
auto-resume, straggler detection, preemption handling.

    PYTHONPATH=src python examples/train_lm.py [--steps 200] [--dense]
"""
import argparse
import dataclasses

import jax

from repro.configs.base import (LRDConfig, ModelConfig, ParallelConfig,
                                RunConfig, ShapeConfig)
from repro.train.data import ByteTextLM
from repro.train.fault_tolerance import PreemptionHandler
from repro.train.loop import train
from repro.train.optim import OptimConfig

# ~100M params: 12L x 512d x 2048ff, byte-level vocab
CFG = ModelConfig(name="lm100m", family="dense", num_layers=10,
                  d_model=640, num_heads=10, num_kv_heads=5, head_dim=64,
                  d_ff=2560, vocab_size=256, dtype="float32")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--dense", action="store_true",
                    help="skip LRD (dense baseline)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--corpus", default=None, help="path to a text file")
    args = ap.parse_args()

    n = sum(x.size for x in jax.tree.leaves(
        jax.eval_shape(lambda k: __import__(
            "repro.models.api", fromlist=["get_model"]
        ).get_model(CFG).init(k)[0], jax.random.PRNGKey(0))))
    print(f"model: {n / 1e6:.1f}M params")

    lrd = LRDConfig() if args.dense else LRDConfig(
        enabled=True, compression=2.0, rank_mode="aligned", rank_align=64,
        min_dim=256, freeze=True)
    run = RunConfig(model=CFG, lrd=lrd,
                    parallel=ParallelConfig(remat="none"))
    data = ByteTextLM(CFG, batch=args.batch, seq_len=args.seq,
                      path=args.corpus)
    with PreemptionHandler() as p:
        result = train(run, data, num_steps=args.steps,
                       optim_cfg=OptimConfig(peak_lr=1e-3, warmup_steps=20,
                                             total_steps=args.steps),
                       ckpt_dir=args.ckpt_dir, ckpt_every=50,
                       preemption=p, log_every=20)
    print(f"done at step {result.step}; final loss "
          f"{result.losses[-1]:.4f}; stragglers: "
          f"{result.straggler_report['stragglers']}")


if __name__ == "__main__":
    main()

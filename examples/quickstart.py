"""Quickstart: decompose a small LM with the paper's technique, fine-tune
briefly, and watch the loss recover.  Runs in <1 min on CPU.

    PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses

import jax

from repro.configs import registry
from repro.configs.base import LRDConfig, ParallelConfig, RunConfig, ShapeConfig
from repro.core.surgery import decompose_model
from repro.models.api import get_model
from repro.train.data import SyntheticLM
from repro.train.loop import train
from repro.train.optim import OptimConfig


def main():
    cfg = registry.get("llama3.2-1b").smoke
    shape = ShapeConfig("quick", 64, 4, "train")

    # 1) the dense model
    model = get_model(cfg)
    params, axes = model.init(jax.random.PRNGKey(0))
    n_dense = sum(x.size for x in jax.tree.leaves(params))

    # 2) the paper's technique: truncated-SVD surgery at 2x compression,
    #    ranks aligned to hardware tiles (§2.1), factors frozen (§2.2)
    lrd = LRDConfig(enabled=True, compression=2.0, rank_mode="aligned",
                    rank_align=32, min_dim=48, freeze=True)
    dec, _, report = decompose_model(params, axes, lrd)
    n_dec = sum(x.size for x in jax.tree.leaves(dec))
    print(f"params: dense {n_dense:,} -> decomposed {n_dec:,} "
          f"({n_dec / n_dense:.2%})")
    for d in report.decisions[:6]:
        print(f"  {d.path:28s} {d.kind:5s} rank={d.rank} "
              f"{d.params_before:>9,d} -> {d.params_after:,d}")

    # 3) fine-tune the decomposed model (only the live factors train)
    #    on byte-level text (learnable structure, unlike random tokens)
    from repro.train.data import ByteTextLM
    run = RunConfig(model=cfg, lrd=lrd,
                    parallel=ParallelConfig(remat="none"))
    data = ByteTextLM(cfg, batch=shape.global_batch, seq_len=shape.seq_len)
    result = train(run, data, num_steps=30,
                   optim_cfg=OptimConfig(peak_lr=3e-3, warmup_steps=5,
                                         total_steps=30),
                   log_every=10)
    print(f"loss: {result.losses[0]:.3f} -> {result.losses[-1]:.3f} "
          f"(fine-tuning recovers the decomposition error)")
    assert result.losses[-1] < result.losses[0]


if __name__ == "__main__":
    main()

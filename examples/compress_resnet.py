"""The paper's full pipeline end-to-end on a ResNet: decompose with each
of the four acceleration techniques, fine-tune briefly, report the
Table-3-style comparison.

    PYTHONPATH=src python examples/compress_resnet.py [--full]
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.configs.base import LRDConfig
from repro.core.surgery import decompose_model
from repro.models.resnet import ResNetModel, merge_bottleneck
from repro.train.data import SyntheticImages
from repro.train.optim import OptimConfig, adamw_init, adamw_update
from repro.core.freezing import trainable_mask


def finetune(m, params, data, steps=5, freeze=False):
    cfg = OptimConfig(peak_lr=1e-3, warmup_steps=1, total_steps=steps)
    mask = trainable_mask(params, enabled=freeze)
    state = adamw_init(params, mask)

    @jax.jit
    def step(p, s, batch):
        def loss(p):
            return m.loss(p, batch, freeze_factors=freeze)[0]
        l, g = jax.value_and_grad(loss)(p)
        p2, s2, _ = adamw_update(g, s, p, cfg, mask)
        return p2, s2, l

    losses = []
    for i in range(steps):
        params, state, l = step(params, state, data.batch(i))
        losses.append(float(l))
    return params, losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="use resnet50 full config (slow on CPU)")
    args = ap.parse_args()

    cfg = registry.get("resnet50").full if args.full \
        else registry.get("resnet50").smoke
    m = ResNetModel(cfg)
    params, axes = m.init(jax.random.PRNGKey(0))
    data = SyntheticImages(cfg, batch=4)
    min_dim = 8

    variants = {}
    vanilla, _, rep = decompose_model(params, axes, LRDConfig(
        enabled=True, compression=2.0, rank_mode="ratio", min_dim=min_dim))
    variants["vanilla_lrd"] = (vanilla, False)
    opt_ranks, _, _ = decompose_model(params, axes, LRDConfig(
        enabled=True, compression=2.0, rank_mode="search", min_dim=min_dim))
    variants["optimized_ranks"] = (opt_ranks, False)
    variants["layer_freezing"] = (vanilla, True)
    cores, _, _ = decompose_model(params, axes, LRDConfig(
        enabled=True, compression=2.0, rank_mode="ratio", min_dim=min_dim,
        targets=("conv",)))
    variants["layer_merging"] = (merge_bottleneck(cores), False)
    branched, _, _ = decompose_model(params, axes, LRDConfig(
        enabled=True, compression=1.0001, rank_mode="ratio",
        min_dim=min_dim, branches=2))
    variants["layer_branching"] = (branched, False)

    n0 = sum(x.size for x in jax.tree.leaves(params))
    print(f"{'variant':18s} {'layers':>6s} {'params':>10s} {'dP%':>7s} "
          f"{'ft loss[0]->[-1]':>18s} {'ft s/step':>9s}")
    _, l0 = finetune(m, params, data, steps=3)
    print(f"{'original':18s} {m.layer_count(params):>6d} {n0:>10,d} "
          f"{0.0:>6.1f}% {l0[0]:>8.3f} -> {l0[-1]:.3f}")
    for name, (tree, freeze) in variants.items():
        n = sum(x.size for x in jax.tree.leaves(tree))
        t0 = time.perf_counter()
        _, losses = finetune(m, tree, data, steps=3, freeze=freeze)
        dt = (time.perf_counter() - t0) / 3
        print(f"{name:18s} {m.layer_count(tree):>6d} {n:>10,d} "
              f"{100 * (n / n0 - 1):>6.1f}% {losses[0]:>8.3f} -> "
              f"{losses[-1]:.3f} {dt:>8.2f}s")


if __name__ == "__main__":
    main()

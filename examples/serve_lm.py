"""Serve an LRD-compressed LM with continuous batching.

    PYTHONPATH=src python examples/serve_lm.py [--kv-layout {slot,paged}]

``--kv-layout paged`` serves from the paged KV pool (fixed-size blocks
behind per-slot block tables + a radix prefix cache): the two requests
below that share a prompt prefix store that prefix's KV blocks once.
"""
import argparse
import dataclasses

import jax

from repro.configs import registry
from repro.configs.base import LRDConfig, ParallelConfig, RunConfig
from repro.core.surgery import decompose_model
from repro.models.api import get_model
from repro.serve.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--kv-layout", choices=["slot", "paged"],
                    default="slot",
                    help="KV pool memory layout (paged = block tables + "
                         "copy-on-write prefix sharing)")
    args = ap.parse_args()

    cfg = registry.get("llama3.2-1b").smoke
    model = get_model(cfg)
    params, axes = model.init(jax.random.PRNGKey(0))

    # compress with the paper's technique before serving
    lrd = LRDConfig(enabled=True, compression=2.0, rank_mode="aligned",
                    rank_align=32, min_dim=48)
    params, _, report = decompose_model(params, axes, lrd)
    print(f"serving a {report.summary()['param_ratio']:.0%}-size model")

    run = RunConfig(model=cfg, lrd=lrd, parallel=ParallelConfig())
    eng = ServeEngine(run, params, slots=4, max_seq=128,
                      kv_layout=args.kv_layout)

    shared = list(range(1, 20))   # > one KV block: paged requests share it
    prompts = [shared + [30], shared + [31, 32], [6, 7, 8, 9], [10],
               [11, 12], [13, 14, 15]]
    reqs = [Request(uid=i, prompt=p, max_new_tokens=16,
                    temperature=0.0 if i % 2 == 0 else 0.8)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.add_request(r)
    eng.run_until_done()
    for r in reqs:
        print(f"req {r.uid}: prompt={r.prompt} -> {r.output}")
    print("throughput:", eng.throughput())
    if args.kv_layout == "paged":
        print("prefix cache:", eng.pool.prefix_stats())


if __name__ == "__main__":
    main()

"""Serve an LRD-compressed LM with continuous batching.

    PYTHONPATH=src python examples/serve_lm.py [--kv-layout {slot,paged}]
        [--replicas N] [--priority {interactive,batch}]

``--kv-layout paged`` serves from the paged KV pool (fixed-size blocks
behind per-slot block tables + a radix prefix cache): the two requests
below that share a prompt prefix store that prefix's KV blocks once.

``--replicas N`` (N > 1) serves through the multi-replica
:class:`repro.serve.router.ServeRouter` instead of a single engine:
least-KV-pressure routing, per-priority-class queues, and SLO-aware
batch admission.  ``--priority batch`` tags every demo request as
batch-class (default alternates interactive/batch so the per-class
stats have both populations).

``--deadline-s`` attaches a wall-clock deadline to every request —
requests that cannot finish in time end with status
``deadline_exceeded`` instead of blocking the batch.  ``--inject``
turns on the seeded chaos injector (allocation failures + NaN logits)
to show the lifecycle guards in action: every request still lands an
explicit terminal status and the pool drains to zero.
"""
import argparse
import dataclasses

import jax

from repro.configs import registry
from repro.configs.base import LRDConfig, ParallelConfig, RunConfig
from repro.core.surgery import decompose_model
from repro.models.api import get_model
from repro.serve.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--kv-layout", choices=["slot", "paged"],
                    default="slot",
                    help="KV pool memory layout (paged = block tables + "
                         "copy-on-write prefix sharing)")
    ap.add_argument("--deadline-s", type=float, default=None,
                    help="per-request wall-clock deadline; expired "
                         "requests end with status=deadline_exceeded")
    ap.add_argument("--inject", action="store_true",
                    help="enable the seeded fault injector (allocation "
                         "failures + NaN logits) to demo the lifecycle "
                         "guards and the numerical watchdog")
    ap.add_argument("--replicas", type=int, default=1,
                    help="N > 1 serves through the data-parallel "
                         "ServeRouter (least-KV-pressure routing, "
                         "priority classes, SLO-aware admission)")
    ap.add_argument("--priority", choices=["interactive", "batch"],
                    default=None,
                    help="priority class for every demo request "
                         "(default: alternate between the two classes)")
    args = ap.parse_args()

    cfg = registry.get("llama3.2-1b").smoke
    model = get_model(cfg)
    params, axes = model.init(jax.random.PRNGKey(0))

    # compress with the paper's technique before serving
    lrd = LRDConfig(enabled=True, compression=2.0, rank_mode="aligned",
                    rank_align=32, min_dim=48)
    params, _, report = decompose_model(params, axes, lrd)
    print(f"serving a {report.summary()['param_ratio']:.0%}-size model")

    run = RunConfig(model=cfg, lrd=lrd, parallel=ParallelConfig())
    faults = None
    if args.inject:
        from repro.serve.faults import FaultInjector
        faults = FaultInjector(
            seed=7,
            rates={"pool_alloc": 0.1, "nan_logits": 0.05},
            params={"nan_logits": {"seg": "decode", "slot": 0}},
            max_fires={"pool_alloc": 3, "nan_logits": 1})
    if args.replicas > 1:
        from repro.serve.router import ServeRouter
        eng = ServeRouter(run, params, replicas=args.replicas, slots=4,
                          max_seq=128, kv_layout=args.kv_layout,
                          faults=faults)
    else:
        eng = ServeEngine(run, params, slots=4, max_seq=128,
                          kv_layout=args.kv_layout, faults=faults)

    shared = list(range(1, 20))   # > one KV block: paged requests share it
    prompts = [shared + [30], shared + [31, 32], [6, 7, 8, 9], [10],
               [11, 12], [13, 14, 15]]
    classes = ["interactive", "batch"]
    reqs = [Request(uid=i, prompt=p, max_new_tokens=16,
                    temperature=0.0 if i % 2 == 0 else 0.8,
                    deadline_s=args.deadline_s,
                    priority=args.priority or classes[i % 2])
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.add_request(r)
    eng.run_until_done()
    for r in reqs:
        print(f"req {r.uid}: status={r.status} class={r.priority} "
              f"prompt={r.prompt} -> {r.output}")
    print("throughput:", eng.throughput())
    if args.replicas > 1:
        for pri in classes:
            print(f"class {pri}:", eng.class_stats(pri))
    if args.inject:
        if args.replicas > 1:
            for rep in eng.replicas:
                print(f"fault report (replica {rep.index}):",
                      rep.engine.faults.report())
        else:
            print("fault report:", eng.faults.report())
    if args.kv_layout == "paged":
        if args.replicas > 1:
            for rep in eng.replicas:
                print(f"prefix cache (replica {rep.index}):",
                      rep.engine.pool.prefix_stats())
        else:
            print("prefix cache:", eng.pool.prefix_stats())


if __name__ == "__main__":
    main()

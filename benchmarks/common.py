"""Shared benchmark utilities: timing, model stats, CSV emission.

``percentiles`` is re-exported from :mod:`repro.serve.metrics` — the
one p50/p99 implementation shared by the serve engine's per-class
stats, the router's SLO tracker, and every bench sweep that reports
tail latency.
"""
from __future__ import annotations

import functools
import socket
import subprocess
import time
from typing import Callable

import jax
import jax.numpy as jnp

from repro.serve.metrics import percentiles  # noqa: F401 — re-export


@functools.lru_cache(maxsize=1)
def run_stamp() -> dict:
    """Provenance stamp merged into every bench record: the repo's git
    revision (``<sha>[-dirty]``, or "unknown" outside a checkout) and
    the host name — trajectory rows from different machines or
    different commits must never be compared as one series."""
    try:
        rev = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"], capture_output=True,
            text=True, timeout=10, check=True).stdout.strip()
        dirty = subprocess.run(
            ["git", "status", "--porcelain"], capture_output=True,
            text=True, timeout=10, check=True).stdout.strip()
        if dirty:
            rev += "-dirty"
    except Exception:
        rev = "unknown"
    try:
        host = socket.gethostname()
    except Exception:
        host = "unknown"
    return {"git_rev": rev, "hostname": host}


def time_jit(fn: Callable, *args, iters: int = 5, warmup: int = 2) -> float:
    """Median wall seconds per call of a jit'd fn on the current backend."""
    f = jax.jit(fn)
    for _ in range(warmup):
        jax.block_until_ready(f(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(f(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def param_count(tree) -> int:
    """Stored model parameters.  ``*_scale`` and ``*_idx`` leaves
    (repro/quant) are quantization / 2:4-packing metadata, not weights —
    counting them skews the compression ratios reported for compressed
    trees.  Packed ``*_sp`` values count at their stored (kept) size."""
    from repro.quant import IDX_SUFFIX, SCALE_SUFFIX
    total = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        last = str(getattr(path[-1], "key", path[-1])) if path else ""
        if last.endswith(SCALE_SUFFIX) or last.endswith(IDX_SUFFIX):
            continue
        total += int(leaf.size)
    return total


def fwd_flops_resnet(params, img_hw: int) -> float:
    """Analytic forward FLOPs of a (possibly decomposed) ResNet tree by
    walking conv/fc subtrees with their spatial sizes."""
    # spatial schedule of bottleneck resnets at input img_hw
    # stem /2, pool /2, stages at /4 /8 /16 /32
    flops = [0.0]

    def conv_flops(p, hw, stride):
        out_hw = hw // stride
        m = out_hw * out_hw
        if "w" in p:
            kh, kw, c, s = p["w"].shape
            flops[0] += 2.0 * m * kh * kw * c * s
        elif "w0" in p:
            c, r = p["w0"].shape[-2:]
            s = p["w1"].shape[-1]
            flops[0] += 2.0 * m * r * (c + s)
        elif "tucker_u" in p:
            c, r1 = p["tucker_u"].shape
            kh, kw, _, r2 = p["core"].shape
            s = p["tucker_v"].shape[-1]
            flops[0] += 2.0 * m * (c * r1 + kh * kw * r1 * r2 + r2 * s)
        else:  # branched
            n, c, r1 = p["u"].shape
            _, kh, kw, _, r2 = p["core"].shape
            s = p["v"].shape[-1]
            flops[0] += 2.0 * m * n * (c * r1 + kh * kw * r1 * r2 + r2 * s)
        return out_hw

    hw = conv_flops(params["stem"], img_hw, 2)
    hw //= 2  # maxpool
    si = 0
    while f"stage{si}" in params:
        stage = params[f"stage{si}"]
        stride = 1 if si == 0 else 2
        bi = 0
        while f"block{bi}" in stage:
            blk = stage[f"block{bi}"]
            s = stride if bi == 0 else 1
            conv_flops(blk["conv1"], hw, 1)
            hw2 = conv_flops(blk["conv2"], hw, s)
            conv_flops(blk["conv3"], hw2, 1)
            if "downsample" in blk:
                conv_flops(blk["downsample"], hw, s)
            hw = hw2
            bi += 1
        si += 1
    fc = params["fc"]
    if "w" in fc:
        c, s = fc["w"].shape
        flops[0] += 2.0 * c * s
    else:
        c, r = fc["w0"].shape
        s = fc["w1"].shape[-1]
        flops[0] += 2.0 * r * (c + s)
    return flops[0]


class Csv:
    def __init__(self, header: list[str]):
        self.header = header
        self.rows: list[list] = []

    def row(self, *vals):
        self.rows.append(list(vals))

    def dump(self, title: str) -> str:
        out = [f"# {title}", ",".join(self.header)]
        for r in self.rows:
            out.append(",".join(str(v) for v in r))
        return "\n".join(out)

"""Paper Table 1: ResNet-50/101/152 layers / params / FLOPs / fps,
original vs vanilla LRD (2x, ratio ranks).

Full-size params + FLOPs are exact (match the paper's 25.56/44.55/60.19 M
and 8.23/15.68/23.14 GFLOPs columns at 224x224 — the paper reports
fwd+bwd-ish "FLOPs (B)", we report forward MACs*2 at 224 and note the
convention).  Throughput is measured on the *current backend* at a reduced
image size (the paper's fps column is PyTorch-on-GPU; the claim we
reproduce is the *relationship*: ~2x params/FLOPs reduction but only
single-digit % throughput gain for vanilla LRD).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import Csv, fwd_flops_resnet, param_count, time_jit
from repro.configs import registry
from repro.configs.base import LRDConfig
from repro.core.surgery import decompose_model
from repro.models.resnet import ResNetModel

MEASURE_HW = 64
MEASURE_BATCH = 4


def run(fast: bool = True) -> str:
    csv = Csv(["model", "variant", "layers", "params_M", "fwd_gflops_224",
               "fps_measured", "speedup_vs_dense"])
    archs = ["resnet50"] if fast else ["resnet50", "resnet101", "resnet152"]
    paper = {"resnet50": (25.56, 8.23 / 2), "resnet101": (44.55, 15.68 / 2),
             "resnet152": (60.19, 23.14 / 2)}
    for arch in archs:
        cfg = registry.get(arch).full
        m = ResNetModel(cfg)
        params, axes = m.init(jax.random.PRNGKey(0))
        lrd = LRDConfig(enabled=True, compression=2.0, rank_mode="ratio",
                        min_dim=8)
        dec, _, _ = decompose_model(params, axes, lrd)

        import dataclasses
        mcfg = dataclasses.replace(cfg, img_size=MEASURE_HW)
        mm = ResNetModel(mcfg)
        x = jax.random.normal(jax.random.PRNGKey(1),
                              (MEASURE_BATCH, MEASURE_HW, MEASURE_HW, 3))
        t_dense = time_jit(mm.forward, params, x)
        t_lrd = time_jit(mm.forward, dec, x)

        for name, tree, t in (("original", params, t_dense),
                              ("vanilla_lrd", dec, t_lrd)):
            csv.row(arch, name, m.layer_count(tree),
                    round(param_count(tree) / 1e6, 2),
                    round(fwd_flops_resnet(tree, 224) / 1e9, 2),
                    round(MEASURE_BATCH / t, 1),
                    round(t_dense / t, 3))
    title = ("Table 1 repro (paper: params 25.56/44.55/60.19M; "
             "fwd GFLOPs ~4.1/7.8/11.6; vanilla-LRD speedup +6.8/10.5/13.1%)")
    return csv.dump(title)


if __name__ == "__main__":
    print(run(fast=False))

"""Quantized-factor benchmark: the int8 low-rank serving path vs bf16.

Decode is weight-streaming-bound, so the number that matters is *bytes
moved per token* by the weight stream; the fused quantized kernel
(`repro/kernels/lowrank_matmul_q.py`) moves 1-byte factors instead of
2-byte.  Reported per geometry:

* round-trip quantization error of the factor pair (must be ~1e-2),
* fused-q kernel max error vs the dequant oracle (interpret mode; ~0),
* weight bytes per token: dense bf16 vs low-rank bf16 vs low-rank int8,
* roofline TPU decode time of the weight stream (bytes / HBM bandwidth),
* measured CPU time of the jnp dequant pair vs the bf16 pair (the
  production fallback path — dequant costs compute on CPU; the win is
  the bandwidth column, realized on TPU),

plus end-to-end ``ServeEngine`` tokens/s, bf16 vs ``quantize="int8"``,
on the smoke llama config.

    PYTHONPATH=src python -m benchmarks.bench_quant [--dry-run]
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from benchmarks.common import Csv, time_jit
from repro.analysis.hw_specs import TPU_V5E
from repro.kernels import ops, ref
from repro.quant import quantize_array, relative_error, tree_bytes


def _weight_bytes(c: int, r: int, s: int) -> tuple[int, int, int]:
    """(dense bf16, lowrank bf16, lowrank int8+scales) bytes per token."""
    dense = c * s * 2
    lr_bf16 = (c * r + r * s) * 2
    lr_int8 = (c * r + r * s) * 1 + (r + s) * 4
    return dense, lr_bf16, lr_int8


def _serve_tokens_per_s(quantize: str | None) -> tuple[float, int]:
    import dataclasses

    from repro.configs import registry
    from repro.configs.base import LRDConfig, ParallelConfig, RunConfig
    from repro.core.surgery import decompose_model
    from repro.models.api import get_model
    from repro.serve.engine import Request, ServeEngine

    cfg = registry.get("llama3.2-1b").smoke
    lrd = LRDConfig(enabled=True, rank_mode="ratio", min_dim=32)
    run = RunConfig(model=cfg, lrd=lrd, parallel=ParallelConfig())
    m = get_model(cfg)
    params, axes = m.init(jax.random.PRNGKey(0))
    p2, _, _ = decompose_model(params, axes, lrd)
    eng = ServeEngine(run, p2, slots=2, max_seq=64, quantize=quantize)
    for i in range(4):
        eng.add_request(Request(uid=i, prompt=[i + 1, 2, 3],
                                max_new_tokens=8))
    done = eng.run_until_done()
    assert len(done) == 4 and all(len(r.output) == 8 for r in done)
    return eng.throughput()["tokens_per_s"], tree_bytes(eng.params)


def run(fast: bool = True, dry_run: bool = False) -> str:
    csv = Csv(["c", "r", "s", "q_rel_err", "kernel_max_err",
               "bytes_dense_bf16", "bytes_lr_bf16", "bytes_lr_int8",
               "byte_gain_vs_lr", "tpu_decode_us_bf16", "tpu_decode_us_int8",
               "cpu_pair_us", "cpu_dequant_us"])
    shapes = [(512, 128, 512), (2048, 256, 2048), (2048, 512, 8192)]
    if dry_run:
        shapes = shapes[:1]
    elif fast:
        shapes = shapes[:2]
    for c, r, s in shapes:
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        w0 = jax.random.normal(ks[0], (c, r)) * 0.05
        w1 = jax.random.normal(ks[1], (r, s)) * 0.05
        w0q, w0s = quantize_array(w0)
        w1q, w1s = quantize_array(w1)
        q_err = max(relative_error(w0), relative_error(w1))
        m = 8 if dry_run else 64
        x = (jax.random.normal(ks[2], (m, c)) * 0.1).astype(jnp.bfloat16)
        got = ops.lowrank_matmul_q(x, w0q, w0s, w1q, w1s, force_kernel=True)
        want = ref.lowrank_matmul_q_ref(x, w0q, w0s, w1q, w1s)
        k_err = float(jnp.abs(got.astype(jnp.float32)
                              - want.astype(jnp.float32)).max())
        b_dense, b_bf16, b_int8 = _weight_bytes(c, r, s)
        t_bf16 = b_bf16 / TPU_V5E.hbm_bandwidth * 1e6
        t_int8 = b_int8 / TPU_V5E.hbm_bandwidth * 1e6
        w0h, w1h = w0.astype(jnp.bfloat16), w1.astype(jnp.bfloat16)
        t_pair = time_jit(lambda a: (a @ w0h) @ w1h, x, iters=3) * 1e6
        t_dq = time_jit(
            lambda a: ops.lowrank_matmul_q(a, w0q, w0s, w1q, w1s),
            x, iters=3) * 1e6
        csv.row(c, r, s, f"{q_err:.1e}", f"{k_err:.1e}",
                b_dense, b_bf16, b_int8, round(b_bf16 / b_int8, 2),
                round(t_bf16, 2), round(t_int8, 2),
                round(t_pair, 1), round(t_dq, 1))
    out = csv.dump("quant: int8 factor serving path (interpret-validated; "
                   "TPU gain = halved weight stream on the decode "
                   "hot path)")
    tok_bf16, bytes_bf16 = _serve_tokens_per_s(None)
    tok_int8, bytes_int8 = _serve_tokens_per_s("int8")
    out += (f"\n# serve (llama3.2-1b smoke, CPU): "
            f"bf16 {tok_bf16:.1f} tok/s ({bytes_bf16} param bytes) | "
            f"int8 {tok_int8:.1f} tok/s ({bytes_int8} param bytes)")
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--dry-run", action="store_true",
                    help="tiny shapes; CPU interpret smoke for CI")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    print(run(fast=not args.full, dry_run=args.dry_run))

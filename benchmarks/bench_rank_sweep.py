"""Paper Fig. 2 + Table 2: layer throughput vs decomposition rank.

Two instruments:
* the TPU cost model (the staircase: throughput cliffs at every 128-lane
  MXU boundary — the paper saw 15% between ranks 257 and 256 on GPU),
* measured wall-clock of the jit'd decomposed layer on the current
  backend (the paper's method verbatim; CPU shows its own, shallower,
  SIMD-width staircase).

Also emits the Table-2-style rank decisions (2x ratio rank vs Algorithm-1
optimized rank vs ORG) for a selection of layer geometries.
"""
from __future__ import annotations

from benchmarks.common import Csv
from repro.core import cost_model as cm
from repro.core import rank_selection as rs


def run(fast: bool = True) -> str:
    out = []
    # --- Fig. 2: throughput vs rank around a tile boundary -------------
    # geometry chosen compute-bound on the MXU (a memory-bound layer shows
    # no cliff — rank padding only burns FLOPs, not bandwidth)
    csv = Csv(["rank", "tpu_model_time_us", "tpu_model_throughput_rel"])
    m, c, s = 4096, 2048, 8192
    base = None
    ranks = list(range(240, 272)) if fast else list(range(128, 520))
    for r in ranks:
        t = cm.lowrank_layer_time(m, c, s, r) * 1e6
        base = base or t
        csv.row(r, round(t, 3), round(base / t, 4))
    t256 = cm.lowrank_layer_time(m, c, s, 256)
    t257 = cm.lowrank_layer_time(m, c, s, 257)
    out.append(csv.dump(
        f"Fig 2 repro: TPU cost-model staircase, [{c},{s}] FC layer at "
        f"M={m}; cliff 256->257 = {100 * (t257 / t256 - 1):.1f}% time "
        f"(paper measured 15% on GPU — the 128-wide MXU amplifies it)"))

    # --- Table 2: rank decisions per layer geometry --------------------
    csv2 = Csv(["layer", "c_in", "c_out", "ratio_rank_2x",
                "algorithm1_rank", "aligned_rank"])
    geoms = [("early.conv1", 64, 64), ("early.conv3", 64, 256),
             ("late.conv1", 2048, 512), ("late.conv2", 512, 512),
             ("late.conv3", 512, 2048), ("fc", 2048, 1001),
             ("lm.qproj", 2048, 2048), ("lm.ffn_up", 2048, 8192),
             ("lm.unembed", 2048, 128256)]
    for name, c_in, c_out in geoms:
        r0 = rs.select_rank(c_in, c_out, compression=2.0, mode="ratio")
        r1 = rs.select_rank(c_in, c_out, compression=2.0, mode="search",
                            m_tokens=4096)
        r2 = rs.select_rank(c_in, c_out, compression=2.0, mode="aligned")
        fmt = lambda r: "ORG" if r == rs.ORG else r
        csv2.row(name, c_in, c_out, fmt(r0), fmt(r1), fmt(r2))
    out.append(csv2.dump(
        "Table 2 repro: rank decisions (paper: small early layers -> ORG; "
        "late layers -> slightly reduced ranks; ours snap to MXU tiles)"))
    return "\n\n".join(out)


if __name__ == "__main__":
    print(run(fast=False))

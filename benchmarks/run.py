"""Benchmark driver: one benchmark per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full]

--full uses the paper-size models (slow on CPU); the default uses reduced
sizes with identical structure (params/FLOPs columns stay exact full-size
numbers where analytic).
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma list: table1,table3,rank,branch,lm,kernels,"
                         "quant,branched_quant,serve_decode,serve_mla,"
                         "serve_sched,serve_paged,serve_faults,"
                         "serve_prefill,serve_router,frontier")
    ap.add_argument("--list", action="store_true",
                    help="print registered benchmark names and exit")
    args = ap.parse_args()
    fast = not args.full

    from benchmarks import (bench_branched_quant, bench_branching,
                            bench_frontier, bench_kernels, bench_quant,
                            bench_rank_sweep, bench_serve_decode,
                            bench_table1, bench_table3,
                            bench_transformer_lrd)
    benches = {
        "table1": bench_table1.run,
        "table3": bench_table3.run,
        "rank": bench_rank_sweep.run,
        "branch": bench_branching.run,
        "lm": bench_transformer_lrd.run,
        "kernels": bench_kernels.run,
        "quant": bench_quant.run,
        "branched_quant": bench_branched_quant.run,
        "serve_decode": bench_serve_decode.run,
        "serve_mla": bench_serve_decode.run_mla,
        "serve_sched": bench_serve_decode.run_sched,
        "serve_paged": bench_serve_decode.run_paged,
        "serve_faults": bench_serve_decode.run_faults,
        "serve_prefill": bench_serve_decode.run_prefill,
        "serve_router": bench_serve_decode.run_router,
        "frontier": bench_frontier.run,
    }
    if args.list:
        print("\n".join(benches))
        return
    only = set(args.only.split(",")) if args.only else set(benches)
    failures = 0
    for name, fn in benches.items():
        if name not in only:
            continue
        t0 = time.time()
        print(f"\n================ {name} ================", flush=True)
        try:
            print(fn(fast=fast))
        except Exception as e:  # noqa: BLE001 — report and continue
            failures += 1
            print(f"[bench {name} FAILED] {e!r}")
        print(f"[{name}: {time.time() - t0:.1f}s]")
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()

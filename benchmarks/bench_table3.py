"""Paper Table 3: the four acceleration techniques x ResNets.

Rows per model: vanilla LRD / optimized ranks / layer freezing / layer
merging / layer branching.  Columns: layer count, Δparams %, ΔFLOPs %,
train and inference speedup (measured on the current backend at reduced
image size + the TPU cost-model prediction at full size).

Freezing speeds TRAINING only (backward shrinks) — inference equals
vanilla, exactly as the paper states.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from benchmarks.common import Csv, fwd_flops_resnet, param_count, time_jit
from repro.configs import registry
from repro.configs.base import LRDConfig
from repro.core.surgery import decompose_model
from repro.models.resnet import ResNetModel, merge_bottleneck

MEASURE_HW = 64
MEASURE_BATCH = 4


def _variants(params, axes):
    """name -> (tree, freeze_flag) per paper Table 3 rows."""
    out = {}
    vanilla, _, _ = decompose_model(params, axes, LRDConfig(
        enabled=True, compression=2.0, rank_mode="ratio", min_dim=8))
    out["vanilla_lrd"] = (vanilla, False)
    opt, _, _ = decompose_model(params, axes, LRDConfig(
        enabled=True, compression=2.0, rank_mode="search", min_dim=8))
    out["optimized_ranks"] = (opt, False)
    out["layer_freezing"] = (vanilla, True)
    core_only, _, _ = decompose_model(params, axes, LRDConfig(
        enabled=True, compression=2.0, rank_mode="ratio", min_dim=8,
        targets=("conv",)))
    out["layer_merging"] = (merge_bottleneck(core_only), False)
    # branching targets the kxk Tucker cores (the paper's Fig. 4 case)
    branched, _, _ = decompose_model(params, axes, LRDConfig(
        enabled=True, compression=1.0001, rank_mode="ratio", min_dim=8,
        branches=4, targets=("conv",)))
    out["layer_branching"] = (branched, False)
    return out


def run(fast: bool = True) -> str:
    csv = Csv(["model", "variant", "layers", "d_params_pct", "d_flops_pct",
               "train_speedup", "infer_speedup"])
    archs = ["resnet50"] if fast else ["resnet50", "resnet101", "resnet152"]
    for arch in archs:
        cfg = registry.get(arch).full
        m = ResNetModel(cfg)
        params, axes = m.init(jax.random.PRNGKey(0))
        base_p = param_count(params)
        base_f = fwd_flops_resnet(params, 224)

        mcfg = dataclasses.replace(cfg, img_size=MEASURE_HW)
        mm = ResNetModel(mcfg)
        x = jax.random.normal(jax.random.PRNGKey(1),
                              (MEASURE_BATCH, MEASURE_HW, MEASURE_HW, 3))
        y = jax.random.randint(jax.random.PRNGKey(2), (MEASURE_BATCH,), 0,
                               cfg.num_classes)

        def train_time(tree, freeze):
            def step(p):
                def loss(p):
                    return mm.loss(p, {"images": x, "labels": y},
                                   freeze_factors=freeze)[0]
                return jax.grad(loss)(p)
            return time_jit(step, tree, iters=3, warmup=1)

        t_inf_dense = time_jit(mm.forward, params, x)
        t_tr_dense = train_time(params, False)
        csv.row(arch, "original", m.layer_count(params), 0.0, 0.0, 1.0, 1.0)

        for name, (tree, freeze) in _variants(params, axes).items():
            t_inf = time_jit(mm.forward, tree, x)
            t_tr = train_time(tree, freeze)
            csv.row(arch, name, m.layer_count(tree),
                    round(100 * (param_count(tree) / base_p - 1), 2),
                    round(100 * (fwd_flops_resnet(tree, 224) / base_f - 1),
                          2),
                    round(t_tr_dense / t_tr, 3),
                    round(t_inf_dense / t_inf, 3))
    return csv.dump(
        "Table 3 repro (paper: merging strongest: +40-56%% both; freezing "
        "train-only; branching compresses at equal rank)")


if __name__ == "__main__":
    print(run(fast=False))

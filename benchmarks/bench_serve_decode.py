"""Serve-decode benchmarks: KV quantization + admission scheduling +
paged KV pooling + fault-injected lifecycle chaos + int8-activation
prefill + the multi-replica router.

Seven sweeps share this module (select with
``--sweep {all,kv,sched,mla,paged,faults,prefill,router}``):

**kv** — f32 KV pool vs int8-quantized KV pool.

Decode is KV-streaming-bound: every step reads the *entire* cache pool
``(slots, S_max, KV_heads, head_dim)`` per layer (invalid positions are
masked, not skipped), so the number that matters is **KV bytes per
step** — ``repro.quant.kv`` stores the pool as int8 values + f32
per-(slot, head, channel) scale rows, ~4x fewer bytes than f32.
Reported per ``(slots, S_max)`` sweep point:

* KV bytes/step of both engines (from the engine's own plan-summary
  accounting) and their ratio (the acceptance bar is >= ~3.5x),
* roofline TPU time of the KV stream (bytes / HBM bandwidth) — the win
  `cost_model.plan_layer_time(kv_bytes=...)` predicts,
* measured end-to-end CPU tokens/s of both engines (on CPU the fused
  kernel is bypassed for the jnp dequant oracle; the bandwidth column
  is the TPU win),

**mla** — f32 vs int8 *latent* cache on an MLA stack (cache families
``mla_latent`` / ``mla_latent_int8`` of ``repro.layers.cache``).  The
latent is already the rank-compressed K/V factor; quantizing it shrinks
decode's dominant byte stream again on top of the rank reduction.  Same
columns as **kv** (bytes/step from the engine's plan-derived
accounting), served through chunked continuous admission — the MLA
chunk path this PR enabled.

**sched** — continuous (chunked-prefill token-budget scheduler) vs
blocking admission under *mixed load*: short live decode streams with a
long prompt queued behind them.  Blocking admission runs one whole
prefill inside the step that admits the long prompt, stalling every
live stream for that step; the scheduler interleaves ``prefill_chunk``-
token segments with decode, so live streams keep producing a token
every step.  Reported per sweep point and mode: p50/p99/max
*inter-token latency* of the short streams (the head-of-line metric),
mean TTFT, end-to-end tokens/s, and the cross-mode greedy
``token_match``.  A **saturated** row per sweep point (all slots
decoding equal-length streams, no admission at all) gives the decode
ceiling the mixed rows' tok/s should be read against — the mixed-load
number is admission-bubble-dominated by construction.

**paged** — the paged block pool (``kv_layout="paged"``:
``repro.serve.paging`` block tables + radix prefix cache) vs the slot
pool under a shared-prefix load, f32 and int8: bytes/step, radix
hit-rate over the shareable prefix blocks, tokens/s, and slot==paged
greedy agreement.

**faults** — the hardening tier under chaos: a seeded
:class:`repro.serve.faults.FaultInjector` (allocation failures, NaN
logits, corrupted int8 scales, radix blind spots) plus mid-flight
cancels, instant deadlines, and a KV byte budget tight enough to drive
preemption and the load shedder.  Per layout the row records the
terminal status mix (finished / cancelled / deadline_exceeded /
dropped / failed), quarantine + preemption + admission-failure counts,
shed-step and degradation engage/recover totals, and the p99
inter-token latency of the surviving streams — the latency cost of
running degraded.  The run itself doubles as a smoke check: every
request must land a terminal status and the pool must drain to zero
bytes.

**prefill** — f32 activations vs fused dynamic per-token int8
activation quantization on a *decomposed + int8-weight* engine
(``quantize="int8"``, ``act_quantize="int8"``).  Prefill is
MXU-compute-bound, so the TPU win is the int8 x int8 issue rate; the
byte column reports the modelled activation HBM stream per prefill
token from :func:`repro.core.cost_model.plan_act_stream_bytes` — the
same accounting the roofline uses — whose qa rows shrink to int8
values + one f32 row scale (acceptance: >= 1.8x fewer bytes at equal
rank).  Measured CPU prefill tokens/s of both engines (interpret-mode
kernels; the rate column is the TPU story) and the greedy
``token_match`` of the int8-act stream against the f32-act engine.

**router** — the multi-replica serve tier
(:class:`repro.serve.router.ServeRouter`) under mixed-priority load at
saturation.  Three runs per sweep point at identical offered load:
SLO-aware 2-replica (least-KV-pressure routing, per-class queues,
batch held while the interactive tail lacks headroom; the SLO target
is calibrated to 1.3x the measured interactive-only p99 ITL),
priority-blind 2-replica (round-robin FIFO — the baseline), and
SLO-aware 1-replica (the scaling denominator).  Rows report per-class
p50/p99 ITL + p99 TTFT, batch and total tokens/s on the modeled
data-parallel wall clock (max per-replica step seconds per round),
per-replica KV pressure / shed steps / SLO breaches, and the greedy
``token_match`` across all three modes (routing must never change
tokens).  Acceptance: SLO-aware interactive p99 ITL >= 2x better than
blind, batch throughput within 20%, 2 replicas >= 1.7x the saturated
tokens/s of 1.

Every sweep appends to the ``BENCH_serve.json`` trajectory at the repo
root (stamped with ``git_rev`` + ``hostname`` via
:func:`benchmarks.common.run_stamp`) so successive PRs can track the
serve numbers.

    PYTHONPATH=src python -m benchmarks.bench_serve_decode \
        [--dry-run] \
        [--sweep {all,kv,sched,mla,paged,faults,prefill,router}]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time
from pathlib import Path

import jax

from benchmarks.common import Csv, percentiles
from repro.analysis.hw_specs import TPU_V5E

TRAJECTORY = Path(__file__).resolve().parent.parent / "BENCH_serve.json"


def _build(slots: int, max_seq: int, kv_quantize: str | None):
    from repro.configs import registry
    from repro.configs.base import ParallelConfig, RunConfig
    from repro.models.api import get_model
    from repro.serve.engine import ServeEngine

    # f32 model dtype so the baseline pool is genuinely f32 (the smoke
    # config's bf16 would halve the baseline and hide half the win).
    cfg = dataclasses.replace(registry.get("llama3.2-1b").smoke,
                              dtype="float32")
    run = RunConfig(model=cfg, parallel=ParallelConfig())
    m = get_model(cfg)
    params, _ = m.init(jax.random.PRNGKey(0))
    return ServeEngine(run, params, slots=slots, max_seq=max_seq,
                       kv_quantize=kv_quantize)


def _serve(eng, n_requests: int) -> tuple[float, list[list[int]]]:
    from repro.serve.engine import Request

    # Prompt lengths straddle two power-of-2 buckets on purpose.
    reqs = [Request(uid=i, prompt=[(i % 7) + 1] * (3 + (i % 8)),
                    max_new_tokens=8) for i in range(n_requests)]
    for r in reqs:
        eng.add_request(r)
    eng.run_until_done()
    assert all(r.done for r in reqs)
    return eng.throughput()["tokens_per_s"], [r.output for r in reqs]


def run(fast: bool = True, dry_run: bool = False) -> str:
    sweeps = [(2, 64), (4, 128), (4, 256), (8, 512)]
    if dry_run:
        sweeps = sweeps[:1]
    elif fast:
        sweeps = sweeps[:2]
    csv = Csv(["slots", "s_max", "kv_bytes_f32", "kv_bytes_int8",
               "byte_ratio", "tpu_kv_us_f32", "tpu_kv_us_int8",
               "cpu_tok_s_f32", "cpu_tok_s_int8", "token_match"])
    records = []
    for slots, s_max in sweeps:
        n_req = 2 * slots
        eng_f = _build(slots, s_max, None)
        tok_f, out_f = _serve(eng_f, n_req)
        eng_q = _build(slots, s_max, "int8")
        tok_q, out_q = _serve(eng_q, n_req)
        b_f = eng_f.plan_summary["kv_bytes_per_step"]
        b_q = eng_q.plan_summary["kv_bytes_per_step"]
        ratio = b_f / b_q
        us_f = b_f / TPU_V5E.hbm_bandwidth * 1e6
        us_q = b_q / TPU_V5E.hbm_bandwidth * 1e6
        # Greedy token agreement as a fraction: ~1e-2-relative KV quant
        # error can flip near-argmax ties on a random-init model, so a
        # strict bool would measure tie density, not quant quality.
        flat_f = [t for o in out_f for t in o]
        flat_q = [t for o in out_q for t in o]
        match = sum(a == b for a, b in zip(flat_f, flat_q)) / len(flat_f)
        csv.row(slots, s_max, b_f, b_q, round(ratio, 2),
                round(us_f, 3), round(us_q, 3),
                round(tok_f, 1), round(tok_q, 1), round(match, 3))
        records.append({"slots": slots, "s_max": s_max,
                        "kv_bytes_f32": b_f, "kv_bytes_int8": b_q,
                        "kv_byte_ratio": round(ratio, 3),
                        "cpu_tok_s_f32": round(tok_f, 2),
                        "cpu_tok_s_int8": round(tok_q, 2),
                        "token_match": round(match, 4)})
    out = csv.dump("serve decode: f32 vs int8 KV pool (bytes/step from the "
                   "engine's accounting; TPU win = the KV stream column)")
    worst = min(r["kv_byte_ratio"] for r in records)
    out += f"\n# worst-case KV byte ratio int8 vs f32: {worst:.2f}x"
    _append_trajectory({"bench": "serve_decode", "dry_run": dry_run,
                        "unix_time": int(time.time()), "rows": records})
    out += f"\n# trajectory appended to {TRAJECTORY.name}"
    return out


def _build_mla(slots: int, max_seq: int, kv_quantize: str | None):
    from repro.configs.base import ModelConfig, ParallelConfig, RunConfig
    from repro.models.api import get_model
    from repro.serve.engine import ServeEngine

    # Dense-family MLA stack (chunked continuous admission applies);
    # f32 so the baseline latent pool is genuinely full width.
    cfg = ModelConfig(
        name="mla-bench", family="dense", mla=True, num_layers=2,
        d_model=64, num_heads=4, num_kv_heads=4, d_ff=128, vocab_size=256,
        q_lora_rank=0, kv_lora_rank=32, qk_rope_dim=16, qk_nope_dim=32,
        v_head_dim=32, dtype="float32")
    run = RunConfig(model=cfg, parallel=ParallelConfig())
    m = get_model(cfg)
    params, _ = m.init(jax.random.PRNGKey(0))
    return ServeEngine(run, params, slots=slots, max_seq=max_seq,
                       kv_quantize=kv_quantize)


def run_mla(fast: bool = True, dry_run: bool = False) -> str:
    sweeps = [(2, 64), (4, 128), (4, 256)]
    if dry_run:
        sweeps = sweeps[:1]
    elif fast:
        sweeps = sweeps[:2]
    csv = Csv(["slots", "s_max", "latent_bytes_f32", "latent_bytes_int8",
               "byte_ratio", "tpu_kv_us_f32", "tpu_kv_us_int8",
               "cpu_tok_s_f32", "cpu_tok_s_int8", "token_match"])
    records = []
    for slots, s_max in sweeps:
        n_req = 2 * slots
        eng_f = _build_mla(slots, s_max, None)
        tok_f, out_f = _serve(eng_f, n_req)
        eng_q = _build_mla(slots, s_max, "int8")
        tok_q, out_q = _serve(eng_q, n_req)
        assert eng_q.plan_summary["kv_cache_family"] == "mla_latent_int8"
        b_f = eng_f.plan_summary["kv_bytes_per_step"]
        b_q = eng_q.plan_summary["kv_bytes_per_step"]
        ratio = b_f / b_q
        flat_f = [t for o in out_f for t in o]
        flat_q = [t for o in out_q for t in o]
        match = sum(a == b for a, b in zip(flat_f, flat_q)) / len(flat_f)
        csv.row(slots, s_max, b_f, b_q, round(ratio, 2),
                round(b_f / TPU_V5E.hbm_bandwidth * 1e6, 3),
                round(b_q / TPU_V5E.hbm_bandwidth * 1e6, 3),
                round(tok_f, 1), round(tok_q, 1), round(match, 3))
        records.append({"slots": slots, "s_max": s_max,
                        "latent_bytes_f32": b_f, "latent_bytes_int8": b_q,
                        "latent_byte_ratio": round(ratio, 3),
                        "cpu_tok_s_f32": round(tok_f, 2),
                        "cpu_tok_s_int8": round(tok_q, 2),
                        "token_match": round(match, 4)})
    out = csv.dump("serve decode, MLA stack: f32 vs int8 latent cache "
                   "(bytes/step from the CachePlan-derived accounting; "
                   "TPU win = the latent stream column)")
    worst = min(r["latent_byte_ratio"] for r in records)
    out += f"\n# worst-case latent byte ratio int8 vs f32: {worst:.2f}x"
    _append_trajectory({"bench": "serve_mla", "dry_run": dry_run,
                        "unix_time": int(time.time()), "rows": records})
    out += f"\n# trajectory appended to {TRAJECTORY.name}"
    return out


def _build_sched(slots: int, max_seq: int, admission: str, chunk: int):
    from repro.configs import registry
    from repro.configs.base import ParallelConfig, RunConfig
    from repro.models.api import get_model
    from repro.serve.engine import ServeEngine

    cfg = dataclasses.replace(registry.get("llama3.2-1b").smoke,
                              dtype="float32")
    run = RunConfig(model=cfg, parallel=ParallelConfig())
    m = get_model(cfg)
    params, _ = m.init(jax.random.PRNGKey(0))
    return ServeEngine(run, params, slots=slots, max_seq=max_seq,
                       admission=admission, prefill_chunk=chunk,
                       step_token_budget=slots + chunk)


def _mixed_load(eng, *, slots: int, long_len: int, short_new: int) -> dict:
    """Short streams decode live; a long prompt arrives behind them.

    A throwaway round with the same shapes runs first so every compiled
    step (decode, chunk buckets, whole-prefill bucket, insert, sample)
    is warm — the gap metrics measure scheduling, not jit compiles.
    """
    import numpy as np

    from repro.serve.engine import Request

    warm = [Request(uid=1000 + i, prompt=[2] * 4, max_new_tokens=3)
            for i in range(slots)]
    warm.append(Request(uid=1099, prompt=[3] * long_len, max_new_tokens=2))
    for r in warm:
        eng.add_request(r)
    eng.run_until_done()
    eng.stats.clear()

    gaps, ttfts, outputs = [], [], []
    reps = 3
    for rep in range(reps):
        shorts = [Request(uid=100 * rep + i, prompt=[(i % 7) + 1] * 4,
                          max_new_tokens=short_new + 4 * i)
                  for i in range(slots)]
        for r in shorts:
            eng.add_request(r)
        for _ in range(2):              # shorts reach steady decode
            eng.step()
        longr = Request(uid=100 * rep + 99,
                        prompt=[(i % 11) + 1 for i in range(long_len)],
                        max_new_tokens=8)
        eng.add_request(longr)
        eng.run_until_done()
        assert all(r.done for r in shorts + [longr])
        gaps.extend(np.diff(r.token_times) for r in shorts
                    if len(r.token_times) > 1)
        ttfts.extend(r.ttft for r in shorts + [longr])
        outputs.extend(r.output for r in shorts + [longr])
    gaps = np.concatenate(gaps)
    p50, p99 = percentiles(gaps, (50, 99))
    return {"p50_itl_ms": round(p50 * 1e3, 3),
            "p99_itl_ms": round(p99 * 1e3, 3),
            "max_itl_ms": round(float(gaps.max()) * 1e3, 3),
            "ttft_mean_ms": round(sum(ttfts) / len(ttfts) * 1e3, 3),
            "tokens_per_s": round(eng.throughput()["tokens_per_s"], 2),
            "slow_steps": eng.throughput()["slow_steps"],
            "outputs": outputs}


def _saturated_load(eng, *, slots: int, new_tokens: int = 48) -> dict:
    """All-slots-live steady decode: exactly ``slots`` equal-length
    streams admitted together, then pure decode until done — no
    admission bubbles, no prefill interleaving.  The mixed-load rows
    are admission-bubble-dominated (tok/s there measures the bubbles);
    this row is the pool's decode ceiling, making the gap legible."""
    import numpy as np

    from repro.serve.engine import Request

    warm = [Request(uid=2000 + i, prompt=[2] * 4, max_new_tokens=3)
            for i in range(slots)]
    for r in warm:
        eng.add_request(r)
    eng.run_until_done()
    eng.stats.clear()

    reqs = [Request(uid=3000 + i, prompt=[(i % 7) + 1] * 4,
                    max_new_tokens=new_tokens) for i in range(slots)]
    for r in reqs:
        eng.add_request(r)
    eng.run_until_done()
    assert all(r.done for r in reqs)
    gaps = np.concatenate([np.diff(r.token_times) for r in reqs
                           if len(r.token_times) > 1])
    th = eng.throughput()
    p50, p99 = percentiles(gaps, (50, 99))
    return {"p50_itl_ms": round(p50 * 1e3, 3),
            "p99_itl_ms": round(p99 * 1e3, 3),
            "max_itl_ms": round(float(gaps.max()) * 1e3, 3),
            "ttft_mean_ms": round(sum(r.ttft for r in reqs)
                                  / len(reqs) * 1e3, 3),
            "tokens_per_s": round(th["tokens_per_s"], 2),
            "slow_steps": th["slow_steps"],
            "outputs": [r.output for r in reqs]}


def _token_match(a: list[list[int]], b: list[list[int]]) -> float:
    """Position-wise greedy agreement fraction of two output sets."""
    fa = [t for o in a for t in o]
    fb = [t for o in b for t in o]
    n = min(len(fa), len(fb))
    return sum(x == y for x, y in zip(fa[:n], fb[:n])) / max(n, 1)


def run_sched(fast: bool = True, dry_run: bool = False) -> str:
    sweeps = [(4, 256, 192, 8, 32), (4, 512, 384, 16, 32)]
    if dry_run:
        sweeps = sweeps[:1]
    elif not fast:
        sweeps.append((8, 512, 384, 16, 48))
    csv = Csv(["load", "mode", "slots", "s_max", "long_len", "p50_itl_ms",
               "p99_itl_ms", "max_itl_ms", "ttft_mean_ms", "tok_s",
               "token_match"])
    records = []
    for slots, s_max, long_len, chunk, short_new in sweeps:
        for load, runner in (("mixed", _mixed_load),
                             ("saturated", _saturated_load)):
            by_mode = {}
            for mode in ("blocking", "continuous"):
                eng = _build_sched(slots, s_max, mode, chunk)
                if load == "mixed":
                    by_mode[mode] = runner(eng, slots=slots,
                                           long_len=long_len,
                                           short_new=short_new)
                else:
                    by_mode[mode] = runner(eng, slots=slots)
            # greedy token agreement across admission modes (chunked
            # prefill is exact, so this is 1.0 unless something broke)
            match = _token_match(by_mode["blocking"].pop("outputs"),
                                 by_mode["continuous"].pop("outputs"))
            for mode, r in by_mode.items():
                csv.row(load, mode, slots, s_max,
                        long_len if load == "mixed" else 0,
                        r["p50_itl_ms"], r["p99_itl_ms"], r["max_itl_ms"],
                        r["ttft_mean_ms"], r["tokens_per_s"],
                        round(match, 4))
                records.append({"load": load, "mode": mode, "slots": slots,
                                "s_max": s_max,
                                "long_len": long_len if load == "mixed"
                                else 0,
                                "prefill_chunk": chunk,
                                "token_match": round(match, 4), **r})
    out = csv.dump("serve admission: blocking vs continuous (chunked "
                   "prefill) under mixed load; p99 inter-token latency of "
                   "the live short streams is the head-of-line metric; "
                   "'saturated' rows are the all-slots-live decode ceiling")
    by_mode = {}
    for r in records:
        if r["load"] == "mixed":
            by_mode.setdefault(r["mode"], []).append(r["p99_itl_ms"])
    if len(by_mode) == 2:
        blk = max(by_mode["blocking"])
        cont = max(by_mode["continuous"])
        out += (f"\n# worst-case p99 inter-token latency: blocking "
                f"{blk:.1f}ms vs continuous {cont:.1f}ms "
                f"({blk / max(cont, 1e-9):.2f}x)")
    _append_trajectory({"bench": "serve_sched", "dry_run": dry_run,
                        "unix_time": int(time.time()), "rows": records})
    out += f"\n# trajectory appended to {TRAJECTORY.name}"
    return out


def _build_paged(slots: int, max_seq: int, kv_quantize: str | None,
                 kv_layout: str):
    from repro.configs import registry
    from repro.configs.base import ParallelConfig, RunConfig
    from repro.models.api import get_model
    from repro.serve.engine import ServeEngine

    cfg = dataclasses.replace(registry.get("llama3.2-1b").smoke,
                              dtype="float32")
    run = RunConfig(model=cfg, parallel=ParallelConfig())
    m = get_model(cfg)
    params, _ = m.init(jax.random.PRNGKey(0))
    return ServeEngine(run, params, slots=slots, max_seq=max_seq,
                       kv_quantize=kv_quantize, kv_layout=kv_layout)


def _shared_prefix_load(eng, *, slots: int, prefix_len: int,
                        n_requests: int) -> tuple[float, list[list[int]]]:
    """``n_requests`` prompts sharing a ``prefix_len``-token prefix
    (block-aligned), distinct suffixes.  More requests than slots, so
    the later waves admit against a radix cache already holding the
    prefix — the hit-rate rows below come from here."""
    from repro.serve.engine import Request

    prefix = [(i * 5 + 2) % 60 + 1 for i in range(prefix_len)]
    reqs = [Request(uid=i, prompt=prefix + [(i % 9) + 1] * (3 + i % 4),
                    max_new_tokens=8) for i in range(n_requests)]
    for r in reqs:
        eng.add_request(r)
    eng.run_until_done()
    assert all(r.done for r in reqs)
    return eng.throughput()["tokens_per_s"], [r.output for r in reqs]


def run_paged(fast: bool = True, dry_run: bool = False) -> str:
    sweeps = [(2, 64, 32), (4, 128, 64), (4, 256, 128)]
    if dry_run:
        sweeps = sweeps[:1]
    elif fast:
        sweeps = sweeps[:2]
    csv = Csv(["slots", "s_max", "prefix", "kv_bytes_slot",
               "kv_bytes_paged", "kv_bytes_paged_q", "hit_blocks",
               "hit_rate", "tok_s_slot", "tok_s_paged", "tok_s_paged_q",
               "token_match"])
    records = []
    for slots, s_max, prefix_len in sweeps:
        n_req = 2 * slots + 1
        eng_s = _build_paged(slots, s_max, None, "slot")
        tok_s, out_s = _shared_prefix_load(eng_s, slots=slots,
                                           prefix_len=prefix_len,
                                           n_requests=n_req)
        eng_p = _build_paged(slots, s_max, None, "paged")
        tok_p, out_p = _shared_prefix_load(eng_p, slots=slots,
                                           prefix_len=prefix_len,
                                           n_requests=n_req)
        eng_q = _build_paged(slots, s_max, "int8", "paged")
        tok_q, out_q = _shared_prefix_load(eng_q, slots=slots,
                                           prefix_len=prefix_len,
                                           n_requests=n_req)
        assert eng_p.plan_summary["kv_cache_family"] == "gqa_paged_f32"
        assert eng_q.plan_summary["kv_cache_family"] == "gqa_paged_int8"
        st = eng_p.pool.prefix_stats()
        # blocks attached instead of allocated, per radix-consulted
        # admission, normalized by the shareable prefix blocks
        bs = eng_p.pool.block_size
        shareable = (prefix_len // bs) * max(n_req - slots, 0)
        hit_rate = st["prefix_block_hits"] / max(shareable, 1)
        b_s = eng_s.plan_summary["kv_bytes_per_step"]
        b_p = eng_p.plan_summary["kv_bytes_per_step"]
        b_q = eng_q.plan_summary["kv_bytes_per_step"]
        match = _token_match(out_s, out_p)   # paged f32 == slot f32
        csv.row(slots, s_max, prefix_len, b_s, b_p, b_q,
                st["prefix_block_hits"], round(hit_rate, 3),
                round(tok_s, 1), round(tok_p, 1), round(tok_q, 1),
                round(match, 4))
        records.append({"slots": slots, "s_max": s_max,
                        "prefix_len": prefix_len,
                        "kv_bytes_slot": b_s, "kv_bytes_paged": b_p,
                        "kv_bytes_paged_int8": b_q,
                        "prefix_block_hits": st["prefix_block_hits"],
                        "prefix_queries": st["prefix_queries"],
                        "hit_rate": round(hit_rate, 4),
                        "cpu_tok_s_slot": round(tok_s, 2),
                        "cpu_tok_s_paged": round(tok_p, 2),
                        "cpu_tok_s_paged_int8": round(tok_q, 2),
                        "token_match": round(match, 4)})
    out = csv.dump("paged KV pool vs slot pool under a shared-prefix "
                   "load: bytes/step (paged adds block tables, int8 "
                   "shrinks values 4x), radix prefix hit-rate over the "
                   "shareable blocks, and slot==paged greedy agreement")
    _append_trajectory({"bench": "serve_paged", "dry_run": dry_run,
                        "unix_time": int(time.time()), "rows": records})
    out += f"\n# trajectory appended to {TRAJECTORY.name}"
    return out


def _chaos_load(eng, n_requests: int) -> dict:
    """Mixed load with the lifecycle events of the acceptance scenario:
    ~10% of requests get an already-expired deadline, ~10% are cancelled
    mid-flight, the rest ride out whatever the injector throws."""
    import numpy as np

    from repro.serve.engine import Request

    reqs = [Request(uid=i, prompt=[(i * 3) % 50 + 1] * (4 + (i * 5) % 17),
                    max_new_tokens=8, max_preemptions=4)
            for i in range(n_requests)]
    pending_cancel = set()
    for i, r in enumerate(reqs):
        if i % 10 == 3:
            r.deadline_s = 0.0
        elif i % 10 == 7:
            pending_cancel.add(r.uid)
        eng.add_request(r)
    for _ in range(2000):
        if not eng.scheduler.busy():
            break
        eng.step()
        for uid in list(pending_cancel):
            if reqs[uid].output or reqs[uid].done:
                eng.cancel(uid)           # mid-flight (first token seen)
                pending_cancel.discard(uid)
    eng.run_until_done()
    # the smoke contract the chaos suite enforces per step; the bench
    # re-asserts the endpoint so a regression fails loudly here too
    assert all(r.done and r.status for r in reqs)
    assert eng.pool.used_bytes() == 0
    eng.pool.check_integrity()
    gaps = [np.diff(r.token_times) for r in reqs
            if len(r.token_times) > 1]
    gaps = np.concatenate(gaps) if gaps else np.zeros(1)
    th = eng.throughput()
    return {"status_counts": th["status_counts"],
            "preemptions": th["preemptions"],
            "admit_failures": th["admit_failures"],
            "quarantined": th["quarantined"],
            "deadline_expired": th["deadline_expired"],
            "shed_steps": th.get("shed_steps", 0),
            "degradation_engages": th.get("degradation_engages", 0),
            "degradation_recoveries": th.get("degradation_recoveries", 0),
            "slow_steps": th["slow_steps"],
            "p99_itl_ms": round(percentiles(gaps, (99,))[0] * 1e3, 3),
            "tokens_per_s": round(th["tokens_per_s"], 2),
            "fault_report": eng.faults.report()}


def run_faults(fast: bool = True, dry_run: bool = False) -> str:
    from repro.configs import registry
    from repro.configs.base import ParallelConfig, RunConfig
    from repro.models.api import get_model
    from repro.serve.engine import ServeEngine
    from repro.serve.faults import FaultInjector
    from repro.serve.pool import KVPoolManager

    sweeps = [(2, 64, 10), (4, 128, 16)]
    if dry_run:
        sweeps = sweeps[:1]
    csv = Csv(["layout", "slots", "s_max", "n_req", "finished",
               "cancelled", "deadline", "dropped", "failed", "preempt",
               "quarantine", "shed_steps", "engages", "p99_itl_ms",
               "tok_s"])
    records = []
    cfg = dataclasses.replace(registry.get("llama3.2-1b").smoke,
                              dtype="float32")
    run_cfg = RunConfig(model=cfg, parallel=ParallelConfig())
    m = get_model(cfg)
    params, _ = m.init(jax.random.PRNGKey(0))
    for slots, s_max, n_req in sweeps:
        # a budget around half the pool keeps preemption + admission
        # pressure live for most of the run -> the shedder has real
        # work; derived from the plan accounting, not hand-tuned bytes
        budget = KVPoolManager(m, slots, s_max,
                               kv_quantize="int8").bytes_per_token \
            * (slots * s_max // 2)
        for layout in ("slot", "paged"):
            inj = FaultInjector(
                seed=11,
                rates={"pool_alloc": 0.03, "radix_match": 0.3,
                       "nan_logits": 0.02, "block_scale": 0.1},
                params={"nan_logits": {"seg": "decode", "slot": 0}},
                max_fires={"pool_alloc": 4, "nan_logits": 2,
                           "block_scale": 2})
            eng = ServeEngine(run_cfg, params, slots=slots,
                              max_seq=s_max, kv_quantize="int8",
                              kv_layout=layout, kv_byte_budget=budget,
                              faults=inj)
            r = _chaos_load(eng, n_req)
            sc = r["status_counts"]
            csv.row(layout, slots, s_max, n_req,
                    sc.get("finished", 0), sc.get("cancelled", 0),
                    sc.get("deadline_exceeded", 0), sc.get("dropped", 0),
                    sc.get("failed", 0), r["preemptions"],
                    r["quarantined"], r["shed_steps"],
                    r["degradation_engages"], r["p99_itl_ms"],
                    r["tokens_per_s"])
            records.append({"layout": layout, "slots": slots,
                            "s_max": s_max, "n_requests": n_req, **r})
    out = csv.dump("serve hardening under chaos: seeded fault injection "
                   "+ cancels + deadlines + KV pressure; every request "
                   "must land an explicit terminal status and the pool "
                   "must drain to zero (asserted) — p99 ITL is the "
                   "surviving streams' latency cost of degraded mode")
    _append_trajectory({"bench": "serve_faults", "dry_run": dry_run,
                        "unix_time": int(time.time()), "rows": records})
    out += f"\n# trajectory appended to {TRAJECTORY.name}"
    return out


def _prefill_setup():
    """Decomposed + f32 llama smoke params shared by both prefill
    engines (the engine quantizes its own copy at load)."""
    from repro.configs import registry
    from repro.configs.base import LRDConfig, ParallelConfig, RunConfig
    from repro.core.surgery import decompose_model
    from repro.models.api import get_model

    cfg = dataclasses.replace(registry.get("llama3.2-1b").smoke,
                              dtype="float32")
    lrd = LRDConfig(enabled=True, rank_mode="ratio", min_dim=32,
                    use_pallas=True)
    run = RunConfig(model=cfg, lrd=lrd, parallel=ParallelConfig())
    m = get_model(cfg)
    params, axes = m.init(jax.random.PRNGKey(0))
    p2, _, _ = decompose_model(params, axes, lrd)
    return run, p2


def _act_stream_bytes(eng, act_quantize: bool) -> float:
    """Modelled activation HBM bytes per prefill token, summed over the
    engine's quantized linears — the cost model's own accounting
    (:func:`plan_act_stream_bytes`), not a hand-derived formula."""
    from repro.core.cost_model import plan_act_stream_bytes
    from repro.layers import plan as lplan

    plans = [p for p in jax.tree.leaves(
        lplan.build_plan_tree(eng.params),
        is_leaf=lambda n: isinstance(n, lplan.LinearPlan))
        if isinstance(p, lplan.LinearPlan)]
    return sum(plan_act_stream_bytes(p, act_bytes=4,
                                     act_quantize=act_quantize)
               for p in plans)


def _serve_prefill(eng, prompts, n_new: int = 8):
    from repro.serve.engine import Request

    reqs = [Request(uid=i, prompt=list(p), max_new_tokens=n_new)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.add_request(r)
    t0 = time.perf_counter()
    eng.run_until_done()
    dt = time.perf_counter() - t0
    assert all(r.done for r in reqs)
    prefill_tokens = sum(s["prefill_tokens"] for s in eng.stats)
    return prefill_tokens / dt, [r.output for r in reqs]


def run_prefill(fast: bool = True, dry_run: bool = False) -> str:
    from repro.serve.engine import ServeEngine

    # (slots, S_max, prompt_len, n_req) — prefill-heavy on purpose:
    # many prompts, few new tokens, so the measured stream is dominated
    # by the segment act-quant actually runs on.  The full point scales
    # *batch*, not prompt length: past ~100 tokens the random-init smoke
    # model's top-2 logit gap collapses ~4.5x (0.155 -> 0.034 at 200)
    # and greedy match degenerates into tie density instead of act-quant
    # quality.
    sweeps = [(2, 64, 40, 4), (4, 128, 96, 8), (8, 256, 96, 16)]
    if dry_run:
        sweeps = sweeps[:1]
    elif fast:
        sweeps = sweeps[:2]
    run_cfg, params = _prefill_setup()
    csv = Csv(["slots", "s_max", "prompt_len", "act_b_tok_f32",
               "act_b_tok_int8", "act_byte_ratio",
               "cpu_pf_tok_s_f32", "cpu_pf_tok_s_int8", "token_match"])
    records = []
    for slots, s_max, p_len, n_req in sweeps:
        # prompt lengths straddle buckets; tokens deterministic
        prompts = [[(i * 7 + j * 3) % 50 + 1
                    for j in range(p_len - (i % 4))]
                   for i in range(n_req)]
        eng_f = ServeEngine(run_cfg, params, slots=slots, max_seq=s_max,
                            quantize="int8")
        tok_f, out_f = _serve_prefill(eng_f, prompts, n_new=4)
        eng_q = ServeEngine(run_cfg, params, slots=slots, max_seq=s_max,
                            quantize="int8", act_quantize="int8")
        tok_q, out_q = _serve_prefill(eng_q, prompts, n_new=4)
        b_f = _act_stream_bytes(eng_f, act_quantize=False)
        b_q = _act_stream_bytes(eng_q, act_quantize=True)
        ratio = b_f / b_q
        # greedy agreement vs the f32-act engine: int8 act noise can
        # flip near-argmax ties on a random-init model, so report the
        # fraction (acceptance reads it against 31/32)
        flat_f = [t for o in out_f for t in o]
        flat_q = [t for o in out_q for t in o]
        match = sum(a == b for a, b in zip(flat_f, flat_q)) / len(flat_f)
        csv.row(slots, s_max, p_len, int(b_f), int(b_q),
                round(ratio, 2), round(tok_f, 1), round(tok_q, 1),
                round(match, 3))
        records.append({"slots": slots, "s_max": s_max,
                        "prompt_len": p_len,
                        "act_bytes_tok_f32": int(b_f),
                        "act_bytes_tok_int8": int(b_q),
                        "act_byte_ratio": round(ratio, 3),
                        "cpu_prefill_tok_s_f32": round(tok_f, 2),
                        "cpu_prefill_tok_s_int8": round(tok_q, 2),
                        "token_match": round(match, 4)})
    out = csv.dump("prefill: f32 vs fused int8 activation quantization "
                   "on an int8-weight decomposed engine (act bytes/token "
                   "from the cost model's stream accounting; TPU win = "
                   "the int8 x int8 MXU rate)")
    worst = min(r["act_byte_ratio"] for r in records)
    out += f"\n# worst-case act byte ratio int8 vs f32: {worst:.2f}x"
    worst_match = min(r["token_match"] for r in records)
    out += f"\n# worst-case greedy token match vs f32 acts: {worst_match:.3f}"
    _append_trajectory({"bench": "serve_prefill", "dry_run": dry_run,
                        "unix_time": int(time.time()), "rows": records})
    out += f"\n# trajectory appended to {TRAJECTORY.name}"
    return out


def _router_setup():
    from repro.configs import registry
    from repro.configs.base import ParallelConfig, RunConfig
    from repro.models.api import get_model

    cfg = dataclasses.replace(registry.get("llama3.2-1b").smoke,
                              dtype="float32")
    run_cfg = RunConfig(model=cfg, parallel=ParallelConfig())
    m = get_model(cfg)
    params, _ = m.init(jax.random.PRNGKey(0))
    return run_cfg, params


def _build_router(run_cfg, params, *, replicas: int, priority_aware: bool,
                  slots: int, s_max: int, chunk: int):
    from repro.serve.router import ServeRouter

    return ServeRouter(run_cfg, params, replicas=replicas,
                       priority_aware=priority_aware,
                       slots=slots, max_seq=s_max, prefill_chunk=chunk,
                       step_token_budget=slots + chunk)


def _warm_router(router, *, slots: int, batch_len: int) -> None:
    """Compile every segment both classes will hit (decode, small-chunk
    interactive prefill, long-chunk batch prefill, insert, sample) so
    the measured rows time scheduling, not jit."""
    from repro.serve.engine import Request

    n = slots * len(router.replicas)
    reqs = [Request(uid=90000 + i, prompt=[2] * 4, max_new_tokens=3)
            for i in range(n)]
    reqs += [Request(uid=91000 + i, prompt=[3] * batch_len,
                     max_new_tokens=2, priority="batch")
             for i in range(len(router.replicas))]
    for r in reqs:
        router.add_request(r)
    router.run_until_done()
    router.reset_stats()


def _saturated_baseline(router, *, slots: int, int_new: int) -> float:
    """Interactive-only saturated decode on the (warm) router — every
    slot of every replica busy, no admission churn.  Returns tokens/s
    on the modeled data-parallel wall: the replica-scaling
    numerator/denominator."""
    from repro.serve.engine import Request

    n = slots * len(router.replicas)
    reqs = [Request(uid=95000 + i, prompt=[(i % 7) + 1] * 4,
                    max_new_tokens=int_new) for i in range(n)]
    for r in reqs:
        router.add_request(r)
    router.run_until_done()
    tok_s = router.total_tokens / max(router.round_seconds, 1e-9)
    router.reset_stats()
    return tok_s


def _calibrate_slo(router, load: dict) -> float:
    """The SLO target is what this router can actually deliver with no
    batch at all: run the measured load's interactive half alone and
    take 1.3x its p99 service ITL — so interactive admission churn
    (chunk prefills of queued interactive prompts) never reads as a
    breach, while co-scheduled batch prefill does."""
    _router_load(router, **{**load, "n_batch": 0})
    slo_ms = router.class_stats("interactive")["itl_p99_ms"] * 1.3
    router.reset_stats()
    return slo_ms


def _router_load(router, *, n_int: int, int_new: int, n_batch: int,
                 batch_len: int, batch_new: int) -> list:
    """Mixed-priority mixed-length load at saturation in one
    deterministic interleave (a batch long after every third
    interactive short), arrivals paced by one router round per
    submission — open-loop-ish load, not a single burst that
    multi-segment-prefills the whole queue in one step.  Identical
    offered load for every mode."""
    from repro.serve.engine import Request

    specs = []
    ii = bi = 0
    while ii < n_int or bi < n_batch:
        for _ in range(3):
            if ii < n_int:
                specs.append(("interactive", ii))
                ii += 1
        if bi < n_batch:
            specs.append(("batch", bi))
            bi += 1
    reqs = []
    for uid, (pri, k) in enumerate(specs):
        if pri == "interactive":
            prompt = [(k * 7 + j) % 50 + 1 for j in range(4 + k % 3)]
            reqs.append(Request(uid=uid, prompt=prompt,
                                max_new_tokens=int_new))
        else:
            prompt = [(k * 11 + j * 3) % 50 + 1
                      for j in range(batch_len - 8 * (k % 2))]
            reqs.append(Request(uid=uid, prompt=prompt,
                                max_new_tokens=batch_new,
                                priority="batch"))
    for r in reqs:
        router.add_request(r)
        router.step()
    router.run_until_done()
    assert all(r.done for r in reqs)
    return reqs


def _router_metrics(router, reqs) -> dict:
    """Per-class tails from the fleet's service-time sample rings
    (:meth:`ServeRouter.class_stats` — own-replica step seconds, so a
    replica is never charged for its co-tenants on a time-shared test
    device); tokens/s from the modeled data-parallel wall."""
    wall = max(router.round_seconds, 1e-9)
    out = {"rounds": router.rounds,
           "tokens_per_s": round(router.total_tokens / wall, 2)}
    for pri in ("interactive", "batch"):
        rs = [r for r in reqs if r.priority == pri]
        cs = router.class_stats(pri)
        out[pri] = {"p50_itl_ms": round(cs["itl_p50_ms"], 3),
                    "p99_itl_ms": round(cs["itl_p99_ms"], 3),
                    "ttft_p50_ms": round(cs["ttft_p50_ms"], 3),
                    "ttft_p99_ms": round(cs["ttft_p99_ms"], 3),
                    "tokens": sum(len(r.output) for r in rs),
                    "tok_s": round(sum(len(r.output) for r in rs)
                                   / wall, 2)}
    tp = router.throughput()
    out["kv_peak_bytes"] = [d["kv_peak_bytes"] for d in tp["per_replica"]]
    out["kv_pressure"] = [
        round(d["kv_peak_bytes"] / max(d["kv_capacity_bytes"], 1), 4)
        for d in tp["per_replica"]]
    out["shed_steps"] = [d.get("shed_steps", 0)
                         for d in tp["per_replica"]]
    out["slo_breaches"] = [d["slo_breaches"] for d in tp["per_replica"]]
    out["routed"] = [d["routed"] for d in tp["per_replica"]]
    out["rejected"] = tp["rejected"]
    return out


def run_router(fast: bool = True, dry_run: bool = False) -> str:
    """Multi-replica router: SLO-aware priority routing vs priority-
    blind round-robin FIFO at equal offered load, plus 2-replica vs
    1-replica saturated scaling (modeled data-parallel wall: max
    per-replica step seconds per round — replicas run concurrently on
    their own devices in deployment)."""
    # (slots, s_max, chunk, n_int, int_new, n_batch, batch_len, batch_new)
    sweeps = [(4, 1024, 256, 12, 24, 6, 768, 8),
              (4, 2048, 256, 16, 32, 8, 1280, 8)]
    if dry_run:
        sweeps = [(2, 128, 16, 4, 8, 2, 64, 4)]
    elif fast:
        sweeps = sweeps[:1]
    run_cfg, params = _router_setup()
    csv = Csv(["mode", "replicas", "slots", "s_max", "int_p50_ms",
               "int_p99_ms", "int_ttft_p99_ms", "batch_tok_s", "tok_s",
               "shed_steps", "slo_breaches", "match"])
    records = []
    for slots, s_max, chunk, n_int, int_new, n_batch, batch_len, \
            batch_new in sweeps:
        load = dict(n_int=n_int, int_new=int_new, n_batch=n_batch,
                    batch_len=batch_len, batch_new=batch_new)
        runs = {}
        aware2 = _build_router(run_cfg, params, replicas=2,
                               priority_aware=True, slots=slots,
                               s_max=s_max, chunk=chunk)
        _warm_router(aware2, slots=slots, batch_len=batch_len)
        aware1 = _build_router(run_cfg, params, replicas=1,
                               priority_aware=True, slots=slots,
                               s_max=s_max, chunk=chunk)
        _warm_router(aware1, slots=slots, batch_len=batch_len)
        # scaling baselines back to back — both warm, same process
        # state, so the ratio reflects replica count and not drift
        sat2_tok_s = _saturated_baseline(aware2, slots=slots,
                                         int_new=int_new)
        sat1_tok_s = _saturated_baseline(aware1, slots=slots,
                                         int_new=int_new)
        slo_ms = _calibrate_slo(aware2, load)
        aware2.set_slo(slo_ms)
        runs["slo_aware_2rep"] = (aware2, _router_load(aware2, **load))
        blind2 = _build_router(run_cfg, params, replicas=2,
                               priority_aware=False, slots=slots,
                               s_max=s_max, chunk=chunk)
        _warm_router(blind2, slots=slots, batch_len=batch_len)
        runs["blind_2rep"] = (blind2, _router_load(blind2, **load))
        aware1.set_slo(slo_ms)
        runs["slo_aware_1rep"] = (aware1, _router_load(aware1, **load))
        # greedy outputs must be identical across modes and replica
        # counts — routing never changes sampling
        base = {r.uid: r.output for r in runs["slo_aware_2rep"][1]}
        for mode, (_, reqs) in runs.items():
            match = _token_match([base[r.uid] for r in reqs],
                                 [r.output for r in reqs])
            m = _router_metrics(*runs[mode])
            csv.row(mode, len(runs[mode][0].replicas), slots, s_max,
                    m["interactive"]["p50_itl_ms"],
                    m["interactive"]["p99_itl_ms"],
                    m["interactive"]["ttft_p99_ms"],
                    m["batch"]["tok_s"], m["tokens_per_s"],
                    sum(m["shed_steps"]), sum(m["slo_breaches"]),
                    round(match, 4))
            sat = {"slo_aware_2rep": sat2_tok_s,
                   "slo_aware_1rep": sat1_tok_s}.get(mode)
            records.append({"mode": mode,
                            "replicas": len(runs[mode][0].replicas),
                            "slots": slots, "s_max": s_max,
                            "prefill_chunk": chunk,
                            "slo_itl_ms": round(slo_ms, 3),
                            "saturated_tok_s":
                                round(sat, 2) if sat else None,
                            "token_match": round(match, 4), **m})
    out = csv.dump("multi-replica router: SLO-aware priority routing vs "
                   "priority-blind round-robin at equal offered load "
                   "(interactive p99 ITL is the protected metric), plus "
                   "1- vs 2-replica saturated scaling on the modeled "
                   "data-parallel wall clock")
    by = {r["mode"]: r for r in records if r["slots"] == sweeps[0][0]
          and r["s_max"] == sweeps[0][1]}
    if len(by) == 3:
        p99_ratio = (by["blind_2rep"]["interactive"]["p99_itl_ms"]
                     / max(by["slo_aware_2rep"]["interactive"]
                           ["p99_itl_ms"], 1e-9))
        batch_ratio = (by["slo_aware_2rep"]["batch"]["tok_s"]
                       / max(by["blind_2rep"]["batch"]["tok_s"], 1e-9))
        scale = (by["slo_aware_2rep"]["saturated_tok_s"]
                 / max(by["slo_aware_1rep"]["saturated_tok_s"], 1e-9))
        out += (f"\n# interactive p99 ITL: blind "
                f"{by['blind_2rep']['interactive']['p99_itl_ms']:.1f}ms "
                f"vs SLO-aware "
                f"{by['slo_aware_2rep']['interactive']['p99_itl_ms']:.1f}"
                f"ms ({p99_ratio:.2f}x better)")
        out += (f"\n# batch throughput SLO-aware vs blind: "
                f"{batch_ratio:.2f}x")
        out += (f"\n# 2-replica vs 1-replica saturated tokens/s: "
                f"{scale:.2f}x")
    _append_trajectory({"bench": "serve_router", "dry_run": dry_run,
                        "unix_time": int(time.time()), "rows": records})
    out += f"\n# trajectory appended to {TRAJECTORY.name}"
    return out


def _append_trajectory(record: dict) -> None:
    from benchmarks.common import run_stamp
    traj = []
    if TRAJECTORY.exists():
        try:
            traj = json.loads(TRAJECTORY.read_text())
            assert isinstance(traj, list)
        except Exception:
            traj = []
    traj.append({**run_stamp(), **record})
    TRAJECTORY.write_text(json.dumps(traj, indent=1) + "\n")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--dry-run", action="store_true",
                    help="one tiny sweep point; CPU smoke for CI")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--sweep", choices=["all", "kv", "sched", "mla",
                                        "paged", "faults", "prefill",
                                        "router"],
                    default="all")
    args = ap.parse_args()
    if args.sweep in ("all", "kv"):
        print(run(fast=not args.full, dry_run=args.dry_run))
    if args.sweep in ("all", "mla"):
        print(run_mla(fast=not args.full, dry_run=args.dry_run))
    if args.sweep in ("all", "sched"):
        print(run_sched(fast=not args.full, dry_run=args.dry_run))
    if args.sweep in ("all", "paged"):
        print(run_paged(fast=not args.full, dry_run=args.dry_run))
    if args.sweep in ("all", "faults"):
        print(run_faults(fast=not args.full, dry_run=args.dry_run))
    if args.sweep in ("all", "prefill"):
        print(run_prefill(fast=not args.full, dry_run=args.dry_run))
    if args.sweep in ("all", "router"):
        print(run_router(fast=not args.full, dry_run=args.dry_run))

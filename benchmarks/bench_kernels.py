"""Kernel-layer benchmark: fused low-rank / branched matmul.

On this CPU container the Pallas kernels run in interpret mode (Python;
not a performance instrument), so the numbers reported are:

* correctness max-error vs the jnp oracle (must be ~0),
* the *cost-model* TPU time of the fused kernel vs the unfused pair
  (the fused kernel saves the M x R intermediate's HBM round-trip),
* measured XLA-on-CPU time of the jnp reference (the production fallback
  path), dense vs pair — the FLOP effect isolated from the fusion effect.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import Csv, time_jit
from repro.analysis.hw_specs import TPU_V5E
from repro.core import cost_model as cm
from repro.kernels import ops, ref


def _fused_model_time(m, c, r, s, spec=TPU_V5E):
    """Roofline time of the FUSED kernel: same compute, but the (M,R)
    intermediate never hits HBM."""
    compute = 2.0 * m * (cm.mxu_padded(c) * cm.mxu_padded(r)
                         + cm.mxu_padded(r) * cm.mxu_padded(s)) \
        / spec.peak_flops_bf16
    mem = 2 * (m * c + c * r + r * s + m * s) / spec.hbm_bandwidth
    return max(compute, mem)


def run(fast: bool = True) -> str:
    csv = Csv(["m", "c", "r", "s", "kernel_max_err", "tpu_pair_us",
               "tpu_fused_us", "fused_gain", "cpu_dense_us", "cpu_pair_us"])
    shapes = [(4096, 2048, 256, 2048), (4096, 2048, 512, 8192)]
    if fast:
        shapes = shapes[:1]
    for m, c, r, s in shapes:
        mm = min(m, 512) if fast else m
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        x = jax.random.normal(ks[0], (mm, c), jnp.float32) * 0.1
        w0 = jax.random.normal(ks[1], (c, r), jnp.float32) * 0.05
        w1 = jax.random.normal(ks[2], (r, s), jnp.float32) * 0.05
        got = ops.lowrank_matmul(x[:256], w0, w1, force_kernel=True)
        err = float(jnp.abs(got - ref.lowrank_matmul_ref(x[:256], w0, w1)
                            ).max())
        t_pair_tpu = cm.lowrank_layer_time(m, c, s, r) * 1e6
        t_fused_tpu = _fused_model_time(m, c, r, s) * 1e6
        w = jax.random.normal(ks[0], (c, s), jnp.float32) * 0.02
        t_dense_cpu = time_jit(lambda a: a @ w, x, iters=3) * 1e6
        t_pair_cpu = time_jit(lambda a: (a @ w0) @ w1, x, iters=3) * 1e6
        csv.row(m, c, r, s, f"{err:.1e}", round(t_pair_tpu, 1),
                round(t_fused_tpu, 1),
                round(t_pair_tpu / t_fused_tpu, 2),
                round(t_dense_cpu, 1), round(t_pair_cpu, 1))
    return csv.dump("kernels: fused lowrank matmul (interpret-validated; "
                    "TPU gain = removed HBM round-trip of the M x R "
                    "intermediate)")


if __name__ == "__main__":
    print(run(fast=False))

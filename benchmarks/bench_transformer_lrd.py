"""Paper -> LM transfer: the four techniques on a transformer.

For a small LM (the llama3.2-1b smoke config scaled up a notch): params,
step time (train + decode), and loss-recovery after decomposition, for
dense / vanilla LRD / aligned ranks / freezing / branching — the
transformer analogue of Tables 3-6.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from benchmarks.common import Csv, param_count, time_jit
from repro.configs import registry
from repro.configs.base import LRDConfig, ParallelConfig, RunConfig, ShapeConfig
from repro.core.surgery import decompose_model
from repro.models.api import get_model, synth_inputs
from repro.train.optim import OptimConfig
from repro.train.steps import init_opt_state, make_train_step

SHAPE = ShapeConfig("bench", 128, 4, "train")


def _cfg():
    base = registry.get("llama3.2-1b").smoke
    return dataclasses.replace(base, num_layers=4, d_model=256,
                               num_heads=8, num_kv_heads=4, head_dim=32,
                               d_ff=1024, vocab_size=2048)


def run(fast: bool = True) -> str:
    cfg = _cfg()
    m = get_model(cfg)
    params, axes = m.init(jax.random.PRNGKey(0))
    batch = synth_inputs(cfg, SHAPE, jax.random.PRNGKey(1))

    variants = {
        "dense": (None, False),
        "vanilla_lrd": (LRDConfig(enabled=True, rank_mode="ratio",
                                  min_dim=64), False),
        "aligned_ranks": (LRDConfig(enabled=True, rank_mode="aligned",
                                    rank_align=64, min_dim=64), False),
        "freezing": (LRDConfig(enabled=True, rank_mode="ratio", min_dim=64,
                               freeze=True), False),
        "branching": (LRDConfig(enabled=True, rank_mode="aligned",
                                rank_align=32, min_dim=64, branches=2),
                      False),
    }

    csv = Csv(["variant", "params_M", "train_step_ms", "train_speedup",
               "loss_after_5_steps"])
    t_dense = None
    for name, (lrd, _) in variants.items():
        p = params
        run_cfg = RunConfig(model=cfg, parallel=ParallelConfig(),
                            lrd=lrd or LRDConfig())
        if lrd is not None:
            p, _, _ = decompose_model(params, axes, lrd)
        ocfg = OptimConfig(peak_lr=1e-3, warmup_steps=1, total_steps=10)
        opt = init_opt_state(m, run_cfg, p, ocfg)
        step = make_train_step(m, run_cfg, ocfg)
        jit_step = jax.jit(step)
        # timing
        p2, o2, met = jit_step(p, opt, batch)
        t = time_jit(lambda pp, oo: jit_step(pp, oo, batch)[2]["loss"],
                     p, opt, iters=3, warmup=1)
        t_dense = t_dense or t
        # short fine-tune for loss recovery
        loss = None
        pp, oo = p, opt
        for _ in range(5):
            pp, oo, met = jit_step(pp, oo, batch)
            loss = float(met["loss"])
        csv.row(name, round(param_count(p) / 1e6, 2), round(t * 1e3, 1),
                round(t_dense / t, 3), round(loss, 4))
    return csv.dump("transformer LRD transfer (Tables 3-6 analogue): "
                    "params shrink ~2x, freezing accelerates training, "
                    "fine-tuning recovers loss")


if __name__ == "__main__":
    print(run(fast=False))

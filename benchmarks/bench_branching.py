"""Paper Fig. 5 + Eq. 18-20: throughput and params vs branch count N.

Cost-model timing of the branched (block-diagonal) structure, the exact
core-compression accounting of Eq. 18-20, and a measured comparison of
the grouped (branched) matmul against the dense rank-r pair on the
current backend.  Includes the MXU under-fill guard (DESIGN.md §3): past
``max_branches`` the per-branch rank drops under one 128-lane tile and
modeled throughput saturates/regresses — the TPU analogue of Fig. 5's
flattening.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import Csv, time_jit
from repro.core import cost_model as cm
from repro.core import rank_selection as rs
from repro.core.branching import branch_svd, branched_conv_params
from repro.core.tucker import tucker2_params
from repro.kernels import ref


def run(fast: bool = True) -> str:
    out = []
    # --- Eq. 18-20 params + cost-model time vs N -----------------------
    csv = Csv(["branches", "conv_core_params", "conv_total_params",
               "tpu_model_time_us", "rel_throughput"])
    c = s = 512
    r1 = r2 = 256
    k = 3
    base_t = None
    for n in (1, 2, 4, 8, 16):
        p = branched_conv_params(c, s, k, r1, r2, n)
        core = n * (r1 // n) * (r2 // n) * k * k
        t = cm.branched_layer_time(4096, c, s, r1, r2, n) * 1e6
        base_t = base_t or t
        csv.row(n, core, p, round(t, 2), round(base_t / t, 3))
    guard = rs.max_branches(r1)
    out.append(csv.dump(
        f"Fig 5 / Eq 18-20 repro: core shrinks 1/N; max_branches({r1})="
        f"{guard} before MXU under-fill"))

    # --- measured: branched vs plain low-rank on current backend -------
    csv2 = Csv(["branches", "measured_us", "rel_vs_pair"])
    m, c2, s2, rank = (1024, 512, 512, 256) if fast else \
        (4096, 1024, 1024, 512)
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (c2, s2), jnp.float32) * 0.05
    x = jax.random.normal(key, (m, c2), jnp.float32) * 0.1
    from repro.core.svd import svd_decompose
    f = svd_decompose(w, rank)
    t_pair = time_jit(lambda a: (a @ f.w0) @ f.w1, x, iters=3)
    for n in (1, 2, 4):
        bf = branch_svd(w, rank, n)
        t = time_jit(
            lambda a: ref.branched_matmul_ref(a, bf.u, bf.xc, bf.v), x,
            iters=3)
        csv2.row(n, round(t * 1e6, 1), round(t_pair / t, 3))
    out.append(csv2.dump("measured branched matmul (current backend)"))
    return "\n\n".join(out)


if __name__ == "__main__":
    print(run(fast=False))

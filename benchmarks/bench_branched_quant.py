"""Branched quantized path: fused kernel vs dequantize-outside.

Before `kernels/branched_matmul_q.py`, a quantized branched/Tucker layer
dequantized its int8 factors *outside* the kernel — materializing the
full bf16 factor set in HBM every step and forfeiting the bandwidth the
quantization bought.  This benchmark pins the difference per geometry:

* round-trip quantization error of the branched factor triple,
* fused-q kernel max error vs the dequant-outside oracle (interpret
  mode; ~0),
* weight bytes per token: branched bf16 vs branched int8+scales (the
  HBM stream the decode step pays),
* modelled TPU decode time from the plan-driven cost model
  (`cost_model.plan_layer_time` — the LinearPlan seam makes the roofline
  quant-aware),
* measured CPU time: dequant-outside jnp chain vs the fused wrapper
  (CPU pays dequant in compute; the win is the bandwidth column,
  realized on TPU),

plus end-to-end ``ServeEngine`` tokens/s on a branched+SVD smoke llama,
bf16 vs ``quantize="int8"``.

    PYTHONPATH=src python -m benchmarks.bench_branched_quant [--dry-run]
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from benchmarks.common import Csv, time_jit
from repro.core.cost_model import plan_layer_time
from repro.kernels import ops, ref
from repro.layers.plan import build_plan
from repro.quant import quantize_tree, relative_error, tree_bytes


def _serve_tokens_per_s(quantize: str | None) -> tuple[float, int]:
    from repro.configs import registry
    from repro.configs.base import LRDConfig, ParallelConfig, RunConfig
    from repro.core.surgery import decompose_model
    from repro.models.api import get_model
    from repro.serve.engine import Request, ServeEngine

    cfg = registry.get("llama3.2-1b").smoke
    lrd = LRDConfig(enabled=True, rank_mode="ratio", min_dim=32,
                    branches=2, rank_align=8)
    run = RunConfig(model=cfg, lrd=lrd, parallel=ParallelConfig())
    m = get_model(cfg)
    params, axes = m.init(jax.random.PRNGKey(0))
    p2, _, _ = decompose_model(params, axes, lrd)
    eng = ServeEngine(run, p2, slots=2, max_seq=64, quantize=quantize)
    for i in range(4):
        eng.add_request(Request(uid=i, prompt=[i + 1, 2, 3],
                                max_new_tokens=8))
    done = eng.run_until_done()
    assert len(done) == 4 and all(len(r.output) == 8 for r in done)
    return eng.throughput()["tokens_per_s"], tree_bytes(eng.params)


def run(fast: bool = True, dry_run: bool = False) -> str:
    csv = Csv(["n", "c", "r1", "r2", "s", "q_rel_err", "kernel_max_err",
               "bytes_br_bf16", "bytes_br_int8", "byte_gain",
               "tpu_decode_us_bf16", "tpu_decode_us_int8",
               "cpu_dq_outside_us", "cpu_fused_us"])
    shapes = [(4, 512, 64, 64, 512), (8, 2048, 128, 128, 2048),
              (4, 2048, 256, 256, 8192)]
    if dry_run:
        shapes = shapes[:1]
    elif fast:
        shapes = shapes[:2]
    for n, c, r1, r2, s in shapes:
        ks = jax.random.split(jax.random.PRNGKey(0), 4)
        # bf16 factors: the serving dtype, and what the _bf16 columns claim
        p = {"u": (jax.random.normal(ks[0], (n, c, r1)) * 0.05
                   ).astype(jnp.bfloat16),
             "xc": (jax.random.normal(ks[1], (n, r1, r2)) * 0.1
                    ).astype(jnp.bfloat16),
             "v": (jax.random.normal(ks[2], (n, r2, s)) * 0.05
                   ).astype(jnp.bfloat16)}
        pq = quantize_tree(p)
        plan_bf16 = build_plan(p)
        plan_int8 = build_plan(pq)
        q_err = max(relative_error(v) for v in p.values())
        m = 8 if dry_run else 64
        x = (jax.random.normal(ks[3], (m, c)) * 0.1).astype(jnp.bfloat16)
        args_q = (pq["u_q"], pq["u_scale"], pq["xc_q"], pq["xc_scale"],
                  pq["v_q"], pq["v_scale"])
        got = ops.branched_matmul_q(x, *args_q, force_kernel=True)
        want = ref.branched_matmul_q_ref(x, *args_q)
        k_err = float(jnp.abs(got.astype(jnp.float32)
                              - want.astype(jnp.float32)).max())
        # bf16 weights: 2 bytes/elem; int8: plan.weight_bytes (q + scales)
        b_bf16 = 2 * plan_bf16.param_count
        b_int8 = plan_int8.weight_bytes
        t_bf16 = plan_layer_time(plan_bf16, 1) * 1e6
        t_int8 = plan_layer_time(plan_int8, 1) * 1e6
        t_dq = time_jit(lambda a: ref.branched_matmul_q_ref(a, *args_q),
                        x, iters=3) * 1e6
        t_fused = time_jit(
            lambda a: ops.branched_matmul_q(a, *args_q), x, iters=3) * 1e6
        csv.row(n, c, r1, r2, s, f"{q_err:.1e}", f"{k_err:.1e}",
                b_bf16, b_int8, round(b_bf16 / b_int8, 2),
                round(t_bf16, 2), round(t_int8, 2),
                round(t_dq, 1), round(t_fused, 1))
    out = csv.dump("branched quant: fused in-VMEM dequant vs "
                   "dequantize-outside (interpret-validated; TPU gain = "
                   "int8 branch tiles stream instead of bf16)")
    if not dry_run:
        tok_bf16, bytes_bf16 = _serve_tokens_per_s(None)
        tok_int8, bytes_int8 = _serve_tokens_per_s("int8")
        out += (f"\n# serve (llama3.2-1b smoke, branches=2, CPU): "
                f"bf16 {tok_bf16:.1f} tok/s ({bytes_bf16} param bytes) | "
                f"int8 {tok_int8:.1f} tok/s ({bytes_int8} param bytes)")
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--dry-run", action="store_true",
                    help="tiny shapes; CPU interpret smoke for CI")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    print(run(fast=not args.full, dry_run=args.dry_run))

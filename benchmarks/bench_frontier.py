"""Compression-frontier benchmark: rank x sparsity x dtype, end to end.

The paper compresses along one axis (rank); this repo adds two more —
int8 factor quantization and 2:4 semi-structured sparsity of the
factors.  The three compose multiplicatively on the decode roofline's
weight stream (bytes/token ~ density x width x rank), but each also
costs accuracy, so the interesting object is the *frontier*: for every
(compression alpha, quantize, sparsify) point this bench records

* ``weight_bytes`` — whole-tree HBM weight stream (engine plan
  accounting) and ``factor_bytes`` — the decomposed lowrank/branched
  subtrees only (the part the sparse packing acts on),
* ``tokens_per_s`` — end-to-end ``ServeEngine`` throughput (CPU here;
  the byte columns are the TPU-relevant signal),
* ``token_match`` — greedy position-wise agreement vs the *dense f32*
  baseline model (the honest accuracy proxy at smoke scale: the model
  is random-init, so 2:4 pruning is destructive — the column shows the
  cost axis, not a tuned-model result),

plus interpret-mode parity of the fused sparse-int8 kernels vs their
``ref.py`` oracles, and the headline ``sp_int8_gain``: factor bytes of
int8-only over 2:4+int8 at equal rank (>= 1.8x is the acceptance bar —
the mask-shared-over-S packing costs one int8 index per group of 4
plus unchanged f32 scale rows, so the ratio approaches 2x as the
factors grow; the model here is sized so scale rows don't dominate).

Appends a JSON record to ``BENCH_frontier.json`` at the repo root.

    PYTHONPATH=src python -m benchmarks.bench_frontier [--dry-run]
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp

from benchmarks.common import Csv, run_stamp

TRAJECTORY = Path(__file__).resolve().parent.parent / "BENCH_frontier.json"

#: mid-size smoke model: big enough that f32 scale rows don't dominate
#: the packed factor bytes (at d_model 64 the int8->2:4+int8 ratio caps
#: near 1.7x; at 256 it reaches ~1.9x), small enough for CPU serving.
_MODEL = dict(name="frontier-bench", family="dense", num_layers=2,
              d_model=256, num_heads=4, num_kv_heads=2, head_dim=64,
              d_ff=512, vocab_size=512, dtype="float32")


def _model_and_params():
    from repro.configs.base import ModelConfig
    from repro.models.api import get_model

    cfg = ModelConfig(**_MODEL)
    m = get_model(cfg)
    params, axes = m.init(jax.random.PRNGKey(0))
    return cfg, params, axes


def _decomposed(params, axes, alpha: float):
    import dataclasses  # noqa: F401  (kept for symmetry with benches)

    from repro.configs.base import LRDConfig
    from repro.core.surgery import decompose_model

    # rank_align=8 keeps every rank divisible by the 2:4 group size, so
    # both factors of each pair are sparsifiable.
    lrd = LRDConfig(enabled=True, compression=alpha, rank_mode="aligned",
                    rank_align=8, min_dim=32)
    p, a, _ = decompose_model(params, axes, lrd)
    return p, a, lrd


def _engine(cfg, lrd, params, quantize: str | None, sparsify: str | None):
    from repro.configs.base import LRDConfig, ParallelConfig, RunConfig
    from repro.serve.engine import ServeEngine

    run = RunConfig(model=cfg, parallel=ParallelConfig(),
                    lrd=lrd or LRDConfig())
    return ServeEngine(run, params, slots=2, max_seq=64,
                       quantize=quantize or "none",
                       sparsify=sparsify or "none")


def _serve(eng, n_requests: int, max_new: int):
    from repro.serve.engine import Request

    reqs = [Request(uid=i, prompt=[(i % 7) + 1] * (3 + (i % 8)),
                    max_new_tokens=max_new) for i in range(n_requests)]
    for r in reqs:
        eng.add_request(r)
    eng.run_until_done()
    return eng.throughput()["tokens_per_s"], [r.output for r in reqs]


def _token_match(base: list[list[int]], got: list[list[int]]) -> float:
    flat_b = [t for o in base for t in o]
    flat_g = [t for o in got for t in o]
    assert len(flat_b) == len(flat_g) and flat_b
    return sum(a == b for a, b in zip(flat_b, flat_g)) / len(flat_b)


def _factor_bytes(eng) -> int:
    """Weight-stream bytes of the decomposed (lowrank/branched) subtrees
    only — the denominators of the compression headline."""
    from repro.layers.plan import KIND_BRANCHED, KIND_LOWRANK, LinearPlan

    plans = [p for p in jax.tree.leaves(
        eng.plans, is_leaf=lambda n: isinstance(n, LinearPlan))
        if isinstance(p, LinearPlan)]
    return sum(p.weight_bytes for p in plans
               if p.kind in (KIND_LOWRANK, KIND_BRANCHED))


def _kernel_parity() -> dict:
    """Interpret-mode max error of both fused sq kernels vs ref.py —
    runs in every mode (incl. --dry-run) so CI exercises the kernels."""
    from repro.kernels import ops, ref
    from repro.quant import quantize_array
    from repro.quant.sparse import sparsify_array

    ks = jax.random.split(jax.random.PRNGKey(7), 6)
    c, r, s, m = 128, 32, 128, 8
    w0 = jax.random.normal(ks[0], (c, r)) * 0.05
    w1 = jax.random.normal(ks[1], (r, s)) * 0.05
    x = (jax.random.normal(ks[2], (m, c)) * 0.1).astype(jnp.bfloat16)
    lr = ops.lowrank_matmul_sq(x, *sparsify_array(w0), *sparsify_array(w1),
                               force_kernel=True)
    lr_ref = ref.lowrank_matmul_sq_ref(x, *sparsify_array(w0),
                                       *sparsify_array(w1))
    n, r1, r2 = 2, 16, 16
    u = jax.random.normal(ks[3], (n, c, r1)) * 0.05
    xc = jax.random.normal(ks[4], (n, r1, r2)) * 0.05
    v = jax.random.normal(ks[5], (n, r2, s)) * 0.05
    args = (x, *sparsify_array(u), *quantize_array(xc), *sparsify_array(v))
    br = ops.branched_matmul_sq(*args, force_kernel=True)
    br_ref = ref.branched_matmul_sq_ref(*args)
    err = lambda a, b: float(jnp.abs(a.astype(jnp.float32)  # noqa: E731
                                     - b.astype(jnp.float32)).max())
    return {"lowrank_sq_max_err": err(lr, lr_ref),
            "branched_sq_max_err": err(br, br_ref)}


#: the dtype x sparsity grid at each rank point
_MODES = [("none", "none"), ("none", "2:4"),
          ("int8", "none"), ("int8", "2:4")]


def run(fast: bool = True, dry_run: bool = False) -> str:
    del fast  # one size: the mid-size smoke model is the whole point
    csv = Csv(["alpha", "quantize", "sparsify", "weight_bytes",
               "factor_bytes", "tokens_per_s", "token_match"])
    cfg, params, axes = _model_and_params()
    n_req, max_new = (4, 4) if dry_run else (4, 8)

    base_eng = _engine(cfg, None, params, None, None)
    base_tok, base_out = _serve(base_eng, n_req, max_new)
    dense_bytes = base_eng.plan_summary["weight_bytes"]

    alphas = [2.0] if dry_run else [2.0, 4.0]
    records, gains = [], {}
    for alpha in alphas:
        dp, _, lrd = _decomposed(params, axes, alpha)
        fb = {}
        for quantize, sparsify in _MODES:
            eng = _engine(cfg, lrd, dp, quantize, sparsify)
            tok_s, out = _serve(eng, n_req, max_new)
            match = _token_match(base_out, out)
            fbytes = _factor_bytes(eng)
            fb[(quantize, sparsify)] = fbytes
            rec = {"alpha": alpha, "quantize": quantize,
                   "sparsify": sparsify,
                   "weight_bytes": eng.plan_summary["weight_bytes"],
                   "factor_bytes": fbytes,
                   "tokens_per_s": round(tok_s, 2),
                   "token_match": round(match, 4)}
            records.append(rec)
            csv.row(alpha, quantize, sparsify, rec["weight_bytes"],
                    fbytes, rec["tokens_per_s"], rec["token_match"])
        gains[alpha] = fb[("int8", "none")] / fb[("int8", "2:4")]

    parity = _kernel_parity()
    out = csv.dump("compression frontier: rank x sparsity x dtype "
                   "(token_match vs dense f32 on the random-init smoke "
                   "model — the accuracy-cost axis, not a tuned result)")
    out += f"\n# dense f32 weight_bytes: {dense_bytes}, {base_tok:.1f} tok/s"
    for alpha, g in gains.items():
        out += (f"\n# alpha={alpha}: factor bytes int8-only / 2:4+int8 "
                f"= {g:.2f}x")
    out += (f"\n# kernel parity (interpret): "
            f"lowrank_sq {parity['lowrank_sq_max_err']:.1e}, "
            f"branched_sq {parity['branched_sq_max_err']:.1e}")
    _append_trajectory({
        "bench": "frontier", "dry_run": dry_run,
        "unix_time": int(time.time()),
        "dense_weight_bytes": dense_bytes,
        "sp_int8_gain": {str(a): round(g, 3) for a, g in gains.items()},
        "kernel_parity": parity, "rows": records})
    out += f"\n# trajectory appended to {TRAJECTORY.name}"
    return out


def _append_trajectory(record: dict) -> None:
    traj = []
    if TRAJECTORY.exists():
        try:
            traj = json.loads(TRAJECTORY.read_text())
            assert isinstance(traj, list)
        except Exception:
            traj = []
    traj.append({**run_stamp(), **record})
    TRAJECTORY.write_text(json.dumps(traj, indent=1) + "\n")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--dry-run", action="store_true",
                    help="single alpha, short decodes; CPU CI smoke")
    args = ap.parse_args()
    print(run(dry_run=args.dry_run))

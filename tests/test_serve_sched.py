"""Scheduler / KVPoolManager / ModelRunner seam: chunked prefill,
token budgets, KV-pressure preemption, and engine stats.

The load-bearing invariants:

* chunked-prefill greedy token streams == whole-prefill streams,
  bit-exact, for BOTH f32 and int8 KV pools (the scheduler stages
  in-flight prompts at full precision and quantizes once at insert);
* a mixed prefill+decode step never spends more than
  ``step_token_budget`` real tokens (decode-first);
* a long prompt queued behind live streams never stalls their decode;
* preemption + requeue round-trips deterministically under greedy.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.configs.base import LRDConfig, ModelConfig, ParallelConfig, \
    RunConfig
from repro.layers import attention as attn
from repro.layers.param import ParamBuilder
from repro.models.api import get_model
from repro.quant import kv as kvq
from repro.serve.engine import Request, ServeEngine
from repro.serve.pool import KVPoolManager


@pytest.fixture(scope="module")
def rng():
    return jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def setup():
    # f32 model dtype: the equality tests compare full token streams,
    # so near-tied bf16 argmaxes must not inject flakiness.
    cfg = dataclasses.replace(registry.get("llama3.2-1b").smoke,
                              dtype="float32")
    run = RunConfig(model=cfg, parallel=ParallelConfig())
    m = get_model(cfg)
    params, _ = m.init(jax.random.PRNGKey(0))
    return run, m, params


def _engine(run, params, **kw):
    kw.setdefault("slots", 2)
    kw.setdefault("max_seq", 64)
    return ServeEngine(run, params, **kw)


def _serve(eng, prompts, n=6):
    reqs = [Request(uid=i, prompt=list(p), max_new_tokens=n)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.add_request(r)
    eng.run_until_done()
    assert all(r.done for r in reqs)
    return [r.output for r in reqs]


LONG = tuple((i * 7 + 3) % 50 + 1 for i in range(21))   # 3 chunks of 8


# ---------------------------------------------------------------------------
# kv_write_chunk / quantize_kv_tree units
# ---------------------------------------------------------------------------

class TestKVWriteChunk:
    def _mk(self, rng, b=2, s=16, kh=2, d=8, c=5):
        cache = jnp.zeros((b, s, kh, d), jnp.int8)
        scale = jnp.zeros((b, kh, d), jnp.float32)
        new = jax.random.normal(rng, (b, c, kh, d), jnp.float32)
        return cache, scale, new

    def test_final_scale_matches_token_loop(self, rng):
        cache, scale, new = self._mk(rng)
        cq, sc = kvq.kv_write_chunk(cache, scale, new, jnp.asarray(3))
        ct, st = cache, scale
        for t in range(new.shape[1]):
            ct, st = kvq.kv_write_token(ct, st, new[:, t],
                                        jnp.full((2,), 3 + t, jnp.int32))
        np.testing.assert_array_equal(np.asarray(sc), np.asarray(st))

    def test_warm_scale_chunk_equals_token_loop_exactly(self, rng):
        """When no channel's running max grows, the chunk write and the
        per-token loop are bit-identical (no requant rounding)."""
        cache, _, new = self._mk(rng)
        warm = jnp.full((2, 2, 8), 10.0, jnp.float32)   # >> |new|/127
        cq, sc = kvq.kv_write_chunk(cache, warm, new, jnp.asarray(3))
        ct, st = cache, warm
        for t in range(new.shape[1]):
            ct, st = kvq.kv_write_token(ct, st, new[:, t],
                                        jnp.full((2,), 3 + t, jnp.int32))
        np.testing.assert_array_equal(np.asarray(cq), np.asarray(ct))
        np.testing.assert_array_equal(np.asarray(sc), np.asarray(st))

    def test_roundtrip_error_bounded(self, rng):
        cache, scale, new = self._mk(rng)
        cq, sc = kvq.kv_write_chunk(cache, scale, new, jnp.asarray(0))
        deq = kvq.dequantize_kv(cq, sc)[:, :new.shape[1]]
        err = jnp.abs(deq - new)
        bound = jnp.broadcast_to(sc[:, None] * 0.51, err.shape)
        assert bool(jnp.all(err <= bound + 1e-7))

    def test_history_requant_when_scale_grows(self, rng):
        cache, scale, new = self._mk(rng)
        cq, sc = kvq.kv_write_chunk(cache, scale, new * 0.1, jnp.asarray(0))
        # a much louder chunk forces the history to requantize
        cq2, sc2 = kvq.kv_write_chunk(cq, sc, new * 10.0, jnp.asarray(5))
        assert bool(jnp.all(sc2 >= sc))
        deq = kvq.dequantize_kv(cq2, sc2)[:, :5]
        err = jnp.abs(deq - new[:, :5] * 0.1)
        bound = jnp.broadcast_to(sc2[:, None] * 1.01, err.shape)
        assert bool(jnp.all(err <= bound + 1e-7))


class TestQuantizeKVTree:
    def test_matches_prefill_quantization(self, rng):
        """One-shot stream-cache quantization == quantize-on-insert:
        same values AND scales, pad tail masked to exact zero."""
        k = jax.random.normal(rng, (1, 8, 2, 4), jnp.float32)
        v = jax.random.normal(jax.random.fold_in(rng, 1), (1, 8, 2, 4),
                              jnp.float32)
        plen = jnp.asarray(5)
        pm = (jnp.arange(8) < plen)[None, :, None, None]
        km, vm = jnp.where(pm, k, 0.0), jnp.where(pm, v, 0.0)
        k_q, k_scale = kvq.quantize_kv_prefill(km)
        got = kvq.quantize_kv_tree({"deep": {"k": k, "v": v}}, plen)["deep"]
        np.testing.assert_array_equal(np.asarray(got["k_q"]),
                                      np.asarray(k_q))
        np.testing.assert_array_equal(np.asarray(got["k_scale"]),
                                      np.asarray(k_scale))
        assert int(jnp.abs(got["v_q"][:, 5:].astype(jnp.int32)).max()) == 0

    def test_stacked_layer_axis(self, rng):
        k = jax.random.normal(rng, (3, 1, 8, 2, 4), jnp.float32)
        got = kvq.quantize_kv_tree({"k": k, "v": k})
        assert got["k_q"].shape == (3, 1, 8, 2, 4)
        assert got["k_scale"].shape == (3, 1, 2, 4)
        deq = got["k_q"].astype(jnp.float32) \
            * jnp.expand_dims(got["k_scale"], -3)
        assert float(jnp.abs(deq - k).max()) < float(
            got["k_scale"].max()) * 0.51 + 1e-7


# ---------------------------------------------------------------------------
# Attention-level chunk writes
# ---------------------------------------------------------------------------

class TestAttentionChunked:
    def _gqa(self, rng, d_model=32, h=4, kh=2, hd=8):
        pb = ParamBuilder(rng, jnp.float32)
        attn.init_attention(pb, "a", d_model, h, kh, hd)
        kw = dict(num_heads=h, num_kv_heads=kh, head_dim=hd, rope_theta=1e4)
        return pb.params["a"], kw

    def test_chunked_equals_whole_f32(self, rng):
        p, kw = self._gqa(rng)
        s, s_max = 12, 32
        x = jax.random.normal(jax.random.fold_in(rng, 2), (1, s, 32),
                              jnp.float32) * 0.3
        whole_cache = attn.init_kv_cache(1, s_max, 2, 8, jnp.float32)
        pos = jnp.arange(s)[None, :]
        o_whole, c_whole = attn.apply_attention(p, x, positions=pos,
                                                cache=whole_cache, **kw)
        cache = attn.init_kv_cache(1, s_max, 2, 8, jnp.float32)
        outs = []
        for st in (0, 4, 8):
            xc = x[:, st:st + 4]
            o, cache = attn.apply_attention(
                p, xc, positions=st + jnp.arange(4)[None, :], cache=cache,
                start_pos=jnp.asarray(st), prompt_len=jnp.asarray(s), **kw)
            outs.append(o)
        np.testing.assert_allclose(np.asarray(jnp.concatenate(outs, 1)),
                                   np.asarray(o_whole), atol=1e-6,
                                   rtol=1e-6)
        np.testing.assert_allclose(np.asarray(cache["k"][:, :s]),
                                   np.asarray(c_whole["k"][:, :s]),
                                   atol=1e-6, rtol=1e-6)

    def test_chunked_quantized_close_to_f32(self, rng):
        """The direct-to-int8 chunk branch (kv_write_chunk + dequant
        attention) tracks the f32 chunk path within quant error."""
        p, kw = self._gqa(rng)
        s, s_max = 8, 16
        x = jax.random.normal(jax.random.fold_in(rng, 3), (1, s, 32),
                              jnp.float32) * 0.3
        outs = {}
        for mode in (None, "int8"):
            cache = attn.init_kv_cache(1, s_max, 2, 8, jnp.float32, mode)
            chunks = []
            for st in (0, 4):
                o, cache = attn.apply_attention(
                    p, x[:, st:st + 4],
                    positions=st + jnp.arange(4)[None, :], cache=cache,
                    start_pos=jnp.asarray(st), prompt_len=jnp.asarray(s),
                    **kw)
                chunks.append(o)
            outs[mode] = jnp.concatenate(chunks, 1)
        assert outs["int8"].dtype == outs[None].dtype
        assert float(jnp.abs(outs["int8"] - outs[None]).max()) < 5e-2

    def test_padded_chunk_rows_masked_at_write(self, rng):
        """A bucket-padded chunk whose pad rows sit MID-prompt must zero
        them at the K/V write — correctness cannot depend on the next
        chunk's bucket overwriting them."""
        p, kw = self._gqa(rng)
        s, s_max = 12, 32
        x = jax.random.normal(jax.random.fold_in(rng, 7), (1, s, 32),
                              jnp.float32) * 0.3
        garbage = jnp.full((1, 3, 32), 7.7, jnp.float32)
        whole = attn.init_kv_cache(1, s_max, 2, 8, jnp.float32)
        _, c_whole = attn.apply_attention(
            p, x, positions=jnp.arange(s)[None, :], cache=whole, **kw)
        cache = attn.init_kv_cache(1, s_max, 2, 8, jnp.float32)
        # chunk 1: rows 0..4 real, rows 5..7 bucket pad (prompt_len=5
        # marks the chunk's real END, not the prompt's)
        _, cache = attn.apply_attention(
            p, jnp.concatenate([x[:, :5], garbage], 1),
            positions=jnp.arange(8)[None, :], cache=cache,
            start_pos=jnp.asarray(0), prompt_len=jnp.asarray(5), **kw)
        # pad rows landed as zeros, not garbage K/V
        assert float(jnp.abs(cache["k"][:, 5:8]).max()) == 0.0
        _, cache = attn.apply_attention(
            p, x[:, 5:], positions=5 + jnp.arange(7)[None, :], cache=cache,
            start_pos=jnp.asarray(5), prompt_len=jnp.asarray(s), **kw)
        np.testing.assert_allclose(np.asarray(cache["k"][:, :s]),
                                   np.asarray(c_whole["k"][:, :s]),
                                   atol=1e-6, rtol=1e-6)

    def test_mla_chunked_equals_whole(self, rng):
        cfg = ModelConfig(name="mla-tiny", family="moe", mla=True,
                          d_model=32, num_heads=2, q_lora_rank=0,
                          kv_lora_rank=16, qk_rope_dim=8, qk_nope_dim=16,
                          v_head_dim=16, vocab_size=64, dtype="float32")
        pb = ParamBuilder(rng, jnp.float32)
        attn.init_mla(pb, "mla", cfg)
        p = pb.params["mla"]
        s, s_max = 8, 16
        x = jax.random.normal(jax.random.fold_in(rng, 4), (1, s, 32),
                              jnp.float32) * 0.3
        pos = jnp.arange(s)[None, :]
        o_whole, c_whole = attn.apply_mla(
            p, x, cfg, positions=pos,
            cache=attn.init_mla_cache(1, s_max, cfg, jnp.float32))
        cache = attn.init_mla_cache(1, s_max, cfg, jnp.float32)
        outs = []
        for st in (0, 4):
            o, cache = attn.apply_mla(
                p, x[:, st:st + 4], cfg,
                positions=st + jnp.arange(4)[None, :], cache=cache,
                start_pos=jnp.asarray(st))
            outs.append(o)
        got = jnp.concatenate(outs, 1)
        np.testing.assert_allclose(np.asarray(got), np.asarray(o_whole),
                                   atol=1e-5, rtol=1e-5)
        np.testing.assert_array_equal(np.asarray(cache["ckv"][:, :s]),
                                      np.asarray(c_whole["ckv"][:, :s]))


# ---------------------------------------------------------------------------
# Engine: chunked == whole, budgets, head-of-line, preemption
# ---------------------------------------------------------------------------

class TestChunkedEqualsWhole:
    @pytest.mark.parametrize("kvq_mode", [None, "int8"])
    def test_long_prompt_exact(self, setup, kvq_mode):
        run, m, params = setup
        eng_b = _engine(run, params, admission="blocking",
                        kv_quantize=kvq_mode)
        out_b = _serve(eng_b, [LONG, (4, 5, 6)])
        eng_c = _engine(run, params, admission="continuous",
                        prefill_chunk=8, kv_quantize=kvq_mode)
        out_c = _serve(eng_c, [LONG, (4, 5, 6)])
        assert out_b == out_c
        # chunking actually happened: 21-token prompt, 8-token chunks
        assert max(s["prefill_tokens"] for s in eng_c.stats) <= 8 + 3

    def test_matches_full_forward_reference(self, setup):
        run, m, params = setup
        eng = _engine(run, params, prefill_chunk=8)
        (out,) = _serve(eng, [LONG], n=5)
        toks = list(LONG)
        for _ in range(5):
            x, _ = m.forward(params, {"tokens": jnp.asarray([toks])})
            logits = m.logits(params, x)
            toks.append(int(jnp.argmax(logits[0, -1])))
        assert out == toks[len(LONG):]

    def test_int8_pool_stays_int8(self, setup):
        run, m, params = setup
        eng = _engine(run, params, prefill_chunk=8, kv_quantize="int8")
        _serve(eng, [LONG], n=3)
        leaves = jax.tree_util.tree_flatten_with_path(eng.cache)[0]
        dtypes = {str(getattr(p[-1], "key", p[-1])): l.dtype
                  for p, l in leaves}
        assert dtypes["k_q"] == jnp.int8
        assert dtypes["k_scale"] == jnp.float32


class TestTokenBudget:
    def test_mixed_step_respects_budget(self, setup):
        run, m, params = setup
        budget = 6
        eng = _engine(run, params, prefill_chunk=4,
                      step_token_budget=budget)
        reqs = [Request(uid=0, prompt=[1, 2, 3], max_new_tokens=20),
                Request(uid=1, prompt=list(LONG), max_new_tokens=4)]
        for r in reqs:
            eng.add_request(r)
        eng.run_until_done()
        assert all(r.done for r in reqs)
        for s in eng.stats:
            # decode-first is strict (all live slots), prefill spends
            # at most the remainder of the budget
            assert s["tokens"] + s["prefill_tokens"] \
                <= max(budget, s["live"])

    def test_no_head_of_line_stall(self, setup):
        """Decode of a live stream continues EVERY step while a long
        prompt prefills in chunks behind it."""
        run, m, params = setup
        eng = _engine(run, params, prefill_chunk=4)
        short = Request(uid=0, prompt=[1, 2, 3], max_new_tokens=40)
        eng.add_request(short)
        eng.step()                       # short becomes live
        assert len(short.output) >= 1
        long_req = Request(uid=1, prompt=list(LONG), max_new_tokens=4)
        eng.add_request(long_req)
        for _ in range(8):               # 21-token prompt / 4-token chunks
            before = len(short.output)
            eng.step()
            assert len(short.output) == before + 1, \
                "long prompt stalled a live decode stream"
            if long_req in eng.scheduler.active:
                break
        assert long_req in eng.scheduler.active

    def test_continuous_rejected_for_recurrent_family(self):
        cfg = registry.get("mamba2-2.7b").smoke
        run = RunConfig(model=cfg, parallel=ParallelConfig())
        m = get_model(cfg)
        params, _ = m.init(jax.random.PRNGKey(0))
        with pytest.raises(ValueError):
            ServeEngine(run, params, slots=1, max_seq=32,
                        admission="continuous")
        eng = ServeEngine(run, params, slots=1, max_seq=32)
        assert eng.admission == "blocking"


class TestPreemption:
    def test_preempt_requeue_deterministic(self, setup):
        """Under a KV byte budget the youngest stream is evicted,
        requeued with its generated prefix, and finishes with EXACTLY
        the tokens of an unconstrained greedy run."""
        run, m, params = setup
        prompts = [(1, 2, 3, 4), (9, 8, 7)]
        base = _serve(_engine(run, params), prompts, n=10)

        eng = _engine(run, params)
        bpt = eng.pool.bytes_per_token
        assert bpt > 0
        # room for both prompts + a few decoded tokens, then pressure
        eng2 = _engine(run, params, kv_byte_budget=int(bpt * 12))
        out = _serve(eng2, prompts, n=10)
        assert eng2.preemptions > 0
        assert out == base
        preempted = [r for r in eng2.finished if r.preemptions]
        assert preempted and all(len(r.output) == 10 for r in preempted)

    def test_budget_gates_admission(self, setup):
        run, m, params = setup
        eng = _engine(run, params)
        bpt = eng.pool.bytes_per_token
        eng2 = _engine(run, params, kv_byte_budget=int(bpt * 6))
        out = _serve(eng2, [(1, 2, 3, 4), (9, 8, 7)], n=4)
        # second stream could never cohabit: it waited, then ran alone
        assert max(s["live"] for s in eng2.stats) == 1
        base = _serve(_engine(run, params), [(1, 2, 3, 4), (9, 8, 7)], n=4)
        assert out == base


class TestPoolAccounting:
    def test_slot_and_byte_lifecycle(self, setup):
        run, m, params = setup
        pool = KVPoolManager(m, 2, 64, byte_budget=None)
        assert pool.free_slots() == [0, 1]
        assert pool.bytes_per_token > 0
        assert pool.used_bytes() == 0
        pool.allocate(0, 10)
        assert pool.used_bytes() == int(10 * pool.bytes_per_token)
        pool.grow(0)
        assert pool.used_bytes() == int(11 * pool.bytes_per_token)
        pool.release(0)
        assert pool.used_bytes() == 0 and pool.free_slots() == [0, 1]

    def test_pressure_evicts_youngest_first(self, setup):
        run, m, params = setup
        pool = KVPoolManager(m, 3, 64)
        pool.byte_budget = int(12 * pool.bytes_per_token)
        pool.allocate(2, 6)
        pool.allocate(0, 6)
        assert pool.pressure_victims() == []
        pool.allocate(1, 6)            # youngest ticket
        assert pool.pressure_victims() == [1]

    def test_kv_bytes_per_step_matches_engine(self, setup):
        run, m, params = setup
        eng = _engine(run, params)
        assert eng.plan_summary["kv_bytes_per_step"] \
            == eng.pool.kv_bytes_per_step > 0


class TestStatsAndTTFT:
    def test_stats_ring_bounded(self, setup):
        run, m, params = setup
        eng = _engine(run, params, stats_window=4)
        _serve(eng, [(1, 2, 3)], n=12)
        assert len(eng.stats) == 4

    def test_ttft_and_admit_time_recorded(self, setup):
        run, m, params = setup
        for admission in ("continuous", "blocking"):
            eng = _engine(run, params, admission=admission)
            _serve(eng, [(1, 2, 3), (4, 5)], n=4)
            for r in eng.finished:
                assert r.ttft is not None and r.ttft >= 0
                assert len(r.token_times) == len(r.output)
            tp = eng.throughput()
            assert tp["ttft_mean_s"] >= 0
            assert tp["prefill_seconds"] > 0     # admit/prefill counted
            assert tp["tokens_per_s"] > 0

    def test_overlong_prompt_rejected_at_submit(self, setup):
        run, m, params = setup
        eng = _engine(run, params, max_seq=32)
        with pytest.raises(ValueError, match="does not fit"):
            eng.add_request(Request(uid=0, prompt=[1] * 40))
        eng.add_request(Request(uid=1, prompt=[1] * 31, max_new_tokens=4))

    def test_blocking_first_token_only_request_counted(self, setup):
        """A request that finishes on its admission token (max_new=1)
        must still show up in step()'s return and throughput()."""
        run, m, params = setup
        eng = _engine(run, params, admission="blocking")
        req = Request(uid=0, prompt=[1, 2, 3], max_new_tokens=1)
        eng.add_request(req)
        assert eng.step() == 1
        assert req.done and len(req.output) == 1
        tp = eng.throughput()
        assert tp["steps"] == 1 and tp["tokens_per_s"] > 0

    def test_runner_rejects_unknown_segment(self, setup):
        run, m, params = setup
        eng = _engine(run, params)
        with pytest.raises(ValueError):
            eng.runner.step(jnp.zeros((1, 1), jnp.int32), None, "train",
                            cache=None)

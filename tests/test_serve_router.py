"""Multi-replica serve tier: routing, priority classes, SLO admission.

The load-bearing invariants:

* routing only picks *which* engine serves a request — greedy token
  streams are bit-identical across replica counts (1 vs 2 replicas,
  and vs a bare engine);
* priority classes order work end to end: the classed queue pops
  interactive first, the chunk plan places interactive segments ahead
  of batch and share-caps batch while interactive is in flight;
* non-final prefill segments are always exactly ``prefill_chunk`` real
  tokens (no runt compile shapes), except the guaranteed-progress
  fallback when nothing is decoding;
* the SLO gate holds batch from replicas whose interactive tail is
  unmeasured or breached, and stands down when there is nothing left
  to protect;
* one chaos spec splits into per-replica-deterministic injectors;
* a replica whose ``step`` raises is pulled from rotation and its work
  finishes elsewhere;
* per-class ITL samples are *service-time* (the owning engine's step
  seconds), so one replica's heavy step never contaminates another's
  measured tail.
"""
import dataclasses
import os
import subprocess
import sys

import jax
import pytest

from repro.configs import registry
from repro.configs.base import ParallelConfig, RunConfig
from repro.models.api import get_model
from repro.serve.engine import Request, ServeEngine
from repro.serve.faults import FaultInjector
from repro.serve.router import ServeRouter, SLOPolicy, SLOTracker
from repro.serve.scheduler import ClassedQueue, PrefillStream, \
    PRIORITIES, Scheduler


@pytest.fixture(scope="module")
def setup():
    # f32 model dtype: the determinism tests compare full token
    # streams, so near-tied bf16 argmaxes must not inject flakiness.
    cfg = dataclasses.replace(registry.get("llama3.2-1b").smoke,
                              dtype="float32")
    run = RunConfig(model=cfg, parallel=ParallelConfig())
    params, _ = get_model(cfg).init(jax.random.PRNGKey(0))
    return run, params


def _router(run, params, **kw):
    kw.setdefault("replicas", 2)
    kw.setdefault("slots", 2)
    kw.setdefault("max_seq", 64)
    return ServeRouter(run, params, **kw)


def _reqs(prompts, n=6, priority=None):
    classes = list(PRIORITIES)
    return [Request(uid=i, prompt=list(p), max_new_tokens=n,
                    priority=priority or classes[i % 2])
            for i, p in enumerate(prompts)]


PROMPTS = [[1, 2, 3], [4, 5], [6, 7, 8, 9], [10], [11, 12, 13],
           [14, 15]]


# ---------------------------------------------------------------------------
# ClassedQueue / chunk_plan units (no model)
# ---------------------------------------------------------------------------

class TestClassedQueue:
    def test_interactive_pops_first(self):
        q = ClassedQueue(aware=True)
        b = Request(uid=0, prompt=[1], priority="batch")
        i = Request(uid=1, prompt=[2], priority="interactive")
        q.append(b)
        q.append(i)
        assert q.popleft() is i
        assert q.popleft() is b

    def test_blind_is_fifo(self):
        q = ClassedQueue(aware=False)
        b = Request(uid=0, prompt=[1], priority="batch")
        i = Request(uid=1, prompt=[2], priority="interactive")
        q.append(b)
        q.append(i)
        assert q.popleft() is b

    def test_count_and_remove(self):
        q = ClassedQueue(aware=True)
        reqs = _reqs(PROMPTS[:4])
        for r in reqs:
            q.append(r)
        assert q.count("interactive") == 2 and q.count("batch") == 2
        q.remove(reqs[0])
        assert q.count("interactive") == 1 and len(q) == 3


class TestChunkPlan:
    def _sched(self, **kw):
        kw.setdefault("prefill_chunk", 8)
        kw.setdefault("step_token_budget", 24)
        return Scheduler(2, **kw)

    def _stream(self, uid, n, priority, slot=0):
        req = Request(uid=uid, prompt=list(range(1, n + 1)),
                      priority=priority)
        return PrefillStream(req=req, slot=slot, tokens=req.prompt)

    def test_interactive_plans_first(self):
        s = self._sched()
        s.prefilling = [self._stream(0, 16, "batch", 0),
                        self._stream(1, 16, "interactive", 1)]
        plan = s.chunk_plan(n_live=0)
        assert plan[0][0].req.priority == "interactive"

    def test_batch_share_caps_batch_segments(self):
        s = self._sched(batch_share=0.25)   # 24-token quota -> 6 batch
        s.prefilling = [self._stream(0, 32, "interactive", 0),
                        self._stream(1, 32, "batch", 1)]
        s.active = [s.prefilling[0].req, None]   # interactive in flight
        plan = s.chunk_plan(n_live=1)
        batch_tok = sum(c for ps, c in plan
                        if ps.req.priority == "batch")
        assert batch_tok <= int(s.prefill_quota(1) * 0.25)

    def test_runt_nonfinal_segment_waits(self):
        # 20-token quota, streams A/B take a full chunk each; the 4
        # leftover would be a runt NON-final segment for C (24 left) —
        # C waits instead of compiling a fresh 4-token shape.
        s = self._sched(prefill_chunk=8, step_token_budget=20)
        a = self._stream(0, 16, "interactive", 0)
        b = self._stream(1, 16, "interactive", 1)
        c_ = self._stream(2, 24, "interactive", 0)
        s.prefilling = [a, b, c_]
        plan = s.chunk_plan(n_live=0)
        assert plan == [(a, 8), (b, 8)]       # no 4-token runt for C

    def test_final_runt_is_allowed(self):
        s = self._sched(prefill_chunk=8, step_token_budget=24)
        a = self._stream(0, 11, "interactive", 0)   # 8, then final 3
        s.prefilling = [a]
        assert [c for _, c in s.chunk_plan(n_live=0)] == [8]
        a.written = 8
        assert [c for _, c in s.chunk_plan(n_live=0)] == [3]

    def test_progress_guaranteed_when_idle(self):
        # nothing decoding + share-capped to zero: one segment anyway
        s = self._sched(batch_share=0.0)
        s.prefilling = [self._stream(0, 16, "batch", 0)]
        assert s.chunk_plan(n_live=0)


# ---------------------------------------------------------------------------
# SLOTracker / FaultInjector.split units (no model)
# ---------------------------------------------------------------------------

class TestSLOTracker:
    def test_hysteresis(self):
        t = SLOTracker(SLOPolicy(slo_itl_ms=10.0, headroom=0.5,
                                 min_samples=4))
        assert not t.observe(20.0, 2)          # too few samples
        assert t.observe(20.0, 8)              # breach -> engaged
        assert t.breaches == 1
        assert t.observe(7.0, 8)               # inside dead band: held
        assert not t.observe(4.0, 8)           # recovered below 5.0
        assert t.breaches == 1

    def test_idle_reset_stands_down(self):
        t = SLOTracker(SLOPolicy(slo_itl_ms=10.0))
        t.observe(100.0, 64)
        assert t.engaged
        t.idle_reset()
        assert not t.engaged and t.breaches == 1

    def test_batch_ok_requires_measured_tail(self):
        t = SLOTracker(SLOPolicy(slo_itl_ms=10.0, headroom=0.6,
                                 min_samples=8))
        assert not t.batch_ok(1.0, 4)          # unmeasured: hold
        assert t.batch_ok(5.0, 8)              # under headroom * slo
        assert not t.batch_ok(7.0, 8)          # dead band


class TestFaultSplit:
    SPEC = dict(seed=3, rates={"pool_alloc": 0.5},
                max_fires={"pool_alloc": 100})

    def _seq(self, inj, n=32):
        return [inj.fire("pool_alloc") for _ in range(n)]

    def test_same_tag_is_deterministic(self):
        a = FaultInjector(**self.SPEC).split("replica0")
        b = FaultInjector(**self.SPEC).split("replica0")
        assert self._seq(a) == self._seq(b)

    def test_tags_are_independent_and_parent_untouched(self):
        parent = FaultInjector(**self.SPEC)
        base = self._seq(FaultInjector(**self.SPEC))
        s0 = self._seq(parent.split("replica0"))
        s1 = self._seq(parent.split("replica1"))
        assert s0 != s1
        assert parent.fired["pool_alloc"] == 0
        # the parent's own (seed, point) stream is unchanged by splits
        assert self._seq(parent) == base


# ---------------------------------------------------------------------------
# Routing behavior
# ---------------------------------------------------------------------------

class TestRouting:
    def test_unknown_priority_rejected(self, setup):
        run, params = setup
        router = _router(run, params)
        with pytest.raises(ValueError, match="priority"):
            router.add_request(Request(uid=0, prompt=[1],
                                       priority="realtime"))

    def test_least_pressure_spreads_load(self, setup):
        run, params = setup
        router = _router(run, params)
        for r in _reqs(PROMPTS[:4], priority="interactive"):
            router.add_request(r)
        routed = [rep.routed["interactive"] for rep in router.replicas]
        assert sorted(routed) == [2, 2]

    def test_blind_round_robin(self, setup):
        run, params = setup
        router = _router(run, params, priority_aware=False)
        for r in _reqs(PROMPTS[:4]):
            router.add_request(r)
        assert [sum(rep.routed.values()) for rep in router.replicas] \
            == [2, 2]

    def test_slo_gate_holds_batch_until_tail_measured(self, setup):
        run, params = setup
        router = _router(run, params, slo_itl_ms=50.0)
        for r in _reqs(PROMPTS[:4], priority="interactive"):
            router.add_request(r)
        held = Request(uid=9, prompt=[7, 8], max_new_tokens=4,
                       priority="batch")
        router.add_request(held)
        # every replica has interactive pending and an unmeasured tail
        assert list(router.held) == [held]
        done = router.run_until_done()
        assert held in done and held.status == "finished"
        assert router.throughput()["held_batch"] == 0

    def test_batch_pressure_cap_balances_held_drain(self, setup):
        run, params = setup
        router = _router(run, params, slo_itl_ms=50.0,
                         batch_pressure_cap=0.5)
        free, gated = router.replicas
        # `free` is over the cap with batch; `gated` holds interactive
        # (unmeasured tail -> SLO gate) but has headroom under the cap
        router._submit(free, Request(uid=0, prompt=[1] * 50,
                                     max_new_tokens=8,
                                     priority="batch"))
        router._submit(gated, Request(uid=1, prompt=[2, 3],
                                      max_new_tokens=4,
                                      priority="interactive"))
        probe = Request(uid=2, prompt=[4] * 8, max_new_tokens=8,
                        priority="batch")
        assert router._projected(free, probe) > 0.5
        assert router._projected(gated, probe) <= 0.5
        assert router._pick(probe) is None     # wait for `gated`
        done = [r.uid for r in router.run_until_done()]
        assert set(done) == {0, 1}

    def test_prefix_affinity_routes_to_warm_replica(self, setup):
        run, params = setup
        router = _router(run, params, kv_layout="paged")
        shared = list(range(1, 21))            # > one 16-token block
        first = Request(uid=0, prompt=shared + [30], max_new_tokens=4)
        router.add_request(first)
        router.run_until_done()
        warm = [rep for rep in router.replicas
                if rep.engine.pool.prefix_affinity(shared) > 0]
        assert len(warm) == 1
        before = warm[0].routed["interactive"]
        router.add_request(Request(uid=1, prompt=shared + [31],
                                   max_new_tokens=4))
        assert warm[0].routed["interactive"] == before + 1


# ---------------------------------------------------------------------------
# Failure containment
# ---------------------------------------------------------------------------

class TestEvacuation:
    def test_failed_replica_work_finishes_elsewhere(self, setup):
        run, params = setup
        router = _router(run, params)
        reqs = _reqs(PROMPTS[:4], n=4, priority="interactive")
        for r in reqs:
            router.add_request(r)
        victim = router.replicas[0]
        victim.engine.step = lambda: (_ for _ in ()).throw(
            RuntimeError("injected device loss"))
        done = router.run_until_done()
        assert victim.guard.tripped == "step_failures"
        assert victim.evacuated
        assert {r.uid for r in done} == {r.uid for r in reqs}
        assert all(r.status == "finished" for r in reqs)
        assert router.replicas[1].routed["interactive"] == 4


# ---------------------------------------------------------------------------
# Service-time ITL + stats parity
# ---------------------------------------------------------------------------

class TestServiceTimeITL:
    def test_itl_samples_are_engine_service_seconds(self, setup):
        run, params = setup
        eng = ServeEngine(run, params, slots=2, max_seq=64)
        reqs = _reqs(PROMPTS[:2], n=5, priority="interactive")
        for r in reqs:
            eng.add_request(r)
        eng.run_until_done()
        ring = eng.class_itl["interactive"]
        # first token per request sets the mark without a sample
        assert len(ring) == sum(len(r.output) for r in reqs) - len(reqs)
        assert all(g >= 0.0 for g in ring)
        # samples are deltas of one monotone service clock, so any
        # single gap is bounded by the engine's total service seconds
        assert max(ring) <= eng.service_s
        for r in reqs:
            key, mark = r.service_mark
            assert key == id(eng) and 0.0 < mark <= eng.service_s

    def test_fleet_throughput_key_parity(self, setup):
        run, params = setup
        router = _router(run, params, slo_itl_ms=50.0)
        empty = router.throughput()
        for r in _reqs(PROMPTS[:4], n=4):
            router.add_request(r)
        router.run_until_done()
        full = router.throughput()
        assert set(empty) == set(full)
        assert set(empty["per_class"]) == set(PRIORITIES)
        for e, f in zip(empty["per_replica"], full["per_replica"]):
            assert set(e) == set(f)
        for p in PRIORITIES:
            assert set(empty["per_class"][p]) == set(full["per_class"][p])


# ---------------------------------------------------------------------------
# Cross-replica determinism
# ---------------------------------------------------------------------------

class TestDeterminism:
    def test_token_streams_identical_across_replica_counts(self, setup):
        run, params = setup
        outs = {}
        for n in (1, 2):
            router = _router(run, params, replicas=n, slo_itl_ms=50.0)
            reqs = _reqs(PROMPTS, n=6)
            for r in reqs:
                router.add_request(r)
            router.run_until_done()
            assert all(r.status == "finished" for r in reqs)
            outs[n] = {r.uid: r.output for r in reqs}
        assert outs[1] == outs[2]
        # and both match a bare single engine (routing is placement
        # only — it never changes what a stream decodes)
        eng = ServeEngine(run, params, slots=2, max_seq=64)
        reqs = _reqs(PROMPTS, n=6)
        for r in reqs:
            eng.add_request(r)
        eng.run_until_done()
        assert {r.uid: r.output for r in reqs} == outs[2]


# ---------------------------------------------------------------------------
# Multi-device placement (subprocess — the parent must keep 1 device)
# ---------------------------------------------------------------------------

_CHILD = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"

import dataclasses
import jax

from repro.configs import registry
from repro.configs.base import ParallelConfig, RunConfig
from repro.models.api import get_model
from repro.serve.router import ServeRouter
from repro.serve.scheduler import Request

assert len(jax.devices()) == 2, jax.devices()
cfg = dataclasses.replace(registry.get("llama3.2-1b").smoke,
                          dtype="float32")
run = RunConfig(model=cfg, parallel=ParallelConfig())
params, _ = get_model(cfg).init(jax.random.PRNGKey(0))

router = ServeRouter(run, params, replicas=2, devices=jax.devices(),
                     slots=2, max_seq=64, slo_itl_ms=50.0)
# each replica's params and KV pool are committed to its own device
placements = []
for rep in router.replicas:
    leaf = jax.tree.leaves(rep.engine.params)[0]
    (dev,) = leaf.devices()
    (kv_dev,) = jax.tree.leaves(rep.engine.pool.cache)[0].devices()
    assert dev == kv_dev == rep.engine.device, (dev, kv_dev)
    placements.append(dev)
assert placements[0] != placements[1], placements

reqs = [Request(uid=i, prompt=[i + 1, 2, 3], max_new_tokens=4,
                priority="interactive" if i % 2 == 0 else "batch")
        for i in range(4)]
for r in reqs:
    router.add_request(r)
router.run_until_done()
assert all(r.status == "finished" for r in reqs), \
    [(r.uid, r.status) for r in reqs]
tp = router.throughput()
assert tp["tokens"] == 16, tp["tokens"]
print("OK", placements)
"""


def test_router_places_replicas_on_two_devices():
    env = dict(os.environ)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.path.join(root, "src") + os.pathsep \
        + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", _CHILD], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, (proc.stdout[-2000:], proc.stderr[-2000:])
    assert "OK" in proc.stdout

"""Serve-tier hardening: chaos suite.

Drives every :data:`repro.serve.faults.INJECTION_POINTS` entry against
both pool layouts and both cache dtypes and pins the failure-semantics
contract (see ``src/repro/serve/README.md``):

* every request that leaves the engine carries exactly one terminal
  :data:`repro.serve.scheduler.STATUSES` status — under injected
  allocation failures, NaN logits, corrupted scales, expired deadlines,
  cancels, and preemption storms alike;
* after drain ``used_bytes() == 0`` and ``check_integrity()`` holds
  (the pool oracle runs after EVERY step via ``debug=True``);
* a quarantined / cancelled / expired stream never perturbs its
  co-batched neighbors: surviving greedy streams are bit-identical to
  an unpoisoned run;
* degradation engages under sustained pressure and recovers with
  hysteresis once pressure clears;
* the no-progress watchdog fails survivors explicitly instead of
  hanging or silently losing requests.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.configs.base import ParallelConfig, RunConfig
from repro.kernels import ops as kops
from repro.models.api import get_model
from repro.quant import kv as kvq
from repro.serve import guard
from repro.serve.engine import Request, ServeEngine
from repro.serve.faults import FaultInjector, NULL_INJECTOR
from repro.serve.pool import (IntegrityError, KVPoolManager,
                              PagedKVPoolManager)
from repro.serve.scheduler import DegradationPolicy, LoadShedder, STATUSES
from repro.train.fault_tolerance import StragglerDetector


@pytest.fixture(scope="module")
def setup():
    # f32 model dtype: several tests compare full token streams
    # bit-exactly, so near-tied bf16 argmaxes must not inject flakiness.
    cfg = dataclasses.replace(registry.get("llama3.2-1b").smoke,
                              dtype="float32")
    run = RunConfig(model=cfg, parallel=ParallelConfig())
    m = get_model(cfg)
    params, _ = m.init(jax.random.PRNGKey(0))
    return run, m, params


def _engine(run, params, **kw):
    kw.setdefault("slots", 2)
    kw.setdefault("max_seq", 64)
    kw.setdefault("debug", True)          # integrity oracle every step
    if kw.get("kv_layout") == "paged":
        kw.setdefault("kv_block_size", 16)
    return ServeEngine(run, params, **kw)


def _drained(eng, reqs):
    """The terminal-consistency contract every chaos run must meet."""
    for r in reqs:
        assert r.done and r.status in STATUSES, (r.uid, r.status)
    assert eng.pool.used_bytes() == 0
    assert eng.pool.check_integrity()
    assert not eng.scheduler.busy()


LONG = tuple((i * 7 + 3) % 50 + 1 for i in range(21))
LAYOUTS = ("slot", "paged")


# ---------------------------------------------------------------------------
# FaultInjector unit
# ---------------------------------------------------------------------------

class TestFaultInjector:
    def test_unknown_point_rejected(self):
        with pytest.raises(ValueError):
            FaultInjector(rates={"bogus": 1.0})
        with pytest.raises(ValueError):
            FaultInjector().fire("bogus")

    def test_schedule_fires_exact_consultations(self):
        inj = FaultInjector(schedule={"pool_alloc": [2, 4]})
        assert [inj.fire("pool_alloc") for _ in range(5)] == \
            [False, True, False, True, False]
        assert inj.calls["pool_alloc"] == 5
        assert inj.fired["pool_alloc"] == 2

    def test_rate_stream_deterministic_per_seed(self):
        def draw(seed):
            inj = FaultInjector(seed, rates={"nan_logits": 0.5})
            return [inj.fire("nan_logits") for _ in range(64)]
        assert draw(7) == draw(7)
        assert draw(7) != draw(8)

    def test_points_draw_independent_streams(self):
        """Consulting OTHER points must not shift a point's pattern."""
        solo = FaultInjector(3, rates={"pool_alloc": 0.5})
        duo = FaultInjector(3, rates={"pool_alloc": 0.5,
                                      "radix_match": 0.5})
        pattern_solo, pattern_duo = [], []
        for _ in range(64):
            pattern_solo.append(solo.fire("pool_alloc"))
            duo.fire("radix_match")       # interleaved extra draws
            pattern_duo.append(duo.fire("pool_alloc"))
        assert pattern_solo == pattern_duo

    def test_max_fires_caps_total(self):
        inj = FaultInjector(rates={"pool_alloc": 1.0},
                            max_fires={"pool_alloc": 3})
        assert sum(inj.fire("pool_alloc") for _ in range(10)) == 3

    def test_null_injector_inert_and_cheap(self):
        assert not NULL_INJECTOR.active
        assert not NULL_INJECTOR.fire("pool_alloc")
        # unconfigured points short-circuit before any bookkeeping
        assert NULL_INJECTOR.calls["pool_alloc"] == 0

    def test_report_covers_configured_points_only(self):
        inj = FaultInjector(schedule={"slow_step": [1]},
                            rates={"kernel_gate": 0.0})
        inj.fire("slow_step")
        rep = inj.report()
        assert set(rep) == {"slow_step", "kernel_gate"}
        assert rep["slow_step"] == {"calls": 1, "fired": 1}


# ---------------------------------------------------------------------------
# Numerical watchdog units (guard + KV scale overflow)
# ---------------------------------------------------------------------------

class TestGuard:
    def _rows(self):
        logits = jax.random.normal(jax.random.PRNGKey(3), (4, 32))
        temps = jnp.array([0.0, 0.7, 0.0, 1.3], jnp.float32)
        return jax.random.PRNGKey(11), logits, temps

    def test_clean_rows_match_unguarded_sampler(self):
        key, logits, temps = self._rows()
        toks, bad = guard.sample_and_flag(key, logits, temps)
        assert not np.asarray(bad).any()
        safe = jnp.where(temps > 0, temps, 1.0)
        ref = jnp.where(temps > 0,
                        jax.random.categorical(key, logits / safe[:, None],
                                               axis=-1),
                        jnp.argmax(logits, axis=-1))
        np.testing.assert_array_equal(np.asarray(toks), np.asarray(ref))

    @pytest.mark.parametrize("poison", [jnp.nan, jnp.inf, -jnp.inf])
    def test_poisoned_row_flagged_neighbors_bit_identical(self, poison):
        key, logits, temps = self._rows()
        clean_toks, _ = guard.sample_and_flag(key, logits, temps)
        toks, bad = guard.sample_and_flag(
            key, logits.at[2, 5].set(poison), temps)
        np.testing.assert_array_equal(np.asarray(bad),
                                      [False, False, True, False])
        for row in (0, 1, 3):
            assert int(toks[row]) == int(clean_toks[row])
        # the flagged row still yields a valid (in-range) token id —
        # the engine discards it, but a NaN must never index memory
        assert 0 <= int(toks[2]) < logits.shape[-1]


class TestKVScaleOverflowGuard:
    def _pool(self, s=8, warm=4):
        new = jax.random.normal(jax.random.PRNGKey(5), (1, warm, 2, 4))
        return kvq.kv_write_chunk(jnp.zeros((1, s, 2, 4), jnp.int8),
                                  jnp.zeros((1, 2, 4), jnp.float32),
                                  new, jnp.asarray(0))

    @pytest.mark.parametrize("poison", [jnp.nan, jnp.inf])
    def test_token_write_preserves_history_and_scale(self, poison):
        """A non-finite decode write must corrupt only its own row: the
        running-max scale keeps its old (finite) value, the slot's int8
        history survives bit-exact, and the poisoned row lands as 0."""
        cq, sc = self._pool()
        bad = jnp.full((1, 2, 4), poison)
        cq2, sc2 = kvq.kv_write_token(cq, sc, bad, jnp.asarray([4]))
        np.testing.assert_array_equal(np.asarray(sc2), np.asarray(sc))
        np.testing.assert_array_equal(np.asarray(cq2[:, :4]),
                                      np.asarray(cq[:, :4]))
        assert not np.asarray(cq2[:, 4]).any()

    def test_chunk_write_keeps_scale_finite(self):
        cq, sc = self._pool()
        chunk = jnp.full((1, 2, 2, 4), jnp.inf)
        cq2, sc2 = kvq.kv_write_chunk(cq, sc, chunk, jnp.asarray(4))
        assert np.isfinite(np.asarray(sc2)).all()
        np.testing.assert_array_equal(np.asarray(cq2[:, :4]),
                                      np.asarray(cq[:, :4]))
        assert not np.asarray(cq2[:, 4:6]).any()

    def test_kv_scales_clamped(self):
        x = jnp.zeros((1, 4, 2, 4)).at[0, 1, 0, 0].set(jnp.inf) \
            .at[0, 2, 1, 1].set(1e38)
        sc = np.asarray(kvq.kv_scales(x, axis=1))
        assert np.isfinite(sc).all()
        assert (sc <= kvq.KV_SCALE_MAX).all()

    def test_quantize_kv_tree_sanitizes_nonfinite(self):
        k = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 8, 2, 4))
        tree = {"kv": {"k": k.at[0, 0, 3].set(jnp.nan),
                       "v": jnp.abs(k)}}
        q = kvq.quantize_kv_tree(tree, prompt_len=jnp.asarray(6))
        for leaf in jax.tree_util.tree_leaves(q):
            assert np.isfinite(np.asarray(leaf, np.float32)).all()
        assert not np.asarray(q["kv"]["k_q"][0, 0, 3]).any()


class TestKernelGate:
    def test_injected_rejection_forces_fallback(self):
        geometry = dict(m=8, c=64, s=64, r=8)
        assert kops.kernel_fits("lowrank", **geometry)
        kops.set_fault_injector(FaultInjector(rates={"kernel_gate": 1.0}))
        try:
            assert not kops.kernel_fits("lowrank", **geometry)
        finally:
            kops.set_fault_injector(None)
        assert kops.kernel_fits("lowrank", **geometry)


# ---------------------------------------------------------------------------
# Lifecycle: cancel from every state (COW block counts asserted)
# ---------------------------------------------------------------------------

class TestCancel:
    @pytest.mark.parametrize("layout", LAYOUTS)
    def test_cancel_waiting(self, setup, layout):
        run, _, params = setup
        eng = _engine(run, params, kv_layout=layout)
        reqs = [Request(uid=i, prompt=[3, 4, 5], max_new_tokens=4)
                for i in range(2)]
        for r in reqs:
            eng.add_request(r)
        assert eng.cancel(1)
        eng.run_until_done()
        assert reqs[0].status == "finished"
        assert reqs[1].status == "cancelled" and not reqs[1].output
        _drained(eng, reqs)

    def test_cancel_unknown_or_terminal_returns_false(self, setup):
        run, _, params = setup
        eng = _engine(run, params)
        req = Request(uid=0, prompt=[3, 4, 5], max_new_tokens=2)
        eng.add_request(req)
        assert not eng.cancel(99)
        eng.run_until_done()
        assert not eng.cancel(0)          # already terminal
        assert req.status == "finished"

    def test_cancel_mid_prefill_frees_blocks(self, setup):
        run, _, params = setup
        eng = _engine(run, params, kv_layout="paged", prefill_chunk=8)
        req = Request(uid=0, prompt=list(LONG), max_new_tokens=4)
        eng.add_request(req)
        eng.step()                        # admitted, chunk 1 of 3
        assert eng.scheduler.prefilling
        assert eng.pool.blocks.used_blocks() > 0
        assert eng.cancel(0)
        assert req.status == "cancelled"
        # no KV landed -> nothing published: every block physically free
        assert eng.pool.blocks.used_blocks() == 0
        assert all(r == 0 for r in eng.pool.blocks.ref)
        _drained(eng, [req])

    @pytest.mark.parametrize("layout", LAYOUTS)
    def test_cancel_active_mid_decode(self, setup, layout):
        run, _, params = setup
        eng = _engine(run, params, kv_layout=layout)
        req = Request(uid=0, prompt=[5, 6, 7, 8], max_new_tokens=16)
        eng.add_request(req)
        for _ in range(6):
            eng.step()
            if req.output:
                break
        assert req.output and not req.done
        assert eng.cancel(0)
        assert req.status == "cancelled"
        _drained(eng, [req])

    def test_cancel_preempted_in_queue(self, setup):
        run, _, params = setup
        eng = _engine(run, params)
        req = Request(uid=0, prompt=[5, 6, 7], max_new_tokens=16)
        eng.add_request(req)
        for _ in range(4):
            eng.step()
            if req.output:
                break
        eng.scheduler.preempt(0)          # requeued with its prefix
        eng.pool.release(0)
        assert eng.scheduler.waiting and req.preemptions == 1
        assert eng.cancel(0)
        assert req.status == "cancelled"
        _drained(eng, [req])

    def test_cancel_cow_shared_releases_exact_blocks(self, setup):
        """Cancelling a stream attached copy-on-write to radix blocks
        must drop exactly the refcounts admission took: shared blocks
        return to cold (still cached), fresh ones to free."""
        run, _, params = setup
        eng = _engine(run, params, kv_layout="paged", prefill_chunk=8)
        base = list(LONG) + [31] * 11     # 32 tokens = 2 full blocks
        first = Request(uid=0, prompt=base, max_new_tokens=4)
        eng.add_request(first)
        eng.run_until_done()
        assert first.status == "finished"
        pool = eng.pool
        assert pool.blocks.used_blocks() == 0
        cold0 = set(pool.blocks.cold)
        assert cold0                      # prefix published at release
        twin = Request(uid=1, prompt=list(base), max_new_tokens=4)
        eng.add_request(twin)
        eng.step()                        # admit: radix match + fresh
        ps = next(p for p in eng.scheduler.prefilling if p.req.uid == 1)
        assert ps.written >= pool.block_size      # prefix actually shared
        assert pool._shared[ps.slot] >= 1
        assert pool.blocks.used_blocks() == len(pool.tables[ps.slot])
        assert eng.cancel(1)
        assert twin.status == "cancelled"
        assert pool.blocks.used_blocks() == 0
        assert all(r == 0 for r in pool.blocks.ref)
        assert set(pool.blocks.cold) == cold0     # shares went back cold
        _drained(eng, [first, twin])


# ---------------------------------------------------------------------------
# Deadlines, queue timeouts, preemption-retry budget
# ---------------------------------------------------------------------------

class TestDeadlinesAndDrops:
    def test_deadline_expires_in_queue(self, setup):
        run, _, params = setup
        eng = _engine(run, params)
        doomed = Request(uid=0, prompt=[3, 4], max_new_tokens=4,
                         deadline_s=0.0)
        ok = Request(uid=1, prompt=[5, 6], max_new_tokens=4)
        eng.add_request(doomed)
        eng.add_request(ok)
        eng.run_until_done()
        assert doomed.status == "deadline_exceeded" and not doomed.output
        assert ok.status == "finished"
        assert eng.deadline_expired == 1
        _drained(eng, [doomed, ok])

    def test_max_queue_s_only_counts_queue_time(self, setup):
        run, _, params = setup
        eng = _engine(run, params)
        req = Request(uid=0, prompt=[3, 4, 5], max_new_tokens=6,
                      max_queue_s=30.0)
        eng.add_request(req)
        for _ in range(3):
            eng.step()
        assert req.output                 # admitted and decoding
        req.submit_time -= 100.0          # "queued" long ago
        eng.run_until_done()
        assert req.status == "finished"   # admitted streams are exempt
        _drained(eng, [req])

    @pytest.mark.parametrize("layout", LAYOUTS)
    def test_deadline_expires_mid_flight(self, setup, layout):
        run, _, params = setup
        eng = _engine(run, params, kv_layout=layout, prefill_chunk=8)
        decoding = Request(uid=0, prompt=[3, 4, 5], max_new_tokens=32,
                           deadline_s=30.0)
        prefilling = Request(uid=1, prompt=list(LONG), max_new_tokens=8,
                             deadline_s=30.0)
        eng.add_request(decoding)
        eng.add_request(prefilling)
        eng.step()
        assert eng.scheduler.prefilling   # uid 1 still chunking
        for r in (decoding, prefilling):
            r.submit_time -= 100.0
        eng.run_until_done()
        assert decoding.status == "deadline_exceeded"
        assert prefilling.status == "deadline_exceeded"
        assert eng.deadline_expired == 2
        _drained(eng, [decoding, prefilling])

    def test_preempt_within_budget_requeues_then_finishes(self, setup):
        run, _, params = setup
        eng = _engine(run, params)
        req = Request(uid=0, prompt=[5, 6, 7], max_new_tokens=8,
                      max_preemptions=2)
        eng.add_request(req)
        for _ in range(4):
            eng.step()
            if req.output:
                break
        eng.scheduler.preempt(0)
        eng.pool.release(0)
        assert req.status is None and eng.scheduler.waiting
        eng.run_until_done()
        assert req.status == "finished" and req.preemptions == 1
        _drained(eng, [req])

    def test_preemption_budget_exhaustion_drops(self, setup):
        run, _, params = setup
        eng = _engine(run, params)
        req = Request(uid=0, prompt=[5, 6, 7], max_new_tokens=8,
                      max_preemptions=0)
        eng.add_request(req)
        eng.step()
        eng.scheduler.preempt(0)
        eng.pool.release(0)
        assert req.status == "dropped"
        assert not eng.scheduler.waiting
        _drained(eng, [req])

    def test_pressure_storm_drops_over_budget_stream(self, setup):
        """Engine-level: sustained KV pressure preempts the youngest
        stream; with a zero retry budget it terminates ``dropped``
        instead of thrashing, and the survivor finishes normally."""
        run, m, params = setup
        budget = KVPoolManager(m, 2, 64).bytes_per_token * 12
        eng = _engine(run, params, kv_byte_budget=budget,
                      degradation=False)
        old = Request(uid=0, prompt=[3, 4, 5], max_new_tokens=16)
        young = Request(uid=1, prompt=[6, 7, 8], max_new_tokens=16,
                        max_preemptions=0)
        eng.add_request(old)
        eng.add_request(young)
        eng.run_until_done()
        assert old.status == "finished" and len(old.output) == 16
        assert young.status == "dropped"
        _drained(eng, [old, young])


# ---------------------------------------------------------------------------
# Quarantine: NaN logits and corrupted scales
# ---------------------------------------------------------------------------

class TestQuarantine:
    @pytest.mark.parametrize("layout", LAYOUTS)
    def test_nan_decode_quarantines_victim_survivor_bit_identical(
            self, setup, layout):
        run, _, params = setup
        prompts = ([9, 10, 11, 12], [20, 21, 22])
        clean = _engine(run, params, kv_layout=layout)
        reqs0 = [Request(uid=i, prompt=list(p), max_new_tokens=8)
                 for i, p in enumerate(prompts)]
        for r in reqs0:
            clean.add_request(r)
        clean.run_until_done()

        inj = FaultInjector(schedule={"nan_logits": [3]},
                            params={"nan_logits": {"seg": "decode",
                                                   "slot": 0}})
        eng = _engine(run, params, kv_layout=layout, faults=inj)
        reqs = [Request(uid=i, prompt=list(p), max_new_tokens=8)
                for i, p in enumerate(prompts)]
        for r in reqs:
            eng.add_request(r)
        eng.run_until_done()
        assert reqs[0].status == "failed"         # slot 0 = first admit
        assert len(reqs[0].output) < 8            # killed mid-stream
        assert reqs[0].output == reqs0[0].output[:len(reqs[0].output)]
        assert reqs[1].status == "finished"
        assert reqs[1].output == reqs0[1].output  # neighbor untouched
        assert eng.quarantined == 1
        assert inj.fired["nan_logits"] == 1
        _drained(eng, reqs)

    def test_nan_prefill_quarantines_before_first_token(self, setup):
        run, _, params = setup
        inj = FaultInjector(schedule={"nan_logits": [1]},
                            params={"nan_logits": {"seg": "prefill_chunk"}})
        eng = _engine(run, params, faults=inj)
        req = Request(uid=0, prompt=[3, 4, 5], max_new_tokens=4)
        eng.add_request(req)
        eng.run_until_done()
        assert req.status == "failed" and not req.output
        assert eng.quarantined == 1
        _drained(eng, [req])

    @pytest.mark.parametrize("layout", LAYOUTS)
    def test_corrupted_scale_block_trips_watchdog(self, setup, layout):
        """``block_scale`` poisons the first inserted stream's int8
        scales: its next decode logits go non-finite and the watchdog
        must quarantine exactly that stream."""
        run, _, params = setup
        inj = FaultInjector(schedule={"block_scale": [1]})
        eng = _engine(run, params, kv_layout=layout, kv_quantize="int8",
                      faults=inj)
        victim = Request(uid=0, prompt=[9, 10, 11, 12], max_new_tokens=8)
        bystander = Request(uid=1, prompt=[20, 21, 22], max_new_tokens=8)
        eng.add_request(victim)
        eng.add_request(bystander)
        eng.run_until_done()
        assert inj.fired["block_scale"] == 1
        assert victim.status == "failed"
        assert bystander.status == "finished"
        assert len(bystander.output) == 8
        assert eng.quarantined == 1
        _drained(eng, [victim, bystander])


# ---------------------------------------------------------------------------
# Graceful degradation
# ---------------------------------------------------------------------------

class TestDegradation:
    def test_load_shedder_hysteresis(self):
        policy = DegradationPolicy(window=8, engage=0.5, disengage=0.125,
                                   budget_factor=0.5, min_engaged_steps=4)
        shed = LoadShedder(policy, base_budget=16)
        for _ in range(3):
            assert not shed.observe(True)
        assert shed.observe(True)                 # 4/8 >= watermark
        assert shed.budget == 8
        # pressure stops, but the dwell + dead band hold it engaged
        for _ in range(3):
            assert shed.observe(False)
        for _ in range(10):
            if not shed.observe(False):
                break
        assert not shed.engaged and shed.budget == 16
        assert shed.engage_count == 1 and shed.recover_count == 1
        # one isolated pressure blip must not re-engage (no flapping)
        assert not shed.observe(True)
        assert shed.engage_count == 1

    def test_engine_engages_and_recovers(self, setup):
        run, m, params = setup
        budget = KVPoolManager(m, 2, 64).bytes_per_token * 20
        eng = _engine(run, params, kv_byte_budget=budget)
        reqs = [Request(uid=i, prompt=[i + 2] * 8, max_new_tokens=16)
                for i in range(4)]
        for r in reqs:
            eng.add_request(r)
        eng.run_until_done()
        tp = eng.throughput()
        assert tp["degradation_engages"] >= 1
        assert tp["shed_steps"] >= 1
        # pressure is gone: idle steps keep observing and must recover
        for _ in range(2 * eng.shedder.policy.window):
            if not eng.shedder.engaged:
                break
            eng.step()
        assert not eng.shedder.engaged
        assert eng.scheduler.step_token_budget == eng.step_token_budget
        for r in reqs:
            assert r.status in ("finished", "dropped")
        _drained(eng, reqs)

    def test_degradation_disabled(self, setup):
        run, _, params = setup
        eng = _engine(run, params, degradation=False)
        assert eng.shedder is None
        req = Request(uid=0, prompt=[3, 4], max_new_tokens=2)
        eng.add_request(req)
        eng.run_until_done()
        assert "shed_steps" not in eng.throughput()
        _drained(eng, [req])


# ---------------------------------------------------------------------------
# Watchdogs: no-progress stall, max_steps, stragglers
# ---------------------------------------------------------------------------

class TestWatchdogs:
    def test_stall_fails_survivors_instead_of_hanging(self, setup):
        """With every allocation failing, admission can never proceed;
        the old loop span silently forever — now the no-progress
        watchdog terminates the survivors ``failed`` and returns."""
        run, _, params = setup
        inj = FaultInjector(rates={"pool_alloc": 1.0})
        eng = _engine(run, params, faults=inj, stall_steps=4,
                      degradation=False)
        reqs = [Request(uid=i, prompt=[3, 4, 5], max_new_tokens=4)
                for i in range(2)]
        for r in reqs:
            eng.add_request(r)
        done = eng.run_until_done()
        assert len(done) == 2
        assert all(r.status == "failed" for r in reqs)
        assert eng.throughput()["status_counts"] == {"failed": 2}
        assert eng.scheduler.admit_failures >= 4
        _drained(eng, reqs)

    def test_max_steps_exhaustion_raises(self, setup):
        run, _, params = setup
        eng = _engine(run, params)
        eng.add_request(Request(uid=0, prompt=[3, 4, 5],
                                max_new_tokens=32))
        with pytest.raises(RuntimeError, match="steps exhausted"):
            eng.run_until_done(max_steps=3)
        eng.run_until_done()              # plenty of steps: drains fine
        assert eng.finished[0].status == "finished"

    def test_slow_step_trips_straggler_detector(self, setup):
        run, _, params = setup
        eng = _engine(run, params)
        eng.add_request(Request(uid=0, prompt=[3, 4], max_new_tokens=6))
        eng.run_until_done()              # warm every compile first
        eng.stragglers = StragglerDetector()   # fresh EWMA, warm steps
        eng.runner.faults = FaultInjector(
            schedule={"slow_step": [5]},
            params={"slow_step": {"seconds": 0.75}})
        req = Request(uid=1, prompt=[5, 6], max_new_tokens=8)
        eng.add_request(req)
        eng.run_until_done()
        assert req.status == "finished"
        assert eng.throughput()["slow_steps"] >= 1
        assert any(s["straggler"] for s in eng.stats)


# ---------------------------------------------------------------------------
# check_integrity as an oracle
# ---------------------------------------------------------------------------

class TestIntegrityOracle:
    def test_slot_pool_passes_then_catches_corruption(self, setup):
        _, m, _ = setup
        pool = KVPoolManager(m, 2, 64)
        assert pool.check_integrity()
        pool.allocate(0, 5, tokens=[1, 2, 3, 4, 5])
        pool.positions[0] = 5
        assert pool.check_integrity()
        pool.lengths[1] = 7               # free slot holding state
        with pytest.raises(IntegrityError, match="free slot 1"):
            pool.check_integrity()

    def test_paged_pool_catches_refcount_drift(self, setup):
        _, m, _ = setup
        pool = PagedKVPoolManager(m, 2, 64, block_size=16)
        toks = list(range(1, 20))
        pool.allocate(0, len(toks), tokens=toks)
        assert pool.check_integrity()
        pool.blocks.ref[pool.tables[0][0]] += 1
        with pytest.raises(IntegrityError, match="refcount mismatch"):
            pool.check_integrity()

    def test_paged_pool_catches_table_leak(self, setup):
        _, m, _ = setup
        pool = PagedKVPoolManager(m, 2, 64, block_size=16)
        pool.allocate(0, 3, tokens=[1, 2, 3])
        stray = pool.blocks.free[0]
        pool.tables[0].append(stray)      # referenced but never alloc'd
        with pytest.raises(IntegrityError):
            pool.check_integrity()


# ---------------------------------------------------------------------------
# Chaos matrix: every injection point x layout x cache dtype
# ---------------------------------------------------------------------------

class TestChaosMatrix:
    @pytest.mark.parametrize("layout", LAYOUTS)
    @pytest.mark.parametrize("kv_mode", [None, "int8"])
    def test_converges_to_consistent_terminal_state(self, setup, layout,
                                                    kv_mode):
        """All points at once, seeded: whatever fires, every request
        ends with an explicit status, the pool drains to zero bytes,
        and the per-step integrity oracle never trips."""
        run, _, params = setup
        inj = FaultInjector(
            seed=3,
            rates={"pool_alloc": 0.1, "radix_match": 0.5,
                   "nan_logits": 0.05, "block_scale": 0.25,
                   "kernel_gate": 0.1},
            params={"nan_logits": {"seg": "decode", "slot": 0}},
            max_fires={"pool_alloc": 6, "nan_logits": 2,
                       "block_scale": 2})
        eng = _engine(run, params, kv_layout=layout, kv_quantize=kv_mode,
                      faults=inj, prefill_chunk=8, stall_steps=16)
        prompts = [LONG, LONG[:13], (2, 3, 4, 5), list(LONG),
                   (9,) * 10, (4, 5, 6)]
        reqs = [Request(uid=i, prompt=list(p), max_new_tokens=6,
                        max_preemptions=4)
                for i, p in enumerate(prompts)]
        reqs[4].deadline_s = 0.0          # one guaranteed expiry
        for r in reqs:
            eng.add_request(r)
        try:
            eng.run_until_done()
        finally:
            # the engine installed the module-global kernel_gate hook
            # (kernel_fits is consulted at trace time, far from any
            # serve object) — never leak it into later tests
            kops.set_fault_injector(None)
        _drained(eng, reqs)
        counts = eng.throughput()["status_counts"]
        assert sum(counts.values()) == len(reqs)
        assert counts.get("deadline_exceeded", 0) >= 1
        # every configured point was actually consulted (the injection
        # seams are wired), except paged-only / int8-only ones
        assert inj.calls["pool_alloc"] > 0
        assert inj.calls["nan_logits"] > 0
        if layout == "paged":
            assert inj.calls["radix_match"] > 0
        if kv_mode == "int8":
            assert inj.calls["block_scale"] > 0
        if inj.fired["nan_logits"] or inj.fired["block_scale"]:
            assert eng.quarantined >= 1


# ---------------------------------------------------------------------------
# Acceptance: mixed load with cancels + deadlines, survivors bit-exact
# ---------------------------------------------------------------------------

class TestMixedLoadExactness:
    def test_survivor_streams_identical_to_clean_run(self, setup):
        """10 greedy requests on a paged COW pool; one cancelled
        mid-flight, one expiring its deadline.  The other eight token
        streams must be bit-identical to a run with no lifecycle events
        at all."""
        run, _, params = setup
        prompts = [list(LONG), list(LONG[:17]) + [33, 34],
                   [2, 3, 4, 5], [7] * 9, [11, 12], [13, 14, 15, 16],
                   [17] * 6, [19, 20, 21], [23, 24], [25, 26, 27]]

        clean = _engine(run, params, kv_layout="paged", prefill_chunk=8)
        ref = [Request(uid=i, prompt=list(p), max_new_tokens=6)
               for i, p in enumerate(prompts)]
        for r in ref:
            clean.add_request(r)
        clean.run_until_done()
        assert all(r.status == "finished" for r in ref)

        eng = _engine(run, params, kv_layout="paged", prefill_chunk=8)
        reqs = [Request(uid=i, prompt=list(p), max_new_tokens=6)
                for i, p in enumerate(prompts)]
        reqs[3].deadline_s = 0.0                  # ~10% expired
        for r in reqs:
            eng.add_request(r)
        for _ in range(64):                       # ~10% cancelled,
            eng.step()                            # strictly mid-flight
            if reqs[7].output:
                break
        assert eng.cancel(7)
        eng.run_until_done()

        assert reqs[3].status == "deadline_exceeded" and not reqs[3].output
        assert reqs[7].status == "cancelled"
        assert reqs[7].output == ref[7].output[:len(reqs[7].output)]
        for i in set(range(10)) - {3, 7}:
            assert reqs[i].status == "finished"
            assert reqs[i].output == ref[i].output, i
        _drained(eng, reqs)

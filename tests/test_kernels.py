"""Pallas kernels vs the jnp oracle — shape/dtype sweeps (interpret=True)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels import ops, ref


def _tol(dtype):
    return dict(atol=3e-2, rtol=3e-2) if dtype == jnp.bfloat16 \
        else dict(atol=1e-5, rtol=1e-5)


LOWRANK_SHAPES = [
    (256, 512, 128, 512),
    (300, 512, 128, 640),     # unaligned M/S -> padding path
    (512, 1024, 256, 2048),
    (64, 256, 8, 256),        # tiny rank
    (1024, 256, 64, 128),
    (8, 128, 16, 384),        # M smaller than a tile
]


@pytest.mark.parametrize("m,c,r,s", LOWRANK_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_lowrank_matmul_allclose(m, c, r, s, dtype, rng):
    ks = jax.random.split(rng, 3)
    x = (jax.random.normal(ks[0], (m, c), jnp.float32) * 0.1).astype(dtype)
    w0 = (jax.random.normal(ks[1], (c, r), jnp.float32) * 0.05).astype(dtype)
    w1 = (jax.random.normal(ks[2], (r, s), jnp.float32) * 0.05).astype(dtype)
    got = ops.lowrank_matmul(x, w0, w1, force_kernel=True)
    want = ref.lowrank_matmul_ref(x, w0, w1)
    assert got.dtype == want.dtype and got.shape == want.shape
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


BRANCHED_SHAPES = [
    (256, 512, 64, 64, 512, 4),
    (200, 256, 32, 32, 300, 2),    # unaligned
    (512, 512, 128, 128, 1024, 8),
    (128, 384, 16, 32, 256, 3),    # r1 != r2, odd branch count
]


@pytest.mark.parametrize("m,c,r1,r2,s,n", BRANCHED_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_branched_matmul_allclose(m, c, r1, r2, s, n, dtype, rng):
    ks = jax.random.split(rng, 4)
    x = (jax.random.normal(ks[0], (m, c), jnp.float32) * 0.1).astype(dtype)
    u = (jax.random.normal(ks[1], (n, c, r1), jnp.float32) * 0.05
         ).astype(dtype)
    xc = (jax.random.normal(ks[2], (n, r1, r2), jnp.float32) * 0.1
          ).astype(dtype)
    v = (jax.random.normal(ks[3], (n, r2, s), jnp.float32) * 0.05
         ).astype(dtype)
    got = ops.branched_matmul(x, u, xc, v, force_kernel=True)
    want = ref.branched_matmul_ref(x, u, xc, v)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@given(m=st.integers(1, 80), c=st.sampled_from([64, 192]),
       r=st.sampled_from([16, 48]), s=st.sampled_from([64, 160]))
@settings(max_examples=12, deadline=None)
def test_lowrank_property_leading_dims(m, c, r, s):
    """ops wrapper handles arbitrary leading batch dims + ragged M."""
    key = jax.random.PRNGKey(m * 7 + c)
    ks = jax.random.split(key, 3)
    x = jax.random.normal(ks[0], (2, m, c), jnp.float32) * 0.1
    w0 = jax.random.normal(ks[1], (c, r), jnp.float32) * 0.1
    w1 = jax.random.normal(ks[2], (r, s), jnp.float32) * 0.1
    got = ops.lowrank_matmul(x, w0, w1, force_kernel=True)
    want = ref.lowrank_matmul_ref(x.reshape(-1, c), w0, w1).reshape(2, m, s)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-4, rtol=1e-4)


def test_oversize_falls_back_to_ref(rng):
    """Geometries exceeding the VMEM budget dispatch to the jnp path."""
    x = jax.random.normal(rng, (32, 16384), jnp.float32)
    w0 = jax.random.normal(rng, (16384, 4096), jnp.float32) * 0.01
    w1 = jax.random.normal(rng, (4096, 8192), jnp.float32) * 0.01
    got = ops.lowrank_matmul(x, w0, w1)          # no force -> fallback
    want = ref.lowrank_matmul_ref(x, w0, w1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-2, atol=2e-2)


def test_kernel_equals_dense_when_factors_from_svd(rng):
    """End-to-end: SVD factors through the kernel reproduce the dense
    layer at full rank."""
    from repro.core.svd import svd_decompose
    w = jax.random.normal(rng, (256, 384), jnp.float32) * 0.1
    f = svd_decompose(w, 256)
    x = jax.random.normal(jax.random.fold_in(rng, 1), (128, 256)) * 0.1
    got = ops.lowrank_matmul(x, f.w0, f.w1, force_kernel=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(x @ w),
                               atol=1e-3, rtol=1e-3)

"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests must see 1 CPU device
(only launch/dryrun.py forces 512 placeholder devices, in its own process).
"""
import jax
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")

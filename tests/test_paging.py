"""Paged KV pool: block allocator, radix prefix cache, paged CachePlan
families, the block-table fused decode kernel, and paged serving.

The load-bearing invariants:

* N requests sharing a block-aligned prompt prefix store that prefix's
  KV blocks exactly ONCE — via the admit-time radix match AND via the
  insert-time adoption dedup for concurrently admitted twins (physical
  block counts asserted);
* a shared block is never written (copy-on-write = fresh allocation
  past the divergence point; the tail partial block is always private);
* paged greedy decode is token-identical to the slot pool for f32
  (bit-exact: exact gather + identical attention op order) and int8;
* the paged decode kernel matches its ref.py oracle <= 1e-2 in
  interpret mode, for f32 / int8 / softcap, and the ``kernel_fits``
  fallback dispatch returns the oracle bit-for-bit;
* preemption under a block byte budget stays greedy-deterministic, and
  ``used_bytes`` returns to exactly zero after every release.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.configs.base import ModelConfig, ParallelConfig, RunConfig
from repro.layers import cache as cache_mod
from repro.kernels import ops, ref
from repro.models.api import get_model
from repro.quant import kv as kvq
from repro.serve.engine import Request, ServeEngine
from repro.serve.paging import BlockPool, RadixPrefixCache
from repro.serve.pool import KVPoolManager, PagedKVPoolManager

BS = 16


@pytest.fixture(scope="module")
def setup():
    cfg = dataclasses.replace(registry.get("llama3.2-1b").smoke,
                              dtype="float32")
    run = RunConfig(model=cfg, parallel=ParallelConfig())
    m = get_model(cfg)
    params, _ = m.init(jax.random.PRNGKey(0))
    return run, m, params


def _engine(run, params, **kw):
    kw.setdefault("slots", 2)
    kw.setdefault("max_seq", 64)
    kw.setdefault("prefill_chunk", 8)
    return ServeEngine(run, params, **kw)


def _serve(eng, prompts, n=6):
    reqs = [Request(uid=i, prompt=list(p), max_new_tokens=n)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.add_request(r)
    eng.run_until_done()
    assert all(r.done for r in reqs)
    return [r.output for r in reqs]


# robust prompt: int8 quantization noise (per-slot OR per-block scales)
# stays below every greedy argmax margin along this trajectory
ROBUST = tuple((i * 7 + 14) % 50 + 1 for i in range(21))
SHARED = tuple((i * 5 + 2) % 60 + 1 for i in range(33))   # 2 full blocks


# ---------------------------------------------------------------------------
# RadixPrefixCache / BlockPool units
# ---------------------------------------------------------------------------

class TestRadix:
    def test_match_block_aligned_prefix_only(self):
        rx = RadixPrefixCache(4)
        rx.insert(list(range(8)), [10, 11])
        assert rx.match(list(range(8))) == [10, 11]
        assert rx.match(list(range(12))) == [10, 11]    # tail not cached
        assert rx.match(list(range(4)) + [99] * 4) == [10]
        assert rx.match([99] * 8) == []
        assert rx.match([0, 1, 2]) == []                # partial block

    def test_insert_first_writer_wins(self):
        rx = RadixPrefixCache(4)
        rx.insert(list(range(8)), [10, 11])
        kept = rx.insert(list(range(8)), [20, 21])
        assert kept == [10, 11]                         # theirs survived
        assert 20 not in rx and 21 not in rx

    def test_forget_leaf_only(self):
        rx = RadixPrefixCache(4)
        rx.insert(list(range(8)), [10, 11])
        assert not rx.is_leaf(10) and rx.is_leaf(11)
        rx.forget(11)
        assert 11 not in rx and rx.is_leaf(10)
        assert rx.match(list(range(8))) == [10]


class TestBlockPool:
    def test_refcount_states(self):
        bp = BlockPool(4, 4)
        a = bp.alloc()
        assert bp.used_blocks() == 1 and bp.free_capacity() == 3
        bp.release(a)                       # unregistered -> free
        assert bp.used_blocks() == 0
        b = bp.alloc()
        bp.register(list(range(4)), [b])
        bp.release(b)                       # registered -> cold
        assert bp.used_blocks() == 0 and bp.free_capacity() == 4
        ids = bp.match_retain(list(range(4)))
        assert ids == [b] and bp.used_blocks() == 1   # cold -> warm

    def test_lru_cold_eviction_is_leaf_only(self):
        bp = BlockPool(2, 4)
        a, b = bp.alloc(), bp.alloc()
        bp.register(list(range(8)), [a, b])   # a interior, b leaf
        bp.release(a)
        bp.release(b)
        c = bp.alloc()                        # must evict leaf b, not a
        assert c == b
        assert bp.match_peek(list(range(8))) == [a]
        assert bp.stats.evictions == 1

    def test_exhaustion_raises(self):
        bp = BlockPool(1, 4)
        bp.alloc()
        with pytest.raises(RuntimeError):
            bp.alloc()

    def test_match_retain_cap(self):
        bp = BlockPool(4, 4)
        a, b = bp.alloc(), bp.alloc()
        bp.register(list(range(8)), [a, b])
        bp.release(a)
        bp.release(b)
        # cap one token short of the full match: the last block must
        # stay unmatched so at least one token re-prefills
        assert bp.match_retain(list(range(8)), max_tokens=7) == [a]
        assert bp.ref[a] == 1 and bp.ref[b] == 0


# ---------------------------------------------------------------------------
# CachePlan paged families
# ---------------------------------------------------------------------------

class TestPagedPlan:
    GEOM = cache_mod.PagedGeometry(block_size=4, num_blocks=8, slots=2,
                                   blocks_per_slot=4)

    def test_families_and_spec(self):
        plan = cache_mod.gqa_paged_plan(2, 8, jnp.float32,
                                        geometry=self.GEOM)
        assert plan.family == "gqa_paged_f32"
        spec = plan.spec(9, 4)              # (num_blocks + 1, block_size)
        assert spec["k"] == jax.ShapeDtypeStruct((9, 4, 2, 8), jnp.float32)
        assert spec["block_tables"] == jax.ShapeDtypeStruct((2, 4),
                                                            jnp.int32)
        init = plan.init(9, 4)
        assert int(init["block_tables"].min()) == self.GEOM.dummy_block

    def test_int8_blocked_scales(self):
        plan = cache_mod.gqa_paged_plan(2, 8, jnp.float32, "int8",
                                        geometry=self.GEOM)
        assert plan.family == "gqa_paged_int8" and plan.quantized
        spec = plan.spec(9, 4)
        assert spec["k_q"] == jax.ShapeDtypeStruct((9, 4, 2, 8), jnp.int8)
        # ONE scale row per physical block, blocked with its values
        assert spec["k_scale"] == jax.ShapeDtypeStruct((9, 2, 8),
                                                       jnp.float32)

    def test_bytes_per_block(self):
        plan = cache_mod.gqa_paged_plan(2, 8, jnp.float32,
                                        geometry=self.GEOM)
        assert plan.bytes_per_block == 4 * plan.bytes_per_token
        planq = cache_mod.gqa_paged_plan(2, 8, jnp.float32, "int8",
                                         geometry=self.GEOM)
        # int8 values + the block's f32 scale rows
        assert planq.bytes_per_block == 4 * (2 * 2 * 8) + 2 * 2 * 8 * 4

    def test_prefill_writes_rejected(self):
        plan = cache_mod.gqa_paged_plan(2, 8, jnp.float32,
                                        geometry=self.GEOM)
        cache = plan.init(9, 4)
        with pytest.raises(ValueError):
            plan.write_prefill(cache, {"k": jnp.zeros((1, 4, 2, 8)),
                                       "v": jnp.zeros((1, 4, 2, 8))})

    def test_plan_from_cache_roundtrip(self):
        for q in (None, "int8"):
            plan = cache_mod.gqa_paged_plan(2, 8, jnp.float32, q,
                                            geometry=self.GEOM)
            got = cache_mod.plan_from_cache(plan.init(9, 4), jnp.float32)
            assert got.family == plan.family
            assert got.paged == self.GEOM

    def test_mla_paged_rejected(self):
        cfg = ModelConfig(
            name="mla-t", family="dense", mla=True, num_layers=1,
            d_model=64, num_heads=4, num_kv_heads=4, d_ff=128,
            vocab_size=64, q_lora_rank=0, kv_lora_rank=32, qk_rope_dim=16,
            qk_nope_dim=32, v_head_dim=32, dtype="float32")
        with pytest.raises(ValueError):
            cache_mod.build_cache_plan(cfg, jnp.float32, None, self.GEOM)

    def test_decode_write_oob_hits_dummy(self):
        """At position == max_seq the write must land in the dummy
        block, not clamp onto the stream's last real block."""
        geom = self.GEOM
        plan = cache_mod.gqa_paged_plan(2, 8, jnp.float32, geometry=geom)
        cache = plan.init(9, 4)
        bt = cache["block_tables"].at[0].set(jnp.arange(4, dtype=jnp.int32))
        cache["block_tables"] = bt.at[1].set(
            jnp.arange(4, 8, dtype=jnp.int32))
        key = jax.random.PRNGKey(0)
        cache["k"] = jax.random.normal(key, cache["k"].shape)
        cache["v"] = jax.random.normal(key, cache["v"].shape)
        before_k = cache["k"]
        new = {"k": jnp.ones((2, 2, 8)), "v": jnp.ones((2, 2, 8))}
        out = plan.write_decode(cache, new,
                                jnp.asarray([geom.max_seq, 3]))
        # slot 0 (full) wrote only the dummy block; slot 1 wrote
        # row 3 of its first block (physical block 4)
        np.testing.assert_array_equal(
            np.asarray(out["k"][:8]),
            np.asarray(before_k.at[4, 3].set(1.0)[:8]))
        assert float(out["k"][geom.dummy_block, 0].min()) == 1.0


# ---------------------------------------------------------------------------
# Paged decode kernel vs oracle
# ---------------------------------------------------------------------------

def _paged_case(key, b=2, kh=2, g=2, d=16, nb=6, bs=8, nblk=3):
    ks = jax.random.split(key, 4)
    q = jax.random.normal(ks[0], (b, 1, kh * g, d), jnp.float32)
    k = jax.random.normal(ks[1], (nb + 1, bs, kh, d), jnp.float32)
    v = jax.random.normal(ks[2], (nb + 1, bs, kh, d), jnp.float32)
    # distinct physical blocks per stream, some entries at the dummy
    bt = jnp.asarray([[0, 2, nb], [1, 4, 5]], jnp.int32)[:b, :nblk]
    cache_pos = jnp.asarray([11, 20][:b], jnp.int32)
    return q, k, v, bt, cache_pos


class TestPagedKernel:
    def test_f32_matches_ref(self):
        q, k, v, bt, pos = _paged_case(jax.random.PRNGKey(0))
        want = ref.decode_attention_paged_ref(q, k, v, bt, pos)
        got = ops.decode_attention_paged(q, k, v, bt, pos,
                                         force_kernel=True)
        assert float(jnp.max(jnp.abs(got - want))) <= 1e-2
        assert got.shape == q.shape

    def test_f32_softcap(self):
        q, k, v, bt, pos = _paged_case(jax.random.PRNGKey(1))
        want = ref.decode_attention_paged_ref(q, k, v, bt, pos,
                                              softcap=20.0)
        got = ops.decode_attention_paged(q, k, v, bt, pos, softcap=20.0,
                                         force_kernel=True)
        assert float(jnp.max(jnp.abs(got - want))) <= 1e-2

    def test_f32_ref_matches_slot_gqa_exactly(self):
        """Paged f32 attention == the slot path's gqa_decode_attention
        bit-for-bit when the gathered blocks reproduce the slot cache —
        the op-order contract behind paged==slot token identity."""
        q, k, v, bt, pos = _paged_case(jax.random.PRNGKey(2))
        b, d = q.shape[0], q.shape[-1]
        kh = k.shape[2]
        ks = k[bt].reshape(b, -1, kh, d)
        vs = v[bt].reshape(b, -1, kh, d)
        valid = jnp.arange(ks.shape[1])[None, :] <= pos[:, None]
        want = cache_mod.gqa_decode_attention(q, ks, vs, valid, 0.0)
        got = ref.decode_attention_paged_ref(q, k, v, bt, pos)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_int8_matches_ref(self):
        q, k, v, bt, pos = _paged_case(jax.random.PRNGKey(3))
        k_scale = kvq.kv_scales(k, axis=1)
        v_scale = kvq.kv_scales(v, axis=1)
        k_q = kvq.quantize_kv(k, k_scale[:, None])
        v_q = kvq.quantize_kv(v, v_scale[:, None])
        want = ref.decode_attention_paged_q_ref(q, k_q, k_scale, v_q,
                                                v_scale, bt, pos)
        got = ops.decode_attention_paged_q(q, k_q, k_scale, v_q, v_scale,
                                           bt, pos, force_kernel=True)
        assert float(jnp.max(jnp.abs(got - want))) <= 1e-2

    def test_fallback_dispatch(self, monkeypatch):
        """With the VMEM budget squeezed to nothing, kernel_fits routes
        both wrappers to the jnp oracle bit-for-bit."""
        monkeypatch.setattr(ops, "VMEM_BUDGET", 1)
        assert not ops.kernel_fits("decode_attn_paged", 2, c=16, s=8, r=2,
                                   q_bytes=4, bn=8)
        q, k, v, bt, pos = _paged_case(jax.random.PRNGKey(4))
        want = ref.decode_attention_paged_ref(q, k, v, bt, pos)
        got = ops.decode_attention_paged(q, k, v, bt, pos)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        k_scale = kvq.kv_scales(k, axis=1)
        v_scale = kvq.kv_scales(v, axis=1)
        k_q = kvq.quantize_kv(k, k_scale[:, None])
        v_q = kvq.quantize_kv(v, v_scale[:, None])
        want_q = ref.decode_attention_paged_q_ref(q, k_q, k_scale, v_q,
                                                  v_scale, bt, pos)
        got_q = ops.decode_attention_paged_q(q, k_q, k_scale, v_q, v_scale,
                                             bt, pos)
        np.testing.assert_array_equal(np.asarray(got_q), np.asarray(want_q))


# ---------------------------------------------------------------------------
# PagedKVPoolManager: sharing, accounting, round trips
# ---------------------------------------------------------------------------

class TestPagedPool:
    def _prefill_stream(self, m, params, prompt, max_seq=64):
        stream = m.init_cache(1, max_seq)
        toks = jnp.asarray([list(prompt)], jnp.int32)
        pad = jnp.zeros((1, max_seq - len(prompt)), jnp.int32)
        logits, stream = m.prefill(
            params, {"tokens": jnp.concatenate([toks, pad], 1)}, stream,
            last_pos=jnp.asarray(len(prompt) - 1))
        return stream, int(jnp.argmax(logits[0]))

    def test_insert_gather_roundtrip_exact(self, setup):
        run, m, params = setup
        pool = PagedKVPoolManager(m, 2, 64, block_size=BS)
        stream, _ = self._prefill_stream(m, params, SHARED)
        pool.allocate(0, len(SHARED), tokens=list(SHARED))
        pool.insert(stream, 0, len(SHARED))
        pool.release(0)
        matched = pool.allocate(1, len(SHARED), tokens=list(SHARED))
        assert matched == (len(SHARED) // BS) * BS      # 2 full blocks
        staged = pool.gather_prefix(m.init_cache(1, 64), 1, matched)

        def first_leaf(tree, name):
            if isinstance(tree, dict):
                if name in tree:
                    return tree[name]
                for v in tree.values():
                    r = first_leaf(v, name)
                    if r is not None:
                        return r
            return None
        for name in ("k", "v"):
            want = first_leaf(stream, name)[..., 0, :matched, :, :]
            got = first_leaf(staged, name)[..., 0, :matched, :, :]
            np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_prefix_stored_once_admit_match(self, setup):
        """Sequential same-prefix requests re-attach to the registered
        blocks: block count grows by the private tail only."""
        run, m, params = setup
        pool = PagedKVPoolManager(m, 3, 64, block_size=BS)
        stream, _ = self._prefill_stream(m, params, SHARED)
        pool.allocate(0, len(SHARED), tokens=list(SHARED))
        pool.insert(stream, 0, len(SHARED))
        pool.release(0)
        n_shared = (len(SHARED) // BS)                  # 2 full blocks
        pool.allocate(0, len(SHARED), tokens=list(SHARED))
        pool.allocate(1, len(SHARED), tokens=list(SHARED))
        pool.allocate(2, len(SHARED), tokens=list(SHARED))
        # 2 shared prefix blocks + one private tail block per stream
        assert pool.physical_blocks_in_use() == n_shared + 3
        assert pool.blocks.ref[pool.tables[0][0]] == 3
        # copy-on-write: every stream's tail block is private
        tails = {pool.tables[i][-1] for i in range(3)}
        assert len(tails) == 3
        st = pool.prefix_stats()
        assert st["prefix_block_hits"] == 3 * n_shared

    def test_used_bytes_counts_shared_once(self, setup):
        run, m, params = setup
        pool = PagedKVPoolManager(m, 2, 64, block_size=BS)
        stream, _ = self._prefill_stream(m, params, SHARED)
        pool.allocate(0, len(SHARED), tokens=list(SHARED))
        pool.insert(stream, 0, len(SHARED))
        pool.release(0)
        pool.allocate(0, len(SHARED), tokens=list(SHARED))
        pool.allocate(1, len(SHARED), tokens=list(SHARED))
        assert pool.used_bytes() == 4 * pool.bytes_per_block  # 2+1+1
        pool.release(0)
        pool.release(1)
        assert pool.used_bytes() == 0

    def test_grow_allocates_block_on_crossing(self, setup):
        run, m, params = setup
        pool = PagedKVPoolManager(m, 2, 64, block_size=BS)
        pool.allocate(0, BS - 1, tokens=list(range(1, BS)))
        assert len(pool.tables[0]) == 1
        pool.positions[0] = BS - 1                      # as if inserted
        pool.grow(0, token=7)                           # crosses into blk 1
        assert len(pool.tables[0]) == 2


# ---------------------------------------------------------------------------
# Engine end to end: token identity, sharing, preemption
# ---------------------------------------------------------------------------

class TestPagedEngine:
    def test_f32_token_identical_to_slot(self, setup):
        run, m, params = setup
        base = _serve(_engine(run, params), [ROBUST, (4, 5, 6)])
        out = _serve(_engine(run, params, kv_layout="paged"),
                     [ROBUST, (4, 5, 6)])
        assert out == base

    def test_int8_token_identical_to_slot(self, setup):
        run, m, params = setup
        base = _serve(_engine(run, params, kv_quantize="int8"),
                      [ROBUST, (4, 5, 6)])
        out = _serve(_engine(run, params, kv_quantize="int8",
                             kv_layout="paged"), [ROBUST, (4, 5, 6)])
        assert out == base
        assert base == _serve(_engine(run, params), [ROBUST, (4, 5, 6)])

    def test_concurrent_twins_store_prefix_once(self, setup):
        """Identical prompts admitted in the SAME wave (nothing in the
        radix yet) converge at insert: the adoption dedup retains the
        first twin's registered blocks and frees the duplicates."""
        run, m, params = setup
        eng = _engine(run, params, slots=3, kv_layout="paged")
        reqs = [Request(uid=i, prompt=list(SHARED), max_new_tokens=16)
                for i in range(3)]
        for r in reqs:
            eng.add_request(r)
        n_shared = len(SHARED) // BS
        seen = []
        for _ in range(200):
            if not eng.scheduler.busy():
                break
            eng.step()
            if all(r is not None for r in eng.scheduler.active):
                seen.append(eng.pool.physical_blocks_in_use())
        assert all(r.done for r in reqs)
        assert seen, "streams never cohabited"
        # while all three decoded together (before any block growth
        # past the prompt): 2 shared + 3 private tails = 5, not 9
        assert min(seen) == n_shared + 3
        assert reqs[0].output == reqs[1].output == reqs[2].output
        # the two later twins each adopted the first's registered blocks
        assert eng.pool.prefix_stats()["adopted_blocks"] == 2 * n_shared

    def test_shared_prefix_outputs_match_slot(self, setup):
        run, m, params = setup
        prompts = [list(SHARED) + [40 + i] for i in range(4)]
        base = _serve(_engine(run, params, slots=2), prompts, n=4)
        out = _serve(_engine(run, params, slots=2, kv_layout="paged"),
                     prompts, n=4)
        assert out == base

    def test_paged_preempt_requeue_deterministic(self, setup):
        """Block-budget preemption requeues the youngest stream; it
        re-admits onto its own radix-registered blocks and finishes
        with EXACTLY the unconstrained greedy tokens."""
        run, m, params = setup
        # both streams cross block boundaries mid-decode: 15+20 -> 3
        # blocks, 3+20 -> 2 blocks; a 3-block budget must preempt
        prompts = [ROBUST[:15], (9, 8, 7)]
        base = _serve(_engine(run, params, kv_layout="paged"), prompts,
                      n=20)
        eng = _engine(run, params, kv_layout="paged")
        bpb = eng.pool.bytes_per_block
        eng2 = _engine(run, params, kv_layout="paged",
                       kv_byte_budget=int(bpb * 3))
        out = _serve(eng2, prompts, n=20)
        assert eng2.preemptions > 0
        assert out == base
        assert eng2.pool.used_bytes() == 0

    def test_blocking_admission_rejected(self, setup):
        run, m, params = setup
        with pytest.raises(ValueError):
            _engine(run, params, kv_layout="paged", admission="blocking")

    def test_block_size_must_divide_max_seq(self, setup):
        run, m, params = setup
        with pytest.raises(ValueError):
            _engine(run, params, kv_layout="paged", max_seq=60)

    def test_plan_summary_reports_layout(self, setup):
        run, m, params = setup
        eng = _engine(run, params, kv_layout="paged", kv_quantize="int8")
        assert eng.plan_summary["kv_layout"] == "paged"
        assert eng.plan_summary["kv_cache_family"] == "gqa_paged_int8"
        assert _engine(run, params).plan_summary["kv_layout"] == "slot"

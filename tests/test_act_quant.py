"""Activation quantization (int8 x int8 prefill): kernels, dispatch, serving.

Covers the qa tentpole's acceptance surface:

* ``lowrank_matmul_qa`` / ``branched_matmul_qa`` match their exact-math
  oracles in interpret mode (<= 1e-2) and the weight-only int8 path
  within int8 tolerance;
* bucket-padded rows carry zero act scales — padded and unpadded
  launches are bit-identical on the real rows;
* when ``kernel_fits`` rejects a geometry the wrapper falls back to the
  oracle itself, so fallback output is exactly the reference;
* ``LinearPlan.kernel_for(act_quantize=True)`` picks the qa kernels
  only for fully int8 non-sparse plans and degrades to weight-only
  dispatch everywhere else;
* chunked-prefill greedy == whole-prefill greedy bit-exact with
  ``act_quantize="int8"``, for both f32 and int8 KV pools, and batched
  outputs equal isolated outputs (engine-level pad discipline).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.lowrank_matmul_qa import quantize_rows
from repro.layers import plan as lplan
from repro.layers.param import apply_linear
from repro.quant import quantize_array, quantize_tree


@pytest.fixture(scope="module")
def rng():
    return jax.random.PRNGKey(0)


def _lowrank(rng, c=128, r=32, s=64):
    ks = jax.random.split(rng, 2)
    return {"w0": jax.random.normal(ks[0], (c, r)) * 0.1,
            "w1": jax.random.normal(ks[1], (r, s)) * 0.1}


def _branched(rng, n=4, c=128, r1=16, r2=16, s=64):
    ks = jax.random.split(rng, 3)
    return {"u": jax.random.normal(ks[0], (n, c, r1)) * 0.1,
            "xc": jax.random.normal(ks[1], (n, r1, r2)) * 0.1,
            "v": jax.random.normal(ks[2], (n, r2, s)) * 0.1}


def _qfactors(rng, c, r, s):
    ks = jax.random.split(rng, 2)
    w0q, w0s = quantize_array(jax.random.normal(ks[0], (c, r)) * 0.05)
    w1q, w1s = quantize_array(jax.random.normal(ks[1], (r, s)) * 0.05)
    return w0q, w0s, w1q, w1s


class TestQuantizeRows:
    def test_roundtrip_bounded(self, rng):
        x = jax.random.normal(rng, (16, 256))
        q, s = quantize_rows(x)
        assert q.dtype == jnp.int8 and s.shape == (16, 1)
        rel = float(jnp.linalg.norm(q * s - x) / jnp.linalg.norm(x))
        assert rel <= 1e-2, rel

    def test_zero_rows_get_zero_scale(self, rng):
        x = jnp.zeros((4, 64)).at[1].set(
            jax.random.normal(rng, (64,)))
        q, s = quantize_rows(x)
        assert float(s[0, 0]) == 0.0 and float(s[2, 0]) == 0.0
        np.testing.assert_array_equal(np.asarray(q[0]), 0)
        assert float(s[1, 0]) > 0.0

    def test_scales_are_row_local(self, rng):
        """A huge row must not change its neighbours' quantization."""
        x = jax.random.normal(rng, (4, 64))
        loud = x.at[2].mul(1e4)
        q, s = quantize_rows(x)
        ql, sl = quantize_rows(loud)
        for i in (0, 1, 3):
            np.testing.assert_array_equal(np.asarray(q[i]),
                                          np.asarray(ql[i]))
            assert float(s[i, 0]) == float(sl[i, 0])


class TestKernelQA:
    """Interpret-mode parity for the fused act-quant kernels
    (satellite: both _qa kernels in the kernel test matrix)."""

    SHAPES = [
        (256, 512, 128, 512),
        (300, 512, 128, 640),     # unaligned M/S -> padding path
        (8, 128, 16, 384),        # M smaller than a tile
    ]

    @pytest.mark.parametrize("m,c,r,s", SHAPES)
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_lowrank_matches_oracle(self, m, c, r, s, dtype, rng):
        x = (jax.random.normal(rng, (m, c)) * 0.1).astype(dtype)
        w0q, w0s, w1q, w1s = _qfactors(jax.random.fold_in(rng, 1), c, r, s)
        got = ops.lowrank_matmul_qa(x, w0q, w0s, w1q, w1s,
                                    force_kernel=True)
        want = ref.lowrank_matmul_qa_ref(x, w0q, w0s, w1q, w1s)
        assert got.dtype == want.dtype and got.shape == want.shape
        # interpret mode may accumulate the int dots in f32; the real
        # MXU is exact int32, so the bar is loose but small.
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   atol=1e-2, rtol=1e-2)

    @pytest.mark.parametrize("m,c,r1,r2,s,n", [
        (256, 512, 64, 64, 512, 4),
        (300, 512, 64, 64, 640, 4),
        (8, 128, 16, 16, 384, 2),
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_branched_matches_oracle(self, m, c, r1, r2, s, n, dtype, rng):
        ks = jax.random.split(rng, 4)
        x = (jax.random.normal(ks[0], (m, c)) * 0.1).astype(dtype)
        uq, us = quantize_array(jax.random.normal(ks[1], (n, c, r1)) * 0.05)
        xcq, xcs = quantize_array(
            jax.random.normal(ks[2], (n, r1, r2)) * 0.05)
        vq, vs = quantize_array(jax.random.normal(ks[3], (n, r2, s)) * 0.05)
        got = ops.branched_matmul_qa(x, uq, us, xcq, xcs, vq, vs,
                                     force_kernel=True)
        want = ref.branched_matmul_qa_ref(x, uq, us, xcq, xcs, vq, vs)
        assert got.dtype == want.dtype and got.shape == want.shape
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   atol=1e-2, rtol=1e-2)

    @pytest.mark.parametrize("m,c,r,s", SHAPES)
    def test_within_int8_tolerance_of_weight_only_path(self, m, c, r, s,
                                                       rng):
        """Quantizing the activations on top of int8 weights stays
        within the same rel-err family as weight-only int8."""
        ks = jax.random.split(rng, 3)
        x = jax.random.normal(ks[0], (m, c), jnp.float32) * 0.1
        w0q, w0s, w1q, w1s = _qfactors(jax.random.fold_in(rng, 1), c, r, s)
        got = ops.lowrank_matmul_qa(x, w0q, w0s, w1q, w1s,
                                    force_kernel=True)
        want = ref.lowrank_matmul_q_ref(x, w0q, w0s, w1q, w1s)
        rel = float(jnp.linalg.norm(got - want) / jnp.linalg.norm(want))
        assert rel <= 5e-2, rel

    def test_padded_rows_bit_identical(self, rng):
        """Bucket padding discipline: appending zero rows (what the
        serve buckets do) leaves the real rows bit-for-bit unchanged —
        per-row scales make padding invisible."""
        m, c, r, s = 100, 256, 64, 256
        x = jax.random.normal(rng, (m, c), jnp.float32) * 0.1
        w0q, w0s, w1q, w1s = _qfactors(jax.random.fold_in(rng, 1), c, r, s)
        y = ops.lowrank_matmul_qa(x, w0q, w0s, w1q, w1s, force_kernel=True)
        xp = jnp.concatenate([x, jnp.zeros((28, c), x.dtype)])
        yp = ops.lowrank_matmul_qa(xp, w0q, w0s, w1q, w1s,
                                   force_kernel=True)
        np.testing.assert_array_equal(np.asarray(yp[:m]), np.asarray(y))
        np.testing.assert_array_equal(np.asarray(yp[m:]), 0.0)

    def test_padded_rows_bit_identical_branched(self, rng):
        m, c, r1, r2, s, n = 100, 256, 32, 32, 256, 4
        ks = jax.random.split(rng, 4)
        x = jax.random.normal(ks[0], (m, c), jnp.float32) * 0.1
        uq, us = quantize_array(jax.random.normal(ks[1], (n, c, r1)) * 0.05)
        xcq, xcs = quantize_array(
            jax.random.normal(ks[2], (n, r1, r2)) * 0.05)
        vq, vs = quantize_array(jax.random.normal(ks[3], (n, r2, s)) * 0.05)
        y = ops.branched_matmul_qa(x, uq, us, xcq, xcs, vq, vs,
                                   force_kernel=True)
        xp = jnp.concatenate([x, jnp.zeros((28, c), x.dtype)])
        yp = ops.branched_matmul_qa(xp, uq, us, xcq, xcs, vq, vs,
                                    force_kernel=True)
        np.testing.assert_array_equal(np.asarray(yp[:m]), np.asarray(y))
        np.testing.assert_array_equal(np.asarray(yp[m:]), 0.0)

    def test_oversize_falls_back_to_oracle_exactly(self, rng):
        """The fallback IS the oracle, so a rejected geometry returns
        bit-identical results to the reference."""
        x = jax.random.normal(rng, (16, 16384), jnp.float32) * 0.01
        w0q, w0s = quantize_array(
            jax.random.normal(rng, (16384, 4096)) * 0.01)
        w1q, w1s = quantize_array(
            jax.random.normal(rng, (4096, 8192)) * 0.01)
        assert not ops.kernel_fits("lowrank_qa", 16, c=16384, r=4096,
                                   s=8192)
        got = ops.lowrank_matmul_qa(x, w0q, w0s, w1q, w1s)   # no force
        want = ref.lowrank_matmul_qa_ref(x, w0q, w0s, w1q, w1s)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_leading_dims_flattened(self, rng):
        """(B, T, c) activations run through the same kernel."""
        b, t, c, r, s = 2, 48, 128, 32, 256
        x = jax.random.normal(rng, (b, t, c), jnp.float32) * 0.1
        w0q, w0s, w1q, w1s = _qfactors(jax.random.fold_in(rng, 1), c, r, s)
        got = ops.lowrank_matmul_qa(x, w0q, w0s, w1q, w1s,
                                    force_kernel=True)
        flat = ops.lowrank_matmul_qa(x.reshape(-1, c), w0q, w0s, w1q, w1s,
                                     force_kernel=True)
        assert got.shape == (b, t, s)
        np.testing.assert_array_equal(np.asarray(got.reshape(-1, s)),
                                      np.asarray(flat))


class TestPlanDispatch:
    def test_qa_kernel_names(self, rng):
        assert lplan.build_plan(quantize_tree(_lowrank(rng))) \
            .kernel_for((256, 128), True, act_quantize=True) == "lowrank_qa"
        assert lplan.build_plan(quantize_tree(_branched(rng))) \
            .kernel_for((256, 128), True, act_quantize=True) == "branched_qa"

    def test_off_by_default(self, rng):
        plan = lplan.build_plan(quantize_tree(_lowrank(rng)))
        assert plan.kernel_for((256, 128), True) == "lowrank_q"

    def test_requires_use_pallas(self, rng):
        plan = lplan.build_plan(quantize_tree(_lowrank(rng)))
        assert plan.kernel_for((256, 128), False, act_quantize=True) is None

    def test_unquantized_plan_ignores_flag(self, rng):
        plan = lplan.build_plan(_lowrank(rng))
        assert plan.kernel_for((256, 128), True,
                               act_quantize=True) == "lowrank"

    def test_fp8_weights_fall_back_to_weight_only(self, rng):
        plan = lplan.build_plan(quantize_tree(_lowrank(rng), "fp8"))
        assert plan.kernel_for((256, 128), True,
                               act_quantize=True) == "lowrank_q"

    def test_partial_quant_falls_back(self, rng):
        plan = lplan.build_plan(quantize_tree(_lowrank(rng),
                                              targets=("w0",)))
        assert plan.kernel_for((256, 128), True, act_quantize=True) is None

    @pytest.mark.parametrize("tree_fn", [_lowrank, _branched])
    def test_apply_linear_parity(self, tree_fn, rng):
        """End-to-end through the plan seam: act-quant execution stays
        within int8 tolerance of the weight-only quantized path."""
        pq = quantize_tree(tree_fn(rng))
        x = jax.random.normal(jax.random.fold_in(rng, 7),
                              (4, 40, 128)) * 0.1
        y_wq = apply_linear(pq, x, use_pallas=True)
        y_qa = apply_linear(pq, x, use_pallas=True, act_quantize=True)
        assert y_qa.shape == y_wq.shape and y_qa.dtype == y_wq.dtype
        rel = float(jnp.linalg.norm(y_qa - y_wq) / jnp.linalg.norm(y_wq))
        assert rel <= 5e-2, rel

    def test_apply_linear_flag_inert_without_quant(self, rng):
        p = _lowrank(rng)
        x = jax.random.normal(jax.random.fold_in(rng, 7), (8, 128)) * 0.1
        np.testing.assert_array_equal(
            np.asarray(apply_linear(p, x, use_pallas=True,
                                    act_quantize=True)),
            np.asarray(apply_linear(p, x, use_pallas=True)))


# ---------------------------------------------------------------------------
# Engine level: chunked == whole and batched == isolated under act-quant
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def serve_setup():
    from repro.configs import registry
    from repro.configs.base import LRDConfig, ParallelConfig, RunConfig
    from repro.core.surgery import decompose_model
    from repro.models.api import get_model

    # f32 model dtype: the equality tests compare full token streams.
    cfg = dataclasses.replace(registry.get("llama3.2-1b").smoke,
                              dtype="float32")
    lrd = LRDConfig(enabled=True, rank_mode="ratio", min_dim=32,
                    use_pallas=True)
    run = RunConfig(model=cfg, lrd=lrd, parallel=ParallelConfig())
    m = get_model(cfg)
    params, axes = m.init(jax.random.PRNGKey(0))
    p2, _, _ = decompose_model(params, axes, lrd)
    return run, m, p2


def _serve(eng, prompts, n=6):
    from repro.serve.engine import Request
    reqs = [Request(uid=i, prompt=list(p), max_new_tokens=n)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.add_request(r)
    eng.run_until_done()
    assert all(r.done for r in reqs)
    return [r.output for r in reqs]


LONG = tuple((i * 7 + 3) % 50 + 1 for i in range(21))


class TestServeActQuant:
    def _engine(self, run, params, **kw):
        from repro.serve.engine import ServeEngine
        kw.setdefault("slots", 2)
        kw.setdefault("max_seq", 64)
        kw.setdefault("quantize", "int8")
        kw.setdefault("act_quantize", "int8")
        return ServeEngine(run, params, **kw)

    @pytest.mark.parametrize("kvq_mode", [None, "int8"])
    def test_chunked_equals_whole_exact(self, serve_setup, kvq_mode):
        """Acceptance: chunked greedy bit-exact vs whole-prefill with
        act-quant enabled — chunk boundaries sit on row boundaries, so
        per-token scales see identical rows either way."""
        run, m, params = serve_setup
        out_b = _serve(self._engine(run, params, admission="blocking",
                                    kv_quantize=kvq_mode),
                       [LONG, (4, 5, 6)])
        eng_c = self._engine(run, params, admission="continuous",
                             prefill_chunk=8, kv_quantize=kvq_mode)
        out_c = _serve(eng_c, [LONG, (4, 5, 6)])
        assert out_b == out_c
        assert max(s["prefill_tokens"] for s in eng_c.stats) <= 8 + 3

    def test_chunk_size_invariant(self, serve_setup):
        """Different chunk sizes must agree token-for-token."""
        run, m, params = serve_setup
        out3 = _serve(self._engine(run, params, admission="continuous",
                                   prefill_chunk=3), [LONG])
        out8 = _serve(self._engine(run, params, admission="continuous",
                                   prefill_chunk=8), [LONG])
        assert out3 == out8

    def test_batched_equals_isolated(self, serve_setup):
        """Bucket padding at the engine level: a request's tokens are
        identical whether it shares a step with others or runs alone."""
        run, m, params = serve_setup
        prompts = [[3, 1, 4], [1, 5, 9, 2], [6, 5]]
        solo = [
            _serve(self._engine(run, params, slots=1), [p], n=5)[0]
            for p in prompts]
        batched = _serve(self._engine(run, params, slots=3), prompts, n=5)
        assert solo == batched

    def test_tokens_close_to_full_width_activations(self, serve_setup):
        """Act-quant perturbs logits at int8 scale; greedy streams stay
        mostly aligned with the weight-only int8 engine even on a
        random-init smoke model with near-uniform logits."""
        run, m, params = serve_setup
        out_f = _serve(self._engine(run, params, act_quantize=None),
                       [LONG, (4, 5, 6), (9, 8, 7, 6)], n=8)
        out_q = _serve(self._engine(run, params),
                       [LONG, (4, 5, 6), (9, 8, 7, 6)], n=8)
        flat_f = [t for o in out_f for t in o]
        flat_q = [t for o in out_q for t in o]
        match = sum(a == b for a, b in zip(flat_f, flat_q))
        assert match >= int(0.7 * len(flat_f)), (match, len(flat_f))

    def test_requires_weight_quant(self, serve_setup):
        from repro.serve.engine import ServeEngine
        run, m, params = serve_setup
        with pytest.raises(ValueError):
            ServeEngine(run, params, slots=1, max_seq=64,
                        act_quantize="int8")

    def test_config_knob_enables(self, serve_setup):
        run, m, params = serve_setup
        run_q = run.replace(lrd=dataclasses.replace(
            run.lrd, quantize="int8", act_quantize="int8"))
        from repro.serve.engine import ServeEngine
        eng = ServeEngine(run_q, params, slots=1, max_seq=64)
        assert eng.act_quantize == "int8"
        assert eng.runner.prefill_opts.act_quantize
        assert not eng.runner.opts.act_quantize    # decode stays f32

"""Unit + property tests for the paper's decompositions (Eq. 1-6)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import svd, tucker


class TestSVD:
    def test_full_rank_exact(self, rng):
        w = jax.random.normal(rng, (64, 48))
        f = svd.svd_decompose(w, 48)
        np.testing.assert_allclose(np.asarray(svd.reconstruct(f)),
                                   np.asarray(w), atol=1e-4)

    def test_factor_shapes(self, rng):
        f = svd.svd_decompose(jax.random.normal(rng, (64, 48)), 16)
        assert f.w0.shape == (64, 16) and f.w1.shape == (16, 48)

    def test_balanced_factors(self, rng):
        """Eq. 3: both factors carry sqrt(sigma) — comparable norms."""
        w = jax.random.normal(rng, (64, 64))
        f = svd.svd_decompose(w, 32)
        n0 = float(jnp.linalg.norm(f.w0))
        n1 = float(jnp.linalg.norm(f.w1))
        assert 0.5 < n0 / n1 < 2.0

    def test_truncation_is_best_rank_r(self, rng):
        """Eckart-Young: SVD truncation error equals the singular tail."""
        w = jax.random.normal(rng, (32, 32))
        s = jnp.linalg.svd(w, compute_uv=False)
        for r in (4, 16, 28):
            f = svd.svd_decompose(w, r)
            err = float(jnp.linalg.norm(w - svd.reconstruct(f)))
            tail = float(jnp.sqrt(jnp.sum(s[r:] ** 2)))
            assert abs(err - tail) < 1e-3

    def test_batched(self, rng):
        w = jax.random.normal(rng, (4, 32, 24))
        f = svd.svd_decompose(w, 24)
        assert f.w0.shape == (4, 32, 24)
        np.testing.assert_allclose(
            np.asarray(jnp.matmul(f.w0, f.w1)), np.asarray(w), atol=1e-4)

    def test_randomized_close_to_exact(self, rng):
        # low-rank-structured matrix: randomized SVD should nail it
        a = jax.random.normal(rng, (256, 16))
        b = jax.random.normal(jax.random.fold_in(rng, 1), (16, 128))
        w = a @ b
        f = svd.randomized_svd(w, 16)
        assert svd.approximation_error(w, f) < 1e-3

    def test_host_twin_matches(self, rng):
        w = np.asarray(jax.random.normal(rng, (32, 48)))
        w0, w1 = svd.host_svd_decompose(w, 16)
        f = svd.svd_decompose(jnp.asarray(w), 16)
        np.testing.assert_allclose(w0 @ w1, np.asarray(f.w0 @ f.w1),
                                   atol=1e-4)

    @given(c=st.integers(8, 96), s=st.integers(8, 96),
           alpha=st.floats(1.2, 8.0))
    @settings(max_examples=40, deadline=None)
    def test_ratio_rank_property(self, c, s, alpha):
        """ratio_rank always compresses by >= alpha (paper's Eq. 7 goal)."""
        r = svd.ratio_rank(c, s, alpha)
        assert 1 <= r <= min(c, s)
        if c * s >= alpha * (c + s):  # a rank >= 1 can hit alpha at all
            assert svd.compression_of_rank(c, s, r) >= alpha * 0.99

    def test_energy_rank_monotone(self, rng):
        w = jax.random.normal(rng, (64, 64))
        r90 = svd.energy_rank(w, 0.90)
        r99 = svd.energy_rank(w, 0.99)
        assert r90 <= r99 <= 64


class TestTucker:
    def test_full_rank_exact(self, rng):
        w = jax.random.normal(rng, (3, 3, 16, 32))
        f = tucker.tucker2_decompose(w, 16, 32)
        assert tucker.approximation_error(w, f) < 1e-5

    def test_shapes(self, rng):
        f = tucker.tucker2_decompose(
            jax.random.normal(rng, (3, 3, 32, 64)), 8, 16)
        assert f.u.shape == (32, 8)
        assert f.core.shape == (3, 3, 8, 16)
        assert f.v.shape == (16, 64)

    def test_truncation_monotone(self, rng):
        w = jax.random.normal(rng, (3, 3, 24, 24))
        errs = [tucker.approximation_error(
            w, tucker.tucker2_decompose(w, r, r)) for r in (4, 12, 24)]
        assert errs[0] >= errs[1] >= errs[2]

    @given(c=st.sampled_from([32, 64, 128]), s=st.sampled_from([32, 64, 256]),
           alpha=st.floats(1.5, 6.0))
    @settings(max_examples=30, deadline=None)
    def test_ratio_ranks_hit_compression(self, c, s, alpha):
        """Paper Eq. 7: returned ranks compress the conv by ~alpha."""
        k = 3
        r1, r2 = tucker.ratio_ranks(c, s, k, alpha)
        dense = tucker.dense_conv_params(c, s, k)
        got = dense / tucker.tucker2_params(c, s, k, r1, r2)
        assert got > alpha * 0.7      # integer rounding slack

    def test_params_formula(self):
        assert tucker.tucker2_params(64, 128, 3, 8, 16) \
            == 64 * 8 + 8 * 16 * 9 + 16 * 128

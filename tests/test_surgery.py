"""Whole-model surgery: targeting, ORG fallbacks, freezing, accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import LRDConfig
from repro.core.freezing import (frozen_param_count, trainable_mask,
                                 trainable_param_count)
from repro.core.surgery import classify_path, decompose_model
from repro.layers.param import (ParamBuilder, apply_linear, init_linear,
                                EMBED, FFN, VOCAB, EXPERTS)


@pytest.fixture
def small_tree(rng):
    pb = ParamBuilder(rng, jnp.float32)
    attn = pb.child("attn")
    init_linear(attn, "q", 256, 256, EMBED, "qkv")
    init_linear(attn, "o", 256, 256, "qkv", EMBED)
    mlp = pb.child("mlp")
    init_linear(mlp, "up", 256, 1024, EMBED, FFN)
    init_linear(mlp, "down", 1024, 256, FFN, EMBED)
    init_linear(pb, "unembed", 256, 2048, EMBED, VOCAB)
    ex = pb.child("moe").child("experts")
    ex.child("up").param("w", (4, 256, 512), (EXPERTS, EMBED, FFN))
    return pb


def test_targets_respected(small_tree):
    lrd = LRDConfig(enabled=True, rank_mode="ratio", min_dim=64,
                    targets=("ffn_up",))
    p2, _, rep = decompose_model(small_tree.params, small_tree.axes, lrd)
    assert "w0" in p2["mlp"]["up"]
    assert "w" in p2["mlp"]["down"]           # untargeted stays dense
    kinds = {d.path: d.kind for d in rep.decisions}
    assert kinds["mlp/up"] == "svd"
    assert kinds["unembed"] == "skip"


def test_min_dim_skip(small_tree):
    lrd = LRDConfig(enabled=True, rank_mode="ratio", min_dim=512)
    p2, _, rep = decompose_model(small_tree.params, small_tree.axes, lrd)
    assert "w" in p2["attn"]["q"]             # 256 < min_dim -> skipped


def test_expert_bank_batched(small_tree):
    lrd = LRDConfig(enabled=True, rank_mode="ratio", min_dim=64)
    p2, a2, _ = decompose_model(small_tree.params, small_tree.axes, lrd)
    w0 = p2["moe"]["experts"]["up"]["w0"]
    assert w0.ndim == 3 and w0.shape[0] == 4
    # reconstruction is per-expert
    w = small_tree.params["moe"]["experts"]["up"]["w"]
    rec = jnp.matmul(p2["moe"]["experts"]["up"]["w0"],
                     p2["moe"]["experts"]["up"]["w1"])
    assert rec.shape == w.shape


def test_search_mode_emits_org(small_tree):
    """Algorithm-1 mode: small memory-bound layers keep the original
    (the paper's ORG rows)."""
    lrd = LRDConfig(enabled=True, rank_mode="search", min_dim=64)
    p2, _, rep = decompose_model(small_tree.params, small_tree.axes, lrd,
                                 m_tokens=4096)
    orgs = [d for d in rep.decisions if d.kind == "org"]
    assert orgs, "expected at least one ORG decision on small layers"
    for d in orgs:
        assert d.params_after == d.params_before


def test_branched_surgery_and_apply(small_tree, rng):
    # 256->1024 @ 2x gives ratio rank 102 -> aligned(32) = 96; 96/2 >= 32
    # satisfies the per-branch MXU-tile guard -> branched subtree
    lrd = LRDConfig(enabled=True, rank_mode="aligned", rank_align=32,
                    min_dim=64, branches=2)
    p2, _, rep = decompose_model(small_tree.params, small_tree.axes, lrd)
    node = p2["mlp"]["up"]
    assert set(node) == {"u", "xc", "v"}
    x = jax.random.normal(rng, (8, 256)) * 0.1
    y_dense = apply_linear(small_tree.params["mlp"]["up"], x)
    y_br = apply_linear(node, x)
    assert y_br.shape == y_dense.shape
    # branched init == rank-r SVD (exact grouping, FC case)
    from repro.core.svd import svd_decompose
    f = svd_decompose(small_tree.params["mlp"]["up"]["w"],
                      node["u"].shape[-1] * 2)
    np.testing.assert_allclose(np.asarray(y_br),
                               np.asarray((x @ f.w0) @ f.w1),
                               atol=1e-3)


def test_freezing_masks(small_tree):
    lrd = LRDConfig(enabled=True, rank_mode="ratio", min_dim=64,
                    freeze=True)
    p2, _, _ = decompose_model(small_tree.params, small_tree.axes, lrd)
    mask = trainable_mask(p2, enabled=True)
    froz = frozen_param_count(p2, mask)
    train = trainable_param_count(p2, mask)
    assert froz > 0 and train > 0
    # every w0 frozen, every w1 trainable
    assert not jax.tree.leaves(mask_at(mask, "mlp", "up", "w0"))[0]
    assert jax.tree.leaves(mask_at(mask, "mlp", "up", "w1"))[0]


def mask_at(tree, *path):
    for p in path:
        tree = tree[p]
    return tree


def test_freeze_stops_gradient(small_tree, rng):
    lrd = LRDConfig(enabled=True, rank_mode="ratio", min_dim=64)
    p2, _, _ = decompose_model(small_tree.params, small_tree.axes, lrd)
    x = jax.random.normal(rng, (4, 256))

    def loss(p, freeze):
        return jnp.sum(apply_linear(p["mlp"]["up"], x,
                                    freeze_factors=freeze) ** 2)

    g_free = jax.grad(lambda p: loss(p, False))(p2)
    g_froz = jax.grad(lambda p: loss(p, True))(p2)
    assert float(jnp.abs(g_free["mlp"]["up"]["w0"]).max()) > 0
    assert float(jnp.abs(g_froz["mlp"]["up"]["w0"]).max()) == 0
    assert float(jnp.abs(g_froz["mlp"]["up"]["w1"]).max()) > 0


def test_classify_path():
    assert classify_path(("layers", "attn", "q")) == "attn_q"
    assert classify_path(("moe", "experts", "down")) == "moe_down"
    assert classify_path(("ssm", "in_proj")) == "ssm_in"
    assert classify_path(("unembed",)) == "unembed"


def test_surgery_accounting_consistent(small_tree):
    lrd = LRDConfig(enabled=True, rank_mode="ratio", min_dim=64)
    p2, _, rep = decompose_model(small_tree.params, small_tree.axes, lrd)
    got = sum(x.size for x in jax.tree.leaves(p2))
    # report covers only linear subtrees == the whole small tree here
    assert rep.params_after == got
    s = rep.summary()
    assert 0 < s["param_ratio"] < 1
